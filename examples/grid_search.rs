//! Model selection scenario: (C, γ) grid search with cross-validation on
//! a Breiman benchmark — the §7 protocol that produced Table 1's
//! hyper-parameters — run twice to show the warm-start session win:
//! the seeded sweep answers the same grid in fewer solver iterations.
//!
//! ```sh
//! cargo run --release --example grid_search
//! ```

use pasmo::data::synth::twonorm;
use pasmo::ensure;
use pasmo::svm::gridsearch::{grid_search, log_grid, WarmStart};
use pasmo::svm::{SolverChoice, Trainer};
use pasmo::util::error::Result;

fn main() -> Result<()> {
    let ds = twonorm(600, 7);
    println!("grid search on twonorm (ℓ={}, d={})\n", ds.len(), ds.dim());

    let base = Trainer::rbf(1.0, 1.0).solver(SolverChoice::Pasmo);
    let cs = log_grid(10.0, -2, 2);
    let gammas = log_grid(10.0, -3, 0);
    let cold = grid_search(&ds, &cs, &gammas, 4, 1, &base, WarmStart::Cold);
    let warm = grid_search(&ds, &cs, &gammas, 4, 1, &base, WarmStart::Seeded);

    println!("{:>10} {:>10} {:>8} {:>12} {:>12}", "C", "gamma", "cv-acc", "iters(cold)", "iters(warm)");
    for (p, w) in cold.evaluated.iter().zip(&warm.evaluated) {
        let mark = if p.c == cold.best.c && p.gamma == cold.best.gamma { "  <-- best" } else { "" };
        println!(
            "{:>10} {:>10} {:>8.4} {:>12} {:>12}{}",
            p.c, p.gamma, p.cv_accuracy, p.iterations, w.iterations, mark
        );
    }
    println!(
        "\nbest: C={} γ={} cv-accuracy={:.4}\n\
         (paper's Table 1 for twonorm: C=0.5, γ=0.02 — same order of magnitude)\n\
         total solver iterations: cold={} warm-started={}",
        cold.best.c,
        cold.best.gamma,
        cold.best.cv_accuracy,
        cold.total_iterations,
        warm.total_iterations,
    );
    ensure!(cold.best.cv_accuracy > 0.9, "twonorm should be very learnable");
    ensure!(
        warm.total_iterations < cold.total_iterations,
        "warm-started grid should need fewer iterations ({} vs {})",
        warm.total_iterations,
        cold.total_iterations
    );
    println!("grid_search OK");
    Ok(())
}
