//! Model selection scenario: (C, γ) grid search with cross-validation on
//! a Breiman benchmark — the §7 protocol that produced Table 1's
//! hyper-parameters.
//!
//! ```sh
//! cargo run --release --example grid_search
//! ```

use pasmo::data::synth::twonorm;
use pasmo::svm::gridsearch::{grid_search, log_grid};
use pasmo::svm::train::{SolverChoice, TrainConfig};

fn main() -> anyhow::Result<()> {
    let ds = twonorm(600, 7);
    println!("grid search on twonorm (ℓ={}, d={})\n", ds.len(), ds.dim());

    let base = TrainConfig::new(1.0, 1.0).with_solver(SolverChoice::Pasmo);
    let cs = log_grid(10.0, -2, 2);
    let gammas = log_grid(10.0, -3, 0);
    let res = grid_search(&ds, &cs, &gammas, 4, 1, &base);

    println!("{:>10} {:>10} {:>8}", "C", "gamma", "cv-acc");
    for p in &res.evaluated {
        let mark = if p.c == res.best.c && p.gamma == res.best.gamma { "  <-- best" } else { "" };
        println!("{:>10} {:>10} {:>8.4}{}", p.c, p.gamma, p.cv_accuracy, mark);
    }
    println!(
        "\nbest: C={} γ={} cv-accuracy={:.4}\n\
         (paper's Table 1 for twonorm: C=0.5, γ=0.02 — same order of magnitude)",
        res.best.c, res.best.gamma, res.best.cv_accuracy
    );
    anyhow::ensure!(res.best.cv_accuracy > 0.9, "twonorm should be very learnable");
    println!("grid_search OK");
    Ok(())
}
