//! Beyond classification: ε-SVR, one-class SVM and Platt-calibrated
//! probabilities — all running on the same PA-SMO solver core, which
//! handles the paper's general dual form `max pᵀα − ½αᵀKα` with
//! arbitrary linear term, box and warm start — and all predicting
//! through the same batch `Scorer` (blocked SV×query tiles, optional
//! threads) and saving through the same kind-tagged JSON schema.
//!
//! ```sh
//! cargo run --release --example regression_and_anomaly
//! ```

use std::sync::Arc;

use pasmo::data::dataset::Dataset;
use pasmo::data::regression::sinc;
use pasmo::ensure;
use pasmo::svm::oneclass::{train_one_class, OneClassConfig};
use pasmo::svm::platt::PlattScaler;
use pasmo::svm::svr::{train_svr_native, SvrConfig};
use pasmo::svm::Trainer;
use pasmo::util::error::Result;
use pasmo::util::prng::Pcg;

fn main() -> Result<()> {
    // ---- ε-SVR on the sinc benchmark ----
    let train_set = sinc(400, 0.05, 1);
    let test_set = sinc(300, 0.0, 2);
    let cfg = SvrConfig::new(10.0, 0.05, 0.5);
    let (svr, res) = train_svr_native(&train_set, &cfg);
    println!(
        "ε-SVR on sinc(x):  iterations={} (2ℓ dual), SVs={}/{}, planning={}\n\
         test RMSE = {:.4} (tube ε = {})",
        res.iterations,
        svr.coef.len(),
        train_set.len(),
        res.telemetry.planning_steps,
        svr.rmse(&test_set),
        cfg.epsilon
    );
    ensure!(res.converged && svr.rmse(&test_set) < 0.12);

    // sample predictions along the curve
    println!("\n    x      sinc(x)   f(x)");
    for k in 0..7 {
        let x = -9.0 + 3.0 * k as f64;
        let truth = if x.abs() < 1e-9 { 1.0 } else { x.sin() / x };
        println!("{:>6.1}  {:>8.4}  {:>8.4}", x, truth, svr.predict(&[x as f32]));
    }

    // ---- batch scoring + the unified model schema ----
    // One threaded scorer pass over the whole test set (bit-identical to
    // scoring one example at a time), and an SVR save/load round trip
    // through the same kind-tagged JSON schema classifiers use.
    let batch = svr.predict_all(&test_set, 2);
    ensure!(batch.len() == test_set.len());
    ensure!(batch[0] == svr.predict(test_set.row(0)), "batch != scalar");
    let model_path = std::env::temp_dir().join("pasmo-example-svr.json");
    svr.save(&model_path)?;
    let reloaded = pasmo::svm::svr::SvrModel::load(&model_path)?;
    ensure!((reloaded.predict(test_set.row(0)) - batch[0]).abs() < 1e-9);
    std::fs::remove_file(&model_path).ok();
    println!(
        "\nbatch scorer: {} predictions in one threaded pass; \
         svr.json round trip OK (kind-tagged schema v2)",
        batch.len()
    );

    // ---- one-class SVM: anomaly detection on a Gaussian blob ----
    let mut rng = Pcg::new(7);
    let mut blob = Dataset::with_dim(2);
    for _ in 0..500 {
        blob.push(&[rng.normal() as f32, rng.normal() as f32], 1);
    }
    let blob = Arc::new(blob);
    let (oc, oc_res) = train_one_class(&blob, &OneClassConfig::new(0.1, 0.2));
    let inlier = oc.is_inlier(&[0.2, -0.3]);
    let outlier = !oc.is_inlier(&[8.0, 8.0]);
    println!(
        "\none-class SVM (ν=0.1): SVs={}, ρ={:.4}, converged={}\n\
         center classified inlier: {inlier} | (8,8) classified outlier: {outlier}",
        oc.coef.len(),
        oc.rho,
        oc_res.converged
    );
    ensure!(inlier && outlier && oc_res.converged);

    // ---- Platt scaling on a classifier ----
    let spec = pasmo::data::suite::find("twonorm").unwrap();
    let data = Arc::new(spec.generate(600, 3));
    let calib = spec.generate(400, 4);
    let model = Trainer::rbf(spec.c, spec.gamma).train(&data).model;
    let scaler = PlattScaler::fit_model(&model, &calib);
    println!("\nPlatt scaling on twonorm: A={:.4} B={:.4}", scaler.a, scaler.b);
    for f in [-2.0, -0.5, 0.0, 0.5, 2.0] {
        println!("  P(y=+1 | f={f:>4}) = {:.3}", scaler.prob(f));
    }
    ensure!(scaler.prob(2.0) > 0.8 && scaler.prob(-2.0) < 0.2);

    println!("\nregression_and_anomaly OK");
    Ok(())
}
