//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Exercises every layer of the system on a real small workload:
//!   1. generates the benchmark suite's QPs,
//!   2. solves each with baseline SMO *and* PA-SMO over paired
//!      permutations through the Rust coordinator (threaded fan-out),
//!   3. verifies solution quality against the independent dense
//!      projected-gradient reference on a subsample,
//!   4. (with `--features pjrt` and artifacts) runs prediction through
//!      the AOT/PJRT decision artifact and checks it against the native
//!      decision path,
//!   5. prints the paper's headline metric (iterations/time, SMO vs PA,
//!      Wilcoxon-marked) — the Table-2 shape.
//!
//! ```sh
//! cargo run --release --example e2e_benchmark [-- --perms 10 --full]
//! ```

use std::sync::Arc;

use pasmo::coordinator::experiments::{table2, ExpOptions};
use pasmo::data::synth::chessboard;
use pasmo::ensure;
use pasmo::kernel::matrix::DenseGram;
use pasmo::kernel::{KernelFunction, NativeRowComputer};
use pasmo::solver::reference::solve_reference;
use pasmo::svm::{SolverChoice, Trainer};
use pasmo::util::cli::Args;
use pasmo::util::error::Result;

fn main() -> Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let mut opts = ExpOptions::default();
    opts.perms = args.get_parse_or("perms", 5usize);
    opts.scale = args.get_parse_or("scale", 0.15);
    opts.max_len = args.get_parse_or("max-len", 800usize);
    opts.full = args.flag("full");

    println!("=== PA-SMO end-to-end validation ===\n");

    // ---- (1)+(5) the headline Table-2 run over the fast suite ----
    println!("{}", table2(&opts));

    // ---- (3) oracle check: solvers vs dense projected gradient ----
    let small = Arc::new(chessboard(120, 4, 3));
    let nc = NativeRowComputer::new(small.clone(), KernelFunction::Rbf { gamma: 0.5 });
    let dense = DenseGram::materialize(&nc);
    let reference = solve_reference(&dense, small.labels(), 100.0, 200_000, 1e-14);
    let base = Trainer::rbf(100.0, 0.5);
    let pa = base.clone().solver(SolverChoice::Pasmo).train(&small).result;
    let smo = base.solver(SolverChoice::Smo).train(&small).result;
    println!(
        "## Oracle check (chess-board ℓ=120, C=100)\n\
         reference objective  = {:.6}\n\
         SMO objective        = {:.6}\n\
         PA-SMO objective     = {:.6}\n",
        reference.objective, smo.objective, pa.objective
    );
    let tol = 1e-3 * (1.0 + reference.objective.abs());
    ensure!((smo.objective - reference.objective).abs() < tol, "SMO off oracle");
    ensure!((pa.objective - reference.objective).abs() < tol, "PA-SMO off oracle");

    // ---- (2)+(4) the PJRT layers: train + predict through artifacts ----
    pjrt_layers()?;

    println!("e2e_benchmark OK");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_layers() -> Result<()> {
    use pasmo::runtime::engine::PjrtEngine;
    use pasmo::runtime::gram::{PjrtDecision, PjrtRowComputer};
    use pasmo::svm::predict::decision_values;
    use std::rc::Rc;

    match PjrtEngine::open_default() {
        Ok(engine) => {
            let engine = Rc::new(engine);
            let ds = Arc::new(chessboard(600, 4, 4));
            let computer = PjrtRowComputer::new(engine.clone(), ds.clone(), 0.5)?;
            let t0 = std::time::Instant::now();
            let out = Trainer::rbf(1e4, 0.5).train_with_computer(&ds, Box::new(computer));
            let (model, res) = (out.model, out.result);
            println!(
                "## PJRT training path (chess-board ℓ=600)\n\
                 converged={} iterations={} time={:.3}s SV={}",
                res.converged,
                res.iterations,
                t0.elapsed().as_secs_f64(),
                res.sv
            );
            ensure!(res.converged, "PJRT-path training failed to converge");

            // decision artifact vs native decision
            let queries = chessboard(64, 4, 5);
            let dec = PjrtDecision::new(
                engine,
                &model.support,
                &model.coef,
                model.bias,
                0.5,
            )?;
            let via_pjrt = dec.decide(&queries)?;
            let via_native = decision_values(&model, &queries);
            // Relative tolerance: with C = 10⁴ the dual coefficients round
            // to f32 on device, so the error scales with the coef norm.
            let coef_scale = model.coef.iter().map(|c| c.abs()).fold(1.0f64, f64::max);
            let max_rel = via_pjrt
                .iter()
                .zip(&via_native)
                .map(|(a, b)| (a - b).abs() / coef_scale.max(1.0 + b.abs()))
                .fold(0.0f64, f64::max);
            println!("decision artifact vs native: max relative |Δf| = {max_rel:.2e}\n");
            ensure!(max_rel < 1e-4, "PJRT decision mismatch");
        }
        Err(e) => {
            println!("## PJRT layers skipped ({e}); run `make artifacts`\n");
        }
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_layers() -> Result<()> {
    println!("## PJRT layers skipped (build with --features pjrt)\n");
    Ok(())
}
