//! Figure 1: the oscillation cone, in the minimal scenario the paper
//! draws — a low-dimensional QP where plain SMO zig-zags between two
//! working-set directions while PA-SMO's planned step cuts through.
//!
//! We build a 3-variable problem (two +1 examples, one −1) with strong
//! second-order cross terms, trace both solvers at full resolution, and
//! print the α-path plus per-iteration objective so the cone is visible
//! in the numbers (and pipeable to a plotting tool).
//!
//! ```sh
//! cargo run --release --example oscillation_trace
//! ```

use pasmo::ensure;
use pasmo::kernel::matrix::{DenseGram, Gram, RowComputer};
use pasmo::solver::events::TelemetryConfig;
use pasmo::solver::{Engine, EngineConfig, QpProblem, SolverChoice, SolverConfig, StepKind};
use pasmo::util::error::Result;

/// RowComputer over an explicit Gram matrix (the "two working sets"
/// scenario needs exact control of the cross terms).
struct ExplicitGram(DenseGram);

impl RowComputer for ExplicitGram {
    fn len(&self) -> usize {
        self.0.len()
    }
    fn compute_row(&self, i: usize, out: &mut [f32]) {
        for j in 0..self.0.len() {
            out[j] = self.0.at(i, j) as f32;
        }
    }
    fn diag(&self, i: usize) -> f64 {
        self.0.at(i, i)
    }
    fn entry(&self, i: usize, j: usize) -> f64 {
        self.0.at(i, j)
    }
}

fn scenario() -> (DenseGram, Vec<i8>, f64) {
    // Strong positive coupling between the two +1 variables creates the
    // narrow niveau ellipses of Figure 1; C is large enough that all
    // steps stay free (the planning regime).
    let k = DenseGram::from_matrix(
        3,
        vec![
            1.0, 0.85, 0.10, //
            0.85, 1.0, 0.15, //
            0.10, 0.15, 1.0,
        ],
    );
    (k, vec![1, 1, -1], 1e6)
}

fn run(label: &str, pa: bool) -> (u64, Vec<(u64, f64)>, u64) {
    let (k, labels, c) = scenario();
    let mut gram = Gram::new(Box::new(ExplicitGram(k)), 1 << 20);
    let cfg = SolverConfig {
        eps: 1e-8, // tight accuracy makes the oscillation phase long
        shrinking: false,
        telemetry: TelemetryConfig::full(1),
        ..Default::default()
    };
    let choice = if pa { SolverChoice::Pasmo } else { SolverChoice::Smo };
    let engine = EngineConfig::new(choice, cfg).build();
    let res = engine.solve(&QpProblem::classification(&labels, c), &mut gram);
    println!(
        "{label:<8} iterations={:<4} planning={:<3} final f={:.10}",
        res.iterations, res.telemetry.planning_steps, res.objective
    );
    let planning = res.telemetry.planning_steps;
    (res.iterations, res.telemetry.objective_trace.clone(), planning)
}

fn main() -> Result<()> {
    println!("Figure-1 minimal oscillation scenario (3 variables, ε=1e-8)\n");
    let (it_smo, trace_smo, _) = run("SMO", false);
    let (it_pa, trace_pa, planning) = run("PA-SMO", true);

    println!("\niter   f(SMO)            f(PA-SMO)");
    for t in 0..trace_smo.len().max(trace_pa.len()).min(30) {
        let fs = trace_smo.get(t).map(|&(_, f)| format!("{f:.12}")).unwrap_or_default();
        let fp = trace_pa.get(t).map(|&(_, f)| format!("{f:.12}")).unwrap_or_default();
        println!("{t:>4}   {fs:<16}  {fp:<16}");
    }

    println!(
        "\nSMO needed {it_smo} iterations; PA-SMO {it_pa} (with {planning} planned steps)."
    );
    ensure!(
        it_pa <= it_smo,
        "planning should not lose on the oscillation scenario"
    );
    // sanity: PA actually planned
    ensure!(planning > 0 || it_pa <= 4, "expected planning steps in the cone");
    let _ = StepKind::Planning;
    println!("oscillation_trace OK");
    Ok(())
}
