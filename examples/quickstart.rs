//! Quickstart: train a PA-SMO SVM on the chess-board problem and evaluate.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the public API end to end: synthetic data → PA-SMO
//! training (PJRT kernel path when artifacts exist, native fallback) →
//! prediction → model save/load round trip.

use std::rc::Rc;
use std::sync::Arc;

use pasmo::data::synth::chessboard;
use pasmo::runtime::engine::PjrtEngine;
use pasmo::runtime::gram::PjrtRowComputer;
use pasmo::svm::predict::accuracy;
use pasmo::svm::train::{train, train_with_computer, SolverChoice, TrainConfig};
use pasmo::svm::SvmModel;

fn main() -> anyhow::Result<()> {
    // The paper's hardest benchmark family, at quickstart size.
    let train_set = Arc::new(chessboard(1000, 4, 1));
    let test_set = chessboard(2000, 4, 2);

    // Paper hyper-parameters for chess-board: C = 10⁶, γ = 0.5.
    let cfg = TrainConfig::new(1e6, 0.5).with_solver(SolverChoice::Pasmo);

    // Prefer the AOT/PJRT kernel path (the three-layer deployment shape);
    // fall back to the native Rust kernel when artifacts are not built.
    let (model, result) = match PjrtEngine::open_default() {
        Ok(engine) => {
            println!("kernel path: PJRT ({} artifacts)", engine.manifest.artifacts.len());
            let computer = PjrtRowComputer::new(Rc::new(engine), train_set.clone(), 0.5)?;
            train_with_computer(&train_set, &cfg, Box::new(computer))
        }
        Err(e) => {
            println!("kernel path: native (PJRT unavailable: {e})");
            train(&train_set, &cfg)
        }
    };

    println!(
        "\ntrained chess-board-1000 with PA-SMO:\n\
         iterations        = {}\n\
         planning steps    = {}\n\
         wall time         = {:.3}s\n\
         dual objective    = {:.4}\n\
         KKT gap           = {:.2e} (ε = 10⁻³)\n\
         support vectors   = {} ({} bounded)",
        result.iterations,
        result.telemetry.planning_steps,
        result.wall_time_s,
        result.objective,
        result.gap,
        result.sv,
        result.bsv,
    );

    let train_acc = accuracy(&model, &train_set);
    let test_acc = accuracy(&model, &test_set);
    println!("train accuracy    = {train_acc:.4}");
    println!("test  accuracy    = {test_acc:.4}");

    // Model persistence round trip.
    let path = std::env::temp_dir().join("pasmo-quickstart-model.json");
    model.save(&path)?;
    let reloaded = SvmModel::load(&path)?;
    assert_eq!(reloaded.n_sv(), model.n_sv());
    println!("model round-trip  = ok ({} SVs, {})", reloaded.n_sv(), path.display());

    anyhow::ensure!(result.converged, "solver did not converge");
    anyhow::ensure!(test_acc > 0.9, "unexpectedly poor accuracy {test_acc}");
    println!("\nquickstart OK");
    Ok(())
}
