//! Quickstart: train a PA-SMO SVM on the chess-board problem and evaluate.
//!
//! ```sh
//! cargo run --release --example quickstart [-- --len 1000]
//! ```
//!
//! Demonstrates the public API end to end: synthetic data → `Trainer`
//! (PJRT kernel path when built with `--features pjrt` and artifacts
//! exist, native fallback) → prediction → model save/load round trip.

use std::sync::Arc;

use pasmo::data::synth::chessboard;
use pasmo::ensure;
use pasmo::svm::predict::accuracy;
use pasmo::svm::{SolverChoice, SvmModel, Trainer, TrainOutcome};
use pasmo::util::cli::Args;
use pasmo::util::error::Result;

/// Prefer the AOT/PJRT kernel path (the three-layer deployment shape);
/// fall back to the native Rust kernel when artifacts are not built.
#[cfg(feature = "pjrt")]
fn train_preferring_pjrt(
    trainer: &Trainer,
    data: &Arc<pasmo::data::Dataset>,
    gamma: f64,
) -> Result<TrainOutcome> {
    use pasmo::runtime::engine::PjrtEngine;
    use pasmo::runtime::gram::PjrtRowComputer;
    match PjrtEngine::open_default() {
        Ok(engine) => {
            println!("kernel path: PJRT ({} artifacts)", engine.manifest.artifacts.len());
            let computer = PjrtRowComputer::new(std::rc::Rc::new(engine), data.clone(), gamma)?;
            Ok(trainer.train_with_computer(data, Box::new(computer)))
        }
        Err(e) => {
            println!("kernel path: native (PJRT unavailable: {e})");
            Ok(trainer.train(data))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn train_preferring_pjrt(
    trainer: &Trainer,
    data: &Arc<pasmo::data::Dataset>,
    _gamma: f64,
) -> Result<TrainOutcome> {
    println!("kernel path: native (build with --features pjrt for the PJRT path)");
    Ok(trainer.train(data))
}

fn main() -> Result<()> {
    // The paper's hardest benchmark family; `--len` shrinks it for CI.
    let args = Args::from_env();
    let len: usize = args.get_parse_or("len", 1000);
    let train_set = Arc::new(chessboard(len, 4, 1));
    let test_set = chessboard(2 * len, 4, 2);

    // Paper hyper-parameters for chess-board: C = 10⁶, γ = 0.5.
    let trainer = Trainer::rbf(1e6, 0.5).solver(SolverChoice::Pasmo);

    let TrainOutcome { model, result } = train_preferring_pjrt(&trainer, &train_set, 0.5)?;

    println!(
        "\ntrained chess-board-{len} with PA-SMO:\n\
         iterations        = {}\n\
         planning steps    = {}\n\
         wall time         = {:.3}s\n\
         dual objective    = {:.4}\n\
         KKT gap           = {:.2e} (ε = 10⁻³)\n\
         support vectors   = {} ({} bounded)",
        result.iterations,
        result.telemetry.planning_steps,
        result.wall_time_s,
        result.objective,
        result.gap,
        result.sv,
        result.bsv,
    );

    let train_acc = accuracy(&model, &train_set);
    let test_acc = accuracy(&model, &test_set);
    println!("train accuracy    = {train_acc:.4}");
    println!("test  accuracy    = {test_acc:.4}");

    // Model persistence round trip.
    let path = std::env::temp_dir().join("pasmo-quickstart-model.json");
    model.save(&path)?;
    let reloaded = SvmModel::load(&path)?;
    ensure!(reloaded.n_sv() == model.n_sv(), "model round trip changed the SV count");
    println!("model round-trip  = ok ({} SVs, {})", reloaded.n_sv(), path.display());

    ensure!(result.converged, "solver did not converge");
    // The 4×4 chess-board needs a decent sample to generalize; at CI
    // scale (`--len 200`) accept a looser floor.
    let floor = if len >= 800 { 0.9 } else { 0.75 };
    ensure!(test_acc > floor, "unexpectedly poor accuracy {test_acc} (floor {floor})");
    println!("\nquickstart OK");
    Ok(())
}
