//! Shared tiled kernel-evaluation primitives — the one code path that
//! computes "kernel values of a row against many dataset rows", consumed
//! by both sides of the system:
//!
//! * **training** — [`super::native::NativeRowComputer`] produces Gram
//!   rows (full, gathered-through-the-permutation, shrunk-prefix) for
//!   the solver;
//! * **inference** — [`crate::svm::scorer::Scorer`] produces SV×query
//!   blocks for batch prediction.
//!
//! The primitives keep one contract: **per-entry arithmetic is exactly
//! the scalar evaluation**. Every entry accumulates its own f64 dot
//! product in feature order, so tiled, gathered, threaded and batched
//! results are bit-identical to a one-entry-at-a-time loop (asserted by
//! tests on both the Gram and the scorer side). Tiling is purely a
//! memory-locality optimization: the 4-wide tile streams the query row
//! once per four dot products.
//!
//! Queries are [`Row`] views, so the same entry points serve both
//! feature backends: dense query × dense data takes the historical
//! 4-wide tile verbatim, while any pairing that involves a CSR side
//! takes the merged sparse dot ([`Row::dot`]) per entry — which skips
//! only exact-zero terms and is therefore bit-identical to the dense
//! loop (see `data::features`). The RBF arm always uses the
//! `‖a‖²+‖b‖²−2a·b` decomposition with the precomputed
//! [`squared_norms`], dense or sparse alike.

use crate::data::dataset::Dataset;
use crate::data::features::{Features, Row};

use super::function::KernelFunction;

/// Minimum multiply-add work (entries × feature dim) before a block is
/// split across threads. Spawning and joining scoped workers costs tens
/// of microseconds, so low-dimensional or short blocks — whose whole
/// computation is cheaper than a spawn — always run inline; the gate is
/// on estimated flops, not entry count.
pub const PAR_MIN_MADDS: usize = 1 << 16;

/// Precomputed squared norms ‖x_i‖² of every dataset row (f64
/// accumulation in feature order) — the RBF fast path's input for the
/// `‖a‖²+‖b‖²−2a·b` decomposition.
pub fn squared_norms(data: &Dataset) -> Vec<f64> {
    (0..data.len()).map(|i| data.row_ref(i).sqnorm()).collect()
}

/// How many scoped workers a block of `entries` kernel entries over
/// `dim`-dimensional rows deserves: `1` (inline) unless `threads > 1`
/// and the estimated multiply-add work clears [`PAR_MIN_MADDS`]; never
/// more workers than entries.
pub fn workers_for(threads: usize, entries: usize, dim: usize) -> usize {
    if threads > 1 && entries.saturating_mul(dim.max(1)) >= PAR_MIN_MADDS {
        threads.min(entries.max(1))
    } else {
        1
    }
}

/// Split `out` into `workers` contiguous chunks and fill them on scoped
/// threads; `fill(base, chunk)` receives each chunk together with its
/// starting index in `out`. With `workers <= 1` the fill runs inline on
/// the calling thread. Workers write disjoint chunks and the arithmetic
/// per entry does not depend on the chunking, so results are
/// bit-identical for any worker count.
pub fn chunked<T: Send, F: Fn(usize, &mut [T]) + Sync>(workers: usize, out: &mut [T], fill: F) {
    if workers <= 1 || out.len() <= 1 {
        fill(0, out);
        return;
    }
    let chunk = out.len().div_ceil(workers);
    #[cfg(feature = "debug-invariants")]
    {
        // The spawned chunks must partition `out` exactly: contiguous,
        // non-overlapping, and covering every entry once.
        let mut covered = 0usize;
        for (c, piece) in out.chunks(chunk).enumerate() {
            crate::invariant!(
                c * chunk == covered,
                "chunk {c} starts at {} but the previous ended at {covered}",
                c * chunk
            );
            covered += piece.len();
        }
        crate::invariant!(
            covered == out.len(),
            "chunks cover {covered} of {} entries",
            out.len()
        );
    }
    let fill = &fill;
    std::thread::scope(|s| {
        for (c, out_chunk) in out.chunks_mut(chunk).enumerate() {
            let base = c * chunk;
            s.spawn(move || fill(base, out_chunk));
        }
    });
}

/// The tiled dot-product loop: `emit(p, j, dot)` is called for
/// `p ∈ [0, n)` in index order with `j = col(base + p)` and
/// `dot = Σ_k xi[k]·data[j][k]` accumulated in f64 feature order.
/// Dense query × dense data produces four output entries per tile so
/// `xi` is streamed once per four dot products; each entry still owns
/// its accumulator, so the dots are bit-identical to a scalar per-entry
/// loop. Any pairing with a CSR side takes [`Row::dot`] per entry —
/// the same bits, skipping only exact-zero terms.
#[inline]
fn dot_block<C: Fn(usize) -> usize, E: FnMut(usize, usize, f64)>(
    xi: Row<'_>,
    data: &Dataset,
    col: &C,
    base: usize,
    n: usize,
    mut emit: E,
) {
    let xi = match (xi, data.storage()) {
        (Row::Dense(xi), Features::Dense { .. }) => xi,
        _ => {
            // Sparse on either side: the merged dot per entry. Bit-parity
            // with the dense tile holds because skipped terms are exact
            // zero products (see `data::features`).
            for p in 0..n {
                let j = col(base + p);
                emit(p, j, xi.dot(data.row_ref(j)));
            }
            return;
        }
    };
    let d = data.dim();
    let mut p = 0usize;
    while p + 4 <= n {
        let j0 = col(base + p);
        let j1 = col(base + p + 1);
        let j2 = col(base + p + 2);
        let j3 = col(base + p + 3);
        let x0 = data.row(j0);
        let x1 = data.row(j1);
        let x2 = data.row(j2);
        let x3 = data.row(j3);
        let (mut d0, mut d1, mut d2, mut d3) = (0f64, 0f64, 0f64, 0f64);
        for k in 0..d {
            let v = xi[k] as f64;
            d0 += v * x0[k] as f64;
            d1 += v * x1[k] as f64;
            d2 += v * x2[k] as f64;
            d3 += v * x3[k] as f64;
        }
        emit(p, j0, d0);
        emit(p + 1, j1, d1);
        emit(p + 2, j2, d2);
        emit(p + 3, j3, d3);
        p += 4;
    }
    while p < n {
        let j = col(base + p);
        let xj = data.row(j);
        let mut dot = 0f64;
        for k in 0..d {
            dot += xi[k] as f64 * xj[k] as f64;
        }
        emit(p, j, dot);
        p += 1;
    }
}

/// Tiled kernel values of `xi` against dataset rows: `emit(p, value)` is
/// called for `p ∈ [0, n)` in index order with the f64 kernel value
/// `k(xi, data[col(base + p)])`.
///
/// `xi_sqnorm` is ‖xi‖² and `sqnorms` the dataset's [`squared_norms`] —
/// both consumed only by the RBF arm (any slice is accepted for the
/// dot-product kernels, which never index it). The per-entry arithmetic
/// matches the scalar evaluations exactly: for RBF the
/// `‖a‖²+‖b‖²−2a·b` decomposition (the Gram-row fast path), for
/// linear/poly/sigmoid the feature-order f64 dot that
/// [`KernelFunction::eval`] performs — so linear, polynomial and sigmoid
/// values are bit-identical to `eval`, and RBF values are bit-identical
/// to the established decomposition path.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn kernel_block<C: Fn(usize) -> usize, E: FnMut(usize, f64)>(
    kernel: KernelFunction,
    xi: Row<'_>,
    xi_sqnorm: f64,
    sqnorms: &[f64],
    data: &Dataset,
    col: &C,
    base: usize,
    n: usize,
    mut emit: E,
) {
    match kernel {
        KernelFunction::Rbf { gamma } => dot_block(xi, data, col, base, n, |p, j, dot| {
            emit(
                p,
                (-gamma * (xi_sqnorm + sqnorms[j] - 2.0 * dot).max(0.0)).exp(),
            )
        }),
        KernelFunction::Linear => {
            dot_block(xi, data, col, base, n, |p, _, dot| emit(p, dot))
        }
        KernelFunction::Poly { gamma, coef0, degree } => {
            dot_block(xi, data, col, base, n, |p, _, dot| {
                emit(p, (gamma * dot + coef0).powi(degree as i32))
            })
        }
        KernelFunction::Sigmoid { gamma, coef0 } => {
            dot_block(xi, data, col, base, n, |p, _, dot| {
                emit(p, (gamma * dot + coef0).tanh())
            })
        }
    }
}

/// [`kernel_block`] storing into an f32 row — the Gram-row shape
/// ([`super::matrix::RowComputer::compute_cols`] semantics:
/// `out[p] = k(xi, data[col(base + p)])`).
#[allow(clippy::too_many_arguments)]
pub fn kernel_block_f32<C: Fn(usize) -> usize>(
    kernel: KernelFunction,
    xi: Row<'_>,
    xi_sqnorm: f64,
    sqnorms: &[f64],
    data: &Dataset,
    col: &C,
    base: usize,
    out: &mut [f32],
) {
    kernel_block(
        kernel,
        xi,
        xi_sqnorm,
        sqnorms,
        data,
        col,
        base,
        out.len(),
        |p, v| out[p] = v as f32,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    fn random_ds(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Pcg::new(seed);
        let mut ds = Dataset::with_dim(d);
        let mut row = vec![0f32; d];
        for _ in 0..n {
            row.iter_mut().for_each(|v| *v = rng.normal() as f32);
            ds.push(&row, if rng.bernoulli(0.5) { 1 } else { -1 });
        }
        ds
    }

    #[test]
    fn kernel_block_matches_scalar_eval_for_dot_kernels() {
        let ds = random_ds(37, 6, 1); // 37 exercises the remainder lanes
        let sq = squared_norms(&ds);
        let xi: Vec<f32> = ds.row(5).to_vec();
        for k in [
            KernelFunction::Linear,
            KernelFunction::Poly { gamma: 0.4, coef0: 1.0, degree: 3 },
            KernelFunction::Sigmoid { gamma: 0.2, coef0: -0.5 },
        ] {
            let mut got = vec![0f64; ds.len()];
            kernel_block(k, Row::Dense(&xi), sq[5], &sq, &ds, &|p| p, 0, ds.len(), |p, v| {
                got[p] = v
            });
            for j in 0..ds.len() {
                let want = k.eval(&xi, ds.row(j));
                assert_eq!(
                    got[j].to_bits(),
                    want.to_bits(),
                    "{k:?} j={j}: {} vs {want}",
                    got[j]
                );
            }
        }
    }

    #[test]
    fn rbf_block_matches_decomposition_reference() {
        let ds = random_ds(41, 5, 2);
        let sq = squared_norms(&ds);
        let gamma = 0.8;
        let k = KernelFunction::Rbf { gamma };
        let xi: Vec<f32> = ds.row(3).to_vec();
        let mut got = vec![0f64; ds.len()];
        kernel_block(k, Row::Dense(&xi), sq[3], &sq, &ds, &|p| p, 0, ds.len(), |p, v| {
            got[p] = v
        });
        for j in 0..ds.len() {
            let mut dot = 0f64;
            for t in 0..ds.dim() {
                dot += xi[t] as f64 * ds.row(j)[t] as f64;
            }
            let want = (-gamma * (sq[3] + sq[j] - 2.0 * dot).max(0.0)).exp();
            assert_eq!(got[j].to_bits(), want.to_bits(), "j={j}");
            // and the decomposition agrees with the direct sqdist eval
            assert!((got[j] - k.eval(&xi, ds.row(j))).abs() < 1e-12);
        }
    }

    #[test]
    fn gathered_base_offsets_index_correctly() {
        let ds = random_ds(30, 4, 3);
        let sq = squared_norms(&ds);
        let k = KernelFunction::Rbf { gamma: 1.1 };
        let cols: Vec<usize> = (0..30).rev().collect();
        let mut full = vec![0f32; 30];
        kernel_block_f32(k, ds.row_ref(7), sq[7], &sq, &ds, &|p| p, 0, &mut full);
        // gather through cols with a non-zero base, as the chunked path does
        let mut part = vec![0f32; 10];
        kernel_block_f32(k, ds.row_ref(7), sq[7], &sq, &ds, &|p| cols[p], 12, &mut part);
        for p in 0..10 {
            assert_eq!(part[p].to_bits(), full[cols[12 + p]].to_bits(), "p={p}");
        }
    }

    #[test]
    fn chunked_is_bit_identical_and_covers_every_entry() {
        let ds = random_ds(257, 9, 4);
        let sq = squared_norms(&ds);
        let k = KernelFunction::Rbf { gamma: 0.6 };
        let xi: Vec<f32> = ds.row(0).to_vec();
        let mut inline = vec![0f32; 257];
        kernel_block_f32(k, Row::Dense(&xi), sq[0], &sq, &ds, &|p| p, 0, &mut inline);
        for workers in [2usize, 3, 8] {
            let mut par = vec![0f32; 257];
            chunked(workers, &mut par, |base, chunk| {
                kernel_block_f32(k, Row::Dense(&xi), sq[0], &sq, &ds, &|p| p, base, chunk);
            });
            assert!(
                inline.iter().zip(&par).all(|(a, b)| a.to_bits() == b.to_bits()),
                "workers={workers} diverges"
            );
        }
    }

    #[test]
    fn sparse_blocks_are_bit_identical_to_dense_blocks() {
        // Densities chosen so rows contain exact zeros (the skipped terms).
        let dense = {
            let mut rng = Pcg::new(11);
            let mut ds = Dataset::with_dim(7);
            let mut row = vec![0f32; 7];
            for _ in 0..43 {
                row.iter_mut().for_each(|v| {
                    *v = if rng.bernoulli(0.3) { rng.normal() as f32 } else { 0.0 }
                });
                ds.push(&row, if rng.bernoulli(0.5) { 1 } else { -1 });
            }
            ds
        };
        let sparse = dense.to_sparse();
        let sq_d = squared_norms(&dense);
        let sq_s = squared_norms(&sparse);
        assert!(sq_d.iter().zip(&sq_s).all(|(a, b)| a.to_bits() == b.to_bits()));
        for k in [
            KernelFunction::Rbf { gamma: 0.9 },
            KernelFunction::Linear,
            KernelFunction::Poly { gamma: 0.4, coef0: 1.0, degree: 3 },
            KernelFunction::Sigmoid { gamma: 0.2, coef0: -0.5 },
        ] {
            let mut want = vec![0f32; dense.len()];
            kernel_block_f32(k, dense.row_ref(5), sq_d[5], &sq_d, &dense, &|p| p, 0, &mut want);
            // sparse query × sparse data, sparse × dense, dense × sparse
            for (xi, data, sq) in [
                (sparse.row_ref(5), &sparse, &sq_s),
                (sparse.row_ref(5), &dense, &sq_d),
                (dense.row_ref(5), &sparse, &sq_s),
            ] {
                let mut got = vec![0f32; data.len()];
                kernel_block_f32(k, xi, sq_s[5], sq, data, &|p| p, 0, &mut got);
                assert!(
                    want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{k:?} sparse block diverges from dense"
                );
            }
        }
    }

    #[test]
    fn worker_gate_respects_threshold_and_clamps() {
        assert_eq!(workers_for(1, 1 << 20, 10), 1, "single-threaded stays inline");
        assert_eq!(workers_for(4, 10, 2), 1, "tiny work stays inline");
        assert_eq!(workers_for(4, PAR_MIN_MADDS, 1), 4);
        assert_eq!(workers_for(8, PAR_MIN_MADDS / 4, 4), 8);
        assert_eq!(workers_for(8, 3, 1 << 20), 3, "never more workers than entries");
        assert_eq!(workers_for(4, 0, 64), 1, "empty block stays inline");
    }

    #[test]
    fn chunked_handles_empty_and_tiny_outputs() {
        let mut empty: Vec<f32> = Vec::new();
        chunked(4, &mut empty, |_, chunk| assert!(chunk.is_empty()));
        let mut one = vec![0f64; 1];
        chunked(4, &mut one, |base, chunk| {
            assert_eq!(base, 0);
            chunk[0] = 7.0;
        });
        assert_eq!(one[0], 7.0);
    }
}
