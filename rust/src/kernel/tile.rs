//! Shared tiled kernel-evaluation primitives — the one code path that
//! computes "kernel values of a row against many dataset rows", consumed
//! by both sides of the system:
//!
//! * **training** — [`super::native::NativeRowComputer`] produces Gram
//!   rows (full, gathered-through-the-permutation, shrunk-prefix) for
//!   the solver;
//! * **inference** — [`crate::svm::scorer::Scorer`] produces SV×query
//!   blocks for batch prediction.
//!
//! The primitives keep one contract: **per-entry arithmetic is exactly
//! the scalar evaluation**. Every entry accumulates its own f64 dot
//! product in feature order, so tiled, gathered, threaded and batched
//! results are bit-identical to a one-entry-at-a-time loop (asserted by
//! tests on both the Gram and the scorer side). Tiling is purely a
//! memory-locality optimization: the 4-wide tile streams the query row
//! once per four dot products.
//!
//! Queries are [`Row`] views, so the same entry points serve both
//! feature backends: dense query × dense data takes the historical
//! 4-wide tile verbatim, while any pairing that involves a CSR side
//! takes the merged sparse dot ([`Row::dot`]) per entry — which skips
//! only exact-zero terms and is therefore bit-identical to the dense
//! loop (see `data::features`). The RBF arm always uses the
//! `‖a‖²+‖b‖²−2a·b` decomposition with the precomputed
//! [`squared_norms`], dense or sparse alike.
//!
//! ## SIMD floor
//!
//! The dense 4-wide tile has an explicit AVX2 implementation ([`simd`])
//! selected once per process by runtime feature detection
//! (`PASMO_SIMD` / `--simd auto|force|off`). It vectorizes **across the
//! four tile outputs** — the vector lanes are the accumulators d0..d3,
//! not four features of one dot — so each entry still accumulates its
//! own f64 dot in feature order with one IEEE mul + add per term (no
//! FMA), and the SIMD tile is `to_bits`-identical to the scalar tile.
//! CSR pairings never enter the SIMD tile: they keep the merged-dot
//! fallback above. See DESIGN.md §4g.

use crate::data::dataset::Dataset;
use crate::data::features::{Features, Row};

use super::function::KernelFunction;

/// Minimum multiply-add work (entries × feature dim) before a block is
/// split across threads. Spawning and joining scoped workers costs tens
/// of microseconds, so low-dimensional or short blocks — whose whole
/// computation is cheaper than a spawn — always run inline; the gate is
/// on estimated flops, not entry count.
pub const PAR_MIN_MADDS: usize = 1 << 16;

/// Precomputed squared norms ‖x_i‖² of every dataset row (f64
/// accumulation in feature order) — the RBF fast path's input for the
/// `‖a‖²+‖b‖²−2a·b` decomposition.
pub fn squared_norms(data: &Dataset) -> Vec<f64> {
    (0..data.len()).map(|i| data.row_ref(i).sqnorm()).collect()
}

/// Explicit AVX2 tile for dense query × dense data, behind process-wide
/// runtime dispatch.
///
/// The vector lanes are the four tile *outputs* (the accumulators
/// `d0..d3` of the dense tile), not four features of one dot product:
/// every feature step broadcasts `xi[k]`, gathers the four rows' `k`-th
/// coordinates into one register, and performs one IEEE-754 f64
/// multiply followed by one add per lane (`_mm256_mul_pd` +
/// `_mm256_add_pd`, never FMA). Each lane therefore runs exactly the
/// scalar per-entry recurrence `d_t += xi[k] · x_t[k]` in feature
/// order, on exactly-widened `f32 → f64` operands — so the SIMD tile is
/// `to_bits`-identical to the scalar tile, which stays compiled in as
/// the always-available fallback (non-x86_64 targets, miri, CPUs
/// without AVX2, `--simd off`).
pub mod simd {
    use std::sync::atomic::{AtomicU8, Ordering};

    const UNINIT: u8 = 0;
    const ON: u8 = 1;
    const OFF: u8 = 2;

    /// Process-wide tile selection: resolved lazily from `PASMO_SIMD`
    /// on the first [`simd_active`] call, or eagerly by
    /// [`set_simd_mode`] (the `--simd` flag).
    static SIMD_STATE: AtomicU8 = AtomicU8::new(UNINIT);

    /// How the tile implementation is chosen
    /// (`--simd auto|force|off` / `PASMO_SIMD`).
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum SimdMode {
        /// AVX2 tile when the running CPU supports it (the default).
        Auto,
        /// Require the AVX2 tile; selection reports failure on CPUs
        /// without AVX2 (the scalar tile stays selected).
        Force,
        /// Always the scalar tile.
        Off,
    }

    impl SimdMode {
        /// Parse `auto` / `force` / `off` (ASCII case-insensitive).
        pub fn parse(s: &str) -> Option<SimdMode> {
            if s.eq_ignore_ascii_case("auto") {
                Some(SimdMode::Auto)
            } else if s.eq_ignore_ascii_case("force") {
                Some(SimdMode::Force)
            } else if s.eq_ignore_ascii_case("off") {
                Some(SimdMode::Off)
            } else {
                None
            }
        }
    }

    /// True when this process *can* run the AVX2 tile: x86_64, not
    /// under miri (vendor intrinsics are unsupported there), and the
    /// CPU reports `avx2` at runtime.
    pub fn simd_supported() -> bool {
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        {
            std::arch::is_x86_64_feature_detected!("avx2")
        }
        #[cfg(not(all(target_arch = "x86_64", not(miri))))]
        {
            false
        }
    }

    /// Select the tile implementation for the whole process. Returns
    /// `false` only for [`SimdMode::Force`] on hardware without AVX2;
    /// the scalar tile stays selected in that case, so every caller
    /// keeps producing (identical) results.
    pub fn set_simd_mode(mode: SimdMode) -> bool {
        let (state, ok) = match mode {
            SimdMode::Off => (OFF, true),
            SimdMode::Auto => (if simd_supported() { ON } else { OFF }, true),
            SimdMode::Force => {
                if simd_supported() {
                    (ON, true)
                } else {
                    (OFF, false)
                }
            }
        };
        SIMD_STATE.store(state, Ordering::Relaxed);
        ok
    }

    /// True when the AVX2 tile is currently selected. The first call
    /// (unless [`set_simd_mode`] ran earlier) resolves the choice from
    /// the `PASMO_SIMD` environment variable — `auto` when unset or
    /// unparseable.
    #[inline]
    pub fn simd_active() -> bool {
        match SIMD_STATE.load(Ordering::Relaxed) {
            ON => true,
            OFF => false,
            _ => {
                let mode = std::env::var("PASMO_SIMD")
                    .ok()
                    .and_then(|v| SimdMode::parse(&v))
                    .unwrap_or(SimdMode::Auto);
                set_simd_mode(mode);
                SIMD_STATE.load(Ordering::Relaxed) == ON
            }
        }
    }

    /// The scalar reference tile: four f64 dots of `xi` against
    /// `x0..x3`, each accumulated in feature order — exactly the
    /// arithmetic of the historical dense tile (and of the SIMD lanes).
    #[inline]
    pub(crate) fn scalar_dot4(
        xi: &[f32],
        x0: &[f32],
        x1: &[f32],
        x2: &[f32],
        x3: &[f32],
    ) -> [f64; 4] {
        let (mut d0, mut d1, mut d2, mut d3) = (0f64, 0f64, 0f64, 0f64);
        for k in 0..xi.len() {
            let v = xi[k] as f64;
            d0 += v * x0[k] as f64;
            d1 += v * x1[k] as f64;
            d2 += v * x2[k] as f64;
            d3 += v * x3[k] as f64;
        }
        [d0, d1, d2, d3]
    }

    #[cfg(all(target_arch = "x86_64", not(miri)))]
    mod avx2 {
        use core::arch::x86_64::*;

        /// The AVX2 tile: lane `t` of the accumulator register is the
        /// output `d_t`. Per 4-feature step the four rows' coordinates
        /// are transposed into per-feature columns and accumulated in
        /// feature order `k, k+1, k+2, k+3`; the sub-4 feature tail is
        /// broadcast one coordinate at a time in the same order.
        ///
        /// # Safety
        ///
        /// The caller must guarantee the running CPU supports AVX2
        /// (`is_x86_64_feature_detected!("avx2")`). Slice lengths are
        /// asserted before any raw load, so the pointer reads stay in
        /// bounds.
        #[target_feature(enable = "avx2")]
        // SAFETY: the intrinsics below require AVX/AVX2, which the
        // caller contract (runtime detection before dispatch) supplies;
        // the unaligned raw-pointer loads read `k..k+4` with
        // `k + 4 <= d`, in bounds of every slice by the assert below.
        pub(super) unsafe fn dot4(
            xi: &[f32],
            x0: &[f32],
            x1: &[f32],
            x2: &[f32],
            x3: &[f32],
        ) -> [f64; 4] {
            let d = xi.len();
            assert!(
                x0.len() >= d && x1.len() >= d && x2.len() >= d && x3.len() >= d,
                "tile rows shorter than the query row"
            );
            let mut acc = _mm256_setzero_pd();
            let mut k = 0usize;
            while k + 4 <= d {
                // Exact f32 → f64 widening of xi[k..k+4] and the four
                // rows' [k..k+4] windows.
                let q = _mm256_cvtps_pd(_mm_loadu_ps(xi.as_ptr().add(k)));
                let r0 = _mm256_cvtps_pd(_mm_loadu_ps(x0.as_ptr().add(k)));
                let r1 = _mm256_cvtps_pd(_mm_loadu_ps(x1.as_ptr().add(k)));
                let r2 = _mm256_cvtps_pd(_mm_loadu_ps(x2.as_ptr().add(k)));
                let r3 = _mm256_cvtps_pd(_mm_loadu_ps(x3.as_ptr().add(k)));
                // 4×4 transpose: col_t = [x0[k+t], x1[k+t], x2[k+t], x3[k+t]].
                let lo01 = _mm256_unpacklo_pd(r0, r1);
                let hi01 = _mm256_unpackhi_pd(r0, r1);
                let lo23 = _mm256_unpacklo_pd(r2, r3);
                let hi23 = _mm256_unpackhi_pd(r2, r3);
                let col0 = _mm256_permute2f128_pd::<0x20>(lo01, lo23);
                let col1 = _mm256_permute2f128_pd::<0x20>(hi01, hi23);
                let col2 = _mm256_permute2f128_pd::<0x31>(lo01, lo23);
                let col3 = _mm256_permute2f128_pd::<0x31>(hi01, hi23);
                // Feature-order accumulation, one rounded mul + one
                // rounded add per term per lane — bit-for-bit the
                // scalar recurrence. No FMA: fused rounding would
                // change bits.
                let q0 = _mm256_permute4x64_pd::<0x00>(q);
                let q1 = _mm256_permute4x64_pd::<0x55>(q);
                let q2 = _mm256_permute4x64_pd::<0xAA>(q);
                let q3 = _mm256_permute4x64_pd::<0xFF>(q);
                acc = _mm256_add_pd(acc, _mm256_mul_pd(q0, col0));
                acc = _mm256_add_pd(acc, _mm256_mul_pd(q1, col1));
                acc = _mm256_add_pd(acc, _mm256_mul_pd(q2, col2));
                acc = _mm256_add_pd(acc, _mm256_mul_pd(q3, col3));
                k += 4;
            }
            while k < d {
                let v = _mm256_set1_pd(xi[k] as f64);
                // `_mm256_set_pd` takes arguments high-to-low: lane 0
                // (output d0) receives x0[k].
                let col = _mm256_set_pd(x3[k] as f64, x2[k] as f64, x1[k] as f64, x0[k] as f64);
                acc = _mm256_add_pd(acc, _mm256_mul_pd(v, col));
                k += 1;
            }
            let mut out = [0f64; 4];
            _mm256_storeu_pd(out.as_mut_ptr(), acc);
            out
        }
    }

    /// The tile called once [`simd_active`] returned true. On targets
    /// where the intrinsics cannot exist (non-x86_64, miri)
    /// [`simd_active`] is always false, so the fallback body below is
    /// never hot — it exists to keep the dispatch monomorphic.
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    #[inline]
    pub(crate) fn active_dot4(
        xi: &[f32],
        x0: &[f32],
        x1: &[f32],
        x2: &[f32],
        x3: &[f32],
    ) -> [f64; 4] {
        // SAFETY: callers gate on `simd_active()`, which selects the
        // AVX2 tile only after `is_x86_64_feature_detected!("avx2")`
        // succeeded on this CPU; slice lengths are asserted inside.
        unsafe { avx2::dot4(xi, x0, x1, x2, x3) }
    }

    /// Non-x86_64 / miri stub: [`simd_active`] never returns true
    /// there, so this is unreachable in practice — but panic-free and
    /// correct if it ever runs.
    #[cfg(not(all(target_arch = "x86_64", not(miri))))]
    #[inline]
    pub(crate) fn active_dot4(
        xi: &[f32],
        x0: &[f32],
        x1: &[f32],
        x2: &[f32],
        x3: &[f32],
    ) -> [f64; 4] {
        scalar_dot4(xi, x0, x1, x2, x3)
    }

    #[cfg(test)]
    thread_local! {
        /// Tiles routed to the SIMD path on this thread (tests assert
        /// dispatch decisions through this; thread-local so parallel
        /// tests never race on it).
        pub(crate) static SIMD_TILES: std::cell::Cell<usize> =
            std::cell::Cell::new(0);
    }

    /// Tests: SIMD tiles dispatched on the current thread so far.
    #[cfg(test)]
    pub(crate) fn simd_tiles_on_thread() -> usize {
        SIMD_TILES.with(|c| c.get())
    }
}

/// How many scoped workers a block of `entries` kernel entries over
/// `dim`-dimensional rows deserves: `1` (inline) unless `threads > 1`
/// and the estimated multiply-add work clears [`PAR_MIN_MADDS`]; never
/// more workers than entries.
pub fn workers_for(threads: usize, entries: usize, dim: usize) -> usize {
    if threads > 1 && entries.saturating_mul(dim.max(1)) >= PAR_MIN_MADDS {
        threads.min(entries.max(1))
    } else {
        1
    }
}

/// Split `out` into `workers` contiguous chunks and fill them on scoped
/// threads; `fill(base, chunk)` receives each chunk together with its
/// starting index in `out`. With `workers <= 1` the fill runs inline on
/// the calling thread. Workers write disjoint chunks and the arithmetic
/// per entry does not depend on the chunking, so results are
/// bit-identical for any worker count.
pub fn chunked<T: Send, F: Fn(usize, &mut [T]) + Sync>(workers: usize, out: &mut [T], fill: F) {
    if workers <= 1 || out.len() <= 1 {
        fill(0, out);
        return;
    }
    let chunk = out.len().div_ceil(workers);
    #[cfg(feature = "debug-invariants")]
    {
        // The spawned chunks must partition `out` exactly: contiguous,
        // non-overlapping, and covering every entry once.
        let mut covered = 0usize;
        for (c, piece) in out.chunks(chunk).enumerate() {
            crate::invariant!(
                c * chunk == covered,
                "chunk {c} starts at {} but the previous ended at {covered}",
                c * chunk
            );
            covered += piece.len();
        }
        crate::invariant!(
            covered == out.len(),
            "chunks cover {covered} of {} entries",
            out.len()
        );
    }
    let fill = &fill;
    std::thread::scope(|s| {
        for (c, out_chunk) in out.chunks_mut(chunk).enumerate() {
            let base = c * chunk;
            s.spawn(move || fill(base, out_chunk));
        }
    });
}

/// The tiled dot-product loop: `emit(p, j, dot)` is called for
/// `p ∈ [0, n)` in index order with `j = col(base + p)` and
/// `dot = Σ_k xi[k]·data[j][k]` accumulated in f64 feature order.
/// Dense query × dense data produces four output entries per tile so
/// `xi` is streamed once per four dot products; each entry still owns
/// its accumulator, so the dots are bit-identical to a scalar per-entry
/// loop. Any pairing with a CSR side takes [`Row::dot`] per entry —
/// the same bits, skipping only exact-zero terms.
#[inline]
fn dot_block<C: Fn(usize) -> usize, E: FnMut(usize, usize, f64)>(
    xi: Row<'_>,
    data: &Dataset,
    col: &C,
    base: usize,
    n: usize,
    mut emit: E,
) {
    let xi = match (xi, data.storage()) {
        (Row::Dense(xi), Features::Dense { .. }) => xi,
        _ => {
            // Sparse on either side: the merged dot per entry. Bit-parity
            // with the dense tile holds because skipped terms are exact
            // zero products (see `data::features`).
            for p in 0..n {
                let j = col(base + p);
                emit(p, j, xi.dot(data.row_ref(j)));
            }
            return;
        }
    };
    let d = data.dim();
    // One dispatch decision per block: the AVX2 tile only pays off with
    // at least one full 4-feature step, so sub-4 dims stay scalar even
    // when SIMD is selected.
    let use_simd = d >= 4 && simd::simd_active();
    let mut p = 0usize;
    while p + 4 <= n {
        let j0 = col(base + p);
        let j1 = col(base + p + 1);
        let j2 = col(base + p + 2);
        let j3 = col(base + p + 3);
        let x0 = data.row(j0);
        let x1 = data.row(j1);
        let x2 = data.row(j2);
        let x3 = data.row(j3);
        let [d0, d1, d2, d3] = if use_simd {
            #[cfg(test)]
            simd::SIMD_TILES.with(|c| c.set(c.get() + 1));
            simd::active_dot4(xi, x0, x1, x2, x3)
        } else {
            simd::scalar_dot4(xi, x0, x1, x2, x3)
        };
        emit(p, j0, d0);
        emit(p + 1, j1, d1);
        emit(p + 2, j2, d2);
        emit(p + 3, j3, d3);
        p += 4;
    }
    while p < n {
        let j = col(base + p);
        let xj = data.row(j);
        let mut dot = 0f64;
        for k in 0..d {
            dot += xi[k] as f64 * xj[k] as f64;
        }
        emit(p, j, dot);
        p += 1;
    }
}

/// Tiled kernel values of `xi` against dataset rows: `emit(p, value)` is
/// called for `p ∈ [0, n)` in index order with the f64 kernel value
/// `k(xi, data[col(base + p)])`.
///
/// `xi_sqnorm` is ‖xi‖² and `sqnorms` the dataset's [`squared_norms`] —
/// both consumed only by the RBF arm (any slice is accepted for the
/// dot-product kernels, which never index it). The per-entry arithmetic
/// matches the scalar evaluations exactly: for RBF the
/// `‖a‖²+‖b‖²−2a·b` decomposition (the Gram-row fast path), for
/// linear/poly/sigmoid the feature-order f64 dot that
/// [`KernelFunction::eval`] performs — so linear, polynomial and sigmoid
/// values are bit-identical to `eval`, and RBF values are bit-identical
/// to the established decomposition path.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn kernel_block<C: Fn(usize) -> usize, E: FnMut(usize, f64)>(
    kernel: KernelFunction,
    xi: Row<'_>,
    xi_sqnorm: f64,
    sqnorms: &[f64],
    data: &Dataset,
    col: &C,
    base: usize,
    n: usize,
    mut emit: E,
) {
    match kernel {
        KernelFunction::Rbf { gamma } => dot_block(xi, data, col, base, n, |p, j, dot| {
            emit(
                p,
                (-gamma * (xi_sqnorm + sqnorms[j] - 2.0 * dot).max(0.0)).exp(),
            )
        }),
        KernelFunction::Linear => {
            dot_block(xi, data, col, base, n, |p, _, dot| emit(p, dot))
        }
        KernelFunction::Poly { gamma, coef0, degree } => {
            dot_block(xi, data, col, base, n, |p, _, dot| {
                emit(p, (gamma * dot + coef0).powi(degree as i32))
            })
        }
        KernelFunction::Sigmoid { gamma, coef0 } => {
            dot_block(xi, data, col, base, n, |p, _, dot| {
                emit(p, (gamma * dot + coef0).tanh())
            })
        }
    }
}

/// [`kernel_block`] storing into an f32 row — the Gram-row shape
/// ([`super::matrix::RowComputer::compute_cols`] semantics:
/// `out[p] = k(xi, data[col(base + p)])`).
#[allow(clippy::too_many_arguments)]
pub fn kernel_block_f32<C: Fn(usize) -> usize>(
    kernel: KernelFunction,
    xi: Row<'_>,
    xi_sqnorm: f64,
    sqnorms: &[f64],
    data: &Dataset,
    col: &C,
    base: usize,
    out: &mut [f32],
) {
    kernel_block(
        kernel,
        xi,
        xi_sqnorm,
        sqnorms,
        data,
        col,
        base,
        out.len(),
        |p, v| out[p] = v as f32,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    fn random_ds(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Pcg::new(seed);
        let mut ds = Dataset::with_dim(d);
        let mut row = vec![0f32; d];
        for _ in 0..n {
            row.iter_mut().for_each(|v| *v = rng.normal() as f32);
            ds.push(&row, if rng.bernoulli(0.5) { 1 } else { -1 });
        }
        ds
    }

    #[test]
    fn kernel_block_matches_scalar_eval_for_dot_kernels() {
        let ds = random_ds(37, 6, 1); // 37 exercises the remainder lanes
        let sq = squared_norms(&ds);
        let xi: Vec<f32> = ds.row(5).to_vec();
        for k in [
            KernelFunction::Linear,
            KernelFunction::Poly { gamma: 0.4, coef0: 1.0, degree: 3 },
            KernelFunction::Sigmoid { gamma: 0.2, coef0: -0.5 },
        ] {
            let mut got = vec![0f64; ds.len()];
            kernel_block(k, Row::Dense(&xi), sq[5], &sq, &ds, &|p| p, 0, ds.len(), |p, v| {
                got[p] = v
            });
            for j in 0..ds.len() {
                let want = k.eval(&xi, ds.row(j));
                assert_eq!(
                    got[j].to_bits(),
                    want.to_bits(),
                    "{k:?} j={j}: {} vs {want}",
                    got[j]
                );
            }
        }
    }

    #[test]
    fn rbf_block_matches_decomposition_reference() {
        let ds = random_ds(41, 5, 2);
        let sq = squared_norms(&ds);
        let gamma = 0.8;
        let k = KernelFunction::Rbf { gamma };
        let xi: Vec<f32> = ds.row(3).to_vec();
        let mut got = vec![0f64; ds.len()];
        kernel_block(k, Row::Dense(&xi), sq[3], &sq, &ds, &|p| p, 0, ds.len(), |p, v| {
            got[p] = v
        });
        for j in 0..ds.len() {
            let mut dot = 0f64;
            for t in 0..ds.dim() {
                dot += xi[t] as f64 * ds.row(j)[t] as f64;
            }
            let want = (-gamma * (sq[3] + sq[j] - 2.0 * dot).max(0.0)).exp();
            assert_eq!(got[j].to_bits(), want.to_bits(), "j={j}");
            // and the decomposition agrees with the direct sqdist eval
            assert!((got[j] - k.eval(&xi, ds.row(j))).abs() < 1e-12);
        }
    }

    #[test]
    fn gathered_base_offsets_index_correctly() {
        let ds = random_ds(30, 4, 3);
        let sq = squared_norms(&ds);
        let k = KernelFunction::Rbf { gamma: 1.1 };
        let cols: Vec<usize> = (0..30).rev().collect();
        let mut full = vec![0f32; 30];
        kernel_block_f32(k, ds.row_ref(7), sq[7], &sq, &ds, &|p| p, 0, &mut full);
        // gather through cols with a non-zero base, as the chunked path does
        let mut part = vec![0f32; 10];
        kernel_block_f32(k, ds.row_ref(7), sq[7], &sq, &ds, &|p| cols[p], 12, &mut part);
        for p in 0..10 {
            assert_eq!(part[p].to_bits(), full[cols[12 + p]].to_bits(), "p={p}");
        }
    }

    #[test]
    fn chunked_is_bit_identical_and_covers_every_entry() {
        let ds = random_ds(257, 9, 4);
        let sq = squared_norms(&ds);
        let k = KernelFunction::Rbf { gamma: 0.6 };
        let xi: Vec<f32> = ds.row(0).to_vec();
        let mut inline = vec![0f32; 257];
        kernel_block_f32(k, Row::Dense(&xi), sq[0], &sq, &ds, &|p| p, 0, &mut inline);
        for workers in [2usize, 3, 8] {
            let mut par = vec![0f32; 257];
            chunked(workers, &mut par, |base, chunk| {
                kernel_block_f32(k, Row::Dense(&xi), sq[0], &sq, &ds, &|p| p, base, chunk);
            });
            assert!(
                inline.iter().zip(&par).all(|(a, b)| a.to_bits() == b.to_bits()),
                "workers={workers} diverges"
            );
        }
    }

    #[test]
    fn sparse_blocks_are_bit_identical_to_dense_blocks() {
        // Densities chosen so rows contain exact zeros (the skipped terms).
        let dense = {
            let mut rng = Pcg::new(11);
            let mut ds = Dataset::with_dim(7);
            let mut row = vec![0f32; 7];
            for _ in 0..43 {
                row.iter_mut().for_each(|v| {
                    *v = if rng.bernoulli(0.3) { rng.normal() as f32 } else { 0.0 }
                });
                ds.push(&row, if rng.bernoulli(0.5) { 1 } else { -1 });
            }
            ds
        };
        let sparse = dense.to_sparse();
        let sq_d = squared_norms(&dense);
        let sq_s = squared_norms(&sparse);
        assert!(sq_d.iter().zip(&sq_s).all(|(a, b)| a.to_bits() == b.to_bits()));
        for k in [
            KernelFunction::Rbf { gamma: 0.9 },
            KernelFunction::Linear,
            KernelFunction::Poly { gamma: 0.4, coef0: 1.0, degree: 3 },
            KernelFunction::Sigmoid { gamma: 0.2, coef0: -0.5 },
        ] {
            let mut want = vec![0f32; dense.len()];
            kernel_block_f32(k, dense.row_ref(5), sq_d[5], &sq_d, &dense, &|p| p, 0, &mut want);
            // sparse query × sparse data, sparse × dense, dense × sparse
            for (xi, data, sq) in [
                (sparse.row_ref(5), &sparse, &sq_s),
                (sparse.row_ref(5), &dense, &sq_d),
                (dense.row_ref(5), &sparse, &sq_s),
            ] {
                let mut got = vec![0f32; data.len()];
                kernel_block_f32(k, xi, sq_s[5], sq, data, &|p| p, 0, &mut got);
                assert!(
                    want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{k:?} sparse block diverges from dense"
                );
            }
        }
    }

    #[test]
    fn worker_gate_respects_threshold_and_clamps() {
        assert_eq!(workers_for(1, 1 << 20, 10), 1, "single-threaded stays inline");
        assert_eq!(workers_for(4, 10, 2), 1, "tiny work stays inline");
        assert_eq!(workers_for(4, PAR_MIN_MADDS, 1), 4);
        assert_eq!(workers_for(8, PAR_MIN_MADDS / 4, 4), 8);
        assert_eq!(workers_for(8, 3, 1 << 20), 3, "never more workers than entries");
        assert_eq!(workers_for(4, 0, 64), 1, "empty block stays inline");
    }

    #[test]
    fn chunked_handles_empty_and_tiny_outputs() {
        let mut empty: Vec<f32> = Vec::new();
        chunked(4, &mut empty, |_, chunk| assert!(chunk.is_empty()));
        let mut one = vec![0f64; 1];
        chunked(4, &mut one, |base, chunk| {
            assert_eq!(base, 0);
            chunk[0] = 7.0;
        });
        assert_eq!(one[0], 7.0);
    }

    /// The entire SIMD wall lives in one `#[test]` because the tile
    /// selection is process-global: a single test serializes every mode
    /// flip. Concurrently-running tests may observe the flips, but all
    /// their assertions are bit-parity statements that hold under
    /// either tile — only the dispatch-*accounting* assertions here
    /// need the mode pinned.
    #[test]
    fn simd_wall_force_vs_off_parity_and_dispatch() {
        use super::simd::{self, SimdMode};

        // Mode parsing and detection consistency (every host).
        assert_eq!(SimdMode::parse("auto"), Some(SimdMode::Auto));
        assert_eq!(SimdMode::parse("FORCE"), Some(SimdMode::Force));
        assert_eq!(SimdMode::parse("Off"), Some(SimdMode::Off));
        assert_eq!(SimdMode::parse("fast"), None);
        assert!(simd::set_simd_mode(SimdMode::Off), "off always succeeds");
        assert!(!simd::simd_active());
        assert!(simd::set_simd_mode(SimdMode::Auto), "auto always succeeds");
        assert_eq!(simd::simd_active(), simd::simd_supported());
        assert_eq!(
            simd::set_simd_mode(SimdMode::Force),
            simd::simd_supported(),
            "force succeeds exactly on AVX2 hosts"
        );
        assert_eq!(simd::simd_active(), simd::simd_supported());

        if simd::simd_supported() {
            let kernels = [
                KernelFunction::Rbf { gamma: 0.7 },
                KernelFunction::Linear,
                KernelFunction::Poly { gamma: 0.4, coef0: 1.0, degree: 3 },
                KernelFunction::Sigmoid { gamma: 0.2, coef0: -0.5 },
            ];
            // n covers remainder lanes 1–3 and sub-4 blocks; d covers
            // sub-4 dims (scalar even under force) and 4k+r tails.
            for &n in &[1usize, 2, 3, 4, 5, 7, 8, 37] {
                for &d in &[1usize, 2, 3, 4, 5, 7, 8, 13] {
                    let ds = random_ds(n, d, (n * 31 + d) as u64);
                    let sq = squared_norms(&ds);
                    let xi: Vec<f32> = ds.row(n / 2).to_vec();
                    for k in kernels {
                        simd::set_simd_mode(SimdMode::Off);
                        let mut want = vec![0f64; n];
                        kernel_block(k, Row::Dense(&xi), sq[n / 2], &sq, &ds, &|p| p, 0, n, |p, v| {
                            want[p] = v
                        });
                        simd::set_simd_mode(SimdMode::Force);
                        let before = simd::simd_tiles_on_thread();
                        let mut got = vec![0f64; n];
                        kernel_block(k, Row::Dense(&xi), sq[n / 2], &sq, &ds, &|p| p, 0, n, |p, v| {
                            got[p] = v
                        });
                        let tiles = simd::simd_tiles_on_thread() - before;
                        for p in 0..n {
                            assert_eq!(
                                got[p].to_bits(),
                                want[p].to_bits(),
                                "{k:?} n={n} d={d} p={p}: {} vs {}",
                                got[p],
                                want[p]
                            );
                        }
                        assert_eq!(
                            tiles,
                            if d >= 4 { n / 4 } else { 0 },
                            "{k:?} n={n} d={d}: wrong tile dispatch count"
                        );
                    }
                }
            }

            // CSR pairings keep the merged-dot fallback even under force.
            simd::set_simd_mode(SimdMode::Force);
            let dense = random_ds(23, 9, 5);
            let sparse = dense.to_sparse();
            let sq_s = squared_norms(&sparse);
            let before = simd::simd_tiles_on_thread();
            let mut out = vec![0f32; 23];
            kernel_block_f32(
                KernelFunction::Rbf { gamma: 0.6 },
                sparse.row_ref(2),
                sq_s[2],
                &sq_s,
                &sparse,
                &|p| p,
                0,
                &mut out,
            );
            let mut out2 = vec![0f32; 23];
            kernel_block_f32(
                KernelFunction::Linear,
                Row::Dense(&dense.row(2).to_vec()),
                sq_s[2],
                &sq_s,
                &sparse,
                &|p| p,
                0,
                &mut out2,
            );
            assert_eq!(
                simd::simd_tiles_on_thread(),
                before,
                "CSR pairings must not take the SIMD tile"
            );

            // Threaded chunked composition under force is bit-identical
            // to the inline scalar tile.
            let ds = random_ds(257, 16, 6);
            let sq = squared_norms(&ds);
            let xi: Vec<f32> = ds.row(0).to_vec();
            let k = KernelFunction::Rbf { gamma: 0.5 };
            simd::set_simd_mode(SimdMode::Off);
            let mut inline = vec![0f32; 257];
            kernel_block_f32(k, Row::Dense(&xi), sq[0], &sq, &ds, &|p| p, 0, &mut inline);
            simd::set_simd_mode(SimdMode::Force);
            for workers in [2usize, 3, 8] {
                let mut par = vec![0f32; 257];
                chunked(workers, &mut par, |base, chunk| {
                    kernel_block_f32(k, Row::Dense(&xi), sq[0], &sq, &ds, &|p| p, base, chunk);
                });
                assert!(
                    inline.iter().zip(&par).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "workers={workers}: SIMD chunked diverges from scalar inline"
                );
            }

            // A full Gram row through the native computer, both modes.
            use crate::kernel::matrix::RowComputer;
            let ds = std::sync::Arc::new(random_ds(130, 24, 7));
            let nat = crate::kernel::NativeRowComputer::new(
                ds.clone(),
                KernelFunction::Rbf { gamma: 0.3 },
            );
            simd::set_simd_mode(SimdMode::Off);
            let mut off_row = vec![0f32; 130];
            nat.compute_row(17, &mut off_row);
            simd::set_simd_mode(SimdMode::Force);
            let mut on_row = vec![0f32; 130];
            nat.compute_row(17, &mut on_row);
            assert!(
                off_row.iter().zip(&on_row).all(|(a, b)| a.to_bits() == b.to_bits()),
                "native Gram row diverges between tiles"
            );
        }

        // Restore the ambient mode for concurrently-running tests.
        let ambient = std::env::var("PASMO_SIMD")
            .ok()
            .and_then(|v| SimdMode::parse(&v))
            .unwrap_or(SimdMode::Auto);
        simd::set_simd_mode(ambient);
    }
}
