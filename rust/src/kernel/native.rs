//! Native (pure-Rust) Gram row computer — the fallback when PJRT
//! artifacts are absent and the numerics/performance comparator for the
//! runtime path (bench_kernel_throughput).

use std::sync::Arc;

use crate::data::dataset::Dataset;

use super::function::KernelFunction;
use super::matrix::RowComputer;

/// Minimum multiply-add work (entries × feature dim) before a row is
/// split across threads. Spawning and joining scoped workers costs tens
/// of microseconds, so low-dimensional or post-shrink short rows — whose
/// whole computation is cheaper than a spawn — always run inline; the
/// gate is on estimated flops, not entry count.
const PAR_MIN_MADDS: usize = 1 << 16;

/// Computes kernel rows directly from the dataset.
///
/// For RBF the row loop uses the `‖a‖²+‖b‖²−2a·b` decomposition with
/// precomputed squared norms, turning each row into one pass of dot
/// products — the same structure the Pallas kernel uses on the MXU. The
/// pass is tiled four output entries wide so `x_i` is loaded once per
/// four dot products; each entry still accumulates its own f64 dot in
/// index order, so tiled results are bit-identical to the scalar loop.
///
/// With `threads > 1` (see [`NativeRowComputer::with_threads`]) long rows
/// are chunked across a `std::thread::scope` — entries are computed by
/// exactly the same arithmetic regardless of the chunking, so threaded
/// rows are bit-identical to single-threaded ones.
pub struct NativeRowComputer {
    data: Arc<Dataset>,
    kernel: KernelFunction,
    /// Precomputed ‖x_i‖² (used by the RBF fast path).
    sqnorms: Vec<f64>,
    /// Worker threads for row computation (1 = inline).
    threads: usize,
}

impl NativeRowComputer {
    /// Single-threaded computer over `data` with the given kernel.
    pub fn new(data: Arc<Dataset>, kernel: KernelFunction) -> NativeRowComputer {
        NativeRowComputer::with_threads(data, kernel, 1)
    }

    /// Like [`NativeRowComputer::new`] with `threads` row-computation
    /// workers (`0`/`1` = compute inline on the calling thread).
    pub fn with_threads(
        data: Arc<Dataset>,
        kernel: KernelFunction,
        threads: usize,
    ) -> NativeRowComputer {
        let sqnorms = (0..data.len())
            .map(|i| data.row(i).iter().map(|&v| v as f64 * v as f64).sum())
            .collect();
        NativeRowComputer { data, kernel, sqnorms, threads: threads.max(1) }
    }

    /// The kernel function this computer evaluates.
    pub fn kernel(&self) -> KernelFunction {
        self.kernel
    }

    /// Configured row-computation worker threads (1 = inline).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Fill `out` with kernel values of example `i` against the columns
    /// named by `col(p)` (identity for full rows, the active permutation
    /// for gathered rows).
    fn fill<C: Fn(usize) -> usize + Sync>(&self, i: usize, col: C, out: &mut [f32]) {
        let xi = self.data.row(i);
        let m = out.len();
        let work = m * self.data.dim().max(1);
        let workers = if self.threads > 1 && work >= PAR_MIN_MADDS {
            self.threads.min(m)
        } else {
            1
        };
        match self.kernel {
            KernelFunction::Rbf { gamma } => {
                let ni = self.sqnorms[i];
                if workers <= 1 {
                    rbf_tile(xi, &self.sqnorms, &self.data, ni, gamma, &col, 0, out);
                } else {
                    let chunk = m.div_ceil(workers);
                    let data = &*self.data;
                    let sqnorms = &self.sqnorms;
                    let col = &col;
                    std::thread::scope(|s| {
                        for (c, out_chunk) in out.chunks_mut(chunk).enumerate() {
                            let base = c * chunk;
                            s.spawn(move || {
                                rbf_tile(
                                    xi, sqnorms, data, ni, gamma, col, base, out_chunk,
                                );
                            });
                        }
                    });
                }
            }
            k => {
                if workers <= 1 {
                    for (p, o) in out.iter_mut().enumerate() {
                        *o = k.eval(xi, self.data.row(col(p))) as f32;
                    }
                } else {
                    let chunk = m.div_ceil(workers);
                    let data = &*self.data;
                    let col = &col;
                    std::thread::scope(|s| {
                        for (c, out_chunk) in out.chunks_mut(chunk).enumerate() {
                            let base = c * chunk;
                            s.spawn(move || {
                                for (p, o) in out_chunk.iter_mut().enumerate() {
                                    *o = k.eval(xi, data.row(col(base + p))) as f32;
                                }
                            });
                        }
                    });
                }
            }
        }
    }
}

/// The tiled RBF row loop: four output entries per step, `x_i` streamed
/// once per tile. Every entry's dot product accumulates in feature order
/// into its own f64, exactly like the scalar remainder loop — results
/// are bit-identical to a one-entry-at-a-time evaluation (asserted by
/// test), so tiling is purely a memory-locality optimization.
#[allow(clippy::too_many_arguments)]
fn rbf_tile<C: Fn(usize) -> usize>(
    xi: &[f32],
    sqnorms: &[f64],
    data: &Dataset,
    ni: f64,
    gamma: f64,
    col: &C,
    base: usize,
    out: &mut [f32],
) {
    let d = data.dim();
    let m = out.len();
    let mut p = 0usize;
    while p + 4 <= m {
        let j0 = col(base + p);
        let j1 = col(base + p + 1);
        let j2 = col(base + p + 2);
        let j3 = col(base + p + 3);
        let x0 = data.row(j0);
        let x1 = data.row(j1);
        let x2 = data.row(j2);
        let x3 = data.row(j3);
        let (mut d0, mut d1, mut d2, mut d3) = (0f64, 0f64, 0f64, 0f64);
        for k in 0..d {
            let v = xi[k] as f64;
            d0 += v * x0[k] as f64;
            d1 += v * x1[k] as f64;
            d2 += v * x2[k] as f64;
            d3 += v * x3[k] as f64;
        }
        out[p] = (-gamma * (ni + sqnorms[j0] - 2.0 * d0).max(0.0)).exp() as f32;
        out[p + 1] = (-gamma * (ni + sqnorms[j1] - 2.0 * d1).max(0.0)).exp() as f32;
        out[p + 2] = (-gamma * (ni + sqnorms[j2] - 2.0 * d2).max(0.0)).exp() as f32;
        out[p + 3] = (-gamma * (ni + sqnorms[j3] - 2.0 * d3).max(0.0)).exp() as f32;
        p += 4;
    }
    while p < m {
        let j = col(base + p);
        let xj = data.row(j);
        let mut dot = 0f64;
        for k in 0..d {
            dot += xi[k] as f64 * xj[k] as f64;
        }
        out[p] = (-gamma * (ni + sqnorms[j] - 2.0 * dot).max(0.0)).exp() as f32;
        p += 1;
    }
}

impl RowComputer for NativeRowComputer {
    fn len(&self) -> usize {
        self.data.len()
    }

    fn compute_row(&self, i: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.data.len());
        self.fill(i, |p| p, out);
    }

    fn compute_cols(&self, i: usize, cols: &[usize], out: &mut [f32]) {
        assert_eq!(cols.len(), out.len());
        self.fill(i, |p| cols[p], out);
    }

    fn cols_cost(&self, requested: usize) -> usize {
        requested // direct gather: only the requested columns are evaluated
    }

    fn diag(&self, i: usize) -> f64 {
        self.kernel.eval_self(self.data.row(i))
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        self.kernel.eval(self.data.row(i), self.data.row(j))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    fn random_ds(n: usize, d: usize, seed: u64) -> Arc<Dataset> {
        let mut rng = Pcg::new(seed);
        let mut ds = Dataset::with_dim(d);
        let mut row = vec![0f32; d];
        for _ in 0..n {
            row.iter_mut().for_each(|v| *v = rng.normal() as f32);
            ds.push(&row, if rng.bernoulli(0.5) { 1 } else { -1 });
        }
        Arc::new(ds)
    }

    /// The scalar reference: one entry at a time, f64 accumulation in
    /// feature order — the contract the tiled loop must match bit for bit.
    fn scalar_rbf_row(ds: &Dataset, gamma: f64, i: usize, out: &mut [f32]) {
        let sq: Vec<f64> = (0..ds.len())
            .map(|r| ds.row(r).iter().map(|&v| v as f64 * v as f64).sum())
            .collect();
        let xi = ds.row(i);
        for (j, o) in out.iter_mut().enumerate() {
            let xj = ds.row(j);
            let mut dot = 0f64;
            for k in 0..ds.dim() {
                dot += xi[k] as f64 * xj[k] as f64;
            }
            *o = (-gamma * (sq[i] + sq[j] - 2.0 * dot).max(0.0)).exp() as f32;
        }
    }

    #[test]
    fn rbf_row_matches_pairwise_eval() {
        let ds = random_ds(50, 7, 1);
        let k = KernelFunction::Rbf { gamma: 0.8 };
        let nc = NativeRowComputer::new(ds.clone(), k);
        let mut row = vec![0f32; 50];
        nc.compute_row(17, &mut row);
        for j in 0..50 {
            let direct = k.eval(ds.row(17), ds.row(j)) as f32;
            assert!((row[j] - direct).abs() < 1e-6, "j={j}: {} vs {direct}", row[j]);
        }
        assert!((row[17] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn tiled_rows_bit_identical_to_scalar_reference() {
        // sizes exercising every remainder lane of the 4-wide tile
        for (n, d, seed) in [(64, 5, 1u64), (65, 3, 2), (66, 11, 3), (67, 1, 4)] {
            let ds = random_ds(n, d, seed);
            let gamma = 0.7;
            let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma });
            let mut tiled = vec![0f32; n];
            let mut scalar = vec![0f32; n];
            for i in [0usize, n / 2, n - 1] {
                nc.compute_row(i, &mut tiled);
                scalar_rbf_row(&ds, gamma, i, &mut scalar);
                for j in 0..n {
                    assert_eq!(
                        tiled[j].to_bits(),
                        scalar[j].to_bits(),
                        "n={n} i={i} j={j}: tiled {} vs scalar {}",
                        tiled[j],
                        scalar[j]
                    );
                }
            }
        }
    }

    #[test]
    fn gathered_cols_bit_identical_to_full_row() {
        let ds = random_ds(80, 6, 9);
        let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 1.3 });
        let mut full = vec![0f32; 80];
        nc.compute_row(13, &mut full);
        // an arbitrary permutation prefix with repeats and reversals
        let cols: Vec<usize> = (0..80).rev().step_by(3).chain([13, 13, 0, 79]).collect();
        let mut gathered = vec![0f32; cols.len()];
        nc.compute_cols(13, &cols, &mut gathered);
        for (p, &c) in cols.iter().enumerate() {
            assert_eq!(gathered[p].to_bits(), full[c].to_bits(), "col {c}");
        }
    }

    #[test]
    fn threaded_rows_bit_identical_to_single_threaded() {
        // ℓ·d = 700·100 clears the work-based threading threshold
        let ds = random_ds(700, 100, 11);
        let k = KernelFunction::Rbf { gamma: 0.4 };
        let one = NativeRowComputer::new(ds.clone(), k);
        let four = NativeRowComputer::with_threads(ds.clone(), k, 4);
        assert_eq!(four.threads(), 4);
        assert!(700 * 100 >= super::PAR_MIN_MADDS, "test must exercise the threaded path");
        let mut a = vec![0f32; 700];
        let mut b = vec![0f32; 700];
        for i in [0usize, 350, 699] {
            one.compute_row(i, &mut a);
            four.compute_row(i, &mut b);
            assert!(
                a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "row {i} diverges across thread counts"
            );
        }
        // gathered rows too
        let cols: Vec<usize> = (0..700).rev().collect();
        let mut ga = vec![0f32; 700];
        let mut gb = vec![0f32; 700];
        one.compute_cols(3, &cols, &mut ga);
        four.compute_cols(3, &cols, &mut gb);
        assert!(ga.iter().zip(&gb).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn entry_and_diag_consistent_with_row() {
        let ds = random_ds(20, 3, 2);
        let nc = NativeRowComputer::new(ds, KernelFunction::Rbf { gamma: 2.0 });
        let mut row = vec![0f32; 20];
        nc.compute_row(5, &mut row);
        assert!((nc.entry(5, 11) - row[11] as f64).abs() < 1e-6);
        assert_eq!(nc.diag(5), 1.0);
    }

    #[test]
    fn linear_kernel_rows() {
        let ds = random_ds(10, 4, 3);
        let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Linear);
        let mut row = vec![0f32; 10];
        nc.compute_row(0, &mut row);
        for j in 0..10 {
            let want: f64 = ds
                .row(0)
                .iter()
                .zip(ds.row(j))
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum();
            assert!((row[j] as f64 - want).abs() < 1e-5);
        }
        // gathered linear rows go through the generic path
        let cols = [9usize, 0, 4];
        let mut g = vec![0f32; 3];
        nc.compute_cols(0, &cols, &mut g);
        for (p, &c) in cols.iter().enumerate() {
            assert_eq!(g[p].to_bits(), row[c].to_bits());
        }
    }

    #[test]
    fn gram_symmetry_property() {
        crate::util::quickcheck::forall(
            "gram-symmetry",
            10,
            |g| {
                let n = 8 + g.below(24);
                let d = 1 + g.below(6);
                (random_ds(n, d, g.next_u64()), g.range(0.05, 3.0))
            },
            |(ds, gamma)| {
                let nc =
                    NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: *gamma });
                let n = ds.len();
                let mut ri = vec![0f32; n];
                let mut rj = vec![0f32; n];
                for i in 0..n.min(6) {
                    nc.compute_row(i, &mut ri);
                    for j in 0..n.min(6) {
                        nc.compute_row(j, &mut rj);
                        if (ri[j] - rj[i]).abs() > 1e-6 {
                            return Err(format!("K[{i},{j}]={} K[{j},{i}]={}", ri[j], rj[i]));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
