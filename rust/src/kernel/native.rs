//! Native (pure-Rust) Gram row computer — the fallback when PJRT
//! artifacts are absent and the numerics/performance comparator for the
//! runtime path (bench_kernel_throughput).

use std::sync::Arc;

use crate::data::dataset::Dataset;

use super::function::KernelFunction;
use super::matrix::RowComputer;
use super::tile;

/// Computes kernel rows directly from the dataset, on the shared
/// [`tile`] primitives (the same code path the batch
/// [`crate::svm::scorer::Scorer`] uses for SV×query blocks).
///
/// For RBF the row loop uses the `‖a‖²+‖b‖²−2a·b` decomposition with
/// precomputed squared norms, turning each row into one pass of dot
/// products — the same structure the Pallas kernel uses on the MXU. The
/// pass is tiled four output entries wide so `x_i` is loaded once per
/// four dot products; each entry still accumulates its own f64 dot in
/// index order, so tiled results are bit-identical to the scalar loop.
/// The dot-product kernels (linear/poly/sigmoid) run the same tiled
/// pass with their own value map, bit-identical to
/// [`KernelFunction::eval`].
///
/// With `threads > 1` (see [`NativeRowComputer::with_threads`]) long rows
/// are chunked across a `std::thread::scope` ([`tile::chunked`]) —
/// entries are computed by exactly the same arithmetic regardless of the
/// chunking, so threaded rows are bit-identical to single-threaded ones.
///
/// On AVX2 hosts the dense tile underneath runs the explicit SIMD
/// implementation ([`tile::simd`]) selected once per process — it
/// vectorizes *across* the four tile outputs and is `to_bits`-identical
/// to the scalar tile (DESIGN.md §4g), so nothing at this layer or
/// above can observe which path was dispatched.
///
/// The computer is backend-agnostic: CSR-sparse datasets route through
/// the same [`tile`] entry points (merged sparse dots, same bits as the
/// dense tile — see `data::features`), so the solver above never learns
/// which storage it trained on.
pub struct NativeRowComputer {
    data: Arc<Dataset>,
    kernel: KernelFunction,
    /// Precomputed ‖x_i‖² (used by the RBF fast path).
    sqnorms: Vec<f64>,
    /// Worker threads for row computation (1 = inline).
    threads: usize,
}

impl NativeRowComputer {
    /// Single-threaded computer over `data` with the given kernel.
    pub fn new(data: Arc<Dataset>, kernel: KernelFunction) -> NativeRowComputer {
        NativeRowComputer::with_threads(data, kernel, 1)
    }

    /// Like [`NativeRowComputer::new`] with `threads` row-computation
    /// workers (`0`/`1` = compute inline on the calling thread).
    pub fn with_threads(
        data: Arc<Dataset>,
        kernel: KernelFunction,
        threads: usize,
    ) -> NativeRowComputer {
        let sqnorms = tile::squared_norms(&data);
        NativeRowComputer { data, kernel, sqnorms, threads: threads.max(1) }
    }

    /// The kernel function this computer evaluates.
    pub fn kernel(&self) -> KernelFunction {
        self.kernel
    }

    /// Configured row-computation worker threads (1 = inline).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Fill `out` with kernel values of example `i` against the columns
    /// named by `col(p)` (identity for full rows, the active permutation
    /// for gathered rows). One call into the shared [`tile`] primitives:
    /// the worker gate, the chunking and the 4-wide tiled value loop are
    /// the same code the batch scorer runs.
    fn fill<C: Fn(usize) -> usize + Sync>(&self, i: usize, col: C, out: &mut [f32]) {
        let xi = self.data.row_ref(i);
        let ni = self.sqnorms[i];
        let workers = tile::workers_for(self.threads, out.len(), self.data.dim());
        let kernel = self.kernel;
        let data = &*self.data;
        let sqnorms = &self.sqnorms;
        let col = &col;
        tile::chunked(workers, out, |base, chunk| {
            tile::kernel_block_f32(kernel, xi, ni, sqnorms, data, col, base, chunk);
        });
    }
}

impl RowComputer for NativeRowComputer {
    fn len(&self) -> usize {
        self.data.len()
    }

    fn compute_row(&self, i: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.data.len());
        self.fill(i, |p| p, out);
    }

    fn compute_cols(&self, i: usize, cols: &[usize], out: &mut [f32]) {
        assert_eq!(cols.len(), out.len());
        self.fill(i, |p| cols[p], out);
    }

    fn cols_cost(&self, requested: usize) -> usize {
        requested // direct gather: only the requested columns are evaluated
    }

    fn diag(&self, i: usize) -> f64 {
        self.kernel.eval_self_row(self.data.row_ref(i))
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        self.kernel.eval_rows(self.data.row_ref(i), self.data.row_ref(j))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    fn random_ds(n: usize, d: usize, seed: u64) -> Arc<Dataset> {
        let mut rng = Pcg::new(seed);
        let mut ds = Dataset::with_dim(d);
        let mut row = vec![0f32; d];
        for _ in 0..n {
            row.iter_mut().for_each(|v| *v = rng.normal() as f32);
            ds.push(&row, if rng.bernoulli(0.5) { 1 } else { -1 });
        }
        Arc::new(ds)
    }

    /// The scalar reference: one entry at a time, f64 accumulation in
    /// feature order — the contract the tiled loop must match bit for bit.
    fn scalar_rbf_row(ds: &Dataset, gamma: f64, i: usize, out: &mut [f32]) {
        let sq: Vec<f64> = (0..ds.len())
            .map(|r| ds.row(r).iter().map(|&v| v as f64 * v as f64).sum())
            .collect();
        let xi = ds.row(i);
        for (j, o) in out.iter_mut().enumerate() {
            let xj = ds.row(j);
            let mut dot = 0f64;
            for k in 0..ds.dim() {
                dot += xi[k] as f64 * xj[k] as f64;
            }
            *o = (-gamma * (sq[i] + sq[j] - 2.0 * dot).max(0.0)).exp() as f32;
        }
    }

    #[test]
    fn rbf_row_matches_pairwise_eval() {
        let ds = random_ds(50, 7, 1);
        let k = KernelFunction::Rbf { gamma: 0.8 };
        let nc = NativeRowComputer::new(ds.clone(), k);
        let mut row = vec![0f32; 50];
        nc.compute_row(17, &mut row);
        for j in 0..50 {
            let direct = k.eval(ds.row(17), ds.row(j)) as f32;
            assert!((row[j] - direct).abs() < 1e-6, "j={j}: {} vs {direct}", row[j]);
        }
        assert!((row[17] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn tiled_rows_bit_identical_to_scalar_reference() {
        // sizes exercising every remainder lane of the 4-wide tile
        for (n, d, seed) in [(64, 5, 1u64), (65, 3, 2), (66, 11, 3), (67, 1, 4)] {
            let ds = random_ds(n, d, seed);
            let gamma = 0.7;
            let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma });
            let mut tiled = vec![0f32; n];
            let mut scalar = vec![0f32; n];
            for i in [0usize, n / 2, n - 1] {
                nc.compute_row(i, &mut tiled);
                scalar_rbf_row(&ds, gamma, i, &mut scalar);
                for j in 0..n {
                    assert_eq!(
                        tiled[j].to_bits(),
                        scalar[j].to_bits(),
                        "n={n} i={i} j={j}: tiled {} vs scalar {}",
                        tiled[j],
                        scalar[j]
                    );
                }
            }
        }
    }

    #[test]
    fn gathered_cols_bit_identical_to_full_row() {
        let ds = random_ds(80, 6, 9);
        let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 1.3 });
        let mut full = vec![0f32; 80];
        nc.compute_row(13, &mut full);
        // an arbitrary permutation prefix with repeats and reversals
        let cols: Vec<usize> = (0..80).rev().step_by(3).chain([13, 13, 0, 79]).collect();
        let mut gathered = vec![0f32; cols.len()];
        nc.compute_cols(13, &cols, &mut gathered);
        for (p, &c) in cols.iter().enumerate() {
            assert_eq!(gathered[p].to_bits(), full[c].to_bits(), "col {c}");
        }
    }

    #[test]
    fn threaded_rows_bit_identical_to_single_threaded() {
        // ℓ·d = 700·100 clears the work-based threading threshold
        let ds = random_ds(700, 100, 11);
        let k = KernelFunction::Rbf { gamma: 0.4 };
        let one = NativeRowComputer::new(ds.clone(), k);
        let four = NativeRowComputer::with_threads(ds.clone(), k, 4);
        assert_eq!(four.threads(), 4);
        assert!(
            700 * 100 >= crate::kernel::tile::PAR_MIN_MADDS,
            "test must exercise the threaded path"
        );
        let mut a = vec![0f32; 700];
        let mut b = vec![0f32; 700];
        for i in [0usize, 350, 699] {
            one.compute_row(i, &mut a);
            four.compute_row(i, &mut b);
            assert!(
                a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "row {i} diverges across thread counts"
            );
        }
        // gathered rows too
        let cols: Vec<usize> = (0..700).rev().collect();
        let mut ga = vec![0f32; 700];
        let mut gb = vec![0f32; 700];
        one.compute_cols(3, &cols, &mut ga);
        four.compute_cols(3, &cols, &mut gb);
        assert!(ga.iter().zip(&gb).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn entry_and_diag_consistent_with_row() {
        let ds = random_ds(20, 3, 2);
        let nc = NativeRowComputer::new(ds, KernelFunction::Rbf { gamma: 2.0 });
        let mut row = vec![0f32; 20];
        nc.compute_row(5, &mut row);
        assert!((nc.entry(5, 11) - row[11] as f64).abs() < 1e-6);
        assert_eq!(nc.diag(5), 1.0);
    }

    #[test]
    fn linear_kernel_rows() {
        let ds = random_ds(10, 4, 3);
        let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Linear);
        let mut row = vec![0f32; 10];
        nc.compute_row(0, &mut row);
        for j in 0..10 {
            let want: f64 = ds
                .row(0)
                .iter()
                .zip(ds.row(j))
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum();
            assert!((row[j] as f64 - want).abs() < 1e-5);
        }
        // gathered linear rows go through the generic path
        let cols = [9usize, 0, 4];
        let mut g = vec![0f32; 3];
        nc.compute_cols(0, &cols, &mut g);
        for (p, &c) in cols.iter().enumerate() {
            assert_eq!(g[p].to_bits(), row[c].to_bits());
        }
    }

    #[test]
    fn sparse_gram_rows_bit_identical_to_dense() {
        let mut rng = Pcg::new(21);
        let mut dense = Dataset::with_dim(9);
        let mut row = vec![0f32; 9];
        for _ in 0..61 {
            row.iter_mut().for_each(|v| {
                *v = if rng.bernoulli(0.25) { rng.normal() as f32 } else { 0.0 }
            });
            dense.push(&row, if rng.bernoulli(0.5) { 1 } else { -1 });
        }
        let sparse = Arc::new(dense.to_sparse());
        let dense = Arc::new(dense);
        for k in [
            KernelFunction::Rbf { gamma: 0.8 },
            KernelFunction::Linear,
            KernelFunction::Poly { gamma: 0.4, coef0: 1.0, degree: 2 },
            KernelFunction::Sigmoid { gamma: 0.3, coef0: -0.2 },
        ] {
            let nd = NativeRowComputer::new(dense.clone(), k);
            let ns = NativeRowComputer::new(sparse.clone(), k);
            let mut a = vec![0f32; 61];
            let mut b = vec![0f32; 61];
            for i in [0usize, 30, 60] {
                nd.compute_row(i, &mut a);
                ns.compute_row(i, &mut b);
                assert!(
                    a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{k:?} row {i} diverges across storage backends"
                );
                assert_eq!(nd.diag(i).to_bits(), ns.diag(i).to_bits());
                assert_eq!(nd.entry(i, 7).to_bits(), ns.entry(i, 7).to_bits());
            }
            // gathered columns through the permutation path
            let cols: Vec<usize> = (0..61).rev().step_by(2).collect();
            let mut ga = vec![0f32; cols.len()];
            let mut gb = vec![0f32; cols.len()];
            nd.compute_cols(4, &cols, &mut ga);
            ns.compute_cols(4, &cols, &mut gb);
            assert!(ga.iter().zip(&gb).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
    }

    #[test]
    fn gram_symmetry_property() {
        crate::util::quickcheck::forall(
            "gram-symmetry",
            10,
            |g| {
                let n = 8 + g.below(24);
                let d = 1 + g.below(6);
                (random_ds(n, d, g.next_u64()), g.range(0.05, 3.0))
            },
            |(ds, gamma)| {
                let nc =
                    NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: *gamma });
                let n = ds.len();
                let mut ri = vec![0f32; n];
                let mut rj = vec![0f32; n];
                for i in 0..n.min(6) {
                    nc.compute_row(i, &mut ri);
                    for j in 0..n.min(6) {
                        nc.compute_row(j, &mut rj);
                        if (ri[j] - rj[i]).abs() > 1e-6 {
                            return Err(format!("K[{i},{j}]={} K[{j},{i}]={}", ri[j], rj[i]));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
