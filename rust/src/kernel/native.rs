//! Native (pure-Rust) Gram row computer — the fallback when PJRT
//! artifacts are absent and the numerics/performance comparator for the
//! runtime path (bench_kernel_throughput).

use std::sync::Arc;

use crate::data::dataset::Dataset;

use super::function::KernelFunction;
use super::matrix::RowComputer;

/// Computes kernel rows directly from the dataset.
///
/// For RBF the row loop uses the `‖a‖²+‖b‖²−2a·b` decomposition with
/// precomputed squared norms, turning each row into one pass of dot
/// products — the same structure the Pallas kernel uses on the MXU.
pub struct NativeRowComputer {
    data: Arc<Dataset>,
    kernel: KernelFunction,
    /// Precomputed ‖x_i‖² (used by the RBF fast path).
    sqnorms: Vec<f64>,
}

impl NativeRowComputer {
    pub fn new(data: Arc<Dataset>, kernel: KernelFunction) -> NativeRowComputer {
        let sqnorms = (0..data.len())
            .map(|i| data.row(i).iter().map(|&v| v as f64 * v as f64).sum())
            .collect();
        NativeRowComputer { data, kernel, sqnorms }
    }

    pub fn kernel(&self) -> KernelFunction {
        self.kernel
    }
}

impl RowComputer for NativeRowComputer {
    fn len(&self) -> usize {
        self.data.len()
    }

    fn compute_row(&self, i: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.data.len());
        let xi = self.data.row(i);
        match self.kernel {
            KernelFunction::Rbf { gamma } => {
                let ni = self.sqnorms[i];
                let d = self.data.dim();
                for (j, o) in out.iter_mut().enumerate() {
                    let xj = self.data.row(j);
                    // dot product: the compiler auto-vectorizes this loop
                    let mut dot = 0.0f64;
                    for k in 0..d {
                        dot += xi[k] as f64 * xj[k] as f64;
                    }
                    let d2 = (ni + self.sqnorms[j] - 2.0 * dot).max(0.0);
                    *o = (-gamma * d2).exp() as f32;
                }
            }
            k => {
                for (j, o) in out.iter_mut().enumerate() {
                    *o = k.eval(xi, self.data.row(j)) as f32;
                }
            }
        }
    }

    fn diag(&self, i: usize) -> f64 {
        self.kernel.eval_self(self.data.row(i))
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        self.kernel.eval(self.data.row(i), self.data.row(j))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    fn random_ds(n: usize, d: usize, seed: u64) -> Arc<Dataset> {
        let mut rng = Pcg::new(seed);
        let mut ds = Dataset::with_dim(d);
        let mut row = vec![0f32; d];
        for _ in 0..n {
            row.iter_mut().for_each(|v| *v = rng.normal() as f32);
            ds.push(&row, if rng.bernoulli(0.5) { 1 } else { -1 });
        }
        Arc::new(ds)
    }

    #[test]
    fn rbf_row_matches_pairwise_eval() {
        let ds = random_ds(50, 7, 1);
        let k = KernelFunction::Rbf { gamma: 0.8 };
        let nc = NativeRowComputer::new(ds.clone(), k);
        let mut row = vec![0f32; 50];
        nc.compute_row(17, &mut row);
        for j in 0..50 {
            let direct = k.eval(ds.row(17), ds.row(j)) as f32;
            assert!((row[j] - direct).abs() < 1e-6, "j={j}: {} vs {direct}", row[j]);
        }
        assert!((row[17] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn entry_and_diag_consistent_with_row() {
        let ds = random_ds(20, 3, 2);
        let nc = NativeRowComputer::new(ds, KernelFunction::Rbf { gamma: 2.0 });
        let mut row = vec![0f32; 20];
        nc.compute_row(5, &mut row);
        assert!((nc.entry(5, 11) - row[11] as f64).abs() < 1e-6);
        assert_eq!(nc.diag(5), 1.0);
    }

    #[test]
    fn linear_kernel_rows() {
        let ds = random_ds(10, 4, 3);
        let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Linear);
        let mut row = vec![0f32; 10];
        nc.compute_row(0, &mut row);
        for j in 0..10 {
            let want: f64 = ds
                .row(0)
                .iter()
                .zip(ds.row(j))
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum();
            assert!((row[j] as f64 - want).abs() < 1e-5);
        }
    }

    #[test]
    fn gram_symmetry_property() {
        crate::util::quickcheck::forall(
            "gram-symmetry",
            10,
            |g| {
                let n = 8 + g.below(24);
                let d = 1 + g.below(6);
                (random_ds(n, d, g.next_u64()), g.range(0.05, 3.0))
            },
            |(ds, gamma)| {
                let nc =
                    NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: *gamma });
                let n = ds.len();
                let mut ri = vec![0f32; n];
                let mut rj = vec![0f32; n];
                for i in 0..n.min(6) {
                    nc.compute_row(i, &mut ri);
                    for j in 0..n.min(6) {
                        nc.compute_row(j, &mut rj);
                        if (ri[j] - rj[i]).abs() > 1e-6 {
                            return Err(format!("K[{i},{j}]={} K[{j},{i}]={}", ri[j], rj[i]));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
