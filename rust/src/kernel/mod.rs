//! Kernel substrate: Mercer kernel functions, the native (Rust) Gram-row
//! computer, the PJRT-backed computer (`crate::runtime`, behind the
//! `pjrt` feature), the LRU row cache, and the [`matrix::Gram`] facade
//! the solver talks to.

pub mod cache;
pub mod function;
pub mod matrix;
pub mod native;

pub use cache::RowCache;
pub use function::KernelFunction;
pub use matrix::{DenseGram, Gram, RowComputer};
pub use native::NativeRowComputer;
