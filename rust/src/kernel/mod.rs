//! Kernel substrate: Mercer kernel functions, the shared tiled
//! evaluation primitives ([`tile`] — one code path feeding both Gram
//! rows for training and SV×query blocks for batch inference), the
//! native (Rust) Gram-row computer, the PJRT-backed computer
//! (`crate::runtime`, behind the `pjrt` feature), the LRU row cache,
//! and the [`matrix::Gram`] facade the solver talks to.

pub mod cache;
pub mod function;
pub mod matrix;
pub mod native;
pub mod tile;

pub use cache::RowCache;
pub use function::KernelFunction;
pub use matrix::{DenseGram, Gram, RowComputer};
pub use native::NativeRowComputer;
