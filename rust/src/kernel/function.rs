//! Mercer kernel functions (LIBSVM-compatible parameterizations).
//!
//! The paper's experiments use the Gaussian kernel exclusively; linear,
//! polynomial and sigmoid are provided for API completeness and to test
//! the solver on semi-definite / indefinite-direction edge cases.
//!
//! Evaluation comes in two equivalent forms: [`KernelFunction::eval`]
//! over dense slices (the historical API) and
//! [`KernelFunction::eval_rows`] over [`Row`] views from either feature
//! backend. The two are bit-identical — the sparse row arithmetic skips
//! only exact-zero terms (see `data::features` for the argument).

use crate::data::features::Row;

/// A kernel function `k(x, z)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KernelFunction {
    /// `exp(-gamma ||x - z||^2)` — the paper's kernel.
    Rbf {
        /// Kernel width γ.
        gamma: f64,
    },
    /// `x . z`
    Linear,
    /// `(gamma x . z + coef0)^degree`
    Poly {
        /// Dot-product scale γ.
        gamma: f64,
        /// Additive offset.
        coef0: f64,
        /// Polynomial degree.
        degree: u32,
    },
    /// `tanh(gamma x . z + coef0)` — not PSD in general; exercises the
    /// solver's vanishing/negative-curvature handling.
    Sigmoid {
        /// Dot-product scale γ.
        gamma: f64,
        /// Additive offset.
        coef0: f64,
    },
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f64;
    for k in 0..a.len() {
        s += a[k] as f64 * b[k] as f64;
    }
    s
}

#[inline]
fn sqdist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f64;
    for k in 0..a.len() {
        let d = a[k] as f64 - b[k] as f64;
        s += d * d;
    }
    s
}

impl KernelFunction {
    /// Evaluate `k(a, b)`.
    #[inline]
    pub fn eval(&self, a: &[f32], b: &[f32]) -> f64 {
        match *self {
            KernelFunction::Rbf { gamma } => (-gamma * sqdist(a, b)).exp(),
            KernelFunction::Linear => dot(a, b),
            KernelFunction::Poly { gamma, coef0, degree } => {
                (gamma * dot(a, b) + coef0).powi(degree as i32)
            }
            KernelFunction::Sigmoid { gamma, coef0 } => (gamma * dot(a, b) + coef0).tanh(),
        }
    }

    /// Evaluate `k(a, b)` over row views from either feature backend.
    /// Bit-identical to [`KernelFunction::eval`] on the densified rows:
    /// [`Row::dot`] / [`Row::sqdist`] reproduce the dense feature-order
    /// accumulation exactly.
    #[inline]
    pub fn eval_rows(&self, a: Row<'_>, b: Row<'_>) -> f64 {
        match *self {
            KernelFunction::Rbf { gamma } => (-gamma * a.sqdist(b)).exp(),
            KernelFunction::Linear => a.dot(b),
            KernelFunction::Poly { gamma, coef0, degree } => {
                (gamma * a.dot(b) + coef0).powi(degree as i32)
            }
            KernelFunction::Sigmoid { gamma, coef0 } => (gamma * a.dot(b) + coef0).tanh(),
        }
    }

    /// `k(x, x)` — cheap for RBF (always 1).
    #[inline]
    pub fn eval_self(&self, a: &[f32]) -> f64 {
        match *self {
            KernelFunction::Rbf { .. } => 1.0,
            _ => self.eval(a, a),
        }
    }

    /// [`KernelFunction::eval_self`] over a row view.
    #[inline]
    pub fn eval_self_row(&self, a: Row<'_>) -> f64 {
        match *self {
            KernelFunction::Rbf { .. } => 1.0,
            _ => self.eval_rows(a, a),
        }
    }

    /// The γ parameter if the kernel has one.
    pub fn gamma(&self) -> Option<f64> {
        match *self {
            KernelFunction::Rbf { gamma }
            | KernelFunction::Poly { gamma, .. }
            | KernelFunction::Sigmoid { gamma, .. } => Some(gamma),
            KernelFunction::Linear => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: [f32; 3] = [1.0, 0.0, 2.0];
    const B: [f32; 3] = [0.0, 1.0, 2.0];

    #[test]
    fn rbf_hand_computed() {
        let k = KernelFunction::Rbf { gamma: 0.5 };
        // ||A-B||^2 = 1 + 1 + 0 = 2  ->  exp(-1)
        assert!((k.eval(&A, &B) - (-1.0f64).exp()).abs() < 1e-12);
        assert_eq!(k.eval_self(&A), 1.0);
    }

    #[test]
    fn rbf_symmetry_and_unit_diagonal() {
        let k = KernelFunction::Rbf { gamma: 1.3 };
        assert_eq!(k.eval(&A, &B), k.eval(&B, &A));
        assert!((k.eval(&A, &A) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn linear_is_dot() {
        let k = KernelFunction::Linear;
        assert_eq!(k.eval(&A, &B), 4.0);
        assert_eq!(k.eval_self(&A), 5.0);
    }

    #[test]
    fn poly_hand_computed() {
        let k = KernelFunction::Poly { gamma: 0.5, coef0: 1.0, degree: 2 };
        // (0.5*4 + 1)^2 = 9
        assert_eq!(k.eval(&A, &B), 9.0);
    }

    #[test]
    fn sigmoid_bounded() {
        let k = KernelFunction::Sigmoid { gamma: 10.0, coef0: 0.0 };
        let v = k.eval(&A, &B);
        assert!(v > 0.99 && v <= 1.0);
    }

    #[test]
    fn gamma_accessor() {
        assert_eq!(KernelFunction::Rbf { gamma: 0.25 }.gamma(), Some(0.25));
        assert_eq!(KernelFunction::Linear.gamma(), None);
    }

    #[test]
    fn eval_rows_is_bit_identical_to_dense_eval() {
        use crate::data::features::Features;
        // zeros included so the sparse rows actually skip terms
        let a = [1.0f32, 0.0, 2.0, 0.0, -0.5];
        let b = [0.0f32, 1.0, 2.0, 0.0, 3.0];
        let mut sparse = Features::sparse_with_dim(5);
        sparse.push_dense(&a);
        sparse.push_dense(&b);
        let kernels = [
            KernelFunction::Rbf { gamma: 0.7 },
            KernelFunction::Linear,
            KernelFunction::Poly { gamma: 0.5, coef0: 1.0, degree: 3 },
            KernelFunction::Sigmoid { gamma: 0.3, coef0: -0.1 },
        ];
        for k in kernels {
            let want = k.eval(&a, &b);
            for (ra, rb) in [
                (Row::Dense(&a), Row::Dense(&b)),
                (Row::Dense(&a), sparse.row(1)),
                (sparse.row(0), Row::Dense(&b)),
                (sparse.row(0), sparse.row(1)),
            ] {
                assert_eq!(k.eval_rows(ra, rb).to_bits(), want.to_bits(), "{k:?}");
            }
            assert_eq!(
                k.eval_self_row(sparse.row(0)).to_bits(),
                k.eval_self(&a).to_bits(),
                "{k:?} self"
            );
        }
    }
}
