//! Gram matrix facade: what the solver sees.
//!
//! [`Gram`] combines a [`RowComputer`] (native Rust or PJRT-backed) with
//! the LRU [`super::cache::RowCache`] and a precomputed diagonal. The
//! solver's per-iteration needs are:
//!   * `rows_pair(i, j)` — the two working-set rows (cache-pinned borrow),
//!   * `entry(i, j)` — single kernel values for the planning-ahead 4×4
//!     minor (served from resident rows when possible),
//!   * `diag(i)` — `K_ii` for the second-order gain denominator.

use super::cache::{CacheStats, RowCache};

/// Anything that can produce full kernel rows. Implemented by
/// [`super::native::NativeRowComputer`] and the PJRT-backed
/// `runtime::gram::PjrtRowComputer`.
pub trait RowComputer: Send {
    /// Number of examples ℓ (row length).
    fn len(&self) -> usize;
    /// Compute the full row `K[i, :]` into `out` (`out.len() == len()`).
    fn compute_row(&self, i: usize, out: &mut [f32]);
    /// `K[i, i]`.
    fn diag(&self, i: usize) -> f64;
    /// Single entry `K[i, j]` (direct evaluation; no caching).
    fn entry(&self, i: usize, j: usize) -> f64;
}

/// Cached Gram-matrix view over a [`RowComputer`].
pub struct Gram {
    computer: Box<dyn RowComputer>,
    cache: RowCache,
    diag: Vec<f64>,
    len: usize,
}

impl Gram {
    /// Default cache budget: 100 MB, LIBSVM's default.
    pub const DEFAULT_CACHE_BYTES: usize = 100 * 1024 * 1024;

    pub fn new(computer: Box<dyn RowComputer>, cache_bytes: usize) -> Gram {
        let len = computer.len();
        let diag = (0..len).map(|i| computer.diag(i)).collect();
        Gram {
            cache: RowCache::with_budget(cache_bytes, len),
            computer,
            diag,
            len,
        }
    }

    /// Number of examples ℓ.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// `K[i, i]` (precomputed).
    #[inline]
    pub fn diag(&self, i: usize) -> f64 {
        self.diag[i]
    }

    /// Borrow row `i` (computing/caching on miss).
    pub fn row(&mut self, i: usize) -> &[f32] {
        let computer = &self.computer;
        self.cache
            .get_or_compute(i, self.len, None, |out| computer.compute_row(i, out))
    }

    /// Borrow rows `i` and `j` simultaneously (`i != j`).
    ///
    /// Soundness: rows live in individually boxed slices whose storage
    /// never moves; fetching `j` pins `i` so it cannot be evicted between
    /// the two lookups, and the returned borrows tie to `&mut self` so no
    /// further cache mutation can occur while they live.
    pub fn rows_pair(&mut self, i: usize, j: usize) -> (&[f32], &[f32]) {
        assert_ne!(i, j, "rows_pair needs two distinct rows");
        {
            let computer = &self.computer;
            self.cache
                .get_or_compute(i, self.len, Some(j), |out| computer.compute_row(i, out));
            let computer = &self.computer;
            self.cache
                .get_or_compute(j, self.len, Some(i), |out| computer.compute_row(j, out));
        }
        let (pi, li) = self.cache.row_ptr(i).expect("row i resident");
        let (pj, lj) = self.cache.row_ptr(j).expect("row j resident");
        unsafe {
            (
                std::slice::from_raw_parts(pi, li),
                std::slice::from_raw_parts(pj, lj),
            )
        }
    }

    /// Single entry `K[i, j]`, served from a resident row when possible.
    pub fn entry(&mut self, i: usize, j: usize) -> f64 {
        if i == j {
            return self.diag[i];
        }
        if let Some((p, l)) = self.cache.row_ptr(i) {
            debug_assert!(j < l);
            return unsafe { *p.add(j) } as f64;
        }
        if let Some((p, l)) = self.cache.row_ptr(j) {
            debug_assert!(i < l);
            return unsafe { *p.add(i) } as f64;
        }
        self.computer.entry(i, j)
    }

    /// Is row `i` currently cached? (used by WSS cache-affinity heuristics)
    pub fn is_cached(&self, i: usize) -> bool {
        self.cache.contains(i)
    }

    /// Raw borrow of a *resident* row for callers that must keep reading
    /// the matrix (diag/entry) while holding the row. Safety contract as
    /// in [`Gram::rows_pair`]: row storage is individually boxed and only
    /// `get_or_compute` (i.e. [`Gram::row`]/[`Gram::rows_pair`]) can evict;
    /// `diag`/`entry` never mutate the cache.
    pub(crate) fn resident_row(&self, i: usize) -> Option<&'static [f32]> {
        self.cache
            .row_ptr(i)
            .map(|(p, l)| unsafe { std::slice::from_raw_parts(p, l) })
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Direct access to the underlying computer (runtime benches).
    pub fn computer(&self) -> &dyn RowComputer {
        self.computer.as_ref()
    }
}

/// Fully materialized Gram matrix — test oracle and reference-solver
/// substrate for small ℓ.
#[derive(Debug, Clone)]
pub struct DenseGram {
    n: usize,
    k: Vec<f64>,
}

impl DenseGram {
    /// Materialize from a computer (O(ℓ²) memory — small problems only).
    pub fn materialize(computer: &dyn RowComputer) -> DenseGram {
        let n = computer.len();
        let mut k = vec![0f64; n * n];
        let mut row = vec![0f32; n];
        for i in 0..n {
            computer.compute_row(i, &mut row);
            for j in 0..n {
                k[i * n + j] = row[j] as f64;
            }
        }
        DenseGram { n, k }
    }

    /// Build directly from an explicit matrix (tests).
    pub fn from_matrix(n: usize, k: Vec<f64>) -> DenseGram {
        assert_eq!(k.len(), n * n);
        DenseGram { n, k }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.k[i * self.n + j]
    }

    /// `(K α)_i`.
    pub fn mat_vec_at(&self, alpha: &[f64], i: usize) -> f64 {
        let row = &self.k[i * self.n..(i + 1) * self.n];
        row.iter().zip(alpha).map(|(&k, &a)| k * a).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::kernel::function::KernelFunction;
    use crate::kernel::native::NativeRowComputer;
    use crate::util::prng::Pcg;
    use std::sync::Arc;

    fn gram(n: usize, cache_rows_bytes: usize) -> Gram {
        let mut rng = Pcg::new(7);
        let mut ds = Dataset::with_dim(3);
        for _ in 0..n {
            ds.push(
                &[rng.normal() as f32, rng.normal() as f32, rng.normal() as f32],
                1,
            );
        }
        let nc = NativeRowComputer::new(Arc::new(ds), KernelFunction::Rbf { gamma: 0.5 });
        Gram::new(Box::new(nc), cache_rows_bytes)
    }

    #[test]
    fn rows_pair_returns_consistent_rows() {
        let mut g = gram(32, 1 << 20);
        let (ri, rj) = g.rows_pair(3, 9);
        assert_eq!(ri.len(), 32);
        assert_eq!(rj.len(), 32);
        // symmetry through the two borrows
        assert!((ri[9] - rj[3]).abs() < 1e-6);
        let d9 = rj[9];
        assert!((d9 - 1.0).abs() < 1e-6, "diagonal via row j");
    }

    #[test]
    fn rows_pair_with_tiny_cache_still_works() {
        // capacity 2 rows: i must stay pinned while j is computed
        let mut g = gram(16, 1);
        for _ in 0..10 {
            let (ri, rj) = g.rows_pair(1, 2);
            assert!((ri[2] - rj[1]).abs() < 1e-6);
            let (ra, rb) = g.rows_pair(5, 6);
            assert!((ra[6] - rb[5]).abs() < 1e-6);
        }
        assert!(g.cache_stats().evictions > 0);
    }

    #[test]
    fn entry_matches_row_and_uses_cache() {
        let mut g = gram(24, 1 << 20);
        let want = {
            let (ri, _) = g.rows_pair(4, 5);
            ri[11] as f64
        };
        assert!((g.entry(4, 11) - want).abs() < 1e-7);
        assert_eq!(g.entry(4, 4), 1.0);
        // entry for uncached pair falls back to direct eval
        assert!((g.entry(20, 21) - g.entry(21, 20)).abs() < 1e-12);
    }

    #[test]
    fn dense_gram_matches_cached_gram() {
        let mut g = gram(12, 1 << 20);
        let dense = {
            // rebuild an identical computer
            let mut rng = Pcg::new(7);
            let mut ds = Dataset::with_dim(3);
            for _ in 0..12 {
                ds.push(
                    &[rng.normal() as f32, rng.normal() as f32, rng.normal() as f32],
                    1,
                );
            }
            let nc =
                NativeRowComputer::new(Arc::new(ds), KernelFunction::Rbf { gamma: 0.5 });
            DenseGram::materialize(&nc)
        };
        for i in 0..12 {
            let row = g.row(i).to_vec();
            for j in 0..12 {
                assert!((row[j] as f64 - dense.at(i, j)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn mat_vec_hand_computed() {
        let d = DenseGram::from_matrix(2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(d.mat_vec_at(&[1.0, -1.0], 0), -1.0);
        assert_eq!(d.mat_vec_at(&[1.0, -1.0], 1), -1.0);
    }

    #[test]
    #[should_panic(expected = "distinct")]
    fn rows_pair_rejects_same_index() {
        let mut g = gram(8, 1 << 20);
        g.rows_pair(3, 3);
    }
}
