//! Gram matrix facade: what the solver sees.
//!
//! [`Gram`] combines a [`RowComputer`] (native Rust or PJRT-backed) with
//! the LRU [`super::cache::RowCache`] and a precomputed diagonal. The
//! solver's per-iteration needs are:
//!   * `rows_pair(i, j)` — the two working-set rows (cache-pinned borrow),
//!   * `entry(i, j)` — single kernel values for the planning-ahead 4×4
//!     minor (served from resident rows when possible),
//!   * `diag(i)` — `K_ii` for the second-order gain denominator.
//!
//! # The permuted active-prefix view
//!
//! The solver keeps its active variables as a contiguous prefix
//! `[0, active_len)` of a permutation of the examples (LIBSVM's
//! `swap_index` scheme). The Gram mirrors that view: all indices taken by
//! `row`/`rows_pair`/`entry`/`diag` are *positions*; [`Gram::swap_index`]
//! keeps the diagonal, the permutation and every cached row in lockstep
//! with the solver's swaps, and [`Gram::set_active_len`] shortens the
//! rows produced from then on to exactly the active prefix. Shorter rows
//! cost proportionally less to compute *and* let proportionally more
//! rows share the byte-accurate cache budget.

use super::cache::{CacheStats, RowCache};

/// Anything that can produce full kernel rows. Implemented by
/// [`super::native::NativeRowComputer`] and the PJRT-backed
/// `runtime::gram::PjrtRowComputer`.
pub trait RowComputer: Send {
    /// Number of examples ℓ (row length).
    fn len(&self) -> usize;
    /// Compute the full row `K[i, :]` into `out` (`out.len() == len()`).
    fn compute_row(&self, i: usize, out: &mut [f32]);
    /// Compute the gathered row `out[p] = K[i, cols[p]]`
    /// (`cols.len() == out.len()`). This is the shrink-aware hot path:
    /// with an active prefix of the permutation as `cols`, only the
    /// surviving columns are evaluated. The default computes the full row
    /// and gathers — correct for any computer; native computers override
    /// it with a direct tiled loop.
    fn compute_cols(&self, i: usize, cols: &[usize], out: &mut [f32]) {
        debug_assert_eq!(cols.len(), out.len());
        let mut full = vec![0f32; self.len()];
        self.compute_row(i, &mut full);
        for (o, &c) in out.iter_mut().zip(cols) {
            *o = full[c];
        }
    }
    /// Kernel entries actually *evaluated* by [`RowComputer::compute_cols`]
    /// for a `requested`-column gather — the honest input to the
    /// kernel-work meter. The default mirrors the default `compute_cols`
    /// (a full row is computed, then gathered), so computers that do not
    /// implement a direct gather never credit shrinking with savings they
    /// do not deliver; direct-gather computers override this to
    /// `requested`.
    fn cols_cost(&self, requested: usize) -> usize {
        let _ = requested;
        self.len()
    }
    /// `K[i, i]`.
    fn diag(&self, i: usize) -> f64;
    /// Single entry `K[i, j]` (direct evaluation; no caching).
    fn entry(&self, i: usize, j: usize) -> f64;
}

/// Cached Gram-matrix view over a [`RowComputer`].
pub struct Gram {
    computer: Box<dyn RowComputer>,
    cache: RowCache,
    /// `K[perm[p], perm[p]]` — permuted alongside the view.
    diag: Vec<f64>,
    /// Position → original example index.
    perm: Vec<usize>,
    /// Original example index → position.
    pos: Vec<usize>,
    /// Rows computed from now on cover positions `[0, active_len)`.
    active_len: usize,
    len: usize,
    /// Has any swap been applied since construction / `reset_view`?
    permuted: bool,
    /// Kernel entries evaluated by cached-row computations, at the
    /// computer's honest [`RowComputer::cols_cost`].
    row_entries: u64,
    /// Kernel entries evaluated outside cached rows (`entry` fallbacks,
    /// reconstruction tails).
    single_entries: u64,
}

impl Gram {
    /// Default cache budget: 100 MB, LIBSVM's default.
    pub const DEFAULT_CACHE_BYTES: usize = 100 * 1024 * 1024;

    /// A fresh identity-view Gram over `computer` with the given cache
    /// byte budget (the diagonal is precomputed eagerly).
    pub fn new(computer: Box<dyn RowComputer>, cache_bytes: usize) -> Gram {
        let len = computer.len();
        let diag = (0..len).map(|i| computer.diag(i)).collect();
        Gram {
            cache: RowCache::with_budget(cache_bytes, len),
            computer,
            diag,
            perm: (0..len).collect(),
            pos: (0..len).collect(),
            active_len: len,
            len,
            permuted: false,
            row_entries: 0,
            single_entries: 0,
        }
    }

    /// Number of examples ℓ.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Is the underlying dataset empty?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// `K[perm[p], perm[p]]` (precomputed, permuted view).
    #[inline]
    pub fn diag(&self, p: usize) -> f64 {
        self.diag[p]
    }

    /// Current active-prefix length (rows computed from now on cover
    /// exactly this many positions).
    pub fn active_len(&self) -> usize {
        self.active_len
    }

    /// Shorten (or, after an unshrink, restore) the row view.
    pub fn set_active_len(&mut self, len: usize) {
        assert!(len <= self.len, "active length exceeds problem size");
        self.active_len = len;
    }

    /// Is the view the identity permutation over the full problem?
    pub fn is_identity_view(&self) -> bool {
        !self.permuted
    }

    /// Restore the identity view for a fresh solve on this Gram. The
    /// cache is always dropped — rows of a permuted view have their
    /// columns in the old order, and even identity-view residency would
    /// change which `entry` reads are served at f32 row precision, making
    /// back-to-back solves diverge from a cold one. Resetting keeps every
    /// solve bit-deterministic and the work counters per-solve.
    pub fn reset_view(&mut self) {
        self.active_len = self.len;
        self.cache.clear();
        self.row_entries = 0;
        self.single_entries = 0;
        if !self.permuted {
            return;
        }
        // Un-permute the diagonal by gathering the values we already hold
        // (diag[p] is K[perm[p], perm[p]]) — no kernel evaluations.
        let mut diag = vec![0.0f64; self.len];
        for p in 0..self.len {
            diag[self.perm[p]] = self.diag[p];
        }
        self.diag = diag;
        for i in 0..self.len {
            self.perm[i] = i;
            self.pos[i] = i;
        }
        self.permuted = false;
    }

    /// Swap two positions of the view: diagonal, permutation and every
    /// cached row stay consistent. Must be mirrored by the owner of the
    /// solver state (see `solver::shrink`).
    pub fn swap_index(&mut self, p: usize, q: usize) {
        if p != q {
            self.apply_swaps(&[(p, q)]);
        }
    }

    /// Apply one shrink event's whole swap batch. Diagonal/permutation
    /// bookkeeping is O(1) per pair; the resident rows are patched in a
    /// *single* cache traversal (`RowCache::apply_swaps`) instead of one
    /// traversal per swap — compacting k variables costs
    /// O(resident · k) column writes but only one slot walk.
    pub fn apply_swaps(&mut self, swaps: &[(usize, usize)]) {
        let mut any = false;
        for &(p, q) in swaps {
            if p == q {
                continue;
            }
            any = true;
            self.diag.swap(p, q);
            let (a, b) = (self.perm[p], self.perm[q]);
            self.perm.swap(p, q);
            self.pos[a] = q;
            self.pos[b] = p;
        }
        if !any {
            return;
        }
        self.cache.apply_swaps(swaps);
        self.permuted = true;
    }

    /// Ensure row `p` is resident covering the active prefix, metering
    /// the computer's honest evaluation cost on a miss, and return the
    /// resident row's raw parts. Returning parts instead of re-looking
    /// the row up lets callers reborrow without a can't-miss `.expect()`.
    fn fetch(&mut self, p: usize, pinned: Option<usize>) -> (*const f32, usize) {
        debug_assert!(p < self.len);
        let need = self.active_len;
        let misses_before = self.cache.stats().misses;
        let computer = &self.computer;
        let cols = &self.perm[..need];
        let orig = self.perm[p];
        let row = self.cache.get_or_compute(p, need, pinned, |out| {
            computer.compute_cols(orig, cols, out)
        });
        let parts = (row.as_ptr(), row.len());
        if self.cache.stats().misses > misses_before {
            self.row_entries += self.computer.cols_cost(need) as u64;
        }
        parts
    }

    /// Borrow row `p` (computing/caching on miss). The returned slice
    /// covers at least the active prefix; it may be longer if a wider row
    /// is resident.
    pub fn row(&mut self, p: usize) -> &[f32] {
        let (ptr, l) = self.fetch(p, None);
        // SAFETY: `fetch` just made row `p` resident and returned its
        // boxed slice's pointer/length; boxed storage never moves, and
        // the returned borrow ties to `&mut self`, so nothing can evict
        // or mutate the row while it lives.
        unsafe { std::slice::from_raw_parts(ptr, l) }
    }

    /// Borrow rows `i` and `j` simultaneously (`i != j`).
    ///
    /// Soundness: rows live in individually boxed slices whose storage
    /// never moves; fetching `j` pins `i` so it cannot be evicted between
    /// the two lookups, and the returned borrows tie to `&mut self` so no
    /// further cache mutation can occur while they live.
    pub fn rows_pair(&mut self, i: usize, j: usize) -> (&[f32], &[f32]) {
        assert_ne!(i, j, "rows_pair needs two distinct rows");
        let (pi, li) = self.fetch(i, Some(j));
        let (pj, lj) = self.fetch(j, Some(i));
        // SAFETY: both rows are resident — the second fetch pins `i`, so
        // making room for `j` cannot evict it, and only eviction (or a
        // recompute of `i` itself, which fetching `j` cannot trigger)
        // would free the box behind `pi`. Boxed storage never moves, and
        // both borrows tie to `&mut self` (see the soundness note above).
        unsafe {
            (
                std::slice::from_raw_parts(pi, li),
                std::slice::from_raw_parts(pj, lj),
            )
        }
    }

    /// Single entry `K[perm[p], perm[q]]`, served from a resident row
    /// when possible.
    pub fn entry(&mut self, p: usize, q: usize) -> f64 {
        if p == q {
            return self.diag[p];
        }
        if let Some((ptr, l)) = self.cache.row_ptr(p) {
            if q < l {
                // SAFETY: `row_ptr` returned the live resident row's
                // pointer and length; `q < l` keeps the read in bounds,
                // and nothing mutates the cache between lookup and read.
                return unsafe { *ptr.add(q) } as f64;
            }
        }
        if let Some((ptr, l)) = self.cache.row_ptr(q) {
            if p < l {
                // SAFETY: as above, with `p < l` bounding the read.
                return unsafe { *ptr.add(p) } as f64;
            }
        }
        self.single_entries += 1;
        self.computer.entry(self.perm[p], self.perm[q])
    }

    /// Is row `p` currently cached? (used by WSS cache-affinity heuristics)
    pub fn is_cached(&self, p: usize) -> bool {
        self.cache.contains(p)
    }

    /// Borrow of a *resident* row for callers that must keep reading the
    /// immutable matrix surface (`diag`) while holding the row. The
    /// borrow is tied to `&self`, so the compiler enforces the no-evict
    /// contract: nothing that can evict (`row`/`rows_pair`/`entry`, all
    /// `&mut self`) is callable while it lives. Current call sites:
    /// `solver::wss::select_second_order_with_i` (WSS scan over row `i`)
    /// and `Gram::tail_into` (gradient reconstruction fast path).
    pub(crate) fn resident_row(&self, p: usize) -> Option<&[f32]> {
        self.cache
            .row_ptr(p)
            // SAFETY: `row_ptr` hands back the live resident boxed row's
            // pointer and length; boxed storage never moves, and the
            // returned slice borrows `self`, so every evicting method
            // (`&mut self`) is unreachable while it lives.
            .map(|(ptr, l)| unsafe { std::slice::from_raw_parts(ptr, l) })
    }

    /// Fill `buf[k] = K[perm[p], perm[start + k]]` for the tail positions
    /// `[start, len)` — gradient reconstruction after an unshrink. Served
    /// from a resident full row when one exists; otherwise computed
    /// directly *without* touching the cache (tail entries are read once,
    /// caching them would only evict useful prefix rows).
    pub fn tail_into(&mut self, p: usize, start: usize, buf: &mut [f32]) {
        assert_eq!(buf.len(), self.len - start, "tail buffer length mismatch");
        if let Some(row) = self.resident_row(p) {
            if row.len() >= self.len {
                buf.copy_from_slice(&row[start..self.len]);
                return;
            }
        }
        self.computer
            .compute_cols(self.perm[p], &self.perm[start..], buf);
        self.single_entries += self.computer.cols_cost(buf.len()) as u64;
    }

    /// Row-cache statistics since construction / the last view reset.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Total kernel entries evaluated so far: the precomputed diagonal,
    /// every cached-row computation (at the computer's honest
    /// [`RowComputer::cols_cost`] — shrunk length for direct-gather
    /// computers, full length for gather-by-full-row ones) and every
    /// single-entry fallback. This is the solver's kernel-work meter —
    /// the quantity shrinking is supposed to reduce.
    pub fn kernel_entries(&self) -> u64 {
        self.len as u64 + self.row_entries + self.single_entries
    }

    /// Direct access to the underlying computer (runtime benches).
    pub fn computer(&self) -> &dyn RowComputer {
        self.computer.as_ref()
    }
}

/// Fully materialized Gram matrix — test oracle and reference-solver
/// substrate for small ℓ.
#[derive(Debug, Clone)]
pub struct DenseGram {
    n: usize,
    k: Vec<f64>,
}

impl DenseGram {
    /// Materialize from a computer (O(ℓ²) memory — small problems only).
    pub fn materialize(computer: &dyn RowComputer) -> DenseGram {
        let n = computer.len();
        let mut k = vec![0f64; n * n];
        let mut row = vec![0f32; n];
        for i in 0..n {
            computer.compute_row(i, &mut row);
            for j in 0..n {
                k[i * n + j] = row[j] as f64;
            }
        }
        DenseGram { n, k }
    }

    /// Build directly from an explicit matrix (tests).
    pub fn from_matrix(n: usize, k: Vec<f64>) -> DenseGram {
        assert_eq!(k.len(), n * n);
        DenseGram { n, k }
    }

    /// Number of examples ℓ.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Is the matrix 0×0?
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// `K[i, j]`.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.k[i * self.n + j]
    }

    /// `(K α)_i`.
    pub fn mat_vec_at(&self, alpha: &[f64], i: usize) -> f64 {
        let row = &self.k[i * self.n..(i + 1) * self.n];
        row.iter().zip(alpha).map(|(&k, &a)| k * a).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::kernel::function::KernelFunction;
    use crate::kernel::native::NativeRowComputer;
    use crate::util::prng::Pcg;
    use std::sync::Arc;

    fn gram(n: usize, cache_rows_bytes: usize) -> Gram {
        let mut rng = Pcg::new(7);
        let mut ds = Dataset::with_dim(3);
        for _ in 0..n {
            ds.push(
                &[rng.normal() as f32, rng.normal() as f32, rng.normal() as f32],
                1,
            );
        }
        let nc = NativeRowComputer::new(Arc::new(ds), KernelFunction::Rbf { gamma: 0.5 });
        Gram::new(Box::new(nc), cache_rows_bytes)
    }

    #[test]
    fn rows_pair_returns_consistent_rows() {
        let mut g = gram(32, 1 << 20);
        let (ri, rj) = g.rows_pair(3, 9);
        assert_eq!(ri.len(), 32);
        assert_eq!(rj.len(), 32);
        // symmetry through the two borrows
        assert!((ri[9] - rj[3]).abs() < 1e-6);
        let d9 = rj[9];
        assert!((d9 - 1.0).abs() < 1e-6, "diagonal via row j");
    }

    #[test]
    fn rows_pair_with_tiny_cache_still_works() {
        // capacity 2 rows: i must stay pinned while j is computed
        let mut g = gram(16, 1);
        for _ in 0..10 {
            let (ri, rj) = g.rows_pair(1, 2);
            assert!((ri[2] - rj[1]).abs() < 1e-6);
            let (ra, rb) = g.rows_pair(5, 6);
            assert!((ra[6] - rb[5]).abs() < 1e-6);
        }
        assert!(g.cache_stats().evictions > 0);
    }

    #[test]
    fn entry_matches_row_and_uses_cache() {
        let mut g = gram(24, 1 << 20);
        let want = {
            let (ri, _) = g.rows_pair(4, 5);
            ri[11] as f64
        };
        assert!((g.entry(4, 11) - want).abs() < 1e-7);
        assert_eq!(g.entry(4, 4), 1.0);
        // entry for uncached pair falls back to direct eval
        assert!((g.entry(20, 21) - g.entry(21, 20)).abs() < 1e-12);
    }

    #[test]
    fn dense_gram_matches_cached_gram() {
        let mut g = gram(12, 1 << 20);
        let dense = {
            // rebuild an identical computer
            let mut rng = Pcg::new(7);
            let mut ds = Dataset::with_dim(3);
            for _ in 0..12 {
                ds.push(
                    &[rng.normal() as f32, rng.normal() as f32, rng.normal() as f32],
                    1,
                );
            }
            let nc =
                NativeRowComputer::new(Arc::new(ds), KernelFunction::Rbf { gamma: 0.5 });
            DenseGram::materialize(&nc)
        };
        for i in 0..12 {
            let row = g.row(i).to_vec();
            for j in 0..12 {
                assert!((row[j] as f64 - dense.at(i, j)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn swapped_view_reads_the_permuted_matrix() {
        let mut g = gram(10, 1 << 20);
        // snapshot in the identity view
        let full: Vec<Vec<f32>> = (0..10).map(|i| g.row(i).to_vec()).collect();
        g.swap_index(2, 7);
        assert!(!g.is_identity_view());
        // diag follows the permutation
        assert!((g.diag(2) - full[7][7] as f64).abs() < 1e-12);
        // cached rows were patched: row at position 2 is old row 7 with
        // columns 2 and 7 swapped
        let r2 = g.row(2).to_vec();
        assert_eq!(r2[2], full[7][7]);
        assert_eq!(r2[7], full[7][2]);
        assert_eq!(r2[4], full[7][4]);
        // entry goes through the permutation too
        assert!((g.entry(2, 3) - full[7][3] as f64).abs() < 1e-12);
        // a double swap restores the original view
        g.swap_index(2, 7);
        let r2 = g.row(2).to_vec();
        assert_eq!(r2, full[2]);
    }

    #[test]
    fn shrunk_view_produces_short_rows_and_unshrink_recovers() {
        let mut g = gram(12, 1 << 20);
        let full: Vec<Vec<f32>> = (0..12).map(|i| g.row(i).to_vec()).collect();
        g.set_active_len(5);
        // uncached row is computed at prefix length only
        let entries_before = g.kernel_entries();
        let r = {
            let mut g2 = gram(12, 1 << 20);
            g2.set_active_len(5);
            let r = g2.row(3).to_vec();
            assert_eq!(r.len(), 5);
            r
        };
        assert_eq!(&r[..], &full[3][..5]);
        // cached full rows still satisfy the short view without recompute
        let r3 = g.row(3);
        assert_eq!(r3.len(), 12);
        assert_eq!(g.kernel_entries(), entries_before);
        // growing the view back forces longer rows again
        g.set_active_len(12);
        assert_eq!(g.row(6).len(), 12);
    }

    #[test]
    fn tail_into_matches_full_row() {
        let mut g = gram(14, 1 << 20);
        let full = g.row(9).to_vec();
        // resident full row: served by copy
        let mut buf = vec![0f32; 14 - 6];
        g.tail_into(9, 6, &mut buf);
        assert_eq!(&buf[..], &full[6..]);
        // non-resident row: computed directly, bypassing the cache
        let mut g2 = gram(14, 2 * 14 * 4);
        let mut buf2 = vec![0f32; 14 - 6];
        g2.tail_into(9, 6, &mut buf2);
        assert_eq!(&buf2[..], &full[6..]);
        assert!(!g2.is_cached(9), "tail reads must not pollute the cache");
    }

    #[test]
    fn reset_view_restores_identity() {
        let mut g = gram(8, 1 << 20);
        let full: Vec<Vec<f32>> = (0..8).map(|i| g.row(i).to_vec()).collect();
        g.swap_index(1, 6);
        g.set_active_len(3);
        g.reset_view();
        assert!(g.is_identity_view());
        assert_eq!(g.active_len(), 8);
        for i in 0..8 {
            assert_eq!(g.row(i).to_vec(), full[i], "row {i}");
        }
    }

    #[test]
    fn kernel_entries_meter_counts_rows_and_singles() {
        let mut g = gram(10, 1 << 20);
        let base = g.kernel_entries();
        assert_eq!(base, 10, "diagonal precompute");
        g.row(0);
        assert_eq!(g.kernel_entries(), base + 10);
        g.entry(0, 5); // served from the resident row: free
        assert_eq!(g.kernel_entries(), base + 10);
        g.entry(7, 8); // neither row resident: one direct evaluation
        assert_eq!(g.kernel_entries(), base + 11);
        g.set_active_len(4);
        let mut g2 = gram(10, 1 << 20);
        g2.set_active_len(4);
        g2.row(1);
        assert_eq!(g2.kernel_entries(), 10 + 4, "short rows cost their length");
    }

    #[test]
    fn mat_vec_hand_computed() {
        let d = DenseGram::from_matrix(2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(d.mat_vec_at(&[1.0, -1.0], 0), -1.0);
        assert_eq!(d.mat_vec_at(&[1.0, -1.0], 1), -1.0);
    }

    #[test]
    #[should_panic(expected = "distinct")]
    fn rows_pair_rejects_same_index() {
        let mut g = gram(8, 1 << 20);
        g.rows_pair(3, 3);
    }
}
