//! LRU kernel-row cache with a byte budget (the paper §2's caching
//! technique: "the algorithm needs to recompute only those rows … which
//! have not been used recently").
//!
//! Rows are stored in individually boxed allocations, so map growth or
//! eviction of *other* rows never moves a row's storage — this is what
//! makes the pinned two-row borrow in [`super::matrix::Gram`] sound.
//!
//! Recency is tracked by an intrusive doubly-linked LRU list over a slab
//! of entries: a hit is an O(1) unlink/relink and eviction pops from the
//! tail in O(1) (plus at most one skip for the pinned row) — no O(#rows)
//! victim scan, which matters now that short post-shrink rows let
//! thousands of rows share the budget.
//!
//! Rows have *variable* length: with shrinking the active-set prefix gets
//! shorter over a solve, rows computed later are shorter, and the byte
//! accounting automatically lets more of them stay resident. A resident
//! row satisfies a request for any length up to its own; a too-short row
//! is dropped and recomputed at the requested length.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Identity hasher for `usize` keys (row indices are small and dense —
/// SipHash is pure overhead on the two lookups per solver iteration).
#[derive(Default)]
pub struct IdentityHasher(u64);

impl Hasher for IdentityHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, _: &[u8]) {
        unreachable!("IdentityHasher is for usize keys only");
    }
    #[inline]
    fn write_usize(&mut self, n: usize) {
        // spread the low bits a little so HashMap buckets stay balanced
        self.0 = (n as u64).wrapping_mul(0x9E3779B97F4A7C15);
    }
}

/// Maps row index → slot in the entry slab.
type SlotMap = HashMap<usize, usize, BuildHasherDefault<IdentityHasher>>;

/// Cache statistics (exposed in experiment reports and the cache bench).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Requests served from a resident row.
    pub hits: u64,
    /// Requests that had to compute the row.
    pub misses: u64,
    /// Rows evicted to make room.
    pub evictions: u64,
}

impl CacheStats {
    /// `hits / (hits + misses)`, 0 when nothing was requested.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Sentinel for "no slot" in the intrusive list.
const NIL: usize = usize::MAX;

struct Node {
    key: usize,
    row: Box<[f32]>,
    /// Next-more-recent slot (NIL at the head).
    prev: usize,
    /// Next-less-recent slot (NIL at the tail).
    next: usize,
}

/// LRU cache of kernel rows keyed by example index (position, once the
/// Gram view is permuted).
pub struct RowCache {
    map: SlotMap,
    nodes: Vec<Node>,
    free: Vec<usize>,
    /// Most recently used slot.
    head: usize,
    /// Least recently used slot (eviction candidate).
    tail: usize,
    /// Byte budget over the resident rows (`Σ row_len · 4`).
    budget_bytes: usize,
    /// Hard cap on resident rows (row-count constructor; `usize::MAX`
    /// for byte-budgeted caches).
    max_rows: usize,
    /// Budget expressed in full-length rows at construction time (for
    /// reports; actual residency is byte-accurate).
    nominal_rows: usize,
    bytes_used: usize,
    stats: CacheStats,
}

impl RowCache {
    /// Budgeted by bytes; `row_len` is the full-length row used to report
    /// the nominal row capacity. At least two rows are always allowed
    /// (the solver needs the working-set pair resident together).
    pub fn with_budget(bytes: usize, row_len: usize) -> RowCache {
        let nominal = (bytes / (row_len.max(1) * std::mem::size_of::<f32>())).max(2);
        RowCache::build(bytes, usize::MAX, nominal)
    }

    /// Capacity in rows (>= 2 enforced), irrespective of row length.
    pub fn with_capacity_rows(capacity_rows: usize) -> RowCache {
        let cap = capacity_rows.max(2);
        RowCache::build(usize::MAX, cap, cap)
    }

    fn build(budget_bytes: usize, max_rows: usize, nominal_rows: usize) -> RowCache {
        RowCache {
            map: SlotMap::default(),
            nodes: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            budget_bytes,
            max_rows,
            nominal_rows,
            bytes_used: 0,
            stats: CacheStats::default(),
        }
    }

    /// Nominal capacity in full-length rows (reporting only; residency
    /// is byte-accurate).
    pub fn capacity_rows(&self) -> usize {
        self.nominal_rows
    }

    /// Number of resident rows.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Is the cache empty?
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Statistics since construction or the last [`RowCache::clear`].
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Bytes currently held by resident rows.
    pub fn bytes_used(&self) -> usize {
        self.bytes_used
    }

    /// Is row `i` resident (does not touch LRU order)?
    pub fn contains(&self, i: usize) -> bool {
        self.map.contains_key(&i)
    }

    /// Raw pointer + length of a resident row. Used by `Gram::rows_pair`
    /// to hand out two row borrows; the storage is a stable boxed slice.
    pub(crate) fn row_ptr(&self, i: usize) -> Option<(*const f32, usize)> {
        self.map
            .get(&i)
            .map(|&s| (self.nodes[s].row.as_ptr(), self.nodes[s].row.len()))
    }

    // ---- intrusive LRU list primitives (all O(1)) ----

    fn detach(&mut self, slot: usize) {
        let (p, n) = (self.nodes[slot].prev, self.nodes[slot].next);
        if p == NIL {
            self.head = n;
        } else {
            self.nodes[p].next = n;
        }
        if n == NIL {
            self.tail = p;
        } else {
            self.nodes[n].prev = p;
        }
    }

    fn push_front(&mut self, slot: usize) {
        self.nodes[slot].prev = NIL;
        self.nodes[slot].next = self.head;
        if self.head != NIL {
            self.nodes[self.head].prev = slot;
        }
        self.head = slot;
        if self.tail == NIL {
            self.tail = slot;
        }
    }

    fn touch(&mut self, slot: usize) {
        if self.head != slot {
            self.detach(slot);
            self.push_front(slot);
        }
    }

    fn remove_entry(&mut self, key: usize, slot: usize) {
        self.detach(slot);
        self.map.remove(&key);
        self.bytes_used -= self.nodes[slot].row.len() * std::mem::size_of::<f32>();
        self.nodes[slot].row = Vec::new().into_boxed_slice();
        self.free.push(slot);
    }

    fn insert_entry(&mut self, key: usize, row: Box<[f32]>) -> usize {
        self.bytes_used += row.len() * std::mem::size_of::<f32>();
        let slot = match self.free.pop() {
            Some(s) => {
                self.nodes[s] = Node { key, row, prev: NIL, next: NIL };
                s
            }
            None => {
                self.nodes.push(Node { key, row, prev: NIL, next: NIL });
                self.nodes.len() - 1
            }
        };
        self.map.insert(key, slot);
        self.push_front(slot);
        slot
    }

    /// Evict LRU entries (skipping `pinned`) until `new_bytes` more fit
    /// inside both budgets. The working pair is sacred: eviction never
    /// drops residency below one row, so pinned + incoming always fit.
    fn make_room(&mut self, new_bytes: usize, pinned: Option<usize>) {
        while self.map.len() >= 2
            && (self.bytes_used + new_bytes > self.budget_bytes
                || self.map.len() + 1 > self.max_rows)
        {
            let mut victim = self.tail;
            while victim != NIL && Some(self.nodes[victim].key) == pinned {
                victim = self.nodes[victim].prev;
            }
            if victim == NIL {
                break; // everything left is pinned
            }
            let key = self.nodes[victim].key;
            self.remove_entry(key, victim);
            self.stats.evictions += 1;
        }
    }

    /// Get row `i` with at least `row_len` valid entries, computing it via
    /// `compute` on a miss (the computed row has exactly `row_len`
    /// entries). A resident row longer than `row_len` is a hit; a shorter
    /// one is dropped and recomputed. `pinned` is never evicted by this
    /// call (pass the other working-set row).
    pub fn get_or_compute(
        &mut self,
        i: usize,
        row_len: usize,
        pinned: Option<usize>,
        compute: impl FnOnce(&mut [f32]),
    ) -> &[f32] {
        if let Some(&slot) = self.map.get(&i) {
            if self.nodes[slot].row.len() >= row_len {
                self.stats.hits += 1;
                self.touch(slot);
                #[cfg(feature = "debug-invariants")]
                self.debug_validate();
                let (p, l) = (self.nodes[slot].row.as_ptr(), self.nodes[slot].row.len());
                // SAFETY: the raw-parts round trip only works around the
                // NLL borrow limitation (the early return would otherwise
                // extend the `map.get` borrow over the miss arm below).
                // `p`/`l` come from the live boxed slice owned by
                // `self.nodes[slot]`; boxed storage never moves, and the
                // returned slice borrows `self`, so no `&mut self` method
                // can evict or mutate the row while it is alive.
                return unsafe { std::slice::from_raw_parts(p, l) };
            }
            // Resident but shorter than the current active view (the
            // active set grew back after an unshrink): recompute.
            self.remove_entry(i, slot);
            self.stats.evictions += 1;
        }
        self.stats.misses += 1;
        self.make_room(row_len * std::mem::size_of::<f32>(), pinned);
        let mut row = vec![0f32; row_len].into_boxed_slice();
        compute(&mut row);
        let slot = self.insert_entry(i, row);
        #[cfg(feature = "debug-invariants")]
        self.debug_validate();
        let (p, l) = (self.nodes[slot].row.as_ptr(), self.nodes[slot].row.len());
        // SAFETY: as on the hit path — the box just inserted into
        // `self.nodes[slot]` is stable storage, and the returned slice's
        // lifetime is tied to the `&mut self` borrow, so nothing can
        // evict or mutate the row while the borrow lives.
        unsafe { std::slice::from_raw_parts(p, l) }
    }

    /// Mirror one position swap of the owning Gram view (see
    /// [`RowCache::apply_swaps`]).
    pub fn swap_index(&mut self, p: usize, q: usize) {
        if p != q {
            self.apply_swaps(&[(p, q)]);
        }
    }

    /// Mirror a whole batch of position swaps (one shrink event's
    /// compaction): re-key the rows stored *for* swapped positions and
    /// swap the two columns of every pair inside every resident row. A
    /// row long enough to hold only one of a pair's two columns cannot be
    /// patched and is dropped (counted as an eviction).
    ///
    /// Cost: one traversal of the resident slots with all column swaps
    /// applied per row in a tight inner loop — O(resident · swaps) column
    /// writes but only O(resident + swaps) map/slot walks, instead of one
    /// full traversal per swap. Only runs on shrink events, never in the
    /// per-iteration hot path.
    pub fn apply_swaps(&mut self, swaps: &[(usize, usize)]) {
        if swaps.is_empty() {
            return;
        }
        let mut dropped: Vec<usize> = Vec::new();
        // Iteration order over the map is irrelevant here: every resident
        // row receives the same column patches, and drops are collected
        // first, removed after (allowlisted for the hashmap-iter lint).
        for (&key, &slot) in self.map.iter() {
            let row = &mut self.nodes[slot].row;
            let len = row.len();
            for &(a, b) in swaps {
                if a == b {
                    continue;
                }
                let (lo, hi) = (a.min(b), a.max(b));
                if len > hi {
                    row.swap(lo, hi);
                } else if len > lo {
                    dropped.push(key);
                    break;
                }
            }
        }
        for key in dropped {
            let slot = self.map[&key];
            self.remove_entry(key, slot);
            self.stats.evictions += 1;
        }
        // Re-key sequentially — key movement composes exactly like the
        // column swaps above (O(1) hash ops per swap, not per row).
        for &(a, b) in swaps {
            if a == b {
                continue;
            }
            let sa = self.map.remove(&a);
            let sb = self.map.remove(&b);
            if let Some(s) = sa {
                self.nodes[s].key = b;
                self.map.insert(b, s);
            }
            if let Some(s) = sb {
                self.nodes[s].key = a;
                self.map.insert(a, s);
            }
        }
        #[cfg(feature = "debug-invariants")]
        self.debug_validate();
    }

    /// Invalidate everything (dataset changed). Also resets the
    /// statistics so reports never bleed across datasets.
    pub fn clear(&mut self) {
        self.map.clear();
        self.nodes.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
        self.bytes_used = 0;
        self.stats = CacheStats::default();
        #[cfg(feature = "debug-invariants")]
        self.debug_validate();
    }

    /// Full structural validation of the cache (`debug-invariants`
    /// builds only; called after every mutating operation):
    ///
    /// * byte accounting: `bytes_used` == Σ resident row lengths · 4,
    /// * map/slab agreement: every non-free slot's key maps back to it,
    /// * the intrusive LRU list is a consistent doubly-linked chain from
    ///   `head` to `tail` visiting every resident slot exactly once,
    /// * `free` and the resident slots partition the slab.
    #[cfg(feature = "debug-invariants")]
    pub(crate) fn debug_validate(&self) {
        let free: std::collections::BTreeSet<usize> = self.free.iter().copied().collect();
        let mut resident = 0usize;
        let mut resident_bytes = 0usize;
        for (s, node) in self.nodes.iter().enumerate() {
            if free.contains(&s) {
                continue;
            }
            resident += 1;
            resident_bytes += node.row.len() * std::mem::size_of::<f32>();
            crate::invariant!(
                self.map.get(&node.key) == Some(&s),
                "cache map and slab disagree for key {} (slot {})",
                node.key,
                s
            );
        }
        crate::invariant!(
            resident == self.map.len(),
            "resident slots {} != map entries {}",
            resident,
            self.map.len()
        );
        crate::invariant!(
            free.len() + resident == self.nodes.len(),
            "free list and resident slots do not partition the slab"
        );
        crate::invariant!(
            resident_bytes == self.bytes_used,
            "byte accounting drift: {} bytes resident vs {} accounted",
            resident_bytes,
            self.bytes_used
        );
        let mut count = 0usize;
        let mut prev = NIL;
        let mut cur = self.head;
        while cur != NIL {
            crate::invariant!(
                self.nodes[cur].prev == prev,
                "LRU back-link broken at slot {cur}"
            );
            crate::invariant!(!free.contains(&cur), "free slot {cur} linked in the LRU list");
            count += 1;
            crate::invariant!(count <= self.nodes.len(), "LRU list cycles");
            prev = cur;
            cur = self.nodes[cur].next;
        }
        crate::invariant!(prev == self.tail, "LRU tail does not terminate the list");
        crate::invariant!(
            count == self.map.len(),
            "LRU list length {} != resident rows {}",
            count,
            self.map.len()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(v: f32) -> impl FnOnce(&mut [f32]) {
        move |row| row.iter_mut().for_each(|x| *x = v)
    }

    #[test]
    fn hit_after_miss() {
        let mut c = RowCache::with_capacity_rows(4);
        let r = c.get_or_compute(3, 8, None, fill(3.0));
        assert_eq!(r[0], 3.0);
        let computed = std::cell::Cell::new(false);
        let r = c.get_or_compute(3, 8, None, |row| {
            computed.set(true);
            row[0] = 99.0;
        });
        assert_eq!(r[0], 3.0, "hit must not recompute");
        assert!(!computed.get());
        assert_eq!(c.stats(), CacheStats { hits: 1, misses: 1, evictions: 0 });
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = RowCache::with_capacity_rows(2);
        c.get_or_compute(0, 4, None, fill(0.0));
        c.get_or_compute(1, 4, None, fill(1.0));
        c.get_or_compute(0, 4, None, fill(0.0)); // touch 0; 1 is now LRU
        c.get_or_compute(2, 4, None, fill(2.0)); // evicts 1
        assert!(c.contains(0));
        assert!(!c.contains(1));
        assert!(c.contains(2));
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn pinned_row_survives_eviction() {
        let mut c = RowCache::with_capacity_rows(2);
        c.get_or_compute(0, 4, None, fill(0.0));
        c.get_or_compute(1, 4, None, fill(1.0));
        // 0 is LRU, but pinned — so 1 must be evicted instead.
        c.get_or_compute(2, 4, Some(0), fill(2.0));
        assert!(c.contains(0));
        assert!(!c.contains(1));
    }

    #[test]
    fn byte_budget_translates_to_rows() {
        let c = RowCache::with_budget(100 * 4 * 10, 100);
        assert_eq!(c.capacity_rows(), 10);
        // tiny budget still allows the working pair
        let c = RowCache::with_budget(1, 1000);
        assert_eq!(c.capacity_rows(), 2);
    }

    #[test]
    fn byte_accounting_lets_short_rows_pack_denser() {
        // Budget for exactly 4 full-length rows of 100 entries.
        let mut c = RowCache::with_budget(4 * 100 * 4, 100);
        for i in 0..4 {
            c.get_or_compute(i, 100, None, fill(i as f32));
        }
        assert_eq!(c.len(), 4);
        assert_eq!(c.bytes_used(), 4 * 100 * 4);
        // Half-length rows: twice as many fit in the same budget.
        let mut c = RowCache::with_budget(4 * 100 * 4, 100);
        for i in 0..8 {
            c.get_or_compute(i, 50, None, fill(i as f32));
        }
        assert_eq!(c.len(), 8, "short rows must share the freed budget");
        assert_eq!(c.stats().evictions, 0);
        // one more full-length row now evicts several short ones
        c.get_or_compute(100, 100, None, fill(0.5));
        assert!(c.stats().evictions >= 2);
        assert!(c.bytes_used() <= 4 * 100 * 4);
    }

    #[test]
    fn too_short_resident_row_is_recomputed_at_new_length() {
        let mut c = RowCache::with_capacity_rows(4);
        c.get_or_compute(7, 10, None, fill(1.0));
        // request a longer view of the same row (post-unshrink)
        let r = c.get_or_compute(7, 20, None, fill(2.0));
        assert_eq!(r.len(), 20);
        assert!(r.iter().all(|&x| x == 2.0));
        // and a shorter request is served by the resident longer row
        let r = c.get_or_compute(7, 5, None, fill(9.0));
        assert_eq!(r.len(), 20, "longer resident row satisfies short reads");
        assert!(r.iter().all(|&x| x == 2.0));
    }

    #[test]
    fn swap_index_rekeys_rows_and_swaps_columns() {
        let mut c = RowCache::with_capacity_rows(4);
        c.get_or_compute(0, 6, None, |r| {
            for (j, x) in r.iter_mut().enumerate() {
                *x = j as f32;
            }
        });
        c.get_or_compute(1, 6, None, fill(10.0));
        c.swap_index(0, 5);
        // the row stored for index 0 is now keyed 5 …
        assert!(!c.contains(0));
        assert!(c.contains(5));
        // … and its columns 0 and 5 are swapped, in every resident row
        let r = c.get_or_compute(5, 6, None, |_| panic!("must be a hit"));
        assert_eq!(r[0], 5.0);
        assert_eq!(r[5], 0.0);
        assert_eq!(r[3], 3.0);
    }

    #[test]
    fn batched_swaps_match_sequential_swaps() {
        // apply_swaps([a, b, c]) must equal swap_index(a); swap_index(b);
        // swap_index(c) — same data, same keys, same drops.
        let fill_idx = |r: &mut [f32]| {
            for (j, x) in r.iter_mut().enumerate() {
                *x = j as f32;
            }
        };
        let swaps = [(0usize, 5usize), (1, 4), (0, 3), (2, 5)];
        let mut batched = RowCache::with_capacity_rows(4);
        let mut sequential = RowCache::with_capacity_rows(4);
        for c in [&mut batched, &mut sequential] {
            c.get_or_compute(0, 8, None, fill_idx);
            c.get_or_compute(2, 8, None, fill_idx);
            c.get_or_compute(5, 3, None, fill_idx); // too short: dropped
        }
        batched.apply_swaps(&swaps);
        for &(p, q) in &swaps {
            sequential.swap_index(p, q);
        }
        for key in 0..8 {
            assert_eq!(batched.contains(key), sequential.contains(key), "key {key}");
            if batched.contains(key) {
                let a = batched.get_or_compute(key, 1, None, |_| panic!("hit"));
                let a = a.to_vec();
                let b = sequential.get_or_compute(key, 1, None, |_| panic!("hit"));
                assert_eq!(a, b.to_vec(), "row data for key {key}");
            }
        }
    }

    #[test]
    fn swap_index_drops_rows_too_short_to_patch() {
        let mut c = RowCache::with_capacity_rows(4);
        c.get_or_compute(0, 4, None, fill(0.0)); // holds columns 0..4
        c.get_or_compute(1, 8, None, fill(1.0)); // holds columns 0..8
        // swapping columns 2 and 6: row 0 has column 2 but not 6 → dropped,
        // row 1 has both → patched in place.
        c.swap_index(2, 6);
        assert!(!c.contains(0));
        assert!(c.contains(1));
    }

    #[test]
    fn behaves_like_oracle_map_under_random_access() {
        use crate::util::prng::Pcg;
        // Property: a cached read always returns exactly what the oracle
        // computes for that index, regardless of access pattern.
        let mut c = RowCache::with_capacity_rows(8);
        let mut rng = Pcg::new(0xC0FFEE);
        for _ in 0..2000 {
            let i = rng.below(32);
            let row = c.get_or_compute(i, 4, None, move |r| {
                r.iter_mut().for_each(|x| *x = i as f32 * 10.0)
            });
            assert!(row.iter().all(|&x| x == i as f32 * 10.0), "index {i}");
        }
        assert!(c.len() <= 8);
        let s = c.stats();
        assert_eq!(s.hits + s.misses, 2000);
        assert!(s.hits > 0 && s.evictions > 0);
    }

    #[test]
    fn intrusive_list_matches_naive_lru_model() {
        use crate::util::prng::Pcg;
        // The intrusive list must make exactly the decisions of a naive
        // recency-ordered Vec model: same hits, same residents, same
        // victims, over a long random access trace with pinning.
        let mut c = RowCache::with_capacity_rows(6);
        let mut model: Vec<usize> = Vec::new(); // most recent first
        let mut rng = Pcg::new(42);
        for step in 0..5000 {
            let i = rng.below(24);
            let pinned = if rng.bernoulli(0.3) {
                model.first().copied().filter(|&p| p != i)
            } else {
                None
            };
            let model_hit = model.contains(&i);
            if model_hit {
                model.retain(|&k| k != i);
            } else if model.len() >= 6 {
                // evict least-recent not pinned
                let victim = model
                    .iter()
                    .rev()
                    .find(|&&k| Some(k) != pinned)
                    .copied()
                    .unwrap();
                model.retain(|&k| k != victim);
            }
            model.insert(0, i);

            let hits_before = c.stats().hits;
            c.get_or_compute(i, 4, pinned, fill(i as f32));
            let was_hit = c.stats().hits > hits_before;
            assert_eq!(was_hit, model_hit, "step {step}: hit divergence on {i}");
            for &k in &model {
                assert!(c.contains(k), "step {step}: model row {k} missing");
            }
            assert_eq!(c.len(), model.len(), "step {step}");
        }
    }

    #[test]
    fn byte_accounting_matches_resident_rows_throughout_random_workload() {
        use crate::util::prng::Pcg;
        // Regression guard for the accounting the debug-invariants
        // checker enforces: after any mix of hits, misses, variable-length
        // recomputes and swap batches, `bytes_used` equals the sum of the
        // resident rows' actual lengths.
        let mut c = RowCache::with_budget(64 * 4, 8);
        let mut rng = Pcg::new(7);
        for step in 0..600 {
            let i = rng.below(16);
            let len = 2 + rng.below(6);
            c.get_or_compute(i, len, None, fill(i as f32));
            if step % 97 == 0 {
                c.apply_swaps(&[(rng.below(8), rng.below(8)), (rng.below(8), rng.below(8))]);
            }
            let expected: usize = c
                .map
                .values()
                .map(|&s| c.nodes[s].row.len() * std::mem::size_of::<f32>())
                .sum();
            assert_eq!(c.bytes_used, expected, "accounting drift at step {step}");
            assert!(c.bytes_used <= 64 * 4 || c.len() <= 2, "budget overshoot at step {step}");
        }
        c.clear();
        assert_eq!(c.bytes_used, 0);
    }

    #[cfg(feature = "debug-invariants")]
    #[test]
    fn debug_validate_accepts_a_healthy_cache() {
        let mut c = RowCache::with_capacity_rows(4);
        for i in 0..6 {
            c.get_or_compute(i, 4, None, fill(i as f32));
        }
        c.debug_validate();
    }

    #[cfg(feature = "debug-invariants")]
    #[test]
    #[should_panic(expected = "invariant violated")]
    fn corrupted_byte_accounting_is_caught() {
        let mut c = RowCache::with_capacity_rows(4);
        c.get_or_compute(0, 4, None, fill(1.0));
        c.bytes_used += std::mem::size_of::<f32>();
        c.debug_validate();
    }

    #[cfg(feature = "debug-invariants")]
    #[test]
    #[should_panic(expected = "invariant violated")]
    fn corrupted_lru_list_is_caught() {
        let mut c = RowCache::with_capacity_rows(4);
        c.get_or_compute(0, 4, None, fill(1.0));
        c.get_or_compute(1, 4, None, fill(2.0));
        c.head = NIL; // sever the list from its residents
        c.debug_validate();
    }

    #[test]
    fn clear_empties() {
        let mut c = RowCache::with_capacity_rows(4);
        c.get_or_compute(0, 4, None, fill(0.0));
        c.clear();
        assert!(c.is_empty());
        assert!(!c.contains(0));
        assert_eq!(c.bytes_used(), 0);
    }

    #[test]
    fn clear_resets_stats_and_clock() {
        let mut c = RowCache::with_capacity_rows(2);
        c.get_or_compute(0, 4, None, fill(0.0));
        c.get_or_compute(0, 4, None, fill(0.0)); // hit
        c.get_or_compute(1, 4, None, fill(1.0));
        c.get_or_compute(2, 4, None, fill(2.0)); // eviction
        assert_ne!(c.stats(), CacheStats::default(), "test setup: stats non-trivial");

        c.clear();
        assert_eq!(c.stats(), CacheStats::default(), "stats must not bleed across datasets");
        assert_eq!(c.stats().hit_rate(), 0.0);

        // The cleared cache behaves exactly like a fresh one: same
        // accesses, same counters, same LRU decisions.
        let mut fresh = RowCache::with_capacity_rows(2);
        for cache in [&mut c, &mut fresh] {
            cache.get_or_compute(5, 4, None, fill(5.0));
            cache.get_or_compute(6, 4, None, fill(6.0));
            cache.get_or_compute(5, 4, None, fill(5.0)); // touch 5; 6 is LRU
            cache.get_or_compute(7, 4, None, fill(7.0)); // evicts 6
        }
        assert_eq!(c.stats(), fresh.stats());
        assert!(c.contains(5) && c.contains(7) && !c.contains(6));
    }
}
