//! LRU kernel-row cache with a byte budget (the paper §2's caching
//! technique: "the algorithm needs to recompute only those rows … which
//! have not been used recently").
//!
//! Rows are stored in individually boxed allocations, so map growth or
//! eviction of *other* rows never moves a row's storage — this is what
//! makes the pinned two-row borrow in [`super::matrix::Gram`] sound.
//! Eviction scans for the least-recently-used entry; the scan is O(#rows)
//! but only runs on a miss, which already paid an O(ℓ·d) row computation.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Identity hasher for `usize` keys (row indices are small and dense —
/// SipHash is pure overhead on the two lookups per solver iteration).
#[derive(Default)]
pub struct IdentityHasher(u64);

impl Hasher for IdentityHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, _: &[u8]) {
        unreachable!("IdentityHasher is for usize keys only");
    }
    #[inline]
    fn write_usize(&mut self, n: usize) {
        // spread the low bits a little so HashMap buckets stay balanced
        self.0 = (n as u64).wrapping_mul(0x9E3779B97F4A7C15);
    }
}

type RowMap = HashMap<usize, Entry, BuildHasherDefault<IdentityHasher>>;

/// Cache statistics (exposed in experiment reports and the cache bench).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry {
    row: Box<[f32]>,
    last_use: u64,
}

/// LRU cache of kernel rows keyed by example index.
pub struct RowCache {
    entries: RowMap,
    capacity_rows: usize,
    clock: u64,
    stats: CacheStats,
}

impl RowCache {
    /// Budgeted by bytes; each row costs `row_len * 4` bytes. At least two
    /// rows are always allowed (the solver needs the working-set pair).
    pub fn with_budget(bytes: usize, row_len: usize) -> RowCache {
        let capacity_rows = (bytes / (row_len.max(1) * std::mem::size_of::<f32>())).max(2);
        RowCache::with_capacity_rows(capacity_rows)
    }

    /// Capacity in rows (>= 2 enforced).
    pub fn with_capacity_rows(capacity_rows: usize) -> RowCache {
        RowCache {
            entries: RowMap::default(),
            capacity_rows: capacity_rows.max(2),
            clock: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn capacity_rows(&self) -> usize {
        self.capacity_rows
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Is row `i` resident (does not touch LRU order)?
    pub fn contains(&self, i: usize) -> bool {
        self.entries.contains_key(&i)
    }

    /// Raw pointer + length of a resident row. Used by `Gram::rows_pair`
    /// to hand out two row borrows; the storage is a stable boxed slice.
    pub(crate) fn row_ptr(&self, i: usize) -> Option<(*const f32, usize)> {
        self.entries.get(&i).map(|e| (e.row.as_ptr(), e.row.len()))
    }

    /// Get row `i`, computing it via `compute` on a miss. `pinned` is never
    /// evicted by this call (pass the other working-set row).
    pub fn get_or_compute(
        &mut self,
        i: usize,
        row_len: usize,
        pinned: Option<usize>,
        compute: impl FnOnce(&mut [f32]),
    ) -> &[f32] {
        self.clock += 1;
        let clock = self.clock;
        // Hit path: single hash lookup; the raw-parts round trip works
        // around the NLL borrow limitation (the storage is a boxed slice,
        // stable for the lifetime of the entry).
        if let Some(e) = self.entries.get_mut(&i) {
            self.stats.hits += 1;
            e.last_use = clock;
            let (p, l) = (e.row.as_ptr(), e.row.len());
            return unsafe { std::slice::from_raw_parts(p, l) };
        }
        self.stats.misses += 1;
        if self.entries.len() >= self.capacity_rows {
            self.evict_one(pinned, i);
        }
        let mut row = vec![0f32; row_len].into_boxed_slice();
        compute(&mut row);
        self.entries.insert(i, Entry { row, last_use: clock });
        &self.entries[&i].row
    }

    /// Drop the least-recently-used entry, skipping `pinned` and `incoming`.
    fn evict_one(&mut self, pinned: Option<usize>, incoming: usize) {
        let victim = self
            .entries
            .iter()
            .filter(|(&k, _)| Some(k) != pinned && k != incoming)
            .min_by_key(|(_, e)| e.last_use)
            .map(|(&k, _)| k);
        if let Some(k) = victim {
            self.entries.remove(&k);
            self.stats.evictions += 1;
        }
    }

    /// Invalidate everything (dataset changed). Also resets the LRU clock
    /// and the statistics so hit-rate reports never bleed across datasets.
    pub fn clear(&mut self) {
        self.entries.clear();
        self.clock = 0;
        self.stats = CacheStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(v: f32) -> impl FnOnce(&mut [f32]) {
        move |row| row.iter_mut().for_each(|x| *x = v)
    }

    #[test]
    fn hit_after_miss() {
        let mut c = RowCache::with_capacity_rows(4);
        let r = c.get_or_compute(3, 8, None, fill(3.0));
        assert_eq!(r[0], 3.0);
        let computed = std::cell::Cell::new(false);
        let r = c.get_or_compute(3, 8, None, |row| {
            computed.set(true);
            row[0] = 99.0;
        });
        assert_eq!(r[0], 3.0, "hit must not recompute");
        assert!(!computed.get());
        assert_eq!(c.stats(), CacheStats { hits: 1, misses: 1, evictions: 0 });
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = RowCache::with_capacity_rows(2);
        c.get_or_compute(0, 4, None, fill(0.0));
        c.get_or_compute(1, 4, None, fill(1.0));
        c.get_or_compute(0, 4, None, fill(0.0)); // touch 0; 1 is now LRU
        c.get_or_compute(2, 4, None, fill(2.0)); // evicts 1
        assert!(c.contains(0));
        assert!(!c.contains(1));
        assert!(c.contains(2));
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn pinned_row_survives_eviction() {
        let mut c = RowCache::with_capacity_rows(2);
        c.get_or_compute(0, 4, None, fill(0.0));
        c.get_or_compute(1, 4, None, fill(1.0));
        // 0 is LRU, but pinned — so 1 must be evicted instead.
        c.get_or_compute(2, 4, Some(0), fill(2.0));
        assert!(c.contains(0));
        assert!(!c.contains(1));
    }

    #[test]
    fn byte_budget_translates_to_rows() {
        let c = RowCache::with_budget(100 * 4 * 10, 100);
        assert_eq!(c.capacity_rows(), 10);
        // tiny budget still allows the working pair
        let c = RowCache::with_budget(1, 1000);
        assert_eq!(c.capacity_rows(), 2);
    }

    #[test]
    fn behaves_like_oracle_map_under_random_access() {
        use crate::util::prng::Pcg;
        // Property: a cached read always returns exactly what the oracle
        // computes for that index, regardless of access pattern.
        let mut c = RowCache::with_capacity_rows(8);
        let mut rng = Pcg::new(0xC0FFEE);
        for _ in 0..2000 {
            let i = rng.below(32);
            let row = c.get_or_compute(i, 4, None, move |r| {
                r.iter_mut().for_each(|x| *x = i as f32 * 10.0)
            });
            assert!(row.iter().all(|&x| x == i as f32 * 10.0), "index {i}");
        }
        assert!(c.len() <= 8);
        let s = c.stats();
        assert_eq!(s.hits + s.misses, 2000);
        assert!(s.hits > 0 && s.evictions > 0);
    }

    #[test]
    fn clear_empties() {
        let mut c = RowCache::with_capacity_rows(4);
        c.get_or_compute(0, 4, None, fill(0.0));
        c.clear();
        assert!(c.is_empty());
        assert!(!c.contains(0));
    }

    #[test]
    fn clear_resets_stats_and_clock() {
        let mut c = RowCache::with_capacity_rows(2);
        c.get_or_compute(0, 4, None, fill(0.0));
        c.get_or_compute(0, 4, None, fill(0.0)); // hit
        c.get_or_compute(1, 4, None, fill(1.0));
        c.get_or_compute(2, 4, None, fill(2.0)); // eviction
        assert_ne!(c.stats(), CacheStats::default(), "test setup: stats non-trivial");

        c.clear();
        assert_eq!(c.stats(), CacheStats::default(), "stats must not bleed across datasets");
        assert_eq!(c.stats().hit_rate(), 0.0);

        // The cleared cache behaves exactly like a fresh one: same
        // accesses, same counters, same LRU decisions.
        let mut fresh = RowCache::with_capacity_rows(2);
        for cache in [&mut c, &mut fresh] {
            cache.get_or_compute(5, 4, None, fill(5.0));
            cache.get_or_compute(6, 4, None, fill(6.0));
            cache.get_or_compute(5, 4, None, fill(5.0)); // touch 5; 6 is LRU
            cache.get_or_compute(7, 4, None, fill(7.0)); // evicts 6
        }
        assert_eq!(c.stats(), fresh.stats());
        assert!(c.contains(5) && c.contains(7) && !c.contains(6));
    }
}
