//! Persistent benchmark baselines — the repo's perf trajectory and its
//! CI regression gate.
//!
//! `pasmo bench --save-baseline` records per-metric medians into a
//! committed `BENCH_baseline.json` (written through
//! [`crate::util::artifact`], so the file is checksummed and the write
//! is crash-safe); `pasmo bench --check-baseline` re-measures the same
//! tiny workloads and fails with a positioned diff
//! (`BENCH_baseline.json#metrics.<name>`) when a metric moves beyond
//! its noise tolerance in the worse direction. `ci.sh` runs the check
//! on every build, so a SIMD path or cache layer that silently loses
//! its win fails CI instead of decaying unnoticed.
//!
//! Two tolerance classes keep the gate honest on noisy shared runners:
//! deterministic counters (`kernel_entries`, solver iterations) carry
//! the tight [`TOL_COUNTER`] — they only move when the algorithm
//! changes — while wall-clock-derived metrics carry the loose
//! [`TOL_WALL`], because they move with the machine. Medians of an odd
//! number of repetitions (not means) absorb scheduler spikes.
//!
//! The committed seed file starts with an *empty* metric map: a
//! `--check-baseline` run against an empty baseline bootstraps it by
//! measuring and saving, so the gate self-initializes on a fresh host
//! class instead of comparing against another machine's clock.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::artifact;
use crate::util::error::{Context, Error, Result};
use crate::util::json::Json;

/// Artifact `kind` tag stamped into baseline files.
pub const BASELINE_KIND: &str = "bench_baseline";
/// Baseline schema version.
pub const BASELINE_VERSION: f64 = 1.0;
/// Tight relative tolerance for deterministic counter metrics.
pub const TOL_COUNTER: f64 = 0.02;
/// Loose relative tolerance for wall-clock-derived metrics.
pub const TOL_WALL: f64 = 0.5;

/// Median of `samples` under IEEE total order (sorts in place). Use an
/// odd repetition count so deterministic counters stay exact.
pub fn median(samples: &mut [f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(f64::total_cmp);
    let n = samples.len();
    if n % 2 == 1 {
        samples[n / 2]
    } else {
        0.5 * (samples[n / 2 - 1] + samples[n / 2])
    }
}

/// Which way "better" points for a metric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Smaller is better (wall seconds, kernel entries).
    Lower,
    /// Larger is better (rows/s, queries/s).
    Higher,
}

impl Direction {
    /// The on-disk tag (`"lower"` / `"higher"`).
    pub fn as_str(self) -> &'static str {
        match self {
            Direction::Lower => "lower",
            Direction::Higher => "higher",
        }
    }

    /// Parse the on-disk tag.
    pub fn parse(s: &str) -> Option<Direction> {
        match s {
            "lower" => Some(Direction::Lower),
            "higher" => Some(Direction::Higher),
            _ => None,
        }
    }
}

/// One recorded metric: the median of several measured repetitions plus
/// how future runs compare against it.
#[derive(Debug, Clone)]
pub struct BaselineMetric {
    /// Recorded median.
    pub value: f64,
    /// Which way better points.
    pub direction: Direction,
    /// Relative noise tolerance (`0.02` = ±2%).
    pub tol_rel: f64,
}

/// A named metric set persisted as `BENCH_baseline.json`.
#[derive(Debug, Clone, Default)]
pub struct Baseline {
    /// Metric name → recorded value, in name order (deterministic
    /// serialization, stable diffs).
    pub metrics: BTreeMap<String, BaselineMetric>,
}

impl Baseline {
    /// Empty baseline — the committed bootstrap state.
    pub fn new() -> Baseline {
        Baseline::default()
    }

    /// No metrics recorded yet? (An empty baseline tells
    /// `--check-baseline` to bootstrap rather than compare.)
    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }

    /// Record (or overwrite) one metric.
    pub fn set(&mut self, name: &str, value: f64, direction: Direction, tol_rel: f64) {
        self.metrics
            .insert(name.to_string(), BaselineMetric { value, direction, tol_rel });
    }

    /// Serialize to the artifact document (the checksum is stamped by
    /// [`Baseline::save`]).
    pub fn to_json(&self) -> Json {
        let mut metrics = BTreeMap::new();
        for (name, m) in &self.metrics {
            let mut obj = BTreeMap::new();
            obj.insert("value".to_string(), Json::Num(m.value));
            obj.insert(
                "direction".to_string(),
                Json::Str(m.direction.as_str().to_string()),
            );
            obj.insert("tol_rel".to_string(), Json::Num(m.tol_rel));
            metrics.insert(name.clone(), Json::Obj(obj));
        }
        let mut doc = BTreeMap::new();
        doc.insert("kind".to_string(), Json::Str(BASELINE_KIND.to_string()));
        doc.insert("version".to_string(), Json::Num(BASELINE_VERSION));
        doc.insert("metrics".to_string(), Json::Obj(metrics));
        Json::Obj(doc)
    }

    /// Parse an artifact document. Field errors are positioned as
    /// `metrics.<name>.<field>`.
    pub fn from_json(doc: &Json) -> Result<Baseline> {
        let kind = doc.get("kind").and_then(Json::as_str).unwrap_or(BASELINE_KIND);
        if kind != BASELINE_KIND {
            return Err(Error::msg(format!(
                "kind: expected {BASELINE_KIND:?}, found {kind:?}"
            )));
        }
        let mut out = Baseline::new();
        let metrics = match doc.get("metrics") {
            None => return Ok(out),
            Some(v) => v.as_obj().context("metrics: expected an object")?,
        };
        for (name, v) in metrics {
            let value = v
                .get("value")
                .and_then(Json::as_f64)
                .with_context(|| format!("metrics.{name}.value: expected a number"))?;
            let dir_tag = v
                .get("direction")
                .and_then(Json::as_str)
                .with_context(|| format!("metrics.{name}.direction: expected a string"))?;
            let direction = Direction::parse(dir_tag).with_context(|| {
                format!("metrics.{name}.direction: unknown tag {dir_tag:?} (lower|higher)")
            })?;
            let tol_rel = v
                .get("tol_rel")
                .and_then(Json::as_f64)
                .with_context(|| format!("metrics.{name}.tol_rel: expected a number"))?;
            out.set(name, value, direction, tol_rel);
        }
        Ok(out)
    }

    /// Write through the checksummed atomic artifact layer.
    pub fn save(&self, path: &Path) -> Result<()> {
        artifact::save_json(path, self.to_json())
    }

    /// Load and parse, verifying the artifact checksum when present.
    pub fn load(path: &Path) -> Result<Baseline> {
        let doc = artifact::load_json(path)?;
        Baseline::from_json(&doc).with_context(|| format!("load {}", path.display()))
    }
}

/// Outcome of a baseline check: positioned regression/missing lines
/// (failures) plus informational improvement/new-metric lines.
#[derive(Debug, Clone, Default)]
pub struct CheckReport {
    /// Metrics beyond tolerance in the worse direction; each line is
    /// positioned as `<origin>#metrics.<name>`.
    pub regressions: Vec<String>,
    /// Metrics beyond tolerance in the better direction (worth
    /// re-saving the baseline to bank the win).
    pub improvements: Vec<String>,
    /// Measured metrics absent from the committed baseline.
    pub new_metrics: Vec<String>,
    /// Committed metrics this run failed to measure — failures, because
    /// a silently dropped metric is a regression of the gate itself.
    pub missing: Vec<String>,
}

impl CheckReport {
    /// Does the gate pass?
    pub fn ok(&self) -> bool {
        self.regressions.is_empty() && self.missing.is_empty()
    }
}

/// Compare a fresh measurement set against the committed baseline.
/// `origin` names the baseline file in positioned messages
/// (e.g. `BENCH_baseline.json`).
pub fn check(baseline: &Baseline, current: &Baseline, origin: &str) -> CheckReport {
    let mut report = CheckReport::default();
    for (name, base) in &baseline.metrics {
        let Some(cur) = current.metrics.get(name) else {
            report.missing.push(format!(
                "{origin}#metrics.{name}: recorded in the baseline but not measured by this run"
            ));
            continue;
        };
        let rel = if base.value.abs() > f64::EPSILON {
            (cur.value - base.value) / base.value
        } else {
            0.0
        };
        let worse = match base.direction {
            Direction::Lower => rel > base.tol_rel,
            Direction::Higher => rel < -base.tol_rel,
        };
        let better = match base.direction {
            Direction::Lower => rel < -base.tol_rel,
            Direction::Higher => rel > base.tol_rel,
        };
        let line = format!(
            "{origin}#metrics.{name}: baseline {:.6} -> current {:.6} ({:+.1}%, tol \u{b1}{:.0}%)",
            base.value,
            cur.value,
            100.0 * rel,
            100.0 * base.tol_rel
        );
        if worse {
            report.regressions.push(format!("{line} REGRESSED"));
        } else if better {
            report.improvements.push(line);
        }
    }
    for name in current.metrics.keys() {
        if !baseline.metrics.contains_key(name) {
            report.new_metrics.push(format!(
                "{origin}#metrics.{name}: new metric (not yet in the baseline)"
            ));
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_baseline() -> Baseline {
        let mut b = Baseline::new();
        b.set("train.kernel_entries", 1000.0, Direction::Lower, TOL_COUNTER);
        b.set("predict.rows_per_s", 500.0, Direction::Higher, TOL_WALL);
        b
    }

    #[test]
    fn median_is_deterministic_and_order_free() {
        let mut empty: [f64; 0] = [];
        assert_eq!(median(&mut empty), 0.0);
        assert_eq!(median(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&mut [4.0, 1.0, 3.0, 2.0]), 2.5);
        assert_eq!(median(&mut [7.0]), 7.0);
    }

    #[test]
    fn round_trips_through_the_checksummed_artifact() {
        let dir = std::env::temp_dir()
            .join(format!("pasmo-baseline-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_baseline.json");
        sample_baseline().save(&path).unwrap();
        let doc = crate::util::artifact::load_json(&path).unwrap();
        assert!(doc.get("checksum").is_some(), "artifact layer stamps a checksum");
        assert_eq!(doc.get("kind").and_then(Json::as_str), Some(BASELINE_KIND));
        let loaded = Baseline::load(&path).unwrap();
        assert_eq!(loaded.metrics.len(), 2);
        let m = &loaded.metrics["train.kernel_entries"];
        assert_eq!(m.value.to_bits(), 1000.0f64.to_bits());
        assert_eq!(m.direction, Direction::Lower);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_baseline_parses_and_signals_bootstrap() {
        let b = Baseline::from_json(&Baseline::new().to_json()).unwrap();
        assert!(b.is_empty(), "empty metrics map = bootstrap state");
    }

    #[test]
    fn regressions_are_positioned_and_direction_aware() {
        let base = sample_baseline();
        let mut cur = Baseline::new();
        // +10% on a lower-is-better counter and -60% on a
        // higher-is-better rate: both regress
        cur.set("train.kernel_entries", 1100.0, Direction::Lower, TOL_COUNTER);
        cur.set("predict.rows_per_s", 200.0, Direction::Higher, TOL_WALL);
        let report = check(&base, &cur, "BENCH_baseline.json");
        assert!(!report.ok());
        assert_eq!(report.regressions.len(), 2);
        assert!(report
            .regressions
            .iter()
            .any(|r| r.contains("BENCH_baseline.json#metrics.predict.rows_per_s")));
        assert!(report.regressions.iter().all(|r| r.contains("REGRESSED")));
        assert!(report.missing.is_empty() && report.new_metrics.is_empty());
    }

    #[test]
    fn improvements_new_and_missing_metrics_are_classified() {
        let base = sample_baseline();
        let mut cur = Baseline::new();
        cur.set("train.kernel_entries", 900.0, Direction::Lower, TOL_COUNTER);
        cur.set("brand.new", 1.0, Direction::Higher, TOL_WALL);
        let report = check(&base, &cur, "BENCH_baseline.json");
        assert_eq!(report.improvements.len(), 1);
        assert_eq!(report.new_metrics.len(), 1);
        assert_eq!(report.missing.len(), 1, "predict.rows_per_s was not measured");
        assert!(!report.ok(), "missing committed metrics fail the gate");
    }

    #[test]
    fn within_tolerance_passes_quietly() {
        let base = sample_baseline();
        let mut cur = Baseline::new();
        // +1% against a 2% counter tolerance, -20% against a 50% wall
        // tolerance: both inside the noise band
        cur.set("train.kernel_entries", 1010.0, Direction::Lower, TOL_COUNTER);
        cur.set("predict.rows_per_s", 400.0, Direction::Higher, TOL_WALL);
        let report = check(&base, &cur, "BENCH_baseline.json");
        assert!(report.ok(), "{:?}", report.regressions);
        assert!(report.improvements.is_empty() && report.new_metrics.is_empty());
    }

    #[test]
    fn bad_field_errors_are_positioned() {
        let text = "{\"kind\":\"bench_baseline\",\"metrics\":{\"m\":{\"value\":1,\
                    \"direction\":\"sideways\",\"tol_rel\":0.1}}}";
        let doc = Json::parse(text).unwrap();
        let err = Baseline::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("metrics.m.direction"), "{err}");
        let wrong_kind = Json::parse("{\"kind\":\"model\"}").unwrap();
        let err = Baseline::from_json(&wrong_kind).unwrap_err().to_string();
        assert!(err.contains("bench_baseline"), "{err}");
    }
}
