//! Per-model rolling serving metrics, served by `{"cmd":"stats"}`.
//!
//! Everything is counter-shaped and cheap: the batch loop takes one
//! mutex acquisition per (model × micro-batch) group, never one per
//! query. Latency quantiles come from a fixed power-of-two bucket
//! histogram — constant memory, no per-request allocation, and p50/p99
//! resolve to a bucket upper edge (a factor-of-two resolution, plenty
//! for saturation dashboards).

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Fixed-bucket latency histogram over power-of-two microsecond
/// buckets: bucket `k` counts latencies in `[2^k, 2^{k+1})` µs (bucket
/// 0 also absorbs sub-microsecond values).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: [u64; LatencyHistogram::BUCKETS],
    total: u64,
}

impl LatencyHistogram {
    /// Bucket count: 2^32 µs ≈ 71 minutes tops out the last bucket.
    pub const BUCKETS: usize = 32;

    /// An empty histogram.
    pub fn new() -> LatencyHistogram {
        LatencyHistogram { counts: [0; LatencyHistogram::BUCKETS], total: 0 }
    }

    /// Record one latency observation, in microseconds.
    pub fn record(&mut self, us: u64) {
        let k = (63 - us.max(1).leading_zeros() as usize).min(LatencyHistogram::BUCKETS - 1);
        self.counts[k] += 1;
        self.total += 1;
    }

    /// Observations recorded so far.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// The `q`-quantile (`0 < q ≤ 1`), reported as the upper edge
    /// `2^{k+1}` µs of the first bucket whose cumulative count reaches
    /// `⌈q·total⌉`; 0 when the histogram is empty.
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (k, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return 1u64 << (k + 1).min(63);
            }
        }
        // unreachable: cum == total ≥ target by the final iteration
        1u64 << LatencyHistogram::BUCKETS
    }
}

impl Default for LatencyHistogram {
    fn default() -> LatencyHistogram {
        LatencyHistogram::new()
    }
}

/// One model's rolling counters.
#[derive(Debug, Clone, Default)]
pub struct ModelMetrics {
    /// Queries scored (admitted, batched, and answered).
    pub requests: u64,
    /// Per-model request errors (dimension mismatches and the like).
    pub errors: u64,
    /// Queries shed at admission: the queue was at its `--max-queue`
    /// bound, so the client got an explicit overload reply instead.
    pub shed: u64,
    /// Queries that out-waited their `--deadline-us` in the queue and
    /// were answered `deadline_exceeded` without being scored.
    pub expired: u64,
    /// Micro-batches this model appeared in (a mixed batch counts once
    /// per model group).
    pub batches: u64,
    /// Kernel entries evaluated on this model's behalf, summed over
    /// every machine × batch pass
    /// ([`Scorer::kernel_entries_per_pass`](crate::svm::scorer::Scorer::kernel_entries_per_pass)).
    pub kernel_entries: u64,
    /// Admission→response latency histogram, microseconds.
    pub latency: LatencyHistogram,
}

impl ModelMetrics {
    /// Mean scored queries per micro-batch group (0 before traffic).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// The per-model metrics table.
#[derive(Debug, Default)]
pub struct Metrics {
    per_model: Mutex<BTreeMap<String, ModelMetrics>>,
}

impl Metrics {
    /// An empty table.
    pub fn new() -> Metrics {
        Metrics { per_model: Mutex::new(BTreeMap::new()) }
    }

    /// Run `f` against `name`'s counters under one lock acquisition —
    /// the batch loop records a whole batch group in one call.
    pub fn with_model(&self, name: &str, f: impl FnOnce(&mut ModelMetrics)) {
        let mut map = self.per_model.lock().unwrap_or_else(|p| p.into_inner());
        if !map.contains_key(name) {
            map.insert(name.to_string(), ModelMetrics::default());
        }
        if let Some(m) = map.get_mut(name) {
            f(m);
        }
    }

    /// Clone the whole table (the stats handler renders from this).
    pub fn snapshot(&self) -> BTreeMap<String, ModelMetrics> {
        self.per_model.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_by_power_of_two() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.quantile_us(0.5), 0);
        for us in [0, 1, 3, 100, 1000] {
            h.record(us);
        }
        assert_eq!(h.count(), 5);
        // 0→bucket0, 1→bucket0, 3→bucket1, 100→bucket6, 1000→bucket9
        // p50 target = ⌈0.5·5⌉ = 3rd obs → bucket 1 → upper edge 4 µs
        assert_eq!(h.quantile_us(0.5), 4);
        // p99 target = 5th obs → bucket 9 → upper edge 1024 µs
        assert_eq!(h.quantile_us(0.99), 1024);
        // p-min resolves to the first non-empty bucket's edge
        assert_eq!(h.quantile_us(1e-9), 2);
    }

    #[test]
    fn huge_latencies_saturate_the_last_bucket() {
        let mut h = LatencyHistogram::new();
        h.record(u64::MAX);
        assert_eq!(h.quantile_us(1.0), 1u64 << 32);
    }

    #[test]
    fn metrics_accumulate_per_model() {
        let m = Metrics::new();
        m.with_model("a", |mm| {
            mm.requests += 3;
            mm.batches += 1;
            mm.kernel_entries += 300;
            for us in [10, 20, 30] {
                mm.latency.record(us);
            }
        });
        m.with_model("a", |mm| {
            mm.requests += 1;
            mm.batches += 1;
        });
        let snap = m.snapshot();
        let a = snap.get("a").unwrap();
        assert_eq!((a.requests, a.batches, a.kernel_entries), (4, 2, 300));
        assert_eq!(a.mean_batch(), 2.0);
        assert_eq!(a.latency.count(), 3);
        assert!(snap.get("b").is_none());
    }
}
