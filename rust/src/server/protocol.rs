//! The serve wire protocol: newline-delimited JSON, one object per line.
//!
//! Requests are either **score** lines — `{"x": [..], "model": "name"?,
//! "id": N?}` — or **admin** lines carrying a `"cmd"` key (`load`,
//! `stats`, `models`, `shutdown`). Responses are single JSON objects
//! with `"ok": true|false`; score responses echo the request `id` so
//! clients may pipeline.
//!
//! `"x"` takes two shapes: a dense number array, or a sparse object
//! keyed by **1-based** feature index — `{"x":{"7":0.5,"12":-2}}` —
//! mirroring the LIBSVM convention, so a client holding sparse rows
//! never renders the zeros. Both shapes densify to the identical query
//! vector ([`Query::densify`]), so they score bit-identically.
//!
//! Parsing reuses [`crate::util::json::Json`]; response lines are built
//! by hand here (no intermediate tree on the scoring hot path), with
//! every user-provided string routed through
//! [`write_json_string`](crate::util::json::write_json_string) and every
//! number through [`write_json_num`](crate::util::json::write_json_num)
//! — the same shortest-round-trip policy the offline artifacts use, so
//! served decision values bit-match `pasmo predict` output.

use std::fmt::Write as _;

use crate::util::json::{write_json_num, write_json_string, Json};

/// A parsed request line.
#[derive(Debug, Clone)]
pub enum Request {
    /// `{"x": [..], "model": "name"?, "id": N?}` — score one query.
    Score(ScoreRequest),
    /// `{"cmd": "load", "name": .., "path": ..}` — (re)load a model
    /// file under `name` (hot-swap when the name already exists).
    Load {
        /// Registry name to (re)bind.
        name: String,
        /// Model file path, as sent by the client.
        path: String,
    },
    /// `{"cmd": "stats"}` — per-model serving metrics.
    Stats,
    /// `{"cmd": "models"}` — the registry listing.
    Models,
    /// `{"cmd": "shutdown"}` — drain in-flight batches and exit.
    Shutdown,
}

/// The score-request payload.
#[derive(Debug, Clone)]
pub struct ScoreRequest {
    /// Target model name; may be omitted when exactly one model is loaded.
    pub model: Option<String>,
    /// Query features (JSON numbers are narrowed to `f32`, the dataset
    /// element type — the narrowing every offline loader applies too).
    pub x: Query,
    /// Client correlation id, echoed verbatim in the response.
    pub id: Option<f64>,
}

/// A query's features, in whichever shape the client sent them.
#[derive(Debug, Clone, PartialEq)]
pub enum Query {
    /// `"x": [..]` — a dense feature array.
    Dense(Vec<f32>),
    /// `"x": {"7":0.5,..}` — sparse entries, held as (0-based index,
    /// value) sorted ascending (the wire keys are 1-based).
    Sparse(Vec<(u32, f32)>),
}

impl Query {
    /// Render into the model's dense `dim`-feature layout. The error
    /// string is client-facing; it keeps the historical `expects {dim}`
    /// phrasing for the dense length mismatch.
    pub fn densify(self, dim: usize) -> Result<Vec<f32>, String> {
        match self {
            Query::Dense(x) => {
                if x.len() != dim {
                    return Err(format!("x has {} features", x.len()));
                }
                Ok(x)
            }
            Query::Sparse(entries) => {
                let mut out = vec![0f32; dim];
                for &(i, v) in &entries {
                    if i as usize >= dim {
                        return Err(format!("x has feature index {}", i as u64 + 1));
                    }
                    out[i as usize] = v;
                }
                Ok(out)
            }
        }
    }
}

/// Parse one request line. The error string is client-facing (it comes
/// back in an `{"ok":false}` response), so it names the offending key.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let v = Json::parse(line).map_err(|e| format!("bad json: {e}"))?;
    if v.as_obj().is_none() {
        return Err("request must be a json object".to_string());
    }
    if let Some(cmd) = v.get("cmd") {
        let cmd = cmd.as_str().ok_or_else(|| "cmd: expected a string".to_string())?;
        return match cmd {
            "load" => {
                let name = v
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| "load: missing string \"name\"".to_string())?;
                let path = v
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| "load: missing string \"path\"".to_string())?;
                Ok(Request::Load { name: name.to_string(), path: path.to_string() })
            }
            "stats" => Ok(Request::Stats),
            "models" => Ok(Request::Models),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(format!("unknown cmd {other:?}")),
        };
    }
    let xs = v.get("x").ok_or_else(|| "missing \"x\" array (or \"cmd\")".to_string())?;
    let x = if let Some(arr) = xs.as_arr() {
        if arr.is_empty() {
            return Err("x: must be non-empty".to_string());
        }
        let mut x = Vec::with_capacity(arr.len());
        for (i, j) in arr.iter().enumerate() {
            let n = j.as_f64().ok_or_else(|| format!("x[{i}]: expected a number"))?;
            x.push(n as f32);
        }
        Query::Dense(x)
    } else if let Some(obj) = xs.as_obj() {
        let mut entries = Vec::with_capacity(obj.len());
        for (k, j) in obj {
            let idx: u64 = k
                .parse()
                .map_err(|_| format!("x key {k:?}: expected a 1-based feature index"))?;
            if idx == 0 {
                return Err("x key \"0\": feature indices are 1-based".to_string());
            }
            if idx > u32::MAX as u64 {
                return Err(format!("x key {k:?}: index exceeds the supported maximum"));
            }
            let n = j.as_f64().ok_or_else(|| format!("x[{k:?}]: expected a number"))?;
            entries.push(((idx - 1) as u32, n as f32));
        }
        // BTreeMap orders keys as strings ("10" < "2"); re-sort numerically.
        entries.sort_unstable_by_key(|&(i, _)| i);
        Query::Sparse(entries)
    } else {
        return Err("x: expected an array of numbers or a {\"index\":value} object".to_string());
    };
    let model = match v.get("model") {
        None => None,
        Some(m) => Some(
            m.as_str()
                .map(str::to_string)
                .ok_or_else(|| "model: expected a string".to_string())?,
        ),
    };
    let id = match v.get("id") {
        None => None,
        Some(j) => Some(j.as_f64().ok_or_else(|| "id: expected a number".to_string())?),
    };
    Ok(Request::Score(ScoreRequest { model, x, id }))
}

/// One scored query's outcome, rendered by [`score_response`]. The
/// variants mirror the model kinds of
/// [`AnyModel`](crate::svm::schema::AnyModel).
#[derive(Debug, Clone)]
pub enum Outcome {
    /// Binary svc: decision value, ±1 prediction, Platt probability
    /// when the model was trained with one.
    Classify {
        /// Raw decision-function value.
        decision: f64,
        /// `+1` (decision ≥ 0) or `−1`.
        prediction: i32,
        /// Platt-scaled P(y = +1 | x), when available.
        probability: Option<f64>,
    },
    /// svr: the regressed target.
    Regress {
        /// Predicted value (the decision function itself).
        prediction: f64,
    },
    /// oneclass: decision value, `+1` inlier / `−1` outlier.
    OneClass {
        /// Raw decision-function value (offset by −ρ).
        decision: f64,
        /// `+1` (inlier) or `−1` (outlier).
        prediction: i32,
    },
    /// multiclass: the majority-vote class id.
    Multiclass {
        /// Voted class label.
        prediction: i32,
    },
}

/// Render a successful score response line (no trailing newline).
pub fn score_response(id: Option<f64>, model: &str, out: &Outcome) -> String {
    let mut s = String::from("{\"ok\":true");
    if let Some(id) = id {
        s.push_str(",\"id\":");
        write_json_num(&mut s, id);
    }
    s.push_str(",\"model\":");
    write_json_string(&mut s, model);
    match out {
        Outcome::Classify { decision, prediction, probability } => {
            s.push_str(",\"kind\":\"classify\",\"decision\":");
            write_json_num(&mut s, *decision);
            let _ = write!(s, ",\"prediction\":{prediction}");
            if let Some(p) = probability {
                s.push_str(",\"probability\":");
                write_json_num(&mut s, *p);
            }
        }
        Outcome::Regress { prediction } => {
            s.push_str(",\"kind\":\"regress\",\"prediction\":");
            write_json_num(&mut s, *prediction);
        }
        Outcome::OneClass { decision, prediction } => {
            s.push_str(",\"kind\":\"oneclass\",\"decision\":");
            write_json_num(&mut s, *decision);
            let _ = write!(s, ",\"prediction\":{prediction}");
        }
        Outcome::Multiclass { prediction } => {
            let _ = write!(s, ",\"kind\":\"multiclass\",\"prediction\":{prediction}");
        }
    }
    s.push('}');
    s
}

/// Render an error response line (no trailing newline). `msg` passes
/// through [`write_json_string`], so arbitrary client input — bad model
/// names with quotes, say — cannot break the response framing.
pub fn error_response(id: Option<f64>, msg: &str) -> String {
    let mut s = String::from("{\"ok\":false");
    if let Some(id) = id {
        s.push_str(",\"id\":");
        write_json_num(&mut s, id);
    }
    s.push_str(",\"error\":");
    write_json_string(&mut s, msg);
    s.push('}');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_request_round_trips_f32_features() {
        let req = parse_request(r#"{"x":[0.1,-2.5,3],"model":"m","id":7}"#);
        let Ok(Request::Score(sr)) = req else { panic!("expected score: {req:?}") };
        assert_eq!(sr.x, Query::Dense(vec![0.1f32, -2.5, 3.0]));
        assert_eq!(sr.model.as_deref(), Some("m"));
        assert_eq!(sr.id, Some(7.0));
        // f32 Display → f64 parse → f32 narrow recovers identical bits,
        // so JSON queries can bit-match in-process scoring.
        for v in [0.1f32, -2.5, 1e-8, 3.25e7] {
            let text = format!("{v}");
            let back = text.parse::<f64>().map(|d| d as f32);
            assert_eq!(back.map(f32::to_bits), Ok(v.to_bits()), "{text}");
        }
    }

    #[test]
    fn sparse_queries_parse_sorted_and_densify_like_dense_ones() {
        // keys arrive in string order ("12" < "3" as strings); parsing
        // re-sorts numerically and shifts to 0-based.
        let req = parse_request(r#"{"x":{"12":-2,"3":0.5},"id":1}"#);
        let Ok(Request::Score(sr)) = req else { panic!("expected score: {req:?}") };
        assert_eq!(sr.x, Query::Sparse(vec![(2, 0.5), (11, -2.0)]));
        let dense = sr.x.densify(16).unwrap();
        let mut want = vec![0f32; 16];
        (want[2], want[11]) = (0.5, -2.0);
        assert_eq!(dense, want);
        // both wire shapes densify to the identical vector
        let req = parse_request(r#"{"x":[0,0,0.5,0]}"#);
        let Ok(Request::Score(sr)) = req else { panic!("{req:?}") };
        let sparse = Query::Sparse(vec![(2, 0.5)]).densify(4).unwrap();
        assert_eq!(sr.x.densify(4).unwrap(), sparse);
        // an empty object is a legal all-zeros query
        let req = parse_request(r#"{"x":{}}"#);
        let Ok(Request::Score(sr)) = req else { panic!("{req:?}") };
        assert_eq!(sr.x.densify(3).unwrap(), vec![0.0; 3]);
    }

    #[test]
    fn sparse_query_errors_name_the_offending_key() {
        for (line, needle) in [
            (r#"{"x":{"0":1}}"#, "1-based"),
            (r#"{"x":{"abc":1}}"#, "\"abc\""),
            (r#"{"x":{"-3":1}}"#, "\"-3\""),
            (r#"{"x":{"5000000000":1}}"#, "supported maximum"),
            (r#"{"x":{"2":"v"}}"#, "expected a number"),
            (r#"{"x":"nope"}"#, "array of numbers or a"),
        ] {
            let err = parse_request(line).err().unwrap_or_default();
            assert!(err.contains(needle), "{line} → {err}");
        }
        // out-of-range index surfaces at densify time with its 1-based key
        let err = Query::Sparse(vec![(9, 1.0)]).densify(4).unwrap_err();
        assert!(err.contains("index 10"), "{err}");
        let err = Query::Dense(vec![1.0; 3]).densify(4).unwrap_err();
        assert!(err.contains("3 features"), "{err}");
    }

    #[test]
    fn admin_commands_parse() {
        assert!(matches!(parse_request(r#"{"cmd":"stats"}"#), Ok(Request::Stats)));
        assert!(matches!(parse_request(r#"{"cmd":"models"}"#), Ok(Request::Models)));
        assert!(matches!(parse_request(r#"{"cmd":"shutdown"}"#), Ok(Request::Shutdown)));
        let load = parse_request(r#"{"cmd":"load","name":"a","path":"/p.json"}"#);
        let Ok(Request::Load { name, path }) = load else { panic!("load: {load:?}") };
        assert_eq!((name.as_str(), path.as_str()), ("a", "/p.json"));
    }

    #[test]
    fn malformed_requests_are_rejected_with_the_offending_key() {
        for (line, needle) in [
            ("not json", "bad json"),
            ("[1,2]", "must be a json object"),
            (r#"{"cmd":"frobnicate"}"#, "unknown cmd"),
            (r#"{"cmd":"load","name":"a"}"#, "\"path\""),
            (r#"{"y":[1]}"#, "missing \"x\""),
            (r#"{"x":[]}"#, "non-empty"),
            (r#"{"x":[1,"two"]}"#, "x[1]"),
            (r#"{"x":[1],"model":3}"#, "model"),
            (r#"{"x":[1],"id":"seven"}"#, "id"),
        ] {
            let err = parse_request(line).err().unwrap_or_default();
            assert!(err.contains(needle), "{line} → {err}");
        }
    }

    #[test]
    fn responses_escape_user_strings_and_round_trip() {
        let resp = score_response(
            Some(3.0),
            "na\"me",
            &Outcome::Classify { decision: 0.1 + 0.2, prediction: 1, probability: Some(0.75) },
        );
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("model").and_then(Json::as_str), Some("na\"me"));
        // shortest-round-trip rendering: parsed bits match the input
        let d = v.get("decision").and_then(Json::as_f64);
        assert_eq!(d.map(f64::to_bits), Some((0.1f64 + 0.2).to_bits()));

        let err = error_response(None, "quo\"te\\path\n");
        let v = Json::parse(&err).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(v.get("error").and_then(Json::as_str), Some("quo\"te\\path\n"));
    }
}
