//! The serve wire protocol: newline-delimited JSON, one object per line.
//!
//! Requests are either **score** lines — `{"x": [..], "model": "name"?,
//! "id": N?}` — or **admin** lines carrying a `"cmd"` key (`load`,
//! `stats`, `models`, `shutdown`). Responses are single JSON objects
//! with `"ok": true|false`; score responses echo the request `id` so
//! clients may pipeline.
//!
//! Parsing reuses [`crate::util::json::Json`]; response lines are built
//! by hand here (no intermediate tree on the scoring hot path), with
//! every user-provided string routed through
//! [`write_json_string`](crate::util::json::write_json_string) and every
//! number through [`write_json_num`](crate::util::json::write_json_num)
//! — the same shortest-round-trip policy the offline artifacts use, so
//! served decision values bit-match `pasmo predict` output.

use std::fmt::Write as _;

use crate::util::json::{write_json_num, write_json_string, Json};

/// A parsed request line.
#[derive(Debug, Clone)]
pub enum Request {
    /// `{"x": [..], "model": "name"?, "id": N?}` — score one query.
    Score(ScoreRequest),
    /// `{"cmd": "load", "name": .., "path": ..}` — (re)load a model
    /// file under `name` (hot-swap when the name already exists).
    Load {
        /// Registry name to (re)bind.
        name: String,
        /// Model file path, as sent by the client.
        path: String,
    },
    /// `{"cmd": "stats"}` — per-model serving metrics.
    Stats,
    /// `{"cmd": "models"}` — the registry listing.
    Models,
    /// `{"cmd": "shutdown"}` — drain in-flight batches and exit.
    Shutdown,
}

/// The score-request payload.
#[derive(Debug, Clone)]
pub struct ScoreRequest {
    /// Target model name; may be omitted when exactly one model is loaded.
    pub model: Option<String>,
    /// Query features (JSON numbers are narrowed to `f32`, the dataset
    /// element type — the narrowing every offline loader applies too).
    pub x: Vec<f32>,
    /// Client correlation id, echoed verbatim in the response.
    pub id: Option<f64>,
}

/// Parse one request line. The error string is client-facing (it comes
/// back in an `{"ok":false}` response), so it names the offending key.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let v = Json::parse(line).map_err(|e| format!("bad json: {e}"))?;
    if v.as_obj().is_none() {
        return Err("request must be a json object".to_string());
    }
    if let Some(cmd) = v.get("cmd") {
        let cmd = cmd.as_str().ok_or_else(|| "cmd: expected a string".to_string())?;
        return match cmd {
            "load" => {
                let name = v
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| "load: missing string \"name\"".to_string())?;
                let path = v
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| "load: missing string \"path\"".to_string())?;
                Ok(Request::Load { name: name.to_string(), path: path.to_string() })
            }
            "stats" => Ok(Request::Stats),
            "models" => Ok(Request::Models),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(format!("unknown cmd {other:?}")),
        };
    }
    let xs = v.get("x").ok_or_else(|| "missing \"x\" array (or \"cmd\")".to_string())?;
    let arr = xs.as_arr().ok_or_else(|| "x: expected an array of numbers".to_string())?;
    if arr.is_empty() {
        return Err("x: must be non-empty".to_string());
    }
    let mut x = Vec::with_capacity(arr.len());
    for (i, j) in arr.iter().enumerate() {
        let n = j.as_f64().ok_or_else(|| format!("x[{i}]: expected a number"))?;
        x.push(n as f32);
    }
    let model = match v.get("model") {
        None => None,
        Some(m) => Some(
            m.as_str()
                .map(str::to_string)
                .ok_or_else(|| "model: expected a string".to_string())?,
        ),
    };
    let id = match v.get("id") {
        None => None,
        Some(j) => Some(j.as_f64().ok_or_else(|| "id: expected a number".to_string())?),
    };
    Ok(Request::Score(ScoreRequest { model, x, id }))
}

/// One scored query's outcome, rendered by [`score_response`]. The
/// variants mirror the model kinds of
/// [`AnyModel`](crate::svm::schema::AnyModel).
#[derive(Debug, Clone)]
pub enum Outcome {
    /// Binary svc: decision value, ±1 prediction, Platt probability
    /// when the model was trained with one.
    Classify {
        /// Raw decision-function value.
        decision: f64,
        /// `+1` (decision ≥ 0) or `−1`.
        prediction: i32,
        /// Platt-scaled P(y = +1 | x), when available.
        probability: Option<f64>,
    },
    /// svr: the regressed target.
    Regress {
        /// Predicted value (the decision function itself).
        prediction: f64,
    },
    /// oneclass: decision value, `+1` inlier / `−1` outlier.
    OneClass {
        /// Raw decision-function value (offset by −ρ).
        decision: f64,
        /// `+1` (inlier) or `−1` (outlier).
        prediction: i32,
    },
    /// multiclass: the majority-vote class id.
    Multiclass {
        /// Voted class label.
        prediction: i32,
    },
}

/// Render a successful score response line (no trailing newline).
pub fn score_response(id: Option<f64>, model: &str, out: &Outcome) -> String {
    let mut s = String::from("{\"ok\":true");
    if let Some(id) = id {
        s.push_str(",\"id\":");
        write_json_num(&mut s, id);
    }
    s.push_str(",\"model\":");
    write_json_string(&mut s, model);
    match out {
        Outcome::Classify { decision, prediction, probability } => {
            s.push_str(",\"kind\":\"classify\",\"decision\":");
            write_json_num(&mut s, *decision);
            let _ = write!(s, ",\"prediction\":{prediction}");
            if let Some(p) = probability {
                s.push_str(",\"probability\":");
                write_json_num(&mut s, *p);
            }
        }
        Outcome::Regress { prediction } => {
            s.push_str(",\"kind\":\"regress\",\"prediction\":");
            write_json_num(&mut s, *prediction);
        }
        Outcome::OneClass { decision, prediction } => {
            s.push_str(",\"kind\":\"oneclass\",\"decision\":");
            write_json_num(&mut s, *decision);
            let _ = write!(s, ",\"prediction\":{prediction}");
        }
        Outcome::Multiclass { prediction } => {
            let _ = write!(s, ",\"kind\":\"multiclass\",\"prediction\":{prediction}");
        }
    }
    s.push('}');
    s
}

/// Render an error response line (no trailing newline). `msg` passes
/// through [`write_json_string`], so arbitrary client input — bad model
/// names with quotes, say — cannot break the response framing.
pub fn error_response(id: Option<f64>, msg: &str) -> String {
    let mut s = String::from("{\"ok\":false");
    if let Some(id) = id {
        s.push_str(",\"id\":");
        write_json_num(&mut s, id);
    }
    s.push_str(",\"error\":");
    write_json_string(&mut s, msg);
    s.push('}');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_request_round_trips_f32_features() {
        let req = parse_request(r#"{"x":[0.1,-2.5,3],"model":"m","id":7}"#);
        let Ok(Request::Score(sr)) = req else { panic!("expected score: {req:?}") };
        assert_eq!(sr.x, vec![0.1f32, -2.5, 3.0]);
        assert_eq!(sr.model.as_deref(), Some("m"));
        assert_eq!(sr.id, Some(7.0));
        // f32 Display → f64 parse → f32 narrow recovers identical bits,
        // so JSON queries can bit-match in-process scoring.
        for v in [0.1f32, -2.5, 1e-8, 3.25e7] {
            let text = format!("{v}");
            let back = text.parse::<f64>().map(|d| d as f32);
            assert_eq!(back.map(f32::to_bits), Ok(v.to_bits()), "{text}");
        }
    }

    #[test]
    fn admin_commands_parse() {
        assert!(matches!(parse_request(r#"{"cmd":"stats"}"#), Ok(Request::Stats)));
        assert!(matches!(parse_request(r#"{"cmd":"models"}"#), Ok(Request::Models)));
        assert!(matches!(parse_request(r#"{"cmd":"shutdown"}"#), Ok(Request::Shutdown)));
        let load = parse_request(r#"{"cmd":"load","name":"a","path":"/p.json"}"#);
        let Ok(Request::Load { name, path }) = load else { panic!("load: {load:?}") };
        assert_eq!((name.as_str(), path.as_str()), ("a", "/p.json"));
    }

    #[test]
    fn malformed_requests_are_rejected_with_the_offending_key() {
        for (line, needle) in [
            ("not json", "bad json"),
            ("[1,2]", "must be a json object"),
            (r#"{"cmd":"frobnicate"}"#, "unknown cmd"),
            (r#"{"cmd":"load","name":"a"}"#, "\"path\""),
            (r#"{"y":[1]}"#, "missing \"x\""),
            (r#"{"x":[]}"#, "non-empty"),
            (r#"{"x":[1,"two"]}"#, "x[1]"),
            (r#"{"x":[1],"model":3}"#, "model"),
            (r#"{"x":[1],"id":"seven"}"#, "id"),
        ] {
            let err = parse_request(line).err().unwrap_or_default();
            assert!(err.contains(needle), "{line} → {err}");
        }
    }

    #[test]
    fn responses_escape_user_strings_and_round_trip() {
        let resp = score_response(
            Some(3.0),
            "na\"me",
            &Outcome::Classify { decision: 0.1 + 0.2, prediction: 1, probability: Some(0.75) },
        );
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("model").and_then(Json::as_str), Some("na\"me"));
        // shortest-round-trip rendering: parsed bits match the input
        let d = v.get("decision").and_then(Json::as_f64);
        assert_eq!(d.map(f64::to_bits), Some((0.1f64 + 0.2).to_bits()));

        let err = error_response(None, "quo\"te\\path\n");
        let v = Json::parse(&err).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(v.get("error").and_then(Json::as_str), Some("quo\"te\\path\n"));
    }
}
