//! `pasmo serve` — a persistent micro-batching inference tier.
//!
//! A std-only TCP server (no HTTP, no external crates) speaking
//! newline-delimited JSON ([`protocol`]): each connection gets a thread
//! that parses request lines and answers admin commands inline; score
//! requests are enqueued into the shared admission queue and a single
//! scoring loop ([`batcher`]) drains them in micro-batches, scoring
//! each batch in one tiled SV×query pass per model. Models live in a
//! hot-swappable named [`registry`]; per-model counters ([`metrics`])
//! are served by `{"cmd":"stats"}`.
//!
//! Served decision values are **bit-identical** to offline
//! `pasmo predict` on the same inputs: the scorer accumulates each
//! query independently in support order, so batch composition, batch
//! size, and thread count never perturb a result.
//!
//! Shutdown (`{"cmd":"shutdown"}`) is graceful: admissions close,
//! in-flight batches drain and their responses flush, then the accept
//! loop and every connection thread exit and [`Server::run`] returns.
//!
//! Overload is handled explicitly rather than by unbounded queueing:
//! the admission queue is bounded (`--max-queue`; over-bound queries
//! are shed with an error reply), queries carry optional deadlines
//! (`--deadline-us`; overdue queries are answered `deadline_exceeded`
//! instead of scored), concurrent connections are capped
//! (`--max-conns`; over-cap connections get one polite error line),
//! slow readers hit a write timeout instead of wedging their
//! connection thread, and a panic inside a scoring pass quarantines
//! that model generation (new requests are refused until a reload)
//! while the server keeps serving every other model. Shed and expired
//! counts are surfaced per model by `{"cmd":"stats"}`.

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod registry;

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead as _, Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::ensure;
use crate::svm::schema::AnyModel;
use crate::util::error::{Context as _, Result};
use crate::util::json::{write_json_string, Json};

use batcher::{BatchQueue, Pending, PushError};
use metrics::Metrics;
use protocol::Request;
use registry::Registry;

/// How often blocked connection reads wake to poll the shutdown flag.
const POLL: Duration = Duration::from_millis(25);

/// Per-socket write timeout: a client that stops reading its replies
/// stalls only its own connection thread for this long, then the write
/// errors and the connection closes — slow readers cannot wedge the
/// server or pin buffers forever.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Serving configuration (the `pasmo serve` flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address, `host:port`; port 0 binds an ephemeral port
    /// (read it back via [`Server::local_addr`]).
    pub addr: String,
    /// Admission cap: a micro-batch scores at most this many queries.
    pub max_batch: usize,
    /// Admission window: after a batch's first query arrives, wait at
    /// most this many microseconds for more before scoring.
    pub max_wait_us: u64,
    /// Scoring worker threads per batch pass (1 = inline).
    pub threads: usize,
    /// Admission-queue bound (`--max-queue`, 0 = unbounded): when this
    /// many queries are already waiting, new score requests are shed
    /// with an explicit error reply instead of growing the backlog.
    pub max_queue: usize,
    /// Per-query deadline in microseconds (`--deadline-us`, 0 = none):
    /// a query still waiting in the admission queue past its deadline
    /// is answered `deadline_exceeded` and never scored.
    pub deadline_us: u64,
    /// Concurrent-connection cap (`--max-conns`, 0 = unlimited): a
    /// connection over the cap gets one polite error line and is
    /// closed; established connections are unaffected.
    pub max_conns: usize,
    /// Request the packed-f32 SV fast path (`--f32-sv`): every machine
    /// loaded into the registry runs the accuracy gate at load time and
    /// scores through packed f32 only where it passes (see
    /// `server::registry::F32_SV_TOL_SCALE`).
    pub f32_sv: bool,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            max_batch: 64,
            max_wait_us: 200,
            threads: 1,
            max_queue: 1024,
            deadline_us: 0,
            max_conns: 0,
            f32_sv: false,
        }
    }
}

/// State shared by the accept loop, connection threads, and batch loop.
#[derive(Debug)]
struct ServerState {
    registry: Registry,
    queue: BatchQueue,
    metrics: Metrics,
    shutdown: AtomicBool,
    protocol_errors: AtomicU64,
    active_conns: AtomicUsize,
    started: Instant,
    local_addr: SocketAddr,
    config: ServeConfig,
}

/// A bound, not-yet-running server. [`Server::bind`] then
/// [`Server::run`]; `run` blocks until a shutdown command.
#[derive(Debug)]
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
}

impl Server {
    /// Bind the listen socket and preload `(name, model)` pairs into
    /// the registry.
    pub fn bind(config: ServeConfig, models: Vec<(String, AnyModel)>) -> Result<Server> {
        let listener = TcpListener::bind(&config.addr)
            .with_context(|| format!("bind {}", config.addr))?;
        let local_addr = listener.local_addr().context("listener local_addr")?;
        let state = Arc::new(ServerState {
            registry: Registry::new_with(models, config.f32_sv),
            queue: BatchQueue::new(config.max_queue),
            metrics: Metrics::new(),
            shutdown: AtomicBool::new(false),
            protocol_errors: AtomicU64::new(0),
            active_conns: AtomicUsize::new(0),
            started: Instant::now(),
            local_addr,
            config,
        });
        Ok(Server { listener, state })
    }

    /// The bound address (resolves `host:0` ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.state.local_addr
    }

    /// Serve until `{"cmd":"shutdown"}`: one scoped batch-loop thread,
    /// one thread per accepted connection. Returns after every
    /// connection has flushed and the admission queue has drained.
    pub fn run(self) -> Result<()> {
        let state = &self.state;
        std::thread::scope(|s| {
            s.spawn(|| {
                batcher::run_batch_loop(
                    &state.queue,
                    &state.metrics,
                    state.config.max_batch,
                    Duration::from_micros(state.config.max_wait_us),
                    state.config.threads,
                );
            });
            for stream in self.listener.incoming() {
                if state.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                if let Ok(conn) = stream {
                    // The accept loop is the only incrementer, so the
                    // check-then-spawn pair cannot race itself; the
                    // decrement pairs with the connection thread's exit.
                    let active = state.active_conns.fetch_add(1, Ordering::SeqCst);
                    if state.config.max_conns > 0 && active >= state.config.max_conns {
                        state.active_conns.fetch_sub(1, Ordering::SeqCst);
                        s.spawn(move || refuse_connection(conn));
                        continue;
                    }
                    s.spawn(move || {
                        handle_connection(state, conn);
                        state.active_conns.fetch_sub(1, Ordering::SeqCst);
                    });
                }
            }
            // Idempotent on the shutdown path; on an accept-loop error
            // path it is what lets the batch loop (and scope) exit.
            state.queue.close();
        });
        Ok(())
    }
}

/// A queued reply slot: admin replies are ready immediately, score
/// replies resolve when the batch loop gets to them. Slots flush in
/// request order, so pipelined clients see responses in send order.
enum Reply {
    Ready(String),
    Score(mpsc::Receiver<String>),
}

/// Answer an over-capacity connection with one polite error line and
/// close it. Established connections are never touched by the cap.
fn refuse_connection(mut stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let line = protocol::error_response(
        None,
        "server at connection capacity (--max-conns); retry later",
    );
    let _ = stream.write_all(line.as_bytes());
    let _ = stream.write_all(b"\n");
}

fn handle_connection(state: &ServerState, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(POLL));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let Ok(mut reader) = stream.try_clone() else { return };
    let mut writer = std::io::BufWriter::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut inflight: VecDeque<Reply> = VecDeque::new();
    loop {
        match reader.read(&mut chunk) {
            Ok(0) => break, // client hung up
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                let mut shutdown_after = false;
                // Admit every complete line before writing any reply:
                // a pipelined burst of K score lines lands in the queue
                // together and can drain as one micro-batch.
                while let Some(line) = take_line(&mut buf) {
                    if line.trim().is_empty() {
                        continue;
                    }
                    let (reply, is_shutdown) = process_line(state, &line);
                    inflight.push_back(reply);
                    if is_shutdown {
                        shutdown_after = true;
                        break;
                    }
                }
                if !flush_replies(&mut inflight, &mut writer) || shutdown_after {
                    break;
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if state.shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let _ = writer.flush();
}

/// Split one `\n`-terminated line off the front of `buf` (newline
/// removed, trailing `\r` trimmed). `None` = no complete line yet.
fn take_line(buf: &mut Vec<u8>) -> Option<String> {
    let pos = buf.iter().position(|&b| b == b'\n')?;
    let line: Vec<u8> = buf.drain(..=pos).collect();
    let mut s = String::from_utf8_lossy(&line[..pos]).into_owned();
    if s.ends_with('\r') {
        s.pop();
    }
    Some(s)
}

/// Write queued replies in request order; score slots block until the
/// batch loop answers. `false` = the connection is gone.
fn flush_replies(inflight: &mut VecDeque<Reply>, w: &mut impl std::io::Write) -> bool {
    while let Some(r) = inflight.pop_front() {
        let line = match r {
            Reply::Ready(s) => s,
            Reply::Score(rx) => rx.recv().unwrap_or_else(|_| {
                protocol::error_response(None, "server dropped the query (shutting down)")
            }),
        };
        if w.write_all(line.as_bytes()).is_err() || w.write_all(b"\n").is_err() {
            return false;
        }
    }
    w.flush().is_ok()
}

/// Handle one request line: admin commands answer inline, score
/// requests are admitted to the queue. The bool flags a shutdown
/// command (the connection closes after flushing its reply).
fn process_line(state: &ServerState, line: &str) -> (Reply, bool) {
    let req = match protocol::parse_request(line) {
        Ok(r) => r,
        Err(e) => {
            state.protocol_errors.fetch_add(1, Ordering::Relaxed);
            return (Reply::Ready(protocol::error_response(None, &e)), false);
        }
    };
    match req {
        Request::Score(sr) => {
            let entry = match state.registry.resolve(sr.model.as_deref()) {
                Ok(e) => e,
                Err(e) => {
                    state.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    return (Reply::Ready(protocol::error_response(sr.id, &e)), false);
                }
            };
            let dim = entry.model.dim();
            // Densify at admission: both wire shapes (dense array,
            // sparse 1-based object) become the same dim-length vector,
            // so the batcher tier never sees storage shape.
            let x = match sr.x.densify(dim) {
                Ok(x) => x,
                Err(e) => {
                    state.metrics.with_model(&entry.name, |mm| mm.errors += 1);
                    let msg = format!("{e} but model {:?} expects {dim}", entry.name);
                    return (Reply::Ready(protocol::error_response(sr.id, &msg)), false);
                }
            };
            let (tx, rx) = mpsc::channel();
            let deadline = match state.config.deadline_us {
                0 => None,
                us => Some(Instant::now() + Duration::from_micros(us)),
            };
            let pending = Pending {
                entry,
                x,
                id: sr.id,
                enqueued: Instant::now(),
                deadline,
                reply: tx,
            };
            match state.queue.push(pending) {
                Ok(()) => (Reply::Score(rx), false),
                Err(PushError::Full(p)) => {
                    state.metrics.with_model(&p.entry.name, |mm| mm.shed += 1);
                    (
                        Reply::Ready(protocol::error_response(
                            p.id,
                            "overloaded: admission queue is full (query shed)",
                        )),
                        false,
                    )
                }
                Err(PushError::Closed(p)) => (
                    Reply::Ready(protocol::error_response(p.id, "server is shutting down")),
                    false,
                ),
            }
        }
        Request::Load { name, path } => {
            match state.registry.load_file(&name, Path::new(&path)) {
                Ok(entry) => {
                    let mut s = String::from("{\"ok\":true,\"loaded\":");
                    write_json_string(&mut s, &name);
                    let kind = entry.model.task_name();
                    let (n_sv, dim) = (entry.model.n_sv(), entry.model.dim());
                    s.push_str(&format!(
                        ",\"kind\":\"{kind}\",\"n_sv\":{n_sv},\"dim\":{dim}}}"
                    ));
                    (Reply::Ready(s), false)
                }
                Err(e) => {
                    state.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    let msg = format!("load {name:?}: {e}");
                    (Reply::Ready(protocol::error_response(None, &msg)), false)
                }
            }
        }
        Request::Stats => (Reply::Ready(stats_response(state)), false),
        Request::Models => (Reply::Ready(models_response(state)), false),
        Request::Shutdown => {
            state.shutdown.store(true, Ordering::SeqCst);
            state.queue.close();
            // Wake the blocked accept loop so Server::run can return.
            let _ = TcpStream::connect(state.local_addr);
            (
                Reply::Ready("{\"ok\":true,\"shutting_down\":true}".to_string()),
                true,
            )
        }
    }
}

/// Render the `{"cmd":"stats"}` response: uptime, protocol errors, and
/// the full metrics catalog per registered model.
fn stats_response(state: &ServerState) -> String {
    let snap = state.metrics.snapshot();
    let mut models = BTreeMap::new();
    let (mut shed_total, mut expired_total) = (0u64, 0u64);
    for entry in state.registry.list() {
        let mut o = BTreeMap::new();
        o.insert("kind".to_string(), Json::Str(entry.model.task_name().to_string()));
        o.insert("n_sv".to_string(), Json::Num(entry.model.n_sv() as f64));
        o.insert("dim".to_string(), Json::Num(entry.model.dim() as f64));
        o.insert("healthy".to_string(), Json::Bool(entry.is_healthy()));
        let zero = metrics::ModelMetrics::default();
        let mm = snap.get(&entry.name).unwrap_or(&zero);
        o.insert("requests".to_string(), Json::Num(mm.requests as f64));
        o.insert("errors".to_string(), Json::Num(mm.errors as f64));
        o.insert("shed".to_string(), Json::Num(mm.shed as f64));
        o.insert("expired".to_string(), Json::Num(mm.expired as f64));
        o.insert("batches".to_string(), Json::Num(mm.batches as f64));
        o.insert("mean_batch".to_string(), Json::Num(mm.mean_batch()));
        o.insert("p50_us".to_string(), Json::Num(mm.latency.quantile_us(0.50) as f64));
        o.insert("p99_us".to_string(), Json::Num(mm.latency.quantile_us(0.99) as f64));
        o.insert("kernel_entries".to_string(), Json::Num(mm.kernel_entries as f64));
        shed_total += mm.shed;
        expired_total += mm.expired;
        models.insert(entry.name.clone(), Json::Obj(o));
    }
    let mut top = BTreeMap::new();
    top.insert("ok".to_string(), Json::Bool(true));
    top.insert(
        "uptime_us".to_string(),
        Json::Num(state.started.elapsed().as_micros() as f64),
    );
    top.insert(
        "protocol_errors".to_string(),
        Json::Num(state.protocol_errors.load(Ordering::Relaxed) as f64),
    );
    top.insert("shed".to_string(), Json::Num(shed_total as f64));
    top.insert("expired".to_string(), Json::Num(expired_total as f64));
    top.insert("models".to_string(), Json::Obj(models));
    Json::Obj(top).to_string()
}

/// Render the `{"cmd":"models"}` response: the registry listing.
fn models_response(state: &ServerState) -> String {
    let mut models = BTreeMap::new();
    for entry in state.registry.list() {
        let mut o = BTreeMap::new();
        o.insert("kind".to_string(), Json::Str(entry.model.task_name().to_string()));
        o.insert("n_sv".to_string(), Json::Num(entry.model.n_sv() as f64));
        o.insert("dim".to_string(), Json::Num(entry.model.dim() as f64));
        models.insert(entry.name.clone(), Json::Obj(o));
    }
    let mut top = BTreeMap::new();
    top.insert("ok".to_string(), Json::Bool(true));
    top.insert("models".to_string(), Json::Obj(models));
    Json::Obj(top).to_string()
}

/// Connect, send one request line, read one response line — the
/// one-shot client behind admin calls (stats, load, shutdown), the CI
/// smoke gate, and the bench driver's bookkeeping.
pub fn request_once(addr: SocketAddr, line: &str) -> Result<String> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .context("set read timeout")?;
    stream.write_all(line.as_bytes()).context("send request")?;
    if !line.ends_with('\n') {
        stream.write_all(b"\n").context("send newline")?;
    }
    let mut r = std::io::BufReader::new(stream);
    let mut resp = String::new();
    r.read_line(&mut resp).context("read response")?;
    Ok(resp.trim_end().to_string())
}

/// Open-loop load configuration for [`drive_open_loop`].
#[derive(Debug, Clone)]
pub struct LoadConfig {
    /// Offered arrival rate, queries/second. Open loop: send times are
    /// scheduled up front and never slowed by responses, so queueing
    /// shows up in latency instead of being silently absorbed
    /// (coordinated omission is measured, not hidden).
    pub rate: f64,
    /// Total queries to send.
    pub queries: usize,
    /// Client connections the schedule round-robins over.
    pub conns: usize,
}

/// What an open-loop run measured.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Queries sent.
    pub sent: usize,
    /// `"ok":true` responses received.
    pub ok: usize,
    /// Error responses received (plus dropped connections' shortfall).
    pub errors: usize,
    /// Achieved throughput: responses ÷ (last response − schedule start).
    pub qps: f64,
    /// Median latency, µs, measured from each query's *scheduled* send
    /// time (not the actual write), per open-loop convention.
    pub p50_us: f64,
    /// 99th-percentile latency, µs, same clock.
    pub p99_us: f64,
    /// Wall-clock span of the run, seconds.
    pub wall_s: f64,
}

/// Drive a running server open-loop: `cfg.queries` score requests for
/// `model` (rows cycled from `rows`, row-major with `dim` features) at
/// `cfg.rate` queries/s across `cfg.conns` connections. Per-query
/// latency is measured against the query's scheduled send time.
pub fn drive_open_loop(
    addr: SocketAddr,
    model: Option<&str>,
    dim: usize,
    rows: &[f32],
    cfg: &LoadConfig,
) -> Result<LoadReport> {
    ensure!(dim > 0 && !rows.is_empty() && rows.len() % dim == 0, "rows/dim mismatch");
    ensure!(cfg.rate > 0.0, "rate must be positive");
    ensure!(cfg.queries > 0 && cfg.conns > 0, "queries/conns must be positive");
    let nrows = rows.len() / dim;
    let mut lines: Vec<String> = Vec::with_capacity(cfg.queries);
    for i in 0..cfg.queries {
        use std::fmt::Write as _;
        let mut s = String::from("{\"x\":[");
        let row = &rows[(i % nrows) * dim..(i % nrows + 1) * dim];
        for (k, v) in row.iter().enumerate() {
            if k > 0 {
                s.push(',');
            }
            let _ = write!(s, "{v}");
        }
        let _ = write!(s, "],\"id\":{i}");
        if let Some(m) = model {
            s.push_str(",\"model\":");
            write_json_string(&mut s, m);
        }
        s.push_str("}\n");
        lines.push(s);
    }
    let interval = Duration::from_secs_f64(1.0 / cfg.rate);
    let start = Instant::now() + Duration::from_millis(5);
    let mut latencies: Vec<u64> = Vec::with_capacity(cfg.queries);
    let (mut ok, mut errors) = (0usize, 0usize);
    let mut last_resp = start;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..cfg.conns {
            let lines = &lines;
            handles.push(
                s.spawn(move || conn_worker(addr, lines, c, cfg.conns, start, interval)),
            );
        }
        for h in handles {
            if let Ok((lat, o, e, last)) = h.join() {
                latencies.extend(lat);
                ok += o;
                errors += e;
                if last > last_resp {
                    last_resp = last;
                }
            }
        }
    });
    latencies.sort_unstable();
    let quantile = |q: f64| -> f64 {
        if latencies.is_empty() {
            return 0.0;
        }
        let idx = ((q * latencies.len() as f64).ceil() as usize).clamp(1, latencies.len());
        latencies[idx - 1] as f64
    };
    let wall = last_resp.saturating_duration_since(start).as_secs_f64().max(1e-9);
    Ok(LoadReport {
        sent: cfg.queries,
        ok,
        errors,
        qps: (ok + errors) as f64 / wall,
        p50_us: quantile(0.50),
        p99_us: quantile(0.99),
        wall_s: wall,
    })
}

/// One load-driver connection: a paced writer thread sends this
/// connection's share of the schedule; the reader (this thread)
/// correlates responses by id and measures latency vs scheduled send.
fn conn_worker(
    addr: SocketAddr,
    lines: &[String],
    c: usize,
    conns: usize,
    start: Instant,
    interval: Duration,
) -> (Vec<u64>, usize, usize, Instant) {
    let empty = (Vec::new(), 0, 0, start);
    let Ok(stream) = TcpStream::connect(addr) else { return empty };
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let Ok(write_half) = stream.try_clone() else { return empty };
    let my: Vec<usize> = (c..lines.len()).step_by(conns).collect();
    let expected = my.len();
    std::thread::scope(|s| {
        s.spawn(move || {
            let mut w = write_half;
            for &i in &my {
                let target = start + interval.mul_f64(i as f64);
                let now = Instant::now();
                if target > now {
                    let wait = target - now;
                    if wait > Duration::from_millis(2) {
                        std::thread::sleep(wait - Duration::from_millis(1));
                    }
                    while Instant::now() < target {
                        std::hint::spin_loop();
                    }
                }
                if w.write_all(lines[i].as_bytes()).is_err() {
                    return;
                }
            }
            let _ = w.flush();
        });
        let mut reader = std::io::BufReader::new(&stream);
        let mut lat = Vec::with_capacity(expected);
        let (mut ok, mut err) = (0usize, 0usize);
        let mut last = start;
        let mut line = String::new();
        for _ in 0..expected {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => break,
                Ok(_) => {
                    let now = Instant::now();
                    last = now;
                    if let Some(id) = extract_id(&line) {
                        let sched = start + interval.mul_f64(id as f64);
                        lat.push(now.saturating_duration_since(sched).as_micros() as u64);
                    }
                    if line.contains("\"ok\":true") {
                        ok += 1;
                    } else {
                        err += 1;
                    }
                }
            }
        }
        (lat, ok, err, last)
    })
}

/// Pull the numeric `"id":N` out of a response line without a full JSON
/// parse — the load driver's per-response hot path.
fn extract_id(line: &str) -> Option<u64> {
    let p = line.find("\"id\":")?;
    let rest = &line[p + 5..];
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::chessboard;
    use crate::svm::trainer::Trainer;

    fn tiny_model() -> AnyModel {
        let data = Arc::new(chessboard(80, 4, 1));
        AnyModel::Svc(Trainer::rbf(10.0, 0.5).train(&data).model)
    }

    fn spawn_server(cfg: ServeConfig) -> (std::thread::JoinHandle<()>, SocketAddr) {
        let server = Server::bind(cfg, vec![("m".to_string(), tiny_model())]).unwrap();
        let addr = server.local_addr();
        let handle = std::thread::spawn(move || server.run().unwrap());
        (handle, addr)
    }

    fn tiny_server(max_batch: usize) -> (std::thread::JoinHandle<()>, SocketAddr) {
        spawn_server(ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_batch,
            max_wait_us: 100,
            threads: 1,
            ..ServeConfig::default()
        })
    }

    #[test]
    fn serves_scores_stats_and_shuts_down() {
        let (handle, addr) = tiny_server(8);
        let resp = request_once(addr, r#"{"x":[0.5,0.5],"id":1}"#).unwrap();
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert!(v.get("decision").and_then(Json::as_f64).is_some());

        let stats = request_once(addr, r#"{"cmd":"stats"}"#).unwrap();
        let v = Json::parse(&stats).unwrap();
        let m = v.get("models").and_then(|m| m.get("m")).unwrap();
        assert_eq!(m.get("requests").and_then(Json::as_f64), Some(1.0));
        assert_eq!(m.get("kind").and_then(Json::as_str), Some("svc"));

        let bye = request_once(addr, r#"{"cmd":"shutdown"}"#).unwrap();
        assert!(bye.contains("\"ok\":true"));
        handle.join().unwrap();
    }

    #[test]
    fn sparse_query_lines_score_bit_identically_to_dense_ones() {
        let (handle, addr) = tiny_server(8);
        let dense = request_once(addr, r#"{"x":[0.5,0.0],"id":1}"#).unwrap();
        let sparse = request_once(addr, r#"{"x":{"1":0.5},"id":2}"#).unwrap();
        let d = Json::parse(&dense).unwrap();
        let s = Json::parse(&sparse).unwrap();
        assert_eq!(s.get("ok").and_then(Json::as_bool), Some(true), "{sparse}");
        let dv = d.get("decision").and_then(Json::as_f64).unwrap();
        let sv = s.get("decision").and_then(Json::as_f64).unwrap();
        assert_eq!(dv.to_bits(), sv.to_bits(), "dense {dv} vs sparse {sv}");
        // sparse shape errors are positioned like dense ones
        let err = request_once(addr, r#"{"x":{"9":1},"id":3}"#).unwrap();
        assert!(err.contains("\"ok\":false") && err.contains("expects 2"), "{err}");
        let _ = request_once(addr, r#"{"cmd":"shutdown"}"#).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn malformed_lines_get_errors_and_the_connection_survives() {
        let (handle, addr) = tiny_server(4);
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        stream.write_all(b"this is not json\n").unwrap();
        stream
            .write_all(b"{\"x\":[1.0],\"id\":2}\n{\"x\":[0.1,0.2],\"id\":3}\n")
            .unwrap();
        let mut reader = std::io::BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":false") && line.contains("bad json"), "{line}");
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":false") && line.contains("expects 2"), "{line}");
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true") && line.contains("\"id\":3"), "{line}");
        let _ = request_once(addr, r#"{"cmd":"shutdown"}"#).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn open_loop_driver_reports_throughput() {
        let (handle, addr) = tiny_server(16);
        let queries = chessboard(8, 4, 2);
        let cfg = LoadConfig { rate: 2000.0, queries: 40, conns: 2 };
        let report =
            drive_open_loop(addr, Some("m"), queries.dim(), queries.features(), &cfg)
                .unwrap();
        assert_eq!(report.sent, 40);
        assert_eq!(report.ok, 40, "errors: {}", report.errors);
        assert!(report.qps > 0.0);
        assert!(report.p99_us >= report.p50_us);
        let _ = request_once(addr, r#"{"cmd":"shutdown"}"#).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn expired_queries_get_deadline_exceeded_replies() {
        // A 1 ms deadline against a 100 ms admission window: the lone
        // query always out-waits its deadline inside the window, so the
        // expiry path is exercised deterministically.
        let (handle, addr) = spawn_server(ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_batch: 8,
            max_wait_us: 100_000,
            threads: 1,
            deadline_us: 1_000,
            ..ServeConfig::default()
        });
        let resp = request_once(addr, r#"{"x":[0.5,0.5],"id":9}"#).unwrap();
        assert!(resp.contains("deadline_exceeded"), "{resp}");
        assert!(resp.contains("\"id\":9"), "{resp}");
        let stats = request_once(addr, r#"{"cmd":"stats"}"#).unwrap();
        let v = Json::parse(&stats).unwrap();
        assert_eq!(v.get("expired").and_then(Json::as_f64), Some(1.0), "{stats}");
        let m = v.get("models").and_then(|m| m.get("m")).unwrap();
        assert_eq!(m.get("expired").and_then(Json::as_f64), Some(1.0));
        let _ = request_once(addr, r#"{"cmd":"shutdown"}"#).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn connection_cap_refuses_politely_without_touching_established_conns() {
        let (handle, addr) = spawn_server(ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_batch: 4,
            max_wait_us: 100,
            threads: 1,
            max_conns: 1,
            ..ServeConfig::default()
        });
        // First connection occupies the only slot…
        let mut first = TcpStream::connect(addr).unwrap();
        first.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        first.write_all(b"{\"x\":[0.5,0.5],\"id\":1}\n").unwrap();
        let mut reader = std::io::BufReader::new(first.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "{line}");
        // …so a second one is refused with a single error line, while
        // the first keeps serving.
        let mut second = TcpStream::connect(addr).unwrap();
        second.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut line2 = String::new();
        std::io::BufReader::new(&second)
            .read_line(&mut line2)
            .unwrap();
        assert!(line2.contains("connection capacity"), "{line2}");
        drop(second);
        first.write_all(b"{\"x\":[0.1,0.9],\"id\":2}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true") && line.contains("\"id\":2"), "{line}");
        // Closing the first connection frees the slot for the shutdown
        // client.
        drop(reader);
        drop(first);
        // The slot release races the next accept: retry briefly.
        let mut bye = String::new();
        for _ in 0..100 {
            bye = request_once(addr, r#"{"cmd":"shutdown"}"#).unwrap();
            if bye.contains("shutting_down") {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(bye.contains("shutting_down"), "{bye}");
        handle.join().unwrap();
    }

    #[test]
    fn overfull_queue_sheds_with_an_explicit_reply() {
        // max_queue = 1 with a wide-open admission window: the first
        // query sits undrained in the queue for the whole 100 ms window
        // (next_batch only drains when the window closes), so the rest
        // of the pipelined burst finds the queue at capacity
        // deterministically.
        let (handle, addr) = spawn_server(ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_batch: 2,
            max_wait_us: 100_000,
            threads: 1,
            max_queue: 1,
            ..ServeConfig::default()
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        stream
            .write_all(b"{\"x\":[0.5,0.5],\"id\":1}\n{\"x\":[0.5,0.5],\"id\":2}\n{\"x\":[0.5,0.5],\"id\":3}\n")
            .unwrap();
        let mut reader = std::io::BufReader::new(stream);
        let (mut ok, mut shed) = (0, 0);
        let mut line = String::new();
        for _ in 0..3 {
            line.clear();
            reader.read_line(&mut line).unwrap();
            if line.contains("\"ok\":true") {
                ok += 1;
            } else if line.contains("queue is full") {
                shed += 1;
            }
        }
        assert_eq!(ok, 1, "exactly the first query scores");
        assert_eq!(shed, 2, "the rest of the burst is shed");
        let stats = request_once(addr, r#"{"cmd":"stats"}"#).unwrap();
        let v = Json::parse(&stats).unwrap();
        assert_eq!(
            v.get("shed").and_then(Json::as_f64),
            Some(shed as f64),
            "{stats}"
        );
        let _ = request_once(addr, r#"{"cmd":"shutdown"}"#).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn extract_id_finds_the_correlation_id() {
        assert_eq!(extract_id(r#"{"ok":true,"id":42,"model":"m"}"#), Some(42));
        assert_eq!(extract_id(r#"{"ok":true}"#), None);
    }
}
