//! The admission micro-batcher: connection threads enqueue parsed
//! queries; a single scoring loop drains them in micro-batches and
//! scores each batch in one tiled SV×query pass per model group.
//!
//! Batching policy: the loop blocks for the first query, then holds the
//! admission window open up to `max_wait` µs (or until `max_batch`
//! queries are pending), then drains up to `max_batch`. Because the
//! shared [`Scorer`] accumulates each query's kernel expansion
//! independently in support order, a query's decision value is
//! bit-identical whether it was scored alone, inside any micro-batch,
//! or by offline `pasmo predict` — batching changes throughput, never
//! results.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::svm::schema::AnyModel;
use crate::svm::scorer::{ScoreScratch, Scorer};

use super::metrics::Metrics;
use super::protocol::{self, Outcome};
use super::registry::ModelEntry;

/// One admitted query waiting to be scored.
#[derive(Debug)]
pub struct Pending {
    /// Registry entry captured at admission: the query scores against
    /// this model generation even if the name is hot-swapped before the
    /// batch drains.
    pub entry: Arc<ModelEntry>,
    /// The query row (length validated = entry dim at admission).
    pub x: Vec<f32>,
    /// Client correlation id, echoed in the response.
    pub id: Option<f64>,
    /// Admission timestamp; response latency = scored − enqueued.
    pub enqueued: Instant,
    /// Scoring deadline (`--deadline-us`): a query still queued past
    /// this instant is answered `deadline_exceeded` instead of scored.
    /// `None` = no deadline.
    pub deadline: Option<Instant>,
    /// Where the rendered response line goes; the connection thread
    /// blocks on the paired receiver when it is this reply's turn.
    pub reply: mpsc::Sender<String>,
}

#[derive(Debug)]
struct QueueState {
    items: VecDeque<Pending>,
    closed: bool,
}

/// Why an admission was refused; the query is handed back so the
/// caller can answer it with the matching error response.
#[derive(Debug)]
pub enum PushError {
    /// The queue is at its `--max-queue` bound: shed this query
    /// explicitly rather than letting the backlog grow without limit.
    Full(Pending),
    /// The queue has been closed (shutdown is draining).
    Closed(Pending),
}

/// The shared admission queue (mutex + condvar; std only), bounded at
/// `capacity` waiting queries (0 = unbounded).
#[derive(Debug)]
pub struct BatchQueue {
    state: Mutex<QueueState>,
    ready: Condvar,
    capacity: usize,
}

fn lock(state: &Mutex<QueueState>) -> MutexGuard<'_, QueueState> {
    state.lock().unwrap_or_else(|p| p.into_inner())
}

impl BatchQueue {
    /// An open, empty queue admitting at most `capacity` waiting
    /// queries (0 = unbounded).
    pub fn new(capacity: usize) -> BatchQueue {
        BatchQueue {
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
            capacity,
        }
    }

    /// Enqueue an admitted query. [`PushError::Full`] sheds the query
    /// when the backlog is at capacity; [`PushError::Closed`] hands it
    /// back when shutdown is draining. Either way the caller answers
    /// the client with an explicit error response — admission never
    /// blocks and never silently drops.
    pub fn push(&self, p: Pending) -> Result<(), PushError> {
        let mut st = lock(&self.state);
        if st.closed {
            return Err(PushError::Closed(p));
        }
        if self.capacity > 0 && st.items.len() >= self.capacity {
            return Err(PushError::Full(p));
        }
        st.items.push_back(p);
        self.ready.notify_all();
        Ok(())
    }

    /// Close for new admissions. Already-enqueued queries still drain;
    /// [`BatchQueue::next_batch`] returns empty once they have.
    pub fn close(&self) {
        lock(&self.state).closed = true;
        self.ready.notify_all();
    }

    /// Has [`BatchQueue::close`] been called?
    pub fn is_closed(&self) -> bool {
        lock(&self.state).closed
    }

    /// Block for the next micro-batch, filling `out` (cleared first)
    /// with up to `max_batch` queries. Waits for a first query, then
    /// holds the window open up to `max_wait` for more. An empty `out`
    /// on return means closed **and** fully drained — the batch loop's
    /// exit condition.
    ///
    /// `max_wait` = 0 drains whatever is pending immediately (no window,
    /// and no busy-wait — the zero case never enters the timed loop).
    pub fn next_batch(&self, max_batch: usize, max_wait: Duration, out: &mut Vec<Pending>) {
        let max_batch = max_batch.max(1);
        out.clear();
        let mut st = lock(&self.state);
        while st.items.is_empty() {
            if st.closed {
                return;
            }
            st = self.ready.wait(st).unwrap_or_else(|p| p.into_inner());
        }
        if max_batch > 1 && !max_wait.is_zero() {
            // The wall-clock deadline is the single source of truth for
            // the admission window: after *every* wakeup — a push, a
            // timeout, or a spurious one — the loop re-checks the fill
            // and close conditions and recomputes the time left, rather
            // than trusting the condvar's timed-out flag (which races
            // with concurrent pushes and can fire spuriously).
            let deadline = Instant::now() + max_wait;
            while st.items.len() < max_batch && !st.closed {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                let (guard, _timeout) = self
                    .ready
                    .wait_timeout(st, left)
                    .unwrap_or_else(|p| p.into_inner());
                st = guard;
            }
        }
        let n = st.items.len().min(max_batch);
        out.extend(st.items.drain(..n));
    }
}

impl Default for BatchQueue {
    fn default() -> BatchQueue {
        BatchQueue::new(0)
    }
}

/// Reusable batch-loop buffers. After warm-up, scoring a micro-batch
/// allocates nothing beyond the response strings themselves: the query
/// block, decision buffer, per-machine decisions and the group ordering
/// all reuse capacity across batches.
#[derive(Debug, Default)]
pub struct BatchScratch {
    scratch: ScoreScratch,
    machine_out: Vec<f64>,
    order: Vec<usize>,
}

impl BatchScratch {
    /// Empty scratch; buffers grow to steady state over the first batches.
    pub fn new() -> BatchScratch {
        BatchScratch::default()
    }
}

/// Score one drained micro-batch: group queries by registry entry
/// (pointer identity, so two generations of a hot-swapped name score
/// separately), run one tiled pass per (model × group), send every
/// response, and record metrics per group.
///
/// A panic inside a group's scoring pass is contained here: the
/// offending model generation is quarantined (new requests to it are
/// refused by the registry until a reload), the group's queries get
/// error replies, and the loop moves on to the next group — one bad
/// model never takes the scoring thread (and with it the whole server)
/// down.
pub fn score_batch(batch: &[Pending], metrics: &Metrics, threads: usize, sb: &mut BatchScratch) {
    sb.order.clear();
    sb.order.extend(0..batch.len());
    sb.order.sort_by_key(|&i| Arc::as_ptr(&batch[i].entry) as usize);
    let mut g0 = 0;
    while g0 < sb.order.len() {
        let entry = Arc::clone(&batch[sb.order[g0]].entry);
        let mut g1 = g0 + 1;
        while g1 < sb.order.len() && Arc::ptr_eq(&entry, &batch[sb.order[g1]].entry) {
            g1 += 1;
        }
        // AssertUnwindSafe: on a caught panic the group's replies are
        // answered with errors and the scratch buffers are never read
        // before being reset (score_group begins with scratch.reset and
        // machine_out is resized before use), so no torn state escapes.
        let scored = catch_unwind(AssertUnwindSafe(|| {
            score_group(
                &sb.order[g0..g1],
                batch,
                &entry,
                metrics,
                threads,
                &mut sb.scratch,
                &mut sb.machine_out,
            );
        }));
        if scored.is_err() {
            quarantine_group(&sb.order[g0..g1], batch, &entry, metrics);
        }
        g0 = g1;
    }
}

/// A scoring pass panicked: mark the model generation unhealthy and
/// answer the group's queries with an error reply naming the quarantine.
fn quarantine_group(idxs: &[usize], batch: &[Pending], entry: &ModelEntry, metrics: &Metrics) {
    entry.quarantine();
    let msg = format!(
        "model {:?} quarantined: scoring panicked (reload it to restore)",
        entry.name
    );
    for &i in idxs {
        let _ = batch[i].reply.send(protocol::error_response(batch[i].id, &msg));
    }
    metrics.with_model(&entry.name, |mm| mm.errors += idxs.len() as u64);
}

/// Score the `idxs` members of `batch`, all targeting `entry`.
fn score_group(
    idxs: &[usize],
    batch: &[Pending],
    entry: &ModelEntry,
    metrics: &Metrics,
    threads: usize,
    scratch: &mut ScoreScratch,
    machine_out: &mut Vec<f64>,
) {
    let n = idxs.len();
    crate::faults::maybe_panic("server.score_group");
    crate::faults::maybe_delay("server.score_group");
    scratch.reset(entry.model.dim());
    for &i in idxs {
        scratch.push(&batch[i].x);
    }
    let kernel_entries = match &entry.model {
        AnyModel::Svc(m) => {
            let scorer = Scorer::with_invariants(
                m.kernel,
                &m.support,
                &m.coef,
                m.bias,
                &entry.invariants[0],
            )
            .with_threads(threads)
            .with_f32_sv(entry.f32_sv(0));
            let entries = scorer.kernel_entries_per_pass(n);
            let out = scorer.decision_scratch(scratch);
            for (k, &i) in idxs.iter().enumerate() {
                let d = out[k];
                let outcome = Outcome::Classify {
                    decision: d,
                    prediction: if d >= 0.0 { 1 } else { -1 },
                    probability: m.platt.as_ref().map(|p| p.prob(d)),
                };
                let resp = protocol::score_response(batch[i].id, &entry.name, &outcome);
                let _ = batch[i].reply.send(resp);
            }
            entries
        }
        AnyModel::Svr(m) => {
            let scorer = Scorer::with_invariants(
                m.kernel,
                &m.support,
                &m.coef,
                m.bias,
                &entry.invariants[0],
            )
            .with_threads(threads)
            .with_f32_sv(entry.f32_sv(0));
            let entries = scorer.kernel_entries_per_pass(n);
            let out = scorer.decision_scratch(scratch);
            for (k, &i) in idxs.iter().enumerate() {
                let outcome = Outcome::Regress { prediction: out[k] };
                let resp = protocol::score_response(batch[i].id, &entry.name, &outcome);
                let _ = batch[i].reply.send(resp);
            }
            entries
        }
        AnyModel::OneClass(m) => {
            let scorer = Scorer::with_invariants(
                m.kernel,
                &m.support,
                &m.coef,
                -m.rho,
                &entry.invariants[0],
            )
            .with_threads(threads)
            .with_f32_sv(entry.f32_sv(0));
            let entries = scorer.kernel_entries_per_pass(n);
            let out = scorer.decision_scratch(scratch);
            for (k, &i) in idxs.iter().enumerate() {
                let d = out[k];
                let outcome = Outcome::OneClass {
                    decision: d,
                    prediction: if d >= 0.0 { 1 } else { -1 },
                };
                let resp = protocol::score_response(batch[i].id, &entry.name, &outcome);
                let _ = batch[i].reply.send(resp);
            }
            entries
        }
        AnyModel::Multiclass(m) => {
            let n_machines = m.machines.len();
            machine_out.clear();
            machine_out.resize(n_machines * n, 0.0);
            let mut entries = 0u64;
            for (j, mach) in m.machines.iter().enumerate() {
                let scorer = Scorer::with_invariants(
                    mach.kernel,
                    &mach.support,
                    &mach.coef,
                    mach.bias,
                    &entry.invariants[j],
                )
                .with_threads(threads)
                .with_f32_sv(entry.f32_sv(j));
                entries += scorer.kernel_entries_per_pass(n);
                let out = scorer.decision_scratch(scratch);
                machine_out[j * n..(j + 1) * n].copy_from_slice(out);
            }
            for (k, &i) in idxs.iter().enumerate() {
                let class = m.vote_decisions(|j| machine_out[j * n + k]);
                let outcome = Outcome::Multiclass { prediction: class };
                let resp = protocol::score_response(batch[i].id, &entry.name, &outcome);
                let _ = batch[i].reply.send(resp);
            }
            entries
        }
    };
    metrics.with_model(&entry.name, |mm| {
        mm.requests += n as u64;
        mm.batches += 1;
        mm.kernel_entries += kernel_entries;
        for &i in idxs {
            mm.latency.record(batch[i].enqueued.elapsed().as_micros() as u64);
        }
    });
}

/// Answer and drop queries whose deadline passed while they waited in
/// the admission queue: each gets a `deadline_exceeded` error reply and
/// never reaches a scorer — spending a kernel pass on an answer the
/// client has already given up on only deepens an overload.
fn expire_overdue(batch: &mut Vec<Pending>, metrics: &Metrics) {
    let now = Instant::now();
    batch.retain(|p| {
        let expired = matches!(p.deadline, Some(d) if now >= d);
        if expired {
            metrics.with_model(&p.entry.name, |mm| mm.expired += 1);
            let _ = p.reply.send(protocol::error_response(
                p.id,
                "deadline_exceeded: query expired in the admission queue",
            ));
        }
        !expired
    });
}

/// The scoring loop: drain micro-batches until the queue is closed and
/// empty, expiring overdue queries before each scoring pass. Run on one
/// dedicated thread per server.
pub fn run_batch_loop(
    queue: &BatchQueue,
    metrics: &Metrics,
    max_batch: usize,
    max_wait: Duration,
    threads: usize,
) {
    let mut sb = BatchScratch::new();
    let mut batch: Vec<Pending> = Vec::new();
    loop {
        queue.next_batch(max_batch, max_wait, &mut batch);
        if batch.is_empty() {
            return;
        }
        expire_overdue(&mut batch, metrics);
        score_batch(&batch, metrics, threads, &mut sb);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::chessboard;
    use crate::svm::trainer::Trainer;
    use crate::util::json::Json;

    fn entry() -> (Arc<ModelEntry>, crate::data::dataset::Dataset) {
        let data = Arc::new(chessboard(80, 4, 1));
        let model = Trainer::rbf(10.0, 0.5).train(&data).model;
        let e = ModelEntry::new("m".to_string(), AnyModel::Svc(model));
        (Arc::new(e), chessboard(16, 4, 2))
    }

    fn pend(
        entry: &Arc<ModelEntry>,
        x: &[f32],
        id: f64,
    ) -> (Pending, mpsc::Receiver<String>) {
        let (tx, rx) = mpsc::channel();
        let p = Pending {
            entry: Arc::clone(entry),
            x: x.to_vec(),
            id: Some(id),
            enqueued: Instant::now(),
            deadline: None,
            reply: tx,
        };
        (p, rx)
    }

    #[test]
    fn batched_decisions_bit_match_the_offline_scorer() {
        let (entry, queries) = entry();
        let metrics = Metrics::new();
        let mut sb = BatchScratch::new();
        let mut batch = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..queries.len() {
            let (p, rx) = pend(&entry, queries.row(i), i as f64);
            batch.push(p);
            rxs.push(rx);
        }
        score_batch(&batch, &metrics, 1, &mut sb);
        let AnyModel::Svc(m) = &entry.model else { unreachable!() };
        for (i, rx) in rxs.iter().enumerate() {
            let resp = rx.recv().unwrap();
            let v = Json::parse(&resp).unwrap();
            let got = v.get("decision").and_then(Json::as_f64).unwrap();
            let want = m.decision(queries.row(i));
            assert_eq!(got.to_bits(), want.to_bits(), "query {i}");
            assert_eq!(v.get("id").and_then(Json::as_f64), Some(i as f64));
        }
        let snap = metrics.snapshot();
        let mm = snap.get("m").unwrap();
        assert_eq!((mm.requests, mm.batches), (queries.len() as u64, 1));
        assert_eq!(mm.kernel_entries, (queries.len() * m.n_sv()) as u64);
        assert_eq!(mm.latency.count(), queries.len() as u64);
    }

    #[test]
    fn mixed_model_batches_group_by_entry() {
        let (a, queries) = entry();
        let (b, _) = entry();
        let metrics = Metrics::new();
        let mut sb = BatchScratch::new();
        let mut batch = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..6 {
            let e = if i % 2 == 0 { &a } else { &b };
            let (p, rx) = pend(e, queries.row(i), i as f64);
            batch.push(p);
            rxs.push(rx);
        }
        score_batch(&batch, &metrics, 1, &mut sb);
        for rx in &rxs {
            assert!(rx.recv().unwrap().contains("\"ok\":true"));
        }
        // both entries share the name "m": 6 requests over 2 group passes
        let snap = metrics.snapshot();
        let mm = snap.get("m").unwrap();
        assert_eq!((mm.requests, mm.batches), (6, 2));
    }

    #[test]
    fn queue_drains_after_close_then_reports_empty() {
        let q = BatchQueue::new(0);
        let (entry, queries) = entry();
        let (p1, _rx1) = pend(&entry, queries.row(0), 0.0);
        let (p2, _rx2) = pend(&entry, queries.row(1), 1.0);
        assert!(q.push(p1).is_ok());
        assert!(q.push(p2).is_ok());
        q.close();
        assert!(q.is_closed());
        let (p3, _rx3) = pend(&entry, queries.row(2), 2.0);
        assert!(q.push(p3).is_err(), "closed queue must refuse new work");
        let mut out = Vec::new();
        q.next_batch(10, Duration::from_micros(50), &mut out);
        assert_eq!(out.len(), 2, "drains the backlog");
        q.next_batch(10, Duration::from_micros(50), &mut out);
        assert!(out.is_empty(), "then reports drained");
    }

    #[test]
    fn next_batch_caps_at_max_batch() {
        let q = BatchQueue::new(0);
        let (entry, queries) = entry();
        let mut rxs = Vec::new();
        for i in 0..5 {
            let (p, rx) = pend(&entry, queries.row(i), i as f64);
            assert!(q.push(p).is_ok());
            rxs.push(rx);
        }
        let mut out = Vec::new();
        q.next_batch(3, Duration::from_micros(1), &mut out);
        assert_eq!(out.len(), 3);
        q.next_batch(3, Duration::from_micros(1), &mut out);
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn bounded_queue_sheds_at_capacity_and_recovers_after_drain() {
        let q = BatchQueue::new(2);
        let (entry, queries) = entry();
        let (p1, _r1) = pend(&entry, queries.row(0), 0.0);
        let (p2, _r2) = pend(&entry, queries.row(1), 1.0);
        let (p3, _r3) = pend(&entry, queries.row(2), 2.0);
        assert!(q.push(p1).is_ok());
        assert!(q.push(p2).is_ok());
        match q.push(p3) {
            Err(PushError::Full(p)) => assert_eq!(p.id, Some(2.0)),
            other => panic!("expected Full, got {other:?}"),
        }
        let mut out = Vec::new();
        q.next_batch(10, Duration::ZERO, &mut out);
        assert_eq!(out.len(), 2);
        let (p4, _r4) = pend(&entry, queries.row(3), 3.0);
        assert!(q.push(p4).is_ok(), "drained queue admits again");
    }

    #[test]
    fn window_survives_early_wakeups_and_collects_the_late_arrival() {
        // Regression: a condvar wakeup that neither fills the batch nor
        // exhausts the window (a push below max_batch, or a spurious
        // wake) must keep the window open — the loop re-checks the
        // drain condition against the wall-clock deadline.
        let q = Arc::new(BatchQueue::new(0));
        let (entry, queries) = entry();
        let (p1, _r1) = pend(&entry, queries.row(0), 0.0);
        assert!(q.push(p1).is_ok());
        let q2 = Arc::clone(&q);
        let (p2, _r2) = pend(&entry, queries.row(1), 1.0);
        let pusher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            assert!(q2.push(p2).is_ok());
        });
        let mut out = Vec::new();
        // max_batch 3 > 2 pushes: the second push wakes the window but
        // does not fill it, so the loop must keep waiting (not break)
        // and return both items when the deadline lapses.
        q.next_batch(3, Duration::from_millis(300), &mut out);
        pusher.join().unwrap();
        assert_eq!(out.len(), 2, "late arrival joined the open window");
    }

    #[test]
    fn zero_wait_drains_immediately_without_spinning() {
        let q = BatchQueue::new(0);
        let (entry, queries) = entry();
        let (p1, _r1) = pend(&entry, queries.row(0), 0.0);
        assert!(q.push(p1).is_ok());
        let started = Instant::now();
        let mut out = Vec::new();
        q.next_batch(8, Duration::ZERO, &mut out);
        assert_eq!(out.len(), 1);
        // No admission window at --max-wait-us 0: the call returns as
        // soon as the pending item is drained.
        assert!(started.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn overdue_queries_get_deadline_exceeded_and_skip_scoring() {
        let (entry, queries) = entry();
        let metrics = Metrics::new();
        let (mut expired, rx_expired) = pend(&entry, queries.row(0), 0.0);
        expired.deadline = Some(Instant::now() - Duration::from_millis(1));
        let (mut live, _rx_live) = pend(&entry, queries.row(1), 1.0);
        live.deadline = Some(Instant::now() + Duration::from_secs(60));
        let mut batch = vec![expired, live];
        expire_overdue(&mut batch, &metrics);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, Some(1.0));
        let reply = rx_expired.recv().unwrap();
        assert!(reply.contains("deadline_exceeded"), "{reply}");
        assert!(reply.contains("\"ok\":false"), "{reply}");
        assert_eq!(metrics.snapshot().get("m").unwrap().expired, 1);
    }

}
