//! The model registry: named models behind an `RwLock`, hot-swappable.
//!
//! Each entry pairs a loaded [`AnyModel`] with its precomputed
//! [`SupportInvariants`] (squared SV norms for RBF, the collapsed
//! weight vector for linear) so the batch loop constructs scorers via
//! [`Scorer::with_invariants`](crate::svm::scorer::Scorer::with_invariants)
//! without touching the allocator. Entries are `Arc`-shared: a score
//! request captures its entry at admission, so a concurrent hot-swap
//! (`{"cmd":"load"}`) never changes which model generation scores an
//! already-admitted query.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};

use crate::svm::schema::{load_any, AnyModel};
use crate::svm::scorer::SupportInvariants;
use crate::util::error::Result;

/// A registered model plus the support-side invariants its scorers
/// borrow.
#[derive(Debug)]
pub struct ModelEntry {
    /// The name this model is registered under.
    pub name: String,
    /// The model itself.
    pub model: AnyModel,
    /// Precomputed support invariants, one per underlying machine:
    /// a single entry for svc/svr/oneclass, one per pairwise machine
    /// (aligned with `OvoModel::machines`) for multiclass.
    pub invariants: Vec<SupportInvariants>,
    /// Health flag: cleared when a scoring pass over this entry
    /// panics. Unhealthy entries are refused by [`Registry::resolve`]
    /// until the name is reloaded (a reload installs a fresh, healthy
    /// entry).
    healthy: AtomicBool,
}

impl ModelEntry {
    /// Wrap a model, precomputing the scoring invariants once.
    pub fn new(name: String, model: AnyModel) -> ModelEntry {
        let invariants = match &model {
            AnyModel::Svc(m) => {
                vec![SupportInvariants::compute(m.kernel, &m.support, &m.coef)]
            }
            AnyModel::Svr(m) => {
                vec![SupportInvariants::compute(m.kernel, &m.support, &m.coef)]
            }
            AnyModel::OneClass(m) => {
                vec![SupportInvariants::compute(m.kernel, &m.support, &m.coef)]
            }
            AnyModel::Multiclass(m) => m
                .machines
                .iter()
                .map(|b| SupportInvariants::compute(b.kernel, &b.support, &b.coef))
                .collect(),
        };
        ModelEntry { name, model, invariants, healthy: AtomicBool::new(true) }
    }

    /// Is this entry still serving? (Cleared by [`ModelEntry::quarantine`].)
    pub fn is_healthy(&self) -> bool {
        self.healthy.load(Ordering::SeqCst)
    }

    /// Mark this entry unhealthy after a scoring fault: queries that
    /// already captured the `Arc` get error replies, and
    /// [`Registry::resolve`] refuses new ones until a reload replaces
    /// the entry.
    pub fn quarantine(&self) {
        self.healthy.store(false, Ordering::SeqCst);
    }
}

/// Name → model map. Reads (every score request resolves its model)
/// take the shared lock; writes happen only on `{"cmd":"load"}`.
#[derive(Debug)]
pub struct Registry {
    models: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
}

impl Registry {
    /// Build a registry preloaded with `(name, model)` pairs.
    pub fn new(initial: Vec<(String, AnyModel)>) -> Registry {
        let mut map = BTreeMap::new();
        for (name, model) in initial {
            map.insert(name.clone(), Arc::new(ModelEntry::new(name, model)));
        }
        Registry { models: RwLock::new(map) }
    }

    /// Look up a model by name.
    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.read_map(|map| map.get(name).cloned())
    }

    /// Resolve the model a score request targets. `None` is accepted
    /// only while exactly one model is loaded (the single-model fast
    /// path); quarantined entries are refused until reloaded. The error
    /// strings are client-facing.
    pub fn resolve(&self, name: Option<&str>) -> std::result::Result<Arc<ModelEntry>, String> {
        let entry = self.read_map(|map| match name {
            Some(n) => map
                .get(n)
                .cloned()
                .ok_or_else(|| format!("unknown model {n:?}")),
            None if map.len() == 1 => map
                .values()
                .next()
                .cloned()
                .ok_or_else(|| "no models loaded".to_string()),
            None if map.is_empty() => Err("no models loaded".to_string()),
            None => Err(format!(
                "{} models loaded; the request must name one (\"model\": ...)",
                map.len()
            )),
        })?;
        if !entry.is_healthy() {
            return Err(format!(
                "model {:?} is quarantined after a scoring fault; reload it \
                 ({{\"cmd\":\"load\"}}) to restore",
                entry.name
            ));
        }
        Ok(entry)
    }

    /// Register (or hot-swap) `model` under `name`. Queries admitted
    /// against the old generation still score against it; new requests
    /// resolve to the replacement.
    pub fn insert(&self, name: &str, model: AnyModel) -> Arc<ModelEntry> {
        let entry = Arc::new(ModelEntry::new(name.to_string(), model));
        let mut map = self.models.write().unwrap_or_else(|p| p.into_inner());
        map.insert(name.to_string(), Arc::clone(&entry));
        entry
    }

    /// Load a model file (any schema kind) and register it under
    /// `name`, replacing a same-named entry if present.
    pub fn load_file(&self, name: &str, path: &Path) -> Result<Arc<ModelEntry>> {
        let model = load_any(path)?;
        Ok(self.insert(name, model))
    }

    /// All entries, in name order.
    pub fn list(&self) -> Vec<Arc<ModelEntry>> {
        self.read_map(|map| map.values().cloned().collect())
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.read_map(BTreeMap::len)
    }

    /// Whether the registry is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn read_map<T>(&self, f: impl FnOnce(&BTreeMap<String, Arc<ModelEntry>>) -> T) -> T {
        let map = self.models.read().unwrap_or_else(|p| p.into_inner());
        f(&map)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::chessboard;
    use crate::svm::trainer::Trainer;

    fn tiny_model() -> AnyModel {
        let data = std::sync::Arc::new(chessboard(60, 4, 1));
        AnyModel::Svc(Trainer::rbf(10.0, 0.5).train(&data).model)
    }

    #[test]
    fn resolve_falls_back_to_the_single_model() {
        let reg = Registry::new(vec![("only".to_string(), tiny_model())]);
        assert_eq!(reg.resolve(None).unwrap().name, "only");
        assert_eq!(reg.resolve(Some("only")).unwrap().name, "only");
        assert!(reg.resolve(Some("nope")).unwrap_err().contains("unknown model"));

        reg.insert("second", tiny_model());
        assert_eq!(reg.len(), 2);
        let err = reg.resolve(None).unwrap_err();
        assert!(err.contains("must name one"), "{err}");
    }

    #[test]
    fn hot_swap_replaces_the_entry_but_not_held_arcs() {
        let reg = Registry::new(vec![("m".to_string(), tiny_model())]);
        let before = reg.resolve(Some("m")).unwrap();
        let after = reg.insert("m", tiny_model());
        assert!(!Arc::ptr_eq(&before, &after));
        assert!(Arc::ptr_eq(&reg.resolve(Some("m")).unwrap(), &after));
        // the captured generation still scores: its invariants line up
        assert_eq!(before.invariants.len(), 1);
    }

    #[test]
    fn quarantined_entries_are_refused_until_reload() {
        let reg = Registry::new(vec![("m".to_string(), tiny_model())]);
        let entry = reg.resolve(Some("m")).unwrap();
        assert!(entry.is_healthy());
        entry.quarantine();
        let err = reg.resolve(Some("m")).unwrap_err();
        assert!(err.contains("quarantined"), "{err}");
        // the single-model fallback path refuses it too
        assert!(reg.resolve(None).unwrap_err().contains("quarantined"));
        // a hot-swap installs a fresh, healthy generation
        reg.insert("m", tiny_model());
        assert!(reg.resolve(Some("m")).is_ok());
    }

    #[test]
    fn entries_precompute_one_invariant_per_machine() {
        let entry = ModelEntry::new("m".to_string(), tiny_model());
        assert_eq!(entry.invariants.len(), 1);
        let blobs = crate::svm::multiclass::blobs(90, 3, 4.0, 0.5, 1);
        let ovo = crate::svm::multiclass::train_ovo(&blobs, &Trainer::rbf(10.0, 0.5));
        let n_machines = ovo.machines.len();
        let entry = ModelEntry::new("ovo".to_string(), AnyModel::Multiclass(ovo));
        assert_eq!(entry.invariants.len(), n_machines);
    }
}
