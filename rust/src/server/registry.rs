//! The model registry: named models behind an `RwLock`, hot-swappable.
//!
//! Each entry pairs a loaded [`AnyModel`] with its precomputed
//! [`SupportInvariants`] (squared SV norms for RBF, the collapsed
//! weight vector for linear) so the batch loop constructs scorers via
//! [`Scorer::with_invariants`](crate::svm::scorer::Scorer::with_invariants)
//! without touching the allocator. Entries are `Arc`-shared: a score
//! request captures its entry at admission, so a concurrent hot-swap
//! (`{"cmd":"load"}`) never changes which model generation scores an
//! already-admitted query.
//!
//! Two load-time optimizations live here rather than in the scorer:
//!
//! - **Invariant reuse on bit-identical hot-swap.** Reloading a model
//!   file that expands to the same machines (kernel parameters, support
//!   storage, and coefficients all bit-equal) shares the previous
//!   generation's invariants through an `Arc` instead of recomputing
//!   `O(n_sv * d)` squared norms per machine. Observable through
//!   [`ModelEntry::reused_invariants`]; quarantined generations never
//!   donate.
//! - **The packed-f32 admission gate.** When the registry is built with
//!   the fast path requested ([`Registry::new_with`], `pasmo serve
//!   --f32-sv`), each machine is scored over its own support set both
//!   ways at load time and the `Scorer::with_f32_sv` path is enabled
//!   only where the worst decision delta stays under
//!   [`F32_SV_TOL_SCALE`] of the expansion's natural scale.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};

use crate::data::dataset::Dataset;
use crate::kernel::function::KernelFunction;
use crate::svm::schema::{load_any, AnyModel};
use crate::svm::scorer::{Scorer, SupportInvariants};
use crate::util::error::Result;

/// Relative accuracy budget for the packed-f32 admission gate: the
/// worst decision delta over the machine's own support set must stay
/// below this fraction of `1 + |offset| + sum |coef_i|`.
pub const F32_SV_TOL_SCALE: f64 = 1e-4;

/// A registered model plus the support-side invariants its scorers
/// borrow.
#[derive(Debug)]
pub struct ModelEntry {
    /// The name this model is registered under.
    pub name: String,
    /// The model itself.
    pub model: AnyModel,
    /// Precomputed support invariants, one per underlying machine:
    /// a single entry for svc/svr/oneclass, one per pairwise machine
    /// (aligned with `OvoModel::machines`) for multiclass. Behind an
    /// `Arc` so a bit-identical hot-swap shares rather than recomputes
    /// them.
    pub invariants: Arc<Vec<SupportInvariants>>,
    /// Per-machine packed-f32 verdicts (aligned with `invariants`);
    /// all-false unless the registry requested the fast path.
    f32_flags: Vec<bool>,
    /// Did this generation inherit its invariants from the entry it
    /// replaced?
    reused: bool,
    /// Health flag: cleared when a scoring pass over this entry
    /// panics. Unhealthy entries are refused by [`Registry::resolve`]
    /// until the name is reloaded (a reload installs a fresh, healthy
    /// entry).
    healthy: AtomicBool,
}

/// Flatten a model into its scoring machines: `(kernel, support, coef,
/// offset)` per machine, aligned with the entry's invariants.
fn machine_expansions(model: &AnyModel) -> Vec<(KernelFunction, &Dataset, &[f64], f64)> {
    match model {
        AnyModel::Svc(m) => vec![(m.kernel, &m.support, &m.coef[..], m.bias)],
        AnyModel::Svr(m) => vec![(m.kernel, &m.support, &m.coef[..], m.bias)],
        AnyModel::OneClass(m) => vec![(m.kernel, &m.support, &m.coef[..], -m.rho)],
        AnyModel::Multiclass(m) => m
            .machines
            .iter()
            .map(|b| (b.kernel, &b.support, &b.coef[..], b.bias))
            .collect(),
    }
}

/// Bit-level kernel equality: every parameter compared through
/// `to_bits`, so NaN parameters never alias a reuse.
fn same_kernel(a: KernelFunction, b: KernelFunction) -> bool {
    use KernelFunction::{Linear, Poly, Rbf, Sigmoid};
    match (a, b) {
        (Linear, Linear) => true,
        (Rbf { gamma: ga }, Rbf { gamma: gb }) => ga.to_bits() == gb.to_bits(),
        (
            Poly { gamma: ga, coef0: ca, degree: da },
            Poly { gamma: gb, coef0: cb, degree: db },
        ) => ga.to_bits() == gb.to_bits() && ca.to_bits() == cb.to_bits() && da == db,
        (Sigmoid { gamma: ga, coef0: ca }, Sigmoid { gamma: gb, coef0: cb }) => {
            ga.to_bits() == gb.to_bits() && ca.to_bits() == cb.to_bits()
        }
        _ => false,
    }
}

/// Is `b` the same expansion as `a` for invariant purposes? The offset
/// is deliberately ignored — it never enters `SupportInvariants`. The
/// storage comparison requires the same backend (a dense reload of a
/// sparse model recomputes — conservative, never wrong).
fn same_expansion(
    a: &(KernelFunction, &Dataset, &[f64], f64),
    b: &(KernelFunction, &Dataset, &[f64], f64),
) -> bool {
    same_kernel(a.0, b.0)
        && a.2.len() == b.2.len()
        && a.2.iter().zip(b.2.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
        && a.1.storage() == b.1.storage()
}

/// Admission gate for the packed-f32 SV fast path: score the machine's
/// own support set through both tiles and require the worst decision
/// delta to stay within [`F32_SV_TOL_SCALE`] of the expansion's natural
/// scale.
fn f32_gate(kernel: KernelFunction, support: &Dataset, coef: &[f64], offset: f64) -> bool {
    let mass: f64 = coef.iter().map(|c| c.abs()).sum();
    let delta = Scorer::new(kernel, support, coef, offset).f32_sv_max_delta();
    delta <= F32_SV_TOL_SCALE * (1.0 + offset.abs() + mass)
}

impl ModelEntry {
    /// Wrap a model, precomputing the scoring invariants once. The
    /// packed-f32 fast path stays off; registries request it through
    /// [`Registry::new_with`].
    pub fn new(name: String, model: AnyModel) -> ModelEntry {
        ModelEntry::build(name, model, false, None)
    }

    /// Build an entry, reusing `prev`'s invariants when the new model
    /// expands bit-identically, and running the packed-f32 admission
    /// gate per machine when `f32_sv` is requested.
    fn build(name: String, model: AnyModel, f32_sv: bool, prev: Option<&ModelEntry>) -> ModelEntry {
        let reuse = prev.filter(|p| {
            p.is_healthy() && {
                let pm = machine_expansions(&p.model);
                let nm = machine_expansions(&model);
                pm.len() == nm.len() && pm.iter().zip(&nm).all(|(a, b)| same_expansion(a, b))
            }
        });
        match reuse {
            Some(p) => {
                let invariants = Arc::clone(&p.invariants);
                let f32_flags = p.f32_flags.clone();
                ModelEntry {
                    name,
                    model,
                    invariants,
                    f32_flags,
                    reused: true,
                    healthy: AtomicBool::new(true),
                }
            }
            None => {
                let machines = machine_expansions(&model);
                let invariants: Vec<SupportInvariants> = machines
                    .iter()
                    .map(|(k, s, c, _)| SupportInvariants::compute(*k, s, c))
                    .collect();
                let f32_flags: Vec<bool> = if f32_sv {
                    machines.iter().map(|(k, s, c, o)| f32_gate(*k, s, c, *o)).collect()
                } else {
                    vec![false; machines.len()]
                };
                drop(machines);
                ModelEntry {
                    name,
                    model,
                    invariants: Arc::new(invariants),
                    f32_flags,
                    reused: false,
                    healthy: AtomicBool::new(true),
                }
            }
        }
    }

    /// Did this generation inherit the previous generation's invariants
    /// because the hot-swap installed a bit-identical expansion?
    pub fn reused_invariants(&self) -> bool {
        self.reused
    }

    /// Whether machine `j` passed the packed-f32 admission gate (always
    /// `false` unless the registry requested the fast path, or for
    /// out-of-range `j`).
    pub fn f32_sv(&self, j: usize) -> bool {
        self.f32_flags.get(j).copied().unwrap_or(false)
    }

    /// Is this entry still serving? (Cleared by [`ModelEntry::quarantine`].)
    pub fn is_healthy(&self) -> bool {
        self.healthy.load(Ordering::SeqCst)
    }

    /// Mark this entry unhealthy after a scoring fault: queries that
    /// already captured the `Arc` get error replies, and
    /// [`Registry::resolve`] refuses new ones until a reload replaces
    /// the entry.
    pub fn quarantine(&self) {
        self.healthy.store(false, Ordering::SeqCst);
    }
}

/// Name → model map. Reads (every score request resolves its model)
/// take the shared lock; writes happen only on `{"cmd":"load"}`.
#[derive(Debug)]
pub struct Registry {
    models: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
    f32_sv: bool,
}

impl Registry {
    /// Build a registry preloaded with `(name, model)` pairs. The
    /// packed-f32 fast path stays off; see [`Registry::new_with`].
    pub fn new(initial: Vec<(String, AnyModel)>) -> Registry {
        Registry::new_with(initial, false)
    }

    /// Build a registry, optionally requesting the packed-f32 SV fast
    /// path: every machine loaded into this registry (now or via
    /// hot-swap) is then run through the accuracy gate and scores with
    /// `Scorer::with_f32_sv` only where it passes.
    pub fn new_with(initial: Vec<(String, AnyModel)>, f32_sv: bool) -> Registry {
        let reg = Registry { models: RwLock::new(BTreeMap::new()), f32_sv };
        for (name, model) in initial {
            reg.insert(&name, model);
        }
        reg
    }

    /// Look up a model by name.
    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.read_map(|map| map.get(name).cloned())
    }

    /// Resolve the model a score request targets. `None` is accepted
    /// only while exactly one model is loaded (the single-model fast
    /// path); quarantined entries are refused until reloaded. The error
    /// strings are client-facing.
    pub fn resolve(&self, name: Option<&str>) -> std::result::Result<Arc<ModelEntry>, String> {
        let entry = self.read_map(|map| match name {
            Some(n) => map
                .get(n)
                .cloned()
                .ok_or_else(|| format!("unknown model {n:?}")),
            None if map.len() == 1 => map
                .values()
                .next()
                .cloned()
                .ok_or_else(|| "no models loaded".to_string()),
            None if map.is_empty() => Err("no models loaded".to_string()),
            None => Err(format!(
                "{} models loaded; the request must name one (\"model\": ...)",
                map.len()
            )),
        })?;
        if !entry.is_healthy() {
            return Err(format!(
                "model {:?} is quarantined after a scoring fault; reload it \
                 ({{\"cmd\":\"load\"}}) to restore",
                entry.name
            ));
        }
        Ok(entry)
    }

    /// Register (or hot-swap) `model` under `name`. Queries admitted
    /// against the old generation still score against it; new requests
    /// resolve to the replacement. A bit-identical swap shares the old
    /// generation's invariants ([`ModelEntry::reused_invariants`]).
    pub fn insert(&self, name: &str, model: AnyModel) -> Arc<ModelEntry> {
        let prev = self.get(name);
        let entry = Arc::new(ModelEntry::build(
            name.to_string(),
            model,
            self.f32_sv,
            prev.as_deref(),
        ));
        let mut map = self.models.write().unwrap_or_else(|p| p.into_inner());
        map.insert(name.to_string(), Arc::clone(&entry));
        entry
    }

    /// Load a model file (any schema kind) and register it under
    /// `name`, replacing a same-named entry if present.
    pub fn load_file(&self, name: &str, path: &Path) -> Result<Arc<ModelEntry>> {
        let model = load_any(path)?;
        Ok(self.insert(name, model))
    }

    /// All entries, in name order.
    pub fn list(&self) -> Vec<Arc<ModelEntry>> {
        self.read_map(|map| map.values().cloned().collect())
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.read_map(BTreeMap::len)
    }

    /// Whether the registry is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn read_map<T>(&self, f: impl FnOnce(&BTreeMap<String, Arc<ModelEntry>>) -> T) -> T {
        let map = self.models.read().unwrap_or_else(|p| p.into_inner());
        f(&map)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::chessboard;
    use crate::svm::trainer::Trainer;

    fn tiny_model() -> AnyModel {
        let data = std::sync::Arc::new(chessboard(60, 4, 1));
        AnyModel::Svc(Trainer::rbf(10.0, 0.5).train(&data).model)
    }

    #[test]
    fn resolve_falls_back_to_the_single_model() {
        let reg = Registry::new(vec![("only".to_string(), tiny_model())]);
        assert_eq!(reg.resolve(None).unwrap().name, "only");
        assert_eq!(reg.resolve(Some("only")).unwrap().name, "only");
        assert!(reg.resolve(Some("nope")).unwrap_err().contains("unknown model"));

        reg.insert("second", tiny_model());
        assert_eq!(reg.len(), 2);
        let err = reg.resolve(None).unwrap_err();
        assert!(err.contains("must name one"), "{err}");
    }

    #[test]
    fn hot_swap_replaces_the_entry_but_not_held_arcs() {
        let reg = Registry::new(vec![("m".to_string(), tiny_model())]);
        let before = reg.resolve(Some("m")).unwrap();
        let after = reg.insert("m", tiny_model());
        assert!(!Arc::ptr_eq(&before, &after));
        assert!(Arc::ptr_eq(&reg.resolve(Some("m")).unwrap(), &after));
        // the captured generation still scores: its invariants line up
        assert_eq!(before.invariants.len(), 1);
    }

    #[test]
    fn quarantined_entries_are_refused_until_reload() {
        let reg = Registry::new(vec![("m".to_string(), tiny_model())]);
        let entry = reg.resolve(Some("m")).unwrap();
        assert!(entry.is_healthy());
        entry.quarantine();
        let err = reg.resolve(Some("m")).unwrap_err();
        assert!(err.contains("quarantined"), "{err}");
        // the single-model fallback path refuses it too
        assert!(reg.resolve(None).unwrap_err().contains("quarantined"));
        // a hot-swap installs a fresh, healthy generation
        reg.insert("m", tiny_model());
        assert!(reg.resolve(Some("m")).is_ok());
    }

    #[test]
    fn bit_identical_hot_swap_reuses_invariants() {
        let reg = Registry::new(vec![("m".to_string(), tiny_model())]);
        let first = reg.resolve(Some("m")).unwrap();
        assert!(!first.reused_invariants(), "a cold load computes its own invariants");

        // training is deterministic, so a second tiny_model() expands
        // bit-identically and the swap shares the invariant Arc
        let again = reg.insert("m", tiny_model());
        assert!(again.reused_invariants());
        assert!(Arc::ptr_eq(&first.invariants, &again.invariants));

        // a genuinely different expansion must recompute
        let data = std::sync::Arc::new(chessboard(60, 4, 7));
        let other = AnyModel::Svc(Trainer::rbf(10.0, 0.5).train(&data).model);
        let fresh = reg.insert("m", other);
        assert!(!fresh.reused_invariants());
        assert!(!Arc::ptr_eq(&again.invariants, &fresh.invariants));

        // quarantined generations never donate invariants
        let held = reg.insert("m", tiny_model());
        held.quarantine();
        let after = reg.insert("m", tiny_model());
        assert!(!after.reused_invariants());
    }

    #[test]
    fn invariant_reuse_is_kernel_entries_neutral() {
        use crate::svm::scorer::Scorer;
        let reg = Registry::new(vec![("m".to_string(), tiny_model())]);
        let cold = reg.resolve(Some("m")).unwrap();
        let warm = reg.insert("m", tiny_model());
        assert!(warm.reused_invariants());
        let queries = chessboard(40, 4, 2);
        let (AnyModel::Svc(a), AnyModel::Svc(b)) = (&cold.model, &warm.model) else {
            panic!("tiny_model trains an svc");
        };
        let sa =
            Scorer::with_invariants(a.kernel, &a.support, &a.coef, a.bias, &cold.invariants[0]);
        let sb =
            Scorer::with_invariants(b.kernel, &b.support, &b.coef, b.bias, &warm.invariants[0]);
        assert_eq!(
            sa.kernel_entries_per_pass(queries.len()),
            sb.kernel_entries_per_pass(queries.len()),
            "reuse must not change how much kernel work a pass does"
        );
        let va = sa.decision_values(&queries);
        let vb = sb.decision_values(&queries);
        for (x, y) in va.iter().zip(&vb) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn f32_gate_enables_the_fast_path_only_when_requested() {
        let reg = Registry::new_with(vec![("m".to_string(), tiny_model())], true);
        let entry = reg.resolve(Some("m")).unwrap();
        assert!(entry.f32_sv(0), "the tiny RBF model passes the accuracy gate");
        assert!(!entry.f32_sv(7), "out-of-range machines read false");

        let off = Registry::new(vec![("m".to_string(), tiny_model())]);
        assert!(!off.resolve(Some("m")).unwrap().f32_sv(0));

        // the verdict survives a reusing hot-swap
        let again = reg.insert("m", tiny_model());
        assert!(again.reused_invariants() && again.f32_sv(0));
    }

    #[test]
    fn entries_precompute_one_invariant_per_machine() {
        let entry = ModelEntry::new("m".to_string(), tiny_model());
        assert_eq!(entry.invariants.len(), 1);
        let blobs = crate::svm::multiclass::blobs(90, 3, 4.0, 0.5, 1);
        let ovo = crate::svm::multiclass::train_ovo(&blobs, &Trainer::rbf(10.0, 0.5));
        let n_machines = ovo.machines.len();
        let entry = ModelEntry::new("ovo".to_string(), AnyModel::Multiclass(ovo));
        assert_eq!(entry.invariants.len(), n_machines);
    }
}
