//! Statistics substrate: summary statistics, the paired Wilcoxon
//! signed-rank test (the paper's significance machinery for Table 2),
//! and the log-scale histogram used by Figure 3.

pub mod histogram;
pub mod summary;
pub mod wilcoxon;

pub use summary::Summary;
pub use wilcoxon::{wilcoxon_signed_rank, WilcoxonOutcome};
