//! Summary statistics for experiment measurements.

/// Mean / sd / median / min / max of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub sd: f64,
    /// Median (midpoint of the two central values for even n).
    pub median: f64,
    /// Smallest value.
    pub min: f64,
    /// Largest value.
    pub max: f64,
}

impl Summary {
    /// Compute from a sample (empty sample -> NaNs, n = 0).
    pub fn of(xs: &[f64]) -> Summary {
        let n = xs.len();
        if n == 0 {
            return Summary { n, mean: f64::NAN, sd: f64::NAN, median: f64::NAN, min: f64::NAN, max: f64::NAN };
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            (sorted[n / 2 - 1] + sorted[n / 2]) / 2.0
        };
        Summary {
            n,
            mean,
            sd: var.sqrt(),
            median,
            min: sorted[0],
            max: sorted[n - 1],
        }
    }
}

/// Quantile (linear interpolation, q in [0,1]).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty() && (0.0..=1.0).contains(&q));
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (pos - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hand_computed_summary() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.median, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.sd - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn odd_median() {
        assert_eq!(Summary::of(&[5.0, 1.0, 3.0]).median, 3.0);
    }

    #[test]
    fn empty_is_nan() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert!(s.mean.is_nan());
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 0.25), 2.0);
    }
}
