//! Paired Wilcoxon signed-rank test — the significance test behind the
//! ">" markers in the paper's Table 2 ("paired Wilcoxon rank sum test,
//! p = 0.05 over 100 permutations of the datasets").
//!
//! Implementation: exact null distribution by dynamic programming for
//! n ≤ 25 (no ties across |differences| assumed; ties get average ranks
//! and fall back to the normal approximation), normal approximation with
//! tie correction and continuity correction otherwise.

/// Test outcome for paired samples (a vs b).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WilcoxonOutcome {
    /// Number of non-zero differences actually used.
    pub n_used: usize,
    /// Signed-rank statistic W+ (sum of ranks of positive differences a>b).
    pub w_plus: f64,
    /// Two-sided p-value.
    pub p_two_sided: f64,
    /// One-sided p-value for the alternative "a > b".
    pub p_greater: f64,
    /// One-sided p-value for the alternative "a < b".
    pub p_less: f64,
}

impl WilcoxonOutcome {
    /// The paper's table marker at level `alpha`:
    /// `Some(true)` = a significantly greater, `Some(false)` = b greater.
    pub fn significantly_greater(&self, alpha: f64) -> Option<bool> {
        if self.p_greater <= alpha {
            Some(true)
        } else if self.p_less <= alpha {
            Some(false)
        } else {
            None
        }
    }
}

/// Run the paired test on equal-length samples. Zero differences are
/// dropped (standard Wilcoxon practice). Returns None if fewer than 3
/// usable pairs remain.
pub fn wilcoxon_signed_rank(a: &[f64], b: &[f64]) -> Option<WilcoxonOutcome> {
    assert_eq!(a.len(), b.len(), "paired test needs equal lengths");
    let mut diffs: Vec<f64> = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| x - y)
        .filter(|d| *d != 0.0)
        .collect();
    let n = diffs.len();
    if n < 3 {
        return None;
    }
    // Rank |d| ascending with average ranks for ties.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| diffs[i].abs().total_cmp(&diffs[j].abs()));
    let mut ranks = vec![0f64; n];
    let mut has_ties = false;
    let mut tie_correction = 0.0f64; // Σ (t³ - t) over tie groups
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && diffs[order[j + 1]].abs() == diffs[order[i]].abs() {
            j += 1;
        }
        let avg_rank = (i + j + 2) as f64 / 2.0; // ranks are 1-based
        for k in i..=j {
            ranks[order[k]] = avg_rank;
        }
        let t = (j - i + 1) as f64;
        if t > 1.0 {
            has_ties = true;
            tie_correction += t * t * t - t;
        }
        i = j + 1;
    }
    let w_plus: f64 = (0..n).filter(|&k| diffs[k] > 0.0).map(|k| ranks[k]).sum();

    let (p_greater, p_less) = if n <= 25 && !has_ties {
        exact_p(w_plus, n)
    } else {
        normal_p(w_plus, n, tie_correction)
    };
    let p_two = (2.0 * p_greater.min(p_less)).min(1.0);
    diffs.clear();
    Some(WilcoxonOutcome {
        n_used: n,
        w_plus,
        p_two_sided: p_two,
        p_greater,
        p_less,
    })
}

/// Exact null distribution of W+ by DP: counts[w] = #subsets of {1..n}
/// with sum w. P(W+ >= w) etc. under the symmetric null.
fn exact_p(w_plus: f64, n: usize) -> (f64, f64) {
    let max_w = n * (n + 1) / 2;
    let mut counts = vec![0f64; max_w + 1];
    counts[0] = 1.0;
    for r in 1..=n {
        for w in (r..=max_w).rev() {
            counts[w] += counts[w - r];
        }
    }
    let total: f64 = counts.iter().sum(); // = 2^n
    let w = w_plus.round() as usize;
    let p_ge: f64 = counts[w..].iter().sum::<f64>() / total;
    let p_le: f64 = counts[..=w].iter().sum::<f64>() / total;
    // alternative "a > b" means large W+ -> p_greater = P(W+ >= w)
    (p_ge, p_le)
}

/// Normal approximation with tie and continuity correction.
fn normal_p(w_plus: f64, n: usize, tie_correction: f64) -> (f64, f64) {
    let nf = n as f64;
    let mean = nf * (nf + 1.0) / 4.0;
    let var = nf * (nf + 1.0) * (2.0 * nf + 1.0) / 24.0 - tie_correction / 48.0;
    let sd = var.sqrt().max(1e-12);
    let z_greater = (w_plus - mean - 0.5) / sd;
    let z_less = (w_plus - mean + 0.5) / sd;
    (1.0 - phi(z_greater), phi(z_less))
}

/// Standard normal CDF via erf (Abramowitz-Stegun 7.1.26, |err| < 1.5e-7).
pub fn phi(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    #[test]
    fn clearly_greater_sample_is_significant() {
        let a: Vec<f64> = (0..20).map(|i| 10.0 + i as f64).collect();
        let b: Vec<f64> = (0..20).map(|i| 1.0 + 0.1 * i as f64).collect();
        let out = wilcoxon_signed_rank(&a, &b).unwrap();
        assert!(out.p_greater < 0.001, "{out:?}");
        assert_eq!(out.significantly_greater(0.05), Some(true));
        // symmetric call flips the verdict
        let out2 = wilcoxon_signed_rank(&b, &a).unwrap();
        assert_eq!(out2.significantly_greater(0.05), Some(false));
    }

    #[test]
    fn identical_samples_give_none() {
        let a = vec![1.0; 10];
        assert!(wilcoxon_signed_rank(&a, &a).is_none());
    }

    #[test]
    fn exact_matches_known_small_case() {
        // n=5, all differences positive -> W+ = 15, P(W+ >= 15) = 1/32.
        let a = vec![2.0, 3.0, 4.0, 5.0, 6.0];
        let b = vec![1.0, 1.5, 2.0, 2.5, 3.0];
        let out = wilcoxon_signed_rank(&a, &b).unwrap();
        assert_eq!(out.w_plus, 15.0);
        assert!((out.p_greater - 1.0 / 32.0).abs() < 1e-12, "{out:?}");
        assert!((out.p_two_sided - 2.0 / 32.0).abs() < 1e-12);
    }

    #[test]
    fn exact_and_normal_agree_for_moderate_n() {
        // Construct n=20 with distinct |d|, compute both ways.
        let mut rng = Pcg::new(3);
        let a: Vec<f64> = (0..20).map(|i| i as f64 + rng.uniform() * 0.3).collect();
        let b: Vec<f64> = a.iter().map(|x| x - 0.4 - 0.01 * x).collect();
        let out = wilcoxon_signed_rank(&a, &b).unwrap(); // exact branch
        let (pg_n, pl_n) = normal_p(out.w_plus, out.n_used, 0.0);
        assert!((out.p_greater - pg_n).abs() < 0.02, "{} vs {pg_n}", out.p_greater);
        assert!((out.p_less - pl_n).abs() < 0.02);
    }

    #[test]
    fn null_distribution_rejects_at_nominal_rate() {
        // Property: under H0 (paired samples from the same distribution)
        // the test should reject ~5% of the time at alpha = 0.05.
        let mut rng = Pcg::new(42);
        let trials = 400;
        let mut rejections = 0;
        for _ in 0..trials {
            let a: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
            if let Some(out) = wilcoxon_signed_rank(&a, &b) {
                if out.p_two_sided <= 0.05 {
                    rejections += 1;
                }
            }
        }
        let rate = rejections as f64 / trials as f64;
        assert!(rate < 0.12, "type-I rate {rate} too high");
    }

    #[test]
    fn phi_sanity() {
        assert!((phi(0.0) - 0.5).abs() < 1e-7);
        assert!((phi(1.96) - 0.975).abs() < 1e-3);
        assert!(phi(-6.0) < 1e-8);
    }

    #[test]
    fn zero_differences_are_dropped() {
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = vec![1.0, 2.0, 2.0, 3.0, 4.0, 5.0];
        let out = wilcoxon_signed_rank(&a, &b).unwrap();
        assert_eq!(out.n_used, 4);
    }
}
