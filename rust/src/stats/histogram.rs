//! Log-resolution histogram for Figure 3.
//!
//! The paper plots histograms of `μ/μ* − 1` using the symmetric
//! parameterization `t ↦ sign(t)·(10^{t²/2} − 1)` on the x-axis (high
//! resolution around the Newton step, log growth outward) and a log count
//! axis. We bin in `t`-space: the inverse map is
//! `t(r) = sign(r)·sqrt(2·log10(1 + |r|))`.

/// Fixed-bin histogram in the paper's Figure-3 parameterization, with an
/// explicit overflow bin on each side ("the rightmost bin counts all
/// steps which exceed the scale").
#[derive(Debug, Clone)]
pub struct Fig3Histogram {
    /// Bin edges in t-space (len = bins + 1), symmetric around 0.
    pub t_max: f64,
    /// Number of regular bins between the overflow bins.
    pub bins: usize,
    counts: Vec<u64>,
    /// Samples below `−t_max` (left overflow bin).
    pub underflow: u64,
    /// Samples at or above `t_max` (right overflow bin).
    pub overflow: u64,
    /// Total samples recorded (regular + overflow).
    pub total: u64,
}

/// Forward map of the paper's x-axis: t -> relative step offset r.
pub fn t_to_ratio(t: f64) -> f64 {
    t.signum() * (10f64.powf(t * t / 2.0) - 1.0)
}

/// Inverse map: relative step offset r = μ/μ* − 1 -> t.
pub fn ratio_to_t(r: f64) -> f64 {
    r.signum() * (2.0 * (1.0 + r.abs()).log10()).sqrt()
}

impl Fig3Histogram {
    /// `t_max = 3` covers ratios up to ~10^4.5 − 1, matching the paper's
    /// scale; larger offsets land in the overflow bin.
    pub fn new(bins: usize, t_max: f64) -> Fig3Histogram {
        assert!(bins >= 2 && t_max > 0.0);
        Fig3Histogram {
            t_max,
            bins,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
            total: 0,
        }
    }

    /// Record one planning step's `μ/μ* − 1`.
    pub fn record(&mut self, ratio_minus_one: f64) {
        self.total += 1;
        let t = ratio_to_t(ratio_minus_one);
        if t < -self.t_max {
            self.underflow += 1;
            return;
        }
        if t >= self.t_max {
            self.overflow += 1;
            return;
        }
        let idx = ((t + self.t_max) / (2.0 * self.t_max) * self.bins as f64) as usize;
        self.counts[idx.min(self.bins - 1)] += 1;
    }

    /// Per-bin counts (regular bins only).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Bin center in t-space.
    pub fn t_center(&self, bin: usize) -> f64 {
        -self.t_max + (bin as f64 + 0.5) / self.bins as f64 * 2.0 * self.t_max
    }

    /// Render an ASCII sketch (log-scaled bar lengths), one line per
    /// non-empty bin: `t-center  ratio  count  bar`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        out.push_str("   t-center     mu/mu*-1        count\n");
        if self.underflow > 0 {
            out.push_str(&format!("   < -{:<8.2} (underflow) {:>10}\n", self.t_max, self.underflow));
        }
        for (b, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let bar_len = (((c as f64).ln_1p() / (max as f64).ln_1p()) * 40.0) as usize;
            out.push_str(&format!(
                "   {:>8.2}  {:>12.4}  {:>10}  {}\n",
                self.t_center(b),
                t_to_ratio(self.t_center(b)),
                c,
                "#".repeat(bar_len.max(1))
            ));
        }
        if self.overflow > 0 {
            out.push_str(&format!("   > +{:<8.2} (overflow)  {:>10}\n", self.t_max, self.overflow));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameterization_round_trips() {
        for r in [-0.99, -0.5, 0.0, 0.1, 1.0, 100.0, 1e4] {
            let t = ratio_to_t(r);
            assert!((t_to_ratio(t) - r).abs() < 1e-9 * (1.0 + r.abs()), "r={r}");
        }
    }

    #[test]
    fn newton_step_lands_in_central_bin() {
        let mut h = Fig3Histogram::new(40, 3.0);
        h.record(0.0);
        let central = h.counts()[20]; // t=0 is at the center boundary -> bin 20
        assert_eq!(central, 1);
    }

    #[test]
    fn overflow_counts_extreme_steps() {
        let mut h = Fig3Histogram::new(10, 2.0);
        h.record(1e9); // far beyond scale
        h.record(-1e9);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.total, 2);
    }

    #[test]
    fn asymmetric_mass_shows_up_on_the_right() {
        let mut h = Fig3Histogram::new(20, 3.0);
        for i in 0..100 {
            h.record(0.05 + i as f64 * 0.1); // enlarged steps only
        }
        let left: u64 = h.counts()[..10].iter().sum();
        let right: u64 = h.counts()[10..].iter().sum();
        assert_eq!(left, 0);
        assert_eq!(right + h.overflow, 100);
    }

    #[test]
    fn render_contains_counts() {
        let mut h = Fig3Histogram::new(8, 2.0);
        for _ in 0..5 {
            h.record(0.1);
        }
        let s = h.render();
        assert!(s.contains('5'), "{s}");
        assert!(s.contains('#'));
    }
}
