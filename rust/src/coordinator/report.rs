//! Report sink: print experiment sections and append them to a file.

use std::io::Write;
use std::path::Path;

use crate::util::error::{Context, Result};

/// Collects report sections, mirroring them to stdout.
pub struct Report {
    sections: Vec<String>,
    quiet: bool,
}

impl Report {
    /// Empty report; `quiet` suppresses the stdout echo.
    pub fn new(quiet: bool) -> Report {
        Report { sections: Vec::new(), quiet }
    }

    /// Add a section (echoed to stdout unless quiet).
    pub fn section(&mut self, text: impl Into<String>) {
        let text = text.into();
        if !self.quiet {
            println!("{text}");
        }
        self.sections.push(text);
    }

    /// Has no section been added yet?
    pub fn is_empty(&self) -> bool {
        self.sections.is_empty()
    }

    /// Concatenated report.
    pub fn render(&self) -> String {
        self.sections.join("\n\n")
    }

    /// Write (overwrite) the report to a file.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(self.render().as_bytes())?;
        f.write_all(b"\n")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_and_saves() {
        let mut r = Report::new(true);
        assert!(r.is_empty());
        r.section("## A\ndata");
        r.section("## B");
        assert_eq!(r.render(), "## A\ndata\n\n## B");
        let path = std::env::temp_dir().join("pasmo-report-test.md");
        r.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("## B"));
        std::fs::remove_file(&path).ok();
    }
}
