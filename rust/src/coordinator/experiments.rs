//! Experiment drivers — one per paper table/figure (DESIGN.md §3).
//!
//! Every driver returns a rendered report string (also consumed by the
//! `cargo bench` targets and the `pasmo experiment …` CLI). Paper values
//! are printed next to measured values wherever the paper reports them.

use std::sync::Arc;

use crate::data::suite::{self, DatasetSpec};
use crate::solver::engine::SolverChoice;
use crate::solver::events::TelemetryConfig;
use crate::stats::histogram::Fig3Histogram;
use crate::stats::summary::Summary;
use crate::stats::wilcoxon::wilcoxon_signed_rank;
use crate::svm::trainer::Trainer;
use crate::util::table::{fnum, Align, Table};

use super::jobs::{self, run_permutations};

/// Shared experiment options (CLI-settable).
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Dataset size scale relative to the paper's ℓ (1.0 = paper size).
    pub scale: f64,
    /// Hard cap on ℓ regardless of scale (0 = no cap).
    pub max_len: usize,
    /// Number of random permutations (paper: 100).
    pub perms: usize,
    /// Stopping accuracy ε.
    pub eps: f64,
    /// Master seed.
    pub seed: u64,
    /// Restrict to these dataset names (empty = fast sub-suite).
    pub datasets: Vec<String>,
    /// Use the complete 22-dataset suite at paper sizes.
    pub full: bool,
    /// Worker threads for permutation fan-out.
    pub threads: usize,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            scale: 0.25,
            max_len: 2000,
            perms: 10,
            eps: 1e-3,
            seed: 42,
            datasets: Vec::new(),
            full: false,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        }
    }
}

impl ExpOptions {
    /// The dataset specs this run covers.
    pub fn specs(&self) -> Vec<DatasetSpec> {
        if !self.datasets.is_empty() {
            return self
                .datasets
                .iter()
                .filter_map(|n| suite::find(n))
                .collect();
        }
        if self.full {
            suite::suite()
        } else {
            suite::fast_suite_names()
                .into_iter()
                .filter_map(suite::find)
                .collect()
        }
    }

    /// Experiment length for a spec.
    pub fn len_for(&self, spec: &DatasetSpec) -> usize {
        let scale = if self.full { 1.0 } else { self.scale };
        let mut n = spec.scaled_len(scale);
        if !self.full && self.max_len > 0 {
            n = n.min(self.max_len);
        }
        n
    }

    /// The trainer template for a spec (paper (C, γ), CLI-set ε).
    fn trainer(&self, spec: &DatasetSpec) -> Trainer {
        Trainer::rbf(spec.c, spec.gamma).stop_eps(self.eps)
    }
}

/// Significance marker column (the paper's ">" notation, α = 0.05).
fn marker(a: &[f64], b: &[f64]) -> &'static str {
    match wilcoxon_signed_rank(a, b).and_then(|o| o.significantly_greater(0.05)) {
        Some(true) => ">",
        Some(false) => "<",
        None => " ",
    }
}

/// Table 1: dataset statistics — ℓ, C, γ, and measured SV / BSV next to
/// the paper's reported counts.
pub fn table1(opts: &ExpOptions) -> String {
    let mut t = Table::new(&[
        "dataset", "ℓ", "C", "γ", "SV", "BSV", "SV(paper@ℓ₀)", "BSV(paper@ℓ₀)",
    ])
    .align(&[
        Align::Left, Align::Right, Align::Right, Align::Right, Align::Right,
        Align::Right, Align::Right, Align::Right,
    ]);
    for spec in opts.specs() {
        let n = opts.len_for(&spec);
        let ds = Arc::new(spec.generate(n, opts.seed));
        let res = opts.trainer(&spec).train(&ds).result;
        t.add_row(vec![
            spec.name.to_string(),
            n.to_string(),
            fnum(spec.c, 1),
            format!("{}", spec.gamma),
            res.sv.to_string(),
            res.bsv.to_string(),
            spec.paper_sv.to_string(),
            spec.paper_bsv.to_string(),
        ]);
    }
    format!(
        "## Table 1 — datasets, hyper-parameters, support vectors\n\
         (paper columns refer to the paper's dataset size ℓ₀; ours is scaled)\n\n{}",
        t.render()
    )
}

/// Table 2: SMO vs PA-SMO — mean time and iterations over permutations
/// with Wilcoxon significance markers, plus the §7.1 objective check.
pub fn table2(opts: &ExpOptions) -> String {
    let mut t = Table::new(&[
        "dataset", "time SMO", "", "time PA", "iters SMO", "", "iters PA", "obj: PA better",
    ])
    .align(&[
        Align::Left, Align::Right, Align::Left, Align::Right, Align::Right,
        Align::Left, Align::Right, Align::Right,
    ]);
    for spec in opts.specs() {
        let n = opts.len_for(&spec);
        let ds = Arc::new(spec.generate(n, opts.seed));
        let base = opts.trainer(&spec);
        let cfgs = [
            base.clone().solver(SolverChoice::Smo),
            base.solver(SolverChoice::Pasmo),
        ];
        let res = run_permutations(&ds, &cfgs, opts.perms, opts.seed ^ 0xF00D, opts.threads);
        let (smo, pa) = (&res[0], &res[1]);
        let (ts, tp) = (jobs::times(smo), jobs::times(pa));
        let (is_, ip) = (jobs::iterations(smo), jobs::iterations(pa));
        let (os, op) = (jobs::objectives(smo), jobs::objectives(pa));
        let obj_mark = match wilcoxon_signed_rank(&op, &os)
            .and_then(|o| o.significantly_greater(0.05))
        {
            Some(true) => "yes",
            Some(false) => "NO (worse!)",
            None => "~",
        };
        t.add_row(vec![
            spec.name.to_string(),
            fnum(Summary::of(&ts).mean, 4),
            marker(&ts, &tp).to_string(),
            fnum(Summary::of(&tp).mean, 4),
            fnum(Summary::of(&is_).mean, 0),
            marker(&is_, &ip).to_string(),
            fnum(Summary::of(&ip).mean, 0),
            obj_mark.to_string(),
        ]);
    }
    format!(
        "## Table 2 — SMO vs PA-SMO ({} permutations, ε = {}, scale = {})\n\
         '>' marks a paired-Wilcoxon-significant (p=0.05) advantage of PA-SMO.\n\n{}",
        opts.perms,
        opts.eps,
        if opts.full { 1.0 } else { opts.scale },
        t.render()
    )
}

/// Engine shootout — SMO vs PA-SMO vs Conjugate SMO on the Table-2
/// protocol: every engine trains on the *same* random permutations
/// (measurements stay paired), Wilcoxon `>` markers compare each
/// challenger against the SMO baseline on iterations, and the last
/// column reports the worst relative objective deviation from SMO
/// across all engines and permutations (the §7.1-style parity check —
/// all three engines solve the same QP, so it must stay at solver
/// tolerance).
pub fn engine_shootout(opts: &ExpOptions) -> String {
    let mut t = Table::new(&[
        "dataset", "iters SMO", "", "iters PA", "", "iters CSMO", "t SMO", "t PA", "t CSMO",
        "max |Δobj|",
    ])
    .align(&[
        Align::Left, Align::Right, Align::Left, Align::Right, Align::Left, Align::Right,
        Align::Right, Align::Right, Align::Right, Align::Right,
    ]);
    for spec in opts.specs() {
        let n = opts.len_for(&spec);
        let ds = Arc::new(spec.generate(n, opts.seed));
        let base = opts.trainer(&spec);
        let cfgs = [
            base.clone().solver(SolverChoice::Smo),
            base.clone().solver(SolverChoice::Pasmo),
            base.solver(SolverChoice::ConjugateSmo),
        ];
        let res = run_permutations(&ds, &cfgs, opts.perms, opts.seed ^ 0x53D0, opts.threads);
        let (smo, pa, cj) = (&res[0], &res[1], &res[2]);
        let (is_, ip, ic) =
            (jobs::iterations(smo), jobs::iterations(pa), jobs::iterations(cj));
        let (ts, tp, tc) = (jobs::times(smo), jobs::times(pa), jobs::times(cj));
        let os = jobs::objectives(smo);
        let mut max_dev = 0.0f64;
        for challenger in [jobs::objectives(pa), jobs::objectives(cj)] {
            for (o, &b) in challenger.iter().zip(&os) {
                max_dev = max_dev.max((o - b).abs() / (1.0 + b.abs()));
            }
        }
        t.add_row(vec![
            spec.name.to_string(),
            fnum(Summary::of(&is_).mean, 0),
            marker(&is_, &ip).to_string(),
            fnum(Summary::of(&ip).mean, 0),
            marker(&is_, &ic).to_string(),
            fnum(Summary::of(&ic).mean, 0),
            fnum(Summary::of(&ts).mean, 4),
            fnum(Summary::of(&tp).mean, 4),
            fnum(Summary::of(&tc).mean, 4),
            format!("{max_dev:.1e}"),
        ]);
    }
    format!(
        "## Engine shootout — SMO vs PA-SMO vs Conjugate SMO ({} permutations, ε = {}, scale = {})\n\
         '>' marks a paired-Wilcoxon-significant (p=0.05) iteration advantage over SMO;\n\
         'max |Δobj|' is the worst relative objective deviation from SMO (engine parity).\n\n{}",
        opts.perms,
        opts.eps,
        if opts.full { 1.0 } else { opts.scale },
        t.render()
    )
}

/// §7.2 — isolate the WSS change from planning: SMO vs SMO+Alg3-WSS
/// (no planning) vs full PA-SMO, in iterations and time.
pub fn wss_ablation(opts: &ExpOptions) -> String {
    let mut t = Table::new(&[
        "dataset", "iters SMO", "iters WSS-only", "iters PA-SMO", "t SMO", "t WSS-only", "t PA",
    ])
    .align(&[
        Align::Left, Align::Right, Align::Right, Align::Right, Align::Right,
        Align::Right, Align::Right,
    ]);
    for spec in opts.specs() {
        let n = opts.len_for(&spec);
        let ds = Arc::new(spec.generate(n, opts.seed));
        let base = opts.trainer(&spec);
        let mut wss_only = base.clone().solver(SolverChoice::Pasmo);
        wss_only.solver_config.ablation_wss_only = true;
        let cfgs = [
            base.clone().solver(SolverChoice::Smo),
            wss_only,
            base.solver(SolverChoice::Pasmo),
        ];
        let res = run_permutations(&ds, &cfgs, opts.perms, opts.seed ^ 0xAB1A, opts.threads);
        t.add_row(vec![
            spec.name.to_string(),
            fnum(Summary::of(&jobs::iterations(&res[0])).mean, 0),
            fnum(Summary::of(&jobs::iterations(&res[1])).mean, 0),
            fnum(Summary::of(&jobs::iterations(&res[2])).mean, 0),
            fnum(Summary::of(&jobs::times(&res[0])).mean, 4),
            fnum(Summary::of(&jobs::times(&res[1])).mean, 4),
            fnum(Summary::of(&jobs::times(&res[2])).mean, 4),
        ]);
    }
    format!(
        "## §7.2 — influence of planning-ahead vs working-set selection\n\
         Expectation (paper): WSS-only ≈ SMO (ambiguous), PA-SMO clearly ahead.\n\n{}",
        t.render()
    )
}

/// §7.3 / Figure 3 — histograms of the planning-step size μ/μ*−1 in the
/// paper's log-log parameterization.
pub fn fig3(opts: &ExpOptions) -> String {
    let mut out = String::from(
        "## Figure 3 — planning-step size histograms (μ/μ* − 1)\n\
         x-binning: t ↦ sign(t)(10^{t²/2}−1); rightmost row = overflow bin.\n",
    );
    for spec in opts.specs() {
        let n = opts.len_for(&spec);
        let ds = Arc::new(spec.generate(n, opts.seed));
        let mut trainer = opts.trainer(&spec).solver(SolverChoice::Pasmo);
        trainer.solver_config.telemetry = TelemetryConfig::fig3();
        let res = trainer.train(&ds).result;
        let mut h = Fig3Histogram::new(40, 3.0);
        for &r in &res.telemetry.planning_ratios {
            h.record(r);
        }
        out.push_str(&format!(
            "\n### {} (ℓ={n}, planning steps: {})\n{}",
            spec.name,
            res.telemetry.planning_steps,
            h.render()
        ));
    }
    out
}

/// §7.3 second part — the "heretical" 1.1× over-relaxed Newton step as a
/// cheap planning substitute: SMO vs OverRelaxed(1.1) vs PA-SMO.
pub fn heuristic_step(opts: &ExpOptions) -> String {
    let mut t = Table::new(&[
        "dataset", "iters SMO", "iters 1.1x", "iters PA-SMO", "t SMO", "t 1.1x", "t PA",
    ])
    .align(&[
        Align::Left, Align::Right, Align::Right, Align::Right, Align::Right,
        Align::Right, Align::Right,
    ]);
    for spec in opts.specs() {
        let n = opts.len_for(&spec);
        let ds = Arc::new(spec.generate(n, opts.seed));
        let base = opts.trainer(&spec);
        let mut over = base.clone().solver(SolverChoice::Smo);
        over.solver_config.step_policy =
            crate::solver::step::OverStep::OverRelaxed(1.1);
        let cfgs = [
            base.clone().solver(SolverChoice::Smo),
            over,
            base.solver(SolverChoice::Pasmo),
        ];
        let res = run_permutations(&ds, &cfgs, opts.perms, opts.seed ^ 0x11E7, opts.threads);
        t.add_row(vec![
            spec.name.to_string(),
            fnum(Summary::of(&jobs::iterations(&res[0])).mean, 0),
            fnum(Summary::of(&jobs::iterations(&res[1])).mean, 0),
            fnum(Summary::of(&jobs::iterations(&res[2])).mean, 0),
            fnum(Summary::of(&jobs::times(&res[0])).mean, 4),
            fnum(Summary::of(&jobs::times(&res[1])).mean, 4),
            fnum(Summary::of(&jobs::times(&res[2])).mean, 4),
        ]);
    }
    format!(
        "## §7.3 — fixed 1.1× over-relaxation vs planning-ahead\n\
         Expectation (paper): 1.1× ≈ PA-SMO on easy sets, clearly worse on chess-board.\n\n{}",
        t.render()
    )
}

/// §7.4 / Figure 4 — multiple planning-ahead: runtime with N ∈
/// {1,2,3,5,10,20} recent working sets, normalized by N = 1.
pub fn fig4(opts: &ExpOptions) -> String {
    let ns = [1usize, 2, 3, 5, 10, 20];
    let mut t = Table::new(&[
        "dataset", "N=1", "N=2", "N=3", "N=5", "N=10", "N=20",
    ])
    .align(&[
        Align::Left, Align::Right, Align::Right, Align::Right, Align::Right,
        Align::Right, Align::Right,
    ]);
    for spec in opts.specs() {
        let n = opts.len_for(&spec);
        let ds = Arc::new(spec.generate(n, opts.seed));
        let base = opts.trainer(&spec);
        let cfgs: Vec<Trainer> = ns
            .iter()
            .map(|&k| base.clone().solver(SolverChoice::PasmoMulti(k)))
            .collect();
        let res = run_permutations(&ds, &cfgs, opts.perms, opts.seed ^ 0xF164, opts.threads);
        let t1 = Summary::of(&jobs::times(&res[0])).mean.max(1e-12);
        let mut row = vec![spec.name.to_string()];
        for (k, _) in ns.iter().enumerate() {
            let tk = Summary::of(&jobs::times(&res[k])).mean;
            row.push(fnum(tk / t1, 3));
        }
        t.add_row(row);
    }
    format!(
        "## Figure 4 — multiple planning-ahead (runtime normalized to N=1)\n\
         Expectation (paper): N=2,3 ≈ 1 (or slightly better); N≥10 degrades.\n\n{}",
        t.render()
    )
}

/// Figure 2 — the gain parabola: relative gain of a step of size μ
/// against the Newton gain, as a function of μ/μ*. Pure analytics.
pub fn fig2() -> String {
    let mut t = Table::new(&["μ/μ*", "gain/ĝ*", "note"]).align(&[
        Align::Right,
        Align::Right,
        Align::Left,
    ]);
    let eta = 0.9;
    for k in 0..=26 {
        let r = -0.2 + 0.1 * k as f64; // hits 0, 1 and 2 exactly
        let rel_gain = 2.0 * r - r * r; // (2μ/μ* − (μ/μ*)²)·ĝ*
        let note = if r <= 0.0 || r >= 2.0 {
            "objective decays"
        } else if (1.0 - r).abs() <= eta {
            "η-band: gain ≥ (1−η²)ĝ*"
        } else {
            ""
        };
        t.add_row(vec![fnum(r, 3), fnum(rel_gain, 4), note.to_string()]);
    }
    format!(
        "## Figure 2 — gain of a step of size μ relative to the Newton gain\n\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ExpOptions {
        ExpOptions {
            scale: 0.05,
            max_len: 150,
            perms: 3,
            datasets: vec!["chess-board-1000".into(), "thyroid".into()],
            threads: 2,
            ..Default::default()
        }
    }

    #[test]
    fn table1_reports_all_requested_datasets() {
        let s = table1(&tiny_opts());
        assert!(s.contains("chess-board-1000"));
        assert!(s.contains("thyroid"));
        assert!(s.contains("SV"));
    }

    #[test]
    fn table2_runs_and_renders_markers() {
        let s = table2(&tiny_opts());
        assert!(s.contains("chess-board-1000"), "{s}");
        assert!(s.contains("time SMO"));
    }

    #[test]
    fn engine_shootout_runs_three_engines_paired() {
        let s = engine_shootout(&tiny_opts());
        assert!(s.contains("Conjugate SMO"), "{s}");
        assert!(s.contains("iters CSMO"), "{s}");
        assert!(s.contains("chess-board-1000"), "{s}");
        assert!(s.contains("thyroid"), "{s}");
    }

    #[test]
    fn fig2_is_analytic_and_fast() {
        let s = fig2();
        assert!(s.contains("0.0000")); // gain at ratio 0 or 2
        assert!(s.contains("η-band"));
    }

    #[test]
    fn fig3_renders_histograms() {
        let mut o = tiny_opts();
        o.datasets = vec!["chess-board-1000".into()];
        let s = fig3(&o);
        assert!(s.contains("planning steps"));
    }

    #[test]
    fn options_select_fast_suite_by_default() {
        let o = ExpOptions::default();
        let specs = o.specs();
        assert!(specs.len() >= 10);
        assert!(o.len_for(&specs[0]) <= 2000);
    }
}
