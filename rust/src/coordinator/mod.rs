//! Experiment coordinator: permutation fan-out and per-table/figure drivers.
pub mod jobs;
pub mod report;
pub mod experiments;
