//! Permutation fan-out: run a set of solver configurations over N random
//! permutations of a dataset, in parallel across OS threads.
//!
//! This mirrors the paper's §7 protocol: "we created 100 random
//! permutations of each dataset … all measurements reported are mean
//! values over these 100 permutations" — the permutation changes the
//! solver's tie-breaking in the first iteration and hence the whole
//! optimization path, so the *same* permutation is fed to every solver
//! (the measurements are paired for the Wilcoxon test).

use std::sync::{Arc, Mutex};

use crate::data::dataset::Dataset;
use crate::data::splits::permutations;
use crate::svm::trainer::Trainer;

/// One (solver, permutation) measurement.
#[derive(Debug, Clone)]
pub struct RunMeasurement {
    /// Wall-clock training time in seconds.
    pub time_s: f64,
    /// Solver iterations.
    pub iterations: u64,
    /// Final dual objective.
    pub objective: f64,
    /// Did the solve converge (vs hit the iteration cap)?
    pub converged: bool,
    /// Support vectors in the solution.
    pub sv: usize,
    /// Bounded support vectors.
    pub bsv: usize,
    /// Planning-ahead steps taken (0 for non-PA engines).
    pub planning_steps: u64,
}

/// Run `trainers` over `perms` permutations of `base`. Returns
/// `results[trainer][perm]` (paired across trainers by permutation
/// index).
pub fn run_permutations(
    base: &Arc<Dataset>,
    trainers: &[Trainer],
    perms: usize,
    seed: u64,
    threads: usize,
) -> Vec<Vec<RunMeasurement>> {
    let perm_list = permutations(base.len(), perms, seed);
    let results: Vec<Mutex<Vec<Option<RunMeasurement>>>> = trainers
        .iter()
        .map(|_| Mutex::new(vec![None; perms]))
        .collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let workers = threads
        .max(1)
        .min(perms.max(1))
        .min(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4));

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let p = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if p >= perms {
                    break;
                }
                let permuted = Arc::new(base.permuted(&perm_list[p]));
                for (ci, trainer) in trainers.iter().enumerate() {
                    let res = trainer.train(&permuted).result;
                    let m = RunMeasurement {
                        time_s: res.wall_time_s,
                        iterations: res.iterations,
                        objective: res.objective,
                        converged: res.converged,
                        sv: res.sv,
                        bsv: res.bsv,
                        planning_steps: res.telemetry.planning_steps,
                    };
                    // A poisoned lock only means another worker panicked
                    // mid-store; the slot vector itself is still valid.
                    results[ci].lock().unwrap_or_else(|e| e.into_inner())[p] = Some(m);
                }
            });
        }
    });

    results
        .into_iter()
        .enumerate()
        .map(|(ci, m)| {
            m.into_inner()
                .unwrap_or_else(|e| e.into_inner())
                .into_iter()
                .enumerate()
                .map(|(p, r)| {
                    r.unwrap_or_else(|| {
                        panic!(
                            "permutation run missing: trainer #{ci} {:?} on permutation \
                             #{p}/{perms} (seed {seed}) — a worker exited before \
                             completing this (trainer, permutation) pair",
                            trainers[ci]
                        )
                    })
                })
                .collect()
        })
        .collect()
}

/// Column extractors for paired statistics.
pub fn times(ms: &[RunMeasurement]) -> Vec<f64> {
    ms.iter().map(|m| m.time_s).collect()
}
/// Iteration counts as a paired-statistics column.
pub fn iterations(ms: &[RunMeasurement]) -> Vec<f64> {
    ms.iter().map(|m| m.iterations as f64).collect()
}
/// Final objectives as a paired-statistics column.
pub fn objectives(ms: &[RunMeasurement]) -> Vec<f64> {
    ms.iter().map(|m| m.objective).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::chessboard;
    use crate::solver::engine::SolverChoice;

    #[test]
    fn paired_runs_cover_all_permutations_and_converge() {
        let ds = Arc::new(chessboard(120, 4, 1));
        let base = Trainer::rbf(10.0, 0.5);
        let cfgs = [
            base.clone().solver(SolverChoice::Smo),
            base.solver(SolverChoice::Pasmo),
        ];
        let res = run_permutations(&ds, &cfgs, 4, 7, 2);
        assert_eq!(res.len(), 2);
        for per_cfg in &res {
            assert_eq!(per_cfg.len(), 4);
            assert!(per_cfg.iter().all(|m| m.converged));
        }
        // paired: same permutation => same problem => close objectives
        for p in 0..4 {
            let rel = (res[0][p].objective - res[1][p].objective).abs()
                / (1.0 + res[0][p].objective.abs());
            assert!(rel < 5e-3, "perm {p}: {rel}");
        }
    }

    #[test]
    fn single_thread_and_multi_thread_agree_on_iterations() {
        let ds = Arc::new(chessboard(100, 4, 2));
        let cfgs = [Trainer::rbf(10.0, 0.5).solver(SolverChoice::Smo)];
        let a = run_permutations(&ds, &cfgs, 3, 5, 1);
        let b = run_permutations(&ds, &cfgs, 3, 5, 3);
        let ia: Vec<u64> = a[0].iter().map(|m| m.iterations).collect();
        let ib: Vec<u64> = b[0].iter().map(|m| m.iterations).collect();
        assert_eq!(ia, ib, "determinism across thread counts");
    }
}
