//! Deterministic, seedable PRNG: xoshiro256++ with splitmix64 seeding.
//!
//! Replaces the `rand` crate (unavailable offline). All experiment code
//! threads explicit seeds through this type so every run is reproducible.

/// xoshiro256++ generator (Blackman & Vigna). Passes BigCrush; more than
/// adequate for synthetic data generation and permutation sampling.
#[derive(Clone, Debug)]
pub struct Pcg {
    s: [u64; 4],
    /// Cached second output of the Box-Muller transform.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Pcg {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Pcg { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-thread / per-dataset seeding).
    pub fn fork(&mut self, tag: u64) -> Pcg {
        Pcg::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased integer in `[0, n)` (Lemire rejection).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Standard normal via Box-Muller (caches the spare value).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u == 0 so ln is finite.
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut g = Pcg::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = g.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_over_small_range() {
        let mut g = Pcg::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[g.below(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut g = Pcg::new(11);
        let n = 100_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = g.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut g = Pcg::new(13);
        let p = g.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn fork_streams_are_independent_of_parent_consumption() {
        let mut a = Pcg::new(5);
        let mut f1 = a.fork(1);
        let x: Vec<u64> = (0..4).map(|_| f1.next_u64()).collect();
        // forked stream differs from parent stream
        let y: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        assert_ne!(x, y);
    }
}
