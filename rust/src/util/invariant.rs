//! Feature-gated runtime invariants (`--features debug-invariants`).
//!
//! The solver stack layers shrinking permutations, an intrusive LRU row
//! cache and conjugate momentum on top of one shared `SolverState`; the
//! paper's convergence argument only holds while dual feasibility and
//! the perm/pos/cache bookkeeping stay exact. The [`invariant!`] macro
//! is the single assertion point for those properties: it compiles to
//! nothing in normal builds (zero overhead on the hot path) and to a
//! `panic!` with an `invariant violated:` prefix under
//! `--features debug-invariants`, which CI runs the full test suite
//! with. Checker methods (`SolverState::check_invariants`,
//! `RowCache::debug_validate`, the `tile::chunked` partition check, the
//! shrink/unshrink seam checks) are themselves compiled only under the
//! feature, so release binaries carry no checking code at all.
//!
//! Corruption tests assert the firing path with
//! `#[should_panic(expected = "invariant violated")]`.

/// Assert a runtime invariant in `debug-invariants` builds.
///
/// Expands to nothing unless the crate is compiled with
/// `--features debug-invariants`. On failure it panics with a message
/// prefixed `invariant violated:` (the condition itself when no message
/// is given, a `format!`-style message otherwise).
///
/// ```
/// let total = 2 + 2;
/// pasmo::invariant!(total == 4, "arithmetic drifted: {total}");
/// ```
#[macro_export]
macro_rules! invariant {
    ($cond:expr $(,)?) => {
        #[cfg(feature = "debug-invariants")]
        {
            if !($cond) {
                panic!("invariant violated: {}", stringify!($cond));
            }
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        #[cfg(feature = "debug-invariants")]
        {
            if !($cond) {
                panic!("invariant violated: {}", format_args!($($arg)*));
            }
        }
    };
}

/// True when `pos` is the inverse of the permutation `perm`: both are
/// the same length `l`, every entry is `< l`, and `pos[perm[k]] == k`
/// for every `k` (which forces both to be bijections on `0..l`).
pub fn inverse_permutation_ok(perm: &[usize], pos: &[usize]) -> bool {
    if perm.len() != pos.len() {
        return false;
    }
    let l = perm.len();
    (0..l).all(|k| perm[k] < l && pos[k] < l && pos[perm[k]] == k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inverse_permutation_accepts_identity_and_real_inverses() {
        assert!(inverse_permutation_ok(&[0, 1, 2], &[0, 1, 2]));
        assert!(inverse_permutation_ok(&[2, 0, 1], &[1, 2, 0]));
        assert!(inverse_permutation_ok(&[], &[]));
    }

    #[test]
    fn inverse_permutation_rejects_corruption() {
        // Length mismatch.
        assert!(!inverse_permutation_ok(&[0, 1], &[0, 1, 2]));
        // Out of range.
        assert!(!inverse_permutation_ok(&[0, 7], &[0, 1]));
        // Not inverse (pos is perm itself for a non-involution).
        assert!(!inverse_permutation_ok(&[1, 2, 0], &[1, 2, 0]));
        // Duplicate entry (not a bijection).
        assert!(!inverse_permutation_ok(&[0, 0, 2], &[0, 1, 2]));
    }

    #[cfg(not(feature = "debug-invariants"))]
    #[test]
    fn invariant_is_a_no_op_without_the_feature() {
        // Would panic under the feature; must be silent without it.
        crate::invariant!(false, "never evaluated");
        crate::invariant!(1 == 2);
    }

    #[cfg(feature = "debug-invariants")]
    mod armed {
        #[test]
        fn invariant_passes_silently_when_true() {
            crate::invariant!(1 + 1 == 2, "math broke");
            crate::invariant!(true);
        }

        #[test]
        #[should_panic(expected = "invariant violated")]
        fn invariant_fires_with_message() {
            crate::invariant!(1 == 2, "one is not {}", 2);
        }

        #[test]
        #[should_panic(expected = "invariant violated")]
        fn invariant_fires_without_message() {
            crate::invariant!(false);
        }
    }
}
