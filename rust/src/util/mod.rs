//! In-repo substrates replacing crates.io dependencies (offline build).

pub mod artifact;
pub mod cli;
pub mod error;
pub mod invariant;
pub mod json;
pub mod prng;
pub mod quickcheck;
pub mod table;
pub mod timer;
