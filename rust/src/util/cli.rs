//! Minimal command-line argument parser (replaces `clap`, offline build).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Subcommand dispatch is done by the caller on positionals.

use std::collections::BTreeMap;

/// Parsed command line: positionals in order plus `--key [value]` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order (subcommand first).
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (used by tests).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    match it.next() {
                        Some(v) => {
                            out.options.insert(rest.to_string(), v);
                        }
                        None => out.flags.push(rest.to_string()),
                    }
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Boolean flag (`--flag` present, or `--flag true/false`).
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
            || self
                .get(key)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }

    /// Typed option parse with default; panics with a clear message on
    /// malformed input (CLI surface, so fail fast and loud).
    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Debug,
    {
        match self.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{key} {v:?}: bad value ({e:?})")),
        }
    }

    /// Subcommand = first positional.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("experiment table2 --perms 20 --dataset chess-board-1000");
        assert_eq!(a.command(), Some("experiment"));
        assert_eq!(a.positional[1], "table2");
        assert_eq!(a.get("perms"), Some("20"));
        assert_eq!(a.get("dataset"), Some("chess-board-1000"));
    }

    #[test]
    fn equals_form() {
        let a = parse("train --c=10 --gamma=0.5");
        assert_eq!(a.get_parse_or("c", 0.0), 10.0);
        assert_eq!(a.get_parse_or("gamma", 0.0), 0.5);
    }

    #[test]
    fn trailing_flag_and_flag_before_positional() {
        let a = parse("run --verbose");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        // NB: `--verbose run` would consume `run` as a value; callers put
        // flags last or use `--verbose=true`. Document via this test:
        let b = parse("--full run");
        assert_eq!(b.get("full"), Some("run"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse("x");
        assert_eq!(a.get_parse_or("eps", 1e-3), 1e-3);
        assert_eq!(a.get_or("out", "report.md"), "report.md");
    }

    #[test]
    #[should_panic(expected = "bad value")]
    fn malformed_number_panics() {
        let a = parse("x --eps abc");
        let _: f64 = a.get_parse_or("eps", 0.0);
    }
}
