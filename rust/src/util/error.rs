//! Minimal error substrate replacing `anyhow` (offline build).
//!
//! Provides the small slice of `anyhow`'s API surface the codebase uses:
//! a chained [`Error`], a [`Result`] alias with a default error type, the
//! [`Context`] extension trait (`.context(..)` / `.with_context(|| ..)`
//! on both `Result` and `Option`), and the [`bail!`] / [`ensure!`]
//! macros. `Display` renders the whole chain outermost-first
//! (`open model.json: read /tmp/x: No such file or directory`), so
//! `{e}` and `{e:#}` both show the full story.
//!
//! The macros are `#[macro_export]`ed at the crate root: import them with
//! `use crate::{bail, ensure};` (or `use pasmo::{bail, ensure};` from the
//! binary and integration tests).
//!
//! [`bail!`]: crate::bail
//! [`ensure!`]: crate::ensure

use std::fmt;

/// A chained error: an innermost root message plus outer context frames.
pub struct Error {
    /// Context frames, outermost first.
    frames: Vec<String>,
    /// Root cause message.
    message: String,
}

/// `anyhow::Result`-style alias: error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a root message.
    pub fn msg(message: impl Into<String>) -> Error {
        Error { frames: Vec::new(), message: message.into() }
    }

    /// Wrap with an outer context frame.
    pub fn wrap(mut self, context: impl Into<String>) -> Error {
        self.frames.insert(0, context.into());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_message(&self) -> &str {
        &self.message
    }

    /// All frames, outermost context first, root message last.
    pub fn chain(&self) -> impl Iterator<Item = &str> + '_ {
        self.frames
            .iter()
            .map(String::as_str)
            .chain(std::iter::once(self.message.as_str()))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for frame in &self.frames {
            write!(f, "{frame}: ")?;
        }
        f.write_str(&self.message)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<String> for Error {
    fn from(message: String) -> Error {
        Error::msg(message)
    }
}

impl From<&str> for Error {
    fn from(message: &str) -> Error {
        Error::msg(message)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(|| ..)` to
/// `Result` (any `Display`-able error) and `Option`.
pub trait Context<T> {
    /// Attach a context frame (eagerly evaluated).
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Attach a context frame computed only on the error path.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e.to_string()).wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e.to_string()).wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context.to_string())),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f().to_string())),
        }
    }
}

/// Return early with an [`Error`] built from a format string
/// (`anyhow::bail!` equivalent).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an error unless the condition holds
/// (`anyhow::ensure!` equivalent).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err::<(), std::io::Error>(e)?;
        Ok(())
    }

    #[test]
    fn display_renders_chain_outermost_first() {
        let e = Error::msg("root").wrap("inner ctx").wrap("outer ctx");
        assert_eq!(e.to_string(), "outer ctx: inner ctx: root");
        assert_eq!(format!("{e:#}"), "outer ctx: inner ctx: root");
        assert_eq!(format!("{e:?}"), "outer ctx: inner ctx: root");
        assert_eq!(e.root_message(), "root");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer ctx", "inner ctx", "root"]);
    }

    #[test]
    fn result_context_wraps_any_display_error() {
        let r: Result<u32> = "12x".parse::<u32>().context("parse the count");
        let msg = r.unwrap_err().to_string();
        assert!(msg.starts_with("parse the count: "), "{msg}");
        assert!(msg.contains("invalid digit"), "{msg}");
    }

    #[test]
    fn with_context_is_lazy_on_ok() {
        let r: Result<u32> = Ok::<u32, Error>(7).with_context(|| -> String {
            panic!("context closure must not run on Ok")
        });
        assert_eq!(r.unwrap(), 7);
    }

    #[test]
    fn option_context_turns_none_into_error() {
        let r: Result<u32> = None.context("missing field");
        assert_eq!(r.unwrap_err().to_string(), "missing field");
        let r: Result<u32> = Some(3).context("unused");
        assert_eq!(r.unwrap(), 3);
    }

    #[test]
    fn io_errors_convert_via_question_mark() {
        let msg = fails_io().unwrap_err().to_string();
        assert!(msg.contains("gone"), "{msg}");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            ensure!(x != 13);
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-2).unwrap_err().to_string(), "negative input -2");
        assert_eq!(f(200).unwrap_err().to_string(), "too big: 200");
        assert!(f(13).unwrap_err().to_string().contains("x != 13"));
    }

    #[test]
    fn nested_context_through_result_flattens_text() {
        fn inner() -> Result<()> {
            bail!("root cause");
        }
        fn outer() -> Result<()> {
            inner().context("outer step")?;
            Ok(())
        }
        assert_eq!(outer().unwrap_err().to_string(), "outer step: root cause");
    }
}
