//! Minimal JSON parser/writer (replaces `serde_json`, offline build).
//!
//! Parses the artifact `MANIFEST.json` and serializes experiment reports.
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! held as f64 (sufficient: the manifest only contains small integers).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (held as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys — deterministic serialization).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// The key→value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_json_num(out, *n),
            Json::Str(s) => write_json_string(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (k, v) in a.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (k, (key, v)) in o.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    write_json_string(out, key);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Append `s` to `out` as a JSON string literal, quotes included.
///
/// This is the one escaping routine in the crate: everywhere a
/// user-provided string (a model name, an error message, a file path)
/// is embedded in JSON output — server responses, model files, bench
/// artifacts — it must pass through here so that `"`/`\`/control
/// characters cannot break the surrounding document.
///
/// ```
/// let mut s = String::new();
/// pasmo::util::json::write_json_string(&mut s, "a\"b\\c\nd");
/// assert_eq!(s, r#""a\"b\\c\nd""#);
/// ```
pub fn write_json_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Append `n` to `out` as a JSON number, with the crate's one number
/// policy: integer-valued floats render without a decimal point,
/// everything else via Rust's shortest round-trip `Display` (parsing the
/// text back recovers the identical f64 bits). [`Json::to_string`] and
/// the serving tier's hand-built response lines share this routine, so
/// a served decision value prints exactly as the offline artifacts do.
///
/// ```
/// let mut s = String::new();
/// pasmo::util::json::write_json_num(&mut s, 3.0);
/// pasmo::util::json::write_json_num(&mut s, 0.1);
/// assert_eq!(s, "30.1");
/// ```
pub fn write_json_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u hex")?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8")?;
                    let ch = rest.chars().next().ok_or("invalid utf8")?;
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect_byte(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect_byte(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "format": "hlo-text", "return_tuple": true,
          "artifacts": {
            "gram_q4_l2048_d64": {
              "file": "gram_q4_l2048_d64.hlo.txt",
              "arg_shapes": [[4, 64], [2048, 64], [1, 1]],
              "q": 4, "l": 2048, "d": 64
            }
          }
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text"));
        assert_eq!(v.get("return_tuple").unwrap().as_bool(), Some(true));
        let art = v.get("artifacts").unwrap().get("gram_q4_l2048_d64").unwrap();
        assert_eq!(art.get("l").unwrap().as_usize(), Some(2048));
        let shapes = art.get("arg_shapes").unwrap().as_arr().unwrap();
        assert_eq!(shapes[1].as_arr().unwrap()[0].as_usize(), Some(2048));
    }

    #[test]
    fn round_trip() {
        let doc = r#"{"a":[1,2.5,-3],"b":"x\ny","c":null,"d":false}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn writer_escapes_control_chars() {
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(s, r#""a\"b\\c\nd""#);
    }

    #[test]
    fn write_json_string_escapes_round_trip() {
        // Quotes, backslashes, every named escape, raw control chars and
        // a non-ASCII scalar: the emitted literal must parse back to the
        // identical string, and object *keys* go through the same path.
        let nasty = "q\"uote \\back\\slash\nnl\ttab\rcr \u{1}\u{1f} é ok";
        let mut lit = String::new();
        write_json_string(&mut lit, nasty);
        assert!(lit.starts_with('"') && lit.ends_with('"'));
        assert!(lit.contains(r#"\""#) && lit.contains(r"\\"));
        assert!(lit.contains("\\u0001") && lit.contains("\\u001f"));
        assert_eq!(Json::parse(&lit).unwrap().as_str(), Some(nasty));

        let mut obj = BTreeMap::new();
        obj.insert(nasty.to_string(), Json::Bool(true));
        let doc = Json::Obj(obj).to_string();
        let back = Json::parse(&doc).unwrap();
        assert_eq!(back.get(nasty).and_then(Json::as_bool), Some(true));
    }
}
