//! Plain-text / markdown table rendering for experiment reports.

/// Column alignment.
#[derive(Clone, Copy, PartialEq)]
pub enum Align {
    /// Left-aligned (labels, dataset names).
    Left,
    /// Right-aligned (numbers; the default).
    Right,
}

/// A simple table builder producing aligned monospace output (and markdown).
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers (all right-aligned).
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers.iter().map(|_| Align::Right).collect(),
            rows: Vec::new(),
        }
    }

    /// Set alignment per column (defaults to Right; first column often Left).
    pub fn align(mut self, aligns: &[Align]) -> Table {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    /// Append a row (must match the header arity).
    pub fn add_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as aligned monospace text.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_cell = |s: &str, width: usize, a: Align| -> String {
            let pad = width.saturating_sub(s.chars().count());
            match a {
                Align::Left => format!("{s}{}", " ".repeat(pad)),
                Align::Right => format!("{}{s}", " ".repeat(pad)),
            }
        };
        let line: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| fmt_cell(h, w[i], self.aligns[i]))
            .collect();
        out.push_str(&line.join("  "));
        out.push('\n');
        out.push_str(&w.iter().map(|n| "-".repeat(*n)).collect::<Vec<_>>().join("  "));
        out.push('\n');
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| fmt_cell(c, w[i], self.aligns[i]))
                .collect();
            out.push_str(line.join("  ").trim_end());
            out.push('\n');
        }
        out
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        let seps: Vec<&str> = self
            .aligns
            .iter()
            .map(|a| match a {
                Align::Left => ":---",
                Align::Right => "---:",
            })
            .collect();
        out.push_str(&format!("| {} |\n", seps.join(" | ")));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format a float with `digits` significant-ish decimals, trimming noise.
pub fn fnum(x: f64, digits: usize) -> String {
    if !x.is_finite() {
        return format!("{x}");
    }
    if x != 0.0 && x.abs() < 10f64.powi(-(digits as i32)) {
        return format!("{x:.*e}", digits);
    }
    format!("{x:.*}", digits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["dataset", "iters"]).align(&[Align::Left, Align::Right]);
        t.add_row(vec!["banana".into(), "23295".into()]);
        t.add_row(vec!["x".into(), "1".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].starts_with("dataset"));
        assert!(lines[2].starts_with("banana"));
        assert!(lines[3].trim_end().ends_with("1"));
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(&["a", "b"]);
        t.add_row(vec!["1".into(), "2".into()]);
        let md = t.render_markdown();
        assert_eq!(md.lines().count(), 3);
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.add_row(vec!["1".into()]);
    }

    #[test]
    fn fnum_behaviour() {
        assert_eq!(fnum(1.23456, 2), "1.23");
        assert_eq!(fnum(0.0, 2), "0.00");
        assert!(fnum(1e-9, 3).contains('e'));
    }
}
