//! Crash-safe artifact IO: atomic temp-file + rename writes with an
//! embedded content checksum verified on load.
//!
//! Every artifact the system persists (model files, solver checkpoints,
//! `BENCH_*.json` reports) goes through [`save_json`]: the document is
//! stamped with an FNV-1a 64 checksum over its canonical serialization,
//! written to a temporary file *in the same directory* as the target,
//! flushed, and only then renamed into place. A crash or injected IO
//! fault at any point leaves either the old artifact or nothing — never
//! a half-written file. [`load_json`] re-verifies the checksum when the
//! field is present (older artifacts without one still load), so silent
//! on-disk corruption is refused with a clear error instead of being
//! parsed into a subtly wrong model.
//!
//! ```
//! use pasmo::util::artifact;
//! use pasmo::util::json::Json;
//! use std::collections::BTreeMap;
//!
//! let dir = std::env::temp_dir().join("pasmo-artifact-doc");
//! std::fs::create_dir_all(&dir).unwrap();
//! let path = dir.join("doc.json");
//! let mut obj = BTreeMap::new();
//! obj.insert("answer".to_string(), Json::Num(42.0));
//! artifact::save_json(&path, Json::Obj(obj)).unwrap();
//! let doc = artifact::load_json(&path).unwrap();
//! assert_eq!(doc.get("answer").and_then(|v| v.as_f64()), Some(42.0));
//! assert!(doc.get("checksum").is_some());
//! std::fs::remove_file(&path).unwrap();
//! ```

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::faults;
use crate::util::error::{Context, Error, Result};
use crate::util::json::Json;

/// Name of the checksum field stamped into saved JSON artifacts.
pub const CHECKSUM_FIELD: &str = "checksum";

/// FNV-1a 64-bit hash of a byte string (the artifact checksum basis).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Render a checksum as the stored field value (`fnv1a:` + 16 hex digits).
fn checksum_string(h: u64) -> String {
    format!("fnv1a:{h:016x}")
}

/// Distinguishes concurrent writers targeting the same path so their
/// temporary files never collide.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

fn tmp_path_for(path: &Path) -> PathBuf {
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "artifact".to_string());
    let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp_name = format!(".{name}.tmp.{}.{seq}", std::process::id());
    match path.parent() {
        Some(dir) if !dir.as_os_str().is_empty() => dir.join(tmp_name),
        _ => PathBuf::from(tmp_name),
    }
}

/// Write `bytes` to `path` atomically: a temporary sibling file is
/// written and flushed first, then renamed over the target. On any
/// failure the temporary file is removed and the target is untouched.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = tmp_path_for(path);
    let attempt = (|| -> std::io::Result<()> {
        faults::maybe_io_error("artifact.write")?;
        let mut f = fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        faults::maybe_io_error("artifact.sync")?;
        f.sync_all()?;
        drop(f);
        fs::rename(&tmp, path)
    })();
    if let Err(e) = attempt {
        let _ = fs::remove_file(&tmp);
        return Err(Error::msg(e.to_string()))
            .with_context(|| format!("write {}", path.display()));
    }
    Ok(())
}

/// Stamp a JSON object with its content checksum and write it
/// atomically. The checksum covers the canonical serialization of the
/// document *without* the checksum field, so [`load_json`] can recompute
/// and compare it.
pub fn save_json(path: &Path, doc: Json) -> Result<()> {
    let mut obj = match doc {
        Json::Obj(obj) => obj,
        other => {
            let mut text = other.to_string();
            text.push('\n');
            return write_atomic(path, text.as_bytes());
        }
    };
    obj.remove(CHECKSUM_FIELD);
    let stripped = Json::Obj(obj);
    let sum = checksum_string(fnv1a64(stripped.to_string().as_bytes()));
    let mut obj = match stripped {
        Json::Obj(obj) => obj,
        _ => return Err(Error::msg("artifact document must be an object")),
    };
    obj.insert(CHECKSUM_FIELD.to_string(), Json::Str(sum));
    let mut text = Json::Obj(obj).to_string();
    text.push('\n');
    write_atomic(path, text.as_bytes())
}

/// Verify the embedded checksum of a parsed artifact, if present.
///
/// Documents without a `checksum` field pass (artifacts written before
/// checksumming existed, and hand-written fixtures). A present field
/// must be a `fnv1a:<16 hex>` string matching the recomputed hash of the
/// document minus the field.
pub fn verify_checksum(doc: &Json) -> Result<()> {
    let Json::Obj(obj) = doc else { return Ok(()) };
    let Some(field) = obj.get(CHECKSUM_FIELD) else { return Ok(()) };
    let stored = field
        .as_str()
        .context("checksum field: expected a string")?;
    let hex = stored
        .strip_prefix("fnv1a:")
        .with_context(|| format!("checksum field: unknown scheme in {stored:?}"))?;
    let want = u64::from_str_radix(hex, 16)
        .with_context(|| format!("checksum field: bad hex in {stored:?}"))?;
    let mut stripped = obj.clone();
    stripped.remove(CHECKSUM_FIELD);
    let got = fnv1a64(Json::Obj(stripped).to_string().as_bytes());
    if got != want {
        bail_checksum(want, got)?;
    }
    Ok(())
}

fn bail_checksum(want: u64, got: u64) -> Result<()> {
    Err(Error::msg(format!(
        "checksum mismatch: stored {}, computed {} (artifact corrupted or truncated)",
        checksum_string(want),
        checksum_string(got)
    )))
}

/// Read and parse a JSON artifact, verifying its checksum when present.
/// Parse errors carry the byte position reported by the parser; checksum
/// failures name both hashes.
pub fn load_json(path: &Path) -> Result<Json> {
    let text =
        fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
    let doc = Json::parse(&text)
        .map_err(|e| Error::msg(format!("parse {}: {e}", path.display())))?;
    verify_checksum(&doc).with_context(|| format!("load {}", path.display()))?;
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "pasmo-artifact-{tag}-{}",
            std::process::id()
        ));
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn small_doc() -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("kind".to_string(), Json::Str("test".to_string()));
        obj.insert("n".to_string(), Json::Num(3.0));
        Json::Obj(obj)
    }

    #[test]
    fn fnv1a_matches_known_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn save_then_load_round_trips_and_verifies() {
        let dir = tmp_dir("roundtrip");
        let path = dir.join("doc.json");
        save_json(&path, small_doc()).unwrap();
        let doc = load_json(&path).unwrap();
        assert_eq!(doc.get("kind").and_then(|v| v.as_str()), Some("test"));
        let sum = doc.get(CHECKSUM_FIELD).and_then(|v| v.as_str()).unwrap();
        assert!(sum.starts_with("fnv1a:"), "{sum}");
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corrupted_artifact_is_refused() {
        let dir = tmp_dir("corrupt");
        let path = dir.join("doc.json");
        save_json(&path, small_doc()).unwrap();
        let text = fs::read_to_string(&path).unwrap();
        fs::write(&path, text.replace("\"n\":3", "\"n\":4")).unwrap();
        let err = load_json(&path).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncated_artifact_reports_a_positioned_parse_error() {
        let dir = tmp_dir("truncate");
        let path = dir.join("doc.json");
        save_json(&path, small_doc()).unwrap();
        let text = fs::read_to_string(&path).unwrap();
        fs::write(&path, &text[..text.len() / 2]).unwrap();
        let err = load_json(&path).unwrap_err().to_string();
        assert!(err.contains("parse"), "{err}");
        assert!(err.contains("byte"), "positioned error expected: {err}");
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn checksum_less_documents_still_load() {
        let dir = tmp_dir("legacy");
        let path = dir.join("doc.json");
        fs::write(&path, "{\"kind\":\"legacy\"}").unwrap();
        let doc = load_json(&path).unwrap();
        assert_eq!(doc.get("kind").and_then(|v| v.as_str()), Some("legacy"));
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn write_atomic_replaces_without_leaving_temp_files() {
        let dir = tmp_dir("atomic");
        let path = dir.join("doc.json");
        write_atomic(&path, b"one").unwrap();
        write_atomic(&path, b"two").unwrap();
        assert_eq!(fs::read_to_string(&path).unwrap(), "two");
        let leftovers: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");
        fs::remove_file(&path).unwrap();
    }
}
