//! Mini property-testing harness (replaces `proptest`, offline build).
//!
//! Deterministic: every case derives from a fixed master seed, and a
//! failing case reports the case index + seed so it can be replayed with
//! [`replay`]. No shrinking — generators are written to produce small
//! cases with reasonable probability instead.

use super::prng::Pcg;

/// Master seed for all property tests (override per-call if needed).
pub const MASTER_SEED: u64 = 0x5EED_CAFE_F00D_D00D;

/// Run `prop` on `cases` generated inputs. Panics with the case seed and
/// the counterexample's Debug rendering on the first failure.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    gen: impl Fn(&mut Pcg) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    forall_seeded(name, MASTER_SEED, cases, gen, prop)
}

/// Like [`forall`] with an explicit master seed.
pub fn forall_seeded<T: std::fmt::Debug>(
    name: &str,
    master_seed: u64,
    cases: usize,
    gen: impl Fn(&mut Pcg) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut master = Pcg::new(master_seed);
    for case in 0..cases {
        let case_seed = master.next_u64();
        let mut rng = Pcg::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay seed {case_seed:#x}):\n  {msg}\n  input: {input:#?}"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn replay<T: std::fmt::Debug>(
    case_seed: u64,
    gen: impl Fn(&mut Pcg) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) -> Result<(), String> {
    let mut rng = Pcg::new(case_seed);
    prop(&gen(&mut rng))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        forall(
            "abs-nonneg",
            50,
            |g| g.normal(),
            |x| {
                counter.set(counter.get() + 1);
                if x.abs() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative abs".into())
                }
            },
        );
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        forall(
            "always-fails-eventually",
            20,
            |g| g.below(10),
            |&x| if x < 9 { Ok(()) } else { Err(format!("x={x}")) },
        );
    }

    #[test]
    fn replay_reproduces_case() {
        // Find a failing seed first, then check replay gives the same verdict.
        let mut master = Pcg::new(MASTER_SEED);
        let mut failing = None;
        for _ in 0..100 {
            let seed = master.next_u64();
            let mut rng = Pcg::new(seed);
            if rng.below(10) == 9 {
                failing = Some(seed);
                break;
            }
        }
        let seed = failing.expect("no failing case in 100 draws?!");
        let res = replay(
            seed,
            |g| g.below(10),
            |&x| if x < 9 { Ok(()) } else { Err(format!("x={x}")) },
        );
        assert!(res.is_err());
    }
}
