//! Timing utilities and a micro-bench harness (replaces `criterion`).
//!
//! `cargo bench` targets in `rust/benches/` are `harness = false` binaries
//! built on [`bench`] / [`Stopwatch`]; they print the rows/series of the
//! paper table or figure they regenerate.

use std::time::{Duration, Instant};

/// Simple stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    /// Time since start/restart.
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
    /// Elapsed time in seconds.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
    /// Return the elapsed time and restart from zero.
    pub fn restart(&mut self) -> Duration {
        let e = self.0.elapsed();
        self.0 = Instant::now();
        e
    }
}

/// Result of a micro-benchmark run.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Standard deviation of the per-iteration seconds.
    pub std_s: f64,
    /// Fastest iteration in seconds.
    pub min_s: f64,
}

impl BenchResult {
    /// Human-readable line, criterion-style.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (± {:>10}, min {:>10}, n={})",
            self.name,
            fmt_duration(self.mean_s),
            fmt_duration(self.std_s),
            fmt_duration(self.min_s),
            self.iters
        )
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` repeatedly: warm up, then sample `samples` timed repetitions and
/// report mean/std/min. The closure's return value is black-boxed to keep
/// the optimizer honest.
pub fn bench<T>(name: &str, samples: usize, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup: one call, plus enough to cover ~50ms for tiny closures.
    let w = Stopwatch::start();
    black_box(f());
    let one = w.secs().max(1e-9);
    let warmups = ((0.05 / one) as usize).clamp(0, 50);
    for _ in 0..warmups {
        black_box(f());
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t = Stopwatch::start();
        black_box(f());
        times.push(t.secs());
    }
    let n = times.len() as f64;
    let mean = times.iter().sum::<f64>() / n;
    let var = times.iter().map(|t| (t - mean).powi(2)).sum::<f64>() / n;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    BenchResult {
        name: name.to_string(),
        iters: times.len(),
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: min,
    }
}

/// Optimizer barrier (stable-Rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", 10, || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert_eq!(r.iters, 10);
        assert!(r.mean_s > 0.0 && r.mean_s < 0.1);
        assert!(r.min_s <= r.mean_s);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.0), "2.000 s");
        assert_eq!(fmt_duration(0.0025), "2.500 ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500 µs");
        assert!(fmt_duration(3e-9).ends_with("ns"));
    }

    #[test]
    fn stopwatch_monotone() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let e1 = sw.restart();
        assert!(e1.as_millis() >= 2);
        assert!(sw.secs() < 1.0);
    }
}
