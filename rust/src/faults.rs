//! Deterministic fault injection for chaos testing.
//!
//! The server and artifact layers call the `maybe_*` hooks at their
//! failure seams (`artifact.write`, `server.score_group`, …). With the
//! `fault-injection` cargo feature **off** — the default — every hook is
//! an empty `#[inline(always)]` function: zero code, zero branches, zero
//! cost. With the feature on, a process-global *fault plan* decides,
//! deterministically, which hit of which site fires.
//!
//! # Fault-plan grammar
//!
//! A plan is a comma-separated list of entries:
//!
//! ```text
//! plan  := entry (',' entry)*
//! entry := site '@' hit ('x' count)?
//! ```
//!
//! `site@N` fires the fault on the Nth hit of `site` (1-based), once.
//! `site@NxM` fires on hits N through N+M−1. Sites are plain strings
//! chosen by the instrumented code; hits are counted per site from the
//! last [`reset`]. Example: `artifact.write@2,server.score_group@1x3`
//! fails the second artifact write and the first three scored batches.
//!
//! Plans are installed programmatically with [`set_plan`] (chaos tests)
//! or inherited from the `PASMO_FAULT_PLAN` environment variable at the
//! first hook hit (child processes under test). The same plan always
//! produces the same faults — no wall clock, no RNG at fire time.

#[cfg(feature = "fault-injection")]
mod armed {
    use std::collections::BTreeMap;
    use std::sync::Mutex;

    /// One parsed plan entry: fire at hits `[hit, hit + count)` of `site`.
    #[derive(Debug, Clone)]
    struct Entry {
        site: String,
        hit: u64,
        count: u64,
    }

    #[derive(Default)]
    struct PlanState {
        entries: Vec<Entry>,
        hits: BTreeMap<String, u64>,
        /// Env plan already consulted (avoid re-reading on every hit).
        env_loaded: bool,
    }

    fn state() -> &'static Mutex<PlanState> {
        static STATE: Mutex<PlanState> = Mutex::new(PlanState {
            entries: Vec::new(),
            hits: BTreeMap::new(),
            env_loaded: false,
        });
        &STATE
    }

    fn lock() -> std::sync::MutexGuard<'static, PlanState> {
        state().lock().unwrap_or_else(|p| p.into_inner())
    }

    fn parse(plan: &str) -> Result<Vec<Entry>, String> {
        let mut entries = Vec::new();
        for raw in plan.split(',') {
            let raw = raw.trim();
            if raw.is_empty() {
                continue;
            }
            let (site, spec) = raw
                .split_once('@')
                .ok_or_else(|| format!("fault entry {raw:?}: expected site@hit"))?;
            let (hit_s, count_s) = match spec.split_once('x') {
                Some((h, c)) => (h, c),
                None => (spec, "1"),
            };
            let hit: u64 = hit_s
                .parse()
                .map_err(|_| format!("fault entry {raw:?}: bad hit number {hit_s:?}"))?;
            let count: u64 = count_s
                .parse()
                .map_err(|_| format!("fault entry {raw:?}: bad count {count_s:?}"))?;
            if hit == 0 {
                return Err(format!("fault entry {raw:?}: hits are 1-based"));
            }
            entries.push(Entry { site: site.trim().to_string(), hit, count });
        }
        Ok(entries)
    }

    pub fn set_plan(plan: &str) -> Result<(), String> {
        let entries = parse(plan)?;
        let mut st = lock();
        st.entries = entries;
        st.hits.clear();
        st.env_loaded = true; // explicit plan overrides the environment
        Ok(())
    }

    pub fn reset() {
        let mut st = lock();
        st.entries.clear();
        st.hits.clear();
        st.env_loaded = true;
    }

    /// Count a hit of `site` and report whether a fault fires on it.
    pub fn fired(site: &str) -> bool {
        let mut st = lock();
        if !st.env_loaded {
            st.env_loaded = true;
            if let Ok(plan) = std::env::var("PASMO_FAULT_PLAN") {
                if let Ok(entries) = parse(&plan) {
                    st.entries = entries;
                }
            }
        }
        if st.entries.is_empty() {
            return false;
        }
        let hit = st.hits.entry(site.to_string()).or_insert(0);
        *hit += 1;
        let n = *hit;
        st.entries
            .iter()
            .any(|e| e.site == site && n >= e.hit && n < e.hit + e.count)
    }
}

/// Install a fault plan (see the module docs for the grammar), replacing
/// any previous plan and resetting all per-site hit counters. Only
/// meaningful with the `fault-injection` feature; a no-op returning `Ok`
/// otherwise.
pub fn set_plan(plan: &str) -> Result<(), String> {
    #[cfg(feature = "fault-injection")]
    return armed::set_plan(plan);
    #[cfg(not(feature = "fault-injection"))]
    {
        let _ = plan;
        Ok(())
    }
}

/// Clear the fault plan and all hit counters.
pub fn reset() {
    #[cfg(feature = "fault-injection")]
    armed::reset();
}

/// Injected IO failure seam. Returns an `Err` styled like a real IO
/// error when the plan fires at `site`; `Ok(())` otherwise (and always,
/// with the feature off).
#[inline(always)]
pub fn maybe_io_error(site: &str) -> std::io::Result<()> {
    #[cfg(feature = "fault-injection")]
    if armed::fired(site) {
        return Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            format!("injected IO fault at {site}"),
        ));
    }
    let _ = site;
    Ok(())
}

/// Injected panic seam (used inside the scoring loop to test panic
/// containment). Panics when the plan fires at `site`.
#[inline(always)]
pub fn maybe_panic(site: &str) {
    #[cfg(feature = "fault-injection")]
    if armed::fired(site) {
        panic!("injected panic at {site}");
    }
    let _ = site;
}

/// Injected latency seam: sleeps 25 ms when the plan fires at `site`
/// (models a stalled peer or slow disk without touching the clock
/// elsewhere).
#[inline(always)]
pub fn maybe_delay(site: &str) {
    #[cfg(feature = "fault-injection")]
    if armed::fired(site) {
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    let _ = site;
}

#[cfg(all(test, feature = "fault-injection"))]
mod tests {
    use super::*;
    use std::sync::{Mutex, MutexGuard};

    /// The plan is process-global: serialize the tests that touch it.
    fn guard() -> MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn plan_fires_on_the_exact_hit_window() {
        let _g = guard();
        set_plan("io.test@2x2").unwrap();
        assert!(maybe_io_error("io.test").is_ok()); // hit 1
        assert!(maybe_io_error("io.test").is_err()); // hit 2
        assert!(maybe_io_error("io.test").is_err()); // hit 3
        assert!(maybe_io_error("io.test").is_ok()); // hit 4
        assert!(maybe_io_error("other.site").is_ok());
        reset();
    }

    #[test]
    fn reset_clears_counters_and_entries() {
        let _g = guard();
        set_plan("io.reset@1").unwrap();
        assert!(maybe_io_error("io.reset").is_err());
        reset();
        assert!(maybe_io_error("io.reset").is_ok());
    }

    #[test]
    fn bad_plans_are_rejected_with_a_reason() {
        let _g = guard();
        assert!(set_plan("no-at-sign").unwrap_err().contains("site@hit"));
        assert!(set_plan("site@0").unwrap_err().contains("1-based"));
        assert!(set_plan("site@x2").unwrap_err().contains("bad hit"));
        reset();
    }

    #[test]
    fn injected_panic_carries_the_site_name() {
        let _g = guard();
        set_plan("panic.here@1").unwrap();
        let err = std::panic::catch_unwind(|| maybe_panic("panic.here")).unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("panic.here"), "{msg}");
        reset();
    }
}
