//! `pasmo` — command-line launcher for the PA-SMO training system.
//!
//! Subcommands:
//! * `datasets` — list the benchmark suite (paper Table 1).
//! * `train` — train an SVM (native or PJRT kernel path) and save a model.
//! * `predict` — evaluate a saved model on a LIBSVM file.
//! * `gridsearch` — (C, γ) grid search with cross-validation.
//! * `bench` — solver perf baseline (wall time, kernel entries, hit rate).
//! * `serve` — persistent micro-batching TCP inference tier (newline-
//!   delimited JSON; responses bit-match offline `predict`).
//! * `experiment <id>` — regenerate a paper table/figure or comparison:
//!   `table1 | table2 | fig2 | fig3 | fig4 | wss | heuristic |
//!   engine_shootout | all`.
//! * `audit` — the repo's own source-tree lint (see `src/audit`).
//! * `info` — environment / artifact status.
//!
//! `pasmo --help`, `pasmo <command> --help` and `pasmo help <command>`
//! print the flag reference; `tests/cli.rs` asserts the help text covers
//! every flag the code reads, so new flags cannot go undocumented.

use std::path::Path;
use std::sync::Arc;

use pasmo::coordinator::experiments::{self, ExpOptions};
use pasmo::coordinator::report::Report;
use pasmo::data::{libsvm, suite, Dataset};
use pasmo::solver::{Checkpoint, StopReason};
use pasmo::svm::multiclass::OvoModel;
use pasmo::svm::oneclass::OneClassModel;
use pasmo::svm::platt::PlattScaler;
use pasmo::svm::predict::accuracy;
use pasmo::svm::schema::{self, AnyModel};
use pasmo::svm::svr::SvrModel;
use pasmo::svm::trainer::TrainOutcome;
use pasmo::svm::{SolverChoice, SvmModel, Trainer};
use pasmo::util::cli::Args;
use pasmo::util::error::{Context, Result};
use pasmo::{bail, ensure};

fn main() {
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    // `pasmo --help`, `pasmo <cmd> --help`, `pasmo help [<cmd>]`.
    if args.flag("help") || args.command() == Some("help") {
        let target = if args.command() == Some("help") {
            args.positional.get(1).map(|s| s.as_str())
        } else {
            args.command()
        };
        match target.and_then(subcommand_help) {
            Some(text) => println!("{text}"),
            None => print_usage(),
        }
        return Ok(());
    }
    // Global `--simd auto|force|off` (same values as `PASMO_SIMD`):
    // pick the kernel-tile implementation once, before any subcommand
    // touches the dispatch. `force` on a CPU without AVX2 is a hard
    // error rather than a silent scalar fallback.
    if let Some(spec) = args.get("simd") {
        use pasmo::kernel::tile::simd::{self, SimdMode};
        let mode = SimdMode::parse(spec)
            .with_context(|| format!("--simd {spec:?}: expected auto, force, or off"))?;
        ensure!(
            simd::set_simd_mode(mode),
            "--simd force: this CPU does not support the AVX2 tile (use auto or off)"
        );
    }
    match args.command() {
        Some("datasets") => cmd_datasets(),
        Some("train") => cmd_train(args),
        Some("predict") => cmd_predict(args),
        Some("gridsearch") => cmd_gridsearch(args),
        Some("bench") => cmd_bench(args),
        Some("serve") => cmd_serve(args),
        Some("experiment") => cmd_experiment(args),
        Some("audit") => cmd_audit(args),
        Some("info") => cmd_info(),
        _ => {
            print_usage();
            Ok(())
        }
    }
}

/// Shared flag descriptions (referenced from several subcommand pages).
const HELP_DATA_FLAGS: &str = "\
  --dataset NAME        synthetic-suite dataset (see `pasmo datasets`)\n\
  --libsvm FILE         load a LIBSVM-format file instead (streaming reader:\n\
                        one line at a time into CSR, never a dense matrix)\n\
  --storage MODE        auto | dense | sparse — feature storage for --libsvm\n\
                        (auto keeps CSR at ≤ 25% stored density; default auto)\n\
  --mmap                parse --libsvm from one whole-file buffer instead of\n\
                        buffered line-at-a-time streaming (same dataset)\n\
  --len N               generated dataset size ℓ (suite datasets only)\n\
  --seed S              generation / protocol seed (default 42)";

const HELP_SOLVER_FLAG: &str = "\
  --solver NAME         smo | pasmo | pasmo-multi:N | conjugate\n\
                        (pasmo = planning-ahead, the default;\n\
                         conjugate = conjugate-direction SMO)";

/// The full flag reference for one subcommand. Every flag a subcommand
/// reads must appear here — `tests/cli.rs` enforces the parity.
fn subcommand_help(cmd: &str) -> Option<String> {
    let body = match cmd {
        "datasets" => "usage: pasmo datasets\n\n\
             List the synthetic benchmark suite standing in for the paper's\n\
             22 datasets: name, paper ℓ, the paper's (C, γ) and SV/BSV counts.\n\
             Takes no flags (--help prints this page)."
            .to_string(),
        "train" => format!(
            "usage: pasmo train (--dataset NAME | --libsvm FILE) [options]\n\n\
             Train an SVM classifier and optionally save the model.\n\n\
             data:\n{HELP_DATA_FLAGS}\n\n\
             model:\n\
               --c C                 regularization constant (default: paper value or 1)\n\
               --gamma G             RBF kernel width (default: paper value or 0.5)\n\
               --w-pos W / --w-neg W per-class cost multipliers C₊ = W·C, C₋ (imbalanced data)\n\n\
             solver:\n{HELP_SOLVER_FLAG}\n\
               --eps E               KKT stopping accuracy (default 1e-3)\n\
               --threads N           kernel-row worker threads (bit-identical results)\n\n\
             crash safety:\n\
               --checkpoint FILE     snapshot the solve to FILE (atomic temp+rename,\n\
                                     checksummed); with --checkpoint-iters the file is\n\
                                     rewritten every N iterations, otherwise once at the\n\
                                     end — a kill never leaves a partial file\n\
               --checkpoint-iters N  checkpoint cadence in iterations (0 = final only)\n\
               --resume FILE         warm-start from a checkpoint written against the\n\
                                     same data (α is clamped/repaired to the current\n\
                                     box, so C / weights may differ); iteration counts\n\
                                     continue from the snapshot\n\n\
             output / backend:\n\
               --probability         fit Platt (A, B) on the training set and save it\n\
                                     in the model (enables `pasmo predict --probability`)\n\
               --out model.json      save the trained model\n\
               --runtime pjrt        use the PJRT kernel path (needs the `pjrt` feature)"
        ),
        "predict" => "usage: pasmo predict --model model.json --libsvm FILE [options]\n\n\
             Evaluate a saved model on a LIBSVM file. The model file's kind\n\
             tag picks the task; all four kinds score through the shared\n\
             batch engine (blocked SV×query tiles, linear primal collapse).\n\n\
               --model FILE          model JSON produced by `pasmo train --out` or the\n\
                                     library save() of SVR / one-class / multiclass models\n\
               --libsvm FILE         evaluation data (targets for svr, class ids for\n\
                                     multiclass, ±1 with +1 = inlier for oneclass)\n\
               --storage MODE        auto | dense | sparse feature storage for the\n\
                                     evaluation file (classify/oneclass; default auto)\n\
               --mmap                whole-file-buffer parse instead of streaming\n\
               --task NAME           classify | svr | oneclass | multiclass — assert the\n\
                                     model kind (defaults to whatever the file holds)\n\
               --threads N           batch-scoring worker threads (bit-identical results)\n\
               --probability         classify only: per-example P(y=+1) and log-loss\n\
                                     (needs a model trained with --probability)\n\
               --out FILE            write per-example predictions"
            .to_string(),
        "gridsearch" => format!(
            "usage: pasmo gridsearch (--dataset NAME | --libsvm FILE) [options]\n\n\
             (C, γ) grid search on k-fold cross-validation accuracy. By default\n\
             the grid is warm-started: one CvSession threads each fold's α from\n\
             grid point to grid point (fewer total iterations, same accuracies).\n\n\
             data:\n{HELP_DATA_FLAGS}\n\n\
             search:\n\
               --folds K             cross-validation folds (default 4)\n\
               --cold                disable warm-starting (every point from α = 0)\n\n\
             solver:\n{HELP_SOLVER_FLAG}\n\
               --threads N           kernel-row worker threads"
        ),
        "bench" => format!(
            "usage: pasmo bench [options]\n\n\
             Solver perf baseline: wall time, iterations, kernel entries and\n\
             cache hit rate per (dataset × solver × shrinking) cell. The cache\n\
             is sized in rows so the kernel/cache layer is actually exercised.\n\n\
               --datasets a,b,c      suite datasets (default chess-board-1000,banana)\n\
               --len N               dataset size ℓ (default 600)\n\
               --seed S              generation seed (default 42)\n\
               --threads N           kernel-row worker threads\n\
               --cache-rows R        cache budget in rows (default ℓ/4)\n\
               --shrink-interval I   shrink check period (0 = solver default)\n\
               --out FILE            write BENCH_solver.json trajectory artifact\n\n\
             solver (default: the smo,pasmo pair — shrink on and off each):\n{HELP_SOLVER_FLAG}\n\n\
             predict mode:\n\
               --predict             benchmark batch scoring instead: scalar loop vs\n\
                                     tiled vs threaded scorer vs linear collapse\n\
                                     (queries/s + kernel-entry columns; --out writes\n\
                                     BENCH_predict.json; --datasets takes the first\n\
                                     name, --len sizes both the model and the queries,\n\
                                     --threads the threaded row)\n\n\
             sparse mode:\n\
               --sparse              density sweep instead: train + score synthetic\n\
                                     sparse data at stored densities 1.0 / 0.1 / 0.001\n\
                                     (the lowest at 10× --len rows), reporting rows/s\n\
                                     and resident bytes vs the dense twin — the run\n\
                                     fails if CSR storage does not beat dense at low\n\
                                     density (--out writes the sweep; --dim sets the\n\
                                     feature dimension, default 2000)\n\
               --dim D               sparse-sweep feature dimension\n\n\
             serve mode:\n\
               --serve               benchmark the serving tier instead: per\n\
                                     --batches config, bind an in-process server\n\
                                     and drive it open-loop at a fixed arrival\n\
                                     rate; reports queries/s and p50/p99 latency\n\
                                     (--out writes BENCH_serve.json; --len sizes\n\
                                     the model, --threads the scoring pass)\n\
               --rate R              offered load, queries/second (default 2000)\n\
               --queries N           total queries per config (default 2000)\n\
               --conns N             client connections (default 4)\n\
               --batches a,b,c       max-batch configs to sweep (default 1,8,64)\n\
               --max-wait-us U       admission window in µs (default 200)\n\
               --max-queue N         admission queue bound (default 0 = unbounded);\n\
                                     shed queries are counted per config\n\
               --deadline-us U       per-query deadline in µs (default 0 = none);\n\
                                     expired queries are counted per config\n\n\
             baseline mode (the CI perf gate — DESIGN.md §4g):\n\
               --save-baseline       measure the tiny fixed train+predict workload\n\
                                     (medians of 5 reps) and record it into the\n\
                                     checksummed baseline artifact\n\
               --check-baseline      re-measure and fail (exit nonzero) when any\n\
                                     committed metric regresses beyond its noise\n\
                                     tolerance — tight for deterministic counters,\n\
                                     loose for wall-clock; a missing or empty\n\
                                     baseline bootstraps (measures, saves, passes)\n\
               --baseline FILE       the baseline artifact (default\n\
                                     BENCH_baseline.json; --len/--seed size the\n\
                                     workload in both modes)"
        ),
        "serve" => "usage: pasmo serve --model FILE[,NAME=FILE...] [options]\n\n\
             Persistent micro-batching inference tier: a std-only TCP server\n\
             speaking newline-delimited JSON, one request object per line.\n\
             Connection threads admit queries into a shared queue; a single\n\
             scoring loop drains micro-batches and scores each in one tiled\n\
             SV×query pass per model — responses are bit-identical to\n\
             offline `pasmo predict` on the same inputs.\n\n\
               --model SPEC          comma-separated models to preload; each entry\n\
                                     is FILE or NAME=FILE (the name defaults to\n\
                                     the file stem). Any schema kind serves:\n\
                                     svc, svr, oneclass, multiclass.\n\
               --addr HOST:PORT      listen address (default 127.0.0.1:7878;\n\
                                     port 0 binds an ephemeral port — the bound\n\
                                     address is printed on startup)\n\
               --max-batch N         micro-batch admission cap (default 64)\n\
               --max-wait-us U       admission window in µs after a batch's\n\
                                     first query arrives (default 200)\n\
               --threads N           scoring worker threads per batch pass\n\
               --f32-sv              opt into the packed-f32 SV fast path: each\n\
                                     loaded machine is accuracy-gated at load time\n\
                                     (worst decision delta over its own SVs vs the\n\
                                     exact f64 tile) and scores through packed f32\n\
                                     only where it passes; dense×dense only, exact\n\
                                     path everywhere else\n\n\
             overload handling (see DESIGN.md §4e):\n\
               --max-queue N         admission queue bound (default 1024; 0 = unbounded).\n\
                                     Queries arriving at a full queue get an explicit\n\
                                     `overloaded` error reply instead of queueing\n\
               --deadline-us U       per-query deadline in µs (0 = none). Queries that\n\
                                     out-wait it in the queue are answered\n\
                                     `deadline_exceeded` and never scored\n\
               --max-conns N         concurrent connection cap (0 = unlimited); over-\n\
                                     capacity connections get one polite error line.\n\
                                     Established connections are never dropped\n\n\
             protocol (one JSON object per line, responses in request order):\n\
               {\"x\":[..], \"model\":\"name\"?, \"id\":n?}    score a query\n\
               {\"x\":{\"7\":0.5,..}, ...}                  sparse query: 1-based\n\
                                                         index → value, omitted\n\
                                                         features are 0 (scores\n\
                                                         bit-match the dense form)\n\
               {\"cmd\":\"stats\"}                           per-model metrics\n\
               {\"cmd\":\"models\"}                          registry listing\n\
               {\"cmd\":\"load\",\"name\":..,\"path\":..}       load / hot-swap\n\
               {\"cmd\":\"shutdown\"}                        drain and exit"
            .to_string(),
        "experiment" => "usage: pasmo experiment <id> [options]\n\n\
             Regenerate a paper table/figure or engine comparison. Ids:\n\
               table1           dataset statistics (SV/BSV vs paper)\n\
               table2           SMO vs PA-SMO, paired permutations + Wilcoxon\n\
               fig2             the gain parabola (pure analytics)\n\
               fig3             planning-step size histograms\n\
               fig4             multiple planning-ahead (N recent working sets)\n\
               wss              §7.2 WSS-only ablation\n\
               heuristic        §7.3 fixed 1.1× over-relaxation\n\
               engine_shootout  SMO vs PA-SMO vs Conjugate SMO, paired + Wilcoxon\n\
               all              everything above\n\n\
             protocol:\n\
               --perms N             random permutations per dataset (default 10)\n\
               --scale S             dataset scale relative to the paper's ℓ\n\
               --max-len N           hard ℓ cap in fast mode (0 = none)\n\
               --full                complete 22-dataset suite at paper sizes\n\
               --datasets a,b,c      restrict to these datasets\n\
               --eps E               stopping accuracy (default 1e-3)\n\
               --seed S              master seed (default 42)\n\
               --threads N           permutation fan-out worker threads\n\
               --out report.md       save the rendered report"
            .to_string(),
        "audit" => "usage: pasmo audit [options]\n\n\
             Run the repo's own source-tree lint: no panics in library\n\
             paths, SAFETY comments on every unsafe block, no float\n\
             literal ==/!= comparisons, thread spawning only in the\n\
             sanctioned concurrency seams (kernel::tile, coordinator::jobs\n\
             and the server:: tier), no HashMap iteration, no printing from\n\
             the library crate. Violations not excused by the allowlist\n\
             (and allowlist entries matching nothing) exit nonzero.\n\n\
               --src DIR             source tree to scan (default: this crate's src/)\n\
               --allowlist FILE      allowlist of excused findings, one\n\
                                     `path:rule:content` entry per line (default:\n\
                                     audit.allow next to Cargo.toml; missing = empty)"
            .to_string(),
        "info" => "usage: pasmo info\n\n\
             Print version, available threads and PJRT artifact status.\n\
             Takes no flags (--help prints this page)."
            .to_string(),
        _ => return None,
    };
    Some(body)
}

fn print_usage() {
    println!(
        "pasmo — planning-ahead SMO SVM training system\n\
         \n\
         usage: pasmo <command> [options]\n\
         \n\
         commands:\n\
           datasets                          list the benchmark suite\n\
           train      --dataset NAME | --libsvm FILE [--c C --gamma G]\n\
                      [--solver smo|pasmo|pasmo-multi:N|conjugate] [--eps E]\n\
                      [--w-pos W --w-neg W] (per-class cost multipliers)\n\
                      [--threads N] (kernel-row worker threads)\n\
                      [--probability] (save Platt calibration in the model)\n\
                      [--checkpoint ck.json --checkpoint-iters N] (crash-safe\n\
                       periodic snapshots) [--resume ck.json] (continue one)\n\
                      [--len N --seed S] [--runtime pjrt] [--out model.json]\n\
           predict    --model model.json --libsvm FILE\n\
                      [--task classify|svr|oneclass|multiclass] [--threads N]\n\
                      [--probability] [--out predictions.txt]\n\
           gridsearch --dataset NAME [--len N] [--folds K] [--cold]\n\
                      [--solver NAME] [--threads N]\n\
           bench      [--datasets a,b,c] [--len N] [--seed S] [--threads N]\n\
                      [--cache-rows R] [--shrink-interval I] [--solver NAME]\n\
                      [--out BENCH_solver.json] [--predict] [--serve]\n\
                      solver perf baseline: wall time, iterations, kernel\n\
                      entries, cache hit rate — shrink on vs off; --predict\n\
                      benchmarks batch scoring into BENCH_predict.json;\n\
                      --serve saturates the serving tier open-loop\n\
                      ([--rate R --queries N --conns N --batches a,b,c])\n\
                      into BENCH_serve.json; --save-baseline /\n\
                      --check-baseline [--baseline FILE] run the persistent\n\
                      perf gate against BENCH_baseline.json\n\
           serve      --model FILE[,NAME=FILE...] [--addr HOST:PORT]\n\
                      [--max-batch N] [--max-wait-us U] [--threads N]\n\
                      [--max-queue N] [--deadline-us U] [--max-conns N]\n\
                      [--f32-sv] (accuracy-gated packed-f32 fast path)\n\
                      micro-batching TCP inference tier (newline-delimited\n\
                      JSON; responses bit-match offline predict; bounded\n\
                      admission sheds overload explicitly)\n\
           experiment table1|table2|fig2|fig3|fig4|wss|heuristic|\n\
                      engine_shootout|all\n\
                      [--perms N --scale S --max-len N --full\n\
                       --datasets a,b,c --eps E --seed S --out report.md]\n\
           audit      [--src DIR] [--allowlist FILE]\n\
                      the repo's own source lint (panic-free library paths,\n\
                      SAFETY comments, float comparisons, thread scope)\n\
           info                              environment / artifact status\n\
         \n\
         global:\n\
           --simd auto|force|off             kernel-tile implementation: auto\n\
                      (AVX2 when the CPU has it — the default), force (error\n\
                      if unsupported), off (scalar tile). Same values as the\n\
                      PASMO_SIMD environment variable; SIMD and scalar tiles\n\
                      are bit-identical (DESIGN.md §4g)\n\
         \n\
         `pasmo <command> --help` (or `pasmo help <command>`) prints the\n\
         complete flag reference for one command."
    );
}

fn load_dataset(args: &Args) -> Result<(Arc<Dataset>, Option<suite::DatasetSpec>)> {
    if let Some(name) = args.get("dataset") {
        let spec = suite::find(name)
            .with_context(|| format!("unknown dataset {name:?} (see `pasmo datasets`)"))?;
        let len = args.get_parse_or("len", spec.paper_len.min(2000));
        let seed = args.get_parse_or("seed", 42u64);
        Ok((Arc::new(spec.generate(len, seed)), Some(spec)))
    } else if let Some(file) = args.get("libsvm") {
        let ds = read_libsvm_file(args, Path::new(file), None)?;
        Ok((Arc::new(ds), None))
    } else {
        bail!("need --dataset NAME or --libsvm FILE");
    }
}

/// Read a binary-classification LIBSVM file honoring the shared
/// `--storage auto|dense|sparse` and `--mmap` flags.
fn read_libsvm_file(args: &Args, path: &Path, force_dim: Option<usize>) -> Result<Dataset> {
    let storage = libsvm::Storage::parse(&args.get_or("storage", "auto"))?;
    if args.flag("mmap") {
        libsvm::read_mapped(path, force_dim, storage)
    } else {
        libsvm::read_with(path, force_dim, storage)
    }
}

fn parse_solver(s: &str) -> Result<SolverChoice> {
    Ok(match s {
        "smo" => SolverChoice::Smo,
        "pasmo" => SolverChoice::Pasmo,
        "conjugate" => SolverChoice::ConjugateSmo,
        other => {
            if let Some(n) = other.strip_prefix("pasmo-multi:") {
                SolverChoice::PasmoMulti(n.parse().context("bad N in pasmo-multi:N")?)
            } else {
                bail!("unknown solver {other:?} (smo | pasmo | pasmo-multi:N | conjugate)");
            }
        }
    })
}

fn solver_choice(args: &Args) -> Result<SolverChoice> {
    parse_solver(&args.get_or("solver", "pasmo"))
}

fn cmd_datasets() -> Result<()> {
    use pasmo::util::table::{Align, Table};
    let mut t = Table::new(&["name", "ℓ(paper)", "C", "γ", "SV(paper)", "BSV(paper)"])
        .align(&[
            Align::Left,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
        ]);
    for s in suite::suite() {
        t.add_row(vec![
            s.name.into(),
            s.paper_len.to_string(),
            format!("{}", s.c),
            format!("{}", s.gamma),
            s.paper_sv.to_string(),
            s.paper_bsv.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let (ds, spec) = load_dataset(args)?;
    let c = args.get_parse_or("c", spec.as_ref().map(|s| s.c).unwrap_or(1.0));
    let gamma = args.get_parse_or("gamma", spec.as_ref().map(|s| s.gamma).unwrap_or(0.5));
    let trainer = Trainer::rbf(c, gamma)
        .solver(solver_choice(args)?)
        .stop_eps(args.get_parse_or("eps", 1e-3))
        .threads(args.get_parse_or("threads", 1usize))
        .class_weights(
            args.get_parse_or("w-pos", 1.0),
            args.get_parse_or("w-neg", 1.0),
        );

    // Crash safety: --checkpoint snapshots the solve so a kill loses at
    // most --checkpoint-iters of progress, and --resume continues from
    // the last snapshot through the ordinary warm-start path.
    let checkpoint_path = args.get("checkpoint").map(Path::new);
    let checkpoint_iters = args.get_parse_or("checkpoint-iters", 0u64);
    let mut base_iters = 0u64;
    let trainer = if let Some(resume) = args.get("resume") {
        let ck = Checkpoint::load(Path::new(resume))?;
        ensure!(
            ck.alpha.len() == ds.len(),
            "cannot resume: {resume} snapshots α for ℓ={} but this dataset \
             has ℓ={} (resuming needs the same data in the same order)",
            ck.alpha.len(),
            ds.len()
        );
        base_iters = ck.iterations;
        println!(
            "resuming from {resume}: {} iterations done, objective {:.6}",
            ck.iterations, ck.objective
        );
        trainer.warm_start(ck.alpha)
    } else {
        trainer
    };

    let chunked = checkpoint_path.is_some() && checkpoint_iters > 0;
    let TrainOutcome { mut model, result: mut res } =
        match (args.get("runtime"), checkpoint_path) {
            (Some("pjrt"), _) => train_pjrt(&ds, &trainer, gamma)?,
            (_, Some(ck)) if checkpoint_iters > 0 => {
                train_checkpointed(&trainer, &ds, ck, checkpoint_iters, base_iters)?
            }
            _ => trainer.train(&ds),
        };
    if !chunked {
        // the chunked path already reports cumulative iterations
        res.iterations += base_iters;
    }
    if let (Some(ck), false) = (checkpoint_path, chunked) {
        // --checkpoint without a cadence: leave one final resumable
        // snapshot (same atomic, checksummed write as the periodic one)
        Checkpoint {
            alpha: res.alpha.clone(),
            iterations: res.iterations,
            objective: res.objective,
            eps: trainer.solver_config.eps,
        }
        .save(ck)?;
        println!("checkpoint saved to {}", ck.display());
    }
    if args.flag("probability") {
        // One batch scoring pass over the training set calibrates the
        // sigmoid; the (A, B) pair is saved inside the model file.
        let p = PlattScaler::fit_model(&model, &ds);
        println!("Platt calibration fitted: A={:.6} B={:.6}", p.a, p.b);
        model.platt = Some(p);
    }

    println!(
        "trained on ℓ={} d={} | C={c} γ={gamma} solver={:?}\n\
         iterations={} time={:.3}s objective={:.6} gap={:.2e} converged={} stop={}\n\
         SV={} BSV={} free/bounded/planning/conjugate steps = {}/{}/{}/{}\n\
         train accuracy = {:.4}",
        ds.len(),
        ds.dim(),
        trainer.solver,
        res.iterations,
        res.wall_time_s,
        res.objective,
        res.gap,
        res.converged,
        res.stop_reason,
        res.sv,
        res.bsv,
        res.telemetry.free_steps,
        res.telemetry.bounded_steps,
        res.telemetry.planning_steps,
        res.telemetry.conjugate_steps,
        accuracy(&model, &ds),
    );
    if let Some(out) = args.get("out") {
        model.save(Path::new(out))?;
        println!("model saved to {out}");
    }
    Ok(())
}

/// Chunked crash-safe training: run the solve `every` iterations at a
/// time, warm-starting each chunk from the previous chunk's α and
/// rewriting `path` atomically (checksummed temp file + rename) after
/// every chunk. A kill at any moment loses at most one chunk of
/// progress; `pasmo train --resume PATH` continues from the snapshot.
/// `base` carries the iteration count of a resumed checkpoint so the
/// snapshots and the returned result report cumulative iterations.
fn train_checkpointed(
    trainer: &Trainer,
    ds: &Arc<Dataset>,
    path: &Path,
    every: u64,
    base: u64,
) -> Result<TrainOutcome> {
    let full_cap = trainer.solver_config.max_iter;
    let mut done = base;
    let mut chunked = trainer.clone();
    loop {
        let mut cfg = chunked.solver_config;
        cfg.max_iter = match full_cap {
            0 => every,
            cap => every.min(cap.saturating_sub(done)).max(1),
        };
        chunked = chunked.solver_config(cfg);
        let mut outcome = chunked.train(ds);
        done += outcome.result.iterations;
        Checkpoint {
            alpha: outcome.result.alpha.clone(),
            iterations: done,
            objective: outcome.result.objective,
            eps: cfg.eps,
        }
        .save(path)?;
        // keep going only when the *chunk* cap cut the solve short; a
        // converged chunk (or the caller's own --max-iter budget spent)
        // ends the loop with that chunk's outcome
        let chunk_cap_only = outcome.result.stop_reason == StopReason::IterLimit
            && (full_cap == 0 || done < full_cap);
        if !chunk_cap_only {
            outcome.result.iterations = done;
            return Ok(outcome);
        }
        chunked = chunked.warm_start(outcome.result.alpha);
    }
}

/// Train over the PJRT kernel path (the `--runtime pjrt` flag).
#[cfg(feature = "pjrt")]
fn train_pjrt(ds: &Arc<Dataset>, trainer: &Trainer, gamma: f64) -> Result<TrainOutcome> {
    use pasmo::runtime::engine::PjrtEngine;
    use pasmo::runtime::gram::PjrtRowComputer;
    let engine = std::rc::Rc::new(PjrtEngine::open_default().context(
        "open PJRT artifacts (run `make artifacts`, or set PASMO_ARTIFACTS)",
    )?);
    let computer = PjrtRowComputer::new(engine, ds.clone(), gamma)?;
    Ok(trainer.train_with_computer(ds, Box::new(computer)))
}

/// Without the `pjrt` feature the runtime module is not compiled at all;
/// requesting it is a clean CLI error, and everything else falls back to
/// the native Rust kernel path.
#[cfg(not(feature = "pjrt"))]
fn train_pjrt(_ds: &Arc<Dataset>, _trainer: &Trainer, _gamma: f64) -> Result<TrainOutcome> {
    bail!(
        "--runtime pjrt requires a build with the `pjrt` feature \
         (cargo build --features pjrt); rerun without --runtime for the \
         native kernel path"
    );
}

fn cmd_predict(args: &Args) -> Result<()> {
    let model_path = args.get("model").context("need --model model.json")?;
    let file = args.get("libsvm").context("need --libsvm FILE")?;
    let threads = args.get_parse_or("threads", 1usize);
    let any = schema::load_any(Path::new(model_path))?;
    if let Some(task) = args.get("task") {
        ensure!(
            task == any.task_name(),
            "--task {task} requested but {model_path} holds a {:?} model",
            any.task_name()
        );
    }
    let out = args.get("out");
    let probability = args.flag("probability");
    ensure!(
        !probability || matches!(&any, AnyModel::Svc(_)),
        "--probability is only available for classify models (this file holds {:?})",
        any.task_name()
    );
    match &any {
        AnyModel::Svc(model) => predict_classify(model, args, file, threads, probability, out),
        AnyModel::Svr(model) => predict_svr(model, file, threads, out),
        AnyModel::OneClass(model) => predict_oneclass(model, args, file, threads, out),
        AnyModel::Multiclass(model) => predict_multiclass(model, file, threads, out),
    }
}

/// Write one value per line to `out` (shared by the predict tasks).
fn write_column<T: std::fmt::Display>(out: Option<&str>, values: &[T]) -> Result<()> {
    if let Some(out) = out {
        let mut text = String::new();
        for v in values {
            text.push_str(&format!("{v}\n"));
        }
        std::fs::write(out, text).with_context(|| format!("write predictions {out}"))?;
        println!("predictions written to {out}");
    }
    Ok(())
}

/// `pasmo predict` on a binary classifier: one batch scoring pass
/// drives accuracy, the confusion counts and (with `--probability` and
/// a Platt-calibrated model) per-example probabilities.
fn predict_classify(
    model: &SvmModel,
    args: &Args,
    file: &str,
    threads: usize,
    probability: bool,
    out: Option<&str>,
) -> Result<()> {
    use pasmo::svm::predict::evaluate;
    let ds = read_libsvm_file(args, Path::new(file), Some(model.support.dim()))?;
    let ev = evaluate(model, &ds, threads);
    let (tp, fp, tn, fnn) = ev.confusion;
    println!(
        "classified {} examples with {} SVs (threads={threads}): accuracy = {:.4}\n\
         confusion: tp={tp} fp={fp} tn={tn} fn={fnn}",
        ds.len(),
        model.n_sv(),
        ev.accuracy
    );
    let probs = if probability {
        let platt = model.platt.as_ref().context(
            "model has no Platt calibration; retrain with `pasmo train --probability`",
        )?;
        let probs = platt.prob_all(&ev.decisions);
        let n = probs.len().max(1) as f64;
        let mean = probs.iter().sum::<f64>() / n;
        let log_loss = probs
            .iter()
            .enumerate()
            .map(|(i, &p)| {
                let p = p.clamp(1e-15, 1.0 - 1e-15);
                if ds.label(i) == 1 {
                    -p.ln()
                } else {
                    -(1.0 - p).ln()
                }
            })
            .sum::<f64>()
            / n;
        println!("mean P(y=+1) = {mean:.4}  log-loss = {log_loss:.4}");
        Some(probs)
    } else {
        None
    };
    if out.is_some() {
        // Full-precision decisions (shortest round-trip Display): the
        // file is the offline half of the serve-parity contract, so a
        // reader can recover the exact f64 bits.
        let lines: Vec<String> = (0..ds.len())
            .map(|i| match &probs {
                Some(p) => {
                    format!("{} {} {}", ev.predictions[i], ev.decisions[i], p[i])
                }
                None => format!("{} {}", ev.predictions[i], ev.decisions[i]),
            })
            .collect();
        write_column(out, &lines)?;
    }
    Ok(())
}

/// `pasmo predict` on an ε-SVR model: batch predictions, RMSE and MAE
/// against the file's real-valued targets.
fn predict_svr(model: &SvrModel, file: &str, threads: usize, out: Option<&str>) -> Result<()> {
    let data = libsvm::read_regression(Path::new(file), Some(model.support.dim()))?;
    let preds = model.predict_all(&data, threads);
    let n = data.len().max(1) as f64;
    let (mut se, mut ae) = (0f64, 0f64);
    for (p, t) in preds.iter().zip(data.targets()) {
        se += (p - t) * (p - t);
        ae += (p - t).abs();
    }
    println!(
        "predicted {} targets with {} SVs (threads={threads}): rmse = {:.6}  mae = {:.6}",
        data.len(),
        model.n_sv(),
        (se / n).sqrt(),
        ae / n
    );
    write_column(out, &preds)
}

/// `pasmo predict` on a one-class model: inlier fraction plus agreement
/// with the file's ±1 labels (+1 = inlier ground truth).
fn predict_oneclass(
    model: &OneClassModel,
    args: &Args,
    file: &str,
    threads: usize,
    out: Option<&str>,
) -> Result<()> {
    let data = read_libsvm_file(args, Path::new(file), Some(model.support.dim()))?;
    let decisions = model.decision_values(&data, threads);
    let n = data.len().max(1) as f64;
    let inliers = decisions.iter().filter(|&&f| f >= 0.0).count();
    let agree = (0..data.len())
        .filter(|&i| (decisions[i] >= 0.0) == (data.label(i) == 1))
        .count();
    println!(
        "scored {} examples with {} SVs (threads={threads}): inlier fraction = {:.4}  \
         label agreement = {:.4}",
        data.len(),
        model.n_sv(),
        inliers as f64 / n,
        agree as f64 / n
    );
    write_column(out, &decisions)
}

/// `pasmo predict` on a one-vs-one multiclass model: every machine
/// scores the whole batch once, votes decide the class.
fn predict_multiclass(
    model: &OvoModel,
    file: &str,
    threads: usize,
    out: Option<&str>,
) -> Result<()> {
    let dim = model.machines[0].support.dim();
    let data = libsvm::read_multiclass(Path::new(file), Some(dim))?;
    let preds = model.predict_all(&data, threads);
    let n = data.len().max(1) as f64;
    let correct = preds
        .iter()
        .enumerate()
        .filter(|&(i, &p)| p == data.label(i))
        .count();
    println!(
        "classified {} examples over {} classes with {} machines (threads={threads}): \
         accuracy = {:.4}",
        data.len(),
        model.classes.len(),
        model.machines.len(),
        correct as f64 / n
    );
    write_column(out, &preds)
}

fn cmd_gridsearch(args: &Args) -> Result<()> {
    use pasmo::svm::gridsearch::{grid_search, log_grid, WarmStart};
    let (ds, spec) = load_dataset(args)?;
    let folds = args.get_parse_or("folds", 4usize);
    let warm = if args.flag("cold") { WarmStart::Cold } else { WarmStart::Seeded };
    let base = Trainer::rbf(1.0, 1.0)
        .solver(solver_choice(args)?)
        .threads(args.get_parse_or("threads", 1usize));
    let res = grid_search(
        &ds,
        &log_grid(10.0, -1, 3),
        &log_grid(10.0, -3, 1),
        folds,
        args.get_parse_or("seed", 42u64),
        &base,
        warm,
    );
    for p in &res.evaluated {
        println!(
            "C={:<8} γ={:<8} cv-acc={:.4} iters={}",
            p.c, p.gamma, p.cv_accuracy, p.iterations
        );
    }
    println!(
        "\nbest: C={} γ={} cv-acc={:.4}  (paper used C={} γ={})\n\
         total solver iterations: {} ({})",
        res.best.c,
        res.best.gamma,
        res.best.cv_accuracy,
        spec.as_ref().map(|s| s.c).unwrap_or(f64::NAN),
        spec.as_ref().map(|s| s.gamma).unwrap_or(f64::NAN),
        res.total_iterations,
        if warm == WarmStart::Seeded { "warm-started; --cold to compare" } else { "cold" },
    );
    Ok(())
}

/// Solver perf baseline (`pasmo bench`): wall time, iterations, kernel
/// entries and cache hit rate per (dataset × solver × shrinking) cell,
/// printed as a table and optionally written as `BENCH_solver.json` so
/// future changes have a trajectory to compare against. The cache is
/// deliberately sized in rows (default ℓ/4) so the kernel/cache layer is
/// actually exercised — with LIBSVM's 100 MB default the tiny synthetic
/// problems fit entirely and every run degenerates to one pass.
fn cmd_bench(args: &Args) -> Result<()> {
    use pasmo::solver::SolverConfig;
    use pasmo::util::json::Json;
    use std::collections::BTreeMap;

    if args.flag("save-baseline") || args.flag("check-baseline") {
        return cmd_bench_baseline(args);
    }
    if args.flag("sparse") {
        return cmd_bench_sparse(args);
    }
    if args.flag("predict") {
        return cmd_bench_predict(args);
    }
    if args.flag("serve") {
        return cmd_bench_serve(args);
    }

    let len = args.get_parse_or("len", 600usize);
    let seed = args.get_parse_or("seed", 42u64);
    let threads = args.get_parse_or("threads", 1usize);
    let cache_rows = args.get_parse_or("cache-rows", (len / 4).max(8));
    let cache_bytes = cache_rows * len * std::mem::size_of::<f32>();
    // 0 = the solver default min(ℓ, 1000); tiny-scale runs pass a smaller
    // period so shrinking engages within their short solves.
    let shrink_interval = args.get_parse_or("shrink-interval", 0usize);
    let names: Vec<String> = match args.get("datasets") {
        Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
        None => vec!["chess-board-1000".into(), "banana".into()],
    };
    // Default matrix: the paper's smo/pasmo pair; `--solver NAME` (any
    // engine, incl. `conjugate`) restricts the run to that one engine.
    let solvers: Vec<(String, SolverChoice)> = match args.get("solver") {
        Some(name) => vec![(name.to_string(), parse_solver(name)?)],
        None => vec![
            ("smo".to_string(), SolverChoice::Smo),
            ("pasmo".to_string(), SolverChoice::Pasmo),
        ],
    };

    println!("==== pasmo bench (solver baseline) ====");
    println!(
        "ℓ={len} seed={seed} threads={threads} cache={cache_rows} rows\n"
    );
    println!(
        "{:<18} {:<6} {:>7} {:>9} {:>9} {:>14} {:>9}",
        "dataset", "solver", "shrink", "time", "iters", "kernel-entries", "hit-rate"
    );

    let mut runs: Vec<Json> = Vec::new();
    for name in &names {
        let spec = suite::find(name)
            .with_context(|| format!("unknown dataset {name:?} (see `pasmo datasets`)"))?;
        let ds = Arc::new(spec.generate(len, seed));
        for (solver_name, choice) in &solvers {
            let choice = *choice;
            for shrinking in [true, false] {
                let trainer = Trainer::rbf(spec.c, spec.gamma)
                    .solver(choice)
                    .solver_config(SolverConfig {
                        shrinking,
                        threads,
                        cache_bytes,
                        shrink_interval,
                        ..Default::default()
                    });
                let res = trainer.train(&ds).result;
                println!(
                    "{:<18} {:<6} {:>7} {:>8.3}s {:>9} {:>14} {:>8.1}%",
                    name,
                    solver_name,
                    if shrinking { "on" } else { "off" },
                    res.wall_time_s,
                    res.iterations,
                    res.kernel_entries,
                    100.0 * res.cache_stats.hit_rate()
                );
                let mut obj = BTreeMap::new();
                obj.insert("dataset".into(), Json::Str(name.clone()));
                obj.insert("solver".into(), Json::Str(solver_name.clone()));
                obj.insert("shrinking".into(), Json::Bool(shrinking));
                obj.insert("converged".into(), Json::Bool(res.converged));
                obj.insert("wall_time_s".into(), Json::Num(res.wall_time_s));
                obj.insert("iterations".into(), Json::Num(res.iterations as f64));
                obj.insert("kernel_entries".into(), Json::Num(res.kernel_entries as f64));
                obj.insert("objective".into(), Json::Num(res.objective));
                obj.insert("sv".into(), Json::Num(res.sv as f64));
                obj.insert("cache_hits".into(), Json::Num(res.cache_stats.hits as f64));
                obj.insert("cache_misses".into(), Json::Num(res.cache_stats.misses as f64));
                obj.insert(
                    "cache_evictions".into(),
                    Json::Num(res.cache_stats.evictions as f64),
                );
                obj.insert("cache_hit_rate".into(), Json::Num(res.cache_stats.hit_rate()));
                runs.push(Json::Obj(obj));
            }
        }
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("solver".into()));
    doc.insert("len".into(), Json::Num(len as f64));
    doc.insert("seed".into(), Json::Num(seed as f64));
    doc.insert("threads".into(), Json::Num(threads as f64));
    doc.insert("cache_rows".into(), Json::Num(cache_rows as f64));
    doc.insert("shrink_interval".into(), Json::Num(shrink_interval as f64));
    doc.insert("runs".into(), Json::Arr(runs));
    let doc = Json::Obj(doc);
    if let Some(out) = args.get("out") {
        // atomic + checksummed, like every other artifact: a killed
        // bench never leaves a truncated BENCH_*.json behind
        pasmo::util::artifact::save_json(Path::new(out), doc)
            .with_context(|| format!("write bench report {out}"))?;
        println!("\nreport written to {out}");
    }
    Ok(())
}

/// Predict-throughput baseline (`pasmo bench --predict`): queries/s and
/// kernel entries per full scoring pass for the seed's scalar per-SV
/// loop, the tiled batch scorer, the threaded scorer, and the linear
/// kernel with and without the primal collapse — printed as a table and
/// optionally written as `BENCH_predict.json` (the inference-side
/// trajectory artifact next to `BENCH_solver.json`).
fn cmd_bench_predict(args: &Args) -> Result<()> {
    use pasmo::kernel::KernelFunction;
    use pasmo::util::json::Json;
    use pasmo::util::timer::{black_box, Stopwatch};
    use std::collections::BTreeMap;

    let len = args.get_parse_or("len", 600usize);
    let seed = args.get_parse_or("seed", 42u64);
    let threads = args.get_parse_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    );
    let name = match args.get("datasets") {
        Some(list) => list.split(',').next().unwrap_or("chess-board-1000").trim().to_string(),
        None => "chess-board-1000".to_string(),
    };
    let spec = suite::find(&name)
        .with_context(|| format!("unknown dataset {name:?} (see `pasmo datasets`)"))?;
    let train_set = Arc::new(spec.generate(len, seed));
    let queries = spec.generate(len, seed.wrapping_add(1));
    let model = Trainer::rbf(spec.c, spec.gamma).train(&train_set).model;
    // Same expansion under the linear kernel exercises the collapse path
    // (throughput only — the decision surface is irrelevant here).
    let linear = SvmModel {
        kernel: KernelFunction::Linear,
        support: model.support.clone(),
        coef: model.coef.clone(),
        bias: model.bias,
        platt: None,
    };
    let n_sv = model.n_sv();
    let q = queries.len();

    println!("==== pasmo bench --predict (scoring baseline) ====");
    println!("dataset={name} ℓ={len} queries={q} SVs={n_sv} threads={threads}\n");
    println!(
        "{:<18} {:>12} {:>14} {:>16}",
        "mode", "s/pass", "queries/s", "kernel-entries"
    );

    // Mean seconds per full scoring pass (1 warmup + `reps` timed).
    fn time_pass(reps: usize, mut pass: impl FnMut() -> f64) -> f64 {
        black_box(pass());
        let mut total = 0.0;
        for _ in 0..reps {
            let t = Stopwatch::start();
            black_box(pass());
            total += t.secs();
        }
        total / reps as f64
    }

    let scalar_pass = |m: &SvmModel| {
        // The seed's per-example, per-SV loop — the pre-scorer baseline.
        let mut acc = 0.0;
        for i in 0..queries.len() {
            let x = queries.row(i);
            let mut f = m.bias;
            for s in 0..m.support.len() {
                f += m.coef[s] * m.kernel.eval(m.support.row(s), x);
            }
            acc += f;
        }
        acc
    };

    let reps = 5usize;
    let full_entries = (q * n_sv) as f64;
    // (mode, kernel, seconds per pass, kernel entries per pass)
    let mut rows: Vec<(String, String, f64, f64)> = Vec::new();
    rows.push((
        "scalar".into(),
        "rbf".into(),
        time_pass(reps, || scalar_pass(&model)),
        full_entries,
    ));
    let tiled = model.scorer();
    rows.push((
        "tiled".into(),
        "rbf".into(),
        time_pass(reps, || tiled.decision_values(&queries).iter().sum()),
        full_entries,
    ));
    let threaded = model.scorer().with_threads(threads);
    rows.push((
        "threaded".into(),
        "rbf".into(),
        time_pass(reps, || threaded.decision_values(&queries).iter().sum()),
        full_entries,
    ));
    let lin_exp = linear.scorer().collapse_linear(false);
    rows.push((
        "linear".into(),
        "linear".into(),
        time_pass(reps, || lin_exp.decision_values(&queries).iter().sum()),
        full_entries,
    ));
    let lin_col = linear.scorer();
    rows.push((
        "linear-collapse".into(),
        "linear".into(),
        time_pass(reps, || lin_col.decision_values(&queries).iter().sum()),
        0.0,
    ));

    let mut runs: Vec<Json> = Vec::new();
    for (mode, kernel, s_per_pass, entries) in &rows {
        println!(
            "{:<18} {:>11.6}s {:>14.1} {:>16}",
            mode,
            s_per_pass,
            q as f64 / s_per_pass,
            *entries as u64
        );
        let mut obj = BTreeMap::new();
        obj.insert("mode".into(), Json::Str(mode.clone()));
        obj.insert("kernel".into(), Json::Str(kernel.clone()));
        obj.insert("wall_s_per_pass".into(), Json::Num(*s_per_pass));
        obj.insert("queries_per_s".into(), Json::Num(q as f64 / s_per_pass));
        obj.insert("kernel_entries_per_pass".into(), Json::Num(*entries));
        runs.push(Json::Obj(obj));
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("predict".into()));
    doc.insert("dataset".into(), Json::Str(name));
    doc.insert("len".into(), Json::Num(len as f64));
    doc.insert("queries".into(), Json::Num(q as f64));
    doc.insert("n_sv".into(), Json::Num(n_sv as f64));
    doc.insert("seed".into(), Json::Num(seed as f64));
    doc.insert("threads".into(), Json::Num(threads as f64));
    doc.insert("runs".into(), Json::Arr(runs));
    let doc = Json::Obj(doc);
    if let Some(out) = args.get("out") {
        pasmo::util::artifact::save_json(Path::new(out), doc)
            .with_context(|| format!("write bench report {out}"))?;
        println!("\nreport written to {out}");
    }
    Ok(())
}

/// Density-sweep benchmark (`pasmo bench --sparse`): train + one batch
/// scoring pass on synthetic sparse data at stored densities 1.0, 0.1
/// and 0.001 — the lowest at 10× the row count, where dense storage
/// starts to hurt. Reports rows/s (scoring) and resident bytes against
/// the dense twin, and fails outright if CSR storage does not beat the
/// dense layout at low density (the memory claim is a gate, not prose).
fn cmd_bench_sparse(args: &Args) -> Result<()> {
    use pasmo::data::synth::sparse_blobs;
    use pasmo::util::json::Json;
    use pasmo::util::timer::{black_box, Stopwatch};
    use std::collections::BTreeMap;

    let len = args.get_parse_or("len", 600usize);
    let dim = args.get_parse_or("dim", 2000usize).max(1);
    let seed = args.get_parse_or("seed", 42u64);
    let threads = args.get_parse_or("threads", 1usize);

    println!("==== pasmo bench --sparse (density sweep) ====");
    println!("base ℓ={len} d={dim} seed={seed} threads={threads}\n");
    println!(
        "{:<8} {:>7} {:>6} {:>9} {:>8} {:>9} {:>12} {:>12} {:>12}",
        "density", "rows", "nnz/r", "train", "iters", "storage", "rows/s", "resident", "dense-twin"
    );

    // (label, nnz numerator over dim, row multiplier): the 0.001 cell
    // runs at 10× rows — the regime the CSR backend exists for.
    let sweep: [(&str, usize, usize); 3] =
        [("1.0", dim, 1), ("0.1", dim / 10, 1), ("0.001", dim / 1000, 10)];
    let mut runs: Vec<Json> = Vec::new();
    for (label, nnz, mult) in sweep {
        let nnz = nnz.clamp(1, dim);
        let rows = len * mult;
        let ds = Arc::new(sparse_blobs(rows, dim, nnz, seed));
        let sparse_storage = ds.is_sparse();
        let t = Stopwatch::start();
        let trained = Trainer::rbf(1.0, 0.5)
            .threads(threads)
            .train(&ds);
        let train_s = t.secs();
        let scorer = trained.model.scorer().with_threads(threads);
        // One warmup, one timed full scoring pass over the training set.
        black_box(scorer.decision_values(&ds).iter().sum::<f64>());
        let t = Stopwatch::start();
        black_box(scorer.decision_values(&ds).iter().sum::<f64>());
        let score_s = t.secs().max(1e-9);
        let rows_per_s = rows as f64 / score_s;
        let resident = ds.resident_bytes();
        // The dense twin's bytes, computed (not materialized): full
        // row-major f32 grid + the i8 label column.
        let dense_twin = rows * dim * std::mem::size_of::<f32>() + rows;
        println!(
            "{:<8} {:>7} {:>6} {:>8.3}s {:>8} {:>9} {:>12.1} {:>12} {:>12}",
            label,
            rows,
            nnz,
            train_s,
            trained.result.iterations,
            if sparse_storage { "csr" } else { "dense" },
            rows_per_s,
            resident,
            dense_twin
        );
        // The acceptance gate: at low density the CSR working set must
        // actually be smaller than the dense layout it replaces.
        if nnz * 4 <= dim {
            ensure!(
                resident < dense_twin,
                "density {label}: CSR resident bytes {resident} are not below \
                 the dense twin's {dense_twin}"
            );
        }
        let mut obj = BTreeMap::new();
        obj.insert("density".into(), Json::Str(label.to_string()));
        obj.insert("rows".into(), Json::Num(rows as f64));
        obj.insert("dim".into(), Json::Num(dim as f64));
        obj.insert("nnz_per_row".into(), Json::Num(nnz as f64));
        obj.insert("sparse_storage".into(), Json::Bool(sparse_storage));
        obj.insert("train_wall_s".into(), Json::Num(train_s));
        obj.insert("iterations".into(), Json::Num(trained.result.iterations as f64));
        obj.insert("converged".into(), Json::Bool(trained.result.converged));
        obj.insert("rows_per_s".into(), Json::Num(rows_per_s));
        obj.insert("bytes_resident".into(), Json::Num(resident as f64));
        obj.insert("dense_bytes".into(), Json::Num(dense_twin as f64));
        runs.push(Json::Obj(obj));
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("sparse".into()));
    doc.insert("len".into(), Json::Num(len as f64));
    doc.insert("dim".into(), Json::Num(dim as f64));
    doc.insert("seed".into(), Json::Num(seed as f64));
    doc.insert("threads".into(), Json::Num(threads as f64));
    doc.insert("runs".into(), Json::Arr(runs));
    let doc = Json::Obj(doc);
    if let Some(out) = args.get("out") {
        pasmo::util::artifact::save_json(Path::new(out), doc)
            .with_context(|| format!("write bench report {out}"))?;
        println!("\nreport written to {out}");
    }
    Ok(())
}

/// Measure the fixed tiny baseline workload: train the chessboard suite
/// entry REPS times, then score a same-sized query set with the trained
/// model. Medians of an odd repetition count keep deterministic
/// counters exact and absorb scheduler spikes on the wall metrics.
fn measure_baseline(len: usize, seed: u64) -> Result<pasmo::bench::Baseline> {
    use pasmo::bench::{median, Baseline, Direction, TOL_COUNTER, TOL_WALL};
    use pasmo::svm::Scorer;
    use pasmo::util::timer::{black_box, Stopwatch};

    const REPS: usize = 5;
    let spec = suite::find("chess-board-1000")
        .context("bench baseline: suite dataset chess-board-1000")?;
    let ds = Arc::new(spec.generate(len, seed));
    let queries = spec.generate(len, seed.wrapping_add(1));

    let mut train_wall = Vec::with_capacity(REPS);
    let mut train_iters = Vec::with_capacity(REPS);
    let mut train_entries = Vec::with_capacity(REPS);
    let mut model = None;
    for _ in 0..REPS {
        let out = Trainer::rbf(spec.c, spec.gamma).train(&ds);
        train_wall.push(out.result.wall_time_s);
        train_iters.push(out.result.iterations as f64);
        train_entries.push(out.result.kernel_entries as f64);
        model = Some(out.model);
    }
    let model = model.context("bench baseline: training produced no model")?;

    let scorer = Scorer::new(model.kernel, &model.support, &model.coef, model.bias);
    let pred_entries = scorer.kernel_entries_per_pass(queries.len()) as f64;
    let mut pred_rate = Vec::with_capacity(REPS);
    for _ in 0..REPS {
        let sw = Stopwatch::start();
        let vals = scorer.decision_values(&queries);
        let secs = sw.secs().max(1e-9);
        black_box(&vals);
        pred_rate.push(queries.len() as f64 / secs);
    }

    let mut b = Baseline::new();
    b.set("train.chess.wall_s", median(&mut train_wall), Direction::Lower, TOL_WALL);
    b.set("train.chess.iterations", median(&mut train_iters), Direction::Lower, TOL_COUNTER);
    b.set(
        "train.chess.kernel_entries",
        median(&mut train_entries),
        Direction::Lower,
        TOL_COUNTER,
    );
    b.set("predict.chess.rows_per_s", median(&mut pred_rate), Direction::Higher, TOL_WALL);
    b.set("predict.chess.kernel_entries", pred_entries, Direction::Lower, TOL_COUNTER);
    Ok(b)
}

/// The perf-trajectory gate (`pasmo bench --save-baseline` /
/// `--check-baseline`): measure the tiny fixed workload, then either
/// record the medians into the checksummed `--baseline FILE` artifact
/// or compare against it and exit nonzero on any regression beyond
/// tolerance (or any committed metric this run failed to measure). A
/// missing or empty committed baseline bootstraps: the check measures,
/// saves, and passes, so the gate self-initializes on a new host class
/// instead of comparing against another machine's clock.
fn cmd_bench_baseline(args: &Args) -> Result<()> {
    use pasmo::bench::{self, Baseline};

    let path_s = args.get_or("baseline", "BENCH_baseline.json");
    let path = Path::new(&path_s);
    let len = args.get_parse_or("len", 240usize);
    let seed = args.get_parse_or("seed", 42u64);
    let simd_on = pasmo::kernel::tile::simd::simd_active();

    println!("==== pasmo bench (baseline gate) ====");
    println!(
        "file={path_s} ℓ={len} seed={seed} simd={}\n",
        if simd_on { "on" } else { "off" }
    );
    let current = measure_baseline(len, seed)?;
    for (name, m) in &current.metrics {
        println!("  {name:<28} {:>16.6}  ({} is better)", m.value, m.direction.as_str());
    }

    if args.flag("save-baseline") {
        current.save(path).with_context(|| format!("write baseline {path_s}"))?;
        println!("\nbaseline saved to {path_s} ({} metrics)", current.metrics.len());
        return Ok(());
    }

    // --check-baseline
    let committed = if path.exists() { Baseline::load(path)? } else { Baseline::new() };
    if committed.is_empty() {
        current.save(path).with_context(|| format!("write baseline {path_s}"))?;
        println!(
            "\nbaseline was empty — bootstrapped {path_s} ({} metrics); \
             future checks gate against this run",
            current.metrics.len()
        );
        return Ok(());
    }
    let report = bench::check(&committed, &current, &path_s);
    println!();
    for line in &report.new_metrics {
        println!("note: {line}");
    }
    for line in &report.improvements {
        println!("improved: {line}");
    }
    for line in &report.missing {
        eprintln!("missing: {line}");
    }
    for line in &report.regressions {
        eprintln!("regression: {line}");
    }
    ensure!(
        report.ok(),
        "bench baseline gate failed: {} regression(s), {} missing metric(s) against {}",
        report.regressions.len(),
        report.missing.len(),
        path_s
    );
    println!(
        "baseline gate passed: {} committed metrics within tolerance of {path_s}",
        committed.metrics.len()
    );
    Ok(())
}

/// Parse a `--model` spec: comma-separated `FILE` or `NAME=FILE`
/// entries; the name defaults to the file stem.
fn parse_model_specs(spec: &str) -> Result<Vec<(String, AnyModel)>> {
    let mut models: Vec<(String, AnyModel)> = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, path) = match part.split_once('=') {
            Some((n, p)) => (n.trim().to_string(), p.trim()),
            None => {
                let stem = Path::new(part)
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .unwrap_or(part);
                (stem.to_string(), part)
            }
        };
        ensure!(!name.is_empty(), "empty model name in --model entry {part:?}");
        ensure!(
            !models.iter().any(|(n, _)| *n == name),
            "duplicate model name {name:?} in --model"
        );
        let model = schema::load_any(Path::new(path))
            .with_context(|| format!("load model {path}"))?;
        models.push((name, model));
    }
    ensure!(!models.is_empty(), "--model needs at least one FILE or NAME=FILE entry");
    Ok(models)
}

/// `pasmo serve` — bind the micro-batching TCP inference tier and run
/// until a `{"cmd":"shutdown"}` request. Startup prints one line per
/// model and a final `listening on HOST:PORT` line (flushed, so drivers
/// reading a pipe can parse the ephemeral port before sending traffic).
fn cmd_serve(args: &Args) -> Result<()> {
    use pasmo::server::{ServeConfig, Server};
    use std::io::Write as _;

    let spec = args.get("model").context("need --model FILE[,NAME=FILE...]")?;
    let models = parse_model_specs(spec)?;
    let config = ServeConfig {
        addr: args.get_or("addr", "127.0.0.1:7878").to_string(),
        max_batch: args.get_parse_or("max-batch", 64usize).max(1),
        max_wait_us: args.get_parse_or("max-wait-us", 200u64),
        threads: args.get_parse_or("threads", 1usize),
        max_queue: args.get_parse_or("max-queue", 1024usize),
        deadline_us: args.get_parse_or("deadline-us", 0u64),
        max_conns: args.get_parse_or("max-conns", 0usize),
        f32_sv: args.flag("f32-sv"),
    };
    let (max_batch, max_wait_us, threads) =
        (config.max_batch, config.max_wait_us, config.threads);
    let (max_queue, deadline_us, max_conns) =
        (config.max_queue, config.deadline_us, config.max_conns);
    let f32_sv = config.f32_sv;
    for (name, m) in &models {
        println!(
            "model {name:?}: kind={} n_sv={} dim={}",
            m.task_name(),
            m.n_sv(),
            m.dim()
        );
    }
    let server = Server::bind(config, models)?;
    println!(
        "pasmo serve listening on {} (max-batch={max_batch} max-wait-us={max_wait_us} \
         threads={threads} max-queue={max_queue} deadline-us={deadline_us} \
         max-conns={max_conns} f32-sv={f32_sv})",
        server.local_addr()
    );
    std::io::stdout().flush().context("flush startup banner")?;
    server.run()?;
    println!("pasmo serve stopped (drained and shut down)");
    Ok(())
}

/// Serving saturation bench (`pasmo bench --serve`): for each
/// `--batches` config, bind an in-process server on an ephemeral port,
/// drive it open-loop over real sockets at a fixed arrival rate, and
/// report achieved queries/s with p50/p99 latency — demonstrating the
/// micro-batching win over batch-size-1 at saturation. `--out` writes
/// the `BENCH_serve.json` trajectory artifact.
fn cmd_bench_serve(args: &Args) -> Result<()> {
    use pasmo::server::{drive_open_loop, request_once, LoadConfig, ServeConfig, Server};
    use pasmo::util::json::Json;
    use std::collections::BTreeMap;

    let len = args.get_parse_or("len", 400usize);
    let seed = args.get_parse_or("seed", 42u64);
    let threads = args.get_parse_or("threads", 1usize);
    let rate = args.get_parse_or("rate", 2000.0f64);
    let queries = args.get_parse_or("queries", 2000usize);
    let conns = args.get_parse_or("conns", 4usize);
    let max_wait_us = args.get_parse_or("max-wait-us", 200u64);
    // overload knobs (0 = off, matching an unbounded/undeadlined server):
    // with them set, the shed/expired columns show how much offered load
    // each config refused instead of absorbing into its latency tail
    let max_queue = args.get_parse_or("max-queue", 0usize);
    let deadline_us = args.get_parse_or("deadline-us", 0u64);
    let batches_spec = args.get_or("batches", "1,8,64");
    let batch_sizes: Vec<usize> = batches_spec
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .filter(|&b| b >= 1)
        .collect();
    ensure!(
        !batch_sizes.is_empty(),
        "--batches needs a comma-separated list of positive sizes"
    );
    let name = match args.get("datasets") {
        Some(list) => {
            list.split(',').next().unwrap_or("chess-board-1000").trim().to_string()
        }
        None => "chess-board-1000".to_string(),
    };
    let spec = suite::find(&name)
        .with_context(|| format!("unknown dataset {name:?} (see `pasmo datasets`)"))?;
    let train_set = Arc::new(spec.generate(len, seed));
    let query_set = spec.generate(len.min(256), seed.wrapping_add(1));
    let model = Trainer::rbf(spec.c, spec.gamma).train(&train_set).model;
    let n_sv = model.n_sv();

    println!("==== pasmo bench --serve (serving saturation) ====");
    println!(
        "dataset={name} ℓ={len} SVs={n_sv} rate={rate}/s queries={queries} \
         conns={conns} threads={threads} max-wait-us={max_wait_us} \
         max-queue={max_queue} deadline-us={deadline_us}\n"
    );
    println!(
        "{:<10} {:>10} {:>10} {:>10} {:>11} {:>8} {:>8} {:>8}",
        "max-batch", "qps", "p50-us", "p99-us", "mean-batch", "shed", "expired", "errors"
    );

    let mut runs: Vec<Json> = Vec::new();
    for &max_batch in &batch_sizes {
        let config = ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_batch,
            max_wait_us,
            threads,
            max_queue,
            deadline_us,
            ..ServeConfig::default()
        };
        let server = Server::bind(
            config,
            vec![("bench".to_string(), AnyModel::Svc(model.clone()))],
        )?;
        let addr = server.local_addr();
        let handle = std::thread::spawn(move || server.run());
        let cfg = LoadConfig { rate, queries, conns };
        let report =
            drive_open_loop(addr, Some("bench"), query_set.dim(), query_set.features(), &cfg)?;
        let stats = request_once(addr, "{\"cmd\":\"stats\"}")?;
        let stats_doc = Json::parse(&stats).ok();
        let mean_batch = stats_doc
            .as_ref()
            .and_then(|v| v.get("models")?.get("bench")?.get("mean_batch")?.as_f64())
            .unwrap_or(0.0);
        // server-side overload counters (top-level totals in the stats
        // reply): queries refused at admission / expired in the queue
        let shed = stats_doc
            .as_ref()
            .and_then(|v| v.get("shed")?.as_f64())
            .unwrap_or(0.0);
        let expired = stats_doc
            .as_ref()
            .and_then(|v| v.get("expired")?.as_f64())
            .unwrap_or(0.0);
        let _ = request_once(addr, "{\"cmd\":\"shutdown\"}")?;
        match handle.join() {
            Ok(r) => r?,
            Err(_) => bail!("server thread panicked (max-batch={max_batch})"),
        }
        println!(
            "{:<10} {:>10.1} {:>10.0} {:>10.0} {:>11.2} {:>8.0} {:>8.0} {:>8}",
            max_batch,
            report.qps,
            report.p50_us,
            report.p99_us,
            mean_batch,
            shed,
            expired,
            report.errors
        );
        let mut obj = BTreeMap::new();
        obj.insert("max_batch".into(), Json::Num(max_batch as f64));
        obj.insert("queries_per_s".into(), Json::Num(report.qps));
        obj.insert("p50_us".into(), Json::Num(report.p50_us));
        obj.insert("p99_us".into(), Json::Num(report.p99_us));
        obj.insert("mean_batch".into(), Json::Num(mean_batch));
        obj.insert("shed".into(), Json::Num(shed));
        obj.insert("expired".into(), Json::Num(expired));
        obj.insert("sent".into(), Json::Num(report.sent as f64));
        obj.insert("ok".into(), Json::Num(report.ok as f64));
        obj.insert("errors".into(), Json::Num(report.errors as f64));
        obj.insert("wall_s".into(), Json::Num(report.wall_s));
        runs.push(Json::Obj(obj));
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("serve".into()));
    doc.insert("dataset".into(), Json::Str(name));
    doc.insert("len".into(), Json::Num(len as f64));
    doc.insert("n_sv".into(), Json::Num(n_sv as f64));
    doc.insert("rate".into(), Json::Num(rate));
    doc.insert("queries".into(), Json::Num(queries as f64));
    doc.insert("conns".into(), Json::Num(conns as f64));
    doc.insert("threads".into(), Json::Num(threads as f64));
    doc.insert("max_wait_us".into(), Json::Num(max_wait_us as f64));
    doc.insert("max_queue".into(), Json::Num(max_queue as f64));
    doc.insert("deadline_us".into(), Json::Num(deadline_us as f64));
    doc.insert("runs".into(), Json::Arr(runs));
    let doc = Json::Obj(doc);
    if let Some(out) = args.get("out") {
        pasmo::util::artifact::save_json(Path::new(out), doc)
            .with_context(|| format!("write bench report {out}"))?;
        println!("\nreport written to {out}");
    }
    Ok(())
}

fn exp_options(args: &Args) -> ExpOptions {
    let d = ExpOptions::default();
    let mut o = ExpOptions {
        scale: args.get_parse_or("scale", d.scale),
        max_len: args.get_parse_or("max-len", d.max_len),
        perms: args.get_parse_or("perms", d.perms),
        eps: args.get_parse_or("eps", d.eps),
        seed: args.get_parse_or("seed", d.seed),
        full: args.flag("full"),
        threads: args.get_parse_or("threads", d.threads),
        ..d
    };
    if let Some(list) = args.get("datasets") {
        o.datasets = list.split(',').map(|s| s.trim().to_string()).collect();
    }
    o
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args.positional.get(1).map(|s| s.as_str()).context(
        "need an experiment id \
         (table1|table2|fig2|fig3|fig4|wss|heuristic|engine_shootout|all)",
    )?;
    let opts = exp_options(args);
    let mut report = Report::new(false);
    match which {
        "table1" => report.section(experiments::table1(&opts)),
        "table2" => report.section(experiments::table2(&opts)),
        "fig2" => report.section(experiments::fig2()),
        "fig3" => report.section(experiments::fig3(&opts)),
        "fig4" => report.section(experiments::fig4(&opts)),
        "wss" => report.section(experiments::wss_ablation(&opts)),
        "heuristic" => report.section(experiments::heuristic_step(&opts)),
        "engine_shootout" => report.section(experiments::engine_shootout(&opts)),
        "all" => {
            report.section(experiments::table1(&opts));
            report.section(experiments::table2(&opts));
            report.section(experiments::fig2());
            report.section(experiments::fig3(&opts));
            report.section(experiments::fig4(&opts));
            report.section(experiments::wss_ablation(&opts));
            report.section(experiments::heuristic_step(&opts));
            report.section(experiments::engine_shootout(&opts));
        }
        other => bail!("unknown experiment {other:?}"),
    }
    if let Some(out) = args.get("out") {
        report.save(Path::new(out))?;
        println!("\nreport saved to {out}");
    }
    Ok(())
}

/// `pasmo audit` — the in-repo lint. Scans a source tree (default: this
/// crate's `src/`), applies the allowlist, prints the report and exits
/// nonzero if any violation (including stale allowlist entries) remains.
fn cmd_audit(args: &Args) -> Result<()> {
    use pasmo::audit::{audit_tree, Allowlist};
    let src = args.get_or("src", concat!(env!("CARGO_MANIFEST_DIR"), "/src"));
    let allow_path =
        args.get_or("allowlist", concat!(env!("CARGO_MANIFEST_DIR"), "/audit.allow"));
    let allowlist = match std::fs::read_to_string(&allow_path) {
        Ok(text) => Allowlist::parse(&text)
            .with_context(|| format!("parse allowlist {allow_path}"))?,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Allowlist::empty(),
        Err(e) => bail!("read allowlist {allow_path}: {e}"),
    };
    let report = audit_tree(Path::new(&src), &allowlist)?;
    print!("{}", report.render());
    ensure!(
        report.is_clean(),
        "audit found {} violation(s) in {src}",
        report.violations.len()
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("pasmo {}", env!("CARGO_PKG_VERSION"));
    println!(
        "threads available: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0)
    );
    info_pjrt();
    Ok(())
}

#[cfg(feature = "pjrt")]
fn info_pjrt() {
    use pasmo::runtime::engine::PjrtEngine;
    match PjrtEngine::open_default() {
        Ok(engine) => {
            println!(
                "PJRT: platform={} devices={}",
                engine.client.platform_name(),
                engine.client.device_count()
            );
            println!("artifacts ({}):", engine.manifest.dir.display());
            for (name, a) in &engine.manifest.artifacts {
                println!("  {name}: entry={} q={} l={} d={}", a.entry, a.q, a.l, a.d);
            }
        }
        Err(e) => println!("PJRT artifacts unavailable: {e} (run `make artifacts`)"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn info_pjrt() {
    println!("PJRT: disabled at build time (native kernel path only; enable with `cargo build --features pjrt`)");
}
