//! First-class description of the dual QP the solvers operate on.
//!
//! Every training task in the crate — C-SVC (optionally with per-class
//! costs C₊/C₋), ε-SVR, one-class SVM — is an instance of the paper's
//! general box-and-hyperplane problem
//!
//! ```text
//! max  pᵀα − ½ αᵀKα   s.t.   Σαᵢ = s,   Lᵢ ≤ αᵢ ≤ Uᵢ.
//! ```
//!
//! [`QpProblem`] captures `(p, L, U, s)` plus an optional warm-start α,
//! and [`QpProblem::lower`] is the *single* site in the crate where a
//! problem becomes a [`SolverState`]: it repairs the warm start onto the
//! feasible set and reconstructs the gradient `G = p − Kα₀` from kernel
//! rows (zero kernel evaluations when α₀ = 0, the paper-§2 cold start).

use crate::kernel::matrix::Gram;

use super::state::SolverState;

/// A general dual QP instance, independent of any solver.
///
/// Every training task is built by one of the constructors:
///
/// ```
/// use pasmo::solver::QpProblem;
///
/// // C-SVC (signed-α convention): box sides follow the labels.
/// let svc = QpProblem::classification(&[1, -1], 2.0);
/// assert_eq!(svc.lower, vec![0.0, -2.0]);
/// assert_eq!(svc.upper, vec![2.0, 0.0]);
/// assert_eq!(svc.equality_sum, 0.0);
///
/// // ε-SVR doubles the variables (α and −α* halves).
/// let svr = QpProblem::svr(&[0.5, -0.5], 1.0, 0.1);
/// assert_eq!(svr.len(), 4);
///
/// // One-class: Σα = 1 with a feasible LIBSVM-style warm start.
/// let oc = QpProblem::one_class(10, 0.5);
/// assert_eq!(oc.equality_sum, 1.0);
/// assert!(oc.alpha0.is_some());
/// ```
#[derive(Debug, Clone)]
pub struct QpProblem {
    /// Linear term `p` (`y` for classification, `y ∓ ε` for SVR, 0 for
    /// one-class).
    pub linear: Vec<f64>,
    /// Per-index lower bounds `L`.
    pub lower: Vec<f64>,
    /// Per-index upper bounds `U`.
    pub upper: Vec<f64>,
    /// Equality-constraint target `s = Σα` (0 for C-SVC and ε-SVR, 1 for
    /// the one-class formulation).
    pub equality_sum: f64,
    /// Optional warm start. Need not be feasible for *this* problem's
    /// box (e.g. α carried over from an adjacent grid point with a
    /// different C): [`QpProblem::lower`] clamps and repairs it.
    pub alpha0: Option<Vec<f64>>,
}

impl QpProblem {
    /// C-SVC dual with the signed-α convention: `p = y`,
    /// `Lᵢ = min(0, yᵢC)`, `Uᵢ = max(0, yᵢC)`.
    pub fn classification(labels: &[i8], c: f64) -> QpProblem {
        QpProblem::classification_weighted(labels, c, c)
    }

    /// C-SVC with per-class costs: positives are budgeted `C₊`,
    /// negatives `C₋` — the standard recipe for imbalanced data. With
    /// `c_pos == c_neg` this is exactly [`QpProblem::classification`].
    pub fn classification_weighted(labels: &[i8], c_pos: f64, c_neg: f64) -> QpProblem {
        assert!(c_pos > 0.0 && c_neg > 0.0, "class costs must be positive");
        let linear: Vec<f64> = labels.iter().map(|&l| l as f64).collect();
        let (mut lower, mut upper) = (Vec::with_capacity(labels.len()), Vec::with_capacity(labels.len()));
        for &yi in &linear {
            let c = if yi > 0.0 { c_pos } else { c_neg };
            lower.push((yi * c).min(0.0));
            upper.push((yi * c).max(0.0));
        }
        QpProblem { linear, lower, upper, equality_sum: 0.0, alpha0: None }
    }

    /// ε-SVR dual over the doubled variable vector `γ` (see `svm::svr`):
    /// `p_i = y_i − ε`, `p_{ℓ+i} = y_i + ε`, `γ_i ∈ [0, C]`,
    /// `γ_{ℓ+i} ∈ [−C, 0]`. The Gram view must be the doubled `K̃`.
    pub fn svr(targets: &[f64], c: f64, epsilon: f64) -> QpProblem {
        assert!(c > 0.0, "C must be positive");
        let l = targets.len();
        let mut linear = Vec::with_capacity(2 * l);
        let mut lower = Vec::with_capacity(2 * l);
        let mut upper = Vec::with_capacity(2 * l);
        for &t in targets {
            linear.push(t - epsilon);
            lower.push(0.0);
            upper.push(c);
        }
        for &t in targets {
            linear.push(t + epsilon);
            lower.push(-c);
            upper.push(0.0);
        }
        QpProblem { linear, lower, upper, equality_sum: 0.0, alpha0: None }
    }

    /// One-class (ν) dual: `p = 0`, `αᵢ ∈ [0, 1/(νℓ)]`, `Σα = 1`, with
    /// the LIBSVM-style feasible start filling α from the front.
    pub fn one_class(l: usize, nu: f64) -> QpProblem {
        assert!(l >= 2, "need at least two examples");
        assert!(nu > 0.0 && nu <= 1.0, "nu must be in (0, 1]");
        let ub = 1.0 / (nu * l as f64);
        let mut alpha0 = vec![0.0f64; l];
        let mut remaining = 1.0f64;
        for a in alpha0.iter_mut() {
            let v = remaining.min(ub);
            *a = v;
            remaining -= v;
            if remaining <= 0.0 {
                break;
            }
        }
        QpProblem {
            linear: vec![0.0; l],
            lower: vec![0.0; l],
            upper: vec![ub; l],
            equality_sum: 1.0,
            alpha0: Some(alpha0),
        }
    }

    /// Builder: seed the solve from `alpha` (e.g. the solution of an
    /// adjacent grid point). Infeasible seeds are repaired at lowering.
    pub fn warm_start(mut self, alpha: Vec<f64>) -> QpProblem {
        assert_eq!(alpha.len(), self.linear.len(), "warm start length mismatch");
        self.alpha0 = Some(alpha);
        self
    }

    /// Problem size ℓ.
    pub fn len(&self) -> usize {
        self.linear.len()
    }

    /// Is this a zero-variable problem?
    pub fn is_empty(&self) -> bool {
        self.linear.is_empty()
    }

    /// Lower the problem to a ready-to-iterate [`SolverState`] — the one
    /// place where warm starts are made feasible and the initial
    /// gradient is built. Kernel evaluations: one Gram row per non-zero
    /// warm-start coefficient, none for a cold start.
    ///
    /// Expects the Gram in its identity view (`Engine::solve` resets it);
    /// the produced state starts fully active with the identity
    /// permutation, and the two views then shrink in lockstep.
    pub fn lower(&self, gram: &mut Gram) -> SolverState {
        let n = self.len();
        assert_eq!(n, gram.len(), "problem/gram size mismatch");
        let alpha0 = match &self.alpha0 {
            None => vec![0.0; n],
            Some(a) => self.repair(a),
        };
        let mut grad0 = self.linear.clone();
        for (j, &aj) in alpha0.iter().enumerate() {
            if aj == 0.0 {
                continue;
            }
            let row = gram.row(j);
            for (g, &k) in grad0.iter_mut().zip(row.iter()) {
                *g -= aj * k as f64;
            }
        }
        SolverState::from_problem(
            self.linear.clone(),
            self.lower.clone(),
            self.upper.clone(),
            alpha0,
            grad0,
        )
    }

    /// Project a candidate α onto the feasible set: clamp into the box,
    /// then restore `Σα = s` by greedily spending per-index box slack.
    /// Always succeeds when the box admits the equality constraint
    /// (`ΣL ≤ s ≤ ΣU`), which every task constructor guarantees.
    fn repair(&self, alpha: &[f64]) -> Vec<f64> {
        let mut a: Vec<f64> = alpha
            .iter()
            .zip(self.lower.iter().zip(&self.upper))
            .map(|(&v, (&lo, &hi))| v.clamp(lo, hi))
            .collect();
        let mut excess = a.iter().sum::<f64>() - self.equality_sum;
        if excess.abs() <= 1e-12 {
            return a;
        }
        for i in 0..a.len() {
            if excess.abs() <= 1e-12 {
                break;
            }
            if excess > 0.0 {
                let give = (a[i] - self.lower[i]).min(excess);
                a[i] -= give;
                excess -= give;
            } else {
                let take = (self.upper[i] - a[i]).min(-excess);
                a[i] += take;
                excess += take;
            }
        }
        debug_assert!(
            excess.abs() <= 1e-9,
            "box cannot satisfy the equality constraint (residual {excess})"
        );
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::kernel::function::KernelFunction;
    use crate::kernel::native::NativeRowComputer;
    use std::sync::Arc;

    fn gram_for(labels: &[i8]) -> Gram {
        let mut ds = Dataset::with_dim(1);
        for (i, &y) in labels.iter().enumerate() {
            ds.push(&[i as f32], y);
        }
        let nc = NativeRowComputer::new(Arc::new(ds), KernelFunction::Rbf { gamma: 0.5 });
        Gram::new(Box::new(nc), 1 << 20)
    }

    #[test]
    fn classification_matches_solver_state_new() {
        let labels = [1i8, -1, 1];
        let p = QpProblem::classification(&labels, 2.0);
        let mut g = gram_for(&labels);
        let st = p.lower(&mut g);
        let direct = SolverState::new(&labels, 2.0);
        assert_eq!(st.y, direct.y);
        assert_eq!(st.alpha, direct.alpha);
        assert_eq!(st.grad, direct.grad);
        assert_eq!(st.lower, direct.lower);
        assert_eq!(st.upper, direct.upper);
    }

    #[test]
    fn equal_class_weights_reduce_to_plain_classification() {
        let labels = [1i8, -1, 1, -1];
        let a = QpProblem::classification(&labels, 3.0);
        let b = QpProblem::classification_weighted(&labels, 3.0, 3.0);
        assert_eq!(a.lower, b.lower);
        assert_eq!(a.upper, b.upper);
        assert_eq!(a.linear, b.linear);
    }

    #[test]
    fn weighted_bounds_scale_per_class() {
        let labels = [1i8, -1];
        let p = QpProblem::classification_weighted(&labels, 4.0, 0.5);
        assert_eq!(p.lower, vec![0.0, -0.5]);
        assert_eq!(p.upper, vec![4.0, 0.0]);
    }

    #[test]
    fn one_class_start_is_feasible() {
        let p = QpProblem::one_class(10, 0.3);
        let a = p.alpha0.as_ref().unwrap();
        let sum: f64 = a.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        let ub = 1.0 / (0.3 * 10.0);
        assert!(a.iter().all(|&v| (0.0..=ub + 1e-12).contains(&v)));
    }

    #[test]
    fn warm_start_gradient_is_p_minus_k_alpha() {
        let labels = [1i8, -1, 1, -1];
        let alpha = vec![0.5, -0.25, 0.0, -0.25];
        let mut g = gram_for(&labels);
        let p = QpProblem::classification(&labels, 1.0).warm_start(alpha.clone());
        let st = p.lower(&mut g);
        for i in 0..4 {
            let mut want = labels[i] as f64;
            for j in 0..4 {
                want -= alpha[j] * g.entry(i, j);
            }
            assert!((st.grad[i] - want).abs() < 1e-6, "index {i}");
        }
    }

    #[test]
    fn repair_clamps_and_restores_equality() {
        // Carry α from C = 2 into a problem with C = 1: clamping breaks
        // Σα = 0, repair must restore it inside the new box.
        let labels = [1i8, 1, -1, -1];
        let stale = vec![2.0, 0.0, -1.0, -1.0];
        let mut g = gram_for(&labels);
        let p = QpProblem::classification(&labels, 1.0).warm_start(stale);
        let st = p.lower(&mut g);
        assert!(st.is_feasible(1e-9), "alpha {:?}", st.alpha);
        let sum: f64 = st.alpha.iter().sum();
        assert!(sum.abs() < 1e-9, "Σα = {sum}");
    }

    #[test]
    fn feasible_warm_start_passes_through_unchanged() {
        let labels = [1i8, -1];
        let alpha = vec![0.25, -0.25];
        let mut g = gram_for(&labels);
        let p = QpProblem::classification(&labels, 1.0).warm_start(alpha.clone());
        let st = p.lower(&mut g);
        assert_eq!(st.alpha, alpha);
    }
}
