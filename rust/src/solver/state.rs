//! Solver state: dual variables, gradient, box bounds and the active set.
//!
//! Conventions follow the paper exactly: labels enter through the bounds
//! `Lᵢ = min(0, yᵢC)`, `Uᵢ = max(0, yᵢC)` (so α is *signed*: the decision
//! coefficient is αᵢ itself, not yᵢαᵢ), the gradient is `G = ∇f = y − Kα`,
//! and the index sets are `I_up = {i | αᵢ < Uᵢ}`, `I_down = {i | αᵢ > Lᵢ}`.
//!
//! # Active-prefix compaction
//!
//! The active (unshrunk) variables always occupy the contiguous prefix
//! `[0, active_len)` of a permutation of the original indices (LIBSVM's
//! `swap_index` scheme): shrinking swaps a variable to the end of the
//! prefix and shortens it, so every downstream loop — stopping scan,
//! working-set selection, the fused gradient update — is a branch-free
//! linear sweep over contiguous slices instead of a gather through an
//! index list. `perm[p]` maps a position back to its original index and
//! `pos[i]` is the inverse; results leave the solver in original
//! coordinates via [`SolverState::alpha_original`].

/// Dual state for one training problem.
///
/// The solver actually handles the *general* box-and-hyperplane QP
/// `max pᵀα − ½αᵀKα  s.t.  Σα = const, L ≤ α ≤ U` — classification is
/// the special case `p = y`, `L/U` from `(y, C)`. ε-SVR and one-class
/// SVM map onto the same state via [`SolverState::from_problem`]
/// (see `svm::svr` / `svm::oneclass`).
///
/// All vectors are stored in the *permuted* view: index `p` everywhere
/// below is a position, and `y[p]`/`alpha[p]`/… refer to original
/// variable `perm[p]`. A freshly constructed state is the identity
/// permutation.
#[derive(Debug, Clone)]
pub struct SolverState {
    /// Linear term of the dual objective (`y` for classification).
    pub y: Vec<f64>,
    /// Dual variables (signed convention).
    pub alpha: Vec<f64>,
    /// Gradient `G = y − Kα`, maintained incrementally on the active set.
    pub grad: Vec<f64>,
    /// Lower bounds `Lᵢ`.
    pub lower: Vec<f64>,
    /// Upper bounds `Uᵢ`.
    pub upper: Vec<f64>,
    /// Position → original index.
    pub perm: Vec<usize>,
    /// Original index → position (inverse of `perm`).
    pub pos: Vec<usize>,
    /// Active variables are exactly the positions `[0, active_len)`.
    pub active_len: usize,
}

impl SolverState {
    /// Fresh state at α = 0 (so `G = y`, no kernel evaluations — paper §2).
    pub fn new(labels: &[i8], c: f64) -> SolverState {
        assert!(c > 0.0, "C must be positive");
        let n = labels.len();
        let y: Vec<f64> = labels.iter().map(|&l| l as f64).collect();
        let lower: Vec<f64> = y.iter().map(|&yi| (yi * c).min(0.0)).collect();
        let upper: Vec<f64> = y.iter().map(|&yi| (yi * c).max(0.0)).collect();
        SolverState {
            grad: y.clone(),
            alpha: vec![0.0; n],
            y,
            lower,
            upper,
            perm: (0..n).collect(),
            pos: (0..n).collect(),
            active_len: n,
        }
    }

    /// General dual problem with an explicit linear term, bounds and a
    /// feasible warm start. `grad0` must equal `p − K α₀` (for `α₀ = 0`
    /// pass `grad0 = p`).
    pub fn from_problem(
        linear: Vec<f64>,
        lower: Vec<f64>,
        upper: Vec<f64>,
        alpha0: Vec<f64>,
        grad0: Vec<f64>,
    ) -> SolverState {
        let n = linear.len();
        assert!(
            lower.len() == n && upper.len() == n && alpha0.len() == n && grad0.len() == n,
            "problem vector lengths disagree"
        );
        for i in 0..n {
            assert!(
                lower[i] <= alpha0[i] && alpha0[i] <= upper[i],
                "infeasible warm start at {i}"
            );
        }
        SolverState {
            y: linear,
            alpha: alpha0,
            grad: grad0,
            lower,
            upper,
            perm: (0..n).collect(),
            pos: (0..n).collect(),
            active_len: n,
        }
    }

    /// Problem size ℓ.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// Is this a zero-variable state?
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Swap two positions of the view (all state vectors plus the
    /// permutation move in lockstep). The caller owning a `Gram` must
    /// mirror this with `Gram::swap_index` — `solver::shrink` is the one
    /// place that does.
    pub fn swap(&mut self, p: usize, q: usize) {
        if p == q {
            return;
        }
        self.y.swap(p, q);
        self.alpha.swap(p, q);
        self.grad.swap(p, q);
        self.lower.swap(p, q);
        self.upper.swap(p, q);
        let (a, b) = (self.perm[p], self.perm[q]);
        self.perm.swap(p, q);
        self.pos[a] = q;
        self.pos[b] = p;
    }

    /// α in original coordinates (undoing the shrink permutation).
    pub fn alpha_original(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.len()];
        for (p, &orig) in self.perm.iter().enumerate() {
            out[orig] = self.alpha[p];
        }
        out
    }

    /// `p ∈ I_up(α)`? (positional)
    #[inline]
    pub fn in_up(&self, p: usize) -> bool {
        self.alpha[p] < self.upper[p]
    }

    /// `p ∈ I_down(α)`? (positional)
    #[inline]
    pub fn in_down(&self, p: usize) -> bool {
        self.alpha[p] > self.lower[p]
    }

    /// Step bounds `[L̃, Ũ]` for direction `v = e_i − e_j` (paper §2).
    #[inline]
    pub fn step_bounds(&self, i: usize, j: usize) -> (f64, f64) {
        let lo = (self.lower[i] - self.alpha[i]).max(self.alpha[j] - self.upper[j]);
        let hi = (self.upper[i] - self.alpha[i]).min(self.alpha[j] - self.lower[j]);
        (lo, hi)
    }

    /// Apply the step `α ← α + μ(e_i − e_j)`, snapping to bounds to keep
    /// the iterate exactly feasible under floating point.
    pub fn apply_step(&mut self, i: usize, j: usize, mu: f64) {
        self.alpha[i] += mu;
        self.alpha[j] -= mu;
        self.alpha[i] = self.alpha[i].clamp(self.lower[i], self.upper[i]);
        self.alpha[j] = self.alpha[j].clamp(self.lower[j], self.upper[j]);
    }

    /// Dual objective from the maintained gradient in O(ℓ):
    /// `f(α) = ½ (αᵀy + αᵀG)` since `G = y − Kα`. Permutation-invariant.
    pub fn objective(&self) -> f64 {
        0.5 * self
            .alpha
            .iter()
            .zip(self.y.iter().zip(&self.grad))
            .map(|(&a, (&y, &g))| a * (y + g))
            .sum::<f64>()
    }

    /// KKT gap over the *active* prefix:
    /// `max{Gᵢ | i ∈ I_up} − min{Gⱼ | j ∈ I_down}` (paper step 4).
    /// Returns `(m, big_m, gap)`; gap is −∞ if either set is empty.
    pub fn kkt_gap_active(&self) -> (f64, f64, f64) {
        let (m, big_m, gap, _) = self.kkt_scan();
        (m, big_m, gap)
    }

    /// Single fused pass producing the stopping quantities *and* the
    /// first-order WSS argmax `i = argmax{Gᵢ | i ∈ I_up}` — the hot loop
    /// runs exactly one such scan per iteration (perf pass, EXPERIMENTS.md
    /// §Perf). The scan is a linear sweep over the contiguous active
    /// prefix. Returns `(m, big_m, gap, argmax_up)` with the argmax as a
    /// *position*.
    pub fn kkt_scan(&self) -> (f64, f64, f64, Option<usize>) {
        let mut m = f64::NEG_INFINITY;
        let mut big_m = f64::INFINITY;
        let mut argmax = None;
        for p in 0..self.active_len {
            let g = self.grad[p];
            if self.in_up(p) && g > m {
                m = g;
                argmax = Some(p);
            }
            if self.in_down(p) && g < big_m {
                big_m = g;
            }
        }
        if m == f64::NEG_INFINITY || big_m == f64::INFINITY {
            (m, big_m, f64::NEG_INFINITY, argmax)
        } else {
            (m, big_m, m - big_m, argmax)
        }
    }

    /// Bias from the KKT conditions: mean gradient over free SVs, falling
    /// back to the midpoint of the violating-pair interval.
    pub fn bias(&self) -> f64 {
        let mut sum = 0.0;
        let mut count = 0usize;
        for i in 0..self.len() {
            if self.in_up(i) && self.in_down(i) {
                sum += self.grad[i];
                count += 1;
            }
        }
        if count > 0 {
            sum / count as f64
        } else {
            let (m, big_m, _) = self.kkt_gap_active();
            if m.is_finite() && big_m.is_finite() {
                (m + big_m) / 2.0
            } else {
                0.0
            }
        }
    }

    /// Feasibility check for tests: box + equality constraint.
    pub fn is_feasible(&self, tol: f64) -> bool {
        let sum: f64 = self.alpha.iter().sum();
        if sum.abs() > tol {
            return false;
        }
        self.alpha
            .iter()
            .zip(self.lower.iter().zip(&self.upper))
            .all(|(&a, (&lo, &hi))| a >= lo - tol && a <= hi + tol)
    }

    /// Support vector counts (total, bounded-at-box).
    pub fn sv_counts(&self, tol: f64) -> (usize, usize) {
        let mut sv = 0;
        let mut bsv = 0;
        for i in 0..self.len() {
            if self.alpha[i].abs() > tol {
                sv += 1;
                if self.alpha[i] >= self.upper[i] - tol || self.alpha[i] <= self.lower[i] + tol
                {
                    bsv += 1;
                }
            }
        }
        (sv, bsv)
    }

    /// Validate the state's structural invariants (`debug-invariants`
    /// builds only; panics via [`crate::invariant!`] on violation):
    ///
    /// * every state vector has the problem length and
    ///   `active_len ≤ ℓ`,
    /// * `perm`/`pos` are inverse permutations of each other,
    /// * the equality constraint holds: `Σα == equality_sum` within
    ///   `1e-6·(1 + Σ|α|)` (SMO steps move mass along `e_i − e_j`, so the
    ///   sum is conserved exactly up to float dust),
    /// * every α lies in its box `[L, U]` (with relative slack for the
    ///   clamp's floating point) and no box is inverted.
    #[cfg(feature = "debug-invariants")]
    pub fn check_invariants(&self, equality_sum: f64) {
        let n = self.len();
        crate::invariant!(
            self.alpha.len() == n
                && self.grad.len() == n
                && self.lower.len() == n
                && self.upper.len() == n
                && self.perm.len() == n
                && self.pos.len() == n,
            "state vector lengths disagree"
        );
        crate::invariant!(self.active_len <= n, "active prefix longer than the problem");
        crate::invariant!(
            crate::util::invariant::inverse_permutation_ok(&self.perm, &self.pos),
            "perm/pos are not inverse permutations"
        );
        let sum: f64 = self.alpha.iter().sum();
        let scale: f64 = self.alpha.iter().map(|a| a.abs()).sum();
        crate::invariant!(
            (sum - equality_sum).abs() <= 1e-6 * (1.0 + scale),
            "equality constraint drifted: sum alpha = {sum}, target {equality_sum}"
        );
        for p in 0..n {
            let slack = 1e-12 * (1.0 + self.lower[p].abs().max(self.upper[p].abs()));
            crate::invariant!(
                self.lower[p] <= self.upper[p],
                "inverted box at position {p}"
            );
            crate::invariant!(
                self.alpha[p] >= self.lower[p] - slack && self.alpha[p] <= self.upper[p] + slack,
                "alpha[{p}] = {} outside box [{}, {}]",
                self.alpha[p],
                self.lower[p],
                self.upper[p]
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_state_matches_paper() {
        let s = SolverState::new(&[1, -1, 1], 2.0);
        assert_eq!(s.alpha, vec![0.0; 3]);
        assert_eq!(s.grad, vec![1.0, -1.0, 1.0]); // G(0) = y
        assert_eq!(s.lower, vec![0.0, -2.0, 0.0]);
        assert_eq!(s.upper, vec![2.0, 0.0, 2.0]);
        assert_eq!(s.perm, vec![0, 1, 2]);
        assert_eq!(s.active_len, 3);
        assert!(s.is_feasible(0.0));
        // at alpha=0 every +1 is in I_up only direction, -1 in I_down
        assert!(s.in_up(0) && !s.in_down(0));
        assert!(!s.in_up(1) || s.in_down(1));
    }

    #[test]
    fn step_bounds_hand_computed() {
        let mut s = SolverState::new(&[1, -1], 1.0);
        // from zero: direction e0 - e1 can grow until alpha0 = 1 or alpha1 = -1
        let (lo, hi) = s.step_bounds(0, 1);
        assert_eq!((lo, hi), (0.0, 1.0));
        s.apply_step(0, 1, 0.25);
        let (lo, hi) = s.step_bounds(0, 1);
        assert_eq!((lo, hi), (-0.25, 0.75));
    }

    #[test]
    fn apply_step_keeps_feasibility_and_snaps() {
        let mut s = SolverState::new(&[1, -1], 1.0);
        s.apply_step(0, 1, 1.0 + 1e-16); // numerically slightly over
        assert!(s.is_feasible(1e-12));
        assert_eq!(s.alpha[0], 1.0);
        assert_eq!(s.alpha[1], -1.0);
    }

    #[test]
    fn swap_keeps_all_vectors_and_maps_in_lockstep() {
        let mut s = SolverState::new(&[1, -1, 1, -1], 2.0);
        s.alpha = vec![0.5, -0.25, 0.0, -0.25];
        s.grad = vec![0.1, 0.2, 0.3, 0.4];
        s.swap(0, 3);
        assert_eq!(s.perm, vec![3, 1, 2, 0]);
        assert_eq!(s.pos, vec![3, 1, 2, 0]);
        assert_eq!(s.alpha, vec![-0.25, -0.25, 0.0, 0.5]);
        assert_eq!(s.grad, vec![0.4, 0.2, 0.3, 0.1]);
        assert_eq!(s.y[0], -1.0);
        assert_eq!(s.lower[0], -2.0);
        // swapping back restores identity
        s.swap(3, 0);
        assert_eq!(s.perm, vec![0, 1, 2, 3]);
        assert_eq!(s.alpha, vec![0.5, -0.25, 0.0, -0.25]);
        // self-swap is a no-op
        s.swap(2, 2);
        assert_eq!(s.perm, vec![0, 1, 2, 3]);
    }

    #[test]
    fn alpha_original_undoes_the_permutation() {
        let mut s = SolverState::new(&[1, -1, 1], 1.0);
        s.alpha = vec![0.1, -0.3, 0.2];
        s.swap(0, 2);
        s.swap(1, 2);
        assert_eq!(s.alpha_original(), vec![0.1, -0.3, 0.2]);
    }

    #[test]
    fn objective_identity_vs_direct_computation() {
        // 2-variable problem with explicit K
        let k = [[1.0, 0.5], [0.5, 1.0]];
        let mut s = SolverState::new(&[1, -1], 10.0);
        let (a0, a1) = (0.7, -0.7);
        s.alpha = vec![a0, a1];
        // maintain G = y - K alpha by hand
        s.grad = vec![
            1.0 - (k[0][0] * a0 + k[0][1] * a1),
            -1.0 - (k[1][0] * a0 + k[1][1] * a1),
        ];
        let direct = (1.0 * a0 + -1.0 * a1)
            - 0.5
                * (a0 * (k[0][0] * a0 + k[0][1] * a1) + a1 * (k[1][0] * a0 + k[1][1] * a1));
        assert!((s.objective() - direct).abs() < 1e-12);
    }

    #[test]
    fn kkt_gap_at_origin_is_two() {
        // classic: at alpha=0, m = max G over I_up = 1 (a +1 example),
        // M = min over I_down = -1 (a -1 example), gap = 2.
        let s = SolverState::new(&[1, 1, -1, -1], 1.0);
        let (m, big_m, gap) = s.kkt_gap_active();
        assert_eq!((m, big_m, gap), (1.0, -1.0, 2.0));
    }

    #[test]
    fn kkt_scan_ignores_positions_beyond_the_active_prefix() {
        let mut s = SolverState::new(&[1, 1, -1, -1], 1.0);
        s.grad = vec![0.5, 9.0, -0.5, -9.0];
        // move the extreme gradients out of the active prefix
        s.swap(1, 3);
        s.active_len = 2; // positions 0 and 1 = originals 0 and 3
        let (m, big_m, gap, argmax) = s.kkt_scan();
        assert_eq!(m, 0.5);
        assert_eq!(big_m, -9.0);
        assert_eq!(gap, 9.5);
        assert_eq!(argmax, Some(0));
    }

    #[test]
    fn bias_prefers_free_svs() {
        let mut s = SolverState::new(&[1, -1], 1.0);
        s.alpha = vec![0.5, -0.5]; // both free
        s.grad = vec![0.3, 0.1];
        assert!((s.bias() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn sv_counts_distinguish_bounded() {
        let mut s = SolverState::new(&[1, 1, -1], 1.0);
        s.alpha = vec![1.0, 0.5, -0.2];
        let (sv, bsv) = s.sv_counts(1e-9);
        assert_eq!((sv, bsv), (3, 1));
    }

    #[cfg(feature = "debug-invariants")]
    mod invariant_checks {
        use super::*;
        use crate::util::prng::Pcg;
        use crate::util::quickcheck::forall;

        #[test]
        fn healthy_state_passes() {
            let mut s = SolverState::new(&[1, -1, 1, -1], 2.0);
            s.check_invariants(0.0);
            s.apply_step(0, 1, 0.5);
            s.swap(0, 3);
            s.check_invariants(0.0);
        }

        #[test]
        #[should_panic(expected = "invariant violated")]
        fn alpha_sum_drift_is_caught() {
            let mut s = SolverState::new(&[1, -1], 1.0);
            s.alpha[0] = 0.5; // one-sided update breaks the equality sum
            s.check_invariants(0.0);
        }

        #[test]
        #[should_panic(expected = "invariant violated")]
        fn out_of_box_alpha_is_caught() {
            let mut s = SolverState::new(&[1, -1], 1.0);
            s.alpha = vec![2.0, -2.0]; // sum is fine, the box is not
            s.check_invariants(0.0);
        }

        #[test]
        #[should_panic(expected = "invariant violated")]
        fn broken_permutation_is_caught() {
            let mut s = SolverState::new(&[1, -1, 1], 1.0);
            s.pos.swap(0, 1); // pos no longer inverts perm
            s.check_invariants(0.0);
        }

        #[test]
        fn random_step_and_swap_sequences_never_trip_the_checkers() {
            forall(
                "steps and swaps preserve state invariants",
                60,
                |rng: &mut Pcg| {
                    let n = 3 + rng.below(12);
                    let ops: Vec<(usize, usize, f64)> = (0..25)
                        .map(|_| (rng.below(n), rng.below(n), rng.range(-2.0, 2.0)))
                        .collect();
                    (n, ops)
                },
                |&(n, ref ops)| {
                    let labels: Vec<i8> =
                        (0..n).map(|k| if k % 2 == 0 { 1 } else { -1 }).collect();
                    let mut s = SolverState::new(&labels, 1.5);
                    for &(p, q, mu) in ops {
                        if p != q {
                            // alternate SMO-style steps (kept inside the
                            // feasible interval, as the solver does) and
                            // shrink-style swaps
                            let (lo, hi) = s.step_bounds(p, q);
                            s.apply_step(p, q, mu.clamp(lo, hi));
                            s.swap(p, q);
                        }
                        s.check_invariants(0.0);
                    }
                    Ok(())
                },
            );
        }
    }
}
