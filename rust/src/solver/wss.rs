//! Working-set selection policies.
//!
//! * [`select_max_violating`] — first-order WSS-1 (Keerthi's MVP).
//! * [`select_second_order`] with [`GainKind::Approx`] — WSS-2 of Fan
//!   et al. (paper eq. 3): `i = argmax G` over `I_up`, `j = argmax ĝ_{(i,n)}`
//!   over `I_down`.
//! * [`GainKind::Exact`] — the same scan but scored with the *exact*
//!   (clipped) SMO gain `g` instead of `ĝ`, as required by the else-branch
//!   of Algorithm 3.
//! * `extra` candidates — Algorithm 3 additionally offers the working set
//!   used for planning (`B^(t−2)`) to the selection; the multiple-planning
//!   variant (§7.4) offers the N most recent sets.

use crate::kernel::matrix::Gram;

use super::state::SolverState;
use super::step::{newton_gain_tau, SubProblem, TAU};

/// Gain function used to score candidate pairs (Algorithm 3's two modes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GainKind {
    /// `ĝ` — Newton-step gain (eq. 3), exact only for free steps.
    Approx,
    /// `g` — exact SMO gain with box clipping (eq. 4 with clipped μ).
    Exact,
}

/// A selected working set (tuple, paper's ordered convention).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Selection {
    /// The ascent index (`i ∈ I_up`, positional).
    pub i: usize,
    /// The descent index (`j ∈ I_down`, positional).
    pub j: usize,
}

/// First-order selection: most violating pair over the active prefix.
pub fn select_max_violating(state: &SolverState) -> Option<Selection> {
    let mut best_i: Option<usize> = None;
    let mut best_j: Option<usize> = None;
    let (mut gi, mut gj) = (f64::NEG_INFINITY, f64::INFINITY);
    for n in 0..state.active_len {
        let g = state.grad[n];
        if state.in_up(n) && g > gi {
            gi = g;
            best_i = Some(n);
        }
        if state.in_down(n) && g < gj {
            gj = g;
            best_j = Some(n);
        }
    }
    match (best_i, best_j) {
        (Some(i), Some(j)) if i != j && gi - gj > 0.0 => Some(Selection { i, j }),
        _ => None,
    }
}

/// Score a candidate pair `(i, j)` under the given gain kind.
/// Requires `i ∈ I_up`, `j ∈ I_down`, positive violation `l = G_i − G_j`.
fn pair_gain(
    state: &SolverState,
    kind: GainKind,
    l: f64,
    q: f64,
    i: usize,
    j: usize,
) -> f64 {
    match kind {
        GainKind::Approx => newton_gain_tau(l, q),
        GainKind::Exact => {
            let (lo, hi) = state.step_bounds(i, j);
            let sp = SubProblem { l, q: q.max(TAU), lo, hi };
            sp.gain(sp.clipped_step())
        }
    }
}

/// Second-order selection (paper eq. 3 / Algorithm 3), optionally scored
/// with the exact gain and with extra candidate tuples in the running.
///
/// Fetches kernel row `i` through the Gram cache — the same row the
/// subsequent gradient update needs, so the fetch is never wasted.
pub fn select_second_order(
    state: &SolverState,
    gram: &mut Gram,
    kind: GainKind,
    extra: &[(usize, usize)],
) -> Option<Selection> {
    // i = argmax G over I_up (active prefix)
    let mut i = usize::MAX;
    let mut gi = f64::NEG_INFINITY;
    for n in 0..state.active_len {
        if state.in_up(n) && state.grad[n] > gi {
            gi = state.grad[n];
            i = n;
        }
    }
    if i == usize::MAX {
        return None;
    }
    select_second_order_with_i(state, gram, kind, extra, i)
}

/// [`select_second_order`] with the `i = argmax G over I_up` already known
/// (the solver core computes it in the fused stopping scan — one O(active)
/// pass saved per iteration).
pub fn select_second_order_with_i(
    state: &SolverState,
    gram: &mut Gram,
    kind: GainKind,
    extra: &[(usize, usize)],
    i: usize,
) -> Option<Selection> {
    let gi = state.grad[i];

    let kii = gram.diag(i);
    // Pull row i through the cache, then hold a shared borrow of the
    // resident row for the scan. The borrow ties to `&Gram`, so only the
    // non-evicting read surface (`diag`) is reachable while it lives —
    // the no-evict contract is compiler-enforced.
    gram.row(i);
    let row_i = gram.resident_row(i).expect("row i just fetched");

    // j = argmax gain over I_down with positive violation — a linear
    // sweep over the contiguous active prefix.
    let mut best: Option<(usize, f64)> = None;
    for n in 0..state.active_len {
        if n == i || !state.in_down(n) {
            continue;
        }
        let l = gi - state.grad[n];
        if l <= 0.0 {
            continue;
        }
        let q = kii - 2.0 * row_i[n] as f64 + gram.diag(n);
        let gain = pair_gain(state, kind, l, q, i, n);
        if best.map(|(_, g)| gain > g).unwrap_or(true) {
            best = Some((n, gain));
        }
    }
    let (mut sel, mut sel_gain) = match best {
        Some((j, g)) => (Selection { i, j }, g),
        None => return None,
    };

    // Algorithm 3: candidate working sets from planning history. Callers
    // pass *active positions* (PA-SMO maps its original-coordinate
    // history through `state.pos` and drops shrunk pairs). They are
    // scored with the same gain function and must be feasible directions.
    for &(a, b) in extra {
        if a == b || a >= state.active_len || b >= state.active_len {
            continue;
        }
        if !state.in_up(a) || !state.in_down(b) {
            continue;
        }
        let l = state.grad[a] - state.grad[b];
        if l <= 0.0 {
            continue;
        }
        let q = gram.diag(a) - 2.0 * gram.entry(a, b) + gram.diag(b);
        let gain = pair_gain(state, kind, l, q, a, b);
        if gain > sel_gain {
            sel = Selection { i: a, j: b };
            sel_gain = gain;
        }
    }
    Some(sel)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::kernel::function::KernelFunction;
    use crate::kernel::native::NativeRowComputer;
    use crate::util::prng::Pcg;
    use std::sync::Arc;

    fn toy_problem(n: usize, seed: u64) -> (SolverState, Gram) {
        let mut rng = Pcg::new(seed);
        let mut ds = Dataset::with_dim(2);
        for _ in 0..n {
            ds.push(
                &[rng.normal() as f32, rng.normal() as f32],
                if rng.bernoulli(0.5) { 1 } else { -1 },
            );
        }
        // guarantee both classes exist
        let labels: Vec<i8> = ds.labels().to_vec();
        let mut ds2 = Dataset::with_dim(2);
        for (i, &y) in labels.iter().enumerate() {
            let y = if i == 0 { 1 } else if i == 1 { -1 } else { y };
            ds2.push(ds.row(i), y);
        }
        let labels: Vec<i8> = ds2.labels().to_vec();
        let state = SolverState::new(&labels, 1.0);
        let nc = NativeRowComputer::new(Arc::new(ds2), KernelFunction::Rbf { gamma: 1.0 });
        (state, Gram::new(Box::new(nc), 1 << 20))
    }

    #[test]
    fn mvp_at_origin_picks_pos_and_neg() {
        let (state, _) = toy_problem(10, 1);
        let sel = select_max_violating(&state).unwrap();
        // at alpha=0, I_up members with max G are +1 examples (G=+1),
        // I_down members with min G are −1 examples (G=−1).
        assert_eq!(state.y[sel.i], 1.0);
        assert_eq!(state.y[sel.j], -1.0);
    }

    #[test]
    fn second_order_agrees_with_exhaustive_argmax() {
        let (state, mut gram) = toy_problem(16, 2);
        let sel = select_second_order(&state, &mut gram, GainKind::Approx, &[]).unwrap();
        // exhaustive over the same i
        let mut gi = f64::NEG_INFINITY;
        let mut i = 0;
        for n in 0..state.len() {
            if state.in_up(n) && state.grad[n] > gi {
                gi = state.grad[n];
                i = n;
            }
        }
        assert_eq!(sel.i, i);
        let mut best = (usize::MAX, f64::NEG_INFINITY);
        for n in 0..state.len() {
            if n == i || !state.in_down(n) {
                continue;
            }
            let l = gi - state.grad[n];
            if l <= 0.0 {
                continue;
            }
            let q = gram.diag(i) - 2.0 * gram.entry(i, n) + gram.diag(n);
            let g = newton_gain_tau(l, q);
            if g > best.1 {
                best = (n, g);
            }
        }
        assert_eq!(sel.j, best.0);
    }

    #[test]
    fn no_selection_at_optimum() {
        // Bounded optimum: α = (U₀, L₁) leaves I_up = {1}, I_down = {0};
        // with G₁ < G₀ the only candidate pair is non-violating.
        let mut state = SolverState::new(&[1, -1], 1.0);
        state.alpha = vec![1.0, -1.0];
        state.grad = vec![0.5, -0.5];
        assert!(select_max_violating(&state).is_none());
        let (_, mut gram) = toy_problem(2, 3);
        assert!(select_second_order(&state, &mut gram, GainKind::Approx, &[]).is_none());
    }

    #[test]
    fn extra_candidate_can_win_under_exact_gain() {
        let (mut state, mut gram) = toy_problem(12, 4);
        // Make the default selection's step heavily clipped by shrinking
        // the best pair's room: push the argmax-G index near its bound.
        let base = select_second_order(&state, &mut gram, GainKind::Exact, &[]).unwrap();
        state.alpha[base.i] = state.upper[base.i] - 1e-9; // nearly no room
        // find any other feasible violating pair to offer
        let mut offer = None;
        for a in 0..state.len() {
            for b in 0..state.len() {
                if a != b
                    && a != base.i
                    && b != base.i
                    && state.in_up(a)
                    && state.in_down(b)
                    && state.grad[a] - state.grad[b] > 0.0
                {
                    offer = Some((a, b));
                }
            }
        }
        if let Some(pair) = offer {
            let sel =
                select_second_order(&state, &mut gram, GainKind::Exact, &[pair]).unwrap();
            // the selection is at least as good as the offered pair under g
            let gain = |s: &Selection, st: &SolverState, gr: &mut Gram| {
                let l = st.grad[s.i] - st.grad[s.j];
                let q = gr.diag(s.i) - 2.0 * gr.entry(s.i, s.j) + gr.diag(s.j);
                super::pair_gain(st, GainKind::Exact, l, q, s.i, s.j)
            };
            let g_sel = gain(&sel, &state, &mut gram);
            let g_off = gain(&Selection { i: pair.0, j: pair.1 }, &state, &mut gram);
            assert!(g_sel >= g_off - 1e-12);
        }
    }

    #[test]
    fn infeasible_extras_are_ignored() {
        let (state, mut gram) = toy_problem(8, 5);
        let sel0 = select_second_order(&state, &mut gram, GainKind::Approx, &[]).unwrap();
        // candidates violating the I_up/I_down constraints must not crash
        // or alter the outcome
        let bogus = [(0, 0), (sel0.i, sel0.i)];
        let sel1 =
            select_second_order(&state, &mut gram, GainKind::Approx, &bogus).unwrap();
        assert_eq!(sel0, sel1);
    }
}
