//! Shrinking heuristic and gradient reconstruction (paper §2; Joachims).
//!
//! Variables confidently bounded in the final solution are removed from
//! the active set, so working-set selection, the stopping check and the
//! gradient update only touch the (usually small) interesting subset.
//! Removal is *prefix compaction*: a shrunk variable is swapped behind
//! the active prefix `[0, active_len)` (LIBSVM's `swap_index`), with the
//! Gram view swapped in lockstep — so kernel rows computed afterwards
//! cover only the surviving prefix and cost proportionally less, both to
//! evaluate and in cache budget. Before declaring convergence the
//! gradient is reconstructed for the shrunk tail and the full problem
//! re-checked.

use crate::kernel::matrix::Gram;

use super::state::SolverState;

/// Can variable at position `p` serve as neither the `i` nor the `j` of
/// any violating pair, given the extremes `m = max G over I_up`,
/// `big_m = min G over I_down`?
/// * `α_p = U_p` (not in `I_up`): only usable as `j`; useless if `G_p ≥ m`.
/// * `α_p = L_p` (not in `I_down`): only usable as `i`; useless if `G_p ≤ big_m`.
/// * free variables are never shrunk.
fn removable(state: &SolverState, p: usize, m: f64, big_m: f64) -> bool {
    let at_upper = !state.in_up(p);
    let at_lower = !state.in_down(p);
    if at_upper && at_lower {
        // fixed variable (C degenerate); always removable
        true
    } else if at_upper {
        state.grad[p] >= m
    } else if at_lower {
        state.grad[p] <= big_m
    } else {
        false
    }
}

/// Shrink bounded, confidently non-violating variables out of the active
/// prefix, given the current violating-pair extremes. The state and the
/// Gram view are compacted together with a two-pointer partition (the
/// keepers end up in `[0, keepers)`, in-order relative to each other on
/// the left side of the partition). At least two variables always stay
/// active. Returns the number of newly shrunk indices.
pub fn shrink(state: &mut SolverState, gram: &mut Gram, m: f64, big_m: f64) -> usize {
    if !m.is_finite() || !big_m.is_finite() {
        return 0;
    }
    let al = state.active_len;
    let mut keep: Vec<bool> = (0..al).map(|p| !removable(state, p, m, big_m)).collect();
    let mut keepers = keep.iter().filter(|&&k| k).count();
    if keepers < 2 {
        // promote the lowest-position shrink candidates back to active
        for k in keep.iter_mut() {
            if keepers >= 2 {
                break;
            }
            if !*k {
                *k = true;
                keepers += 1;
            }
        }
    }
    if keepers == al {
        return 0;
    }
    let mut lo = 0usize;
    let mut hi = al;
    let mut swaps: Vec<(usize, usize)> = Vec::new();
    while lo < hi {
        if keep[lo] {
            lo += 1;
            continue;
        }
        hi -= 1;
        if !keep[hi] {
            continue; // already on the correct (shrunk) side
        }
        state.swap(lo, hi);
        swaps.push((lo, hi));
        keep.swap(lo, hi);
        lo += 1;
    }
    debug_assert_eq!(lo, keepers);
    // Mirror the whole compaction into the Gram in one batch (single
    // cache traversal instead of one per swap).
    gram.apply_swaps(&swaps);
    state.active_len = keepers;
    gram.set_active_len(keepers);
    #[cfg(feature = "debug-invariants")]
    {
        crate::invariant!(
            crate::util::invariant::inverse_permutation_ok(&state.perm, &state.pos),
            "shrink broke the perm/pos bijection"
        );
        crate::invariant!(
            gram.active_len() == state.active_len,
            "gram/state active prefixes disagree after shrink"
        );
        crate::invariant!(state.active_len >= 2, "shrink left fewer than two active");
    }
    al - keepers
}

/// Reactivate all variables and reconstruct their gradients:
/// `G_p = y_p − Σ_q α_q K_{qp}` for tail positions `p ≥ active_len`. The
/// sum runs over support vectors only; each contributes one *tail-only*
/// gathered row (`Gram::tail_into`) — resident full rows are reused for
/// free, and freshly computed tails never evict useful prefix rows.
pub fn unshrink_and_reconstruct(state: &mut SolverState, gram: &mut Gram) {
    let n = state.len();
    let start = state.active_len;
    if start == n {
        gram.set_active_len(n);
        return;
    }
    // Start tail gradients from y_p.
    for p in start..n {
        state.grad[p] = state.y[p];
    }
    // Subtract α_q K_{qp} contributions from every support vector q.
    let mut tail = vec![0f32; n - start];
    for q in 0..n {
        let aq = state.alpha[q];
        if aq == 0.0 {
            continue;
        }
        gram.tail_into(q, start, &mut tail);
        for (p, &k) in (start..n).zip(tail.iter()) {
            state.grad[p] -= aq * k as f64;
        }
    }
    state.active_len = n;
    gram.set_active_len(n);
    #[cfg(feature = "debug-invariants")]
    {
        // Gradient parity: the incrementally maintained gradient must
        // agree with a direct recompute G_p = y_p − Σ_q α_q K_qp on a
        // spread-out sample of positions. Rows are f32 and the increments
        // accumulate over the whole solve, so the tolerance is generous —
        // this catches structural corruption (a missed update, a wrong
        // index or sign), not float dust. Sampling keeps the check (and
        // its kernel-meter footprint) linear rather than quadratic.
        let scale: f64 = state.alpha.iter().map(|a| a.abs()).sum();
        let tol = 1e-3 * (1.0 + scale);
        for p in (0..n).step_by((n / 8).max(1)) {
            let mut want = state.y[p];
            for q in 0..n {
                if state.alpha[q].abs() > 0.0 {
                    want -= state.alpha[q] * gram.entry(q, p);
                }
            }
            crate::invariant!(
                (state.grad[p] - want).abs() <= tol,
                "gradient parity lost at position {p}: maintained {} vs recomputed {want}",
                state.grad[p]
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::kernel::function::KernelFunction;
    use crate::kernel::native::NativeRowComputer;
    use crate::util::prng::Pcg;
    use std::sync::Arc;

    fn problem(n: usize, seed: u64) -> (SolverState, Gram, Arc<Dataset>) {
        let mut rng = Pcg::new(seed);
        let mut ds = Dataset::with_dim(3);
        for k in 0..n {
            let y = if k % 2 == 0 { 1 } else { -1 };
            ds.push(
                &[rng.normal() as f32, rng.normal() as f32, rng.normal() as f32],
                y,
            );
        }
        let ds = Arc::new(ds);
        let labels: Vec<i8> = ds.labels().to_vec();
        let state = SolverState::new(&labels, 1.0);
        let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 0.7 });
        (state, Gram::new(Box::new(nc), 1 << 20), ds)
    }

    /// Original indices currently in the active prefix.
    fn active_originals(state: &SolverState) -> Vec<usize> {
        state.perm[..state.active_len].to_vec()
    }

    #[test]
    fn shrinks_only_confident_bounded_variables() {
        let (mut state, mut gram, _) = problem(6, 1);
        // construct: index 0 at upper bound with G >= m, index 1 free,
        // index 2 at lower bound with G <= M.
        state.alpha[0] = state.upper[0];
        state.grad[0] = 5.0;
        state.alpha[2] = state.lower[2];
        state.grad[2] = -5.0;
        let before = state.active_len;
        let removed = shrink(&mut state, &mut gram, 1.0, -1.0);
        assert_eq!(removed, 2);
        assert_eq!(state.active_len, before - 2);
        assert_eq!(gram.active_len(), state.active_len);
        let actives = active_originals(&state);
        assert!(!actives.contains(&0));
        assert!(!actives.contains(&2));
        assert!(actives.contains(&1));
    }

    #[test]
    fn free_variables_never_shrunk() {
        let (mut state, mut gram, _) = problem(4, 2);
        // index 1 has y=-1 => bounds [-1, 0]; put it strictly inside.
        state.alpha[1] = 0.5 * (state.lower[1] + state.upper[1]) - 0.25;
        assert!(state.in_up(1) && state.in_down(1), "test setup: must be free");
        state.grad[1] = 100.0;
        shrink(&mut state, &mut gram, 0.0, 0.0);
        assert!(active_originals(&state).contains(&1));
    }

    #[test]
    fn keeps_at_least_two_active() {
        let (mut state, mut gram, _) = problem(4, 3);
        for n in 0..4 {
            state.alpha[n] = state.upper[n]; // everyone at a bound
            state.grad[n] = 10.0;
        }
        shrink(&mut state, &mut gram, 0.0, 0.0);
        assert!(state.active_len >= 2);
    }

    #[test]
    fn shrunk_state_and_gram_stay_aligned() {
        // After compaction, position (p, q) of the Gram must evaluate the
        // kernel of exactly the original pair the state's permutation
        // names — the lockstep-swap contract between shrink and the view.
        let (mut state, mut gram, ds) = problem(10, 7);
        for p in 0..10 {
            if p % 3 == 0 {
                state.alpha[p] = state.upper[p];
                state.grad[p] = 5.0;
            }
        }
        let removed = shrink(&mut state, &mut gram, 1.0, -1.0);
        assert!(removed > 0, "test setup: something must shrink");
        assert!(state.active_len >= 2);
        let k = KernelFunction::Rbf { gamma: 0.7 };
        for p in 0..state.len() {
            for q in 0..state.len() {
                let want = k.eval(ds.row(state.perm[p]), ds.row(state.perm[q]));
                let got = gram.entry(p, q);
                assert!(
                    (got - want).abs() < 1e-6,
                    "({p},{q}): gram {got} vs kernel {want}"
                );
            }
        }
    }

    #[test]
    fn reconstruction_matches_full_recompute() {
        let (mut state, mut gram, ds) = problem(12, 4);
        // random feasible alpha (pairs to keep sum zero)
        let mut rng = Pcg::new(9);
        for k in 0..6 {
            let a = rng.range(0.0, 0.8);
            let (i, j) = (2 * k, 2 * k + 1); // +1 and -1 labels
            state.alpha[i] = a;
            state.alpha[j] = -a;
        }
        // set the true gradient everywhere (positional == original here)
        for n in 0..12 {
            let mut s = state.y[n];
            for j in 0..12 {
                s -= state.alpha[j] * gram.entry(j, n);
            }
            state.grad[n] = s;
        }
        // shrink half of the positions arbitrarily (mirrored swaps), then
        // corrupt the inactive gradients
        let mut al = 12;
        for _ in 0..6 {
            al -= 1;
            let victim = al % 3; // deactivate some low positions via swaps
            state.swap(victim, al);
            gram.swap_index(victim, al);
        }
        state.active_len = al;
        gram.set_active_len(al);
        for p in al..12 {
            state.grad[p] = f64::NAN;
        }
        unshrink_and_reconstruct(&mut state, &mut gram);
        assert_eq!(state.active_len, 12);
        assert_eq!(gram.active_len(), 12);
        for p in 0..12 {
            let mut want = state.y[p];
            for q in 0..12 {
                want -= state.alpha[q] * gram.entry(q, p);
            }
            // f32 row evaluation vs f64 single-entry evaluation differ at
            // float precision per term; 1e-5 covers the 12-term sum.
            assert!(
                (state.grad[p] - want).abs() < 1e-5,
                "p={p}: {} vs {want}",
                state.grad[p]
            );
        }
        let _ = ds;
    }

    #[test]
    fn unshrink_on_fully_active_state_is_noop() {
        let (mut state, mut gram, _) = problem(5, 5);
        let grad_before = state.grad.clone();
        unshrink_and_reconstruct(&mut state, &mut gram);
        assert_eq!(state.grad, grad_before);
    }
}
