//! Shrinking heuristic and gradient reconstruction (paper §2; Joachims).
//!
//! Variables confidently bounded in the final solution are removed from
//! the active set, so working-set selection, the stopping check and the
//! gradient update only touch the (usually small) interesting subset.
//! Before declaring convergence the gradient is reconstructed for the
//! shrunk indices and the full problem re-checked.

use crate::kernel::matrix::Gram;

use super::state::SolverState;

/// Shrink bounded, confidently non-violating variables out of the active
/// set, given the current violating-pair extremes `m = max G over I_up`,
/// `big_m = min G over I_down`. Returns the number of newly shrunk indices.
///
/// Criteria (a variable is shrunk only if it can serve *neither* as the
/// `i` nor the `j` of any violating pair):
/// * `α_n = U_n` (not in `I_up`): only usable as `j`; useless if `G_n ≥ m`.
/// * `α_n = L_n` (not in `I_down`): only usable as `i`; useless if `G_n ≤ big_m`.
/// * free variables are never shrunk.
pub fn shrink(state: &mut SolverState, m: f64, big_m: f64) -> usize {
    if !m.is_finite() || !big_m.is_finite() {
        return 0;
    }
    let mut removed = 0usize;
    let mut idx = 0usize;
    while idx < state.active.len() {
        let n = state.active[idx];
        let at_upper = !state.in_up(n);
        let at_lower = !state.in_down(n);
        let useless = if at_upper && at_lower {
            // fixed variable (C degenerate); always removable
            true
        } else if at_upper {
            state.grad[n] >= m
        } else if at_lower {
            state.grad[n] <= big_m
        } else {
            false
        };
        if useless && state.active.len() > 2 {
            state.active.swap_remove(idx);
            state.is_active[n] = false;
            removed += 1;
        } else {
            idx += 1;
        }
    }
    removed
}

/// Reactivate all variables and reconstruct their gradients:
/// `G_n = y_n − Σ_j α_j K_{jn}` for previously inactive `n`. The sum runs
/// over support vectors only; their rows come through the Gram cache.
pub fn unshrink_and_reconstruct(state: &mut SolverState, gram: &mut Gram) {
    let n_total = state.len();
    if state.active.len() == n_total {
        return;
    }
    // Start inactive gradients from y_n.
    let inactive: Vec<usize> = (0..n_total).filter(|&n| !state.is_active[n]).collect();
    for &n in &inactive {
        state.grad[n] = state.y[n];
    }
    // Subtract α_j K_jn contributions from every support vector j.
    for j in 0..n_total {
        let aj = state.alpha[j];
        if aj == 0.0 {
            continue;
        }
        let row = gram.row(j);
        for &n in &inactive {
            state.grad[n] -= aj * row[n] as f64;
        }
    }
    state.active = (0..n_total).collect();
    state.is_active.iter_mut().for_each(|b| *b = true);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::kernel::function::KernelFunction;
    use crate::kernel::native::NativeRowComputer;
    use crate::util::prng::Pcg;
    use std::sync::Arc;

    fn problem(n: usize, seed: u64) -> (SolverState, Gram, Arc<Dataset>) {
        let mut rng = Pcg::new(seed);
        let mut ds = Dataset::with_dim(3);
        for k in 0..n {
            let y = if k % 2 == 0 { 1 } else { -1 };
            ds.push(
                &[rng.normal() as f32, rng.normal() as f32, rng.normal() as f32],
                y,
            );
        }
        let ds = Arc::new(ds);
        let labels: Vec<i8> = ds.labels().to_vec();
        let state = SolverState::new(&labels, 1.0);
        let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 0.7 });
        (state, Gram::new(Box::new(nc), 1 << 20), ds)
    }

    #[test]
    fn shrinks_only_confident_bounded_variables() {
        let (mut state, _, _) = problem(6, 1);
        // construct: index 0 at upper bound with G >= m, index 1 free,
        // index 2 at lower bound with G <= M.
        state.alpha[0] = state.upper[0];
        state.grad[0] = 5.0;
        state.alpha[2] = state.lower[2];
        state.grad[2] = -5.0;
        let before = state.active.len();
        let removed = shrink(&mut state, 1.0, -1.0);
        assert_eq!(removed, 2);
        assert_eq!(state.active.len(), before - 2);
        assert!(!state.is_active[0]);
        assert!(!state.is_active[2]);
        assert!(state.is_active[1]);
    }

    #[test]
    fn free_variables_never_shrunk() {
        let (mut state, _, _) = problem(4, 2);
        // index 1 has y=-1 => bounds [-1, 0]; put it strictly inside.
        state.alpha[1] = 0.5 * (state.lower[1] + state.upper[1]) - 0.25;
        assert!(state.in_up(1) && state.in_down(1), "test setup: must be free");
        state.grad[1] = 100.0;
        shrink(&mut state, 0.0, 0.0);
        assert!(state.is_active[1]);
    }

    #[test]
    fn keeps_at_least_two_active() {
        let (mut state, _, _) = problem(4, 3);
        for n in 0..4 {
            state.alpha[n] = state.upper[n]; // everyone at a bound
            state.grad[n] = 10.0;
        }
        shrink(&mut state, 0.0, 0.0);
        assert!(state.active.len() >= 2);
    }

    #[test]
    fn reconstruction_matches_full_recompute() {
        let (mut state, mut gram, ds) = problem(12, 4);
        // random feasible alpha (pairs to keep sum zero)
        let mut rng = Pcg::new(9);
        for k in 0..6 {
            let a = rng.range(0.0, 0.8);
            let (i, j) = (2 * k, 2 * k + 1); // +1 and -1 labels
            state.alpha[i] = a;
            state.alpha[j] = -a;
        }
        // set the true gradient everywhere
        for n in 0..12 {
            let mut s = state.y[n];
            for j in 0..12 {
                s -= state.alpha[j] * gram.entry(j, n);
            }
            state.grad[n] = s;
        }
        // shrink half of the indices arbitrarily, corrupt their gradients
        for n in 0..6 {
            state.is_active[n] = false;
            state.grad[n] = f64::NAN;
        }
        state.active = (6..12).collect();
        unshrink_and_reconstruct(&mut state, &mut gram);
        assert_eq!(state.active.len(), 12);
        for n in 0..12 {
            let mut want = state.y[n];
            for j in 0..12 {
                want -= state.alpha[j] * gram.entry(j, n);
            }
            assert!(
                (state.grad[n] - want).abs() < 1e-6,
                "n={n}: {} vs {want}",
                state.grad[n]
            );
        }
        let _ = ds;
    }

    #[test]
    fn unshrink_on_fully_active_state_is_noop() {
        let (mut state, mut gram, _) = problem(5, 5);
        let grad_before = state.grad.clone();
        unshrink_and_reconstruct(&mut state, &mut gram);
        assert_eq!(state.grad, grad_before);
    }
}
