//! The engine contract: one stable interface between problem
//! descriptions ([`QpProblem`]) and the solver family.
//!
//! Everything above the solver layer — `svm::Trainer`, ε-SVR, one-class,
//! the coordinator drivers — talks to a `dyn Engine` built by the single
//! [`EngineConfig::build`] factory. Adding a solver (Frank-Wolfe, …)
//! means implementing [`Engine`] and adding one factory arm; no caller
//! changes — exactly how the conjugate SMO engine
//! (`solver::conjugate`, PR 4) plugged in after PA-SMO.

use crate::kernel::matrix::Gram;

use super::conjugate::ConjugateSmoSolver;
use super::pasmo::PasmoSolver;
use super::problem::QpProblem;
use super::smo::{SmoSolver, SolveResult, SolverConfig};
use super::state::SolverState;

/// Which member of the solver family drives training.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverChoice {
    /// Algorithm 1 (baseline SMO, second-order WSS).
    Smo,
    /// Algorithm 5 (PA-SMO) — the paper's recommended default.
    Pasmo,
    /// Multiple-planning-ahead PA-SMO with N recent working sets (§7.4).
    /// `N = 0` is clamped to 1 (identical to [`SolverChoice::Pasmo`]).
    PasmoMulti(usize),
    /// Conjugate SMO (`solver::conjugate`): conjugate-direction momentum
    /// on top of the SMO step, with a gain fallback to plain SMO.
    ConjugateSmo,
}

/// A QP engine: anything that can drive the paper's general dual problem
/// to an ε-approximate KKT point over a [`Gram`] view.
///
/// ```
/// use std::sync::Arc;
/// use pasmo::data::Dataset;
/// use pasmo::kernel::matrix::Gram;
/// use pasmo::kernel::{KernelFunction, NativeRowComputer};
/// use pasmo::solver::{Engine, EngineConfig, QpProblem, SolverChoice, SolverConfig};
///
/// let ds = Arc::new(Dataset::new(1, vec![1.0, -1.0], vec![1, -1]));
/// let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 0.5 });
/// let mut gram = Gram::new(Box::new(nc), 1 << 20);
/// let engine =
///     EngineConfig::new(SolverChoice::ConjugateSmo, SolverConfig::default()).build();
/// let res = engine.solve(&QpProblem::classification(ds.labels(), 10.0), &mut gram);
/// assert!(res.converged);
/// assert!(res.gap <= 1e-3);
/// ```
pub trait Engine {
    /// Engine name for reports and diagnostics.
    fn name(&self) -> &'static str;

    /// Solve from an explicit, already-lowered state.
    fn solve_state(&self, state: SolverState, gram: &mut Gram) -> SolveResult;

    /// Solve a problem description. This default is the crate's only
    /// [`QpProblem::lower`] call: warm-start repair and gradient
    /// reconstruction happen here for every task and engine alike. The
    /// Gram view is reset first, so a Gram left permuted/shrunk by an
    /// earlier solve is safe to reuse.
    fn solve(&self, problem: &QpProblem, gram: &mut Gram) -> SolveResult {
        gram.reset_view();
        let state = problem.lower(gram);
        self.solve_state(state, gram)
    }
}

/// Complete engine specification: the algorithm plus its shared tuning.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Which solver family member to build.
    pub solver: SolverChoice,
    /// Shared solver tuning handed to the built engine.
    pub config: SolverConfig,
}

impl EngineConfig {
    /// Pair a solver choice with its tuning.
    pub fn new(solver: SolverChoice, config: SolverConfig) -> EngineConfig {
        EngineConfig { solver, config }
    }

    /// The single `SolverChoice` dispatch site in the crate. Centralizes
    /// the `PasmoMulti(n)` → `planning_candidates = max(n, 1)` clamp.
    pub fn build(&self) -> Box<dyn Engine> {
        let mut cfg = self.config;
        match self.solver {
            SolverChoice::Smo => Box::new(SmoSolver::new(cfg)),
            SolverChoice::Pasmo => {
                cfg.planning_candidates = 1;
                Box::new(PasmoSolver::new(cfg))
            }
            SolverChoice::PasmoMulti(n) => {
                cfg.planning_candidates = n.max(1);
                Box::new(PasmoSolver::new(cfg))
            }
            SolverChoice::ConjugateSmo => Box::new(ConjugateSmoSolver::new(cfg)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::smo::tests::{make_gram, random_problem};

    #[test]
    fn factory_names_the_right_engines() {
        let cfg = SolverConfig::default();
        assert_eq!(EngineConfig::new(SolverChoice::Smo, cfg).build().name(), "smo");
        assert_eq!(EngineConfig::new(SolverChoice::Pasmo, cfg).build().name(), "pasmo");
        assert_eq!(
            EngineConfig::new(SolverChoice::PasmoMulti(4), cfg).build().name(),
            "pasmo"
        );
        assert_eq!(
            EngineConfig::new(SolverChoice::ConjugateSmo, cfg).build().name(),
            "conjugate"
        );
    }

    #[test]
    fn pasmo_multi_zero_clamps_to_single_planning() {
        // PasmoMulti(0) is documented to behave as PasmoMulti(1) == Pasmo:
        // identical deterministic solve on the same problem.
        let ds = random_problem(60, 5);
        let problem = QpProblem::classification(ds.labels(), 10.0);
        let cfg = SolverConfig::default();
        let run = |choice: SolverChoice| {
            let mut gram = make_gram(&ds, 1.0, 1 << 22);
            EngineConfig::new(choice, cfg).build().solve(&problem, &mut gram)
        };
        let zero = run(SolverChoice::PasmoMulti(0));
        let one = run(SolverChoice::PasmoMulti(1));
        let pa = run(SolverChoice::Pasmo);
        assert!(zero.converged && one.converged && pa.converged);
        assert_eq!(zero.iterations, one.iterations);
        assert_eq!(zero.objective, one.objective);
        assert_eq!(zero.iterations, pa.iterations);
        assert_eq!(zero.objective, pa.objective);
    }

    #[test]
    fn gram_reuse_across_solves_resets_the_view() {
        // A Gram left permuted/shrunk by one solve must behave exactly
        // like a fresh Gram on the next solve (Engine::solve resets the
        // view): deterministic bit-identical trajectories.
        let ds = random_problem(90, 21);
        let problem = QpProblem::classification(ds.labels(), 50.0);
        let engine = EngineConfig::new(SolverChoice::Pasmo, SolverConfig::default()).build();
        let mut shared = make_gram(&ds, 1.0, 1 << 22);
        let first = engine.solve(&problem, &mut shared);
        let second = engine.solve(&problem, &mut shared);
        let mut fresh = make_gram(&ds, 1.0, 1 << 22);
        let clean = engine.solve(&problem, &mut fresh);
        assert!(first.converged && second.converged && clean.converged);
        assert_eq!(first.alpha, clean.alpha);
        assert_eq!(second.iterations, clean.iterations);
        assert_eq!(second.objective, clean.objective);
        assert_eq!(second.alpha, clean.alpha);
    }

    #[test]
    fn engines_agree_through_the_trait_object() {
        let ds = random_problem(50, 9);
        let problem = QpProblem::classification(ds.labels(), 2.0);
        let mut objectives = Vec::new();
        for choice in [
            SolverChoice::Smo,
            SolverChoice::Pasmo,
            SolverChoice::PasmoMulti(3),
            SolverChoice::ConjugateSmo,
        ] {
            let mut gram = make_gram(&ds, 1.0, 1 << 22);
            let engine = EngineConfig::new(choice, SolverConfig::default()).build();
            let res = engine.solve(&problem, &mut gram);
            assert!(res.converged, "{:?}", choice);
            objectives.push(res.objective);
        }
        for &o in &objectives[1..] {
            let rel = (o - objectives[0]).abs() / (1.0 + objectives[0].abs());
            assert!(rel < 2e-3, "{objectives:?}");
        }
    }
}
