//! The planning-ahead SMO algorithm — the paper's contribution.
//!
//! Implements the complete PA-SMO solver (paper Algorithm 5), composed of:
//! * the planning-ahead update step (Algorithm 4): if the previous
//!   iteration performed a *free* SMO step, compute the planning-ahead
//!   step size μ (eq. 8) assuming the previous working set `B^(t−1)` will
//!   be selected next; revert to the plain SMO step (eq. 2) if either the
//!   current or the planned step would end at the box boundary;
//! * the PA-aware working-set selection (Algorithm 3): after a planning
//!   step whose relative size left the guaranteed-progress band
//!   `[1−η, 1+η]`, select with the *exact* SMO gain `g` instead of `ĝ`,
//!   and in both post-planning branches offer `B^(t−2)` as a candidate —
//!   together these guarantee positive double-step gain (Lemma 3);
//! * the multiple-planning-ahead variant (§7.4): plan with the `N` most
//!   recent working sets and take the largest double-step gain, offering
//!   all of them to the selection.

use std::collections::VecDeque;
use std::time::Instant;

use crate::kernel::matrix::Gram;

use super::engine::Engine;
use super::events::StepKind;
use super::smo::{SolveResult, SolverConfig, SolverCore, StopReason};
use super::state::SolverState;
use super::step::{PlanningSystem, SubProblem};
use super::wss::{GainKind, Selection};

/// The PA-SMO solver (Algorithm 5).
pub struct PasmoSolver {
    /// Shared solver tuning (ε, cache, shrinking, WSS, step policy …).
    pub config: SolverConfig,
}

/// Outcome of a planning attempt against one candidate next working set.
#[derive(Debug, Clone, Copy)]
struct Plan {
    mu: f64,
    gain: f64,
}

impl PasmoSolver {
    /// A planning-ahead SMO engine with the given tuning.
    pub fn new(config: SolverConfig) -> PasmoSolver {
        PasmoSolver { config }
    }

    /// Try to plan ahead on the current working set `sel` assuming `b2`
    /// is selected next (paper §4). Returns `None` — meaning *revert to
    /// the SMO step* — if the 2×2 system is degenerate or either step
    /// would end at the box boundary (Algorithm 2's guard).
    fn plan_with(
        core: &mut SolverCore,
        sel: Selection,
        sp1: &SubProblem,
        b2: (usize, usize),
    ) -> Option<Plan> {
        let (i1, j1) = (sel.i, sel.j);
        let (i2, j2) = b2;
        // Same working set (as a set): det(Q) = 0, nothing to plan.
        if (i1 == i2 && j1 == j2) || (i1 == j2 && j1 == i2) {
            return None;
        }
        let g = &mut *core.gram;
        let st = &core.state;
        let q22 = g.diag(i2) - 2.0 * g.entry(i2, j2) + g.diag(j2);
        // Q12 = v1ᵀ K v2 — the 4 cross entries of the ≤4×4 minor. The rows
        // of B¹ are resident (fetched by selection); B² rows were resident
        // last iteration, so these are almost always cache hits.
        let q12 =
            g.entry(i1, i2) - g.entry(i1, j2) - g.entry(j1, i2) + g.entry(j1, j2);
        let w2 = st.grad[i2] - st.grad[j2];
        let ps = PlanningSystem { w1: sp1.l, w2, q11: sp1.q, q12, q22 };
        let mu = ps.planning_step()?;
        // Current step must stay strictly inside the box (else: SMO step).
        if !(mu > sp1.lo && mu < sp1.hi) {
            return None;
        }
        // The planned second step, evaluated at the post-step-1 point
        // (B¹ and B² may share indices, so shift the affected α first).
        let mu2 = ps.second_step(mu);
        let shift = |n: usize| -> f64 {
            let mut a = st.alpha[n];
            if n == i1 {
                a += mu;
            }
            if n == j1 {
                a -= mu;
            }
            a
        };
        let (a_i2, a_j2) = (shift(i2), shift(j2));
        let lo2 = (st.lower[i2] - a_i2).max(a_j2 - st.upper[j2]);
        let hi2 = (st.upper[i2] - a_i2).min(a_j2 - st.lower[j2]);
        if !(mu2 > lo2 && mu2 < hi2) {
            return None;
        }
        Some(Plan { mu, gain: ps.double_step_gain(mu) })
    }

    fn run(&self, mut core: SolverCore, started: Instant) -> SolveResult {
        let eta = self.config.eta;
        let n_cand = self.config.planning_candidates.max(1);
        // Recent working sets, most recent first: history[0] = B^(t−1).
        // Stored in *original* coordinates — shrink swaps move positions
        // between iterations, originals are stable — and mapped back to
        // active positions (dropping shrunk pairs) at each use.
        let mut history: VecDeque<(usize, usize)> = VecDeque::new();
        // p = "previous iteration performed a SMO step" (Algorithm 5).
        let mut p = true;
        // Did the previous iteration perform a *free* SMO step? (Alg. 4)
        let mut prev_free_smo = false;
        // μ^(t−1)/μ* of the most recent planning step.
        let mut prev_ratio = 1.0f64;

        let reason = loop {
            if let Some(stop) = core.check_stop_and_shrink() {
                break stop;
            }
            // Map an original-coordinate pair to current active positions.
            let to_pos = |st: &SolverState, (a, b): (usize, usize)| {
                let (pa, pb) = (st.pos[a], st.pos[b]);
                (pa < st.active_len && pb < st.active_len).then_some((pa, pb))
            };
            // ---- Working-set selection (Algorithm 3 / Algorithm 5) ----
            let extras: Vec<(usize, usize)> = if self.config.ablation_wss_only {
                // §7.2 ablation: always offer B^(t−2) under ĝ, never plan.
                history
                    .iter()
                    .skip(1)
                    .take(1)
                    .filter_map(|&pair| to_pos(&core.state, pair))
                    .collect()
            } else if p {
                Vec::new()
            } else {
                // Offer the set(s) assumed during planning: B^(t−2) … .
                history
                    .iter()
                    .skip(1)
                    .take(n_cand)
                    .filter_map(|&pair| to_pos(&core.state, pair))
                    .collect()
            };
            let kind = if self.config.ablation_wss_only
                || p
                || (prev_ratio >= 1.0 - eta && prev_ratio <= 1.0 + eta)
            {
                GainKind::Approx
            } else {
                GainKind::Exact
            };
            let Some(sel) = core.select(kind, &extras) else {
                break StopReason::Converged;
            };
            core.iterations += 1;

            let sp = core.subproblem(sel.i, sel.j);
            let mu_star = sp.newton_step();

            // ---- Update step (Algorithm 4) ----
            let plan = if prev_free_smo && !self.config.ablation_wss_only {
                let mut best: Option<Plan> = None;
                for k in 0..history.len().min(n_cand) {
                    let Some(b2) = to_pos(&core.state, history[k]) else {
                        continue; // candidate set was shrunk away
                    };
                    if let Some(pl) = Self::plan_with(&mut core, sel, &sp, b2) {
                        if best.map(|b| pl.gain > b.gain).unwrap_or(true) {
                            best = Some(pl);
                        }
                    }
                }
                if best.is_none() && !history.is_empty() {
                    core.telemetry.planning_reverted += 1;
                }
                best
            } else {
                None
            };

            match plan {
                Some(pl) => {
                    core.apply_and_update(sel.i, sel.j, pl.mu);
                    core.telemetry.count_step(StepKind::Planning);
                    core.telemetry.record_planning_ratio(pl.mu, mu_star);
                    prev_ratio = if mu_star.is_finite() && mu_star != 0.0 {
                        pl.mu / mu_star
                    } else {
                        1.0
                    };
                    p = false;
                    prev_free_smo = false;
                }
                None => {
                    let (_, free) = core.smo_step(sel);
                    p = true;
                    prev_free_smo = free;
                }
            }
            if core.telemetry.config.objective_trace {
                let obj = core.state.objective();
                let it = core.iterations;
                core.telemetry.record_objective(it, || obj);
            }
            history.push_front((core.state.perm[sel.i], core.state.perm[sel.j]));
            history.truncate(n_cand + 2);
        };
        core.finish(reason, started)
    }
}

impl Engine for PasmoSolver {
    fn name(&self) -> &'static str {
        "pasmo"
    }

    fn solve_state(&self, state: SolverState, gram: &mut Gram) -> SolveResult {
        let started = Instant::now();
        let core = SolverCore::from_state(state, gram, self.config);
        self.run(core, started)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::events::TelemetryConfig;
    use crate::solver::smo::tests::{make_gram, random_problem, solve_cls};
    use crate::solver::smo::SmoSolver;
    use crate::util::prng::Pcg;

    fn full_trace_cfg() -> SolverConfig {
        SolverConfig {
            telemetry: TelemetryConfig::full(1),
            shrinking: false,
            ..Default::default()
        }
    }

    #[test]
    fn converges_and_matches_smo_objective() {
        for seed in [1u64, 5, 9] {
            let ds = random_problem(80, seed);
            let mut g1 = make_gram(&ds, 1.0, 1 << 22);
            let mut g2 = make_gram(&ds, 1.0, 1 << 22);
            let smo = solve_cls(&SmoSolver::new(SolverConfig::default()), ds.labels(), 2.0, &mut g1);
            let pa = solve_cls(&PasmoSolver::new(SolverConfig::default()), ds.labels(), 2.0, &mut g2);
            assert!(pa.converged, "seed {seed}");
            assert!(pa.gap <= 1e-3 + 1e-9, "seed {seed}: {}", pa.gap);
            let rel = (pa.objective - smo.objective).abs() / (1.0 + smo.objective.abs());
            assert!(rel < 2e-3, "seed {seed}: {} vs {}", pa.objective, smo.objective);
        }
    }

    #[test]
    fn planning_steps_occur_on_oscillation_prone_problems() {
        // large C + overlapping classes => many free steps => planning
        let ds = random_problem(60, 3);
        let mut gram = make_gram(&ds, 2.0, 1 << 22);
        let res = solve_cls(&PasmoSolver::new(full_trace_cfg()), ds.labels(), 1e4, &mut gram);
        assert!(res.converged);
        assert!(
            res.telemetry.planning_steps > 0,
            "no planning steps: {:?}",
            res.telemetry
        );
    }

    #[test]
    fn lemma3_double_step_gain_is_positive() {
        // For every planning step at iteration t, f(t+1) >= f(t-1):
        // the planning step plus the following step never lose ground.
        let ds = random_problem(50, 7);
        let mut gram = make_gram(&ds, 1.5, 1 << 22);
        let res = solve_cls(&PasmoSolver::new(full_trace_cfg()), ds.labels(), 100.0, &mut gram);
        let kinds = &res.telemetry.kind_trace;
        let objs: Vec<f64> = res.telemetry.objective_trace.iter().map(|&(_, f)| f).collect();
        assert_eq!(kinds.len(), objs.len());
        let mut planning_seen = 0;
        for t in 0..kinds.len() {
            if kinds[t] == StepKind::Planning && t + 1 < objs.len() {
                planning_seen += 1;
                let before = if t == 0 { 0.0 } else { objs[t - 1] };
                assert!(
                    objs[t + 1] >= before - 1e-9,
                    "double step lost ground at t={t}: {} -> {}",
                    before,
                    objs[t + 1]
                );
            }
        }
        assert!(planning_seen > 0, "test vacuous: no planning steps");
    }

    #[test]
    fn final_objective_never_worse_than_smo_across_seeds() {
        // the paper's headline claim, in miniature
        let mut rng = Pcg::new(123);
        for _ in 0..5 {
            let seed = rng.next_u64();
            let ds = random_problem(40, seed);
            let mut g1 = make_gram(&ds, 1.0, 1 << 22);
            let mut g2 = make_gram(&ds, 1.0, 1 << 22);
            let smo =
                solve_cls(&SmoSolver::new(SolverConfig::default()), ds.labels(), 10.0, &mut g1);
            let pa =
                solve_cls(&PasmoSolver::new(SolverConfig::default()), ds.labels(), 10.0, &mut g2);
            assert!(
                pa.objective >= smo.objective - 1e-3 * (1.0 + smo.objective.abs()),
                "seed {seed}: PA {} < SMO {}",
                pa.objective,
                smo.objective
            );
        }
    }

    #[test]
    fn multi_planning_variant_converges() {
        for n in [2usize, 3, 5] {
            let ds = random_problem(60, 11);
            let mut gram = make_gram(&ds, 1.0, 1 << 22);
            let cfg = SolverConfig { planning_candidates: n, ..Default::default() };
            let res = solve_cls(&PasmoSolver::new(cfg), ds.labels(), 50.0, &mut gram);
            assert!(res.converged, "N={n}");
            assert!(res.gap <= 1e-3 + 1e-9, "N={n}");
        }
    }

    #[test]
    fn feasibility_invariants_hold_throughout() {
        use crate::util::quickcheck::forall;
        forall(
            "pasmo-feasible-solutions",
            8,
            |g| (16 + g.below(48), g.next_u64(), 10f64.powf(g.range(-1.0, 3.0))),
            |&(n, seed, c)| {
                let ds = random_problem(n, seed);
                let mut gram = make_gram(&ds, 1.0, 1 << 22);
                let res = solve_cls(&PasmoSolver::new(SolverConfig::default()), ds.labels(), c, &mut gram);
                let sum: f64 = res.alpha.iter().sum();
                if sum.abs() > 1e-8 {
                    return Err(format!("equality constraint violated: {sum}"));
                }
                for (i, &a) in res.alpha.iter().enumerate() {
                    let y = ds.label(i) as f64;
                    let (lo, hi) = ((y * c).min(0.0), (y * c).max(0.0));
                    if a < lo - 1e-9 || a > hi + 1e-9 {
                        return Err(format!("box violated at {i}: {a} not in [{lo},{hi}]"));
                    }
                }
                if !res.converged {
                    return Err("did not converge".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn shrinking_pasmo_matches_unshrunk_objective() {
        let ds = random_problem(120, 17);
        let mut g1 = make_gram(&ds, 1.0, 1 << 22);
        let mut g2 = make_gram(&ds, 1.0, 1 << 22);
        let on = solve_cls(&PasmoSolver::new(SolverConfig { shrinking: true, ..Default::default() }), ds.labels(), 1.0, &mut g1);
        let off = solve_cls(&PasmoSolver::new(SolverConfig { shrinking: false, ..Default::default() }), ds.labels(), 1.0, &mut g2);
        assert!(on.converged && off.converged);
        let rel = (on.objective - off.objective).abs() / (1.0 + off.objective.abs());
        assert!(rel < 2e-3, "{} vs {}", on.objective, off.objective);
    }
}
