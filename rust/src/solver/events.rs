//! Solver telemetry: step-kind counts, the planning-step ratio stream
//! feeding Figure 3, and optional objective/gap traces.
//!
//! Telemetry is opt-in per field so the hot loop pays nothing when a
//! stream is disabled (Table 2 timing runs disable everything).

/// What happened in one iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepKind {
    /// Free SMO step (interior Newton).
    SmoFree,
    /// SMO step clipped at the box.
    SmoAtBound,
    /// Planning-ahead step (Algorithm 4 took the planned μ).
    Planning,
    /// Conjugate-direction step (the `solver::conjugate` engine took the
    /// momentum-combined direction instead of the plain SMO step).
    Conjugate,
}

/// Which streams to record.
#[derive(Debug, Clone, Copy, Default)]
pub struct TelemetryConfig {
    /// Record μ/μ*−1 for every planning step (Figure 3).
    pub planning_ratios: bool,
    /// Record (iteration, objective) every `trace_every` iterations.
    pub objective_trace: bool,
    /// Record (iteration, gap) every `trace_every` iterations.
    pub gap_trace: bool,
    /// Record the [`StepKind`] of every iteration (used by the Lemma-3
    /// double-step tests and the Fig. 1 trace example).
    pub kind_trace: bool,
    /// Trace sampling period (0 = every iteration).
    pub trace_every: usize,
}

impl TelemetryConfig {
    /// All streams disabled (the timing-run default).
    pub fn off() -> TelemetryConfig {
        TelemetryConfig::default()
    }

    /// Only the planning-step ratio stream (Figure 3 input).
    pub fn fig3() -> TelemetryConfig {
        TelemetryConfig { planning_ratios: true, ..Default::default() }
    }

    /// Every stream enabled at the given sampling period.
    pub fn full(trace_every: usize) -> TelemetryConfig {
        TelemetryConfig {
            planning_ratios: true,
            objective_trace: true,
            gap_trace: true,
            kind_trace: true,
            trace_every,
        }
    }
}

/// Collected telemetry.
#[derive(Debug, Clone, Default)]
pub struct Telemetry {
    /// The stream configuration this telemetry was collected under.
    pub config: TelemetryConfig,
    /// Free (interior-Newton) SMO steps taken.
    pub free_steps: u64,
    /// SMO steps clipped at the box boundary.
    pub bounded_steps: u64,
    /// Planning-ahead steps taken (PA-SMO).
    pub planning_steps: u64,
    /// Planning attempts that reverted to a SMO step (box/degeneracy).
    pub planning_reverted: u64,
    /// Conjugate-direction steps taken (conjugate SMO).
    pub conjugate_steps: u64,
    /// Conjugate attempts that fell back to the plain SMO step (the
    /// momentum step would have gained less, or was degenerate).
    pub conjugate_reverted: u64,
    /// μ/μ*−1 per planning step (Figure 3 input).
    pub planning_ratios: Vec<f64>,
    /// (iteration, f(α)) samples.
    pub objective_trace: Vec<(u64, f64)>,
    /// (iteration, KKT gap) samples.
    pub gap_trace: Vec<(u64, f64)>,
    /// Per-iteration step kinds (only when `config.kind_trace`).
    pub kind_trace: Vec<StepKind>,
}

impl Telemetry {
    /// Fresh, empty telemetry for the given stream configuration.
    pub fn new(config: TelemetryConfig) -> Telemetry {
        Telemetry { config, ..Default::default() }
    }

    /// Record what the current iteration did.
    #[inline]
    pub fn count_step(&mut self, kind: StepKind) {
        match kind {
            StepKind::SmoFree => self.free_steps += 1,
            StepKind::SmoAtBound => self.bounded_steps += 1,
            StepKind::Planning => self.planning_steps += 1,
            StepKind::Conjugate => self.conjugate_steps += 1,
        }
        if self.config.kind_trace {
            self.kind_trace.push(kind);
        }
    }

    /// Record a planning step of size `mu` against Newton size `mu_star`.
    #[inline]
    pub fn record_planning_ratio(&mut self, mu: f64, mu_star: f64) {
        if self.config.planning_ratios && mu_star != 0.0 && mu_star.is_finite() {
            self.planning_ratios.push(mu / mu_star - 1.0);
        }
    }

    #[inline]
    fn due(&self, iter: u64) -> bool {
        let every = self.config.trace_every.max(1) as u64;
        iter % every == 0
    }

    /// Record an objective sample if the stream is on and the iteration
    /// is due; the closure is never evaluated otherwise.
    #[inline]
    pub fn record_objective(&mut self, iter: u64, f: impl FnOnce() -> f64) {
        if self.config.objective_trace && self.due(iter) {
            let v = f();
            self.objective_trace.push((iter, v));
        }
    }

    /// Record a KKT-gap sample if the stream is on and the iteration is
    /// due; the closure is never evaluated otherwise.
    #[inline]
    pub fn record_gap(&mut self, iter: u64, gap: impl FnOnce() -> f64) {
        if self.config.gap_trace && self.due(iter) {
            let v = gap();
            self.gap_trace.push((iter, v));
        }
    }

    /// Total iterations accounted for, across every step kind.
    pub fn total_steps(&self) -> u64 {
        self.free_steps + self.bounded_steps + self.planning_steps + self.conjugate_steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_by_kind() {
        let mut t = Telemetry::new(TelemetryConfig::off());
        t.count_step(StepKind::SmoFree);
        t.count_step(StepKind::SmoFree);
        t.count_step(StepKind::SmoAtBound);
        t.count_step(StepKind::Planning);
        assert_eq!((t.free_steps, t.bounded_steps, t.planning_steps), (2, 1, 1));
        assert_eq!(t.total_steps(), 4);
    }

    #[test]
    fn ratios_only_when_enabled() {
        let mut off = Telemetry::new(TelemetryConfig::off());
        off.record_planning_ratio(1.2, 1.0);
        assert!(off.planning_ratios.is_empty());
        let mut on = Telemetry::new(TelemetryConfig::fig3());
        on.record_planning_ratio(1.2, 1.0);
        assert_eq!(on.planning_ratios.len(), 1);
        assert!((on.planning_ratios[0] - 0.2).abs() < 1e-12);
        // degenerate newton sizes are skipped
        on.record_planning_ratio(1.0, 0.0);
        on.record_planning_ratio(1.0, f64::INFINITY);
        assert_eq!(on.planning_ratios.len(), 1);
    }

    #[test]
    fn traces_sample_at_period() {
        let mut t = Telemetry::new(TelemetryConfig::full(10));
        for iter in 0..25 {
            t.record_objective(iter, || iter as f64);
            t.record_gap(iter, || 1.0);
        }
        assert_eq!(t.objective_trace.len(), 3); // 0, 10, 20
        assert_eq!(t.gap_trace.len(), 3);
    }

    #[test]
    fn disabled_traces_do_not_evaluate_closure() {
        let mut t = Telemetry::new(TelemetryConfig::off());
        t.record_objective(0, || panic!("must not be called"));
    }
}
