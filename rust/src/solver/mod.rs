//! The paper's contribution: SMO and planning-ahead SMO solvers for the
//! dual SVM training problem (paper eq. 1)
//!
//! ```text
//! maximize f(α) = yᵀα − ½ αᵀKα
//! s.t.     Σ αᵢ = 0,   Lᵢ ≤ αᵢ ≤ Uᵢ,  Lᵢ = min(0, yᵢC), Uᵢ = max(0, yᵢC)
//! ```
//!
//! Module map:
//! * [`state`] — α/gradient/active-set bookkeeping and feasibility.
//! * [`step`] — the 1-D sub-problem (eq. 2), gains (eqs. 4/7) and the
//!   planning-ahead step size (eq. 8); pure math, heavily unit-tested.
//! * [`wss`] — working-set selection: max-violating-pair, second-order
//!   (Fan et al.), and the PA-aware selection of Algorithm 3.
//! * [`smo`] — Algorithm 1 (the LIBSVM-equivalent baseline).
//! * [`pasmo`] — Algorithms 2/4/5: the planning-ahead solver, including
//!   the multiple-planning-ahead variant (§7.4).
//! * [`conjugate`] — conjugate SMO: the planning idea carried further
//!   with conjugate-direction momentum and an exact line search,
//!   falling back to the plain SMO step whenever momentum would lose
//!   gain (related work; see PAPERS.md).
//! * [`shrink`] — shrinking heuristic + gradient reconstruction.
//! * [`events`] — telemetry (step-kind counts, μ/μ* ratios for Fig. 3,
//!   objective/gap traces).
//! * [`reference`] — independent dense projected-gradient solver used as
//!   a ground-truth oracle in tests.
//! * [`problem`] — first-class [`QpProblem`] description of the general
//!   dual (linear term, per-index bounds, equality target, warm start).
//! * [`engine`] — the [`Engine`] trait every solver implements, plus the
//!   single [`SolverChoice`] → engine factory ([`EngineConfig`]).
//! * [`checkpoint`] — crash-safe solver snapshots (α in original
//!   coordinates, atomic checksummed envelope) resumed through the
//!   [`QpProblem`] warm-start path.

pub mod checkpoint;
pub mod conjugate;
pub mod engine;
pub mod events;
pub mod pasmo;
pub mod problem;
pub mod reference;
pub mod shrink;
pub mod smo;
pub mod state;
pub mod step;
pub mod wss;

pub use checkpoint::Checkpoint;
pub use conjugate::ConjugateSmoSolver;
pub use engine::{Engine, EngineConfig, SolverChoice};
pub use events::{StepKind, Telemetry, TelemetryConfig};
pub use pasmo::PasmoSolver;
pub use problem::QpProblem;
pub use smo::{SmoSolver, SolveResult, SolverConfig, StepPolicy, StopReason, WssKind};
pub use state::SolverState;
