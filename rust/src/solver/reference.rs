//! Independent ground-truth solver for tests: projected gradient ascent
//! on the dual with *exact* projection onto `{Σα = 0} ∩ box` by bisection
//! on the hyperplane multiplier.
//!
//! Deliberately shares no code or algorithmic structure with the SMO
//! family, so agreement between the two is strong evidence of
//! correctness. O(ℓ²) per iteration — small problems only.

use crate::kernel::matrix::DenseGram;

/// Exact Euclidean projection of `v` onto `{x | Σx = 0, lo ≤ x ≤ hi}`.
///
/// The projection is `x_i(λ) = clamp(v_i − λ, lo_i, hi_i)` where λ solves
/// `Σ x(λ) = 0`; the sum is continuous and non-increasing in λ, so
/// bisection converges unconditionally.
pub fn project(v: &[f64], lo: &[f64], hi: &[f64]) -> Vec<f64> {
    let sum_at = |lambda: f64| -> f64 {
        v.iter()
            .zip(lo.iter().zip(hi))
            .map(|(&vi, (&l, &h))| (vi - lambda).clamp(l, h))
            .sum()
    };
    // Bracket λ: for very negative λ all coordinates sit at hi (sum ≥ 0),
    // for very positive λ at lo (sum ≤ 0).
    let spread = v
        .iter()
        .map(|x| x.abs())
        .fold(0.0f64, f64::max)
        .max(hi.iter().map(|x| x.abs()).fold(0.0f64, f64::max))
        + 1.0;
    let (mut a, mut b) = (-spread * 2.0, spread * 2.0);
    for _ in 0..200 {
        let mid = 0.5 * (a + b);
        if sum_at(mid) > 0.0 {
            a = mid;
        } else {
            b = mid;
        }
    }
    let lambda = 0.5 * (a + b);
    v.iter()
        .zip(lo.iter().zip(hi))
        .map(|(&vi, (&l, &h))| (vi - lambda).clamp(l, h))
        .collect()
}

/// Result of the reference solve.
#[derive(Debug, Clone)]
pub struct ReferenceResult {
    /// The solution found by projected gradient ascent.
    pub alpha: Vec<f64>,
    /// Dual objective f(α) at the solution.
    pub objective: f64,
    /// Ascent iterations performed.
    pub iterations: usize,
}

/// Maximize `f(α) = yᵀα − ½ αᵀKα` over the feasible region by projected
/// gradient ascent with a conservative `1/L` step size.
pub fn solve_reference(
    k: &DenseGram,
    labels: &[i8],
    c: f64,
    max_iters: usize,
    tol: f64,
) -> ReferenceResult {
    let n = k.len();
    assert_eq!(labels.len(), n);
    let y: Vec<f64> = labels.iter().map(|&l| l as f64).collect();
    let lo: Vec<f64> = y.iter().map(|&yi| (yi * c).min(0.0)).collect();
    let hi: Vec<f64> = y.iter().map(|&yi| (yi * c).max(0.0)).collect();
    // Lipschitz bound on ∇f: L ≤ max_i Σ_j |K_ij| (row-sum norm).
    let l_bound = (0..n)
        .map(|i| (0..n).map(|j| k.at(i, j).abs()).sum::<f64>())
        .fold(0.0f64, f64::max)
        .max(1e-12);
    let step = 1.0 / l_bound;

    let mut alpha = vec![0.0f64; n];
    let objective = |a: &[f64]| -> f64 {
        let mut f = 0.0;
        for i in 0..n {
            f += y[i] * a[i] - 0.5 * a[i] * k.mat_vec_at(a, i);
        }
        f
    };
    let mut last_f = objective(&alpha);
    let mut iters = 0;
    for it in 0..max_iters {
        iters = it + 1;
        // gradient G = y − Kα
        let v: Vec<f64> = (0..n)
            .map(|i| alpha[i] + step * (y[i] - k.mat_vec_at(&alpha, i)))
            .collect();
        alpha = project(&v, &lo, &hi);
        if it % 50 == 49 {
            let f = objective(&alpha);
            let converged = (f - last_f).abs() <= tol * (1.0 + f.abs());
            last_f = f;
            if converged {
                break;
            }
        }
    }
    ReferenceResult { objective: objective(&alpha), alpha, iterations: iters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::matrix::Gram;
    use crate::kernel::{KernelFunction, NativeRowComputer};
    use crate::solver::pasmo::PasmoSolver;
    use crate::solver::smo::tests::{random_problem, solve_cls};
    use crate::solver::smo::{SmoSolver, SolverConfig};
    use std::sync::Arc;

    #[test]
    fn projection_is_feasible_and_idempotent() {
        let v = vec![3.0, -1.0, 0.5, 2.0];
        let lo = vec![0.0, -1.0, 0.0, -2.0];
        let hi = vec![1.0, 0.0, 2.0, 0.0];
        let p = project(&v, &lo, &hi);
        let sum: f64 = p.iter().sum();
        assert!(sum.abs() < 1e-9, "sum={sum}");
        for i in 0..4 {
            assert!(p[i] >= lo[i] - 1e-12 && p[i] <= hi[i] + 1e-12);
        }
        let p2 = project(&p, &lo, &hi);
        for i in 0..4 {
            assert!((p[i] - p2[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn projection_of_feasible_point_is_identity() {
        let v = vec![0.5, -0.5];
        let lo = vec![0.0, -1.0];
        let hi = vec![1.0, 0.0];
        let p = project(&v, &lo, &hi);
        assert!((p[0] - 0.5).abs() < 1e-9 && (p[1] + 0.5).abs() < 1e-9);
    }

    #[test]
    fn reference_matches_hand_solvable_2x2() {
        // K = I, y = (1, -1), C large: f = a0 - a1 - 0.5(a0²+a1²),
        // unconstrained optimum a = (1, -1), feasible, f* = 1.
        let k = DenseGram::from_matrix(2, vec![1.0, 0.0, 0.0, 1.0]);
        let res = solve_reference(&k, &[1, -1], 100.0, 20_000, 1e-12);
        assert!((res.alpha[0] - 1.0).abs() < 1e-4, "{:?}", res.alpha);
        assert!((res.alpha[1] + 1.0).abs() < 1e-4);
        assert!((res.objective - 1.0).abs() < 1e-6);
    }

    #[test]
    fn smo_and_pasmo_match_reference_on_random_problems() {
        for seed in [2u64, 4] {
            let ds = random_problem(24, seed);
            let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 0.8 });
            let dense = DenseGram::materialize(&nc);
            let c = 5.0;
            let reference = solve_reference(&dense, ds.labels(), c, 200_000, 1e-14);

            let cfg = SolverConfig { eps: 1e-6, ..Default::default() };
            let mut g1 = Gram::new(
                Box::new(NativeRowComputer::new(
                    ds.clone(),
                    KernelFunction::Rbf { gamma: 0.8 },
                )),
                1 << 22,
            );
            let smo = solve_cls(&SmoSolver::new(cfg), ds.labels(), c, &mut g1);
            let mut g2 = Gram::new(
                Box::new(NativeRowComputer::new(
                    ds.clone(),
                    KernelFunction::Rbf { gamma: 0.8 },
                )),
                1 << 22,
            );
            let pa = solve_cls(&PasmoSolver::new(cfg), ds.labels(), c, &mut g2);

            let tol = 1e-4 * (1.0 + reference.objective.abs());
            assert!(
                (smo.objective - reference.objective).abs() < tol,
                "seed {seed}: SMO {} vs ref {}",
                smo.objective,
                reference.objective
            );
            assert!(
                (pa.objective - reference.objective).abs() < tol,
                "seed {seed}: PA {} vs ref {}",
                pa.objective,
                reference.objective
            );
            let _ = Arc::strong_count(&ds);
        }
    }
}
