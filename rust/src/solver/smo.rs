//! Algorithm 1: the canonical (greedy) SMO solver — the paper's baseline,
//! equivalent to LIBSVM 2.84's solver with second-order working-set
//! selection — plus the shared iteration core reused by PA-SMO.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use crate::kernel::cache::CacheStats;
use crate::kernel::matrix::Gram;

use super::engine::Engine;
use super::events::{StepKind, Telemetry, TelemetryConfig};
use super::shrink;
use super::state::SolverState;
use super::step::{OverStep, SubProblem};
use super::wss::{self, GainKind, Selection};

/// Working-set selection flavour for the baseline solver.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WssKind {
    /// First-order most-violating pair.
    MaxViolating,
    /// Second-order (Fan et al.) — the paper's baseline and default.
    SecondOrder,
}

/// Step policy re-export (§7.3's over-relaxation ablation lives here).
pub type StepPolicy = OverStep;

/// Why a solve stopped — surfaced in [`SolveResult::stop_reason`] so
/// callers can distinguish a real ε-approximate KKT point from a run
/// that merely hit its iteration budget or was asked to checkpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// The ε-approximate KKT condition held on the full problem.
    Converged,
    /// The iteration cap (`SolverConfig::max_iter` or the LIBSVM-style
    /// default) was reached before convergence.
    IterLimit,
    /// The cooperative stop flag ([`SolverConfig::stop_flag`]) was raised
    /// — the caller intends to checkpoint and resume this solve.
    Checkpointed,
}

impl std::fmt::Display for StopReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            StopReason::Converged => "converged",
            StopReason::IterLimit => "iteration-limit",
            StopReason::Checkpointed => "checkpointed",
        })
    }
}

/// Solver configuration shared by SMO and PA-SMO.
#[derive(Debug, Clone, Copy)]
pub struct SolverConfig {
    /// KKT stopping accuracy ε (paper uses 0.001).
    pub eps: f64,
    /// Hard iteration cap (0 = LIBSVM-style `max(10⁷, 100ℓ)`).
    pub max_iter: u64,
    /// Kernel cache budget in bytes.
    pub cache_bytes: usize,
    /// Enable the shrinking heuristic.
    pub shrinking: bool,
    /// Shrink check period (0 = `min(ℓ, 1000)`).
    pub shrink_interval: usize,
    /// Baseline working-set selection.
    pub wss: WssKind,
    /// Step-size policy for SMO steps (Newton or §7.3 over-relaxed).
    pub step_policy: StepPolicy,
    /// Telemetry streams.
    pub telemetry: TelemetryConfig,
    /// PA-SMO η (paper fixes 0.9; not a free hyper-parameter).
    pub eta: f64,
    /// PA-SMO: number of recent working sets used for planning (§7.4;
    /// 1 = standard PA-SMO).
    pub planning_candidates: usize,
    /// §7.2 ablation: run PA-SMO's *working-set selection* modification
    /// (offer `B^(t−2)`, ĝ scoring) but never take a planning step —
    /// isolates how much of the speed-up comes from WSS vs planning.
    pub ablation_wss_only: bool,
    /// Worker threads for kernel-row computation (0/1 = single-threaded).
    /// Threaded rows are bit-identical to single-threaded ones, so the
    /// solve path — and `SolveResult::alpha` — does not depend on this.
    pub threads: usize,
    /// Cooperative early-stop flag (SIGTERM-style). When the referenced
    /// flag turns `true` the solver stops at the next iteration boundary
    /// and reports [`StopReason::Checkpointed`]; the caller snapshots
    /// `SolveResult::alpha` (already in original coordinates) and later
    /// resumes through the `QpProblem` warm-start path. `None` (the
    /// default) compiles to a no-op check.
    pub stop_flag: Option<&'static AtomicBool>,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            eps: 1e-3,
            max_iter: 0,
            cache_bytes: Gram::DEFAULT_CACHE_BYTES,
            shrinking: true,
            shrink_interval: 0,
            wss: WssKind::SecondOrder,
            step_policy: OverStep::Newton,
            telemetry: TelemetryConfig::off(),
            eta: 0.9,
            planning_candidates: 1,
            ablation_wss_only: false,
            threads: 1,
            stop_flag: None,
        }
    }
}

/// Outcome of a solve.
#[derive(Debug, Clone)]
pub struct SolveResult {
    /// Dual variables in *original* coordinates (shrink permutations are
    /// undone before the result leaves the solver).
    pub alpha: Vec<f64>,
    /// Bias term b from the KKT conditions (see `SolverState::bias`).
    pub bias: f64,
    /// Iterations performed (= SMO-family steps taken).
    pub iterations: u64,
    /// Final dual objective f(α).
    pub objective: f64,
    /// Final (full) KKT gap.
    pub gap: f64,
    /// Did the solve reach the ε-approximate KKT point (vs hitting the
    /// iteration cap)?
    pub converged: bool,
    /// Why the solve stopped (convergence, iteration cap, or a raised
    /// checkpoint flag) — `converged` is exactly
    /// `stop_reason == StopReason::Converged`.
    pub stop_reason: StopReason,
    /// Support vectors (|αᵢ| > 0) in the solution.
    pub sv: usize,
    /// Bounded support vectors (αᵢ at its box bound).
    pub bsv: usize,
    /// Wall-clock duration of the solve in seconds.
    pub wall_time_s: f64,
    /// Collected telemetry streams (step kinds, ratios, traces).
    pub telemetry: Telemetry,
    /// Row-cache statistics over this solve.
    pub cache_stats: CacheStats,
    /// Kernel entries evaluated by the Gram over this solve (diagonal +
    /// row computations at their actual, possibly shrunk, lengths +
    /// single-entry fallbacks) — the quantity shrinking reduces.
    pub kernel_entries: u64,
}

/// Shared per-iteration machinery for SMO-family solvers.
pub(crate) struct SolverCore<'a> {
    pub state: SolverState,
    pub gram: &'a mut Gram,
    pub config: SolverConfig,
    pub telemetry: Telemetry,
    pub iterations: u64,
    shrink_counter: usize,
    shrink_period: usize,
    /// Set once the gradient has been reconstructed near convergence;
    /// further shrinking is disabled to guarantee termination.
    unshrunk: bool,
    /// `argmax{Gᵢ | i ∈ I_up}` from the most recent stopping scan, in
    /// *original* coordinates (shrink swaps move positions, originals are
    /// stable) — handed to WSS so the hot loop runs one O(active) scan,
    /// not two.
    hint_argmax_up: Option<usize>,
    /// Stopping quantities `(m, big_m, gap, argmax_original)` computed
    /// inside the fused gradient-update loop of the previous iteration;
    /// when present the stop check runs with zero additional scans.
    cached_scan: Option<(f64, f64, f64, Option<usize>)>,
    /// Σα at entry — the equality-constraint target every later iterate
    /// must preserve (SMO steps move mass along `e_i − e_j`).
    #[cfg(feature = "debug-invariants")]
    equality_sum: f64,
}

impl<'a> SolverCore<'a> {
    /// Build around an arbitrary (general-QP / warm-started) state.
    pub fn from_state(state: SolverState, gram: &'a mut Gram, config: SolverConfig) -> Self {
        assert_eq!(state.len(), gram.len(), "state/gram size mismatch");
        assert!(
            gram.is_identity_view(),
            "Gram view is permuted by an earlier solve; call Gram::reset_view first"
        );
        let n = state.len();
        gram.set_active_len(n); // fresh state ⇒ fully active view
        let shrink_period = if config.shrink_interval > 0 {
            config.shrink_interval
        } else {
            n.min(1000).max(1)
        };
        #[cfg(feature = "debug-invariants")]
        let equality_sum = state.alpha.iter().sum::<f64>();
        SolverCore {
            state,
            gram,
            config,
            telemetry: Telemetry::new(config.telemetry),
            iterations: 0,
            shrink_counter: shrink_period,
            shrink_period,
            unshrunk: false,
            hint_argmax_up: None,
            cached_scan: None,
            #[cfg(feature = "debug-invariants")]
            equality_sum,
        }
    }

    pub fn max_iter(&self) -> u64 {
        if self.config.max_iter > 0 {
            self.config.max_iter
        } else {
            10_000_000u64.max(100 * self.state.len() as u64)
        }
    }

    /// Stopping / shrinking bookkeeping run at the top of each iteration.
    /// Returns `Some(reason)` if the loop should stop.
    pub fn check_stop_and_shrink(&mut self) -> Option<StopReason> {
        #[cfg(feature = "debug-invariants")]
        self.state.check_invariants(self.equality_sum);
        if let Some(flag) = self.config.stop_flag {
            if flag.load(Ordering::Relaxed) {
                return Some(StopReason::Checkpointed);
            }
        }
        let (m, big_m, gap, argmax) = match self.cached_scan.take() {
            Some(scan) => scan,
            None => {
                let (m, big_m, gap, p) = self.state.kkt_scan();
                (m, big_m, gap, p.map(|p| self.state.perm[p]))
            }
        };
        self.hint_argmax_up = argmax;
        self.telemetry.record_gap(self.iterations, || gap);
        if gap <= self.config.eps {
            // Converged on the active set: reconstruct and re-check on the
            // full problem before declaring victory.
            if self.state.active_len < self.state.len() {
                shrink::unshrink_and_reconstruct(&mut self.state, self.gram);
                self.unshrunk = true;
                let (_, _, full_gap, full_argmax) = self.state.kkt_scan();
                self.hint_argmax_up = full_argmax.map(|p| self.state.perm[p]);
                if full_gap <= self.config.eps {
                    return Some(StopReason::Converged);
                }
                // keep optimizing on the full set
                return None;
            }
            return Some(StopReason::Converged);
        }
        if self.config.shrinking && !self.unshrunk {
            self.shrink_counter -= 1;
            if self.shrink_counter == 0 {
                self.shrink_counter = self.shrink_period;
                shrink::shrink(&mut self.state, self.gram, m, big_m);
            }
        }
        if self.iterations >= self.max_iter() {
            return Some(StopReason::IterLimit);
        }
        None
    }

    /// Baseline working-set selection per config. Reuses the argmax from
    /// the fused stopping scan when it is still valid (mapped back from
    /// original coordinates — shrink swaps may have moved it).
    pub fn select(&mut self, kind: GainKind, extra: &[(usize, usize)]) -> Option<Selection> {
        match self.config.wss {
            WssKind::MaxViolating => wss::select_max_violating(&self.state),
            WssKind::SecondOrder => {
                let hint = self.hint_argmax_up.take().map(|orig| self.state.pos[orig]);
                match hint {
                    Some(p) if p < self.state.active_len && self.state.in_up(p) => {
                        wss::select_second_order_with_i(&self.state, self.gram, kind, extra, p)
                    }
                    _ => wss::select_second_order(&self.state, self.gram, kind, extra),
                }
            }
        }
    }

    /// Build the 1-D sub-problem for a pair, fetching both rows.
    /// Returns (sub-problem, q12-capable row data is left in cache).
    pub fn subproblem(&mut self, i: usize, j: usize) -> SubProblem {
        let (lo, hi) = self.state.step_bounds(i, j);
        let kii = self.gram.diag(i);
        let kjj = self.gram.diag(j);
        let kij = self.gram.entry(i, j);
        SubProblem {
            l: self.state.grad[i] - self.state.grad[j],
            q: kii - 2.0 * kij + kjj,
            lo,
            hi,
        }
    }

    /// Apply step μ on (i, j) and update the active gradient:
    /// `G_n ← G_n − μ (K_in − K_jn)`.
    ///
    /// With prefix compaction this is a branch-light linear sweep over
    /// four contiguous slices (gradient, bounds, two kernel rows) that
    /// the compiler can vectorize — no index gather. The next iteration's
    /// stopping quantities (m, M, gap, argmax) are computed inside the
    /// same loop: the updated gradient is already in registers, so the
    /// stop check costs zero extra passes (perf pass, EXPERIMENTS.md
    /// §Perf items 1+3).
    pub fn apply_and_update(&mut self, i: usize, j: usize, mu: f64) {
        if mu == 0.0 {
            return;
        }
        self.state.apply_step(i, j, mu);
        let al = self.state.active_len;
        let (row_i, row_j) = self.gram.rows_pair(i, j);
        let (row_i, row_j) = (&row_i[..al], &row_j[..al]);
        let st = &mut self.state;
        self.cached_scan = Some(fused_scan_update(
            &mut st.grad[..al],
            &st.alpha[..al],
            &st.lower[..al],
            &st.upper[..al],
            &st.perm[..al],
            mu,
            |n| row_i[n] as f64 - row_j[n] as f64,
        ));
    }

    /// Direction-step core shared with `solver::conjugate`: apply
    /// `α ← α + μ·d` for the sparse original-coordinate direction
    /// `d = v_B + β·d_prev` (given as `(original index, component)`
    /// pairs), refresh the direction's kernel image in place
    /// (`kd[s] ← (K_{i·} − K_{j·})[s] + β·kd[s]` for every active
    /// original index `s` — so `kd` holds `K·d` for the *new* direction
    /// afterwards), and update the active gradient `G ← G − μ·K·d` with
    /// the same fused stopping scan as [`SolverCore::apply_and_update`].
    ///
    /// `(i, j)` is the current working set in *positions* (its rows are
    /// fetched through the cache, exactly the rows a plain SMO step
    /// would need). With `β = 0` and `dir = [(iₒ, 1), (jₒ, −1)]` this
    /// degenerates to `apply_and_update` plus seeding `kd` with
    /// `K_{i·} − K_{j·}` — the momentum bootstrap after a fallback step.
    ///
    /// The caller guarantees μ lies in the direction's feasible interval;
    /// the per-coordinate clamp only snaps floating-point dust, exactly
    /// like [`SolverState::apply_step`].
    pub(crate) fn apply_direction_and_update(
        &mut self,
        i: usize,
        j: usize,
        beta: f64,
        dir: &[(usize, f64)],
        kd: &mut [f64],
        mu: f64,
    ) {
        for &(s, ds) in dir {
            let p = self.state.pos[s];
            self.state.alpha[p] = (self.state.alpha[p] + mu * ds)
                .clamp(self.state.lower[p], self.state.upper[p]);
        }
        let al = self.state.active_len;
        let (row_i, row_j) = self.gram.rows_pair(i, j);
        let (row_i, row_j) = (&row_i[..al], &row_j[..al]);
        let st = &mut self.state;
        let perm = &st.perm[..al];
        self.cached_scan = Some(fused_scan_update(
            &mut st.grad[..al],
            &st.alpha[..al],
            &st.lower[..al],
            &st.upper[..al],
            perm,
            mu,
            |n| {
                let kdn = (row_i[n] as f64 - row_j[n] as f64) + beta * kd[perm[n]];
                kd[perm[n]] = kdn;
                kdn
            },
        ));
    }

    /// One plain SMO step (eq. 2 / configured policy) on the selected pair.
    /// Returns (step size, was it a *free* SMO step).
    pub fn smo_step(&mut self, sel: Selection) -> (f64, bool) {
        let sp = self.subproblem(sel.i, sel.j);
        let mu = self.config.step_policy.step(&sp);
        let free = self.config.step_policy.step_is_free(&sp, mu);
        self.apply_and_update(sel.i, sel.j, mu);
        self.telemetry.count_step(if free {
            StepKind::SmoFree
        } else {
            StepKind::SmoAtBound
        });
        (mu, free)
    }

    pub fn finish(mut self, reason: StopReason, started: Instant) -> SolveResult {
        // Always report on the full problem, in original coordinates.
        shrink::unshrink_and_reconstruct(&mut self.state, self.gram);
        #[cfg(feature = "debug-invariants")]
        self.state.check_invariants(self.equality_sum);
        let (_, _, gap) = self.state.kkt_gap_active();
        let (sv, bsv) = self.state.sv_counts(1e-12);
        SolveResult {
            bias: self.state.bias(),
            objective: self.state.objective(),
            alpha: self.state.alpha_original(),
            iterations: self.iterations,
            gap,
            converged: reason == StopReason::Converged,
            stop_reason: reason,
            sv,
            bsv,
            wall_time_s: started.elapsed().as_secs_f64(),
            telemetry: self.telemetry,
            cache_stats: self.gram.cache_stats(),
            kernel_entries: self.gram.kernel_entries(),
        }
    }
}

/// The fused gradient-update + stopping-scan body shared by
/// [`SolverCore::apply_and_update`] (plain SMO pair steps) and
/// [`SolverCore::apply_direction_and_update`] (conjugate directions):
/// one linear sweep over the contiguous active prefix that updates
/// `grad[n] ← grad[n] − μ·kdn(n)` and computes the next iteration's
/// stopping quantities with the updated gradient still in registers.
/// `kdn(n)` is the direction's kernel image at position `n`; it is
/// monomorphized and inlined per caller, so the SMO hot path keeps its
/// plain two-row codegen. Returns the `cached_scan` tuple
/// `(m, big_m, gap, argmax_up in original coordinates)`.
#[inline(always)]
fn fused_scan_update(
    grad: &mut [f64],
    alpha: &[f64],
    lower: &[f64],
    upper: &[f64],
    perm: &[usize],
    mu: f64,
    mut kdn: impl FnMut(usize) -> f64,
) -> (f64, f64, f64, Option<usize>) {
    let mut m = f64::NEG_INFINITY;
    let mut big_m = f64::INFINITY;
    let mut argmax = None;
    for n in 0..grad.len() {
        let g = grad[n] - mu * kdn(n);
        grad[n] = g;
        if g > m && alpha[n] < upper[n] {
            m = g;
            argmax = Some(n);
        }
        if g < big_m && alpha[n] > lower[n] {
            big_m = g;
        }
    }
    let gap = if m == f64::NEG_INFINITY || big_m == f64::INFINITY {
        f64::NEG_INFINITY
    } else {
        m - big_m
    };
    (m, big_m, gap, argmax.map(|p| perm[p]))
}

/// Algorithm 1 — the baseline SMO solver.
pub struct SmoSolver {
    /// Shared solver tuning (ε, cache, shrinking, WSS, step policy …).
    pub config: SolverConfig,
}

impl SmoSolver {
    /// A baseline SMO engine with the given tuning.
    pub fn new(config: SolverConfig) -> SmoSolver {
        SmoSolver { config }
    }

    fn run(&self, mut core: SolverCore, started: Instant) -> SolveResult {
        let reason = loop {
            if let Some(stop) = core.check_stop_and_shrink() {
                break stop;
            }
            let Some(sel) = core.select(GainKind::Approx, &[]) else {
                break StopReason::Converged; // no violating pair on the active set
            };
            core.iterations += 1;
            core.smo_step(sel);
            let it = core.iterations;
            // borrow dance: compute objective lazily only when tracing
            if core.telemetry.config.objective_trace {
                let obj = core.state.objective();
                core.telemetry.record_objective(it, || obj);
            }
        };
        core.finish(reason, started)
    }
}

impl Engine for SmoSolver {
    fn name(&self) -> &'static str {
        "smo"
    }

    fn solve_state(&self, state: SolverState, gram: &mut Gram) -> SolveResult {
        let started = Instant::now();
        let core = SolverCore::from_state(state, gram, self.config);
        self.run(core, started)
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::kernel::function::KernelFunction;
    use crate::kernel::native::NativeRowComputer;
    use crate::solver::problem::QpProblem;
    use crate::util::prng::Pcg;
    use std::sync::Arc;

    /// Classification shorthand used across the solver test suites.
    pub(crate) fn solve_cls(
        engine: &dyn Engine,
        labels: &[i8],
        c: f64,
        gram: &mut Gram,
    ) -> SolveResult {
        engine.solve(&QpProblem::classification(labels, c), gram)
    }

    pub(crate) fn make_gram(ds: &Arc<Dataset>, gamma: f64, cache: usize) -> Gram {
        let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma });
        Gram::new(Box::new(nc), cache)
    }

    pub(crate) fn random_problem(n: usize, seed: u64) -> Arc<Dataset> {
        let mut rng = Pcg::new(seed);
        let mut ds = Dataset::with_dim(2);
        for k in 0..n {
            let y: i8 = if k % 2 == 0 { 1 } else { -1 };
            let cx = if y == 1 { 0.8 } else { -0.8 };
            ds.push(
                &[(cx + rng.normal() * 0.9) as f32, (rng.normal() * 0.9) as f32],
                y,
            );
        }
        Arc::new(ds)
    }

    #[test]
    fn solves_trivially_separable_pair() {
        let ds = Arc::new(Dataset::new(1, vec![1.0, -1.0], vec![1, -1]));
        let mut gram = make_gram(&ds, 0.5, 1 << 20);
        let res = solve_cls(&SmoSolver::new(SolverConfig::default()), ds.labels(), 10.0, &mut gram);
        assert!(res.converged);
        assert!(res.gap <= 1e-3);
        // symmetric problem: alpha = (a, -a) with a = l/q at optimum or bound
        assert!((res.alpha[0] + res.alpha[1]).abs() < 1e-12);
        assert!(res.alpha[0] > 0.0);
        assert!(res.objective > 0.0);
    }

    #[test]
    fn objective_is_monotonically_non_decreasing() {
        let ds = random_problem(60, 3);
        let mut gram = make_gram(&ds, 1.0, 1 << 22);
        let cfg = SolverConfig {
            telemetry: TelemetryConfig {
                objective_trace: true,
                trace_every: 1,
                ..Default::default()
            },
            shrinking: false,
            ..Default::default()
        };
        let res = solve_cls(&SmoSolver::new(cfg), ds.labels(), 1.0, &mut gram);
        assert!(res.converged);
        let trace = &res.telemetry.objective_trace;
        assert!(trace.len() > 2);
        for w in trace.windows(2) {
            assert!(
                w[1].1 >= w[0].1 - 1e-9,
                "objective decreased: {} -> {}",
                w[0].1,
                w[1].1
            );
        }
    }

    #[test]
    fn kkt_gap_below_eps_at_convergence() {
        for seed in [1u64, 7, 13] {
            let ds = random_problem(80, seed);
            let mut gram = make_gram(&ds, 0.7, 1 << 22);
            let res =
                solve_cls(&SmoSolver::new(SolverConfig::default()), ds.labels(), 2.0, &mut gram);
            assert!(res.converged, "seed {seed}");
            assert!(res.gap <= 1e-3 + 1e-9, "seed {seed}: gap {}", res.gap);
            // feasibility of the returned alpha
            let sum: f64 = res.alpha.iter().sum();
            assert!(sum.abs() < 1e-9);
        }
    }

    #[test]
    fn shrinking_does_not_change_the_solution() {
        let ds = random_problem(100, 11);
        let mut g1 = make_gram(&ds, 1.2, 1 << 22);
        let mut g2 = make_gram(&ds, 1.2, 1 << 22);
        let on = solve_cls(&SmoSolver::new(SolverConfig { shrinking: true, ..Default::default() }), ds.labels(), 1.5, &mut g1);
        let off = solve_cls(&SmoSolver::new(SolverConfig { shrinking: false, ..Default::default() }), ds.labels(), 1.5, &mut g2);
        assert!(on.converged && off.converged);
        assert!(
            (on.objective - off.objective).abs() < 1e-3 * (1.0 + off.objective.abs()),
            "{} vs {}",
            on.objective,
            off.objective
        );
    }

    #[test]
    fn shrinking_solution_is_reported_in_original_coordinates() {
        // Aggressive shrinking permutes the internal view many times; the
        // reported alpha must still line up with the original examples —
        // checked against the unshrunk run coordinate by coordinate.
        let ds = random_problem(120, 19);
        let mut g1 = make_gram(&ds, 1.0, 1 << 22);
        let mut g2 = make_gram(&ds, 1.0, 1 << 22);
        let tight = SolverConfig { eps: 1e-5, shrink_interval: 7, ..Default::default() };
        let on = solve_cls(
            &SmoSolver::new(SolverConfig { shrinking: true, ..tight }),
            ds.labels(),
            5.0,
            &mut g1,
        );
        let off = solve_cls(
            &SmoSolver::new(SolverConfig { shrinking: false, ..tight }),
            ds.labels(),
            5.0,
            &mut g2,
        );
        assert!(on.converged && off.converged);
        for i in 0..ds.len() {
            assert!(
                (on.alpha[i] - off.alpha[i]).abs() < 5e-2 * (1.0 + 5.0),
                "alpha[{i}] diverges: shrunk {} vs full {}",
                on.alpha[i],
                off.alpha[i]
            );
            // sign structure must match the label bounds in original order
            let y = ds.label(i) as f64;
            assert!(on.alpha[i] * y >= -1e-9, "alpha[{i}] violates its box side");
        }
    }

    #[test]
    fn max_violating_pair_wss_also_converges() {
        let ds = random_problem(60, 5);
        let mut gram = make_gram(&ds, 1.0, 1 << 22);
        let cfg = SolverConfig { wss: WssKind::MaxViolating, ..Default::default() };
        let res = solve_cls(&SmoSolver::new(cfg), ds.labels(), 1.0, &mut gram);
        assert!(res.converged);
        assert!(res.gap <= 1e-3 + 1e-9);
    }

    #[test]
    fn over_relaxed_policy_converges_with_positive_gain() {
        let ds = random_problem(60, 6);
        let mut gram = make_gram(&ds, 1.0, 1 << 22);
        let cfg = SolverConfig {
            step_policy: OverStep::OverRelaxed(1.1),
            ..Default::default()
        };
        let res = solve_cls(&SmoSolver::new(cfg), ds.labels(), 1.0, &mut gram);
        assert!(res.converged);
        assert!(res.gap <= 1e-3 + 1e-9);
    }

    #[test]
    fn iteration_cap_reports_non_convergence() {
        let ds = random_problem(100, 7);
        let mut gram = make_gram(&ds, 1.0, 1 << 22);
        let cfg = SolverConfig { max_iter: 3, ..Default::default() };
        let res = solve_cls(&SmoSolver::new(cfg), ds.labels(), 1.0, &mut gram);
        assert!(!res.converged);
        assert_eq!(res.stop_reason, StopReason::IterLimit);
        assert!(res.iterations <= 4);
    }

    #[test]
    fn stop_reason_is_converged_on_a_full_solve() {
        let ds = random_problem(60, 4);
        let mut gram = make_gram(&ds, 1.0, 1 << 22);
        let res = solve_cls(&SmoSolver::new(SolverConfig::default()), ds.labels(), 1.0, &mut gram);
        assert!(res.converged);
        assert_eq!(res.stop_reason, StopReason::Converged);
    }

    #[test]
    fn raised_stop_flag_checkpoints_at_the_next_iteration_boundary() {
        static FLAG: AtomicBool = AtomicBool::new(false);
        let ds = random_problem(80, 12);
        let mut gram = make_gram(&ds, 1.0, 1 << 22);
        FLAG.store(true, Ordering::Relaxed);
        let cfg = SolverConfig { stop_flag: Some(&FLAG), ..Default::default() };
        let res = solve_cls(&SmoSolver::new(cfg), ds.labels(), 1.0, &mut gram);
        assert_eq!(res.stop_reason, StopReason::Checkpointed);
        assert!(!res.converged);
        // The flag fires before the first step: nothing was optimized,
        // but the result is still a feasible original-coordinate iterate.
        assert_eq!(res.iterations, 0);
        let sum: f64 = res.alpha.iter().sum();
        assert!(sum.abs() < 1e-9);
        FLAG.store(false, Ordering::Relaxed);
    }

    #[test]
    fn free_and_bounded_steps_are_counted() {
        let ds = random_problem(40, 8);
        let mut gram = make_gram(&ds, 1.0, 1 << 22);
        let cfg = SolverConfig {
            telemetry: TelemetryConfig::fig3(),
            ..Default::default()
        };
        let res = solve_cls(&SmoSolver::new(cfg), ds.labels(), 0.05, &mut gram);
        // tiny C forces bounded steps
        assert!(res.telemetry.bounded_steps > 0);
        assert_eq!(res.telemetry.total_steps(), res.iterations);
    }

    #[test]
    fn kernel_entries_are_reported_and_bounded_by_work() {
        let ds = random_problem(80, 9);
        let mut gram = make_gram(&ds, 1.0, 1 << 22);
        let res = solve_cls(&SmoSolver::new(SolverConfig::default()), ds.labels(), 2.0, &mut gram);
        assert!(res.converged);
        // at least the diagonal plus one row was evaluated …
        assert!(res.kernel_entries >= 80 + 80);
        // … and no more than every miss paying a full row, plus singles
        // (subproblem entries, reconstruction tails bounded by ℓ² here)
        let ceiling = (res.cache_stats.misses + res.cache_stats.evictions + 2) * 80
            + 2 * 80 * 80
            + 10 * res.iterations;
        assert!(
            res.kernel_entries <= ceiling,
            "{} entries vs ceiling {ceiling}",
            res.kernel_entries
        );
    }
}
