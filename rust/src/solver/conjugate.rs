//! Conjugate SMO — a third [`Engine`] that augments the SMO working-set
//! step with conjugate-direction momentum (after Torres-Barrán, Alaíz &
//! Dorronsoro, *Faster SVM Training via Conjugate SMO*; see PAPERS.md).
//!
//! The planning-ahead idea — reuse information from previous iterations
//! to pick a better step — is carried further here: instead of *solving
//! a 2×2 system for the predicted next working set* (PA-SMO), the
//! solver *keeps the previous update direction* `d` and combines it
//! with the freshly selected SMO direction `v_B = e_i − e_j` into
//!
//! ```text
//! d' = v_B + β·d,     β = − (v_Bᵀ K d) / (dᵀ K d),
//! ```
//!
//! the classical conjugate-direction momentum: `d'` is K-conjugate to
//! `d` (`d'ᵀKd = 0`), so the exact line search along `d'` does not undo
//! the progress of the previous step. The step size is the exact
//! maximizer of the quadratic along `d'`, clipped to the box-feasible
//! interval:
//!
//! ```text
//! μ = clip( (d'ᵀ∇f) / (d'ᵀKd'),  [lo, hi] ),
//! ```
//!
//! with `[lo, hi]` the largest interval keeping every coordinate of
//! `α + μ·d'` inside its box (the re-projection of the momentum onto
//! the current feasible set). Because `K d` is maintained incrementally
//! (`K d' = (K_{i·} − K_{j·}) + β·K d`, fused into the gradient update),
//! a conjugate step costs **zero extra kernel evaluations** over a
//! plain SMO step — the two working-set rows are needed either way.
//!
//! **Gain-fallback safety** (mirroring PA-SMO's Lemma-3 discipline): the
//! conjugate step is taken only when its gain *strictly exceeds* the
//! gain of the plain SMO step on the same working set; otherwise the
//! solver reverts to the SMO step. Every iteration therefore gains at
//! least as much as baseline SMO, so the standard SMO convergence
//! argument carries over unchanged.
//!
//! **Shrinking / warm starts.** The momentum is stored in *original*
//! coordinates (like PA-SMO's planning history), so shrink swaps never
//! corrupt it. It is dropped when it can no longer be applied: when a
//! support coordinate is shrunk out of the active prefix (the direction
//! would move a fixed variable) or when an unshrink reactivates
//! coordinates whose `K d` entries went stale. Warm starts need no
//! special handling — the momentum simply starts empty.
//!
//! The engine plugs into the ordinary training surface via
//! `SolverChoice::ConjugateSmo`:
//!
//! ```
//! use pasmo::solver::SolverChoice;
//! use pasmo::svm::Trainer;
//!
//! let data = std::sync::Arc::new(pasmo::data::synth::chessboard(120, 4, 5));
//! let conj = Trainer::rbf(100.0, 0.5).solver(SolverChoice::ConjugateSmo).train(&data);
//! let smo = Trainer::rbf(100.0, 0.5).solver(SolverChoice::Smo).train(&data);
//! assert!(conj.result.converged);
//! // Same optimum as baseline SMO (the gain fallback guarantees every
//! // iteration gains at least as much as the plain SMO step).
//! let rel = (conj.result.objective - smo.result.objective).abs()
//!     / (1.0 + smo.result.objective.abs());
//! assert!(rel < 2e-3);
//! ```

use std::time::Instant;

use crate::kernel::matrix::Gram;

use super::engine::Engine;
use super::events::StepKind;
use super::smo::{SolveResult, SolverConfig, SolverCore, StopReason};
use super::state::SolverState;
use super::step::{clamp, SubProblem, TAU};
use super::wss::GainKind;

/// The conjugate SMO solver: SMO working-set selection, momentum-
/// combined update directions, gain fallback to the plain SMO step.
pub struct ConjugateSmoSolver {
    /// Shared solver tuning (ε, cache, shrinking, WSS, step policy …).
    pub config: SolverConfig,
}

/// A conjugate step decision: the momentum coefficient β and the exact
/// (clipped) line-search step μ along `v_B + β·d`.
#[derive(Debug, Clone, Copy)]
struct ConjugateStep {
    beta: f64,
    mu: f64,
}

/// Conjugate momentum carried between iterations.
///
/// `d` and `kd = K·d` are dense vectors over *original* indices;
/// `support` lists the originals with a non-zero direction component.
/// `kd` is refreshed over the active prefix on every step (fused into
/// the gradient update), so its entries are valid exactly for the
/// originals that stayed active since the momentum was last (re)built —
/// [`Momentum::revalidate`] drops the momentum whenever that invariant
/// could break.
struct Momentum {
    d: Vec<f64>,
    kd: Vec<f64>,
    support: Vec<usize>,
    have: bool,
    last_active_len: usize,
}

impl Momentum {
    fn new(n: usize, active_len: usize) -> Momentum {
        Momentum {
            d: vec![0.0; n],
            kd: vec![0.0; n],
            support: Vec::new(),
            have: false,
            last_active_len: active_len,
        }
    }

    fn clear(&mut self) {
        for &s in &self.support {
            self.d[s] = 0.0;
        }
        self.support.clear();
        self.have = false;
    }

    /// Component of the combined direction `v_B + β·d` at original
    /// index `s`, for the working set `(i_orig, j_orig)`.
    #[inline]
    fn component(&self, beta: f64, s: usize, i_orig: usize, j_orig: usize) -> f64 {
        let mut ds = beta * self.d[s];
        if s == i_orig {
            ds += 1.0;
        }
        if s == j_orig {
            ds -= 1.0;
        }
        ds
    }

    /// Drop momentum the current active view can no longer honor. Called
    /// once per iteration, after shrinking may have run:
    /// * `active_len` grew (unshrink) — reactivated originals carry
    ///   stale `kd` entries, and the next working set may select them;
    /// * `active_len` shrank and a support coordinate left the prefix —
    ///   the direction would move a variable the solver fixed.
    ///
    /// Swaps only ever happen alongside an `active_len` change
    /// (`solver::shrink`), so an unchanged length means the view is
    /// unchanged and the momentum stays valid.
    fn revalidate(&mut self, state: &SolverState) {
        let al = state.active_len;
        if al > self.last_active_len {
            self.clear();
        } else if al < self.last_active_len
            && self.have
            && self.support.iter().any(|&s| state.pos[s] >= al)
        {
            self.clear();
        }
        self.last_active_len = al;
    }

    /// Replace the stored direction with `dir` (already combined and
    /// filtered to non-zero components) and rescale if its magnitude
    /// drifted — the direction's scale is arbitrary (β is scale-free),
    /// so renormalizing keeps repeated |β| > 1 chains finite.
    fn store_direction(&mut self, dir: &[(usize, f64)]) {
        for &s in &self.support {
            self.d[s] = 0.0;
        }
        self.support.clear();
        let mut maxabs = 0.0f64;
        for &(s, ds) in dir {
            if ds != 0.0 {
                self.d[s] = ds;
                self.support.push(s);
                maxabs = maxabs.max(ds.abs());
            }
        }
        self.have = !self.support.is_empty();
        if maxabs > 1e12 {
            let inv = 1.0 / maxabs;
            for &s in &self.support {
                self.d[s] *= inv;
            }
            for v in self.kd.iter_mut() {
                *v *= inv;
            }
        }
    }
}

impl ConjugateSmoSolver {
    /// A conjugate SMO engine with the given tuning.
    pub fn new(config: SolverConfig) -> ConjugateSmoSolver {
        ConjugateSmoSolver { config }
    }

    /// Evaluate the conjugate step for working set `(i_orig, j_orig)`
    /// against the momentum. Returns `None` — *revert to the SMO step* —
    /// when the momentum is degenerate (vanishing curvature), the line
    /// search collapses, or the conjugate gain does not strictly beat
    /// the plain SMO step's gain `gain_smo`.
    ///
    /// Reads only the maintained state and `K d` — no kernel entries.
    fn try_conjugate(
        state: &SolverState,
        mom: &Momentum,
        sp: &SubProblem,
        i_orig: usize,
        j_orig: usize,
        gain_smo: f64,
    ) -> Option<ConjugateStep> {
        // Curvature of the previous direction, dᵀKd.
        let mut qd = 0.0;
        for &s in &mom.support {
            qd += mom.d[s] * mom.kd[s];
        }
        if !(qd > TAU) {
            return None;
        }
        // β = −(v_BᵀKd)/(dᵀKd) makes d' = v_B + β·d K-conjugate to d.
        let t = mom.kd[i_orig] - mom.kd[j_orig];
        let beta = -t / qd;
        if !beta.is_finite() {
            return None;
        }
        // Curvature along d': q_c = v_BᵀKv_B − t²/qd (Gram–Schmidt step).
        let qc = sp.q - t * t / qd;
        if !(qc > TAU) {
            return None;
        }
        // Linear term d'ᵀ∇f = (G_i − G_j) + β·(dᵀG).
        let mut dg = 0.0;
        for &s in &mom.support {
            dg += mom.d[s] * state.grad[state.pos[s]];
        }
        let lc = sp.l + beta * dg;
        // Box re-projection: the largest μ-interval keeping every moved
        // coordinate feasible. Each coordinate's interval contains 0, so
        // lo ≤ 0 ≤ hi always holds.
        let (lo, hi) = Self::direction_bounds(state, mom, beta, i_orig, j_orig);
        let mu = clamp(lc / qc, lo, hi);
        if !mu.is_finite() || mu == 0.0 {
            return None;
        }
        // Gain of the (possibly clipped) exact line search along d'.
        let gain = lc * mu - 0.5 * qc * mu * mu;
        if gain > gain_smo {
            Some(ConjugateStep { beta, mu })
        } else {
            None
        }
    }

    /// Feasible step interval along `v_B + β·d` given the current α.
    fn direction_bounds(
        state: &SolverState,
        mom: &Momentum,
        beta: f64,
        i_orig: usize,
        j_orig: usize,
    ) -> (f64, f64) {
        let mut lo = f64::NEG_INFINITY;
        let mut hi = f64::INFINITY;
        let mut consider = |s: usize, ds: f64| {
            if ds == 0.0 {
                return;
            }
            let p = state.pos[s];
            let (a, l, u) = (state.alpha[p], state.lower[p], state.upper[p]);
            if ds > 0.0 {
                hi = hi.min((u - a) / ds);
                lo = lo.max((l - a) / ds);
            } else {
                hi = hi.min((l - a) / ds);
                lo = lo.max((u - a) / ds);
            }
        };
        for &s in &mom.support {
            consider(s, mom.component(beta, s, i_orig, j_orig));
        }
        if mom.d[i_orig] == 0.0 {
            consider(i_orig, 1.0);
        }
        if mom.d[j_orig] == 0.0 {
            consider(j_orig, -1.0);
        }
        (lo, hi)
    }

    fn run(&self, mut core: SolverCore, started: Instant) -> SolveResult {
        let mut mom = Momentum::new(core.state.len(), core.state.active_len);
        // Combined-direction scratch, reused across iterations.
        let mut dir: Vec<(usize, f64)> = Vec::new();
        let reason = loop {
            if let Some(stop) = core.check_stop_and_shrink() {
                break stop;
            }
            mom.revalidate(&core.state);
            let Some(sel) = core.select(GainKind::Approx, &[]) else {
                break StopReason::Converged; // no violating pair on the active set
            };
            core.iterations += 1;
            let (i, j) = (sel.i, sel.j);
            let sp = core.subproblem(i, j);
            let mu_smo = self.config.step_policy.step(&sp);
            let gain_smo = sp.gain(mu_smo);
            let (i_orig, j_orig) = (core.state.perm[i], core.state.perm[j]);

            let conj = if mom.have {
                let attempt =
                    Self::try_conjugate(&core.state, &mom, &sp, i_orig, j_orig, gain_smo);
                if attempt.is_none() {
                    core.telemetry.conjugate_reverted += 1;
                }
                attempt
            } else {
                None
            };

            match conj {
                Some(ConjugateStep { beta, mu }) => {
                    // Materialize d' = v_B + β·d sparsely over its support.
                    dir.clear();
                    for &s in &mom.support {
                        let ds = mom.component(beta, s, i_orig, j_orig);
                        if ds != 0.0 {
                            dir.push((s, ds));
                        }
                    }
                    if mom.d[i_orig] == 0.0 {
                        dir.push((i_orig, 1.0));
                    }
                    if mom.d[j_orig] == 0.0 {
                        dir.push((j_orig, -1.0));
                    }
                    core.apply_direction_and_update(i, j, beta, &dir, &mut mom.kd, mu);
                    mom.store_direction(&dir);
                    core.telemetry.count_step(StepKind::Conjugate);
                }
                None => {
                    // Plain SMO step; the applied pair direction (with its
                    // kernel image, seeded by β = 0) becomes the momentum.
                    // Free/bounded accounting matches `SolverCore::smo_step`
                    // (shared policy definition), so step-kind telemetry is
                    // comparable across engines.
                    let free = self.config.step_policy.step_is_free(&sp, mu_smo);
                    if mu_smo != 0.0 {
                        dir.clear();
                        dir.push((i_orig, 1.0));
                        dir.push((j_orig, -1.0));
                        core.apply_direction_and_update(i, j, 0.0, &dir, &mut mom.kd, mu_smo);
                        mom.store_direction(&dir);
                    } else {
                        mom.clear();
                    }
                    core.telemetry.count_step(if free {
                        StepKind::SmoFree
                    } else {
                        StepKind::SmoAtBound
                    });
                }
            }
            if core.telemetry.config.objective_trace {
                let obj = core.state.objective();
                let it = core.iterations;
                core.telemetry.record_objective(it, || obj);
            }
        };
        core.finish(reason, started)
    }
}

impl Engine for ConjugateSmoSolver {
    fn name(&self) -> &'static str {
        "conjugate"
    }

    fn solve_state(&self, state: SolverState, gram: &mut Gram) -> SolveResult {
        let started = Instant::now();
        let core = SolverCore::from_state(state, gram, self.config);
        self.run(core, started)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::matrix::DenseGram;
    use crate::kernel::{KernelFunction, NativeRowComputer};
    use crate::solver::events::TelemetryConfig;
    use crate::solver::reference::solve_reference;
    use crate::solver::smo::tests::{make_gram, random_problem, solve_cls};
    use crate::solver::smo::SmoSolver;
    use crate::util::prng::Pcg;

    #[test]
    fn converges_and_matches_smo_objective() {
        for seed in [1u64, 5, 9] {
            let ds = random_problem(80, seed);
            let mut g1 = make_gram(&ds, 1.0, 1 << 22);
            let mut g2 = make_gram(&ds, 1.0, 1 << 22);
            let smo =
                solve_cls(&SmoSolver::new(SolverConfig::default()), ds.labels(), 2.0, &mut g1);
            let cj = solve_cls(
                &ConjugateSmoSolver::new(SolverConfig::default()),
                ds.labels(),
                2.0,
                &mut g2,
            );
            assert!(cj.converged, "seed {seed}");
            assert!(cj.gap <= 1e-3 + 1e-9, "seed {seed}: {}", cj.gap);
            let rel = (cj.objective - smo.objective).abs() / (1.0 + smo.objective.abs());
            assert!(rel < 2e-3, "seed {seed}: {} vs {}", cj.objective, smo.objective);
        }
    }

    #[test]
    fn conjugate_steps_occur_and_are_counted() {
        // Overlapping classes at large C: many free steps, so momentum
        // builds and the conjugate direction strictly beats the plain
        // step whenever v_BᵀKd ≠ 0 (which is the typical case).
        let ds = random_problem(60, 3);
        let mut gram = make_gram(&ds, 2.0, 1 << 22);
        let cfg = SolverConfig {
            telemetry: TelemetryConfig::full(1),
            shrinking: false,
            ..Default::default()
        };
        let res = solve_cls(&ConjugateSmoSolver::new(cfg), ds.labels(), 1e4, &mut gram);
        assert!(res.converged);
        assert!(
            res.telemetry.conjugate_steps > 0,
            "no conjugate steps: {:?}",
            res.telemetry
        );
        assert_eq!(res.telemetry.total_steps(), res.iterations);
    }

    #[test]
    fn objective_is_monotone_and_gains_at_least_the_smo_step() {
        // The gain-fallback guarantee in observable form: the objective
        // trace never decreases (each step gains ≥ the plain SMO step's
        // positive gain).
        let ds = random_problem(60, 7);
        let mut gram = make_gram(&ds, 1.5, 1 << 22);
        let cfg = SolverConfig {
            telemetry: TelemetryConfig::full(1),
            shrinking: false,
            ..Default::default()
        };
        let res = solve_cls(&ConjugateSmoSolver::new(cfg), ds.labels(), 100.0, &mut gram);
        assert!(res.converged);
        let trace = &res.telemetry.objective_trace;
        assert!(trace.len() > 2);
        for w in trace.windows(2) {
            assert!(
                w[1].1 >= w[0].1 - 1e-9,
                "objective decreased: {} -> {}",
                w[0].1,
                w[1].1
            );
        }
    }

    #[test]
    fn matches_reference_oracle_at_tight_eps() {
        for seed in [2u64, 4] {
            let ds = random_problem(24, seed);
            let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 0.8 });
            let dense = DenseGram::materialize(&nc);
            let c = 5.0;
            let reference = solve_reference(&dense, ds.labels(), c, 200_000, 1e-14);
            let cfg = SolverConfig { eps: 1e-6, ..Default::default() };
            let mut gram = make_gram(&ds, 0.8, 1 << 22);
            let cj = solve_cls(&ConjugateSmoSolver::new(cfg), ds.labels(), c, &mut gram);
            let tol = 1e-4 * (1.0 + reference.objective.abs());
            assert!(
                (cj.objective - reference.objective).abs() < tol,
                "seed {seed}: CSMO {} vs ref {}",
                cj.objective,
                reference.objective
            );
        }
    }

    #[test]
    fn final_objective_never_worse_than_smo_across_seeds() {
        let mut rng = Pcg::new(321);
        for _ in 0..5 {
            let seed = rng.next_u64();
            let ds = random_problem(40, seed);
            let mut g1 = make_gram(&ds, 1.0, 1 << 22);
            let mut g2 = make_gram(&ds, 1.0, 1 << 22);
            let smo =
                solve_cls(&SmoSolver::new(SolverConfig::default()), ds.labels(), 10.0, &mut g1);
            let cj = solve_cls(
                &ConjugateSmoSolver::new(SolverConfig::default()),
                ds.labels(),
                10.0,
                &mut g2,
            );
            assert!(
                cj.objective >= smo.objective - 1e-3 * (1.0 + smo.objective.abs()),
                "seed {seed}: CSMO {} < SMO {}",
                cj.objective,
                smo.objective
            );
        }
    }

    #[test]
    fn feasibility_invariants_hold_throughout() {
        use crate::util::quickcheck::forall;
        forall(
            "conjugate-feasible-solutions",
            8,
            |g| (16 + g.below(48), g.next_u64(), 10f64.powf(g.range(-1.0, 3.0))),
            |&(n, seed, c)| {
                let ds = random_problem(n, seed);
                let mut gram = make_gram(&ds, 1.0, 1 << 22);
                let res = solve_cls(
                    &ConjugateSmoSolver::new(SolverConfig::default()),
                    ds.labels(),
                    c,
                    &mut gram,
                );
                // The momentum direction sums to zero by construction; a
                // long β-chain may accumulate float dust, never more.
                let sum: f64 = res.alpha.iter().sum();
                if sum.abs() > 1e-6 {
                    return Err(format!("equality constraint violated: {sum}"));
                }
                for (i, &a) in res.alpha.iter().enumerate() {
                    let y = ds.label(i) as f64;
                    let (lo, hi) = ((y * c).min(0.0), (y * c).max(0.0));
                    if a < lo - 1e-9 || a > hi + 1e-9 {
                        return Err(format!("box violated at {i}: {a} not in [{lo},{hi}]"));
                    }
                }
                if !res.converged {
                    return Err("did not converge".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn shrinking_conjugate_matches_unshrunk_objective() {
        let ds = random_problem(120, 17);
        let mut g1 = make_gram(&ds, 1.0, 1 << 22);
        let mut g2 = make_gram(&ds, 1.0, 1 << 22);
        // An aggressive shrink period exercises the momentum-drop paths
        // (support shrunk away, unshrink reactivation) many times.
        let tight = SolverConfig { shrink_interval: 7, ..Default::default() };
        let on = solve_cls(
            &ConjugateSmoSolver::new(SolverConfig { shrinking: true, ..tight }),
            ds.labels(),
            1.0,
            &mut g1,
        );
        let off = solve_cls(
            &ConjugateSmoSolver::new(SolverConfig { shrinking: false, ..tight }),
            ds.labels(),
            1.0,
            &mut g2,
        );
        assert!(on.converged && off.converged);
        let rel = (on.objective - off.objective).abs() / (1.0 + off.objective.abs());
        assert!(rel < 2e-3, "{} vs {}", on.objective, off.objective);
    }

    #[test]
    fn solves_are_bit_deterministic() {
        let ds = random_problem(90, 21);
        let engine = ConjugateSmoSolver::new(SolverConfig::default());
        let mut g1 = make_gram(&ds, 1.0, 1 << 22);
        let mut g2 = make_gram(&ds, 1.0, 1 << 22);
        let a = solve_cls(&engine, ds.labels(), 50.0, &mut g1);
        let b = solve_cls(&engine, ds.labels(), 50.0, &mut g2);
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.objective, b.objective);
        assert_eq!(a.alpha, b.alpha);
    }

    #[test]
    fn warm_start_from_own_solution_converges_immediately() {
        use crate::solver::problem::QpProblem;
        let ds = random_problem(80, 13);
        let engine = ConjugateSmoSolver::new(SolverConfig::default());
        let mut g1 = make_gram(&ds, 1.0, 1 << 22);
        let cold = engine.solve(&QpProblem::classification(ds.labels(), 10.0), &mut g1);
        assert!(cold.converged);
        let mut g2 = make_gram(&ds, 1.0, 1 << 22);
        let warm = engine.solve(
            &QpProblem::classification(ds.labels(), 10.0).warm_start(cold.alpha.clone()),
            &mut g2,
        );
        assert!(warm.converged);
        assert!(
            warm.iterations <= cold.iterations / 4,
            "warm restart took {} iterations vs cold {}",
            warm.iterations,
            cold.iterations
        );
    }
}
