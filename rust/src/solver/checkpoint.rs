//! Solver checkpoints: crash-safe snapshots of a long-running solve.
//!
//! A checkpoint captures everything needed to resume a training solve
//! after the process dies: the dual iterate α **in original
//! coordinates** (the shrink permutation is undone before serialization
//! — see [`crate::solver::SolverState::alpha_original`] — so the stored
//! vector is a plain identity-ordered snapshot and no permutation needs
//! to be persisted alongside it), the cumulative iteration count, and
//! the objective at snapshot time for sanity reporting. Resuming feeds
//! the α back through [`crate::solver::QpProblem::warm_start`], which
//! clamps/repairs it against the (possibly different) box and
//! reconstructs the gradient — the same path grid-search warm starts
//! use, so a resumed solve is an ordinary warm-started solve.
//!
//! On disk a checkpoint is a schema-v2-style JSON envelope written
//! atomically with an embedded content checksum
//! ([`crate::util::artifact`]): a kill mid-write leaves the previous
//! checkpoint intact, and a truncated or bit-flipped file is refused at
//! load with a positioned parse error or a checksum mismatch instead of
//! resuming from garbage.
//!
//! ```
//! use pasmo::solver::Checkpoint;
//!
//! let dir = std::env::temp_dir().join("pasmo-checkpoint-doc");
//! std::fs::create_dir_all(&dir).unwrap();
//! let path = dir.join("ck.json");
//! let ck = Checkpoint {
//!     alpha: vec![0.5, -0.5],
//!     iterations: 42,
//!     objective: 1.25,
//!     eps: 1e-3,
//! };
//! ck.save(&path).unwrap();
//! let back = Checkpoint::load(&path).unwrap();
//! assert_eq!(back.alpha, ck.alpha);
//! assert_eq!(back.iterations, 42);
//! std::fs::remove_file(&path).unwrap();
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::artifact;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::{bail, ensure};

/// The on-disk `format` tag of a checkpoint envelope.
pub const FORMAT: &str = "pasmo-checkpoint";
/// Current envelope version.
pub const VERSION: u64 = 1;

/// A resumable snapshot of a training solve.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Dual variables in original coordinates (permutation undone).
    pub alpha: Vec<f64>,
    /// Cumulative iterations performed up to this snapshot (across all
    /// resumed segments).
    pub iterations: u64,
    /// Dual objective at snapshot time (reporting only; recomputed on
    /// resume).
    pub objective: f64,
    /// Stopping accuracy ε the interrupted solve was running with.
    pub eps: f64,
}

impl Checkpoint {
    /// Serialize to the JSON envelope (without the checksum — the
    /// artifact writer stamps that).
    fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("format".to_string(), Json::Str(FORMAT.to_string()));
        obj.insert("version".to_string(), Json::Num(VERSION as f64));
        obj.insert("n".to_string(), Json::Num(self.alpha.len() as f64));
        obj.insert(
            "alpha".to_string(),
            Json::Arr(self.alpha.iter().map(|&a| Json::Num(a)).collect()),
        );
        obj.insert("iterations".to_string(), Json::Num(self.iterations as f64));
        obj.insert("objective".to_string(), Json::Num(self.objective));
        obj.insert("eps".to_string(), Json::Num(self.eps));
        Json::Obj(obj)
    }

    /// Write the checkpoint atomically (temp file + rename, checksummed).
    /// A crash at any point leaves either the previous checkpoint or
    /// nothing — never a partial file.
    pub fn save(&self, path: &Path) -> Result<()> {
        artifact::save_json(path, self.to_json())
            .with_context(|| format!("save checkpoint {}", path.display()))
    }

    /// Load and validate a checkpoint. Refuses wrong formats/versions,
    /// corrupted content (checksum), truncated files (positioned parse
    /// error) and malformed fields.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let doc = artifact::load_json(path)
            .with_context(|| format!("load checkpoint {}", path.display()))?;
        let format = doc
            .get("format")
            .and_then(|v| v.as_str())
            .with_context(|| format!("{}: missing format tag", path.display()))?;
        ensure!(
            format == FORMAT,
            "{}: not a checkpoint (format {format:?}, expected {FORMAT:?})",
            path.display()
        );
        let version = doc
            .get("version")
            .and_then(|v| v.as_usize())
            .with_context(|| format!("{}: missing version", path.display()))?;
        ensure!(
            version as u64 == VERSION,
            "{}: unsupported checkpoint version {version} (expected {VERSION})",
            path.display()
        );
        let n = doc
            .get("n")
            .and_then(|v| v.as_usize())
            .with_context(|| format!("{}: missing n", path.display()))?;
        let alpha_json = doc
            .get("alpha")
            .and_then(|v| v.as_arr())
            .with_context(|| format!("{}: missing alpha array", path.display()))?;
        let mut alpha = Vec::with_capacity(alpha_json.len());
        for (i, v) in alpha_json.iter().enumerate() {
            match v.as_f64() {
                Some(a) => alpha.push(a),
                None => bail!("{}: alpha[{i}]: expected a number", path.display()),
            }
        }
        ensure!(
            alpha.len() == n,
            "{}: alpha has {} entries, envelope says n={n}",
            path.display(),
            alpha.len()
        );
        let iterations = doc
            .get("iterations")
            .and_then(|v| v.as_f64())
            .with_context(|| format!("{}: missing iterations", path.display()))?
            as u64;
        let objective = doc
            .get("objective")
            .and_then(|v| v.as_f64())
            .with_context(|| format!("{}: missing objective", path.display()))?;
        let eps = doc
            .get("eps")
            .and_then(|v| v.as_f64())
            .with_context(|| format!("{}: missing eps", path.display()))?;
        Ok(Checkpoint { alpha, iterations, objective, eps })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "pasmo-checkpoint-{tag}-{}",
            std::process::id()
        ));
        fs::create_dir_all(&dir).unwrap();
        dir.join("ck.json")
    }

    fn sample() -> Checkpoint {
        Checkpoint {
            alpha: vec![0.25, -0.25, 1.5, -1.5],
            iterations: 1234,
            objective: 9.875,
            eps: 1e-3,
        }
    }

    #[test]
    fn round_trips_bit_exact() {
        let path = tmp("roundtrip");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        // f64 bits survive the shortest-round-trip number rendering
        for (a, b) in ck.alpha.iter().zip(&back.alpha) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncated_checkpoint_is_refused_with_a_positioned_error() {
        let path = tmp("truncated");
        sample().save(&path).unwrap();
        let text = fs::read_to_string(&path).unwrap();
        fs::write(&path, &text[..text.len() / 3]).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("parse"), "{err}");
        assert!(err.contains("byte"), "{err}");
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn bit_flipped_checkpoint_fails_the_checksum() {
        let path = tmp("bitflip");
        sample().save(&path).unwrap();
        let text = fs::read_to_string(&path).unwrap();
        fs::write(&path, text.replace("1234", "1235")).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn wrong_format_and_length_mismatch_are_refused() {
        let path = tmp("format");
        fs::write(&path, "{\"format\":\"pasmo-model\",\"version\":1}").unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("not a checkpoint"), "{err}");
        fs::write(
            &path,
            "{\"format\":\"pasmo-checkpoint\",\"version\":1,\"n\":3,\"alpha\":[0.5],\
             \"iterations\":1,\"objective\":0,\"eps\":0.001}",
        )
        .unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("envelope says n=3"), "{err}");
        fs::remove_file(&path).unwrap();
    }
}
