//! The 1-D SMO sub-problem and the planning-ahead step mathematics —
//! pure functions implementing the paper's equations (2), (4), (6)–(8).
//!
//! All quantities follow the paper's notation for a working-set tuple
//! `B = (i, j)` with direction `v_B = e_i − e_j`:
//! `l = v_Bᵀ∇f(α) = G_i − G_j`, `q = v_BᵀKv_B = K_ii − 2K_ij + K_jj`.

/// Numerical floor for vanishing curvature (LIBSVM's τ).
pub const TAU: f64 = 1e-12;

/// The 1-D sub-problem `max_μ  l·μ − ½ q·μ²  s.t. lo ≤ μ ≤ hi`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SubProblem {
    /// Linear term `l = v_Bᵀ∇f(α) = G_i − G_j` (the pair's violation).
    pub l: f64,
    /// Curvature `q = v_BᵀKv_B = K_ii − 2K_ij + K_jj ≥ 0`.
    pub q: f64,
    /// Lower feasible step bound `L̃` (≤ 0).
    pub lo: f64,
    /// Upper feasible step bound `Ũ` (≥ 0).
    pub hi: f64,
}

impl SubProblem {
    /// Unconstrained Newton step `μ* = l/q` (paper eq. 2's interior case).
    /// Degenerate curvature (`q ≤ TAU`): the objective is (sub-)linear in
    /// this direction, so the maximizer is ±∞ by the sign of `l` (paper
    /// Fig. 2 caption); `l = 0` gives `μ* = 0`.
    pub fn newton_step(&self) -> f64 {
        if self.q > TAU {
            self.l / self.q
        } else if self.l > 0.0 {
            f64::INFINITY
        } else if self.l < 0.0 {
            f64::NEG_INFINITY
        } else {
            0.0
        }
    }

    /// The SMO step: Newton clipped to the feasible interval (eq. 2).
    pub fn clipped_step(&self) -> f64 {
        clamp(self.newton_step(), self.lo, self.hi)
    }

    /// Is the SMO step *free* (interior Newton step, paper §2)?
    pub fn is_free(&self) -> bool {
        let mu = self.newton_step();
        mu.is_finite() && mu > self.lo && mu < self.hi
    }

    /// Gain of an arbitrary step size: `g(μ) = l·μ − ½ q·μ²`.
    pub fn gain(&self, mu: f64) -> f64 {
        self.l * mu - 0.5 * self.q * mu * mu
    }
}

/// NaN-safe clamp that also tolerates `lo > hi` (empty direction set —
/// can happen transiently for a bounded pair; collapses to lo).
#[inline]
pub fn clamp(x: f64, lo: f64, hi: f64) -> f64 {
    x.min(hi).max(lo)
}

/// The second-order working-set-selection gain `ĝ_B(α)` (paper eq. 3):
/// `½ l² / q`, exact iff the step is unconstrained. Vanishing curvature
/// with a nonzero linear term gives ∞ (paper's footnote-1 case handled
/// without LIBSVM's τ-floor); we still expose a τ-floored variant for the
/// LIBSVM-compatible selection path.
pub fn newton_gain(l: f64, q: f64) -> f64 {
    if q > TAU {
        0.5 * l * l / q
    } else if l != 0.0 {
        f64::INFINITY
    } else {
        0.0
    }
}

/// LIBSVM-compatible gain with τ-floored denominator (finite, orderable).
pub fn newton_gain_tau(l: f64, q: f64) -> f64 {
    0.5 * l * l / q.max(TAU)
}

/// The 2×2 planning system of paper §4 for working sets B¹ (current) and
/// B² (predicted next): `w_t = v_{B^t}ᵀ∇f(α⁰)`, `Q_st = v_{B^s}ᵀ K v_{B^t}`.
#[derive(Debug, Clone, Copy)]
pub struct PlanningSystem {
    pub w1: f64,
    pub w2: f64,
    pub q11: f64,
    pub q12: f64,
    pub q22: f64,
}

impl PlanningSystem {
    /// `det(Q) = Q₁₁Q₂₂ − Q₁₂²` (≥ 0 for PSD K, barring rounding).
    pub fn det(&self) -> f64 {
        self.q11 * self.q22 - self.q12 * self.q12
    }

    /// Planning-ahead step size (paper eq. 8):
    /// `μ¹ = (Q₂₂w₁ − Q₁₂w₂) / det(Q)`.
    /// `None` when the system is degenerate (near-zero determinant or
    /// vanishing Q₂₂) — callers fall back to the plain SMO step, exactly
    /// as Algorithms 2/4 revert on infeasibility.
    pub fn planning_step(&self) -> Option<f64> {
        if self.q22 <= TAU {
            return None;
        }
        let det = self.det();
        if det <= TAU * self.q11.max(self.q22).max(1.0) {
            return None;
        }
        Some((self.q22 * self.w1 - self.q12 * self.w2) / det)
    }

    /// The greedy second step given the first (paper eq. 6):
    /// `μ² = w₂/Q₂₂ − (Q₁₂/Q₂₂)·μ¹`.
    pub fn second_step(&self, mu1: f64) -> f64 {
        debug_assert!(self.q22 > TAU);
        (self.w2 - self.q12 * mu1) / self.q22
    }

    /// Double-step gain as a function of μ¹ (paper eq. 7):
    /// `g(μ¹) = −½·det(Q)/Q₂₂·(μ¹)² + (Q₂₂w₁ − Q₁₂w₂)/Q₂₂·μ¹ + ½·w₂²/Q₂₂`.
    pub fn double_step_gain(&self, mu1: f64) -> f64 {
        debug_assert!(self.q22 > TAU);
        -0.5 * self.det() / self.q22 * mu1 * mu1
            + (self.q22 * self.w1 - self.q12 * self.w2) / self.q22 * mu1
            + 0.5 * self.w2 * self.w2 / self.q22
    }
}

/// Step-size policy for the update step — the §7.3 ablation knob.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OverStep {
    /// Plain truncated Newton (eq. 2).
    Newton,
    /// The "heretical" fixed over-relaxation `μ = clip(factor · l/q)`
    /// (§7.3 uses 1.1; any factor in (0,2) keeps positive gain, Fig. 2).
    OverRelaxed(f64),
}

impl OverStep {
    /// Apply the policy to a sub-problem.
    pub fn step(&self, sp: &SubProblem) -> f64 {
        match *self {
            OverStep::Newton => sp.clipped_step(),
            OverStep::OverRelaxed(f) => {
                let newton = sp.newton_step();
                if newton.is_finite() {
                    clamp(f * newton, sp.lo, sp.hi)
                } else {
                    sp.clipped_step()
                }
            }
        }
    }

    /// Was `mu` (this policy's step on `sp`) a *free* step? Newton
    /// counts interior Newton steps; over-relaxed steps count as free
    /// if uncut. One definition shared by every SMO-family engine so
    /// free/bounded telemetry stays comparable across them.
    pub fn step_is_free(&self, sp: &SubProblem, mu: f64) -> bool {
        match *self {
            OverStep::Newton => sp.is_free(),
            OverStep::OverRelaxed(_) => {
                mu.is_finite() && mu > sp.lo && mu < sp.hi && sp.q > TAU
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::forall;

    #[test]
    fn newton_and_clipping_hand_computed() {
        let sp = SubProblem { l: 2.0, q: 4.0, lo: -1.0, hi: 10.0 };
        assert_eq!(sp.newton_step(), 0.5);
        assert_eq!(sp.clipped_step(), 0.5);
        assert!(sp.is_free());
        let sp = SubProblem { hi: 0.25, ..sp };
        assert_eq!(sp.clipped_step(), 0.25);
        assert!(!sp.is_free());
    }

    #[test]
    fn degenerate_curvature_cases() {
        let sp = SubProblem { l: 1.0, q: 0.0, lo: -2.0, hi: 3.0 };
        assert_eq!(sp.newton_step(), f64::INFINITY);
        assert_eq!(sp.clipped_step(), 3.0); // linear ascent to the bound
        let sp = SubProblem { l: -1.0, ..sp };
        assert_eq!(sp.clipped_step(), -2.0);
        let sp = SubProblem { l: 0.0, ..sp };
        assert_eq!(sp.clipped_step(), 0.0);
    }

    #[test]
    fn newton_gain_matches_gain_at_newton_step() {
        let sp = SubProblem { l: 3.0, q: 1.5, lo: -100.0, hi: 100.0 };
        let mu = sp.newton_step();
        assert!((sp.gain(mu) - newton_gain(sp.l, sp.q)).abs() < 1e-12);
        // eq. (4) equivalent form: 0.5 * q * mu^2
        assert!((newton_gain(sp.l, sp.q) - 0.5 * sp.q * mu * mu).abs() < 1e-12);
    }

    #[test]
    fn gain_is_positive_iff_relative_step_in_zero_two() {
        // Paper Fig. 2: positive progress iff mu/mu* in (0, 2).
        let sp = SubProblem { l: 2.0, q: 1.0, lo: -1e9, hi: 1e9 };
        let mu_star = sp.newton_step();
        for (ratio, positive) in [
            (0.1, true),
            (0.5, true),
            (1.0, true),
            (1.9, true),
            (2.0, false),
            (2.1, false),
            (-0.1, false),
            (0.0, false),
        ] {
            let g = sp.gain(ratio * mu_star);
            assert_eq!(g > 0.0, positive, "ratio={ratio}, g={g}");
        }
    }

    #[test]
    fn eta_band_gain_bound() {
        // For mu/mu* in [1-eta, 1+eta], gain >= (1-eta^2) * newton gain.
        let eta = 0.9;
        let sp = SubProblem { l: 1.7, q: 0.6, lo: -1e9, hi: 1e9 };
        let gstar = newton_gain(sp.l, sp.q);
        let mu_star = sp.newton_step();
        for k in 0..=20 {
            let ratio = (1.0 - eta) + 2.0 * eta * (k as f64 / 20.0);
            let g = sp.gain(ratio * mu_star);
            assert!(
                g >= (1.0 - eta * eta) * gstar - 1e-12,
                "ratio={ratio}: {g} < {}",
                (1.0 - eta * eta) * gstar
            );
        }
    }

    #[test]
    fn planning_step_recovers_exact_2d_optimum() {
        // Solve max w.mu - 0.5 mu^T Q mu exactly and compare: the planned
        // first step followed by the greedy second step must land on the
        // unconstrained optimizer of the 2-variable problem.
        let ps = PlanningSystem { w1: 1.0, w2: 0.5, q11: 2.0, q12: 0.8, q22: 1.5 };
        let mu1 = ps.planning_step().unwrap();
        let mu2 = ps.second_step(mu1);
        // optimum: Q [mu1 mu2]^T = [w1 w2]^T
        assert!((ps.q11 * mu1 + ps.q12 * mu2 - ps.w1).abs() < 1e-12);
        assert!((ps.q12 * mu1 + ps.q22 * mu2 - ps.w2).abs() < 1e-12);
    }

    #[test]
    fn double_step_gain_formula_matches_quadratic_form() {
        forall(
            "double-step-gain-eq7",
            200,
            |g| PlanningSystem {
                w1: g.normal() * 2.0,
                w2: g.normal() * 2.0,
                // random PSD 2x2: A^T A
                q11: 0.0,
                q12: 0.0,
                q22: 0.0,
            }
            .into_psd(g),
            |ps| {
                if ps.q22 <= TAU || ps.det() <= 1e-9 {
                    return Ok(()); // degenerate draws are skipped
                }
                for mu1 in [-1.5, -0.3, 0.0, 0.4, 1.0, 2.5] {
                    let mu2 = ps.second_step(mu1);
                    let direct = ps.w1 * mu1 + ps.w2 * mu2
                        - 0.5
                            * (ps.q11 * mu1 * mu1
                                + 2.0 * ps.q12 * mu1 * mu2
                                + ps.q22 * mu2 * mu2);
                    let via_eq7 = ps.double_step_gain(mu1);
                    if (direct - via_eq7).abs() > 1e-9 * (1.0 + direct.abs()) {
                        return Err(format!("mu1={mu1}: {direct} vs {via_eq7}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn planning_step_maximizes_double_gain() {
        let ps = PlanningSystem { w1: -0.7, w2: 1.9, q11: 3.0, q12: -1.1, q22: 2.0 };
        let mu_opt = ps.planning_step().unwrap();
        let g_opt = ps.double_step_gain(mu_opt);
        for d in [-0.5, -0.1, 0.1, 0.5] {
            assert!(ps.double_step_gain(mu_opt + d) < g_opt + 1e-12);
        }
        // and it beats the greedy (Newton-first) choice whenever Q12 != 0
        let greedy = ps.w1 / ps.q11;
        assert!(g_opt >= ps.double_step_gain(greedy) - 1e-12);
    }

    #[test]
    fn planning_degenerate_returns_none() {
        // identical working sets: Q12 = Q11 = Q22 -> det = 0
        let ps = PlanningSystem { w1: 1.0, w2: 1.0, q11: 2.0, q12: 2.0, q22: 2.0 };
        assert!(ps.planning_step().is_none());
        let ps = PlanningSystem { q22: 0.0, ..ps };
        assert!(ps.planning_step().is_none());
    }

    #[test]
    fn over_relaxed_policy() {
        let sp = SubProblem { l: 2.0, q: 1.0, lo: -10.0, hi: 10.0 };
        assert_eq!(OverStep::Newton.step(&sp), 2.0);
        assert!((OverStep::OverRelaxed(1.1).step(&sp) - 2.2).abs() < 1e-12);
        // clipping still applies
        let sp = SubProblem { hi: 2.1, ..sp };
        assert_eq!(OverStep::OverRelaxed(1.1).step(&sp), 2.1);
        // degenerate curvature falls back to the SMO step
        let sp = SubProblem { l: 1.0, q: 0.0, lo: -1.0, hi: 1.0 };
        assert_eq!(OverStep::OverRelaxed(1.1).step(&sp), 1.0);
    }

    impl PlanningSystem {
        /// Test helper: fill Q with a random PSD matrix AᵀA.
        fn into_psd(mut self, g: &mut crate::util::prng::Pcg) -> PlanningSystem {
            let (a, b, c, d) = (g.normal(), g.normal(), g.normal(), g.normal());
            self.q11 = a * a + c * c;
            self.q12 = a * b + c * d;
            self.q22 = b * b + d * d;
            self
        }
    }
}
