//! The shared batch scoring engine — one decision-function core for
//! every model kind.
//!
//! Every trained model in this crate predicts through the same kernel
//! expansion `f(x) = Σ_s coef_s · k(x_s, x) + offset`; only the
//! coefficients and the offset differ (C-SVC bias, ε-SVR bias,
//! one-class `−ρ`, one machine per class pair for OvO). [`Scorer`]
//! evaluates that expansion for a whole query batch in blocked SV×query
//! tiles on the same [`crate::kernel::tile`] primitives the training
//! side uses for Gram rows:
//!
//! * support vectors stay in the support [`Dataset`]'s own storage —
//!   dense row-major or CSR sparse, whichever the model trained on;
//!   queries are scored against L2-sized SV blocks so a support row is
//!   streamed from memory once per query *chunk*, not once per query;
//! * within a block the 4-wide tiled dot loop of
//!   [`crate::kernel::tile::kernel_block`] runs with per-entry f64
//!   accumulation in feature order — batch results are **bit-identical**
//!   to scoring one query at a time, and threaded chunks
//!   ([`crate::kernel::tile::chunked`] over disjoint query ranges) are
//!   bit-identical to single-threaded runs;
//! * for the linear kernel the expansion collapses to the primal weight
//!   vector `w = Σ_s coef_s · x_s`, making a query cost O(d) instead of
//!   O(n_sv · d) with zero kernel evaluations (disable with
//!   [`Scorer::collapse_linear`] to force the expansion path).
//!
//! RBF values use the `‖a‖²+‖b‖²−2a·b` decomposition (the Gram-row fast
//! path), which differs from the direct `exp(−γ‖a−b‖²)` evaluation only
//! in the last floating-point bits; the dot-product kernels
//! (linear/poly/sigmoid) are bit-identical to [`KernelFunction::eval`].
//!
//! ## Opt-in packed-f32 fast path
//!
//! [`Scorer::with_f32_sv`] switches the SV×query dot products from the
//! f64 accumulator to a deterministic eight-lane f32 accumulation
//! (features are stored as f32 anyway, so the operands are exact; only
//! the accumulation precision drops). This is an *approximate* path —
//! decisions can differ from the f64 tile in the low bits — so it is
//! opt-in and meant to be gated by [`Scorer::f32_sv_max_delta`], which
//! measures the worst decision-value disagreement over the model's own
//! support vectors. Dense support × dense query only: any CSR side
//! keeps the exact f64 merged dot, and the linear primal collapse
//! (already O(d) with zero kernel entries) always wins over the flag.

use std::borrow::Cow;

use crate::data::dataset::Dataset;
use crate::data::features::{Features, Row};
use crate::kernel::function::KernelFunction;
use crate::kernel::tile;

/// Support rows per SV×query tile block. A block of `SV_BLOCK · d` f32
/// features is revisited by every query of a chunk, so it is sized to
/// stay cache-resident for the dimensions the suite uses
/// (512 rows × 64 dims × 4 B = 128 KiB).
const SV_BLOCK: usize = 512;

/// Where a batch's query rows come from: a raw row-major f32 block (the
/// wire/scratch shape) or a [`Features`] matrix in either backend. Both
/// yield [`Row`] views, so the scoring loops below are written once.
#[derive(Clone, Copy)]
enum QuerySrc<'q> {
    /// Row-major dense block: query `q` is `rows[q·dim..(q+1)·dim]`.
    Raw {
        /// Query dimension.
        dim: usize,
        /// Row-major query block.
        rows: &'q [f32],
    },
    /// Queries are the rows of a feature matrix (dense or CSR).
    Feats(&'q Features),
}

impl<'q> QuerySrc<'q> {
    #[inline]
    fn row(&self, q: usize) -> Row<'q> {
        match *self {
            QuerySrc::Raw { dim, rows } => Row::Dense(&rows[q * dim..(q + 1) * dim]),
            QuerySrc::Feats(f) => f.row(q),
        }
    }
}

/// Batch decision-function evaluator over a borrowed support set.
///
/// Construction precomputes the support-side invariants (RBF squared
/// norms, the collapsed linear `w`), so build it once per batch — the
/// model types expose a `scorer()` method doing exactly that.
///
/// ```
/// use pasmo::svm::Trainer;
/// let data = std::sync::Arc::new(pasmo::data::synth::chessboard(150, 4, 1));
/// let model = Trainer::rbf(10.0, 0.5).train(&data).model;
/// let scorer = model.scorer().with_threads(2);
/// let decisions = scorer.decision_values(&data);
/// assert_eq!(decisions.len(), data.len());
/// assert_eq!(decisions[0], model.decision(data.row(0)));
/// ```
#[derive(Debug, Clone)]
pub struct Scorer<'m> {
    kernel: KernelFunction,
    support: &'m Dataset,
    coef: &'m [f64],
    offset: f64,
    /// ‖x_s‖² per support row (RBF only; empty otherwise). Owned by
    /// [`Scorer::new`], borrowed from a [`SupportInvariants`] by
    /// [`Scorer::with_invariants`].
    sv_sqnorms: Cow<'m, [f64]>,
    /// Collapsed primal weights for the linear kernel (None = expansion).
    w: Option<Cow<'m, [f64]>>,
    threads: usize,
    /// Opt-in packed-f32 dot accumulation (dense×dense pairs only; see
    /// the module docs). Off by default — the exact f64 tile.
    f32_sv: bool,
}

/// Deterministic packed-f32 dot: eight fixed strided accumulators over
/// `chunks_exact(8)`, a fixed tree reduction, then the scalar tail.
/// No reassociation is left to the compiler — the result is identical
/// at every optimization level — while the fixed 8-lane stride maps
/// directly onto 8-wide f32 SIMD, which is where the ~2× width win
/// over the 4-wide f64 tile comes from.
#[inline]
fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    let mut acc = [0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for l in 0..8 {
            acc[l] += xa[l] * xb[l];
        }
    }
    let mut tail = 0f32;
    for (xa, xb) in ca.remainder().iter().zip(cb.remainder()) {
        tail += xa * xb;
    }
    (((acc[0] + acc[4]) + (acc[2] + acc[6])) + ((acc[1] + acc[5]) + (acc[3] + acc[7]))) + tail
}

/// Collapsed primal weights `w = Σ_s coef_s · x_s` for the linear
/// kernel, accumulated per-row in support order. Dense support rows
/// visit every coordinate (the historical loop); sparse rows accumulate
/// only their stored entries.
fn linear_w(support: &Dataset, coef: &[f64]) -> Vec<f64> {
    let mut w = vec![0f64; support.dim()];
    for s in 0..support.len() {
        let c = coef[s];
        support
            .row_ref(s)
            .for_each_entry(|idx, v| w[idx as usize] += c * v as f64);
    }
    w
}

/// Precomputed support-side invariants of one kernel expansion — the
/// RBF squared norms and the collapsed linear `w` that [`Scorer::new`]
/// otherwise recomputes on every construction.
///
/// A long-lived owner (the serving tier's model registry) computes them
/// once per loaded model and builds per-batch scorers with
/// [`Scorer::with_invariants`], so constructing a scorer in a hot loop
/// allocates nothing and the resulting decision values are bit-identical
/// to the owned construction (same values, same association order).
#[derive(Debug, Clone)]
pub struct SupportInvariants {
    sv_sqnorms: Vec<f64>,
    w: Option<Vec<f64>>,
}

impl SupportInvariants {
    /// Compute the invariants `Scorer::new(kernel, support, coef, _)`
    /// would compute internally.
    pub fn compute(
        kernel: KernelFunction,
        support: &Dataset,
        coef: &[f64],
    ) -> SupportInvariants {
        assert_eq!(
            support.len(),
            coef.len(),
            "support rows and coefficients must align"
        );
        let sv_sqnorms = match kernel {
            KernelFunction::Rbf { .. } => tile::squared_norms(support),
            _ => Vec::new(),
        };
        let w = match kernel {
            KernelFunction::Linear => Some(linear_w(support, coef)),
            _ => None,
        };
        SupportInvariants { sv_sqnorms, w }
    }
}

impl<'m> Scorer<'m> {
    /// Scorer over `support`/`coef` computing
    /// `f(x) = Σ_s coef[s]·k(support[s], x) + offset`. The linear kernel
    /// is collapsed to its primal weight vector by default.
    pub fn new(
        kernel: KernelFunction,
        support: &'m Dataset,
        coef: &'m [f64],
        offset: f64,
    ) -> Scorer<'m> {
        assert_eq!(
            support.len(),
            coef.len(),
            "support rows and coefficients must align"
        );
        let sv_sqnorms = match kernel {
            KernelFunction::Rbf { .. } => tile::squared_norms(support),
            _ => Vec::new(),
        };
        let mut s = Scorer {
            kernel,
            support,
            coef,
            offset,
            sv_sqnorms: Cow::Owned(sv_sqnorms),
            w: None,
            threads: 1,
            f32_sv: false,
        };
        s = s.collapse_linear(true);
        s
    }

    /// Like [`Scorer::new`] but borrowing support-side invariants
    /// precomputed by [`SupportInvariants::compute`] for this exact
    /// `(kernel, support, coef)` triple, instead of recomputing them —
    /// the zero-allocation construction the serving tier's batch loop
    /// uses once per micro-batch. Decision values are bit-identical to
    /// the owned construction.
    pub fn with_invariants(
        kernel: KernelFunction,
        support: &'m Dataset,
        coef: &'m [f64],
        offset: f64,
        inv: &'m SupportInvariants,
    ) -> Scorer<'m> {
        assert_eq!(
            support.len(),
            coef.len(),
            "support rows and coefficients must align"
        );
        if matches!(kernel, KernelFunction::Rbf { .. }) {
            assert_eq!(
                inv.sv_sqnorms.len(),
                support.len(),
                "invariants were computed for a different support set"
            );
        }
        Scorer {
            kernel,
            support,
            coef,
            offset,
            sv_sqnorms: Cow::Borrowed(&inv.sv_sqnorms),
            w: inv.w.as_deref().map(Cow::Borrowed),
            threads: 1,
            f32_sv: false,
        }
    }

    /// Worker threads for batch scoring (0/1 = inline). Threaded batches
    /// are bit-identical to single-threaded ones — threads only chunk
    /// the query range.
    pub fn with_threads(mut self, threads: usize) -> Scorer<'m> {
        self.threads = threads.max(1);
        self
    }

    /// Enable/disable the linear-kernel collapse to the primal `w`
    /// (enabled by default; a no-op for non-linear kernels). The
    /// collapsed path reorders the floating-point reduction, so values
    /// can differ from the expansion in the last bits.
    pub fn collapse_linear(mut self, enabled: bool) -> Scorer<'m> {
        self.w = match (enabled, self.kernel) {
            (true, KernelFunction::Linear) => {
                Some(Cow::Owned(linear_w(self.support, self.coef)))
            }
            _ => None,
        };
        self
    }

    /// Opt into (or out of) the packed-f32 SV dot accumulation for
    /// dense×dense pairs (module docs). Approximate — gate it with
    /// [`Scorer::f32_sv_max_delta`] before serving traffic through it.
    /// CSR pairings keep the exact f64 merged dot, and the linear
    /// primal collapse always wins over this flag.
    pub fn with_f32_sv(mut self, on: bool) -> Scorer<'m> {
        self.f32_sv = on;
        self
    }

    /// Is the packed-f32 fast path enabled?
    pub fn is_f32_sv(&self) -> bool {
        self.f32_sv
    }

    /// The accuracy-delta gate for the packed-f32 path: score the
    /// model's **own support vectors** through the exact f64 tile and
    /// through the f32 path, and return the worst absolute
    /// decision-value disagreement. The support set brackets the data
    /// distribution the model was trained on, so this is a cheap,
    /// deterministic proxy for the expansion's sensitivity to the
    /// reduced accumulator — callers compare it against a tolerance
    /// scaled to their decision margins before enabling the path.
    /// Returns 0.0 for collapsed or empty expansions.
    pub fn f32_sv_max_delta(&self) -> f64 {
        if self.is_collapsed() || self.n_sv() == 0 {
            return 0.0;
        }
        let exact = self.clone().with_f32_sv(false).decision_values(self.support);
        let fast = self.clone().with_f32_sv(true).decision_values(self.support);
        exact
            .iter()
            .zip(&fast)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// The kernel this scorer evaluates.
    pub fn kernel(&self) -> KernelFunction {
        self.kernel
    }

    /// Number of support vectors in the expansion.
    pub fn n_sv(&self) -> usize {
        self.coef.len()
    }

    /// The constant added to every decision value (bias, or −ρ).
    pub fn offset(&self) -> f64 {
        self.offset
    }

    /// Is the linear collapse active (queries cost O(d), zero kernel
    /// evaluations)?
    pub fn is_collapsed(&self) -> bool {
        self.w.is_some()
    }

    /// Kernel entries one full pass over `queries` rows evaluates:
    /// `queries · n_sv` for the expansion, 0 for the collapsed linear
    /// path — the inference-side analogue of the solver's kernel-work
    /// meter.
    pub fn kernel_entries_per_pass(&self, queries: usize) -> u64 {
        if self.is_collapsed() {
            0
        } else {
            queries as u64 * self.n_sv() as u64
        }
    }

    /// Decision value of a single query (the batch path at batch size 1
    /// — bit-identical to the same query inside any batch).
    pub fn decision(&self, x: &[f32]) -> f64 {
        let mut out = [0f64];
        self.decision_block(x.len(), x, &mut out);
        out[0]
    }

    /// Decision values for every row of a dataset, in the dataset's own
    /// storage backend — CSR queries are scored without densification.
    pub fn decision_values(&self, data: &Dataset) -> Vec<f64> {
        assert_eq!(data.dim(), self.support.dim(), "query dim != support dim");
        let mut out = vec![0f64; data.len()];
        self.decide(QuerySrc::Feats(data.storage()), &mut out);
        out
    }

    /// Decision values for `out.len()` row-major `dim`-dimensional query
    /// rows — the raw batch entry point for wire/scratch-shaped queries.
    pub fn decision_block(&self, dim: usize, rows: &[f32], out: &mut [f64]) {
        assert_eq!(dim, self.support.dim(), "query dim != support dim");
        assert_eq!(rows.len(), out.len() * dim, "rows/out length mismatch");
        self.decide(QuerySrc::Raw { dim, rows }, out);
    }

    /// The one batch loop behind [`Scorer::decision_values`] and
    /// [`Scorer::decision_block`] — results are bit-identical for the
    /// same logical queries regardless of source shape or backend.
    fn decide(&self, src: QuerySrc<'_>, out: &mut [f64]) {
        if out.is_empty() {
            return;
        }
        let dim = self.support.dim();
        if let Some(w) = &self.w {
            let workers = tile::workers_for(self.threads, out.len(), dim);
            let offset = self.offset;
            let w = &w[..];
            tile::chunked(workers, out, |base, chunk| {
                for (q, o) in chunk.iter_mut().enumerate() {
                    // Dense queries visit every coordinate in order (the
                    // historical w·x loop); sparse queries visit stored
                    // entries only.
                    let mut f = 0f64;
                    src.row(base + q)
                        .for_each_entry(|idx, v| f += w[idx as usize] * v as f64);
                    *o = f + offset;
                }
            });
            return;
        }
        let workers = tile::workers_for(
            self.threads,
            out.len().saturating_mul(self.n_sv()),
            dim,
        )
        .min(out.len());
        tile::chunked(workers, out, |base, chunk| self.score_chunk(src, base, chunk));
    }

    /// Score every row pushed into `scratch` since its last
    /// [`ScoreScratch::reset`], returning the decision values in push
    /// order. This **is** [`Scorer::decision_block`] over the scratch's
    /// row buffer — results are bit-identical to any other batch shape —
    /// but both the query rows and the output live in the caller's
    /// scratch, so a loop calling this once per micro-batch performs
    /// zero steady-state allocation.
    pub fn decision_scratch<'s>(&self, scratch: &'s mut ScoreScratch) -> &'s [f64] {
        let n = scratch.len();
        scratch.out.clear();
        if n == 0 {
            return &scratch.out;
        }
        scratch.out.resize(n, 0.0);
        self.decision_block(scratch.dim, &scratch.rows, &mut scratch.out);
        &scratch.out
    }

    /// Score one contiguous query chunk through blocked SV×query tiles.
    /// Each query's value threads through the blocks as one running f64
    /// (`f = offset; f += coef_s·k_s` in ascending SV order — blocks in
    /// order, entries within a block in order), exactly the association
    /// order of the scalar per-SV loop: chunking and blocking never
    /// change a result bit.
    fn score_chunk(&self, src: QuerySrc<'_>, base: usize, out: &mut [f64]) {
        for o in out.iter_mut() {
            *o = self.offset;
        }
        let n_sv = self.coef.len();
        let rbf = matches!(self.kernel, KernelFunction::Rbf { .. });
        // Multi-block passes revisit every query once per SV block, so
        // hoist the query norms to one computation per chunk. The
        // single-block case — the serving tier's steady state — keeps
        // the inline computation and its zero-allocation property.
        let qnorms: Vec<f64> = if rbf && n_sv > SV_BLOCK {
            (0..out.len()).map(|q| src.row(base + q).sqnorm()).collect()
        } else {
            Vec::new()
        };
        let mut s0 = 0usize;
        while s0 < n_sv {
            let block = (n_sv - s0).min(SV_BLOCK);
            for (q, o) in out.iter_mut().enumerate() {
                let x = src.row(base + q);
                let nq = if rbf {
                    if qnorms.is_empty() {
                        x.sqnorm()
                    } else {
                        qnorms[q]
                    }
                } else {
                    0.0
                };
                if self.f32_sv {
                    if let (Row::Dense(xq), Features::Dense { .. }) = (x, self.support.storage())
                    {
                        *o = self.score_block_f32(xq, nq, s0, block, *o);
                        continue;
                    }
                }
                let mut f = *o;
                tile::kernel_block(
                    self.kernel,
                    x,
                    nq,
                    &self.sv_sqnorms,
                    self.support,
                    &|p| p,
                    s0,
                    block,
                    |p, v| f += self.coef[s0 + p] * v,
                );
                *o = f;
            }
            s0 += block;
        }
    }

    /// One query against one SV block through the packed-f32 dot — the
    /// same kernel maps as [`tile::kernel_block`] (RBF via the
    /// `‖a‖²+‖b‖²−2a·b` decomposition with f64 norms), only the dot
    /// accumulation differs. SV order, and therefore the coefficient
    /// association order, matches the exact path.
    fn score_block_f32(&self, xq: &[f32], nq: f64, s0: usize, block: usize, init: f64) -> f64 {
        let mut f = init;
        for p in 0..block {
            let dot = dot_f32(xq, self.support.row(s0 + p)) as f64;
            let v = match self.kernel {
                KernelFunction::Rbf { gamma } => {
                    (-gamma * (nq + self.sv_sqnorms[s0 + p] - 2.0 * dot).max(0.0)).exp()
                }
                KernelFunction::Linear => dot,
                KernelFunction::Poly { gamma, coef0, degree } => {
                    (gamma * dot + coef0).powi(degree as i32)
                }
                KernelFunction::Sigmoid { gamma, coef0 } => (gamma * dot + coef0).tanh(),
            };
            f += self.coef[s0 + p] * v;
        }
        f
    }
}

/// Reusable query-side buffers for [`Scorer::decision_scratch`].
///
/// The serving tier's batch loop scores an unbounded stream of
/// micro-batches; pushing each batch's rows into one long-lived scratch
/// means the steady state allocates nothing — the row and output
/// vectors grow to the high-water mark once and are reused thereafter.
///
/// ```
/// use pasmo::svm::Trainer;
/// use pasmo::svm::scorer::ScoreScratch;
/// let data = std::sync::Arc::new(pasmo::data::synth::chessboard(120, 4, 1));
/// let model = Trainer::rbf(10.0, 0.5).train(&data).model;
/// let scorer = model.scorer();
/// let mut scratch = ScoreScratch::new();
/// scratch.reset(data.dim());
/// scratch.push(data.row(0));
/// scratch.push(data.row(1));
/// let out = scorer.decision_scratch(&mut scratch);
/// assert_eq!(out.len(), 2);
/// assert_eq!(out[0], model.decision(data.row(0)));
/// ```
#[derive(Debug, Clone, Default)]
pub struct ScoreScratch {
    dim: usize,
    rows: Vec<f32>,
    out: Vec<f64>,
}

impl ScoreScratch {
    /// An empty scratch; buffers grow on first use.
    pub fn new() -> ScoreScratch {
        ScoreScratch::default()
    }

    /// Drop the pushed rows and fix the query dimensionality for the
    /// next batch. Buffer capacity is kept.
    pub fn reset(&mut self, dim: usize) {
        assert!(dim > 0, "query dim must be positive");
        self.dim = dim;
        self.rows.clear();
    }

    /// Append one query row (length must match the [`reset`] dim).
    ///
    /// [`reset`]: ScoreScratch::reset
    pub fn push(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.dim, "query dim != scratch dim");
        self.rows.extend_from_slice(x);
    }

    /// Rows pushed since the last reset.
    pub fn len(&self) -> usize {
        if self.dim == 0 {
            0
        } else {
            self.rows.len() / self.dim
        }
    }

    /// No rows pushed?
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    /// Random kernel expansion: support rows, coefficients, offset.
    fn random_expansion(n_sv: usize, d: usize, seed: u64) -> (Dataset, Vec<f64>, f64) {
        let mut rng = Pcg::new(seed);
        let mut sv = Dataset::with_dim(d);
        let mut row = vec![0f32; d];
        let mut coef = Vec::with_capacity(n_sv);
        for _ in 0..n_sv {
            row.iter_mut().for_each(|v| *v = rng.normal() as f32);
            sv.push(&row, 1);
            coef.push(rng.normal() * 2.0);
        }
        (sv, coef, rng.normal())
    }

    fn random_queries(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        (0..n * d).map(|_| rng.normal() as f32).collect()
    }

    /// The legacy per-example loop every model used before the scorer.
    fn legacy_decision(
        kernel: KernelFunction,
        sv: &Dataset,
        coef: &[f64],
        offset: f64,
        x: &[f32],
    ) -> f64 {
        let mut f = offset;
        for s in 0..sv.len() {
            f += coef[s] * kernel.eval(sv.row(s), x);
        }
        f
    }

    const KERNELS: [KernelFunction; 4] = [
        KernelFunction::Rbf { gamma: 0.7 },
        KernelFunction::Linear,
        KernelFunction::Poly { gamma: 0.3, coef0: 1.0, degree: 2 },
        KernelFunction::Sigmoid { gamma: 0.2, coef0: 0.1 },
    ];

    /// The ≤1e-12 agreement bound, conditioned on the expansion's
    /// magnitude: per-term rounding differences (RBF decomposition vs
    /// direct ‖a−b‖², collapsed vs expanded linear reduction) accumulate
    /// with the ℓ1 coefficient mass, so that mass is the natural scale.
    fn tol(coef: &[f64], want: f64) -> f64 {
        1e-12 * (1.0 + want.abs() + coef.iter().map(|c| c.abs()).sum::<f64>())
    }

    #[test]
    fn batch_matches_legacy_loop_within_1e12() {
        for (ki, kernel) in KERNELS.into_iter().enumerate() {
            let (sv, coef, offset) = random_expansion(57, 5, 10 + ki as u64);
            let scorer = Scorer::new(kernel, &sv, &coef, offset);
            let queries = random_queries(23, 5, 99);
            let mut out = vec![0f64; 23];
            scorer.decision_block(5, &queries, &mut out);
            for q in 0..23 {
                let x = &queries[q * 5..(q + 1) * 5];
                let want = legacy_decision(kernel, &sv, &coef, offset, x);
                assert!(
                    (out[q] - want).abs() <= tol(&coef, want),
                    "{kernel:?} q={q}: {} vs {want}",
                    out[q]
                );
            }
        }
    }

    #[test]
    fn dot_kernels_are_bit_identical_to_legacy_loop() {
        // Linear (collapse disabled), poly, sigmoid share the exact
        // f64 dot of KernelFunction::eval — bitwise equality holds.
        for kernel in [
            KernelFunction::Linear,
            KernelFunction::Poly { gamma: 0.3, coef0: 1.0, degree: 2 },
            KernelFunction::Sigmoid { gamma: 0.2, coef0: 0.1 },
        ] {
            let (sv, coef, offset) = random_expansion(41, 7, 21);
            let scorer = Scorer::new(kernel, &sv, &coef, offset).collapse_linear(false);
            assert!(!scorer.is_collapsed());
            let queries = random_queries(17, 7, 22);
            let mut out = vec![0f64; 17];
            scorer.decision_block(7, &queries, &mut out);
            for q in 0..17 {
                let x = &queries[q * 7..(q + 1) * 7];
                let want = legacy_decision(kernel, &sv, &coef, offset, x);
                assert_eq!(out[q].to_bits(), want.to_bits(), "{kernel:?} q={q}");
            }
        }
    }

    #[test]
    fn single_query_is_bit_identical_to_batch() {
        for kernel in KERNELS {
            let (sv, coef, offset) = random_expansion(33, 4, 31);
            let scorer = Scorer::new(kernel, &sv, &coef, offset);
            let queries = random_queries(11, 4, 32);
            let mut batch = vec![0f64; 11];
            scorer.decision_block(4, &queries, &mut batch);
            for q in 0..11 {
                let one = scorer.decision(&queries[q * 4..(q + 1) * 4]);
                assert_eq!(one.to_bits(), batch[q].to_bits(), "{kernel:?} q={q}");
            }
        }
    }

    #[test]
    fn threaded_batches_are_bit_identical() {
        // queries · n_sv · d clears the threading threshold
        let (sv, coef, offset) = random_expansion(300, 30, 41);
        for kernel in KERNELS {
            let scorer = Scorer::new(kernel, &sv, &coef, offset);
            let queries = random_queries(90, 30, 42);
            let mut one = vec![0f64; 90];
            scorer.decision_block(30, &queries, &mut one);
            let threaded = scorer.clone().with_threads(4);
            let mut four = vec![0f64; 90];
            threaded.decision_block(30, &queries, &mut four);
            assert!(
                one.iter().zip(&four).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{kernel:?} diverges across thread counts"
            );
        }
    }

    #[test]
    fn sv_blocking_covers_more_than_one_block() {
        // n_sv > SV_BLOCK exercises the multi-block accumulation order.
        let (sv, coef, offset) = random_expansion(SV_BLOCK + 77, 3, 51);
        let kernel = KernelFunction::Rbf { gamma: 0.5 };
        let scorer = Scorer::new(kernel, &sv, &coef, offset);
        let queries = random_queries(5, 3, 52);
        let mut out = vec![0f64; 5];
        scorer.decision_block(3, &queries, &mut out);
        for q in 0..5 {
            let x = &queries[q * 3..(q + 1) * 3];
            let want = legacy_decision(kernel, &sv, &coef, offset, x);
            assert!((out[q] - want).abs() <= tol(&coef, want), "q={q}");
        }
    }

    #[test]
    fn linear_collapse_matches_expansion_and_counts_zero_entries() {
        let (sv, coef, offset) = random_expansion(64, 6, 61);
        let collapsed = Scorer::new(KernelFunction::Linear, &sv, &coef, offset);
        assert!(collapsed.is_collapsed());
        assert_eq!(collapsed.kernel_entries_per_pass(10), 0);
        let expansion = collapsed.clone().collapse_linear(false);
        assert!(!expansion.is_collapsed());
        assert_eq!(expansion.kernel_entries_per_pass(10), 640);
        let queries = random_queries(19, 6, 62);
        let (mut a, mut b) = (vec![0f64; 19], vec![0f64; 19]);
        collapsed.decision_block(6, &queries, &mut a);
        expansion.decision_block(6, &queries, &mut b);
        for q in 0..19 {
            assert!(
                (a[q] - b[q]).abs() <= tol(&coef, b[q]),
                "q={q}: collapsed {} vs expansion {}",
                a[q],
                b[q]
            );
        }
    }

    #[test]
    fn empty_support_scores_the_offset() {
        let sv = Dataset::with_dim(3);
        let coef: Vec<f64> = Vec::new();
        for kernel in KERNELS {
            let scorer = Scorer::new(kernel, &sv, &coef, 0.75);
            assert_eq!(scorer.n_sv(), 0);
            assert_eq!(scorer.decision(&[1.0, 2.0, 3.0]), 0.75);
        }
    }

    #[test]
    fn empty_query_batch_is_a_no_op() {
        let (sv, coef, offset) = random_expansion(5, 2, 71);
        let scorer = Scorer::new(KernelFunction::Rbf { gamma: 1.0 }, &sv, &coef, offset);
        let mut out: Vec<f64> = Vec::new();
        scorer.decision_block(2, &[], &mut out);
    }

    #[test]
    fn with_invariants_is_bit_identical_to_owned_construction() {
        for kernel in KERNELS {
            let (sv, coef, offset) = random_expansion(48, 5, 91);
            let inv = SupportInvariants::compute(kernel, &sv, &coef);
            let owned = Scorer::new(kernel, &sv, &coef, offset);
            let borrowed = Scorer::with_invariants(kernel, &sv, &coef, offset, &inv);
            assert_eq!(owned.is_collapsed(), borrowed.is_collapsed());
            let queries = random_queries(13, 5, 92);
            let (mut a, mut b) = (vec![0f64; 13], vec![0f64; 13]);
            owned.decision_block(5, &queries, &mut a);
            borrowed.decision_block(5, &queries, &mut b);
            assert!(
                a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "{kernel:?}: invariant-borrowing scorer diverges"
            );
        }
    }

    #[test]
    fn decision_scratch_is_bit_identical_and_reuses_capacity() {
        let (sv, coef, offset) = random_expansion(37, 4, 95);
        let scorer = Scorer::new(KernelFunction::Rbf { gamma: 0.9 }, &sv, &coef, offset);
        let queries = random_queries(12, 4, 96);
        let mut want = vec![0f64; 12];
        scorer.decision_block(4, &queries, &mut want);

        let mut scratch = ScoreScratch::new();
        // Warm the buffers once, then assert later batches never grow them.
        scratch.reset(4);
        for q in 0..12 {
            scratch.push(&queries[q * 4..(q + 1) * 4]);
        }
        assert_eq!(scratch.len(), 12);
        let got: Vec<f64> = scorer.decision_scratch(&mut scratch).to_vec();
        assert!(got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));

        let (rows_cap, out_cap) = (scratch.rows.capacity(), scratch.out.capacity());
        for _ in 0..3 {
            scratch.reset(4);
            for q in 0..12 {
                scratch.push(&queries[q * 4..(q + 1) * 4]);
            }
            let again = scorer.decision_scratch(&mut scratch);
            assert!(again.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
        assert_eq!(scratch.rows.capacity(), rows_cap, "rows reallocated");
        assert_eq!(scratch.out.capacity(), out_cap, "out reallocated");

        // An empty batch is fine and returns an empty slice.
        scratch.reset(4);
        assert!(scratch.is_empty());
        assert!(scorer.decision_scratch(&mut scratch).is_empty());
    }

    #[test]
    fn sparse_support_and_queries_match_dense_bitwise() {
        // Expansion with exact zeros in the support rows and queries, so
        // the sparse backends actually skip terms.
        let mut rng = Pcg::new(103);
        let mut sv = Dataset::with_dim(8);
        let mut row = vec![0f32; 8];
        let mut coef = Vec::new();
        for _ in 0..45 {
            row.iter_mut().for_each(|v| {
                *v = if rng.bernoulli(0.3) { rng.normal() as f32 } else { 0.0 }
            });
            sv.push(&row, 1);
            coef.push(rng.normal() * 2.0);
        }
        let offset = rng.normal();
        let sv_sparse = sv.to_sparse();
        let mut queries = Dataset::with_dim(8);
        for _ in 0..14 {
            row.iter_mut().for_each(|v| {
                *v = if rng.bernoulli(0.3) { rng.normal() as f32 } else { 0.0 }
            });
            queries.push(&row, 1);
        }
        let q_sparse = queries.to_sparse();
        for kernel in KERNELS {
            let dense_scorer = Scorer::new(kernel, &sv, &coef, offset);
            let sparse_scorer = Scorer::new(kernel, &sv_sparse, &coef, offset);
            let want = dense_scorer.decision_values(&queries);
            for (name, got) in [
                ("sparse SVs", sparse_scorer.decision_values(&queries)),
                ("sparse queries", dense_scorer.decision_values(&q_sparse)),
                ("sparse both", sparse_scorer.decision_values(&q_sparse)),
            ] {
                assert!(
                    want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{kernel:?} {name} diverges from the dense run"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "query dim != support dim")]
    fn dimension_mismatch_is_rejected() {
        let (sv, coef, offset) = random_expansion(5, 3, 81);
        let scorer = Scorer::new(KernelFunction::Linear, &sv, &coef, offset);
        scorer.decision(&[1.0, 2.0]);
    }

    #[test]
    fn rbf_multiblock_norm_hoist_is_bit_identical_across_query_backends() {
        // n_sv > SV_BLOCK takes the hoisted-qnorm path; dense and CSR
        // query sources must produce the same bits (Row::sqnorm is
        // bit-identical across backends), and both must stay within the
        // legacy tolerance.
        let mut rng = Pcg::new(131);
        let mut sv = Dataset::with_dim(6);
        let mut row = vec![0f32; 6];
        let mut coef = Vec::new();
        for _ in 0..SV_BLOCK + 33 {
            row.iter_mut().for_each(|v| *v = rng.normal() as f32);
            sv.push(&row, 1);
            coef.push(rng.normal());
        }
        let kernel = KernelFunction::Rbf { gamma: 0.4 };
        let scorer = Scorer::new(kernel, &sv, &coef, 0.25);
        let mut queries = Dataset::with_dim(6);
        for _ in 0..9 {
            row.iter_mut().for_each(|v| {
                *v = if rng.bernoulli(0.5) { rng.normal() as f32 } else { 0.0 }
            });
            queries.push(&row, 1);
        }
        let dense = scorer.decision_values(&queries);
        let sparse = scorer.decision_values(&queries.to_sparse());
        assert!(
            dense.iter().zip(&sparse).all(|(a, b)| a.to_bits() == b.to_bits()),
            "hoisted norms diverge across query backends"
        );
        for q in 0..queries.len() {
            let want = legacy_decision(kernel, &sv, &coef, 0.25, queries.row(q));
            assert!((dense[q] - want).abs() <= tol(&coef, want), "q={q}");
        }
    }

    #[test]
    fn f32_sv_path_tracks_the_exact_tile_within_the_gate() {
        for kernel in KERNELS {
            let (sv, coef, offset) = random_expansion(53, 19, 141);
            let exact = Scorer::new(kernel, &sv, &coef, offset).collapse_linear(false);
            let fast = exact.clone().with_f32_sv(true);
            assert!(fast.is_f32_sv() && !exact.is_f32_sv());
            let delta = fast.f32_sv_max_delta();
            // Modest expansion, unit-scale features: the f32 accumulator
            // loses ~2^-24 per term relative to the coefficient mass.
            let mass: f64 = coef.iter().map(|c| c.abs()).sum();
            assert!(delta <= 1e-3 * (1.0 + mass), "{kernel:?}: delta {delta}");
            let queries = random_queries(21, 19, 142);
            let (mut a, mut b) = (vec![0f64; 21], vec![0f64; 21]);
            exact.decision_block(19, &queries, &mut a);
            fast.decision_block(19, &queries, &mut b);
            for q in 0..21 {
                assert!(
                    (a[q] - b[q]).abs() <= 1e-3 * (1.0 + a[q].abs() + mass),
                    "{kernel:?} q={q}: exact {} vs f32 {}",
                    a[q],
                    b[q]
                );
            }
        }
    }

    #[test]
    fn f32_sv_flag_is_inert_for_sparse_pairs_and_collapsed_linear() {
        // CSR on either side keeps the exact f64 merged dot: bits match
        // the flag-off run exactly.
        let mut rng = Pcg::new(151);
        let mut sv = Dataset::with_dim(7);
        let mut row = vec![0f32; 7];
        let mut coef = Vec::new();
        for _ in 0..31 {
            row.iter_mut().for_each(|v| {
                *v = if rng.bernoulli(0.4) { rng.normal() as f32 } else { 0.0 }
            });
            sv.push(&row, 1);
            coef.push(rng.normal());
        }
        let sv_sparse = sv.to_sparse();
        let mut queries = Dataset::with_dim(7);
        for _ in 0..11 {
            row.iter_mut().for_each(|v| *v = rng.normal() as f32);
            queries.push(&row, 1);
        }
        let kernel = KernelFunction::Rbf { gamma: 0.8 };
        let off = Scorer::new(kernel, &sv_sparse, &coef, 0.5);
        let on = off.clone().with_f32_sv(true);
        let (a, b) = (off.decision_values(&queries), on.decision_values(&queries));
        assert!(
            a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
            "f32 flag must be inert for sparse support"
        );
        // Collapsed linear: the primal path wins over the flag and the
        // gate reports zero delta.
        let collapsed = Scorer::new(KernelFunction::Linear, &sv, &coef, 0.5).with_f32_sv(true);
        assert!(collapsed.is_collapsed());
        assert_eq!(collapsed.f32_sv_max_delta(), 0.0);
        let (c, d) = (
            collapsed.decision_values(&queries),
            collapsed.clone().with_f32_sv(false).decision_values(&queries),
        );
        assert!(c.iter().zip(&d).all(|(x, y)| x.to_bits() == y.to_bits()));
    }
}
