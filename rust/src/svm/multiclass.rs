//! One-vs-one multiclass classification (LIBSVM's scheme): train
//! k(k−1)/2 binary PA-SMO machines and combine them by majority vote.
//!
//! The dataset type lives in the data layer
//! ([`crate::data::multiclass`], re-exported here) so LIBSVM IO can
//! produce it; voting runs on the shared batch
//! [`Scorer`](super::scorer::Scorer) — one scorer per machine per
//! batch, each scoring the whole query set in blocked SV×query tiles.

use std::path::Path;
use std::sync::Arc;

use crate::data::dataset::Dataset;
use crate::util::error::Result;
use crate::{bail, ensure};

pub use crate::data::multiclass::{blobs, MulticlassDataset};

use super::model::SvmModel;
use super::schema;
use super::trainer::Trainer;

/// A one-vs-one multiclass model.
#[derive(Debug, Clone)]
pub struct OvoModel {
    /// Distinct classes, sorted (vote-index order).
    pub classes: Vec<i32>,
    /// Binary machine per (a, b) class pair, a < b (index order of
    /// `pair_index`); positive decision votes for `a`.
    pub machines: Vec<SvmModel>,
    pairs: Vec<(i32, i32)>,
}

impl OvoModel {
    /// Assemble from parts (the schema loader's entry): classes must be
    /// sorted and distinct, machines aligned with pairs, pairs drawn
    /// from the classes.
    pub fn from_parts(
        classes: Vec<i32>,
        machines: Vec<SvmModel>,
        pairs: Vec<(i32, i32)>,
    ) -> Result<OvoModel> {
        ensure!(classes.len() >= 2, "need at least two classes");
        ensure!(
            classes.windows(2).all(|w| w[0] < w[1]),
            "classes must be sorted and distinct"
        );
        ensure!(
            machines.len() == pairs.len(),
            "machines/pairs counts disagree ({} vs {})",
            machines.len(),
            pairs.len()
        );
        ensure!(!machines.is_empty(), "need at least one pairwise machine");
        for &(a, b) in &pairs {
            if !(classes.contains(&a) && classes.contains(&b)) {
                bail!("pair ({a}, {b}) references a class not in classes");
            }
        }
        Ok(OvoModel { classes, machines, pairs })
    }

    /// The (a, b) class pair of every machine, aligned with
    /// [`OvoModel::machines`].
    pub fn pairs(&self) -> &[(i32, i32)] {
        &self.pairs
    }

    /// Majority vote over one example's per-machine decision values
    /// (ties → smaller class id, LIBSVM convention).
    fn vote(&self, decision_of: impl Fn(usize) -> f64) -> i32 {
        let mut votes = vec![0usize; self.classes.len()];
        for (m, &(a, b)) in (0..self.machines.len()).zip(&self.pairs) {
            let winner = if decision_of(m) >= 0.0 { a } else { b };
            // The constructor validated every pair against `classes`, so
            // the position lookup cannot miss; stay panic-free regardless.
            if let Some(idx) = self.classes.iter().position(|&c| c == winner) {
                votes[idx] += 1;
            }
        }
        let best = votes.iter().enumerate().max_by_key(|&(i, &v)| (v, usize::MAX - i));
        self.classes[best.map(|(i, _)| i).unwrap_or(0)]
    }

    /// Majority vote from precomputed per-machine decision values for
    /// one example: `decision_of(m)` is machine `m`'s decision, aligned
    /// with [`OvoModel::machines`] / [`OvoModel::pairs`]. This is the
    /// exact tally [`OvoModel::predict`] / [`OvoModel::predict_all`] use
    /// (ties → smaller class id), exposed so callers that already hold
    /// batch decisions — the serving tier's batch loop — predict
    /// bit-identically to the offline paths.
    pub fn vote_decisions(&self, decision_of: impl Fn(usize) -> f64) -> i32 {
        self.vote(decision_of)
    }

    /// Majority vote over all pairwise machines (ties → smaller class id,
    /// LIBSVM convention). One-off convenience — batch callers use
    /// [`OvoModel::predict_all`], which builds each machine's scorer
    /// once instead of once per example.
    pub fn predict(&self, x: &[f32]) -> i32 {
        let decisions: Vec<f64> =
            self.machines.iter().map(|m| m.scorer().decision(x)).collect();
        self.vote(|m| decisions[m])
    }

    /// Predicted classes for every row of `data`: each machine scores
    /// the whole batch in one pass (`threads` scoring workers), then
    /// votes are tallied per example.
    pub fn predict_all(&self, data: &MulticlassDataset, threads: usize) -> Vec<i32> {
        let per_machine: Vec<Vec<f64>> = self
            .machines
            .iter()
            .map(|m| {
                let mut out = vec![0f64; data.len()];
                m.scorer().with_threads(threads).decision_block(
                    data.dim(),
                    data.features(),
                    &mut out,
                );
                out
            })
            .collect();
        (0..data.len())
            .map(|i| self.vote(|m| per_machine[m][i]))
            .collect()
    }

    /// Accuracy on a multiclass dataset (one batch pass per machine).
    pub fn accuracy(&self, data: &MulticlassDataset) -> f64 {
        if data.is_empty() {
            return f64::NAN;
        }
        let preds = self.predict_all(data, 1);
        let correct = preds
            .iter()
            .enumerate()
            .filter(|&(i, &p)| p == data.label(i))
            .count();
        correct as f64 / data.len() as f64
    }

    /// Serialize to a JSON file (schema v2, `kind: "multiclass"`).
    pub fn save(&self, path: &Path) -> Result<()> {
        schema::save(path, &schema::ovo_to_json(self))
    }

    /// Load from a JSON file written by [`OvoModel::save`].
    pub fn load(path: &Path) -> Result<OvoModel> {
        match schema::load_any(path)? {
            schema::AnyModel::Multiclass(m) => Ok(m),
            other => crate::bail!(
                "{} holds a {:?} model, not a multiclass model",
                path.display(),
                other.task_name()
            ),
        }
    }
}

/// Train a one-vs-one model; `trainer` is applied to every pairwise
/// machine.
pub fn train_ovo(data: &MulticlassDataset, trainer: &Trainer) -> OvoModel {
    let classes = data.classes();
    assert!(classes.len() >= 2, "need at least two classes");
    let mut machines = Vec::new();
    let mut pairs = Vec::new();
    for ai in 0..classes.len() {
        for bi in ai + 1..classes.len() {
            let (a, b) = (classes[ai], classes[bi]);
            let mut sub = Dataset::with_dim(data.dim());
            for i in 0..data.len() {
                if data.label(i) == a {
                    sub.push(data.row(i), 1);
                } else if data.label(i) == b {
                    sub.push(data.row(i), -1);
                }
            }
            machines.push(trainer.train(&Arc::new(sub)).model);
            pairs.push((a, b));
        }
    }
    OvoModel { classes, machines, pairs }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_and_pairs_enumeration() {
        let ds = blobs(90, 3, 4.0, 0.5, 1);
        assert_eq!(ds.classes(), vec![0, 1, 2]);
        let model = train_ovo(&ds, &Trainer::rbf(10.0, 0.5));
        assert_eq!(model.machines.len(), 3); // 3 choose 2
        assert_eq!(model.pairs(), &[(0, 1), (0, 2), (1, 2)]);
    }

    #[test]
    fn separable_blobs_classified_accurately() {
        let train_set = blobs(240, 4, 6.0, 0.4, 2);
        let test_set = blobs(200, 4, 6.0, 0.4, 3);
        let model = train_ovo(&train_set, &Trainer::rbf(10.0, 0.3));
        let acc = model.accuracy(&test_set);
        assert!(acc > 0.95, "accuracy {acc}");
    }

    #[test]
    fn predicts_the_nearest_blob_center() {
        let train_set = blobs(300, 3, 5.0, 0.4, 4);
        let model = train_ovo(&train_set, &Trainer::rbf(10.0, 0.3));
        for c in 0..3 {
            let theta = 2.0 * std::f64::consts::PI * c as f64 / 3.0;
            let x = [(5.0 * theta.cos()) as f32, (5.0 * theta.sin()) as f32];
            assert_eq!(model.predict(&x), c as i32, "center of class {c}");
        }
    }

    #[test]
    fn batch_prediction_matches_per_example_and_round_trips() {
        let train_set = blobs(150, 3, 5.0, 0.4, 6);
        let test_set = blobs(90, 3, 5.0, 0.4, 7);
        let model = train_ovo(&train_set, &Trainer::rbf(10.0, 0.3));
        let batch = model.predict_all(&test_set, 1);
        let threaded = model.predict_all(&test_set, 4);
        for i in 0..test_set.len() {
            assert_eq!(batch[i], model.predict(test_set.row(i)), "i={i}");
            assert_eq!(batch[i], threaded[i], "i={i} threaded");
        }
        // save/load round trip through the v2 `multiclass` schema
        let dir = std::env::temp_dir().join("pasmo-ovo-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ovo.json");
        model.save(&path).unwrap();
        let loaded = OvoModel::load(&path).unwrap();
        assert_eq!(loaded.classes, model.classes);
        assert_eq!(loaded.pairs(), model.pairs());
        assert_eq!(loaded.predict_all(&test_set, 1), batch);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn from_parts_validates_shape() {
        let ds = blobs(60, 2, 4.0, 0.4, 8);
        let m = train_ovo(&ds, &Trainer::rbf(5.0, 0.5));
        let machine = m.machines[0].clone();
        assert!(OvoModel::from_parts(vec![0], vec![machine.clone()], vec![(0, 1)]).is_err());
        assert!(OvoModel::from_parts(vec![0, 1], vec![], vec![]).is_err());
        assert!(
            OvoModel::from_parts(vec![0, 1], vec![machine.clone()], vec![(0, 7)]).is_err()
        );
        assert!(OvoModel::from_parts(vec![0, 1], vec![machine], vec![(0, 1)]).is_ok());
    }

    #[test]
    fn binary_case_degenerates_to_single_machine() {
        let ds = blobs(100, 2, 4.0, 0.5, 5);
        let model = train_ovo(&ds, &Trainer::rbf(5.0, 0.5));
        assert_eq!(model.machines.len(), 1);
        assert!(model.accuracy(&ds) > 0.9);
    }

    #[test]
    #[should_panic(expected = "at least two classes")]
    fn single_class_rejected() {
        let mut ds = MulticlassDataset::with_dim(2);
        ds.push(&[0.0, 0.0], 7);
        ds.push(&[1.0, 1.0], 7);
        train_ovo(&ds, &Trainer::rbf(1.0, 1.0));
    }
}
