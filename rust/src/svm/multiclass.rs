//! One-vs-one multiclass classification (LIBSVM's scheme): train
//! k(k−1)/2 binary PA-SMO machines and combine them by majority vote.

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::data::dataset::Dataset;

use super::model::SvmModel;
use super::trainer::Trainer;

/// A multiclass dataset: dense features with arbitrary integer labels.
#[derive(Debug, Clone, PartialEq)]
pub struct MulticlassDataset {
    dim: usize,
    features: Vec<f32>,
    labels: Vec<i32>,
}

impl MulticlassDataset {
    /// Empty dataset of the given feature dimension.
    pub fn with_dim(dim: usize) -> MulticlassDataset {
        assert!(dim > 0);
        MulticlassDataset { dim, features: Vec::new(), labels: Vec::new() }
    }

    /// Append an example.
    pub fn push(&mut self, x: &[f32], y: i32) {
        assert_eq!(x.len(), self.dim);
        self.features.extend_from_slice(x);
        self.labels.push(y);
    }

    /// Number of examples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// Is the dataset empty?
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Feature dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Feature row of example `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.features[i * self.dim..(i + 1) * self.dim]
    }

    /// Class label of example `i`.
    #[inline]
    pub fn label(&self, i: usize) -> i32 {
        self.labels[i]
    }

    /// Distinct classes, sorted.
    pub fn classes(&self) -> Vec<i32> {
        self.labels.iter().copied().collect::<BTreeSet<_>>().into_iter().collect()
    }
}

/// A one-vs-one multiclass model.
#[derive(Debug, Clone)]
pub struct OvoModel {
    /// Distinct classes, sorted (vote-index order).
    pub classes: Vec<i32>,
    /// Binary machine per (a, b) class pair, a < b (index order of
    /// `pair_index`); positive decision votes for `a`.
    pub machines: Vec<SvmModel>,
    pairs: Vec<(i32, i32)>,
}

impl OvoModel {
    /// Majority vote over all pairwise machines (ties → smaller class id,
    /// LIBSVM convention).
    pub fn predict(&self, x: &[f32]) -> i32 {
        let mut votes = vec![0usize; self.classes.len()];
        for (m, &(a, b)) in self.machines.iter().zip(&self.pairs) {
            let winner = if m.decision(x) >= 0.0 { a } else { b };
            let idx = self.classes.iter().position(|&c| c == winner).unwrap();
            votes[idx] += 1;
        }
        let best = votes.iter().enumerate().max_by_key(|&(i, &v)| (v, usize::MAX - i));
        self.classes[best.map(|(i, _)| i).unwrap_or(0)]
    }

    /// Accuracy on a multiclass dataset.
    pub fn accuracy(&self, data: &MulticlassDataset) -> f64 {
        if data.is_empty() {
            return f64::NAN;
        }
        let correct = (0..data.len())
            .filter(|&i| self.predict(data.row(i)) == data.label(i))
            .count();
        correct as f64 / data.len() as f64
    }
}

/// Train a one-vs-one model; `trainer` is applied to every pairwise
/// machine.
pub fn train_ovo(data: &MulticlassDataset, trainer: &Trainer) -> OvoModel {
    let classes = data.classes();
    assert!(classes.len() >= 2, "need at least two classes");
    let mut machines = Vec::new();
    let mut pairs = Vec::new();
    for ai in 0..classes.len() {
        for bi in ai + 1..classes.len() {
            let (a, b) = (classes[ai], classes[bi]);
            let mut sub = Dataset::with_dim(data.dim());
            for i in 0..data.len() {
                if data.label(i) == a {
                    sub.push(data.row(i), 1);
                } else if data.label(i) == b {
                    sub.push(data.row(i), -1);
                }
            }
            machines.push(trainer.train(&Arc::new(sub)).model);
            pairs.push((a, b));
        }
    }
    OvoModel { classes, machines, pairs }
}

/// Synthetic k-class Gaussian blobs on a circle (test/demo generator).
pub fn blobs(n: usize, k: usize, radius: f64, sd: f64, seed: u64) -> MulticlassDataset {
    use crate::util::prng::Pcg;
    assert!(k >= 2);
    let mut rng = Pcg::new(seed);
    let mut ds = MulticlassDataset::with_dim(2);
    for _ in 0..n {
        let c = rng.below(k);
        let theta = 2.0 * std::f64::consts::PI * c as f64 / k as f64;
        ds.push(
            &[
                (radius * theta.cos() + rng.normal() * sd) as f32,
                (radius * theta.sin() + rng.normal() * sd) as f32,
            ],
            c as i32,
        );
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_and_pairs_enumeration() {
        let ds = blobs(90, 3, 4.0, 0.5, 1);
        assert_eq!(ds.classes(), vec![0, 1, 2]);
        let model = train_ovo(&ds, &Trainer::rbf(10.0, 0.5));
        assert_eq!(model.machines.len(), 3); // 3 choose 2
    }

    #[test]
    fn separable_blobs_classified_accurately() {
        let train_set = blobs(240, 4, 6.0, 0.4, 2);
        let test_set = blobs(200, 4, 6.0, 0.4, 3);
        let model = train_ovo(&train_set, &Trainer::rbf(10.0, 0.3));
        let acc = model.accuracy(&test_set);
        assert!(acc > 0.95, "accuracy {acc}");
    }

    #[test]
    fn predicts_the_nearest_blob_center() {
        let train_set = blobs(300, 3, 5.0, 0.4, 4);
        let model = train_ovo(&train_set, &Trainer::rbf(10.0, 0.3));
        for c in 0..3 {
            let theta = 2.0 * std::f64::consts::PI * c as f64 / 3.0;
            let x = [(5.0 * theta.cos()) as f32, (5.0 * theta.sin()) as f32];
            assert_eq!(model.predict(&x), c as i32, "center of class {c}");
        }
    }

    #[test]
    fn binary_case_degenerates_to_single_machine() {
        let ds = blobs(100, 2, 4.0, 0.5, 5);
        let model = train_ovo(&ds, &Trainer::rbf(5.0, 0.5));
        assert_eq!(model.machines.len(), 1);
        assert!(model.accuracy(&ds) > 0.9);
    }

    #[test]
    #[should_panic(expected = "at least two classes")]
    fn single_class_rejected() {
        let mut ds = MulticlassDataset::with_dim(2);
        ds.push(&[0.0, 0.0], 7);
        ds.push(&[1.0, 1.0], 7);
        train_ovo(&ds, &Trainer::rbf(1.0, 1.0));
    }
}
