//! The trained SVM model: support vectors, signed dual coefficients, bias.

use std::path::Path;

use crate::util::error::Result;

use crate::data::dataset::Dataset;
use crate::kernel::function::KernelFunction;

use super::platt::PlattScaler;
use super::schema;
use super::scorer::Scorer;

/// A trained binary SVM classifier.
///
/// In the paper's signed-α convention the decision function is
/// `f(x) = Σ_s coef_s · k(x_s, x) + b` with `coef_s = α_s` (the label sign
/// is already inside α).
#[derive(Debug, Clone)]
pub struct SvmModel {
    /// The kernel the machine was trained with.
    pub kernel: KernelFunction,
    /// Support vectors (rows with α ≠ 0).
    pub support: Dataset,
    /// Signed dual coefficients, aligned with `support` rows.
    pub coef: Vec<f64>,
    /// Bias term b of the decision function.
    pub bias: f64,
    /// Optional Platt probability calibration (fitted by
    /// [`PlattScaler::fit_model`]; saved/loaded with the model).
    pub platt: Option<PlattScaler>,
}

impl SvmModel {
    /// Build from a full training set and its dual solution, keeping only
    /// the support vectors. The support set inherits the training set's
    /// storage backend — a CSR-sparse training run yields CSR-sparse
    /// support vectors, so serving never densifies.
    pub fn from_solution(
        data: &Dataset,
        alpha: &[f64],
        bias: f64,
        kernel: KernelFunction,
        tol: f64,
    ) -> SvmModel {
        assert_eq!(data.len(), alpha.len());
        let mut support = data.empty_like();
        let mut coef = Vec::new();
        for i in 0..data.len() {
            if alpha[i].abs() > tol {
                support.push_row(data.row_ref(i), data.label(i));
                coef.push(alpha[i]);
            }
        }
        SvmModel { kernel, support, coef, bias, platt: None }
    }

    /// Number of support vectors.
    pub fn n_sv(&self) -> usize {
        self.coef.len()
    }

    /// The batch scoring engine over this model's expansion — build it
    /// once per batch (it precomputes the support-side invariants), then
    /// score whole datasets via [`Scorer::decision_values`] /
    /// [`Scorer::decision_block`].
    pub fn scorer(&self) -> Scorer<'_> {
        Scorer::new(self.kernel, &self.support, &self.coef, self.bias)
    }

    /// Decision value `f(x)` (one-off convenience: builds a throwaway
    /// [`Scorer`]; batch callers use [`SvmModel::scorer`] directly).
    pub fn decision(&self, x: &[f32]) -> f64 {
        self.scorer().decision(x)
    }

    /// Predicted label (±1; 0-decision maps to +1, LIBSVM convention).
    pub fn predict(&self, x: &[f32]) -> i8 {
        if self.decision(x) >= 0.0 {
            1
        } else {
            -1
        }
    }

    /// Serialize to a JSON file (schema v2, `kind: "svc"` — see
    /// [`schema`]).
    pub fn save(&self, path: &Path) -> Result<()> {
        schema::save(path, &schema::svc_to_json(self))
    }

    /// Load from a JSON file written by [`SvmModel::save`] (v1 files
    /// without a `kind` tag load as classifiers too). Parsing is strict:
    /// a non-numeric `coef`/`labels`/`sv` entry fails with its position
    /// instead of being silently dropped.
    pub fn load(path: &Path) -> Result<SvmModel> {
        match schema::load_any(path)? {
            schema::AnyModel::Svc(m) => Ok(m),
            other => crate::bail!(
                "{} holds a {:?} model, not a binary classifier",
                path.display(),
                other.task_name()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_model() -> SvmModel {
        let data = Dataset::new(2, vec![1.0, 0.0, -1.0, 0.0, 0.0, 5.0], vec![1, -1, 1]);
        SvmModel::from_solution(
            &data,
            &[0.8, -0.8, 0.0],
            0.1,
            KernelFunction::Rbf { gamma: 0.5 },
            1e-12,
        )
    }

    #[test]
    fn keeps_only_support_vectors() {
        let m = toy_model();
        assert_eq!(m.n_sv(), 2);
        assert_eq!(m.coef, vec![0.8, -0.8]);
    }

    #[test]
    fn decision_hand_computed() {
        let m = toy_model();
        // at x = (1, 0): k(sv0, x) = 1, k(sv1, x) = exp(-0.5*4) = e^-2
        let want = 0.8 * 1.0 - 0.8 * (-2.0f64).exp() + 0.1;
        assert!((m.decision(&[1.0, 0.0]) - want).abs() < 1e-12);
        assert_eq!(m.predict(&[1.0, 0.0]), 1);
        assert_eq!(m.predict(&[-1.0, 0.0]), -1);
    }

    #[test]
    fn save_load_round_trip() {
        let m = toy_model();
        let dir = std::env::temp_dir().join("pasmo-model-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");
        m.save(&path).unwrap();
        let l = SvmModel::load(&path).unwrap();
        assert_eq!(l.n_sv(), m.n_sv());
        assert_eq!(l.kernel, m.kernel);
        assert!(l.platt.is_none());
        for x in [[0.3f32, -0.7], [2.0, 1.0]] {
            assert!((l.decision(&x) - m.decision(&x)).abs() < 1e-9);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn platt_calibration_round_trips() {
        let mut m = toy_model();
        m.platt = Some(PlattScaler { a: -1.25, b: 0.5 });
        let dir = std::env::temp_dir().join("pasmo-model-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model-platt.json");
        m.save(&path).unwrap();
        let l = SvmModel::load(&path).unwrap();
        assert_eq!(l.platt, Some(PlattScaler { a: -1.25, b: 0.5 }));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_malformed() {
        let dir = std::env::temp_dir().join("pasmo-model-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.json");
        std::fs::write(&path, "{\"kernel\": \"rbf\"}").unwrap();
        assert!(SvmModel::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_reports_position_of_non_numeric_coef() {
        // The v1 loader silently dropped non-numeric coef entries and
        // failed later (or worse, misaligned); the strict parser names
        // the offending position.
        let dir = std::env::temp_dir().join("pasmo-model-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad-coef.json");
        std::fs::write(
            &path,
            "{\"kernel\":\"rbf\",\"gamma\":0.5,\"coef0\":0,\"degree\":0,\
             \"bias\":0.1,\"dim\":2,\"coef\":[0.8,\"oops\"],\
             \"labels\":[1,-1],\"sv\":[[1,0],[-1,0]]}",
        )
        .unwrap();
        let err = SvmModel::load(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("coef[1]"), "error does not name the position: {msg}");
        std::fs::remove_file(&path).ok();
    }
}
