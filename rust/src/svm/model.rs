//! The trained SVM model: support vectors, signed dual coefficients, bias.

use std::path::Path;

use crate::util::error::{Context, Error, Result};
use crate::{bail, ensure};

use crate::data::dataset::Dataset;
use crate::kernel::function::KernelFunction;
use crate::util::json::Json;

/// A trained binary SVM classifier.
///
/// In the paper's signed-α convention the decision function is
/// `f(x) = Σ_s coef_s · k(x_s, x) + b` with `coef_s = α_s` (the label sign
/// is already inside α).
#[derive(Debug, Clone)]
pub struct SvmModel {
    /// The kernel the machine was trained with.
    pub kernel: KernelFunction,
    /// Support vectors (rows with α ≠ 0).
    pub support: Dataset,
    /// Signed dual coefficients, aligned with `support` rows.
    pub coef: Vec<f64>,
    /// Bias term b of the decision function.
    pub bias: f64,
}

impl SvmModel {
    /// Build from a full training set and its dual solution, keeping only
    /// the support vectors.
    pub fn from_solution(
        data: &Dataset,
        alpha: &[f64],
        bias: f64,
        kernel: KernelFunction,
        tol: f64,
    ) -> SvmModel {
        assert_eq!(data.len(), alpha.len());
        let mut support = Dataset::with_dim(data.dim());
        let mut coef = Vec::new();
        for i in 0..data.len() {
            if alpha[i].abs() > tol {
                support.push(data.row(i), data.label(i));
                coef.push(alpha[i]);
            }
        }
        SvmModel { kernel, support, coef, bias }
    }

    /// Number of support vectors.
    pub fn n_sv(&self) -> usize {
        self.coef.len()
    }

    /// Decision value `f(x)`.
    pub fn decision(&self, x: &[f32]) -> f64 {
        let mut f = self.bias;
        for s in 0..self.support.len() {
            f += self.coef[s] * self.kernel.eval(self.support.row(s), x);
        }
        f
    }

    /// Predicted label (±1; 0-decision maps to +1, LIBSVM convention).
    pub fn predict(&self, x: &[f32]) -> i8 {
        if self.decision(x) >= 0.0 {
            1
        } else {
            -1
        }
    }

    /// Serialize to a JSON file.
    pub fn save(&self, path: &Path) -> Result<()> {
        use std::collections::BTreeMap;
        let mut obj = BTreeMap::new();
        let (kname, gamma, coef0, degree) = match self.kernel {
            KernelFunction::Rbf { gamma } => ("rbf", gamma, 0.0, 0),
            KernelFunction::Linear => ("linear", 0.0, 0.0, 0),
            KernelFunction::Poly { gamma, coef0, degree } => ("poly", gamma, coef0, degree),
            KernelFunction::Sigmoid { gamma, coef0 } => ("sigmoid", gamma, coef0, 0),
        };
        obj.insert("kernel".into(), Json::Str(kname.into()));
        obj.insert("gamma".into(), Json::Num(gamma));
        obj.insert("coef0".into(), Json::Num(coef0));
        obj.insert("degree".into(), Json::Num(degree as f64));
        obj.insert("bias".into(), Json::Num(self.bias));
        obj.insert("dim".into(), Json::Num(self.support.dim() as f64));
        obj.insert(
            "coef".into(),
            Json::Arr(self.coef.iter().map(|&c| Json::Num(c)).collect()),
        );
        obj.insert(
            "labels".into(),
            Json::Arr(
                self.support
                    .labels()
                    .iter()
                    .map(|&y| Json::Num(y as f64))
                    .collect(),
            ),
        );
        let mut rows = Vec::new();
        for i in 0..self.support.len() {
            rows.push(Json::Arr(
                self.support.row(i).iter().map(|&v| Json::Num(v as f64)).collect(),
            ));
        }
        obj.insert("sv".into(), Json::Arr(rows));
        std::fs::write(path, Json::Obj(obj).to_string())
            .with_context(|| format!("write {}", path.display()))
    }

    /// Load from a JSON file written by [`SvmModel::save`].
    pub fn load(path: &Path) -> Result<SvmModel> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| Error::msg(format!("parse model: {e}")))?;
        let get = |k: &str| v.get(k).with_context(|| format!("missing field {k}"));
        let gamma = get("gamma")?.as_f64().context("gamma")?;
        let coef0 = get("coef0")?.as_f64().context("coef0")?;
        let degree = get("degree")?.as_f64().context("degree")? as u32;
        let kernel = match get("kernel")?.as_str().context("kernel")? {
            "rbf" => KernelFunction::Rbf { gamma },
            "linear" => KernelFunction::Linear,
            "poly" => KernelFunction::Poly { gamma, coef0, degree },
            "sigmoid" => KernelFunction::Sigmoid { gamma, coef0 },
            other => bail!("unknown kernel {other:?}"),
        };
        let bias = get("bias")?.as_f64().context("bias")?;
        let dim = get("dim")?.as_usize().context("dim")?;
        let coef: Vec<f64> = get("coef")?
            .as_arr()
            .context("coef")?
            .iter()
            .filter_map(|j| j.as_f64())
            .collect();
        let labels: Vec<i8> = get("labels")?
            .as_arr()
            .context("labels")?
            .iter()
            .filter_map(|j| j.as_f64())
            .map(|y| if y > 0.0 { 1 } else { -1 })
            .collect();
        let mut support = Dataset::with_dim(dim);
        let rows = get("sv")?.as_arr().context("sv")?;
        ensure!(
            rows.len() == coef.len() && rows.len() == labels.len(),
            "sv/coef/label counts disagree"
        );
        let mut buf = vec![0f32; dim];
        for (r, row) in rows.iter().enumerate() {
            let vals = row.as_arr().context("sv row")?;
            ensure!(vals.len() == dim, "sv row arity");
            for (k, jv) in vals.iter().enumerate() {
                buf[k] = jv.as_f64().context("sv value")? as f32;
            }
            support.push(&buf, labels[r]);
        }
        Ok(SvmModel { kernel, support, coef, bias })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_model() -> SvmModel {
        let data = Dataset::new(2, vec![1.0, 0.0, -1.0, 0.0, 0.0, 5.0], vec![1, -1, 1]);
        SvmModel::from_solution(
            &data,
            &[0.8, -0.8, 0.0],
            0.1,
            KernelFunction::Rbf { gamma: 0.5 },
            1e-12,
        )
    }

    #[test]
    fn keeps_only_support_vectors() {
        let m = toy_model();
        assert_eq!(m.n_sv(), 2);
        assert_eq!(m.coef, vec![0.8, -0.8]);
    }

    #[test]
    fn decision_hand_computed() {
        let m = toy_model();
        // at x = (1, 0): k(sv0, x) = 1, k(sv1, x) = exp(-0.5*4) = e^-2
        let want = 0.8 * 1.0 - 0.8 * (-2.0f64).exp() + 0.1;
        assert!((m.decision(&[1.0, 0.0]) - want).abs() < 1e-12);
        assert_eq!(m.predict(&[1.0, 0.0]), 1);
        assert_eq!(m.predict(&[-1.0, 0.0]), -1);
    }

    #[test]
    fn save_load_round_trip() {
        let m = toy_model();
        let dir = std::env::temp_dir().join("pasmo-model-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");
        m.save(&path).unwrap();
        let l = SvmModel::load(&path).unwrap();
        assert_eq!(l.n_sv(), m.n_sv());
        assert_eq!(l.kernel, m.kernel);
        for x in [[0.3f32, -0.7], [2.0, 1.0]] {
            assert!((l.decision(&x) - m.decision(&x)).abs() < 1e-9);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_malformed() {
        let dir = std::env::temp_dir().join("pasmo-model-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.json");
        std::fs::write(&path, "{\"kernel\": \"rbf\"}").unwrap();
        assert!(SvmModel::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
