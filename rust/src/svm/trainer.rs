//! The one user-facing training entry point: a builder that assembles a
//! [`QpProblem`], picks an [`Engine`] through the single `SolverChoice`
//! factory, and returns the trained model plus solver diagnostics.
//!
//! ```
//! use pasmo::kernel::KernelFunction;
//! use pasmo::solver::SolverChoice;
//! use pasmo::svm::Trainer;
//! # let data = std::sync::Arc::new(pasmo::data::synth::chessboard(100, 4, 1));
//! let outcome = Trainer::new(KernelFunction::Rbf { gamma: 0.5 })
//!     .c(100.0)
//!     .solver(SolverChoice::Pasmo)
//!     .stop_eps(1e-3)
//!     .class_weights(2.0, 1.0) // C₊ = 200, C₋ = 100
//!     .train(&data);
//! assert!(outcome.result.converged);
//! println!("{} SVs in {} iterations", outcome.result.sv, outcome.result.iterations);
//! ```

use std::sync::Arc;

use crate::data::dataset::Dataset;
use crate::kernel::function::KernelFunction;
use crate::kernel::matrix::{Gram, RowComputer};
use crate::kernel::native::NativeRowComputer;
use crate::solver::engine::{Engine, EngineConfig, SolverChoice};
use crate::solver::problem::QpProblem;
use crate::solver::smo::{SolveResult, SolverConfig};

use super::model::SvmModel;

/// A trained classifier plus the solve diagnostics that produced it.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    /// The trained model (support vectors, coefficients, bias, kernel).
    pub model: SvmModel,
    /// Solver diagnostics: iterations, objective, telemetry, cache stats.
    pub result: SolveResult,
}

/// Builder for C-SVC training runs (the general tasks — ε-SVR, one-class
/// — construct their [`QpProblem`] directly; see `svm::svr` /
/// `svm::oneclass`).
#[derive(Debug, Clone)]
pub struct Trainer {
    /// The kernel function k(x, x′).
    pub kernel: KernelFunction,
    /// Regularization constant C.
    pub c: f64,
    /// Per-class cost multipliers `(w₊, w₋)`: positives are budgeted
    /// `w₊·C`, negatives `w₋·C`. `(1, 1)` is the unweighted machine.
    pub weights: (f64, f64),
    /// Which engine drives training (PA-SMO by default).
    pub solver: SolverChoice,
    /// Full low-level solver configuration.
    pub solver_config: SolverConfig,
    /// Optional α seed for the next [`Trainer::train`] call (repaired to
    /// feasibility at lowering — see [`QpProblem::lower`]).
    pub warm_start: Option<Vec<f64>>,
}

impl Trainer {
    /// A PA-SMO trainer with the paper's defaults (C = 1, ε = 10⁻³).
    pub fn new(kernel: KernelFunction) -> Trainer {
        Trainer {
            kernel,
            c: 1.0,
            weights: (1.0, 1.0),
            solver: SolverChoice::Pasmo,
            solver_config: SolverConfig::default(),
            warm_start: None,
        }
    }

    /// Shorthand for the common case: RBF kernel at the given (C, γ).
    pub fn rbf(c: f64, gamma: f64) -> Trainer {
        Trainer::new(KernelFunction::Rbf { gamma }).c(c)
    }

    /// Regularization constant C.
    pub fn c(mut self, c: f64) -> Trainer {
        assert!(c > 0.0, "C must be positive");
        self.c = c;
        self
    }

    /// Replace the kernel function.
    pub fn kernel(mut self, kernel: KernelFunction) -> Trainer {
        self.kernel = kernel;
        self
    }

    /// Which engine drives training.
    pub fn solver(mut self, solver: SolverChoice) -> Trainer {
        self.solver = solver;
        self
    }

    /// Kernel row-cache budget in bytes.
    pub fn cache_bytes(mut self, bytes: usize) -> Trainer {
        self.solver_config.cache_bytes = bytes;
        self
    }

    /// Worker threads for kernel-row computation (0/1 = single-threaded).
    /// `SolveResult::alpha` is bit-identical across thread counts —
    /// threaded rows use exactly the per-entry arithmetic of the scalar
    /// path (see `kernel::native`).
    pub fn threads(mut self, threads: usize) -> Trainer {
        self.solver_config.threads = threads;
        self
    }

    /// KKT stopping accuracy ε.
    pub fn stop_eps(mut self, eps: f64) -> Trainer {
        self.solver_config.eps = eps;
        self
    }

    /// Per-class cost multipliers (w₊, w₋) for imbalanced data.
    pub fn class_weights(mut self, w_pos: f64, w_neg: f64) -> Trainer {
        assert!(w_pos > 0.0 && w_neg > 0.0, "class weights must be positive");
        self.weights = (w_pos, w_neg);
        self
    }

    /// Seed the next solve from a previous solution's α.
    pub fn warm_start(mut self, alpha: Vec<f64>) -> Trainer {
        self.warm_start = Some(alpha);
        self
    }

    /// Replace the full low-level solver configuration (telemetry,
    /// shrinking, step policy, ablations …).
    pub fn solver_config(mut self, config: SolverConfig) -> Trainer {
        self.solver_config = config;
        self
    }

    /// The dual problem this trainer poses for `labels` — the C-SVC
    /// lowering site (weighted bounds + warm start).
    pub fn problem(&self, labels: &[i8]) -> QpProblem {
        let (w_pos, w_neg) = self.weights;
        let p = QpProblem::classification_weighted(labels, w_pos * self.c, w_neg * self.c);
        match &self.warm_start {
            Some(alpha) => p.warm_start(alpha.clone()),
            None => p,
        }
    }

    /// The engine this trainer dispatches to.
    pub fn engine(&self) -> Box<dyn Engine> {
        EngineConfig::new(self.solver, self.solver_config).build()
    }

    /// Train on a dataset using the native (Rust) kernel path.
    pub fn train(&self, data: &Arc<Dataset>) -> TrainOutcome {
        let computer =
            NativeRowComputer::with_threads(data.clone(), self.kernel, self.solver_config.threads);
        self.train_with_computer(data, Box::new(computer))
    }

    /// Train with a caller-supplied row computer (e.g. the PJRT-backed
    /// `crate::runtime::gram::PjrtRowComputer`, available with the `pjrt`
    /// feature). [`Trainer::train`] is the native-path shorthand — the
    /// default build always has that fallback.
    pub fn train_with_computer(
        &self,
        data: &Arc<Dataset>,
        computer: Box<dyn RowComputer>,
    ) -> TrainOutcome {
        let mut gram = Gram::new(computer, self.solver_config.cache_bytes);
        let problem = self.problem(data.labels());
        let result = self.engine().solve(&problem, &mut gram);
        let model =
            SvmModel::from_solution(data, &result.alpha, result.bias, self.kernel, 1e-12);
        TrainOutcome { model, result }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::chessboard;
    use crate::svm::predict::accuracy;
    use crate::util::prng::Pcg;

    #[test]
    fn trains_a_working_classifier_on_chessboard() {
        let ds = Arc::new(chessboard(300, 4, 1));
        let out = Trainer::rbf(100.0, 0.5).train(&ds);
        assert!(out.result.converged);
        assert!(out.model.n_sv() > 0);
        let train_acc = accuracy(&out.model, &ds);
        assert!(train_acc > 0.9, "train accuracy {train_acc}");
    }

    #[test]
    fn smo_and_pasmo_produce_equivalent_models() {
        let ds = Arc::new(chessboard(200, 4, 2));
        let base = Trainer::rbf(10.0, 0.5);
        let o1 = base.clone().solver(SolverChoice::Smo).train(&ds);
        let o2 = base.solver(SolverChoice::Pasmo).train(&ds);
        assert!(o1.result.converged && o2.result.converged);
        let rel = (o1.result.objective - o2.result.objective).abs()
            / (1.0 + o1.result.objective.abs());
        assert!(rel < 2e-3, "{} vs {}", o1.result.objective, o2.result.objective);
        // decisions agree on most points
        let mut agree = 0;
        for i in 0..ds.len() {
            if o1.model.predict(ds.row(i)) == o2.model.predict(ds.row(i)) {
                agree += 1;
            }
        }
        assert!(agree as f64 / ds.len() as f64 > 0.97);
    }

    #[test]
    fn multi_planning_choice_works() {
        let ds = Arc::new(chessboard(150, 4, 3));
        let out = Trainer::rbf(50.0, 0.5).solver(SolverChoice::PasmoMulti(3)).train(&ds);
        assert!(out.result.converged);
    }

    #[test]
    fn equal_class_weights_match_the_unweighted_path_exactly() {
        // Weighting with (1, 1) must be bit-identical to no weighting:
        // same problem, same deterministic solver path.
        let ds = Arc::new(chessboard(200, 4, 4));
        let plain = Trainer::rbf(10.0, 0.5).train(&ds);
        let weighted = Trainer::rbf(10.0, 0.5).class_weights(1.0, 1.0).train(&ds);
        assert_eq!(plain.result.iterations, weighted.result.iterations);
        assert_eq!(plain.result.objective, weighted.result.objective);
        assert_eq!(plain.result.sv, weighted.result.sv);
        assert_eq!(plain.result.alpha, weighted.result.alpha);
    }

    #[test]
    fn class_weights_shift_the_decision_toward_the_costly_class() {
        // Imbalanced blobs: 85% negatives. Upweighting the positive
        // class must increase positive recall (the new scenario the
        // QpProblem bounds unlock).
        let mut rng = Pcg::new(9);
        let mut ds = Dataset::with_dim(2);
        for _ in 0..360 {
            let y: i8 = if rng.below(100) < 15 { 1 } else { -1 };
            let cx = if y == 1 { 0.9 } else { -0.3 };
            ds.push(&[(cx + rng.normal() * 0.7) as f32, (rng.normal() * 0.7) as f32], y);
        }
        let ds = Arc::new(ds);
        let recall = |out: &TrainOutcome| {
            let mut tp = 0usize;
            let mut pos = 0usize;
            for i in 0..ds.len() {
                if ds.label(i) == 1 {
                    pos += 1;
                    if out.model.predict(ds.row(i)) == 1 {
                        tp += 1;
                    }
                }
            }
            tp as f64 / pos as f64
        };
        let plain = Trainer::rbf(1.0, 0.5).train(&ds);
        let weighted = Trainer::rbf(1.0, 0.5).class_weights(8.0, 1.0).train(&ds);
        assert!(plain.result.converged && weighted.result.converged);
        assert!(
            recall(&weighted) > recall(&plain),
            "weighted recall {} !> plain recall {}",
            recall(&weighted),
            recall(&plain)
        );
    }

    #[test]
    fn warm_start_from_own_solution_converges_immediately() {
        let ds = Arc::new(chessboard(200, 4, 5));
        let cold = Trainer::rbf(10.0, 0.5).train(&ds);
        assert!(cold.result.converged);
        let warm = Trainer::rbf(10.0, 0.5)
            .warm_start(cold.result.alpha.clone())
            .train(&ds);
        assert!(warm.result.converged);
        assert!(
            warm.result.iterations <= cold.result.iterations / 4,
            "warm restart took {} iterations vs cold {}",
            warm.result.iterations,
            cold.result.iterations
        );
    }
}
