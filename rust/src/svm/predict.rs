//! Batch prediction and evaluation metrics, all derived from one
//! [`Scorer`] pass.
//!
//! [`evaluate`] computes the decision values for a whole dataset once
//! (batch scorer, optional threads) and derives predictions, accuracy
//! and the confusion counts from that single pass. The per-metric entry
//! points ([`accuracy`], [`confusion`], [`predict_all`]) are per-call
//! conveniences — each runs its own pass, so callers who want more than
//! one statistic should take them from a single [`evaluate`] /
//! [`evaluate_with`] result instead.

use crate::data::dataset::Dataset;

use super::model::SvmModel;
use super::scorer::Scorer;

/// Everything one scoring pass over a labeled dataset yields.
#[derive(Debug, Clone)]
pub struct Evaluation {
    /// Decision value `f(x)` per example.
    pub decisions: Vec<f64>,
    /// Predicted labels (±1; `f ≥ 0` maps to +1, LIBSVM convention).
    pub predictions: Vec<i8>,
    /// Fraction of predictions matching the dataset labels (NaN on an
    /// empty dataset).
    pub accuracy: f64,
    /// Confusion counts (tp, fp, tn, fn) with +1 as the positive class.
    pub confusion: (usize, usize, usize, usize),
}

/// Label a decision value (±1; 0 maps to +1, LIBSVM convention).
#[inline]
fn label_of(f: f64) -> i8 {
    if f >= 0.0 {
        1
    } else {
        -1
    }
}

/// Derive an [`Evaluation`] from precomputed decision values (one pass,
/// shared by every metric).
fn evaluation_from(decisions: Vec<f64>, data: &Dataset) -> Evaluation {
    let predictions: Vec<i8> = decisions.iter().map(|&f| label_of(f)).collect();
    let (mut tp, mut fp, mut tn, mut fnn) = (0usize, 0usize, 0usize, 0usize);
    let mut correct = 0usize;
    for (i, &p) in predictions.iter().enumerate() {
        match (p, data.label(i)) {
            (1, 1) => tp += 1,
            (1, -1) => fp += 1,
            (-1, -1) => tn += 1,
            (-1, 1) => fnn += 1,
            _ => unreachable!("labels are ±1 by Dataset invariant"),
        }
        if p == data.label(i) {
            correct += 1;
        }
    }
    let accuracy = if data.is_empty() {
        f64::NAN
    } else {
        correct as f64 / data.len() as f64
    };
    Evaluation { decisions, predictions, accuracy, confusion: (tp, fp, tn, fnn) }
}

/// Score `data` once (batch scorer with `threads` workers) and derive
/// decisions, predictions, accuracy and confusion counts from the
/// single pass.
pub fn evaluate(model: &SvmModel, data: &Dataset, threads: usize) -> Evaluation {
    let decisions = model.scorer().with_threads(threads).decision_values(data);
    evaluation_from(decisions, data)
}

/// Like [`evaluate`] over a caller-built scorer (reuse one scorer — and
/// its precomputed support-side invariants — across several datasets).
pub fn evaluate_with(scorer: &Scorer<'_>, data: &Dataset) -> Evaluation {
    evaluation_from(scorer.decision_values(data), data)
}

/// Decision values for every row of `data` (one batch pass).
pub fn decision_values(model: &SvmModel, data: &Dataset) -> Vec<f64> {
    model.scorer().decision_values(data)
}

/// Predicted labels for every row (one batch pass).
pub fn predict_all(model: &SvmModel, data: &Dataset) -> Vec<i8> {
    decision_values(model, data).into_iter().map(label_of).collect()
}

/// Classification accuracy against the dataset's labels.
pub fn accuracy(model: &SvmModel, data: &Dataset) -> f64 {
    if data.is_empty() {
        return f64::NAN;
    }
    evaluate(model, data, 1).accuracy
}

/// Confusion counts (tp, fp, tn, fn) with +1 as the positive class.
pub fn confusion(model: &SvmModel, data: &Dataset) -> (usize, usize, usize, usize) {
    evaluate(model, data, 1).confusion
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::function::KernelFunction;

    fn linear_stump() -> SvmModel {
        // A linear-kernel "model" implementing f(x) = x0: one SV at (1, 0)
        // with coef 1 and no bias.
        let sv = Dataset::new(2, vec![1.0, 0.0], vec![1]);
        SvmModel {
            kernel: KernelFunction::Linear,
            support: sv,
            coef: vec![1.0],
            bias: 0.0,
            platt: None,
        }
    }

    fn quadrant_data() -> Dataset {
        Dataset::new(
            2,
            vec![2.0, 0.0, -3.0, 1.0, 0.5, -1.0, -0.1, 0.0],
            vec![1, -1, 1, -1],
        )
    }

    #[test]
    fn accuracy_and_confusion_hand_checked() {
        let m = linear_stump();
        let d = quadrant_data();
        assert_eq!(predict_all(&m, &d), vec![1, -1, 1, -1]);
        assert_eq!(accuracy(&m, &d), 1.0);
        assert_eq!(confusion(&m, &d), (2, 0, 2, 0));
    }

    #[test]
    fn decision_values_match_model() {
        let m = linear_stump();
        let d = quadrant_data();
        let vals = decision_values(&m, &d);
        for (got, want) in vals.iter().zip([2.0, -3.0, 0.5, -0.1]) {
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
    }

    #[test]
    fn evaluate_derives_every_metric_from_one_pass() {
        let m = linear_stump();
        let d = quadrant_data();
        let ev = evaluate(&m, &d, 1);
        assert_eq!(ev.decisions.len(), 4);
        assert_eq!(ev.predictions, predict_all(&m, &d));
        assert_eq!(ev.accuracy, accuracy(&m, &d));
        assert_eq!(ev.confusion, confusion(&m, &d));
        // the shared-scorer form agrees
        let scorer = m.scorer();
        let ev2 = evaluate_with(&scorer, &d);
        assert_eq!(ev2.predictions, ev.predictions);
        assert_eq!(ev2.confusion, ev.confusion);
    }

    #[test]
    fn threaded_evaluation_matches_single_threaded() {
        let m = linear_stump();
        let d = quadrant_data();
        let one = evaluate(&m, &d, 1);
        let four = evaluate(&m, &d, 4);
        assert_eq!(one.predictions, four.predictions);
        assert!(one
            .decisions
            .iter()
            .zip(&four.decisions)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn empty_dataset_gives_nan_accuracy() {
        let m = linear_stump();
        let d = Dataset::with_dim(2);
        assert!(accuracy(&m, &d).is_nan());
        assert!(evaluate(&m, &d, 1).accuracy.is_nan());
    }
}
