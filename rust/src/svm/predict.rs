//! Batch prediction and evaluation metrics.

use crate::data::dataset::Dataset;

use super::model::SvmModel;

/// Decision values for every row of `data`.
pub fn decision_values(model: &SvmModel, data: &Dataset) -> Vec<f64> {
    (0..data.len()).map(|i| model.decision(data.row(i))).collect()
}

/// Predicted labels for every row.
pub fn predict_all(model: &SvmModel, data: &Dataset) -> Vec<i8> {
    (0..data.len()).map(|i| model.predict(data.row(i))).collect()
}

/// Classification accuracy against the dataset's labels.
pub fn accuracy(model: &SvmModel, data: &Dataset) -> f64 {
    if data.is_empty() {
        return f64::NAN;
    }
    let correct = (0..data.len())
        .filter(|&i| model.predict(data.row(i)) == data.label(i))
        .count();
    correct as f64 / data.len() as f64
}

/// Confusion counts (tp, fp, tn, fn) with +1 as the positive class.
pub fn confusion(model: &SvmModel, data: &Dataset) -> (usize, usize, usize, usize) {
    let (mut tp, mut fp, mut tn, mut fnn) = (0, 0, 0, 0);
    for i in 0..data.len() {
        match (model.predict(data.row(i)), data.label(i)) {
            (1, 1) => tp += 1,
            (1, -1) => fp += 1,
            (-1, -1) => tn += 1,
            (-1, 1) => fnn += 1,
            _ => unreachable!(),
        }
    }
    (tp, fp, tn, fnn)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::function::KernelFunction;

    fn linear_stump() -> SvmModel {
        // A linear-kernel "model" implementing f(x) = x0: one SV at (1, 0)
        // with coef 1 and no bias.
        let sv = Dataset::new(2, vec![1.0, 0.0], vec![1]);
        SvmModel { kernel: KernelFunction::Linear, support: sv, coef: vec![1.0], bias: 0.0 }
    }

    fn quadrant_data() -> Dataset {
        Dataset::new(
            2,
            vec![2.0, 0.0, -3.0, 1.0, 0.5, -1.0, -0.1, 0.0],
            vec![1, -1, 1, -1],
        )
    }

    #[test]
    fn accuracy_and_confusion_hand_checked() {
        let m = linear_stump();
        let d = quadrant_data();
        assert_eq!(predict_all(&m, &d), vec![1, -1, 1, -1]);
        assert_eq!(accuracy(&m, &d), 1.0);
        assert_eq!(confusion(&m, &d), (2, 0, 2, 0));
    }

    #[test]
    fn decision_values_match_model() {
        let m = linear_stump();
        let d = quadrant_data();
        let vals = decision_values(&m, &d);
        for (got, want) in vals.iter().zip([2.0, -3.0, 0.5, -0.1]) {
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
    }

    #[test]
    fn empty_dataset_gives_nan_accuracy() {
        let m = linear_stump();
        let d = Dataset::with_dim(2);
        assert!(accuracy(&m, &d).is_nan());
    }
}
