//! Platt scaling: probability calibration for SVM decision values.
//!
//! Fits `P(y=1|f) = 1/(1+exp(A·f+B))` by regularized maximum likelihood
//! (Lin, Lin & Weng's robust Newton variant of Platt's algorithm).

use crate::data::dataset::Dataset;

use super::model::SvmModel;
use super::predict::decision_values;

/// Fitted sigmoid parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlattScaler {
    /// Sigmoid slope A.
    pub a: f64,
    /// Sigmoid offset B.
    pub b: f64,
}

impl PlattScaler {
    /// Calibrated probability of the positive class for decision value `f`.
    pub fn prob(&self, f: f64) -> f64 {
        let z = self.a * f + self.b;
        // numerically stable logistic
        if z >= 0.0 {
            (-z).exp() / (1.0 + (-z).exp())
        } else {
            1.0 / (1.0 + z.exp())
        }
    }

    /// Fit from decision values and ±1 labels (Newton with backtracking).
    pub fn fit(decisions: &[f64], labels: &[i8]) -> PlattScaler {
        assert_eq!(decisions.len(), labels.len());
        let n = labels.len();
        let n_pos = labels.iter().filter(|&&y| y == 1).count() as f64;
        let n_neg = n as f64 - n_pos;
        // Regularized targets (Platt's prior correction).
        let t_pos = (n_pos + 1.0) / (n_pos + 2.0);
        let t_neg = 1.0 / (n_neg + 2.0);
        let t: Vec<f64> = labels
            .iter()
            .map(|&y| if y == 1 { t_pos } else { t_neg })
            .collect();

        let (mut a, mut b) = (0.0f64, ((n_neg + 1.0) / (n_pos + 1.0)).ln());
        let objective = |a: f64, b: f64| -> f64 {
            let mut obj = 0.0;
            for i in 0..n {
                let z = a * decisions[i] + b;
                // -[t log p + (1-t) log(1-p)] in stable form
                obj += if z >= 0.0 {
                    t[i] * z + (1.0 + (-z).exp()).ln()
                } else {
                    (t[i] - 1.0) * z + (1.0 + z.exp()).ln()
                };
            }
            obj
        };
        let mut fval = objective(a, b);
        for _ in 0..100 {
            // gradient and Hessian
            let (mut g1, mut g2, mut h11, mut h22, mut h12) = (0.0, 0.0, 1e-12, 1e-12, 0.0);
            for i in 0..n {
                let z = a * decisions[i] + b;
                let p = if z >= 0.0 {
                    (-z).exp() / (1.0 + (-z).exp())
                } else {
                    1.0 / (1.0 + z.exp())
                };
                let d1 = t[i] - p;
                let d2 = p * (1.0 - p);
                g1 += decisions[i] * d1;
                g2 += d1;
                h11 += decisions[i] * decisions[i] * d2;
                h22 += d2;
                h12 += decisions[i] * d2;
            }
            if g1.abs() < 1e-10 && g2.abs() < 1e-10 {
                break;
            }
            // Newton direction: Δ = −H⁻¹∇F (dF/dz = t − p, so ∇F = (g1, g2)).
            let det = h11 * h22 - h12 * h12;
            let da = -(h22 * g1 - h12 * g2) / det;
            let db = -(h11 * g2 - h12 * g1) / det;
            let gd = g1 * da + g2 * db; // directional derivative (< 0)
            // backtracking (Armijo) line search
            let mut step = 1.0;
            loop {
                let (na, nb) = (a + step * da, b + step * db);
                let nf = objective(na, nb);
                if nf <= fval + 1e-4 * step * gd + 1e-12 {
                    a = na;
                    b = nb;
                    fval = nf;
                    break;
                }
                step *= 0.5;
                if step < 1e-10 {
                    return PlattScaler { a, b };
                }
            }
        }
        PlattScaler { a, b }
    }

    /// Calibrated probabilities for a whole batch of decision values
    /// (pairs with one [`decision_values`] scoring pass).
    pub fn prob_all(&self, decisions: &[f64]) -> Vec<f64> {
        decisions.iter().map(|&f| self.prob(f)).collect()
    }

    /// Fit against a model's decision values on a calibration set (one
    /// batch scoring pass through the shared scorer).
    pub fn fit_model(model: &SvmModel, calibration: &Dataset) -> PlattScaler {
        let d = decision_values(model, calibration);
        PlattScaler::fit(&d, calibration.labels())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    fn synthetic(n: usize, sep: f64, seed: u64) -> (Vec<f64>, Vec<i8>) {
        let mut rng = Pcg::new(seed);
        let mut d = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let label: i8 = if rng.bernoulli(0.5) { 1 } else { -1 };
            d.push(label as f64 * sep + rng.normal());
            y.push(label);
        }
        (d, y)
    }

    #[test]
    fn probabilities_are_monotone_and_calibrated_in_sign() {
        let (d, y) = synthetic(2000, 1.5, 1);
        let s = PlattScaler::fit(&d, &y);
        assert!(s.prob(3.0) > 0.9, "{:?} p(3)={}", s, s.prob(3.0));
        assert!(s.prob(-3.0) < 0.1);
        assert!((s.prob(0.0) - 0.5).abs() < 0.1);
        // monotone increasing in f (A must be negative)
        assert!(s.a < 0.0);
        let mut prev = 0.0;
        for k in -10..=10 {
            let p = s.prob(k as f64 * 0.5);
            assert!(p >= prev - 1e-12);
            prev = p;
        }
    }

    #[test]
    fn well_separated_data_gives_sharp_sigmoid() {
        let (d1, y1) = synthetic(1000, 0.5, 2);
        let (d2, y2) = synthetic(1000, 4.0, 2);
        let s1 = PlattScaler::fit(&d1, &y1);
        let s2 = PlattScaler::fit(&d2, &y2);
        assert!(s2.a.abs() > s1.a.abs(), "sharper separation => steeper sigmoid");
    }

    #[test]
    fn probabilities_in_unit_interval_even_for_extreme_inputs() {
        let (d, y) = synthetic(500, 2.0, 3);
        let s = PlattScaler::fit(&d, &y);
        for f in [-1e6, -1.0, 0.0, 1.0, 1e6] {
            let p = s.prob(f);
            assert!((0.0..=1.0).contains(&p), "p({f}) = {p}");
        }
    }

    #[test]
    fn degenerate_single_class_does_not_blow_up() {
        let d = vec![1.0, 2.0, 3.0, 4.0];
        let y = vec![1i8, 1, 1, 1];
        let s = PlattScaler::fit(&d, &y);
        // prior correction keeps probabilities strictly inside (0, 1)
        let p = s.prob(2.5);
        assert!(p > 0.5 && p < 1.0, "p = {p}");
    }
}
