//! Grid search over (C, γ) on cross-validation accuracy — how the paper
//! selected the Table-1 hyper-parameters ("grid search on the
//! cross-validation error to ensure … the resulting classifiers
//! generalize reasonably well").
//!
//! With [`WarmStart::Seeded`] the search threads one [`CvSession`]
//! through the whole grid: every fold of every grid point starts from
//! the α the same fold reached at the previous point. Adjacent points
//! pose similar QPs, so the seeded sweep finishes the identical grid in
//! measurably fewer total solver iterations (asserted in tests) while
//! evaluating the same accuracies to within solver tolerance.

use crate::data::dataset::Dataset;
use crate::kernel::function::KernelFunction;

use super::crossval::{cross_validate_session, CvSession};
use super::trainer::Trainer;

/// Whether grid points seed their neighbours.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WarmStart {
    /// Every grid point solves from α = 0.
    Cold,
    /// α flows from grid point to grid point through a [`CvSession`].
    Seeded,
}

/// One evaluated grid point.
#[derive(Debug, Clone, Copy)]
pub struct GridPoint {
    /// Regularization constant C at this point.
    pub c: f64,
    /// RBF kernel width γ at this point.
    pub gamma: f64,
    /// k-fold cross-validation accuracy.
    pub cv_accuracy: f64,
    /// Solver iterations this point's CV spent (all folds).
    pub iterations: u64,
}

/// Result of a grid search.
#[derive(Debug, Clone)]
pub struct GridSearchResult {
    /// Every evaluated point, in sweep order (C-major, γ-minor).
    pub evaluated: Vec<GridPoint>,
    /// The winning point (ties break toward smaller C, then smaller γ).
    pub best: GridPoint,
    /// Solver iterations summed over the whole grid.
    pub total_iterations: u64,
}

/// Exhaustive grid search with `k`-fold CV. Ties break toward smaller C
/// then smaller γ (prefer the smoother machine).
///
/// ```
/// use pasmo::svm::gridsearch::{grid_search, log_grid, WarmStart};
/// use pasmo::svm::Trainer;
///
/// let data = pasmo::data::synth::chessboard(90, 4, 7);
/// let base = Trainer::rbf(1.0, 1.0);
/// let res =
///     grid_search(&data, &log_grid(10.0, 0, 1), &[0.5], 3, 1, &base, WarmStart::Seeded);
/// assert_eq!(res.evaluated.len(), 2); // C ∈ {1, 10} × γ ∈ {0.5}
/// assert!(res.evaluated.iter().any(|p| p.c == res.best.c && p.gamma == res.best.gamma));
/// ```
pub fn grid_search(
    data: &Dataset,
    cs: &[f64],
    gammas: &[f64],
    k: usize,
    seed: u64,
    base: &Trainer,
    warm: WarmStart,
) -> GridSearchResult {
    assert!(!cs.is_empty() && !gammas.is_empty());
    let mut evaluated = Vec::with_capacity(cs.len() * gammas.len());
    let mut session = CvSession::new();
    let mut total_iterations = 0u64;
    for &c in cs {
        for &gamma in gammas {
            let trainer = base.clone().c(c).kernel(KernelFunction::Rbf { gamma });
            if warm == WarmStart::Cold {
                session = CvSession::new();
            }
            let cv = cross_validate_session(data, &trainer, k, seed, &mut session);
            total_iterations += cv.total_iterations;
            evaluated.push(GridPoint {
                c,
                gamma,
                cv_accuracy: cv.mean_accuracy,
                iterations: cv.total_iterations,
            });
        }
    }
    // Best = highest CV accuracy, ties broken toward smaller C then
    // smaller γ (less regularization risk at equal accuracy).
    let mut best = evaluated[0];
    for &p in &evaluated[1..] {
        let better = p
            .cv_accuracy
            .total_cmp(&best.cv_accuracy)
            .then(best.c.total_cmp(&p.c))
            .then(best.gamma.total_cmp(&p.gamma))
            .is_gt();
        if better {
            best = p;
        }
    }
    GridSearchResult { evaluated, best, total_iterations }
}

/// The standard logarithmic grid `base^lo .. base^hi`.
pub fn log_grid(base: f64, lo: i32, hi: i32) -> Vec<f64> {
    (lo..=hi).map(|e| base.powi(e)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::chessboard;

    #[test]
    fn log_grid_values() {
        assert_eq!(log_grid(10.0, -1, 1), vec![0.1, 1.0, 10.0]);
        assert_eq!(log_grid(2.0, 0, 2), vec![1.0, 2.0, 4.0]);
    }

    #[test]
    fn finds_a_sensible_region_on_chessboard() {
        let ds = chessboard(200, 4, 7);
        let base = Trainer::rbf(1.0, 1.0);
        let res = grid_search(
            &ds,
            &[1.0, 100.0],
            &[0.005, 0.5],
            3,
            1,
            &base,
            WarmStart::Cold,
        );
        assert_eq!(res.evaluated.len(), 4);
        // the wide-kernel tiny-C corner should not win on chessboard
        assert!(res.best.cv_accuracy >= 0.6, "{:?}", res.best);
        assert!(
            !(res.best.c == 1.0 && res.best.gamma == 0.005),
            "degenerate corner won: {:?}",
            res.best
        );
    }

    #[test]
    fn evaluates_full_grid() {
        let ds = chessboard(100, 4, 8);
        let base = Trainer::rbf(1.0, 1.0);
        let res =
            grid_search(&ds, &[0.1, 1.0, 10.0], &[0.1, 1.0], 3, 2, &base, WarmStart::Cold);
        assert_eq!(res.evaluated.len(), 6);
        let best_in_list = res
            .evaluated
            .iter()
            .any(|p| p.c == res.best.c && p.gamma == res.best.gamma);
        assert!(best_in_list);
        assert_eq!(
            res.total_iterations,
            res.evaluated.iter().map(|p| p.iterations).sum::<u64>()
        );
    }

    #[test]
    fn warm_started_grid_uses_fewer_total_iterations() {
        // The acceptance metric of the warm-start redesign: the same
        // grid, the same folds, measurably fewer solver iterations.
        let ds = chessboard(220, 4, 9);
        let base = Trainer::rbf(1.0, 1.0);
        let cs = [5.0, 10.0, 20.0];
        let gammas = [0.3, 0.5, 0.8];
        let cold = grid_search(&ds, &cs, &gammas, 3, 4, &base, WarmStart::Cold);
        let warm = grid_search(&ds, &cs, &gammas, 3, 4, &base, WarmStart::Seeded);
        assert!(
            warm.total_iterations < cold.total_iterations,
            "warm {} !< cold {}",
            warm.total_iterations,
            cold.total_iterations
        );
        // model selection is unchanged in quality: accuracies agree per point
        for (a, b) in cold.evaluated.iter().zip(&warm.evaluated) {
            assert!(
                (a.cv_accuracy - b.cv_accuracy).abs() < 0.06,
                "C={} γ={}: {} vs {}",
                a.c,
                a.gamma,
                a.cv_accuracy,
                b.cv_accuracy
            );
        }
    }
}
