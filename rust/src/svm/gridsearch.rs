//! Grid search over (C, γ) on cross-validation accuracy — how the paper
//! selected the Table-1 hyper-parameters ("grid search on the
//! cross-validation error to ensure … the resulting classifiers
//! generalize reasonably well").

use crate::data::dataset::Dataset;
use crate::kernel::function::KernelFunction;

use super::crossval::cross_validate;
use super::train::TrainConfig;

/// One evaluated grid point.
#[derive(Debug, Clone, Copy)]
pub struct GridPoint {
    pub c: f64,
    pub gamma: f64,
    pub cv_accuracy: f64,
}

/// Result of a grid search.
#[derive(Debug, Clone)]
pub struct GridSearchResult {
    pub evaluated: Vec<GridPoint>,
    pub best: GridPoint,
}

/// Exhaustive grid search with `k`-fold CV. Ties break toward smaller C
/// then smaller γ (prefer the smoother machine).
pub fn grid_search(
    data: &Dataset,
    cs: &[f64],
    gammas: &[f64],
    k: usize,
    seed: u64,
    base: &TrainConfig,
) -> GridSearchResult {
    assert!(!cs.is_empty() && !gammas.is_empty());
    let mut evaluated = Vec::with_capacity(cs.len() * gammas.len());
    for &c in cs {
        for &gamma in gammas {
            let cfg = TrainConfig {
                c,
                kernel: KernelFunction::Rbf { gamma },
                ..*base
            };
            let cv = cross_validate(data, &cfg, k, seed);
            evaluated.push(GridPoint { c, gamma, cv_accuracy: cv.mean_accuracy });
        }
    }
    let best = *evaluated
        .iter()
        .max_by(|a, b| {
            (a.cv_accuracy, -a.c, -a.gamma)
                .partial_cmp(&(b.cv_accuracy, -b.c, -b.gamma))
                .unwrap()
        })
        .unwrap();
    GridSearchResult { evaluated, best }
}

/// The standard logarithmic grid `base^lo .. base^hi`.
pub fn log_grid(base: f64, lo: i32, hi: i32) -> Vec<f64> {
    (lo..=hi).map(|e| base.powi(e)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::chessboard;

    #[test]
    fn log_grid_values() {
        assert_eq!(log_grid(10.0, -1, 1), vec![0.1, 1.0, 10.0]);
        assert_eq!(log_grid(2.0, 0, 2), vec![1.0, 2.0, 4.0]);
    }

    #[test]
    fn finds_a_sensible_region_on_chessboard() {
        let ds = chessboard(200, 4, 7);
        let base = TrainConfig::new(1.0, 1.0);
        let res = grid_search(
            &ds,
            &[1.0, 100.0],
            &[0.005, 0.5],
            3,
            1,
            &base,
        );
        assert_eq!(res.evaluated.len(), 4);
        // the wide-kernel tiny-C corner should not win on chessboard
        assert!(res.best.cv_accuracy >= 0.6, "{:?}", res.best);
        assert!(
            !(res.best.c == 1.0 && res.best.gamma == 0.005),
            "degenerate corner won: {:?}",
            res.best
        );
    }

    #[test]
    fn evaluates_full_grid() {
        let ds = chessboard(100, 4, 8);
        let base = TrainConfig::new(1.0, 1.0);
        let res = grid_search(&ds, &[0.1, 1.0, 10.0], &[0.1, 1.0], 3, 2, &base);
        assert_eq!(res.evaluated.len(), 6);
        let best_in_list = res
            .evaluated
            .iter()
            .any(|p| p.c == res.best.c && p.gamma == res.best.gamma);
        assert!(best_in_list);
    }
}
