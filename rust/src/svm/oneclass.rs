//! One-class SVM (Schölkopf's ν-formulation) on the PA-SMO solver —
//! second demonstration that the solver handles the paper's general
//! problem class, here with a non-zero equality constant and a warm
//! start whose initial gradient requires kernel evaluations.
//!
//! Dual: `max −½αᵀKα  s.t.  Σα = 1, 0 ≤ α_i ≤ 1/(νℓ)` (linear term 0).
//! Decision: `f(x) = Σ α_i k(x_i, x) − ρ`, inliers have `f ≥ 0`.

use std::path::Path;
use std::sync::Arc;

use crate::data::dataset::Dataset;
use crate::kernel::function::KernelFunction;
use crate::kernel::matrix::Gram;
use crate::kernel::native::NativeRowComputer;
use crate::solver::engine::{Engine, EngineConfig, SolverChoice};
use crate::solver::problem::QpProblem;
use crate::solver::smo::{SolveResult, SolverConfig};
use crate::util::error::Result;

use super::schema;
use super::scorer::Scorer;

/// One-class SVM configuration.
#[derive(Debug, Clone, Copy)]
pub struct OneClassConfig {
    /// ν ∈ (0, 1]: upper bound on the outlier fraction / lower bound on
    /// the support-vector fraction.
    pub nu: f64,
    /// The kernel function.
    pub kernel: KernelFunction,
    /// Which engine drives the solve (any [`SolverChoice`]).
    pub solver: SolverChoice,
    /// Full low-level solver configuration.
    pub solver_config: SolverConfig,
}

impl OneClassConfig {
    /// RBF one-class configuration at (ν, γ) with PA-SMO defaults.
    pub fn new(nu: f64, gamma: f64) -> OneClassConfig {
        assert!(nu > 0.0 && nu <= 1.0, "nu must be in (0, 1]");
        OneClassConfig {
            nu,
            kernel: KernelFunction::Rbf { gamma },
            solver: SolverChoice::Pasmo,
            solver_config: SolverConfig::default(),
        }
    }
}

/// A trained one-class model.
#[derive(Debug, Clone)]
pub struct OneClassModel {
    /// The kernel the model was trained with.
    pub kernel: KernelFunction,
    /// Support vectors (rows with α > 0).
    pub support: Dataset,
    /// Dual coefficients aligned with `support` rows.
    pub coef: Vec<f64>,
    /// Offset ρ.
    pub rho: f64,
}

impl OneClassModel {
    /// Number of support vectors.
    pub fn n_sv(&self) -> usize {
        self.coef.len()
    }

    /// The batch scoring engine over this model's expansion (offset
    /// `−ρ`) — build it once per batch.
    pub fn scorer(&self) -> Scorer<'_> {
        Scorer::new(self.kernel, &self.support, &self.coef, -self.rho)
    }

    /// Decision value; ≥ 0 means inlier (one-off convenience; batch
    /// callers use [`OneClassModel::scorer`] /
    /// [`OneClassModel::decision_values`]).
    pub fn decision(&self, x: &[f32]) -> f64 {
        self.scorer().decision(x)
    }

    /// Decision values for every row of `data` (either storage backend)
    /// — one batch scoring pass with `threads` workers.
    pub fn decision_values(&self, data: &Dataset, threads: usize) -> Vec<f64> {
        self.scorer().with_threads(threads).decision_values(data)
    }

    /// Is `x` on the inlier side of the decision surface?
    pub fn is_inlier(&self, x: &[f32]) -> bool {
        self.decision(x) >= 0.0
    }

    /// Serialize to a JSON file (schema v2, `kind: "oneclass"`).
    pub fn save(&self, path: &Path) -> Result<()> {
        schema::save(path, &schema::oneclass_to_json(self))
    }

    /// Load from a JSON file written by [`OneClassModel::save`].
    pub fn load(path: &Path) -> Result<OneClassModel> {
        match schema::load_any(path)? {
            schema::AnyModel::OneClass(m) => Ok(m),
            other => crate::bail!(
                "{} holds a {:?} model, not a one-class model",
                path.display(),
                other.task_name()
            ),
        }
    }
}

/// Train a one-class SVM on (unlabeled) rows of `data`.
pub fn train_one_class(data: &Arc<Dataset>, cfg: &OneClassConfig) -> (OneClassModel, SolveResult) {
    let l = data.len();
    let nc = NativeRowComputer::with_threads(data.clone(), cfg.kernel, cfg.solver_config.threads);
    let mut gram = Gram::new(Box::new(nc), cfg.solver_config.cache_bytes);
    // The ν-formulation lowering: Σα = 1 with a LIBSVM-style feasible
    // start whose gradient needs ≈ νℓ kernel rows (built by `lower`).
    let problem = QpProblem::one_class(l, cfg.nu);
    let engine = EngineConfig::new(cfg.solver, cfg.solver_config).build();
    let result = engine.solve(&problem, &mut gram);

    let mut support = data.empty_like();
    let mut coef = Vec::new();
    for i in 0..l {
        if result.alpha[i] > 1e-12 {
            support.push_row(data.row_ref(i), 1);
            coef.push(result.alpha[i]);
        }
    }
    // In this formulation bias() returns mean G over free SVs with
    // G = −(Kα); KKT gives (Kα)_i = ρ for free SVs, so ρ = −bias.
    let rho = -result.bias;
    let model = OneClassModel { kernel: cfg.kernel, support, coef, rho };
    (model, result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    fn blob(n: usize, seed: u64) -> Arc<Dataset> {
        let mut rng = Pcg::new(seed);
        let mut ds = Dataset::with_dim(2);
        for _ in 0..n {
            ds.push(&[rng.normal() as f32, rng.normal() as f32], 1);
        }
        Arc::new(ds)
    }

    #[test]
    fn converges_and_respects_nu_bounds() {
        let ds = blob(200, 1);
        let cfg = OneClassConfig::new(0.1, 0.5);
        let (model, res) = train_one_class(&ds, &cfg);
        assert!(res.converged);
        // Σα = 1 preserved
        let sum: f64 = res.alpha.iter().sum();
        assert!((sum - 1.0).abs() < 1e-8, "Σα = {sum}");
        // support fraction ≥ ν (ν-property, approximately)
        assert!(model.coef.len() as f64 >= 0.1 * 200.0 * 0.8);
    }

    #[test]
    fn far_outliers_are_rejected_and_center_accepted() {
        let ds = blob(300, 2);
        let cfg = OneClassConfig::new(0.1, 0.5);
        let (model, _) = train_one_class(&ds, &cfg);
        assert!(model.is_inlier(&[0.0, 0.0]), "blob center must be inlier");
        assert!(!model.is_inlier(&[25.0, 25.0]), "far point must be outlier");
        assert!(!model.is_inlier(&[-30.0, 5.0]));
    }

    #[test]
    fn batch_decisions_match_per_example_and_round_trip() {
        let ds = blob(150, 4);
        let cfg = OneClassConfig::new(0.2, 0.5);
        let (model, _) = train_one_class(&ds, &cfg);
        let queries = blob(80, 5);
        let batch = model.decision_values(&queries, 1);
        let threaded = model.decision_values(&queries, 4);
        for i in 0..queries.len() {
            let one = model.decision(queries.row(i));
            assert_eq!(one.to_bits(), batch[i].to_bits(), "i={i}");
            assert_eq!(one.to_bits(), threaded[i].to_bits(), "i={i} threaded");
        }
        // save/load round trip through the v2 `oneclass` schema
        let dir = std::env::temp_dir().join("pasmo-oneclass-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("oc.json");
        model.save(&path).unwrap();
        let loaded = OneClassModel::load(&path).unwrap();
        assert_eq!(loaded.n_sv(), model.n_sv());
        assert!((loaded.rho - model.rho).abs() < 1e-12);
        for i in 0..queries.len().min(10) {
            let d = (loaded.decision(queries.row(i)) - model.decision(queries.row(i))).abs();
            assert!(d < 1e-9, "i={i}: Δ={d}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn outlier_fraction_tracks_nu() {
        let ds = blob(400, 3);
        for nu in [0.05, 0.3] {
            // smooth boundary (small γ) keeps the ν-property readable
            let cfg = OneClassConfig::new(nu, 0.15);
            let (model, _) = train_one_class(&ds, &cfg);
            // ν-property counts *margin errors* (f strictly below 0);
            // free boundary SVs sit at f ≈ 0 and can flip sign under the
            // ε-approximate KKT + f32 kernel, so count with a small slack.
            let strictly_rejected = (0..ds.len())
                .filter(|&i| model.decision(ds.row(i)) < -1e-3)
                .count() as f64
                / ds.len() as f64;
            let rejected_at_all = (0..ds.len())
                .filter(|&i| !model.is_inlier(ds.row(i)))
                .count() as f64
                / ds.len() as f64;
            assert!(
                strictly_rejected <= nu + 0.05,
                "nu={nu}: margin errors {strictly_rejected}"
            );
            assert!(rejected_at_all >= nu * 0.2, "nu={nu}: rejected only {rejected_at_all}");
        }
    }
}
