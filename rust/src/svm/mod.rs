//! High-level SVM API: classification train / predict / cross-validation
//! / grid search, plus ε-SVR, one-class SVM and Platt probability
//! calibration — all driven through the `solver::Engine` contract.
//!
//! The front door for training is [`Trainer`]: a builder over kernel, C,
//! per-class costs, solver choice and warm start that yields a
//! [`TrainOutcome`]. The front door for inference is the shared batch
//! [`Scorer`] ([`scorer`]): every model kind's decision loops route
//! through it, and every model kind saves/loads through the kind-tagged
//! JSON schema ([`schema`]).
pub mod crossval;
pub mod gridsearch;
pub mod model;
pub mod multiclass;
pub mod oneclass;
pub mod platt;
pub mod predict;
pub mod schema;
pub mod scorer;
pub mod svr;
pub mod trainer;

pub use crate::solver::engine::SolverChoice;
pub use model::SvmModel;
pub use scorer::Scorer;
pub use trainer::{TrainOutcome, Trainer};
