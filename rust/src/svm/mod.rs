//! High-level SVM API: classification train / predict / cross-validation
//! / grid search, plus ε-SVR, one-class SVM and Platt probability
//! calibration — all driven by the same PA-SMO solver core.
pub mod crossval;
pub mod gridsearch;
pub mod model;
pub mod multiclass;
pub mod oneclass;
pub mod platt;
pub mod predict;
pub mod svr;
pub mod train;

pub use model::SvmModel;
pub use train::{train, SolverChoice, TrainConfig};
