//! High-level SVM API: classification train / predict / cross-validation
//! / grid search, plus ε-SVR, one-class SVM and Platt probability
//! calibration — all driven through the `solver::Engine` contract.
//!
//! The front door is [`Trainer`]: a builder over kernel, C, per-class
//! costs, solver choice and warm start that yields a [`TrainOutcome`].
pub mod crossval;
pub mod gridsearch;
pub mod model;
pub mod multiclass;
pub mod oneclass;
pub mod platt;
pub mod predict;
pub mod svr;
pub mod trainer;

pub use crate::solver::engine::SolverChoice;
pub use model::SvmModel;
pub use trainer::{TrainOutcome, Trainer};
