//! High-level training entry point: dataset + config → model + solver
//! diagnostics.

use std::sync::Arc;

use crate::data::dataset::Dataset;
use crate::kernel::function::KernelFunction;
use crate::kernel::matrix::{Gram, RowComputer};
use crate::kernel::native::NativeRowComputer;
use crate::solver::pasmo::PasmoSolver;
use crate::solver::smo::{SmoSolver, SolveResult, SolverConfig};

use super::model::SvmModel;

/// Which solver drives training.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverChoice {
    /// Algorithm 1 (baseline SMO, second-order WSS).
    Smo,
    /// Algorithm 5 (PA-SMO) — the paper's recommended default.
    Pasmo,
    /// Multiple-planning-ahead PA-SMO with N recent working sets (§7.4).
    PasmoMulti(usize),
}

/// Training configuration.
#[derive(Debug, Clone, Copy)]
pub struct TrainConfig {
    pub c: f64,
    pub kernel: KernelFunction,
    pub solver: SolverChoice,
    pub solver_config: SolverConfig,
}

impl TrainConfig {
    /// The paper's defaults: RBF kernel, PA-SMO, ε = 10⁻³.
    pub fn new(c: f64, gamma: f64) -> TrainConfig {
        TrainConfig {
            c,
            kernel: KernelFunction::Rbf { gamma },
            solver: SolverChoice::Pasmo,
            solver_config: SolverConfig::default(),
        }
    }

    pub fn with_solver(mut self, solver: SolverChoice) -> TrainConfig {
        self.solver = solver;
        self
    }
}

/// Run the configured solver over an existing Gram view.
pub fn solve_with_gram(
    labels: &[i8],
    cfg: &TrainConfig,
    gram: &mut Gram,
) -> SolveResult {
    let mut sc = cfg.solver_config;
    match cfg.solver {
        SolverChoice::Smo => SmoSolver::new(sc).solve(labels, cfg.c, gram),
        SolverChoice::Pasmo => {
            sc.planning_candidates = 1;
            PasmoSolver::new(sc).solve(labels, cfg.c, gram)
        }
        SolverChoice::PasmoMulti(n) => {
            sc.planning_candidates = n.max(1);
            PasmoSolver::new(sc).solve(labels, cfg.c, gram)
        }
    }
}

/// Train on a dataset using the native (Rust) kernel path.
pub fn train(data: &Arc<Dataset>, cfg: &TrainConfig) -> (SvmModel, SolveResult) {
    let computer = NativeRowComputer::new(data.clone(), cfg.kernel);
    train_with_computer(data, cfg, Box::new(computer))
}

/// Train with a caller-supplied row computer (e.g. the PJRT-backed
/// `crate::runtime::gram::PjrtRowComputer`, available with the `pjrt`
/// feature). [`train`] is the native-path shorthand — the default build
/// always has that fallback.
pub fn train_with_computer(
    data: &Arc<Dataset>,
    cfg: &TrainConfig,
    computer: Box<dyn RowComputer>,
) -> (SvmModel, SolveResult) {
    let mut gram = Gram::new(computer, cfg.solver_config.cache_bytes);
    let result = solve_with_gram(data.labels(), cfg, &mut gram);
    let model = SvmModel::from_solution(data, &result.alpha, result.bias, cfg.kernel, 1e-12);
    (model, result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::chessboard;
    use crate::svm::predict::accuracy;

    #[test]
    fn trains_a_working_classifier_on_chessboard() {
        let ds = Arc::new(chessboard(300, 4, 1));
        let cfg = TrainConfig::new(100.0, 0.5);
        let (model, res) = train(&ds, &cfg);
        assert!(res.converged);
        assert!(model.n_sv() > 0);
        let train_acc = accuracy(&model, &ds);
        assert!(train_acc > 0.9, "train accuracy {train_acc}");
    }

    #[test]
    fn smo_and_pasmo_produce_equivalent_models() {
        let ds = Arc::new(chessboard(200, 4, 2));
        let base = TrainConfig::new(10.0, 0.5);
        let (m1, r1) = train(&ds, &base.with_solver(SolverChoice::Smo));
        let (m2, r2) = train(&ds, &base.with_solver(SolverChoice::Pasmo));
        assert!(r1.converged && r2.converged);
        let rel = (r1.objective - r2.objective).abs() / (1.0 + r1.objective.abs());
        assert!(rel < 2e-3, "{} vs {}", r1.objective, r2.objective);
        // decisions agree on most points
        let mut agree = 0;
        for i in 0..ds.len() {
            if m1.predict(ds.row(i)) == m2.predict(ds.row(i)) {
                agree += 1;
            }
        }
        assert!(agree as f64 / ds.len() as f64 > 0.97);
    }

    #[test]
    fn multi_planning_choice_works() {
        let ds = Arc::new(chessboard(150, 4, 3));
        let cfg = TrainConfig::new(50.0, 0.5).with_solver(SolverChoice::PasmoMulti(3));
        let (_, res) = train(&ds, &cfg);
        assert!(res.converged);
    }
}
