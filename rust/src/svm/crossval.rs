//! k-fold cross-validation (the model-selection machinery behind the
//! paper's Table-1 grid search).

use std::sync::Arc;

use crate::data::dataset::Dataset;
use crate::data::splits::kfold;

use super::predict::accuracy;
use super::train::{train, TrainConfig};

/// Result of a cross-validation run.
#[derive(Debug, Clone)]
pub struct CvResult {
    pub fold_accuracies: Vec<f64>,
    pub mean_accuracy: f64,
}

/// k-fold cross-validated accuracy of `cfg` on `data`.
pub fn cross_validate(data: &Dataset, cfg: &TrainConfig, k: usize, seed: u64) -> CvResult {
    let folds = kfold(data.len(), k, seed);
    let mut fold_accuracies = Vec::with_capacity(k);
    for (train_idx, test_idx) in folds {
        let train_set = Arc::new(data.subset(&train_idx));
        let test_set = data.subset(&test_idx);
        let (model, _) = train(&train_set, cfg);
        fold_accuracies.push(accuracy(&model, &test_set));
    }
    let mean_accuracy = fold_accuracies.iter().sum::<f64>() / k as f64;
    CvResult { fold_accuracies, mean_accuracy }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::chessboard;
    use crate::data::synth::surrogate::{surrogate, SurrogateSpec};

    #[test]
    fn cv_on_separable_data_is_accurate() {
        let ds = chessboard(240, 4, 5);
        let cfg = TrainConfig::new(100.0, 0.5);
        let cv = cross_validate(&ds, &cfg, 4, 1);
        assert_eq!(cv.fold_accuracies.len(), 4);
        assert!(cv.mean_accuracy > 0.75, "{:?}", cv);
    }

    #[test]
    fn cv_detects_hopeless_configurations() {
        // label noise 50% => accuracy ~ 0.5 regardless of config
        let spec = SurrogateSpec { label_noise: 0.5, ..Default::default() };
        let ds = surrogate(160, &spec, 3);
        let cfg = TrainConfig::new(1.0, 0.1);
        let cv = cross_validate(&ds, &cfg, 4, 2);
        assert!(cv.mean_accuracy < 0.72, "noise should cap accuracy: {:?}", cv);
    }

    #[test]
    fn folds_use_disjoint_test_data() {
        // indirectly: fold accuracies vary but mean is stable across seeds
        let ds = chessboard(160, 4, 6);
        let cfg = TrainConfig::new(10.0, 0.5);
        let a = cross_validate(&ds, &cfg, 4, 1).mean_accuracy;
        let b = cross_validate(&ds, &cfg, 4, 99).mean_accuracy;
        assert!((a - b).abs() < 0.25);
    }
}
