//! k-fold cross-validation (the model-selection machinery behind the
//! paper's Table-1 grid search), with warm-start *sessions* that carry
//! each fold's α across repeated evaluations — the mechanism grid search
//! uses to seed adjacent grid points.

use std::sync::Arc;

use crate::data::dataset::Dataset;
use crate::data::splits::kfold;

use super::predict::accuracy;
use super::trainer::Trainer;

/// Result of a cross-validation run.
#[derive(Debug, Clone)]
pub struct CvResult {
    /// Held-out accuracy of each fold, in fold order.
    pub fold_accuracies: Vec<f64>,
    /// Mean of the fold accuracies.
    pub mean_accuracy: f64,
    /// Total solver iterations across all folds (the warm-start metric).
    pub total_iterations: u64,
}

/// Per-fold warm-start state carried between cross-validation runs of
/// the *same* (data, k, seed) split — fold index f always sees the same
/// training subset, so its last α is a valid seed for the next
/// evaluation (e.g. the neighbouring grid point). Bounds changes (a
/// different C) are repaired at lowering.
///
/// ```
/// use pasmo::svm::crossval::{cross_validate_session, CvSession};
/// use pasmo::svm::Trainer;
///
/// let data = pasmo::data::synth::chessboard(120, 4, 3);
/// let trainer = Trainer::rbf(50.0, 0.5);
/// let mut session = CvSession::new();
/// let cold = cross_validate_session(&data, &trainer, 4, 1, &mut session);
/// // Re-evaluating the same split re-solves every fold from its own
/// // solution — (nearly) free, identical accuracy.
/// let warm = cross_validate_session(&data, &trainer, 4, 1, &mut session);
/// assert!(warm.total_iterations < cold.total_iterations);
/// assert!((warm.mean_accuracy - cold.mean_accuracy).abs() < 0.05);
/// ```
#[derive(Debug, Clone, Default)]
pub struct CvSession {
    fold_alphas: Vec<Option<Vec<f64>>>,
}

impl CvSession {
    /// An empty session: the first run it seeds degrades to cold starts.
    pub fn new() -> CvSession {
        CvSession::default()
    }

    fn seed(&self, fold: usize) -> Option<&Vec<f64>> {
        self.fold_alphas.get(fold).and_then(|a| a.as_ref())
    }

    fn store(&mut self, fold: usize, alpha: Vec<f64>) {
        if self.fold_alphas.len() <= fold {
            self.fold_alphas.resize(fold + 1, None);
        }
        self.fold_alphas[fold] = Some(alpha);
    }
}

/// k-fold cross-validated accuracy of `trainer` on `data` (cold start).
pub fn cross_validate(data: &Dataset, trainer: &Trainer, k: usize, seed: u64) -> CvResult {
    cross_validate_session(data, trainer, k, seed, &mut CvSession::new())
}

/// k-fold cross-validation seeding every fold from `session` and storing
/// the resulting α back. An empty session degrades to a cold start.
pub fn cross_validate_session(
    data: &Dataset,
    trainer: &Trainer,
    k: usize,
    seed: u64,
    session: &mut CvSession,
) -> CvResult {
    let folds = kfold(data.len(), k, seed);
    let mut fold_accuracies = Vec::with_capacity(k);
    let mut total_iterations = 0u64;
    for (fold, (train_idx, test_idx)) in folds.into_iter().enumerate() {
        let train_set = Arc::new(data.subset(&train_idx));
        let test_set = data.subset(&test_idx);
        // The session is the only valid fold-level seed: a caller-set
        // `warm_start` is sized for the full dataset, not this fold.
        let mut fold_trainer = trainer.clone();
        fold_trainer.warm_start = match session.seed(fold) {
            Some(alpha) if alpha.len() == train_set.len() => Some(alpha.clone()),
            _ => None,
        };
        let out = fold_trainer.train(&train_set);
        total_iterations += out.result.iterations;
        session.store(fold, out.result.alpha);
        fold_accuracies.push(accuracy(&out.model, &test_set));
    }
    let mean_accuracy = fold_accuracies.iter().sum::<f64>() / k as f64;
    CvResult { fold_accuracies, mean_accuracy, total_iterations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::chessboard;
    use crate::data::synth::surrogate::{surrogate, SurrogateSpec};

    #[test]
    fn cv_on_separable_data_is_accurate() {
        let ds = chessboard(240, 4, 5);
        let trainer = Trainer::rbf(100.0, 0.5);
        let cv = cross_validate(&ds, &trainer, 4, 1);
        assert_eq!(cv.fold_accuracies.len(), 4);
        assert!(cv.mean_accuracy > 0.75, "{:?}", cv);
        assert!(cv.total_iterations > 0);
    }

    #[test]
    fn cv_detects_hopeless_configurations() {
        // label noise 50% => accuracy ~ 0.5 regardless of config
        let spec = SurrogateSpec { label_noise: 0.5, ..Default::default() };
        let ds = surrogate(160, &spec, 3);
        let trainer = Trainer::rbf(1.0, 0.1);
        let cv = cross_validate(&ds, &trainer, 4, 2);
        assert!(cv.mean_accuracy < 0.72, "noise should cap accuracy: {:?}", cv);
    }

    #[test]
    fn folds_use_disjoint_test_data() {
        // indirectly: fold accuracies vary but mean is stable across seeds
        let ds = chessboard(160, 4, 6);
        let trainer = Trainer::rbf(10.0, 0.5);
        let a = cross_validate(&ds, &trainer, 4, 1).mean_accuracy;
        let b = cross_validate(&ds, &trainer, 4, 99).mean_accuracy;
        assert!((a - b).abs() < 0.25);
    }

    #[test]
    fn caller_level_warm_start_does_not_leak_into_folds() {
        // A trainer seeded for the *full* dataset must still cross-validate:
        // fold problems are smaller, so the stale seed is dropped per fold.
        let ds = chessboard(120, 4, 8);
        let trainer = Trainer::rbf(10.0, 0.5).warm_start(vec![0.0; ds.len()]);
        let cv = cross_validate(&ds, &trainer, 4, 1);
        assert_eq!(cv.fold_accuracies.len(), 4);
    }

    #[test]
    fn session_reuse_cuts_iterations_on_the_same_configuration() {
        let ds = chessboard(200, 4, 7);
        let trainer = Trainer::rbf(50.0, 0.5);
        let mut session = CvSession::new();
        let first = cross_validate_session(&ds, &trainer, 4, 3, &mut session);
        let second = cross_validate_session(&ds, &trainer, 4, 3, &mut session);
        // Re-solving the identical problems from their own solutions is
        // (nearly) free, and accuracy is unchanged.
        assert!(
            second.total_iterations < first.total_iterations / 4,
            "warm {} !< cold {} / 4",
            second.total_iterations,
            first.total_iterations
        );
        assert!((first.mean_accuracy - second.mean_accuracy).abs() < 0.05);
    }
}
