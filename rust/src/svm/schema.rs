//! The unified model JSON schema (v2): one kind-tagged document shape
//! for every model kind, so SVR / one-class / multiclass models
//! save/load exactly like the binary classifier.
//!
//! Common envelope: `{"format": "pasmo-model", "version": 2,
//! "kind": "svc" | "svr" | "oneclass" | "multiclass", ...}` plus the
//! kernel fields (`kernel`/`gamma`/`coef0`/`degree`), `dim`, and the
//! kind's payload:
//!
//! * `svc` — `bias`, `coef`, `labels`, `sv`, optional `platt: {a, b}`;
//! * `svr` — `bias`, `coef`, `sv`;
//! * `oneclass` — `rho`, `coef`, `sv`;
//! * `multiclass` — `classes`, `pairs`, `machines` (an array of `svc`
//!   payloads, one per class pair).
//!
//! v1 files (no `kind` tag) load as `svc` — the pre-v2 classifier
//! schema is a strict subset. Parsing is **strict with positioned
//! errors**: a non-numeric entry in `coef`/`labels`/`sv`/`classes`
//! fails as e.g. `coef[3]: expected a number` instead of being silently
//! dropped into a count mismatch (or a same-count misalignment).

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::artifact;
use crate::util::error::{Context, Error, Result};
use crate::util::json::Json;
use crate::{bail, ensure};

use crate::data::dataset::Dataset;
use crate::kernel::function::KernelFunction;

use super::model::SvmModel;
use super::multiclass::OvoModel;
use super::oneclass::OneClassModel;
use super::platt::PlattScaler;
use super::svr::SvrModel;

/// Any model the unified schema can hold, tagged by kind.
#[derive(Debug, Clone)]
pub enum AnyModel {
    /// A binary classifier (`kind: "svc"`, or a v1 file).
    Svc(SvmModel),
    /// An ε-SVR regressor (`kind: "svr"`).
    Svr(SvrModel),
    /// A one-class model (`kind: "oneclass"`).
    OneClass(OneClassModel),
    /// A one-vs-one multiclass model (`kind: "multiclass"`).
    Multiclass(OvoModel),
}

impl AnyModel {
    /// The prediction task this model serves — the `--task` vocabulary
    /// of `pasmo predict` (`classify | svr | oneclass | multiclass`).
    pub fn task_name(&self) -> &'static str {
        match self {
            AnyModel::Svc(_) => "classify",
            AnyModel::Svr(_) => "svr",
            AnyModel::OneClass(_) => "oneclass",
            AnyModel::Multiclass(_) => "multiclass",
        }
    }

    /// Feature dimension the model's support vectors live in.
    pub fn dim(&self) -> usize {
        match self {
            AnyModel::Svc(m) => m.support.dim(),
            AnyModel::Svr(m) => m.support.dim(),
            AnyModel::OneClass(m) => m.support.dim(),
            AnyModel::Multiclass(m) => m.machines[0].support.dim(),
        }
    }

    /// Total support vectors (summed over the machines of a multiclass
    /// model) — the size driver of a scoring pass, reported by the
    /// serving tier's registry.
    pub fn n_sv(&self) -> usize {
        match self {
            AnyModel::Svc(m) => m.n_sv(),
            AnyModel::Svr(m) => m.n_sv(),
            AnyModel::OneClass(m) => m.n_sv(),
            AnyModel::Multiclass(m) => m.machines.iter().map(SvmModel::n_sv).sum(),
        }
    }
}

/// Load any model file, dispatching on its `kind` tag (absent = v1
/// classifier).
pub fn load_any(path: &Path) -> Result<AnyModel> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let v = Json::parse(&text).map_err(|e| Error::msg(format!("parse model: {e}")))?;
    // Artifacts written by `save` carry a content checksum; verify it
    // before trusting any field. Older files without one still load.
    artifact::verify_checksum(&v).with_context(|| format!("load {}", path.display()))?;
    let kind = match v.get("kind") {
        None => "svc", // v1 files predate the tag
        Some(k) => k.as_str().context("kind: expected a string")?,
    };
    let loaded = match kind {
        "svc" => AnyModel::Svc(svc_of_json(&v)?),
        "svr" => AnyModel::Svr(svr_of_json(&v)?),
        "oneclass" => AnyModel::OneClass(oneclass_of_json(&v)?),
        "multiclass" => AnyModel::Multiclass(ovo_of_json(&v)?),
        other => bail!("unknown model kind {other:?}"),
    };
    Ok(loaded)
}

/// Write a schema document to disk: checksummed, then atomically via a
/// temp file + rename in the target directory ([`crate::util::artifact`]).
/// A crash or IO failure mid-save leaves the previous file (or nothing)
/// on disk — never a truncated model.
pub fn save(path: &Path, doc: &Json) -> Result<()> {
    artifact::save_json(path, doc.clone())
        .with_context(|| format!("write {}", path.display()))
}

/// The common envelope: format/version/kind plus the kernel fields and
/// the support dimension.
fn envelope(kind: &str, kernel: KernelFunction, dim: usize) -> BTreeMap<String, Json> {
    let mut obj = BTreeMap::new();
    obj.insert("format".into(), Json::Str("pasmo-model".into()));
    obj.insert("version".into(), Json::Num(2.0));
    obj.insert("kind".into(), Json::Str(kind.into()));
    let (kname, gamma, coef0, degree) = match kernel {
        KernelFunction::Rbf { gamma } => ("rbf", gamma, 0.0, 0),
        KernelFunction::Linear => ("linear", 0.0, 0.0, 0),
        KernelFunction::Poly { gamma, coef0, degree } => ("poly", gamma, coef0, degree),
        KernelFunction::Sigmoid { gamma, coef0 } => ("sigmoid", gamma, coef0, 0),
    };
    obj.insert("kernel".into(), Json::Str(kname.into()));
    obj.insert("gamma".into(), Json::Num(gamma));
    obj.insert("coef0".into(), Json::Num(coef0));
    obj.insert("degree".into(), Json::Num(degree as f64));
    obj.insert("dim".into(), Json::Num(dim as f64));
    obj
}

/// Parse the kernel fields of a document.
fn kernel_of(v: &Json) -> Result<KernelFunction> {
    let get = |k: &str| v.get(k).with_context(|| format!("missing field {k}"));
    let gamma = get("gamma")?.as_f64().context("gamma: expected a number")?;
    let coef0 = get("coef0")?.as_f64().context("coef0: expected a number")?;
    let degree = get("degree")?.as_f64().context("degree: expected a number")? as u32;
    Ok(match get("kernel")?.as_str().context("kernel: expected a string")? {
        "rbf" => KernelFunction::Rbf { gamma },
        "linear" => KernelFunction::Linear,
        "poly" => KernelFunction::Poly { gamma, coef0, degree },
        "sigmoid" => KernelFunction::Sigmoid { gamma, coef0 },
        other => bail!("unknown kernel {other:?}"),
    })
}

/// Required field accessor.
fn field<'a>(v: &'a Json, name: &str) -> Result<&'a Json> {
    v.get(name).with_context(|| format!("missing field {name}"))
}

/// Strict f64-array parse: every entry must be a number, errors name
/// the offending position (`name[i]: expected a number`).
fn num_array(v: &Json, name: &str) -> Result<Vec<f64>> {
    let arr = field(v, name)?
        .as_arr()
        .with_context(|| format!("{name}: expected an array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, j) in arr.iter().enumerate() {
        out.push(
            j.as_f64()
                .with_context(|| format!("{name}[{i}]: expected a number"))?,
        );
    }
    Ok(out)
}

/// Serialize support rows as an array of dense row arrays. CSR-sparse
/// support sets are densified row by row — the on-disk schema is dense
/// regardless of the training-time backend (loads also build dense
/// storage; see DESIGN.md §4f).
fn sv_json(support: &Dataset) -> Json {
    let mut rows = Vec::with_capacity(support.len());
    let mut buf = vec![0f32; support.dim()];
    for i in 0..support.len() {
        support.row_ref(i).densify_into(&mut buf);
        rows.push(Json::Arr(buf.iter().map(|&v| Json::Num(v as f64)).collect()));
    }
    Json::Arr(rows)
}

/// Strict support-matrix parse into a dense [`Dataset`]. `labels` gives
/// each row's ±1 label (classifier), or `None` for the label-free kinds
/// (every row stored with label +1, which the kernels never read).
fn sv_of_json(v: &Json, dim: usize, labels: Option<&[i8]>) -> Result<Dataset> {
    let rows = field(v, "sv")?.as_arr().context("sv: expected an array")?;
    if let Some(labels) = labels {
        ensure!(
            rows.len() == labels.len(),
            "sv/labels counts disagree ({} vs {})",
            rows.len(),
            labels.len()
        );
    }
    let mut support = Dataset::with_dim(dim);
    let mut buf = vec![0f32; dim];
    for (r, row) in rows.iter().enumerate() {
        let vals = row
            .as_arr()
            .with_context(|| format!("sv[{r}]: expected an array"))?;
        ensure!(
            vals.len() == dim,
            "sv[{r}]: expected {dim} values, got {}",
            vals.len()
        );
        for (k, jv) in vals.iter().enumerate() {
            buf[k] = jv
                .as_f64()
                .with_context(|| format!("sv[{r}][{k}]: expected a number"))?
                as f32;
        }
        support.push(&buf, labels.map(|l| l[r]).unwrap_or(1));
    }
    Ok(support)
}

/// The `svc` payload (shared by the standalone classifier file and the
/// machines of a multiclass file).
pub(crate) fn svc_to_json(m: &SvmModel) -> Json {
    let mut obj = envelope("svc", m.kernel, m.support.dim());
    obj.insert("bias".into(), Json::Num(m.bias));
    obj.insert(
        "coef".into(),
        Json::Arr(m.coef.iter().map(|&c| Json::Num(c)).collect()),
    );
    obj.insert(
        "labels".into(),
        Json::Arr(
            m.support
                .labels()
                .iter()
                .map(|&y| Json::Num(y as f64))
                .collect(),
        ),
    );
    obj.insert("sv".into(), sv_json(&m.support));
    if let Some(p) = &m.platt {
        let mut platt = BTreeMap::new();
        platt.insert("a".into(), Json::Num(p.a));
        platt.insert("b".into(), Json::Num(p.b));
        obj.insert("platt".into(), Json::Obj(platt));
    }
    Json::Obj(obj)
}

/// Parse an `svc` payload (also accepts v1 documents — same fields).
pub(crate) fn svc_of_json(v: &Json) -> Result<SvmModel> {
    let kernel = kernel_of(v)?;
    let bias = field(v, "bias")?.as_f64().context("bias: expected a number")?;
    let dim = field(v, "dim")?.as_usize().context("dim: expected a number")?;
    let coef = num_array(v, "coef")?;
    let labels: Vec<i8> = num_array(v, "labels")?
        .into_iter()
        .map(|y| if y > 0.0 { 1 } else { -1 })
        .collect();
    let support = sv_of_json(v, dim, Some(&labels))?;
    ensure!(
        support.len() == coef.len(),
        "sv/coef counts disagree ({} vs {})",
        support.len(),
        coef.len()
    );
    let platt = match v.get("platt") {
        None => None,
        Some(p) => Some(PlattScaler {
            a: field(p, "a")?.as_f64().context("platt.a: expected a number")?,
            b: field(p, "b")?.as_f64().context("platt.b: expected a number")?,
        }),
    };
    Ok(SvmModel { kernel, support, coef, bias, platt })
}

/// The `svr` document.
pub(crate) fn svr_to_json(m: &SvrModel) -> Json {
    let mut obj = envelope("svr", m.kernel, m.support.dim());
    obj.insert("bias".into(), Json::Num(m.bias));
    obj.insert(
        "coef".into(),
        Json::Arr(m.coef.iter().map(|&c| Json::Num(c)).collect()),
    );
    obj.insert("sv".into(), sv_json(&m.support));
    Json::Obj(obj)
}

/// Parse an `svr` document.
pub(crate) fn svr_of_json(v: &Json) -> Result<SvrModel> {
    let kernel = kernel_of(v)?;
    let bias = field(v, "bias")?.as_f64().context("bias: expected a number")?;
    let dim = field(v, "dim")?.as_usize().context("dim: expected a number")?;
    let coef = num_array(v, "coef")?;
    let support = sv_of_json(v, dim, None)?;
    ensure!(
        support.len() == coef.len(),
        "sv/coef counts disagree ({} vs {})",
        support.len(),
        coef.len()
    );
    Ok(SvrModel { kernel, support, coef, bias })
}

/// The `oneclass` document.
pub(crate) fn oneclass_to_json(m: &OneClassModel) -> Json {
    let mut obj = envelope("oneclass", m.kernel, m.support.dim());
    obj.insert("rho".into(), Json::Num(m.rho));
    obj.insert(
        "coef".into(),
        Json::Arr(m.coef.iter().map(|&c| Json::Num(c)).collect()),
    );
    obj.insert("sv".into(), sv_json(&m.support));
    Json::Obj(obj)
}

/// Parse a `oneclass` document.
pub(crate) fn oneclass_of_json(v: &Json) -> Result<OneClassModel> {
    let kernel = kernel_of(v)?;
    let rho = field(v, "rho")?.as_f64().context("rho: expected a number")?;
    let dim = field(v, "dim")?.as_usize().context("dim: expected a number")?;
    let coef = num_array(v, "coef")?;
    let support = sv_of_json(v, dim, None)?;
    ensure!(
        support.len() == coef.len(),
        "sv/coef counts disagree ({} vs {})",
        support.len(),
        coef.len()
    );
    Ok(OneClassModel { kernel, support, coef, rho })
}

/// The `multiclass` document: classes, class pairs, one `svc` payload
/// per pairwise machine.
pub(crate) fn ovo_to_json(m: &OvoModel) -> Json {
    let dim = m.machines.first().map(|b| b.support.dim()).unwrap_or(1);
    let kernel = m.machines.first().map(|b| b.kernel).unwrap_or(KernelFunction::Linear);
    let mut obj = envelope("multiclass", kernel, dim);
    obj.insert(
        "classes".into(),
        Json::Arr(m.classes.iter().map(|&c| Json::Num(c as f64)).collect()),
    );
    obj.insert(
        "pairs".into(),
        Json::Arr(
            m.pairs()
                .iter()
                .map(|&(a, b)| {
                    Json::Arr(vec![Json::Num(a as f64), Json::Num(b as f64)])
                })
                .collect(),
        ),
    );
    obj.insert(
        "machines".into(),
        Json::Arr(m.machines.iter().map(svc_to_json).collect()),
    );
    Json::Obj(obj)
}

/// Strict i32 parse of one numeric JSON value.
fn class_id(j: &Json, what: &str) -> Result<i32> {
    let n = j.as_f64().with_context(|| format!("{what}: expected a number"))?;
    ensure!(
        n.fract() == 0.0 && n.abs() <= i32::MAX as f64,
        "{what}: {n} is not an integer class id"
    );
    Ok(n as i32)
}

/// Parse a `multiclass` document.
pub(crate) fn ovo_of_json(v: &Json) -> Result<OvoModel> {
    let classes_arr = field(v, "classes")?
        .as_arr()
        .context("classes: expected an array")?;
    let mut classes = Vec::with_capacity(classes_arr.len());
    for (i, j) in classes_arr.iter().enumerate() {
        classes.push(class_id(j, &format!("classes[{i}]"))?);
    }
    let pairs_arr = field(v, "pairs")?.as_arr().context("pairs: expected an array")?;
    let mut pairs = Vec::with_capacity(pairs_arr.len());
    for (i, j) in pairs_arr.iter().enumerate() {
        let pair = j
            .as_arr()
            .with_context(|| format!("pairs[{i}]: expected an array"))?;
        ensure!(pair.len() == 2, "pairs[{i}]: expected [a, b]");
        pairs.push((
            class_id(&pair[0], &format!("pairs[{i}][0]"))?,
            class_id(&pair[1], &format!("pairs[{i}][1]"))?,
        ));
    }
    let machines_arr = field(v, "machines")?
        .as_arr()
        .context("machines: expected an array")?;
    let dim = field(v, "dim")?.as_usize().context("dim: expected a number")?;
    let mut machines = Vec::with_capacity(machines_arr.len());
    for (i, j) in machines_arr.iter().enumerate() {
        let m = svc_of_json(j).with_context(|| format!("machines[{i}]"))?;
        // Validate here, not at predict time: a dimension mismatch must
        // be a positioned load error, never a mid-batch scorer panic.
        ensure!(
            m.support.dim() == dim,
            "machines[{i}]: support dim {} != model dim {dim}",
            m.support.dim()
        );
        machines.push(m);
    }
    OvoModel::from_parts(classes, machines, pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join("pasmo-schema-test");
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn v1_document_without_kind_loads_as_svc() {
        let path = dir().join("v1.json");
        std::fs::write(
            &path,
            "{\"kernel\":\"rbf\",\"gamma\":0.5,\"coef0\":0,\"degree\":0,\
             \"bias\":0.25,\"dim\":2,\"coef\":[1.5,-0.5],\
             \"labels\":[1,-1],\"sv\":[[1,0],[0,1]]}",
        )
        .unwrap();
        match load_any(&path).unwrap() {
            AnyModel::Svc(m) => {
                assert_eq!(m.n_sv(), 2);
                assert_eq!(m.bias, 0.25);
                assert_eq!(m.support.label(1), -1);
            }
            other => panic!("wrong kind {:?}", other.task_name()),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn unknown_kind_is_rejected() {
        let path = dir().join("alien.json");
        std::fs::write(&path, "{\"kind\":\"ranking\"}").unwrap();
        let err = load_any(&path).unwrap_err();
        assert!(format!("{err:#}").contains("unknown model kind"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn misaligned_counts_are_rejected() {
        let path = dir().join("misaligned.json");
        std::fs::write(
            &path,
            "{\"kernel\":\"linear\",\"gamma\":0,\"coef0\":0,\"degree\":0,\
             \"bias\":0,\"dim\":1,\"coef\":[1,2,3],\
             \"labels\":[1,-1],\"sv\":[[1],[2]]}",
        )
        .unwrap();
        let err = load_any(&path).unwrap_err();
        assert!(format!("{err:#}").contains("counts disagree"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn multiclass_machine_dim_mismatch_is_a_load_error() {
        // A machine whose support dim disagrees with the model dim must
        // fail at load with a position, not panic at predict time.
        let path = dir().join("dim-mismatch.json");
        std::fs::write(
            &path,
            "{\"kind\":\"multiclass\",\"kernel\":\"linear\",\"gamma\":0,\
             \"coef0\":0,\"degree\":0,\"dim\":3,\
             \"classes\":[0,1],\"pairs\":[[0,1]],\
             \"machines\":[{\"kernel\":\"linear\",\"gamma\":0,\"coef0\":0,\
             \"degree\":0,\"bias\":0,\"dim\":2,\"coef\":[1],\
             \"labels\":[1],\"sv\":[[1,0]]}]}",
        )
        .unwrap();
        let err = load_any(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("machines[0]") && msg.contains("dim"), "{msg}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn saved_models_carry_a_verified_checksum() {
        let path = dir().join("checksummed.json");
        let doc = Json::parse(
            "{\"kernel\":\"rbf\",\"gamma\":0.5,\"coef0\":0,\"degree\":0,\
             \"bias\":0.25,\"dim\":2,\"coef\":[1.5,-0.5],\
             \"labels\":[1,-1],\"sv\":[[1,0],[0,1]]}",
        )
        .unwrap();
        save(&path, &doc).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"checksum\":\"fnv1a:"), "{text}");
        // Round trip succeeds with the checksum verified…
        assert!(matches!(load_any(&path).unwrap(), AnyModel::Svc(_)));
        // …and a single corrupted digit is refused.
        std::fs::write(&path, text.replace("0.25", "0.26")).unwrap();
        let err = load_any(&path).unwrap_err();
        assert!(format!("{err:#}").contains("checksum mismatch"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_model_is_refused_and_save_is_atomic() {
        let path = dir().join("truncated-model.json");
        let doc = Json::parse(
            "{\"kernel\":\"linear\",\"gamma\":0,\"coef0\":0,\"degree\":0,\
             \"bias\":0,\"dim\":1,\"coef\":[1],\"labels\":[1],\"sv\":[[1]]}",
        )
        .unwrap();
        save(&path, &doc).unwrap();
        let good = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &good[..good.len() / 2]).unwrap();
        let err = load_any(&path).unwrap_err();
        assert!(format!("{err:#}").contains("byte"), "{err:#}");
        // Re-saving replaces the corrupt file atomically; no temp files
        // remain next to it.
        save(&path, &doc).unwrap();
        assert!(matches!(load_any(&path).unwrap(), AnyModel::Svc(_)));
        let tmp_left = std::fs::read_dir(dir())
            .unwrap()
            .filter_map(|e| e.ok())
            .any(|e| e.file_name().to_string_lossy().contains(".tmp."));
        assert!(!tmp_left, "temp artifact files left behind");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sv_matrix_errors_are_positioned() {
        let path = dir().join("bad-sv.json");
        std::fs::write(
            &path,
            "{\"kernel\":\"linear\",\"gamma\":0,\"coef0\":0,\"degree\":0,\
             \"bias\":0,\"dim\":2,\"coef\":[1],\
             \"labels\":[1],\"sv\":[[1,null]]}",
        )
        .unwrap();
        let err = load_any(&path).unwrap_err();
        assert!(format!("{err:#}").contains("sv[0][1]"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }
}
