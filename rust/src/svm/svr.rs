//! ε-support-vector regression on the PA-SMO solver.
//!
//! The ε-SVR dual maps exactly onto the paper's general problem form by
//! doubling the variables: with `γ_i = α_i ∈ [0, C]` and
//! `γ_{ℓ+i} = −α*_i ∈ [−C, 0]`, the dual becomes
//!
//! ```text
//! max  pᵀγ − ½ γᵀ K̃ γ,   p_i = y_i − ε,  p_{ℓ+i} = y_i + ε,
//! s.t. Σγ = 0,  K̃_{ab} = K_{a mod ℓ, b mod ℓ},
//! ```
//!
//! which is solved unchanged by any `Engine` via
//! [`QpProblem::svr`] — a direct demonstration of the paper's
//! "the method is widely applicable" conclusion. The regression
//! coefficient of example `i` is `γ_i + γ_{ℓ+i} = α_i − α*_i`.

use std::path::Path;
use std::sync::Arc;

use crate::data::dataset::Dataset;
use crate::data::regression::RegressionDataset;
use crate::kernel::function::KernelFunction;
use crate::kernel::matrix::{Gram, RowComputer};
use crate::solver::engine::{Engine, EngineConfig, SolverChoice};
use crate::solver::problem::QpProblem;
use crate::solver::smo::{SolveResult, SolverConfig};
use crate::util::error::Result;

use super::schema;
use super::scorer::Scorer;

/// Row computer for the doubled ε-SVR Gram matrix K̃ (2ℓ × 2ℓ).
struct DoubledRowComputer {
    inner: Box<dyn RowComputer>,
    l: usize,
    /// Reused mod-ℓ column buffer for the gathered path (kernel rows are
    /// computed thousands of times under cache pressure; a fresh Vec per
    /// row would be pure allocator traffic).
    fold: std::cell::RefCell<Vec<usize>>,
    /// Reused base-problem row for wide (> ℓ) gathers.
    base_row: std::cell::RefCell<Vec<f32>>,
}

impl RowComputer for DoubledRowComputer {
    fn len(&self) -> usize {
        2 * self.l
    }
    fn compute_row(&self, a: usize, out: &mut [f32]) {
        assert_eq!(out.len(), 2 * self.l);
        let (lo, hi) = out.split_at_mut(self.l);
        self.inner.compute_row(a % self.l, lo);
        hi.copy_from_slice(lo);
    }
    fn compute_cols(&self, a: usize, cols: &[usize], out: &mut [f32]) {
        if cols.len() > self.l {
            // Wide prefix: the folded columns necessarily repeat mod ℓ, so
            // one ℓ-length base row plus a gather costs at most half the
            // per-column evaluation.
            let mut base = self.base_row.borrow_mut();
            base.resize(self.l, 0.0);
            self.inner.compute_row(a % self.l, &mut base);
            for (o, &c) in out.iter_mut().zip(cols) {
                *o = base[c % self.l];
            }
        } else {
            // Shrink-aware path: fold the doubled columns onto the base
            // problem and gather directly — no full row.
            let mut fold = self.fold.borrow_mut();
            fold.clear();
            fold.extend(cols.iter().map(|&c| c % self.l));
            self.inner.compute_cols(a % self.l, &fold, out);
        }
    }
    fn cols_cost(&self, requested: usize) -> usize {
        if requested > self.l {
            self.l
        } else {
            self.inner.cols_cost(requested)
        }
    }
    fn diag(&self, a: usize) -> f64 {
        self.inner.diag(a % self.l)
    }
    fn entry(&self, a: usize, b: usize) -> f64 {
        self.inner.entry(a % self.l, b % self.l)
    }
}

/// ε-SVR training configuration.
#[derive(Debug, Clone, Copy)]
pub struct SvrConfig {
    /// Regularization constant C.
    pub c: f64,
    /// Tube half-width ε (insensitive-loss zone).
    pub epsilon: f64,
    /// The kernel function.
    pub kernel: KernelFunction,
    /// Which engine drives the solve (any [`SolverChoice`]).
    pub solver: SolverChoice,
    /// Full low-level solver configuration.
    pub solver_config: SolverConfig,
}

impl SvrConfig {
    /// RBF ε-SVR configuration at (C, ε, γ) with PA-SMO defaults.
    pub fn new(c: f64, epsilon: f64, gamma: f64) -> SvrConfig {
        SvrConfig {
            c,
            epsilon,
            kernel: KernelFunction::Rbf { gamma },
            solver: SolverChoice::Pasmo,
            solver_config: SolverConfig::default(),
        }
    }
}

/// A trained ε-SVR model.
#[derive(Debug, Clone)]
pub struct SvrModel {
    /// The kernel the model was trained with.
    pub kernel: KernelFunction,
    /// Support rows (|α_i − α*_i| > 0), dense row-major (labels unused).
    pub support: Dataset,
    /// Regression coefficients `α_i − α*_i`, aligned with `support`.
    pub coef: Vec<f64>,
    /// Bias term b of the regression function.
    pub bias: f64,
}

impl SvrModel {
    /// Number of support vectors.
    pub fn n_sv(&self) -> usize {
        self.coef.len()
    }

    /// The batch scoring engine over this model's expansion — build it
    /// once per batch.
    pub fn scorer(&self) -> Scorer<'_> {
        Scorer::new(self.kernel, &self.support, &self.coef, self.bias)
    }

    /// Predicted target `f(x) = Σ coef_s k(x_s, x) + b` (one-off
    /// convenience; batch callers use [`SvrModel::scorer`] /
    /// [`SvrModel::predict_all`]).
    pub fn predict(&self, x: &[f32]) -> f64 {
        self.scorer().decision(x)
    }

    /// Predicted targets for every row of `data` — one batch scoring
    /// pass with `threads` workers.
    pub fn predict_all(&self, data: &RegressionDataset, threads: usize) -> Vec<f64> {
        let mut out = vec![0f64; data.len()];
        self.scorer()
            .with_threads(threads)
            .decision_block(data.dim(), data.features(), &mut out);
        out
    }

    /// Root-mean-square error over a dataset (one batch pass).
    pub fn rmse(&self, data: &RegressionDataset) -> f64 {
        let preds = self.predict_all(data, 1);
        let se: f64 = preds
            .iter()
            .zip(data.targets())
            .map(|(p, t)| (p - t) * (p - t))
            .sum();
        (se / data.len().max(1) as f64).sqrt()
    }

    /// Serialize to a JSON file (schema v2, `kind: "svr"`).
    pub fn save(&self, path: &Path) -> Result<()> {
        schema::save(path, &schema::svr_to_json(self))
    }

    /// Load from a JSON file written by [`SvrModel::save`].
    pub fn load(path: &Path) -> Result<SvrModel> {
        match schema::load_any(path)? {
            schema::AnyModel::Svr(m) => Ok(m),
            other => crate::bail!(
                "{} holds a {:?} model, not an SVR regressor",
                path.display(),
                other.task_name()
            ),
        }
    }
}

/// Train ε-SVR on `data`. Returns the model plus solver diagnostics
/// (iterations etc. refer to the doubled 2ℓ problem).
pub fn train_svr(
    data: &RegressionDataset,
    inner: Box<dyn RowComputer>,
    cfg: &SvrConfig,
) -> (SvrModel, SolveResult) {
    let l = data.len();
    assert_eq!(inner.len(), l, "computer/data size mismatch");
    let doubled = DoubledRowComputer {
        inner,
        l,
        fold: std::cell::RefCell::new(Vec::new()),
        base_row: std::cell::RefCell::new(Vec::new()),
    };
    let mut gram = Gram::new(Box::new(doubled), cfg.solver_config.cache_bytes);

    // The ε-SVR lowering: one QpProblem over the doubled variables.
    let targets: Vec<f64> = (0..l).map(|i| data.target(i)).collect();
    let problem = QpProblem::svr(&targets, cfg.c, cfg.epsilon);
    let engine = EngineConfig::new(cfg.solver, cfg.solver_config).build();
    let result = engine.solve(&problem, &mut gram);

    let mut support = Dataset::with_dim(data.dim());
    let mut coef = Vec::new();
    for i in 0..l {
        let c = result.alpha[i] + result.alpha[l + i];
        if c.abs() > 1e-12 {
            support.push(data.row(i), 1); // label unused by the kernels
            coef.push(c);
        }
    }
    let model = SvrModel { kernel: cfg.kernel, support, coef, bias: result.bias };
    (model, result)
}

/// Convenience: train with the native kernel path over a regression set.
pub fn train_svr_native(data: &RegressionDataset, cfg: &SvrConfig) -> (SvrModel, SolveResult) {
    // Reuse the classification NativeRowComputer via a feature-only view.
    let mut ds = crate::data::dataset::Dataset::with_dim(data.dim());
    for i in 0..data.len() {
        ds.push(data.row(i), 1); // labels unused by the kernel
    }
    let nc = crate::kernel::native::NativeRowComputer::with_threads(
        Arc::new(ds),
        cfg.kernel,
        cfg.solver_config.threads,
    );
    train_svr(data, Box::new(nc), cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::regression::{linear_target, sinc};

    #[test]
    fn fits_the_sinc_function() {
        let train = sinc(300, 0.05, 1);
        let test = sinc(200, 0.0, 2);
        let cfg = SvrConfig::new(10.0, 0.05, 0.5);
        let (model, res) = train_svr_native(&train, &cfg);
        assert!(res.converged);
        let rmse = model.rmse(&test);
        assert!(rmse < 0.12, "sinc rmse {rmse}");
        // the ε-tube sparsifies: not every point is a support vector
        assert!(model.coef.len() < train.len(), "no sparsity: {}", model.coef.len());
    }

    #[test]
    fn smo_and_pasmo_agree_on_svr() {
        let train = sinc(150, 0.1, 3);
        let base = SvrConfig::new(5.0, 0.1, 0.5);
        let smo = SvrConfig { solver: SolverChoice::Smo, ..base };
        let pa = SvrConfig { solver: SolverChoice::Pasmo, ..base };
        let (_, r1) = train_svr_native(&train, &smo);
        let (_, r2) = train_svr_native(&train, &pa);
        assert!(r1.converged && r2.converged);
        let rel = (r1.objective - r2.objective).abs() / (1.0 + r1.objective.abs());
        assert!(rel < 2e-3, "{} vs {}", r1.objective, r2.objective);
    }

    #[test]
    fn equality_constraint_holds_on_doubled_problem() {
        let train = linear_target(80, 2, 0.05, 4);
        let cfg = SvrConfig::new(2.0, 0.05, 0.3);
        let (_, res) = train_svr_native(&train, &cfg);
        let sum: f64 = res.alpha.iter().sum();
        assert!(sum.abs() < 1e-8, "Σγ = {sum}");
        // box feasibility of both halves
        for i in 0..80 {
            assert!(res.alpha[i] >= -1e-9 && res.alpha[i] <= 2.0 + 1e-9);
            assert!(res.alpha[80 + i] >= -2.0 - 1e-9 && res.alpha[80 + i] <= 1e-9);
        }
    }

    #[test]
    fn batch_prediction_matches_per_example_and_round_trips() {
        let train = sinc(120, 0.05, 6);
        let cfg = SvrConfig::new(5.0, 0.05, 0.5);
        let (model, _) = train_svr_native(&train, &cfg);
        let test = sinc(60, 0.0, 7);
        let batch = model.predict_all(&test, 1);
        let threaded = model.predict_all(&test, 4);
        for i in 0..test.len() {
            let one = model.predict(test.row(i));
            assert_eq!(one.to_bits(), batch[i].to_bits(), "i={i}");
            assert_eq!(one.to_bits(), threaded[i].to_bits(), "i={i} threaded");
        }
        // save/load round trip through the v2 `svr` schema
        let dir = std::env::temp_dir().join("pasmo-svr-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("svr.json");
        model.save(&path).unwrap();
        let loaded = SvrModel::load(&path).unwrap();
        assert_eq!(loaded.n_sv(), model.n_sv());
        assert_eq!(loaded.kernel, model.kernel);
        for i in 0..test.len().min(10) {
            let d = (loaded.predict(test.row(i)) - model.predict(test.row(i))).abs();
            assert!(d < 1e-9, "i={i}: Δ={d}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wider_tube_means_fewer_support_vectors() {
        let train = sinc(200, 0.05, 5);
        let narrow = SvrConfig::new(10.0, 0.01, 0.5);
        let wide = SvrConfig::new(10.0, 0.3, 0.5);
        let (m1, _) = train_svr_native(&train, &narrow);
        let (m2, _) = train_svr_native(&train, &wide);
        assert!(
            m2.coef.len() < m1.coef.len(),
            "ε=0.3 SVs {} !< ε=0.01 SVs {}",
            m2.coef.len(),
            m1.coef.len()
        );
    }
}
