//! # pasmo — Planning-ahead SMO (PA-SMO) SVM training system
//!
//! A reproduction of T. Glasmachers, *"The Planning-ahead SMO Algorithm"*:
//! a three-layer Rust + JAX/Pallas system in which the Rust coordinator owns
//! the sequential-minimal-optimization loop (working-set selection, step
//! policy, shrinking, kernel cache) and the compute hot spot — RBF Gram row
//! evaluation — is AOT-compiled from a Pallas kernel to HLO and executed
//! through PJRT (`runtime`), with a native Rust path as fallback/comparator.
//!
//! ## Quick start
//!
//! Training goes through one entry point, the [`svm::Trainer`] builder;
//! the engine behind it — baseline SMO, the paper's PA-SMO, or the
//! conjugate-direction SMO — is a [`solver::SolverChoice`]:
//!
//! ```
//! use pasmo::solver::SolverChoice;
//! use pasmo::svm::Trainer;
//!
//! let data = std::sync::Arc::new(pasmo::data::synth::chessboard(120, 4, 1));
//! let outcome = Trainer::rbf(100.0, 0.5)
//!     .solver(SolverChoice::Pasmo)
//!     .train(&data);
//! assert!(outcome.result.converged);
//! assert!(outcome.model.n_sv() > 0);
//! ```
//!
//! ## Layer map (see DESIGN.md)
//!
//! * [`solver`] — the paper's contribution: SMO (Alg. 1), the planning-ahead
//!   step (eqs. 7/8, Algs. 2 & 4), PA-aware working-set selection (Alg. 3)
//!   and the complete PA-SMO driver (Alg. 5), plus the conjugate SMO
//!   engine (`solver::conjugate`), shrinking and telemetry — all behind
//!   the [`solver::Engine`] trait over first-class [`solver::QpProblem`]
//!   descriptions (built by the single `solver::EngineConfig` factory).
//! * [`kernel`] — kernel functions, the shared tiled evaluation
//!   primitives (`kernel::tile`, feeding both Gram rows and batch
//!   scoring), the LRU row cache and Gram abstractions.
//! * `runtime` — PJRT engine loading `artifacts/*.hlo.txt`. Compiled only
//!   with the `pjrt` cargo feature (off by default so the crate builds
//!   offline with zero external dependencies); the default build uses the
//!   native Rust kernel path.
//! * [`data`] — LIBSVM IO and the synthetic dataset suite standing in for
//!   the paper's 22 benchmark datasets.
//! * [`svm`] — the user-facing API: the [`svm::Trainer`] builder (kernel, C,
//!   per-class costs, solver choice, warm start → `TrainOutcome`), the
//!   shared batch [`svm::Scorer`] behind predict and every model kind's
//!   decision loops, the kind-tagged model schema (`svm::schema`),
//!   warm-started cross-validation / grid search, ε-SVR, one-class, OvO.
//! * [`server`] — `pasmo serve`: a std-only TCP tier speaking
//!   newline-delimited JSON whose admission micro-batcher scores queued
//!   queries in shared tiled passes, bit-identical to offline predict.
//! * [`stats`] — Wilcoxon signed-rank test and the histogram machinery the
//!   paper's evaluation uses.
//! * [`coordinator`] — experiment drivers regenerating every table/figure.
//! * [`util`] — substrates that would normally come from crates.io (PRNG,
//!   CLI parsing, JSON, error handling, property testing, timing) built
//!   in-repo because the build environment is offline.
//!
//! ## Documentation discipline
//!
//! The whole public surface is documented and the lint below keeps it
//! that way: `ci.sh` runs `RUSTDOCFLAGS="-D warnings" cargo doc` (plus
//! `cargo test --doc`), so an undocumented public item or a broken
//! doctest fails CI rather than silently regressing.

#![warn(missing_docs)]

/// `pasmo audit`: the repo's own source-tree lint (offline, no deps).
pub mod audit;
/// Persistent bench baselines: `BENCH_baseline.json` and the CI perf gate.
pub mod bench;
/// Experiment drivers and the permutation fan-out (paper §7 protocol).
pub mod coordinator;
/// Datasets: dense storage, LIBSVM IO, splits, the synthetic suite.
pub mod data;
/// Deterministic fault injection (active only with `fault-injection`).
pub mod faults;
/// Kernel functions, the LRU row cache and the `Gram` facade.
pub mod kernel;
/// PJRT/XLA runtime (compiled only with the `pjrt` cargo feature).
#[cfg(feature = "pjrt")]
pub mod runtime;
/// `pasmo serve`: the persistent micro-batching TCP inference tier.
pub mod server;
/// The solver family: SMO, PA-SMO, conjugate SMO, and their substrate.
pub mod solver;
/// Statistics for the paper's evaluation protocol.
pub mod stats;
/// The user-facing SVM API: train, predict, CV, grid search, SVR, …
pub mod svm;
/// Offline substrates for what would normally come from crates.io.
pub mod util;
