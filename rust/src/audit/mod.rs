//! `pasmo audit` — the repo's own source-tree lint (offline, no deps).
//!
//! A plain-text, line-level analysis over `rust/src` enforcing the
//! repo-specific rules rustc/clippy cannot express (see [`Rule`]):
//! library code never panics, every `unsafe` block is SAFETY-documented,
//! solver values are never compared to float literals with `==`/`!=`,
//! threads stay inside the blessed concurrency seams (`kernel::tile`,
//! `coordinator::jobs`, and the whole `server::` tier), `HashMap`
//! iteration never feeds a result path (bit-determinism), and the
//! library crate never prints.
//!
//! Intentional exceptions live in a committed allowlist file
//! (`rust/audit.allow`): one `path:rule:content` entry per accepted
//! violation, where `content` is the trimmed source line (or `*` for a
//! per-file-per-rule wildcard) and `#` starts a comment. An entry that
//! stops matching anything is itself reported as [`Rule::StaleAllow`],
//! so the allowlist can only ever shrink.
//!
//! Wired into `ci.sh` as a hard gate; run it locally with
//! `cargo run --release -- audit`.

use std::fmt::Write as _;
use std::path::Path;

use crate::util::error::{Context, Result};

mod rules;

pub use rules::audit_source;

/// The lint rules `pasmo audit` enforces.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// No `.unwrap()` / `.expect(` / `panic!` in library code paths
    /// (tests and `main.rs` are exempt): malformed input must surface
    /// as a positioned `util::error` Result, not a crash.
    NoPanic,
    /// Every `unsafe` block is preceded by (or carries) a `// SAFETY:`
    /// comment justifying it.
    UnsafeSafety,
    /// No `==` / `!=` against float literals: solver quantities compare
    /// through tolerances; exact-zero sentinel tests must be allowlisted
    /// with a justification.
    FloatEq,
    /// `std::thread` only inside the audited concurrency seams:
    /// `kernel::tile`, `coordinator::jobs`, and the `server::` tier
    /// (whose connection and batcher threads are the module's purpose).
    ThreadScope,
    /// No iteration over `HashMap`-typed values: iteration order is
    /// nondeterministic and must never feed a result or report path.
    HashmapIter,
    /// No `println!` / `eprintln!` in the library crate; output belongs
    /// to the binary and the report sinks.
    NoPrint,
    /// An allowlist entry that matches no current violation (the
    /// exception it documented was fixed — delete the entry).
    StaleAllow,
}

impl Rule {
    /// Stable rule id used in reports and the allowlist file.
    pub fn name(self) -> &'static str {
        match self {
            Rule::NoPanic => "no-panic",
            Rule::UnsafeSafety => "unsafe-safety",
            Rule::FloatEq => "float-eq",
            Rule::ThreadScope => "thread-scope",
            Rule::HashmapIter => "hashmap-iter",
            Rule::NoPrint => "no-print",
            Rule::StaleAllow => "stale-allow",
        }
    }

    fn from_name(name: &str) -> Option<Rule> {
        [
            Rule::NoPanic,
            Rule::UnsafeSafety,
            Rule::FloatEq,
            Rule::ThreadScope,
            Rule::HashmapIter,
            Rule::NoPrint,
            Rule::StaleAllow,
        ]
        .into_iter()
        .find(|r| r.name() == name)
    }
}

/// One rule violation at a specific source line.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Path relative to the audited source root, `/`-separated.
    pub file: String,
    /// 1-based line number (0 for allowlist-level findings).
    pub line: usize,
    /// The rule violated.
    pub rule: Rule,
    /// What matched: the offending pattern or a short explanation.
    pub detail: String,
    /// The trimmed raw source line — the allowlist matching key.
    pub raw: String,
}

struct AllowEntry {
    path: String,
    rule: String,
    content: String,
    line: usize,
}

/// The committed set of accepted violations (`rust/audit.allow`).
///
/// Format: one `path:rule:content` entry per line, where `content` is
/// the trimmed source line the violation sits on or `*` to accept every
/// instance of `rule` in `path`; blank lines and `#` comments are
/// ignored. Matching is line-content based, not line-number based, so
/// entries survive unrelated edits but die with the code they excuse.
pub struct Allowlist {
    entries: Vec<AllowEntry>,
}

impl Allowlist {
    /// An empty allowlist (used when the file does not exist).
    pub fn empty() -> Allowlist {
        Allowlist { entries: Vec::new() }
    }

    /// Parse allowlist text; rejects unknown rule names and malformed
    /// entries with the offending line number.
    pub fn parse(text: &str) -> Result<Allowlist> {
        let mut entries = Vec::new();
        for (k, line) in text.lines().enumerate() {
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            let mut parts = t.splitn(3, ':');
            let (path, rule, content) = match (parts.next(), parts.next(), parts.next()) {
                (Some(p), Some(r), Some(c)) => (p, r, c),
                _ => crate::bail!("audit.allow line {}: expected path:rule:content", k + 1),
            };
            if Rule::from_name(rule).is_none() {
                crate::bail!("audit.allow line {}: unknown rule {rule:?}", k + 1);
            }
            entries.push(AllowEntry {
                path: path.to_string(),
                rule: rule.to_string(),
                content: content.to_string(),
                line: k + 1,
            });
        }
        Ok(Allowlist { entries })
    }

    /// Indices of every entry matching this violation (empty = not
    /// allowlisted). All matches are reported so duplicate/wildcard
    /// entries are not flagged stale while they still apply.
    fn matches(&self, v: &Violation) -> Vec<usize> {
        self.entries
            .iter()
            .enumerate()
            .filter(|(_, e)| {
                e.path == v.file
                    && e.rule == v.rule.name()
                    && (e.content == "*" || e.content == v.raw)
            })
            .map(|(i, _)| i)
            .collect()
    }
}

/// The outcome of auditing a source tree.
pub struct AuditReport {
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// Violations suppressed by allowlist entries.
    pub suppressed: usize,
    /// Surviving violations (including stale allowlist entries), sorted
    /// by (file, line, rule, detail) for deterministic output.
    pub violations: Vec<Violation>,
}

impl AuditReport {
    /// True when nothing is left to report.
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Human-readable report: `file:line: [rule] detail` per violation
    /// plus a one-line summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for v in &self.violations {
            if v.line == 0 {
                let _ = writeln!(out, "{}: [{}] {}", v.file, v.rule.name(), v.detail);
            } else {
                let _ = writeln!(out, "{}:{}: [{}] {}", v.file, v.line, v.rule.name(), v.detail);
            }
        }
        let _ = writeln!(
            out,
            "audit: {} files scanned, {} violations, {} allowlisted",
            self.files_scanned,
            self.violations.len(),
            self.suppressed
        );
        out
    }
}

fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("read dir {}", dir.display()))?
    {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(root, &path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            let rel = path
                .strip_prefix(root)
                .context("strip source root prefix")?
                .to_str()
                .with_context(|| format!("non-utf8 path {}", path.display()))?
                .replace('\\', "/");
            out.push(rel);
        }
    }
    Ok(())
}

/// Audit every `.rs` file under `src` (except the binary root
/// `main.rs`, which owns the user-facing print/fail-fast surface),
/// apply the allowlist, and report what remains.
pub fn audit_tree(src: &Path, allowlist: &Allowlist) -> Result<AuditReport> {
    let mut files = Vec::new();
    collect_rs(src, src, &mut files)?;
    files.sort();
    let mut used = vec![false; allowlist.entries.len()];
    let mut violations = Vec::new();
    let mut suppressed = 0usize;
    for rel in &files {
        if rel == "main.rs" {
            continue;
        }
        let text = std::fs::read_to_string(src.join(rel))
            .with_context(|| format!("read {rel}"))?;
        for v in rules::audit_source(rel, &text) {
            let hits = allowlist.matches(&v);
            if hits.is_empty() {
                violations.push(v);
            } else {
                suppressed += 1;
                for idx in hits {
                    used[idx] = true;
                }
            }
        }
    }
    for (idx, e) in allowlist.entries.iter().enumerate() {
        if !used[idx] {
            violations.push(Violation {
                file: e.path.clone(),
                line: 0,
                rule: Rule::StaleAllow,
                detail: format!(
                    "allowlist line {} ({}:{}) matches no violation — delete it",
                    e.line, e.rule, e.content
                ),
                raw: String::new(),
            });
        }
    }
    violations.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule.name(), a.detail.as_str()).cmp(&(
            b.file.as_str(),
            b.line,
            b.rule.name(),
            b.detail.as_str(),
        ))
    });
    let files_scanned = files.iter().filter(|r| r.as_str() != "main.rs").count();
    Ok(AuditReport { files_scanned, suppressed, violations })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("pasmo-audit-{name}-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(dir.join("sub")).unwrap();
        dir
    }

    #[test]
    fn allowlist_parses_and_rejects_garbage() {
        let a = Allowlist::parse(
            "# comment\n\nsolver/x.rs:no-panic:x.unwrap()\nkernel/y.rs:float-eq:*\n",
        )
        .unwrap();
        assert_eq!(a.entries.len(), 2);
        assert!(Allowlist::parse("solver/x.rs:no-panic").is_err());
        assert!(Allowlist::parse("solver/x.rs:bogus-rule:line").is_err());
    }

    #[test]
    fn tree_audit_flags_suppresses_and_reports_stale() {
        let dir = scratch("tree");
        std::fs::write(
            dir.join("sub/bad.rs"),
            "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n",
        )
        .unwrap();
        std::fs::write(dir.join("main.rs"), "fn main() {\n    println!(\"hi\");\n}\n").unwrap();

        // 1. No allowlist: the violation surfaces; main.rs is skipped.
        let report = audit_tree(&dir, &Allowlist::empty()).unwrap();
        assert_eq!(report.files_scanned, 1);
        assert_eq!(report.violations.len(), 1);
        let v = &report.violations[0];
        assert_eq!((v.file.as_str(), v.line, v.rule.name()), ("sub/bad.rs", 2, "no-panic"));
        assert!(report.render().contains("sub/bad.rs:2: [no-panic]"), "{}", report.render());

        // 2. An exact-content entry suppresses it.
        let allow = Allowlist::parse("sub/bad.rs:no-panic:x.unwrap()\n").unwrap();
        let report = audit_tree(&dir, &allow).unwrap();
        assert!(report.is_clean(), "{}", report.render());
        assert_eq!(report.suppressed, 1);

        // 3. A wildcard entry suppresses it too.
        let allow = Allowlist::parse("sub/bad.rs:no-panic:*\n").unwrap();
        assert!(audit_tree(&dir, &allow).unwrap().is_clean());

        // 4. A stale entry is itself a violation.
        let allow =
            Allowlist::parse("sub/bad.rs:no-panic:x.unwrap()\nsub/bad.rs:no-print:*\n").unwrap();
        let report = audit_tree(&dir, &allow).unwrap();
        assert_eq!(report.violations.len(), 1);
        assert_eq!(report.violations[0].rule.name(), "stale-allow");

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn report_is_sorted_deterministically() {
        let dir = scratch("sorted");
        std::fs::write(dir.join("b.rs"), "fn f() {\n    println!(\"x\");\n}\n").unwrap();
        std::fs::write(
            dir.join("a.rs"),
            "fn g(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n",
        )
        .unwrap();
        let report = audit_tree(&dir, &Allowlist::empty()).unwrap();
        let order: Vec<&str> = report.violations.iter().map(|v| v.file.as_str()).collect();
        assert_eq!(order, vec!["a.rs", "b.rs"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rule_names_round_trip() {
        for rule in [
            Rule::NoPanic,
            Rule::UnsafeSafety,
            Rule::FloatEq,
            Rule::ThreadScope,
            Rule::HashmapIter,
            Rule::NoPrint,
            Rule::StaleAllow,
        ] {
            assert_eq!(Rule::from_name(rule.name()), Some(rule));
        }
        assert_eq!(Rule::from_name("nope"), None);
    }
}
