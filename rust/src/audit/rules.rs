//! The lint rules: line-level analysis over comment/string-stripped source.
//!
//! Every rule works on *stripped* code lines — string literal contents are
//! blanked (quotes kept), char literals removed, `//` and `/* */` comments
//! removed, with multi-line strings and block comments tracked across
//! lines — so a pattern inside a string or comment never trips a rule.
//! The one exception is [`Rule::UnsafeSafety`], which by design reads the
//! *raw* lines: the `// SAFETY:` marker it looks for is a comment.
//!
//! Lines inside `#[cfg(test)] mod … { … }` regions are exempt from every
//! rule (test code may unwrap freely); the region is tracked by brace
//! depth from the attribute to the closing brace.

use super::{Rule, Violation};

/// Per-line code with string/char contents blanked and comments removed.
///
/// Tracks multi-line strings and block comments across lines, so the
/// output has exactly one entry per input line.
fn strip_file(text: &str) -> Vec<String> {
    let mut out_lines = Vec::new();
    let mut in_str = false;
    let mut in_block = false;
    for line in text.split('\n') {
        let b: Vec<char> = line.chars().collect();
        let n = b.len();
        let mut out = String::new();
        let mut i = 0usize;
        while i < n {
            let c = b[i];
            if in_block {
                if c == '*' && i + 1 < n && b[i + 1] == '/' {
                    in_block = false;
                    i += 2;
                } else {
                    i += 1;
                }
                continue;
            }
            if in_str {
                if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    in_str = false;
                    out.push('"');
                    i += 1;
                } else {
                    i += 1;
                }
                continue;
            }
            if c == '"' {
                in_str = true;
                out.push('"');
                i += 1;
                continue;
            }
            if c == '\'' {
                // Char literal (escaped or plain) — skipped; a lone quote
                // (lifetime) is kept.
                if i + 1 < n && b[i + 1] == '\\' {
                    let mut j = i + 2;
                    while j < n && b[j] != '\'' {
                        j += 1;
                    }
                    i = j + 1;
                    continue;
                } else if i + 2 < n && b[i + 2] == '\'' {
                    i += 3;
                    continue;
                } else {
                    out.push(c);
                    i += 1;
                    continue;
                }
            }
            if c == '/' && i + 1 < n && b[i + 1] == '/' {
                break;
            }
            if c == '/' && i + 1 < n && b[i + 1] == '*' {
                in_block = true;
                i += 2;
                continue;
            }
            out.push(c);
            i += 1;
        }
        out_lines.push(out);
    }
    out_lines
}

/// For each line: is it inside a `#[cfg(test)] mod … { … }` region?
fn test_regions(code_lines: &[String]) -> Vec<bool> {
    let mut in_test = vec![false; code_lines.len()];
    let mut depth: i64 = 0;
    let mut pending = false;
    // Brace depth at region entry; the region stays active while the
    // running depth exceeds it.
    let mut region_depth: Option<i64> = None;
    for (k, code) in code_lines.iter().enumerate() {
        if region_depth.is_some() {
            in_test[k] = true;
        }
        if region_depth.is_none() && pending && code.contains("mod ") && code.contains('{') {
            region_depth = Some(depth);
            in_test[k] = true;
            pending = false;
        }
        if code.contains("#[cfg(test)]") || code.contains("#[cfg(all(test") {
            pending = true;
        }
        depth += code.matches('{').count() as i64 - code.matches('}').count() as i64;
        if let Some(rd) = region_depth {
            if depth <= rd && code.contains('}') {
                region_depth = None;
            }
        }
    }
    in_test
}

/// Is `tok` (already stripped of a leading `-` and `f64`/`f32` suffixes)
/// a float literal? True when there is a `.` and the mantissa before it
/// is one or more digits.
fn is_float_tok(tok: &str) -> bool {
    let t = tok.trim_start_matches('-');
    let mant = match t.find('.') {
        Some(dot) => &t[..dot],
        None => return false,
    };
    !mant.is_empty() && mant.chars().all(|c| c.is_ascii_digit())
}

/// Find `needle` in `hay` at or after `start` (char indices).
fn find_from(hay: &[char], needle: &[char], start: usize) -> Option<usize> {
    if hay.len() < needle.len() {
        return None;
    }
    (start..=hay.len() - needle.len()).find(|&i| hay[i..i + needle.len()] == *needle)
}

/// Does this stripped line compare a float literal with `==` / `!=`?
/// Scans the token on each side of every occurrence of the operators.
fn float_eq_hit(code: &str) -> bool {
    let b: Vec<char> = code.chars().collect();
    for op in ["==", "!="] {
        let opc: Vec<char> = op.chars().collect();
        let mut start = 0usize;
        while let Some(p) = find_from(&b, &opc, start) {
            start = p + 2;
            // Right-hand token.
            let mut r = p + 2;
            while r < b.len() && b[r] == ' ' {
                r += 1;
            }
            let mut rtok = String::new();
            if r < b.len() && b[r] == '-' {
                rtok.push('-');
                r += 1;
            }
            while r < b.len() && (b[r].is_alphanumeric() || b[r] == '.' || b[r] == '_') {
                rtok.push(b[r]);
                r += 1;
            }
            let rt = rtok.trim_end_matches('_').replace("f64", "").replace("f32", "");
            if is_float_tok(&rt) {
                return true;
            }
            // Left-hand token.
            let mut ltok: Vec<char> = Vec::new();
            let mut l = p;
            while l > 0 && b[l - 1] == ' ' {
                l -= 1;
            }
            while l > 0 && (b[l - 1].is_alphanumeric() || b[l - 1] == '.' || b[l - 1] == '_') {
                ltok.push(b[l - 1]);
                l -= 1;
            }
            let lt: String = ltok.iter().rev().collect();
            let lt = lt.replace("f64", "").replace("f32", "");
            if is_float_tok(&lt) {
                return true;
            }
        }
    }
    false
}

/// Names of fields/locals declared with a `HashMap`-ish type in this
/// file, including through local `type X = …HashMap…` aliases.
fn hashmap_names(code_lines: &[String]) -> std::collections::BTreeSet<String> {
    let mut aliases: Vec<String> = vec!["HashMap".to_string()];
    for code in code_lines {
        let t = code.trim();
        if let Some(rest) = t.strip_prefix("type ") {
            if let Some((lhs, rhs)) = rest.split_once('=') {
                if aliases.iter().any(|a| rhs.contains(a.as_str())) {
                    let name = match lhs.split('<').next() {
                        Some(n) => n.trim(),
                        None => "",
                    };
                    if !name.is_empty() {
                        aliases.push(name.to_string());
                    }
                }
            }
        }
    }
    let mut names = std::collections::BTreeSet::new();
    for code in code_lines {
        let b: Vec<char> = code.chars().collect();
        for a in &aliases {
            let pat: Vec<char> = format!(": {a}").chars().collect();
            let mut idx = 0usize;
            while let Some(p) = find_from(&b, &pat, idx) {
                idx = p + 1;
                // The char after the alias must not be identifier-ish
                // (so `: HashMapLike` does not count as `: HashMap`).
                let after = p + 2 + a.chars().count();
                if after < b.len() && (b[after].is_alphanumeric() || b[after] == '_') {
                    continue;
                }
                // Scan back for the declared identifier.
                let mut tok: Vec<char> = Vec::new();
                let mut l = p;
                while l > 0 && (b[l - 1].is_alphanumeric() || b[l - 1] == '_') {
                    tok.push(b[l - 1]);
                    l -= 1;
                }
                let name: String = tok.iter().rev().collect();
                if name.chars().next().is_some_and(|c| !c.is_ascii_digit()) {
                    names.insert(name);
                }
            }
        }
    }
    names
}

/// Run every rule over one file's text.
///
/// `rel` is the path relative to the audited source root, `/`-separated;
/// it scopes [`Rule::ThreadScope`] (which exempts `kernel/tile.rs`,
/// `coordinator/jobs.rs`, and the whole `server/` tier — a serving layer
/// is connection + batcher threads by nature, so the rule admits the
/// module rather than allowlisting every site). Skipping `main.rs` is
/// the *tree walker's* job ([`super::audit_tree`]) — this function
/// audits whatever it is given.
pub fn audit_source(rel: &str, text: &str) -> Vec<Violation> {
    let mut viols = Vec::new();
    let code_lines = strip_file(text);
    let raw_lines: Vec<&str> = text.split('\n').collect();
    let in_test = test_regions(&code_lines);
    let hm_names = hashmap_names(&code_lines);
    let thread_ok = rel == "kernel/tile.rs"
        || rel == "coordinator/jobs.rs"
        || rel.starts_with("server/");
    let mut push = |line: usize, rule: Rule, detail: String, raw: &str| {
        viols.push(Violation {
            file: rel.to_string(),
            line,
            rule,
            detail,
            raw: raw.trim().to_string(),
        });
    };
    for (k, code) in code_lines.iter().enumerate() {
        if in_test[k] {
            continue;
        }
        let line = k + 1;
        let raw = raw_lines[k];
        // R1: no `.unwrap()` / `.expect(` / `panic!` in library paths.
        for pat in [".unwrap()", ".expect(", "panic!"] {
            if code.contains(pat) {
                push(line, Rule::NoPanic, pat.to_string(), raw);
                break;
            }
        }
        // R2: every `unsafe` block carries a `// SAFETY:` comment, on the
        // same line or in the contiguous comment block directly above.
        if code.contains("unsafe")
            && (code.contains("unsafe ") || code.contains("unsafe{") || code.trim() == "unsafe")
        {
            let mut ok = raw.contains("SAFETY:");
            let mut j = k;
            while !ok && j > 0 {
                j -= 1;
                let t = raw_lines[j].trim();
                if !t.starts_with("//") {
                    break;
                }
                if t.contains("SAFETY:") {
                    ok = true;
                }
            }
            if !ok {
                push(line, Rule::UnsafeSafety, "unsafe without // SAFETY:".to_string(), raw);
            }
        }
        // R3: no float-literal `==` / `!=` on solver values.
        if float_eq_hit(code) {
            push(line, Rule::FloatEq, "float literal ==/!=".to_string(), raw);
        }
        // R4: threads only in the blessed concurrency seams.
        if !thread_ok && (code.contains("std::thread") || code.contains("thread::")) {
            push(
                line,
                Rule::ThreadScope,
                "thread use outside kernel::tile/coordinator::jobs/server::*".to_string(),
                raw,
            );
        }
        // R5: no iteration over HashMap-typed values (bit-determinism).
        for name in &hm_names {
            for m in [".iter()", ".keys()", ".values()", ".drain(", ".into_iter()"] {
                if code.contains(&format!("{name}{m}")) {
                    push(line, Rule::HashmapIter, format!("{name}{m}"), raw);
                    break;
                }
            }
        }
        // R6: the library crate never prints; reports go through sinks.
        for pat in ["println!", "eprintln!", "print!(", "eprint!("] {
            if code.contains(pat) {
                push(line, Rule::NoPrint, pat.to_string(), raw);
                break;
            }
        }
    }
    viols
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hits(rel: &str, src: &str) -> Vec<(usize, &'static str)> {
        audit_source(rel, src).iter().map(|v| (v.line, v.rule.name())).collect()
    }

    #[test]
    fn no_panic_flags_unwrap_expect_and_panic() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n\
                   fn g(x: Option<u32>) -> u32 {\n    x.expect(\"gone\")\n}\n\
                   fn h() {\n    panic!(\"boom\");\n}\n";
        assert_eq!(hits("m.rs", src), vec![(2, "no-panic"), (5, "no-panic"), (8, "no-panic")]);
    }

    #[test]
    fn no_panic_ignores_strings_and_comments() {
        let src = "fn f() -> &'static str {\n    // .unwrap() would panic! here\n    \".unwrap()\"\n}\n";
        assert_eq!(hits("m.rs", src), Vec::<(usize, &str)>::new());
    }

    #[test]
    fn test_modules_are_exempt() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        None::<u32>.unwrap();\n    }\n}\nfn lib2(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
        assert_eq!(hits("m.rs", src), vec![(10, "no-panic")]);
    }

    #[test]
    fn unsafe_needs_safety_comment() {
        let bad = "fn f(p: *const f32) -> f32 {\n    unsafe { *p }\n}\n";
        assert_eq!(hits("m.rs", bad), vec![(2, "unsafe-safety")]);
        let good = "fn f(p: *const f32) -> f32 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}\n";
        assert_eq!(hits("m.rs", good), Vec::<(usize, &str)>::new());
        let same_line = "fn f(p: *const f32) -> f32 {\n    unsafe { *p } // SAFETY: valid by contract\n}\n";
        assert_eq!(hits("m.rs", same_line), Vec::<(usize, &str)>::new());
    }

    #[test]
    fn unsafe_safety_comment_must_be_contiguous() {
        let src = "fn f(p: *const f32) -> f32 {\n    // SAFETY: stale, detached\n    let q = p;\n    unsafe { *q }\n}\n";
        assert_eq!(hits("m.rs", src), vec![(4, "unsafe-safety")]);
    }

    #[test]
    fn float_eq_flags_literal_comparisons() {
        let src = "fn f(x: f64) -> bool {\n    x == 0.0\n}\n\
                   fn g(x: f64) -> bool {\n    1.5 != x\n}\n\
                   fn h(x: f64) -> bool {\n    x == 2.0_f64\n}\n";
        assert_eq!(hits("m.rs", src), vec![(2, "float-eq"), (5, "float-eq"), (8, "float-eq")]);
    }

    #[test]
    fn float_eq_ignores_ints_idents_and_strings() {
        let src = "fn f(x: usize, y: usize, s: &str) -> bool {\n    x == 0 && x == y && s == \"0.0\" && x.min(y) == 2\n}\n";
        assert_eq!(hits("m.rs", src), Vec::<(usize, &str)>::new());
    }

    #[test]
    fn thread_scope_is_path_dependent() {
        let src = "fn f() {\n    std::thread::scope(|_| {});\n}\n";
        assert_eq!(hits("solver/smo.rs", src), vec![(2, "thread-scope")]);
        assert_eq!(hits("kernel/tile.rs", src), Vec::<(usize, &str)>::new());
        assert_eq!(hits("coordinator/jobs.rs", src), Vec::<(usize, &str)>::new());
    }

    #[test]
    fn thread_scope_admits_the_server_tier_as_a_module() {
        // The serving layer is connection + batcher threads by nature:
        // every file under server/ is in scope, not just an allowlisted
        // site — but a server-adjacent path outside the module is not.
        let src = "fn f() {\n    std::thread::scope(|_| {});\n}\n";
        assert_eq!(hits("server/mod.rs", src), Vec::<(usize, &str)>::new());
        assert_eq!(hits("server/batcher.rs", src), Vec::<(usize, &str)>::new());
        assert_eq!(hits("server/deeper/conn.rs", src), Vec::<(usize, &str)>::new());
        assert_eq!(hits("svm/server_like.rs", src), vec![(2, "thread-scope")]);
        assert_eq!(hits("serverless.rs", src), vec![(2, "thread-scope")]);
    }

    #[test]
    fn hashmap_iteration_is_flagged_including_aliases() {
        let src = "use std::collections::HashMap;\n\
                   struct S {\n    m: HashMap<u32, u32>,\n}\n\
                   impl S {\n    fn f(&self) -> usize {\n        self.m.iter().count()\n    }\n}\n";
        assert_eq!(hits("m.rs", src), vec![(7, "hashmap-iter")]);
        let aliased = "use std::collections::HashMap;\n\
                       type Index = HashMap<u32, u32>;\n\
                       fn f(idx: Index) -> usize {\n    idx.keys().count()\n}\n";
        assert_eq!(hits("m.rs", aliased), vec![(4, "hashmap-iter")]);
    }

    #[test]
    fn hashmap_lookup_is_fine() {
        let src = "use std::collections::HashMap;\nfn f(m: HashMap<u32, u32>) -> Option<u32> {\n    m.get(&1).copied()\n}\n";
        assert_eq!(hits("m.rs", src), Vec::<(usize, &str)>::new());
    }

    #[test]
    fn printing_is_flagged_in_library_code() {
        let src = "fn f() {\n    println!(\"hi\");\n}\nfn g() {\n    eprint!(\"no\");\n}\n";
        assert_eq!(hits("m.rs", src), vec![(2, "no-print"), (4, "no-print")]);
    }

    #[test]
    fn stripping_handles_block_comments_and_multiline_strings() {
        let src = "fn f() -> String {\n    /* println!(\"dead\")\n       x.unwrap() */\n    let s = \"line one\n        line two with .unwrap()\n        end\".to_string();\n    s\n}\n";
        assert_eq!(hits("m.rs", src), Vec::<(usize, &str)>::new());
    }

    #[test]
    fn raw_line_is_recorded_trimmed() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
        let v = audit_source("m.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].raw, "x.unwrap()");
        assert_eq!(v[0].detail, ".unwrap()");
    }
}
