//! Artifact manifest: what `python -m compile.aot` produced.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::ensure;
use crate::util::error::{Context, Error, Result};
use crate::util::json::Json;

/// Metadata for one AOT artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Artifact name (manifest key).
    pub name: String,
    /// Path to the HLO-text file.
    pub file: PathBuf,
    /// HLO entry computation name.
    pub entry: String,
    /// Argument names, in call order.
    pub arg_names: Vec<String>,
    /// Argument shapes, aligned with `arg_names`.
    pub arg_shapes: Vec<Vec<usize>>,
    /// Output shape.
    pub out_shape: Vec<usize>,
    /// Query block size Q.
    pub q: usize,
    /// Data chunk length L.
    pub l: usize,
    /// Padded feature dimension D.
    pub d: usize,
}

/// Parsed MANIFEST.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// The artifacts directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Artifacts by name.
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load `MANIFEST.json` from the artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("MANIFEST.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| Error::msg(format!("parse manifest: {e}")))?;
        ensure!(
            v.get("format").and_then(|f| f.as_str()) == Some("hlo-text"),
            "unsupported manifest format"
        );
        let mut artifacts = BTreeMap::new();
        let arts = v
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .context("manifest missing artifacts object")?;
        for (name, meta) in arts {
            let gets = |k: &str| -> Result<&Json> {
                meta.get(k).with_context(|| format!("{name}: missing {k}"))
            };
            let shapes: Vec<Vec<usize>> = gets("arg_shapes")?
                .as_arr()
                .context("arg_shapes")?
                .iter()
                .map(|s| {
                    s.as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|n| n.as_usize())
                        .collect()
                })
                .collect();
            let names: Vec<String> = gets("arg_names")?
                .as_arr()
                .context("arg_names")?
                .iter()
                .filter_map(|s| s.as_str().map(|x| x.to_string()))
                .collect();
            let out_shape: Vec<usize> = gets("out_shape")?
                .as_arr()
                .context("out_shape")?
                .iter()
                .filter_map(|n| n.as_usize())
                .collect();
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: dir.join(gets("file")?.as_str().context("file")?),
                    entry: gets("entry")?.as_str().context("entry")?.to_string(),
                    arg_names: names,
                    arg_shapes: shapes,
                    out_shape,
                    q: gets("q")?.as_usize().context("q")?,
                    l: gets("l")?.as_usize().context("l")?,
                    d: gets("d")?.as_usize().context("d")?,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// The gram_rows artifact with the smallest padded D ≥ `dim` (prefer
    /// the smallest query block — the solver fetches single rows).
    pub fn gram_artifact_for(&self, dim: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .values()
            .filter(|a| a.entry == "gram_rows" && a.d >= dim)
            .min_by_key(|a| (a.d, a.q))
    }

    /// The decision-function artifact with D ≥ `dim`.
    pub fn decision_artifact_for(&self, dim: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .values()
            .filter(|a| a.entry == "decision_function" && a.d >= dim)
            .min_by_key(|a| (a.d, a.q))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let doc = r#"{
          "format": "hlo-text", "return_tuple": true,
          "artifacts": {
            "gram_q4_l2048_d64": {"entry": "gram_rows", "file": "g64.hlo.txt",
              "arg_names": ["xq","x","gamma"],
              "arg_shapes": [[4,64],[2048,64],[1,1]], "out_shape": [4,2048],
              "q": 4, "l": 2048, "d": 64},
            "gram_q4_l2048_d256": {"entry": "gram_rows", "file": "g256.hlo.txt",
              "arg_names": ["xq","x","gamma"],
              "arg_shapes": [[4,256],[2048,256],[1,1]], "out_shape": [4,2048],
              "q": 4, "l": 2048, "d": 256},
            "decision_q16_l2048_d64": {"entry": "decision_function", "file": "d.hlo.txt",
              "arg_names": ["xq","x","coef","bias","gamma"],
              "arg_shapes": [[16,64],[2048,64],[2048],[1],[1,1]], "out_shape": [16],
              "q": 16, "l": 2048, "d": 64}
          }
        }"#;
        std::fs::write(dir.join("MANIFEST.json"), doc).unwrap();
    }

    #[test]
    fn loads_and_selects_artifacts() {
        let dir = std::env::temp_dir().join("pasmo-manifest-test");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.gram_artifact_for(2).unwrap().d, 64);
        assert_eq!(m.gram_artifact_for(64).unwrap().d, 64);
        assert_eq!(m.gram_artifact_for(65).unwrap().d, 256);
        assert!(m.gram_artifact_for(300).is_none());
        assert_eq!(m.decision_artifact_for(10).unwrap().q, 16);
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // integration sanity: if `make artifacts` ran, the real manifest
        // must parse and expose the standard artifact set.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("MANIFEST.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.gram_artifact_for(2).is_some());
            assert!(m.decision_artifact_for(2).is_some());
        }
    }

    #[test]
    fn missing_manifest_is_an_error() {
        let dir = std::env::temp_dir().join("pasmo-manifest-missing");
        std::fs::create_dir_all(&dir).ok();
        std::fs::remove_file(dir.join("MANIFEST.json")).ok();
        assert!(Manifest::load(&dir).is_err());
    }
}
