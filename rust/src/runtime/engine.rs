//! PJRT engine: compile and execute the AOT HLO-text artifacts.
//!
//! Mirrors /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute_b`. One compiled executable per artifact;
//! artifacts are compiled lazily on first use and memoized.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

use crate::util::error::{Context, Result};

use super::manifest::{ArtifactMeta, Manifest};

/// A PJRT CPU engine bound to one artifacts directory.
pub struct PjrtEngine {
    /// The PJRT client executing the compiled artifacts.
    pub client: xla::PjRtClient,
    /// The loaded artifact manifest.
    pub manifest: Manifest,
    execs: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtEngine {
    /// Open the artifacts directory (must contain MANIFEST.json).
    pub fn open(dir: &Path) -> Result<PjrtEngine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(PjrtEngine { client, manifest, execs: RefCell::new(HashMap::new()) })
    }

    /// Default artifacts directory: `$PASMO_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<PjrtEngine> {
        let dir = std::env::var("PASMO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        PjrtEngine::open(Path::new(&dir))
    }

    /// Compile (or fetch memoized) an artifact by name.
    pub fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.execs.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .with_context(|| format!("unknown artifact {name:?}"))?;
        let exe = self.compile(meta)?;
        let rc = std::rc::Rc::new(exe);
        self.execs.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    fn compile(&self, meta: &ArtifactMeta) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            meta.file.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", meta.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compile artifact {}", meta.name))
    }

    /// Upload an f32 tensor to the device.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("upload buffer")
    }

    /// Execute an artifact on device-resident buffers and read back the
    /// single (tuple-wrapped) f32 output.
    pub fn execute_f32(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<f32>> {
        let exe = self.executable(name)?;
        let out = exe.execute_b(args).with_context(|| format!("execute {name}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .context("read back result literal")?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let inner = lit.to_tuple1().context("unwrap result tuple")?;
        inner.to_vec::<f32>().context("result to f32 vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("MANIFEST.json").exists().then_some(dir)
    }

    /// End-to-end load path: HLO text -> PJRT compile -> execute, numerics
    /// vs the native Rust kernel. Skipped (not failed) when artifacts are
    /// absent so `cargo test` works before `make artifacts`.
    #[test]
    fn gram_artifact_executes_with_correct_numerics() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let eng = PjrtEngine::open(&dir).unwrap();
        let meta = eng.manifest.gram_artifact_for(2).unwrap().clone();
        let (q, l, d) = (meta.q, meta.l, meta.d);

        // random padded inputs
        let mut rng = crate::util::prng::Pcg::new(77);
        let mut xq = vec![0f32; q * d];
        let mut x = vec![0f32; l * d];
        for v in xq.iter_mut().take(q * 2) {
            *v = rng.normal() as f32;
        }
        for v in x.iter_mut().take(l * 2) {
            *v = rng.normal() as f32;
        }
        let gamma = 0.5f32;
        let name = meta.name.clone();
        let bq = eng.upload(&xq, &[q, d]).unwrap();
        let bx = eng.upload(&x, &[l, d]).unwrap();
        let bg = eng.upload(&[gamma], &[1, 1]).unwrap();
        let out = eng.execute_f32(&name, &[&bq, &bx, &bg]).unwrap();
        assert_eq!(out.len(), q * l);

        // compare a scattering of entries against direct evaluation
        for (qi, li) in [(0usize, 0usize), (1, 7), (2, 100), (3, 2047)] {
            let mut d2 = 0f64;
            for k in 0..d {
                let diff = xq[qi * d + k] as f64 - x[li * d + k] as f64;
                d2 += diff * diff;
            }
            let want = (-(gamma as f64) * d2).exp();
            let got = out[qi * l + li] as f64;
            assert!((got - want).abs() < 1e-5, "({qi},{li}): {got} vs {want}");
        }
    }

    #[test]
    fn unknown_artifact_is_an_error() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let eng = PjrtEngine::open(&dir).unwrap();
        assert!(eng.executable("nope").is_err());
    }
}
