//! PJRT runtime: load AOT HLO-text artifacts and serve Gram rows.
pub mod manifest;
pub mod engine;
pub mod gram;
