//! PJRT-backed Gram row computer and decision function.
//!
//! The dataset is padded to the artifact's (L-chunk, D) shape once,
//! uploaded once, and stays device-resident; each `compute_row` call only
//! uploads the tiny query block and reads back one row per chunk. This is
//! the production hot path of the three-layer design — Python is not
//! involved at any point here.

use std::rc::Rc;
use std::sync::Arc;

use crate::ensure;
use crate::util::error::{Context, Result};

use crate::data::dataset::Dataset;
use crate::kernel::matrix::RowComputer;

use super::engine::PjrtEngine;

/// Zero-pad `row` (length `dim`) into width-`d` layout at position `q`.
fn place_padded(dst: &mut [f32], q: usize, d: usize, row: &[f32]) {
    let base = q * d;
    dst[base..base + row.len()].copy_from_slice(row);
    dst[base + row.len()..base + d].iter_mut().for_each(|v| *v = 0.0);
}

/// RBF Gram rows served by the AOT gram artifact.
pub struct PjrtRowComputer {
    engine: Rc<PjrtEngine>,
    data: Arc<Dataset>,
    gamma: f64,
    artifact: String,
    q: usize,
    chunk_l: usize,
    d: usize,
    /// Device-resident dataset chunks, each `[chunk_l, d]`.
    chunks: Vec<xla::PjRtBuffer>,
    /// Device-resident `[1,1]` gamma.
    gamma_buf: xla::PjRtBuffer,
    /// Precomputed ‖x_i‖² for `entry()`.
    sqnorms: Vec<f64>,
}

// SAFETY: the PJRT CPU client is thread-safe for compilation/execution and
// every `PjrtRowComputer` instance is used by exactly one solver thread at
// a time (the coordinator creates one per worker). The raw pointers inside
// xla wrappers are never shared across threads concurrently.
unsafe impl Send for PjrtRowComputer {}

impl PjrtRowComputer {
    /// Build the device-resident view of `data` for RBF width `gamma`.
    /// The PJRT path stages dense row-major blocks on device; CSR
    /// datasets must be densified first ([`Dataset::to_dense`]).
    pub fn new(engine: Rc<PjrtEngine>, data: Arc<Dataset>, gamma: f64) -> Result<Self> {
        ensure!(
            !data.is_sparse(),
            "the pjrt gram path requires dense storage; densify with Dataset::to_dense first"
        );
        let meta = engine
            .manifest
            .gram_artifact_for(data.dim())
            .with_context(|| {
                format!("no gram artifact for feature dim {}", data.dim())
            })?
            .clone();
        let (q, chunk_l, d) = (meta.q, meta.l, meta.d);
        let n = data.len();
        let n_chunks = n.div_ceil(chunk_l);
        ensure!(n_chunks > 0, "empty dataset");
        let mut chunks = Vec::with_capacity(n_chunks);
        let mut host = vec![0f32; chunk_l * d];
        for c in 0..n_chunks {
            host.iter_mut().for_each(|v| *v = 0.0);
            for r in 0..chunk_l {
                let idx = c * chunk_l + r;
                if idx < n {
                    place_padded(&mut host, r, d, data.row(idx));
                }
            }
            chunks.push(engine.upload(&host, &[chunk_l, d])?);
        }
        let gamma_buf = engine.upload(&[gamma as f32], &[1, 1])?;
        let sqnorms = (0..n)
            .map(|i| data.row(i).iter().map(|&v| v as f64 * v as f64).sum())
            .collect();
        Ok(PjrtRowComputer {
            artifact: meta.name.clone(),
            engine,
            data,
            gamma,
            q,
            chunk_l,
            d,
            chunks,
            gamma_buf,
            sqnorms,
        })
    }

    /// Number of device chunks (introspection for benches).
    pub fn n_chunks(&self) -> usize {
        self.chunks.len()
    }
}

impl RowComputer for PjrtRowComputer {
    fn len(&self) -> usize {
        self.data.len()
    }

    fn compute_row(&self, i: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.data.len());
        // Query block: row i replicated Q times (single-row fetch).
        let mut xq = vec![0f32; self.q * self.d];
        for qslot in 0..self.q {
            place_padded(&mut xq, qslot, self.d, self.data.row(i));
        }
        let bq = self
            .engine
            .upload(&xq, &[self.q, self.d])
            .expect("upload query block");
        let n = self.data.len();
        for (c, chunk) in self.chunks.iter().enumerate() {
            let res = self
                .engine
                .execute_f32(&self.artifact, &[&bq, chunk, &self.gamma_buf])
                .expect("execute gram artifact");
            let lo = c * self.chunk_l;
            let hi = ((c + 1) * self.chunk_l).min(n);
            // row 0 of the [Q, chunk_l] output
            out[lo..hi].copy_from_slice(&res[..hi - lo]);
        }
    }

    fn diag(&self, _i: usize) -> f64 {
        1.0 // RBF
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        // Single entries are cheaper on the host than a device round-trip.
        let (a, b) = (self.data.row(i), self.data.row(j));
        let mut dot = 0f64;
        for k in 0..a.len() {
            dot += a[k] as f64 * b[k] as f64;
        }
        let d2 = (self.sqnorms[i] + self.sqnorms[j] - 2.0 * dot).max(0.0);
        (-self.gamma * d2).exp()
    }
}

/// Batched decision function via the AOT decision artifact:
/// `f(X_q) = Σ_chunks K(X_q, SV_chunk)·coef_chunk + b`.
pub struct PjrtDecision {
    engine: Rc<PjrtEngine>,
    artifact: String,
    q: usize,
    d: usize,
    sv_chunks: Vec<xla::PjRtBuffer>,
    coef_chunks: Vec<xla::PjRtBuffer>,
    bias: f64,
    zero_bias: xla::PjRtBuffer,
    gamma_buf: xla::PjRtBuffer,
    dim: usize,
}

impl PjrtDecision {
    /// Stage support vectors + signed coefficients on device. Like the
    /// gram path, dense storage only — densify sparse support sets
    /// first.
    pub fn new(
        engine: Rc<PjrtEngine>,
        support: &Dataset,
        coef: &[f64],
        bias: f64,
        gamma: f64,
    ) -> Result<PjrtDecision> {
        assert_eq!(support.len(), coef.len());
        ensure!(
            !support.is_sparse(),
            "the pjrt decision path requires dense storage; densify with Dataset::to_dense first"
        );
        let meta = engine
            .manifest
            .decision_artifact_for(support.dim())
            .with_context(|| {
                format!("no decision artifact for feature dim {}", support.dim())
            })?
            .clone();
        let (q, chunk_l, d) = (meta.q, meta.l, meta.d);
        let n = support.len();
        let n_chunks = n.div_ceil(chunk_l).max(1);
        let mut sv_chunks = Vec::new();
        let mut coef_chunks = Vec::new();
        let mut host = vec![0f32; chunk_l * d];
        let mut chost = vec![0f32; chunk_l];
        for c in 0..n_chunks {
            host.iter_mut().for_each(|v| *v = 0.0);
            chost.iter_mut().for_each(|v| *v = 0.0);
            for r in 0..chunk_l {
                let idx = c * chunk_l + r;
                if idx < n {
                    place_padded(&mut host, r, d, support.row(idx));
                    chost[r] = coef[idx] as f32;
                }
            }
            sv_chunks.push(engine.upload(&host, &[chunk_l, d])?);
            coef_chunks.push(engine.upload(&chost, &[chunk_l])?);
        }
        let zero_bias = engine.upload(&[0f32], &[1])?;
        let gamma_buf = engine.upload(&[gamma as f32], &[1, 1])?;
        Ok(PjrtDecision {
            artifact: meta.name.clone(),
            engine,
            q,
            d,
            sv_chunks,
            coef_chunks,
            bias,
            zero_bias,
            gamma_buf,
            dim: support.dim(),
        })
    }

    /// Decision values for a batch of query rows.
    pub fn decide(&self, queries: &Dataset) -> Result<Vec<f64>> {
        assert_eq!(queries.dim(), self.dim);
        let n = queries.len();
        let mut out = vec![self.bias; n];
        let mut xq = vec![0f32; self.q * self.d];
        let mut base = 0usize;
        while base < n {
            let batch = (n - base).min(self.q);
            xq.iter_mut().for_each(|v| *v = 0.0);
            for r in 0..batch {
                place_padded(&mut xq, r, self.d, queries.row(base + r));
            }
            let bq = self.engine.upload(&xq, &[self.q, self.d])?;
            for (sv, coef) in self.sv_chunks.iter().zip(&self.coef_chunks) {
                let scores = self.engine.execute_f32(
                    &self.artifact,
                    &[&bq, sv, coef, &self.zero_bias, &self.gamma_buf],
                )?;
                for r in 0..batch {
                    out[base + r] += scores[r] as f64;
                }
            }
            base += batch;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::function::KernelFunction;
    use crate::kernel::native::NativeRowComputer;
    use crate::util::prng::Pcg;
    use std::path::Path;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("MANIFEST.json").exists().then_some(dir)
    }

    fn random_ds(n: usize, d: usize, seed: u64) -> Arc<Dataset> {
        let mut rng = Pcg::new(seed);
        let mut ds = Dataset::with_dim(d);
        let mut row = vec![0f32; d];
        for _ in 0..n {
            row.iter_mut().for_each(|v| *v = rng.normal() as f32);
            ds.push(&row, if rng.bernoulli(0.5) { 1 } else { -1 });
        }
        Arc::new(ds)
    }

    /// The central cross-layer test: PJRT rows == native rows.
    #[test]
    fn pjrt_rows_match_native_rows() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let engine = Rc::new(PjrtEngine::open(&dir).unwrap());
        // deliberately non-multiple of the chunk length to exercise padding
        let ds = random_ds(2500, 7, 5);
        let gamma = 0.8;
        let pjrt = PjrtRowComputer::new(engine, ds.clone(), gamma).unwrap();
        assert_eq!(pjrt.n_chunks(), 2);
        let native = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma });
        let mut rp = vec![0f32; ds.len()];
        let mut rn = vec![0f32; ds.len()];
        for &i in &[0usize, 1, 1024, 2047, 2048, 2499] {
            pjrt.compute_row(i, &mut rp);
            native.compute_row(i, &mut rn);
            for j in 0..ds.len() {
                assert!(
                    (rp[j] - rn[j]).abs() < 1e-4,
                    "row {i}, col {j}: pjrt {} vs native {}",
                    rp[j],
                    rn[j]
                );
            }
            assert!((rp[i] - 1.0).abs() < 1e-5);
        }
        assert!((pjrt.entry(3, 77) - native.entry(3, 77)).abs() < 1e-9);
    }

    #[test]
    fn pjrt_decision_matches_native_model() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let engine = Rc::new(PjrtEngine::open(&dir).unwrap());
        let sv = random_ds(300, 5, 9);
        let mut rng = Pcg::new(10);
        let coef: Vec<f64> = (0..300).map(|_| rng.normal()).collect();
        let bias = 0.25;
        let gamma = 0.4;
        let dec = PjrtDecision::new(engine, &sv, &coef, bias, gamma).unwrap();
        let queries = random_ds(33, 5, 11);
        let got = dec.decide(&queries).unwrap();
        let kf = KernelFunction::Rbf { gamma };
        for (r, &g) in got.iter().enumerate() {
            let mut want = bias;
            for s in 0..sv.len() {
                want += coef[s] * kf.eval(sv.row(s), queries.row(r));
            }
            assert!((g - want).abs() < 1e-3, "query {r}: {g} vs {want}");
        }
    }
}
