//! Surrogate generator for the paper's UCI/Rätsch datasets that have no
//! published generative definition (breast-cancer, diabetis, german, …).
//!
//! The QP the solver sees is fully determined by (K, y, C); the *identity*
//! of the features never enters. What shapes SMO's behaviour is ℓ, the
//! kernel-width regime, class balance, and — critically for Table 1/2 —
//! the mix of free vs bounded support vectors, which is driven by class
//! overlap / label noise. The surrogate therefore matches those knobs:
//! a mixture of `clusters` Gaussian blobs per class in `d` dimensions with
//! controlled separation, plus label-flip noise, optionally with a subset
//! of binary (categorical-like) features for the game datasets.

use crate::data::dataset::Dataset;
use crate::util::prng::Pcg;

/// Knobs for a surrogate dataset (see module docs).
#[derive(Debug, Clone)]
pub struct SurrogateSpec {
    /// Feature dimension d.
    pub dim: usize,
    /// Gaussian clusters per class.
    pub clusters: usize,
    /// Distance between class-cluster centers (in units of within-cluster sd).
    pub separation: f64,
    /// Fraction of labels flipped after generation (drives BSV count).
    pub label_noise: f64,
    /// Fraction of positive examples.
    pub positive_fraction: f64,
    /// Fraction of features that are binarized (0/1), mimicking
    /// categorical encodings (tic-tac-toe, connect-4, …).
    pub binary_fraction: f64,
}

impl Default for SurrogateSpec {
    fn default() -> Self {
        SurrogateSpec {
            dim: 10,
            clusters: 3,
            separation: 2.0,
            label_noise: 0.1,
            positive_fraction: 0.5,
            binary_fraction: 0.0,
        }
    }
}

/// Generate `n` examples from the surrogate mixture.
pub fn surrogate(n: usize, spec: &SurrogateSpec, seed: u64) -> Dataset {
    assert!(spec.dim > 0 && spec.clusters > 0);
    let mut rng = Pcg::new(seed);
    let d = spec.dim;
    // Cluster centers: unit-normal directions scaled to separation/2, the
    // positive class offset by +separation/2 along a shared random axis.
    let mut axis = vec![0f64; d];
    let norm = {
        let mut s = 0.0;
        for a in axis.iter_mut() {
            *a = rng.normal();
            s += *a * *a;
        }
        s.sqrt().max(1e-12)
    };
    axis.iter_mut().for_each(|a| *a /= norm);

    let mut centers = Vec::new(); // (class, center)
    for class in [1i8, -1] {
        for _ in 0..spec.clusters {
            let mut c: Vec<f64> = (0..d).map(|_| rng.normal() * spec.separation).collect();
            for (k, a) in axis.iter().enumerate() {
                c[k] += a * spec.separation / 2.0 * class as f64;
            }
            centers.push((class, c));
        }
    }

    let nbin = ((d as f64) * spec.binary_fraction).round() as usize;
    let mut ds = Dataset::with_dim(d);
    let mut row = vec![0f32; d];
    for _ in 0..n {
        let class: i8 = if rng.bernoulli(spec.positive_fraction) { 1 } else { -1 };
        // pick a random cluster of that class
        let of_class: Vec<usize> = centers
            .iter()
            .enumerate()
            .filter(|(_, (c, _))| *c == class)
            .map(|(i, _)| i)
            .collect();
        let (_, center) = &centers[of_class[rng.below(of_class.len())]];
        for k in 0..d {
            let v = center[k] + rng.normal();
            row[k] = if k < nbin {
                // binarize by sign — keeps a categorical flavour
                if v > 0.0 {
                    1.0
                } else {
                    0.0
                }
            } else {
                v as f32
            };
        }
        let y = if rng.bernoulli(spec.label_noise) { -class } else { class };
        ds.push(&row, y);
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_dim_and_len() {
        let ds = surrogate(200, &SurrogateSpec { dim: 7, ..Default::default() }, 1);
        assert_eq!(ds.len(), 200);
        assert_eq!(ds.dim(), 7);
    }

    #[test]
    fn positive_fraction_controls_balance() {
        let spec = SurrogateSpec {
            positive_fraction: 0.66,
            label_noise: 0.0,
            ..Default::default()
        };
        let ds = surrogate(10_000, &spec, 2);
        let (p, n) = ds.class_counts();
        let frac = p as f64 / (p + n) as f64;
        assert!((frac - 0.66).abs() < 0.03, "frac={frac}");
    }

    #[test]
    fn binary_fraction_binarizes_leading_features() {
        let spec = SurrogateSpec {
            dim: 10,
            binary_fraction: 0.5,
            ..Default::default()
        };
        let ds = surrogate(500, &spec, 3);
        for i in 0..ds.len() {
            for k in 0..5 {
                let v = ds.row(i)[k];
                assert!(v == 0.0 || v == 1.0, "feature {k} = {v}");
            }
        }
    }

    #[test]
    fn more_separation_is_more_linearly_separable() {
        // Compare a trivial linear classifier's accuracy on weakly vs
        // strongly separated data.
        let acc = |sep: f64| {
            let spec = SurrogateSpec {
                dim: 5,
                clusters: 1,
                separation: sep,
                label_noise: 0.0,
                ..Default::default()
            };
            let ds = surrogate(4000, &spec, 4);
            // class-mean classifier
            let mut mp = vec![0f64; 5];
            let mut mn = vec![0f64; 5];
            let (p, n) = ds.class_counts();
            for i in 0..ds.len() {
                let tgt = if ds.label(i) == 1 { &mut mp } else { &mut mn };
                for (k, &v) in ds.row(i).iter().enumerate() {
                    tgt[k] += v as f64;
                }
            }
            mp.iter_mut().for_each(|v| *v /= p as f64);
            mn.iter_mut().for_each(|v| *v /= n as f64);
            let mut correct = 0usize;
            for i in 0..ds.len() {
                let (mut dp, mut dn) = (0.0, 0.0);
                for (k, &v) in ds.row(i).iter().enumerate() {
                    dp += (v as f64 - mp[k]).powi(2);
                    dn += (v as f64 - mn[k]).powi(2);
                }
                let pred = if dp < dn { 1 } else { -1 };
                if pred == ds.label(i) {
                    correct += 1;
                }
            }
            correct as f64 / ds.len() as f64
        };
        assert!(acc(6.0) > acc(0.5) + 0.1);
    }

    #[test]
    fn label_noise_flips_labels() {
        let clean = SurrogateSpec {
            separation: 8.0,
            clusters: 1,
            label_noise: 0.0,
            ..Default::default()
        };
        let noisy = SurrogateSpec { label_noise: 0.4, ..clean.clone() };
        // With huge separation and one cluster per class, projection onto
        // the axis classifies perfectly absent noise; noise must degrade it.
        let err = |spec: &SurrogateSpec| {
            let ds = surrogate(3000, spec, 5);
            // 1-NN against 100 reference points of each class
            let refs: Vec<usize> = (0..200).collect();
            let mut wrong = 0usize;
            for i in 200..ds.len() {
                let mut best = (f64::INFINITY, 0i8);
                for &r in &refs {
                    let d = ds.sqdist(i, r);
                    if d < best.0 {
                        best = (d, ds.label(r));
                    }
                }
                if best.1 != ds.label(i) {
                    wrong += 1;
                }
            }
            wrong as f64 / (ds.len() - 200) as f64
        };
        assert!(err(&noisy) > err(&clean) + 0.1);
    }
}
