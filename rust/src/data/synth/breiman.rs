//! Breiman's classic synthetic benchmarks: twonorm, ringnorm, waveform.
//!
//! These three of the paper's Rätsch-suite datasets have published
//! generative definitions (Breiman 1996, "Bias, variance and arcing
//! classifiers"), so we reproduce them exactly rather than substituting.

use crate::data::dataset::Dataset;
use crate::util::prng::Pcg;

/// twonorm: d=20. Class +1 ~ N(+a·1, I), class −1 ~ N(−a·1, I), a = 2/√20.
pub fn twonorm(n: usize, seed: u64) -> Dataset {
    let d = 20usize;
    let a = 2.0 / (d as f64).sqrt();
    let mut rng = Pcg::new(seed);
    let mut ds = Dataset::with_dim(d);
    let mut row = vec![0f32; d];
    for _ in 0..n {
        let y: i8 = if rng.bernoulli(0.5) { 1 } else { -1 };
        let mean = a * y as f64;
        for v in row.iter_mut() {
            *v = rng.normal_ms(mean, 1.0) as f32;
        }
        ds.push(&row, y);
    }
    ds
}

/// ringnorm: d=20. Class +1 ~ N(0, 4I); class −1 ~ N(a·1, I), a = 1/√20.
pub fn ringnorm(n: usize, seed: u64) -> Dataset {
    let d = 20usize;
    let a = 1.0 / (d as f64).sqrt();
    let mut rng = Pcg::new(seed);
    let mut ds = Dataset::with_dim(d);
    let mut row = vec![0f32; d];
    for _ in 0..n {
        let y: i8 = if rng.bernoulli(0.5) { 1 } else { -1 };
        if y == 1 {
            for v in row.iter_mut() {
                *v = rng.normal_ms(0.0, 2.0) as f32;
            }
        } else {
            for v in row.iter_mut() {
                *v = rng.normal_ms(a, 1.0) as f32;
            }
        }
        ds.push(&row, y);
    }
    ds
}

/// The three triangular base waves of the waveform generator.
fn wave(h: usize, t: usize) -> f64 {
    // h1 peaks at t=7, h2 at t=15, h3 at t=11 (classic CART definition,
    // t = 1..21, triangle of half-width 6).
    let center = match h {
        1 => 7.0,
        2 => 15.0,
        3 => 11.0,
        _ => unreachable!(),
    };
    (6.0 - (t as f64 - center).abs()).max(0.0)
}

/// waveform: d=21, binary variant. Class +1 mixes waves (1,2), class −1
/// mixes waves (1,3); u ~ U[0,1], plus unit Gaussian noise per coordinate.
pub fn waveform(n: usize, seed: u64) -> Dataset {
    let d = 21usize;
    let mut rng = Pcg::new(seed);
    let mut ds = Dataset::with_dim(d);
    let mut row = vec![0f32; d];
    for _ in 0..n {
        let y: i8 = if rng.bernoulli(0.5) { 1 } else { -1 };
        let (wa, wb) = if y == 1 { (1, 2) } else { (1, 3) };
        let u = rng.uniform();
        for (t, v) in row.iter_mut().enumerate() {
            let base = u * wave(wa, t + 1) + (1.0 - u) * wave(wb, t + 1);
            *v = (base + rng.normal()) as f32;
        }
        ds.push(&row, y);
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn class_means(ds: &Dataset, class: i8) -> Vec<f64> {
        let mut mean = vec![0f64; ds.dim()];
        let mut count = 0usize;
        for i in 0..ds.len() {
            if ds.label(i) == class {
                count += 1;
                for (k, &v) in ds.row(i).iter().enumerate() {
                    mean[k] += v as f64;
                }
            }
        }
        mean.iter_mut().for_each(|m| *m /= count as f64);
        mean
    }

    #[test]
    fn twonorm_class_means_are_symmetric() {
        let ds = twonorm(20_000, 5);
        assert_eq!(ds.dim(), 20);
        let a = 2.0 / 20f64.sqrt();
        let mp = class_means(&ds, 1);
        let mn = class_means(&ds, -1);
        for k in 0..20 {
            assert!((mp[k] - a).abs() < 0.08, "mp[{k}]={}", mp[k]);
            assert!((mn[k] + a).abs() < 0.08, "mn[{k}]={}", mn[k]);
        }
    }

    #[test]
    fn ringnorm_class_variances_differ() {
        let ds = ringnorm(20_000, 6);
        let var = |class: i8| {
            let m = class_means(&ds, class);
            let mut v = 0f64;
            let mut c = 0usize;
            for i in 0..ds.len() {
                if ds.label(i) == class {
                    c += 1;
                    for (k, &x) in ds.row(i).iter().enumerate() {
                        v += (x as f64 - m[k]).powi(2);
                    }
                }
            }
            v / (c as f64 * 20.0)
        };
        let vp = var(1);
        let vn = var(-1);
        assert!((vp - 4.0).abs() < 0.3, "vp={vp}");
        assert!((vn - 1.0).abs() < 0.1, "vn={vn}");
    }

    #[test]
    fn waveform_has_triangular_structure() {
        let ds = waveform(20_000, 7);
        assert_eq!(ds.dim(), 21);
        // Coordinate 7 (t=8) is near wave-1 peak; both classes share wave 1,
        // so the class-mean difference concentrates at coords near t=15 vs 11.
        let mp = class_means(&ds, 1);
        let mn = class_means(&ds, -1);
        assert!(mp[14] > mn[14] + 0.5, "wave-2 peak separates classes");
        assert!(mn[10] > 0.0 && mp[10] > 0.0);
    }

    #[test]
    fn generators_are_deterministic() {
        assert_eq!(twonorm(64, 1), twonorm(64, 1));
        assert_eq!(ringnorm(64, 2), ringnorm(64, 2));
        assert_eq!(waveform(64, 3), waveform(64, 3));
    }
}
