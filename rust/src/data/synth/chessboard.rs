//! The chess-board problem (Glasmachers & Igel, 2005) — the paper's
//! hardest benchmark: a k×k checkerboard on `[0, k]²` with XOR labels.
//!
//! With γ=0.5 and C=10⁶ nearly all examples become free support vectors
//! with strong cross-dependencies, producing the oscillatory SMO behaviour
//! that motivates planning-ahead (paper §3, Table 2 rows chess-board-*).

use crate::data::dataset::Dataset;
use crate::util::prng::Pcg;

/// Sample `n` points uniformly on `[0, board]²`, labeled by checkerboard
/// parity. `board` is the number of fields per side (paper uses 4).
pub fn chessboard(n: usize, board: usize, seed: u64) -> Dataset {
    assert!(board >= 1);
    let mut rng = Pcg::new(seed);
    let mut ds = Dataset::with_dim(2);
    for _ in 0..n {
        let x0 = rng.range(0.0, board as f64);
        let x1 = rng.range(0.0, board as f64);
        // Clamp floor to the board (x == board has probability 0 but be safe).
        let c0 = (x0.floor() as usize).min(board - 1);
        let c1 = (x1.floor() as usize).min(board - 1);
        let y = if (c0 + c1) % 2 == 0 { 1 } else { -1 };
        ds.push(&[x0 as f32, x1 as f32], y);
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_follow_checkerboard_parity() {
        let ds = chessboard(500, 4, 1);
        for i in 0..ds.len() {
            let r = ds.row(i);
            let c0 = (r[0].floor() as usize).min(3);
            let c1 = (r[1].floor() as usize).min(3);
            let want = if (c0 + c1) % 2 == 0 { 1 } else { -1 };
            assert_eq!(ds.label(i), want);
        }
    }

    #[test]
    fn points_are_in_the_board() {
        let ds = chessboard(300, 4, 2);
        for i in 0..ds.len() {
            let r = ds.row(i);
            assert!(r[0] >= 0.0 && r[0] <= 4.0);
            assert!(r[1] >= 0.0 && r[1] <= 4.0);
        }
    }

    #[test]
    fn roughly_balanced_classes() {
        let ds = chessboard(4000, 4, 3);
        let (pos, neg) = ds.class_counts();
        let ratio = pos as f64 / (pos + neg) as f64;
        assert!((ratio - 0.5).abs() < 0.05, "ratio={ratio}");
    }

    #[test]
    fn deterministic_in_seed() {
        assert_eq!(chessboard(50, 4, 9), chessboard(50, 4, 9));
        assert_ne!(chessboard(50, 4, 9), chessboard(50, 4, 10));
    }

    #[test]
    fn single_field_board_is_one_class() {
        let ds = chessboard(100, 1, 4);
        assert!(ds.labels().iter().all(|&y| y == 1));
    }
}
