//! Banana-shaped 2-D benchmark (Rätsch suite): two interleaved crescents
//! with Gaussian noise — the canonical construction used for the
//! distributed "banana" dataset.

use crate::data::dataset::Dataset;
use crate::util::prng::Pcg;

/// Two noisy crescents of radius `r`, vertical/horizontal offset chosen so
/// the arms interleave. `noise` is the isotropic Gaussian sd.
pub fn banana(n: usize, seed: u64) -> Dataset {
    banana_with(n, 2.0, 0.6, seed)
}

/// Parameterized variant (used by tests and ablations).
pub fn banana_with(n: usize, r: f64, noise: f64, seed: u64) -> Dataset {
    let mut rng = Pcg::new(seed);
    let mut ds = Dataset::with_dim(2);
    for _ in 0..n {
        let y: i8 = if rng.bernoulli(0.5) { 1 } else { -1 };
        // Angle spans a half-moon; the two moons face each other.
        let theta = rng.range(0.0, std::f64::consts::PI);
        let (mut x0, mut x1) = if y == 1 {
            (r * theta.cos(), r * theta.sin())
        } else {
            (r - r * theta.cos(), -r * theta.sin() + r * 0.5)
        };
        x0 += rng.normal() * noise;
        x1 += rng.normal() * noise;
        ds.push(&[x0 as f32, x1 as f32], y);
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn is_2d_and_roughly_balanced() {
        let ds = banana(5000, 1);
        assert_eq!(ds.dim(), 2);
        let (p, n) = ds.class_counts();
        assert!((p as f64 / (p + n) as f64 - 0.5).abs() < 0.05);
    }

    #[test]
    fn classes_overlap_but_are_separated_in_the_mean() {
        let ds = banana(20_000, 2);
        let mut mp = [0f64; 2];
        let mut mn = [0f64; 2];
        let (p, n) = ds.class_counts();
        for i in 0..ds.len() {
            let r = ds.row(i);
            if ds.label(i) == 1 {
                mp[0] += r[0] as f64;
                mp[1] += r[1] as f64;
            } else {
                mn[0] += r[0] as f64;
                mn[1] += r[1] as f64;
            }
        }
        mp.iter_mut().for_each(|v| *v /= p as f64);
        mn.iter_mut().for_each(|v| *v /= n as f64);
        let dist = ((mp[0] - mn[0]).powi(2) + (mp[1] - mn[1]).powi(2)).sqrt();
        assert!(dist > 0.5, "class means too close: {dist}");
        assert!(dist < 6.0, "classes trivially separated: {dist}");
    }

    #[test]
    fn lower_noise_means_tighter_arms() {
        let tight = banana_with(5000, 2.0, 0.05, 3);
        let loose = banana_with(5000, 2.0, 1.5, 3);
        let spread = |ds: &Dataset| {
            let mut m = [0f64; 2];
            for i in 0..ds.len() {
                m[0] += ds.row(i)[0] as f64;
                m[1] += ds.row(i)[1] as f64;
            }
            m.iter_mut().for_each(|v| *v /= ds.len() as f64);
            (0..ds.len())
                .map(|i| {
                    (ds.row(i)[0] as f64 - m[0]).powi(2)
                        + (ds.row(i)[1] as f64 - m[1]).powi(2)
                })
                .sum::<f64>()
                / ds.len() as f64
        };
        assert!(spread(&tight) < spread(&loose));
    }
}
