//! Sparse synthetic classification data — the high-dimensional
//! low-density regime the CSR feature backend exists for (bag-of-words
//! style rows: a few stored coordinates out of thousands).
//!
//! Each example stores `nnz` of `dim` coordinates (so the dataset's
//! density is `nnz/dim` by construction), with values ~ N(0, 1) and the
//! label given by the sign of a fixed ±1 hyperplane drawn once from the
//! seed — a linearly separable-ish problem every kernel can learn, with
//! deterministic generation in the seed like the rest of the suite.

use crate::data::dataset::Dataset;
use crate::data::features::Features;
use crate::util::prng::Pcg;

/// Generate `n` sparse examples of dimension `dim` with exactly
/// `min(nnz, dim)` stored entries per row (values that happen to round
/// to ±0.0 are dropped by the CSR builder). The result uses CSR storage;
/// call [`Dataset::to_dense`] for the dense twin.
pub fn sparse_blobs(n: usize, dim: usize, nnz: usize, seed: u64) -> Dataset {
    assert!(dim > 0, "dim must be positive");
    let nnz = nnz.clamp(1, dim);
    let mut rng = Pcg::new(seed);
    // The labeling hyperplane: a dense ±1 weight vector, fixed per seed.
    let w: Vec<f64> = (0..dim).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
    let mut features = Features::sparse_with_dim(dim);
    let mut labels = Vec::with_capacity(n);
    let mut entries: Vec<(u32, f32)> = Vec::with_capacity(nnz);
    let mut picked = vec![false; dim];
    for _ in 0..n {
        entries.clear();
        // Sample `nnz` distinct coordinates by rejection (nnz ≪ dim in
        // the target regime, so collisions are rare).
        let mut chosen = 0usize;
        while chosen < nnz {
            let k = rng.below(dim);
            if !picked[k] {
                picked[k] = true;
                entries.push((k as u32, rng.normal() as f32));
                chosen += 1;
            }
        }
        entries.sort_unstable_by_key(|&(k, _)| k);
        let margin: f64 = entries.iter().map(|&(k, v)| w[k as usize] * v as f64).sum();
        labels.push(if margin >= 0.0 { 1 } else { -1 });
        features.push_entries(&entries);
        for &(k, _) in &entries {
            picked[k as usize] = false;
        }
    }
    Dataset::from_features(features, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn density_is_by_construction() {
        let ds = sparse_blobs(200, 1000, 10, 1);
        assert!(ds.is_sparse());
        assert_eq!(ds.len(), 200);
        assert_eq!(ds.dim(), 1000);
        // exactly 10 sampled per row; a handful may round to ±0.0
        assert!(ds.nnz() <= 2000 && ds.nnz() >= 1990, "nnz={}", ds.nnz());
    }

    #[test]
    fn deterministic_in_seed_and_rows_are_valid_csr() {
        let a = sparse_blobs(50, 300, 5, 7);
        let b = sparse_blobs(50, 300, 5, 7);
        assert_eq!(a, b);
        assert_ne!(a, sparse_blobs(50, 300, 5, 8));
        // round trip through dense preserves everything
        assert_eq!(a.to_dense().to_sparse(), a);
    }

    #[test]
    fn both_classes_appear() {
        let ds = sparse_blobs(300, 500, 8, 3);
        let (pos, neg) = ds.class_counts();
        assert!(pos > 30 && neg > 30, "pos={pos} neg={neg}");
    }

    #[test]
    fn nnz_clamps_to_dim() {
        let ds = sparse_blobs(10, 3, 50, 2);
        assert_eq!(ds.dim(), 3);
        for i in 0..ds.len() {
            assert!(ds.row_ref(i).nnz() <= 3);
        }
    }
}
