//! Synthetic dataset generators.
//!
//! Where the paper's dataset has a published generative definition
//! (chess-board, twonorm, ringnorm, waveform, banana) we implement it
//! exactly; the remaining UCI/Rätsch sets are replaced by surrogate
//! mixture generators matched on the QP-relevant knobs (ℓ, d, class
//! balance, label noise) — see DESIGN.md §4.

pub mod banana;
pub mod breiman;
pub mod chessboard;
pub mod sparse;
pub mod surrogate;

pub use banana::banana;
pub use breiman::{ringnorm, twonorm, waveform};
pub use chessboard::chessboard;
pub use sparse::sparse_blobs;
pub use surrogate::{surrogate, SurrogateSpec};
