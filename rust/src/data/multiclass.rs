//! Dense multiclass dataset (arbitrary integer labels) — the substrate
//! for one-vs-one classification (`svm::multiclass`). Lives in the data
//! layer so LIBSVM IO ([`super::libsvm::read_multiclass`]) and the
//! batch scorer can consume it without the `svm` layer in between.

use std::collections::BTreeSet;

/// A multiclass dataset: dense features with arbitrary integer labels.
#[derive(Debug, Clone, PartialEq)]
pub struct MulticlassDataset {
    dim: usize,
    features: Vec<f32>,
    labels: Vec<i32>,
}

impl MulticlassDataset {
    /// Empty dataset of the given feature dimension.
    pub fn with_dim(dim: usize) -> MulticlassDataset {
        assert!(dim > 0);
        MulticlassDataset { dim, features: Vec::new(), labels: Vec::new() }
    }

    /// Append an example.
    pub fn push(&mut self, x: &[f32], y: i32) {
        assert_eq!(x.len(), self.dim);
        self.features.extend_from_slice(x);
        self.labels.push(y);
    }

    /// Number of examples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// Is the dataset empty?
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Feature dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Feature row of example `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.features[i * self.dim..(i + 1) * self.dim]
    }

    /// Class label of example `i`.
    #[inline]
    pub fn label(&self, i: usize) -> i32 {
        self.labels[i]
    }

    /// Raw row-major feature buffer (the batch-scoring input shape).
    pub fn features(&self) -> &[f32] {
        &self.features
    }

    /// Distinct classes, sorted.
    pub fn classes(&self) -> Vec<i32> {
        self.labels.iter().copied().collect::<BTreeSet<_>>().into_iter().collect()
    }
}

/// Synthetic k-class Gaussian blobs on a circle (test/demo generator).
pub fn blobs(n: usize, k: usize, radius: f64, sd: f64, seed: u64) -> MulticlassDataset {
    use crate::util::prng::Pcg;
    assert!(k >= 2);
    let mut rng = Pcg::new(seed);
    let mut ds = MulticlassDataset::with_dim(2);
    for _ in 0..n {
        let c = rng.below(k);
        let theta = 2.0 * std::f64::consts::PI * c as f64 / k as f64;
        ds.push(
            &[
                (radius * theta.cos() + rng.normal() * sd) as f32,
                (radius * theta.sin() + rng.normal() * sd) as f32,
            ],
            c as i32,
        );
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors_and_classes() {
        let mut ds = MulticlassDataset::with_dim(2);
        ds.push(&[1.0, 2.0], 7);
        ds.push(&[3.0, 4.0], 2);
        ds.push(&[5.0, 6.0], 7);
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 2);
        assert_eq!(ds.row(1), &[3.0, 4.0]);
        assert_eq!(ds.label(2), 7);
        assert_eq!(ds.classes(), vec![2, 7]);
        assert_eq!(ds.features(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn blobs_generates_k_classes() {
        let ds = blobs(120, 3, 4.0, 0.3, 1);
        assert_eq!(ds.len(), 120);
        assert_eq!(ds.classes(), vec![0, 1, 2]);
    }
}
