//! LIBSVM sparse text format reader/writer.
//!
//! Format: one example per line, `<label> <index>:<value> ...` with
//! 1-based, strictly increasing indices. We densify on read (the solver
//! and the PJRT artifacts are dense); `dim` is the max index seen unless
//! an explicit dimension is forced (to align train/test files).
//!
//! Three label interpretations share one line parser:
//! * [`read`] — binary ±1 labels (sign of the value, zero rejected),
//! * [`read_regression`] — real-valued targets,
//! * [`read_multiclass`] — arbitrary integer class labels.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};

use super::dataset::Dataset;
use super::multiclass::MulticlassDataset;
use super::regression::RegressionDataset;

/// One parsed sparse example.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseExample {
    /// Class label (±1, sign of the parsed value).
    pub label: i8,
    /// (0-based index, value), strictly increasing by index.
    pub entries: Vec<(usize, f32)>,
}

/// Parse one LIBSVM line without interpreting the label: the raw f64
/// label value plus the sparse entries.
fn parse_line_raw(line: &str) -> Result<(f64, Vec<(usize, f32)>)> {
    let mut parts = line.split_ascii_whitespace();
    let label_tok = parts.next().context("empty line")?;
    let label_val: f64 = label_tok
        .parse()
        .with_context(|| format!("bad label {label_tok:?}"))?;
    let mut entries = Vec::new();
    let mut last = 0usize; // 1-based last index
    for tok in parts {
        if tok.starts_with('#') {
            break; // trailing comment
        }
        let (idx, val) = tok
            .split_once(':')
            .with_context(|| format!("bad feature token {tok:?}"))?;
        let idx: usize = idx.parse().with_context(|| format!("bad index {idx:?}"))?;
        if idx == 0 {
            bail!("indices are 1-based, got 0");
        }
        if idx <= last {
            bail!("indices must be strictly increasing ({last} then {idx})");
        }
        last = idx;
        let val: f32 = val.parse().with_context(|| format!("bad value {val:?}"))?;
        entries.push((idx - 1, val));
    }
    Ok((label_val, entries))
}

/// Parse one LIBSVM line. Accepts labels `+1/-1/1/-1.0` etc. (sign only).
pub fn parse_line(line: &str) -> Result<SparseExample> {
    let (label_val, entries) = parse_line_raw(line)?;
    let label = if label_val > 0.0 {
        1
    } else if label_val < 0.0 {
        -1
    } else {
        bail!("label must be nonzero (+1/-1), got {label_val:?}");
    };
    Ok(SparseExample { label, entries })
}

/// One raw example: 1-based source line, raw f64 label, sparse entries.
type RawExample = (usize, f64, Vec<(usize, f32)>);

/// Shared reading loop: every non-comment line's raw (label, entries)
/// plus the resolved dense dimension.
fn read_raw<R: BufRead>(reader: R, force_dim: Option<usize>) -> Result<(usize, Vec<RawExample>)> {
    let mut examples = Vec::new();
    let mut max_dim = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let (label, entries) = parse_line_raw(trimmed)
            .with_context(|| format!("line {}", lineno + 1))?;
        if let Some((idx, _)) = entries.last() {
            max_dim = max_dim.max(idx + 1);
        }
        examples.push((lineno + 1, label, entries));
    }
    let dim = match force_dim {
        Some(d) => {
            if d < max_dim {
                bail!("force_dim {d} < max feature index {max_dim}");
            }
            d
        }
        None => max_dim.max(1),
    };
    Ok((dim, examples))
}

/// Scatter sparse entries into a zeroed dense row.
fn densify(entries: &[(usize, f32)], row: &mut [f32]) {
    row.iter_mut().for_each(|v| *v = 0.0);
    for &(i, v) in entries {
        row[i] = v;
    }
}

/// Read a LIBSVM file into a dense [`Dataset`]. `force_dim` overrides the
/// inferred dimension (must be >= max index).
pub fn read(path: &Path, force_dim: Option<usize>) -> Result<Dataset> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    read_from(std::io::BufReader::new(file), force_dim)
}

/// Read from any buffered reader (unit-testable without touching disk).
pub fn read_from<R: BufRead>(reader: R, force_dim: Option<usize>) -> Result<Dataset> {
    let (dim, examples) = read_raw(reader, force_dim)?;
    let mut ds = Dataset::with_dim(dim);
    let mut row = vec![0f32; dim];
    for (lineno, label, entries) in &examples {
        let y = if *label > 0.0 {
            1
        } else if *label < 0.0 {
            -1
        } else {
            bail!("line {lineno}: label must be nonzero (+1/-1)");
        };
        densify(entries, &mut row);
        ds.push(&row, y);
    }
    Ok(ds)
}

/// Read a LIBSVM file as a regression set: the label column is the
/// real-valued target (any value, including 0).
pub fn read_regression(path: &Path, force_dim: Option<usize>) -> Result<RegressionDataset> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    read_regression_from(std::io::BufReader::new(file), force_dim)
}

/// [`read_regression`] from any buffered reader.
pub fn read_regression_from<R: BufRead>(
    reader: R,
    force_dim: Option<usize>,
) -> Result<RegressionDataset> {
    let (dim, examples) = read_raw(reader, force_dim)?;
    let mut ds = RegressionDataset::with_dim(dim);
    let mut row = vec![0f32; dim];
    for (_, target, entries) in &examples {
        densify(entries, &mut row);
        ds.push(&row, *target);
    }
    Ok(ds)
}

/// Read a LIBSVM file as a multiclass set: the label column is an
/// arbitrary integer class id.
pub fn read_multiclass(path: &Path, force_dim: Option<usize>) -> Result<MulticlassDataset> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    read_multiclass_from(std::io::BufReader::new(file), force_dim)
}

/// [`read_multiclass`] from any buffered reader.
pub fn read_multiclass_from<R: BufRead>(
    reader: R,
    force_dim: Option<usize>,
) -> Result<MulticlassDataset> {
    let (dim, examples) = read_raw(reader, force_dim)?;
    let mut ds = MulticlassDataset::with_dim(dim);
    let mut row = vec![0f32; dim];
    for (lineno, label, entries) in &examples {
        if label.fract() != 0.0 || label.abs() > i32::MAX as f64 {
            bail!("line {lineno}: multiclass label {label} is not an integer class id");
        }
        densify(entries, &mut row);
        ds.push(&row, *label as i32);
    }
    Ok(ds)
}

/// Write one dense row's non-zero entries as ` index:value` tokens.
fn write_entries<W: Write>(w: &mut W, row: &[f32]) -> Result<()> {
    for (j, &v) in row.iter().enumerate() {
        if v != 0.0 {
            write!(w, " {}:{}", j + 1, v)?;
        }
    }
    writeln!(w)?;
    Ok(())
}

/// Write a dataset in LIBSVM format (zero entries skipped).
pub fn write(ds: &Dataset, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for i in 0..ds.len() {
        write!(w, "{}", if ds.label(i) > 0 { "+1" } else { "-1" })?;
        write_entries(&mut w, ds.row(i))?;
    }
    Ok(())
}

/// Write a regression dataset in LIBSVM format (the label column is the
/// f64 target; zero feature entries skipped).
pub fn write_regression(ds: &RegressionDataset, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for i in 0..ds.len() {
        write!(w, "{}", ds.target(i))?;
        write_entries(&mut w, ds.row(i))?;
    }
    Ok(())
}

/// Write a multiclass dataset in LIBSVM format (integer class labels;
/// zero feature entries skipped).
pub fn write_multiclass(ds: &MulticlassDataset, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for i in 0..ds.len() {
        write!(w, "{}", ds.label(i))?;
        write_entries(&mut w, ds.row(i))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_basic_lines() {
        let ex = parse_line("+1 1:0.5 3:2 7:-1.25").unwrap();
        assert_eq!(ex.label, 1);
        assert_eq!(ex.entries, vec![(0, 0.5), (2, 2.0), (6, -1.25)]);
        let ex = parse_line("-1.0 2:1e-3").unwrap();
        assert_eq!(ex.label, -1);
        assert_eq!(ex.entries, vec![(1, 1e-3)]);
    }

    #[test]
    fn label_only_line_is_valid() {
        let ex = parse_line("+1").unwrap();
        assert!(ex.entries.is_empty());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_line("0 1:2").is_err()); // zero label
        assert!(parse_line("+1 0:2").is_err()); // 0-based index
        assert!(parse_line("+1 2:1 2:3").is_err()); // non-increasing
        assert!(parse_line("+1 a:b").is_err());
        assert!(parse_line("").is_err());
    }

    #[test]
    fn read_densifies_and_infers_dim() {
        let text = "+1 1:1 3:3\n-1 2:2\n\n# comment\n+1 1:9\n";
        let ds = read_from(Cursor::new(text), None).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.row(0), &[1.0, 0.0, 3.0]);
        assert_eq!(ds.row(1), &[0.0, 2.0, 0.0]);
        assert_eq!(ds.labels(), &[1, -1, 1]);
    }

    #[test]
    fn force_dim_pads_and_validates() {
        let ds = read_from(Cursor::new("+1 1:1\n"), Some(5)).unwrap();
        assert_eq!(ds.dim(), 5);
        assert!(read_from(Cursor::new("+1 9:1\n"), Some(3)).is_err());
    }

    #[test]
    fn regression_reader_keeps_real_targets() {
        let text = "0.5 1:1\n-2.25 2:3\n0 1:7\n";
        let ds = read_regression_from(Cursor::new(text), None).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 2);
        assert_eq!(ds.target(0), 0.5);
        assert_eq!(ds.target(1), -2.25);
        assert_eq!(ds.target(2), 0.0, "zero targets are valid for regression");
        assert_eq!(ds.row(1), &[0.0, 3.0]);
    }

    #[test]
    fn multiclass_reader_keeps_integer_classes() {
        let text = "3 1:1\n0 2:1\n-7 1:2 2:2\n";
        let ds = read_multiclass_from(Cursor::new(text), None).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.label(0), 3);
        assert_eq!(ds.label(1), 0);
        assert_eq!(ds.label(2), -7);
        assert_eq!(ds.classes(), vec![-7, 0, 3]);
    }

    #[test]
    fn multiclass_reader_rejects_fractional_labels() {
        let err = read_multiclass_from(Cursor::new("1.5 1:1\n"), None).unwrap_err();
        assert!(format!("{err:#}").contains("not an integer"), "{err:#}");
    }

    #[test]
    fn binary_reader_rejects_zero_label_with_line_number() {
        let err = read_from(Cursor::new("+1 1:1\n0 1:2\n"), None).unwrap_err();
        assert!(format!("{err:#}").contains("line 2"), "{err:#}");
    }

    #[test]
    fn regression_and_multiclass_round_trip_through_files() {
        let dir = std::env::temp_dir().join("pasmo-libsvm-rt-test");
        std::fs::create_dir_all(&dir).unwrap();

        let rpath = dir.join("reg.libsvm");
        let mut rd = crate::data::regression::RegressionDataset::with_dim(2);
        rd.push(&[1.5, 0.0], 0.25);
        rd.push(&[0.0, -2.0], -3.5);
        write_regression(&rd, &rpath).unwrap();
        let rrt = read_regression(&rpath, Some(2)).unwrap();
        assert_eq!(rd, rrt);

        let mpath = dir.join("multi.libsvm");
        let mut md = MulticlassDataset::with_dim(2);
        md.push(&[1.0, 2.0], 4);
        md.push(&[0.5, 0.0], -1);
        write_multiclass(&md, &mpath).unwrap();
        let mrt = read_multiclass(&mpath, Some(2)).unwrap();
        assert_eq!(md, mrt);

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn round_trip_through_file() {
        let dir = std::env::temp_dir().join("pasmo-libsvm-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.libsvm");
        let ds = Dataset::new(3, vec![1.0, 0.0, 2.5, 0.0, 0.0, 0.0], vec![1, -1]);
        write(&ds, &path).unwrap();
        let rt = read(&path, Some(3)).unwrap();
        assert_eq!(ds, rt);
        std::fs::remove_file(&path).ok();
    }
}
