//! LIBSVM sparse text format: strict positioned parsing, streaming
//! CSR-building readers, and writers.
//!
//! Format: one example per line, `<label> <index>:<value> ...` with
//! 1-based, strictly increasing indices. Whole-line comments (`# ...`)
//! and trailing comments (`... # note`) are allowed; anything else that
//! deviates from the grammar — empty lines, duplicate or out-of-order
//! indices, index `0`, indices beyond `u32::MAX`, non-numeric labels,
//! indices or values, stray tokens — is refused with a positioned
//! `line N, col C` error instead of being skipped or silently repaired.
//!
//! Reading is **streaming**: lines are parsed one at a time (a reused
//! buffer per line, [`read_with`]) or as borrowed slices of one
//! whole-file buffer ([`read_mapped`], the std-only stand-in for an
//! mmap'd view), and each example's entries are appended directly to a
//! CSR accumulation — a dense matrix is never materialized unless dense
//! storage is actually requested. [`Storage`] selects the final backend;
//! [`Storage::Auto`] keeps CSR for files at or below
//! [`AUTO_SPARSE_MAX_DENSITY`] stored density and densifies above it.
//! `dim` is the max index seen unless an explicit dimension is forced
//! (to align train/test files).
//!
//! Three label interpretations share the strict parser:
//! * [`read`] / [`read_auto`] / [`read_with`] — binary ±1 labels (sign
//!   of the value, zero rejected),
//! * [`read_regression`] — real-valued targets,
//! * [`read_multiclass`] — arbitrary integer class labels.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};

use super::dataset::Dataset;
use super::features::{Features, Row};
use super::multiclass::MulticlassDataset;
use super::regression::RegressionDataset;

/// [`Storage::Auto`] threshold: a file whose stored-entry density is at
/// or below this fraction keeps its CSR representation; denser files
/// are scattered into the dense row-major layout (at which point CSR
/// bookkeeping would cost more than it saves).
pub const AUTO_SPARSE_MAX_DENSITY: f64 = 0.25;

/// Which feature backend a LIBSVM read materializes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Storage {
    /// Choose by stored density: CSR at or below
    /// [`AUTO_SPARSE_MAX_DENSITY`], dense above it.
    Auto,
    /// Scatter into dense row-major storage (the historical behavior).
    Dense,
    /// Keep the CSR representation built while streaming.
    Sparse,
}

impl Storage {
    /// Parse a `--storage` flag value (`auto` / `dense` / `sparse`).
    pub fn parse(s: &str) -> Result<Storage> {
        match s {
            "auto" => Ok(Storage::Auto),
            "dense" => Ok(Storage::Dense),
            "sparse" => Ok(Storage::Sparse),
            other => bail!("unknown storage {other:?} (expected auto|dense|sparse)"),
        }
    }
}

/// One parsed sparse example (the single-line entry point's shape).
#[derive(Debug, Clone, PartialEq)]
pub struct SparseExample {
    /// Class label (±1, sign of the parsed value).
    pub label: i8,
    /// (0-based index, value), strictly increasing by index. Values that
    /// parse to exact `±0.0` are dropped (they are indistinguishable
    /// from absent coordinates to every consumer).
    pub entries: Vec<(usize, f32)>,
}

/// Tokens of a line paired with their 1-based byte column — the `col`
/// every parse error reports.
fn tokens(line: &str) -> impl Iterator<Item = (usize, &str)> + '_ {
    let base = line.as_ptr() as usize;
    line.split_ascii_whitespace()
        .map(move |tok| (tok.as_ptr() as usize - base + 1, tok))
}

/// Streaming CSR accumulation: every fed line appends its stored
/// entries in place; no per-line or whole-matrix dense buffer exists.
struct CsrAccum {
    /// Row start offsets (`examples + 1` entries).
    offsets: Vec<usize>,
    /// 0-based column indices, strictly increasing within each row.
    indices: Vec<u32>,
    /// Stored values, parallel to `indices`.
    values: Vec<f32>,
    /// Raw f64 label column, one per example.
    labels: Vec<f64>,
    /// 1-based source line of each example (for positioned label errors).
    linenos: Vec<usize>,
    /// Highest 1-based feature index seen (zero-valued entries count).
    max_index: u64,
}

impl CsrAccum {
    fn new() -> CsrAccum {
        CsrAccum {
            offsets: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
            labels: Vec::new(),
            linenos: Vec::new(),
            max_index: 0,
        }
    }

    /// Parse one source line (1-based `lineno`). Comment lines are
    /// skipped; anything else must be a grammatical example or the whole
    /// read fails with a `line N, col C` position.
    fn feed(&mut self, lineno: usize, line: &str) -> Result<()> {
        let line = line.trim_end_matches(|c| c == '\n' || c == '\r');
        let trimmed = line.trim();
        if trimmed.starts_with('#') {
            return Ok(()); // whole-line comment
        }
        if trimmed.is_empty() {
            bail!("line {lineno}, col 1: empty line (remove it or comment it out with '#')");
        }
        let mut toks = tokens(line);
        let (lcol, ltok) = toks.next().unwrap_or((1, ""));
        let label: f64 = ltok
            .parse()
            .ok()
            .with_context(|| format!("line {lineno}, col {lcol}: bad label {ltok:?}"))?;
        let mut last = 0u64; // 1-based previous index
        for (col, tok) in toks {
            if tok.starts_with('#') {
                break; // trailing comment
            }
            let (itok, vtok) = tok.split_once(':').with_context(|| {
                format!("line {lineno}, col {col}: bad feature token {tok:?} (expected index:value)")
            })?;
            let idx: u64 = itok
                .parse()
                .ok()
                .with_context(|| format!("line {lineno}, col {col}: bad index {itok:?}"))?;
            if idx == 0 {
                bail!("line {lineno}, col {col}: indices are 1-based, got 0");
            }
            if idx > u32::MAX as u64 {
                bail!(
                    "line {lineno}, col {col}: index {idx} exceeds the supported maximum {}",
                    u32::MAX
                );
            }
            if idx == last {
                bail!("line {lineno}, col {col}: duplicate index {idx}");
            }
            if idx < last {
                bail!("line {lineno}, col {col}: out-of-order index {idx} after {last}");
            }
            last = idx;
            let val: f32 = vtok
                .parse()
                .ok()
                .with_context(|| format!("line {lineno}, col {col}: bad value {vtok:?}"))?;
            // Exact ±0.0 is indistinguishable from an absent coordinate;
            // dropping it keeps CSR reads identical to densify→sparsify.
            if val.to_bits() << 1 != 0 {
                self.indices.push((idx - 1) as u32);
                self.values.push(val);
            }
            self.max_index = self.max_index.max(idx);
        }
        self.offsets.push(self.indices.len());
        self.labels.push(label);
        self.linenos.push(lineno);
        Ok(())
    }

    /// Resolve the dense dimension and freeze the accumulation.
    fn finish(self, force_dim: Option<usize>) -> Result<LibsvmFile> {
        let max_dim = self.max_index as usize;
        let dim = match force_dim {
            Some(d) => {
                if d < max_dim {
                    bail!("force_dim {d} < max feature index {max_dim}");
                }
                d
            }
            None => max_dim.max(1),
        };
        Ok(LibsvmFile { dim, accum: self })
    }
}

/// A fully parsed LIBSVM file: the CSR accumulation plus its resolved
/// dense dimension, ready to materialize under any [`Storage`].
struct LibsvmFile {
    dim: usize,
    accum: CsrAccum,
}

impl LibsvmFile {
    fn len(&self) -> usize {
        self.accum.labels.len()
    }

    /// Stored entries over the full `len × dim` grid.
    fn density(&self) -> f64 {
        let cells = self.len() * self.dim;
        if cells == 0 {
            1.0
        } else {
            self.accum.indices.len() as f64 / cells as f64
        }
    }

    /// Scatter example `r` into a dense row buffer.
    fn densify_row(&self, r: usize, row: &mut [f32]) {
        row.iter_mut().for_each(|v| *v = 0.0);
        for p in self.accum.offsets[r]..self.accum.offsets[r + 1] {
            row[self.accum.indices[p] as usize] = self.accum.values[p];
        }
    }

    /// Materialize the feature matrix under the requested storage.
    fn into_features(self, storage: Storage) -> Features {
        let keep_csr = match storage {
            Storage::Sparse => true,
            Storage::Dense => false,
            Storage::Auto => self.density() <= AUTO_SPARSE_MAX_DENSITY,
        };
        if keep_csr {
            Features::from_csr(self.dim, self.accum.offsets, self.accum.indices, self.accum.values)
        } else {
            let (len, dim) = (self.len(), self.dim);
            let mut rows = vec![0f32; len * dim];
            for r in 0..len {
                let base = r * dim;
                for p in self.accum.offsets[r]..self.accum.offsets[r + 1] {
                    rows[base + self.accum.indices[p] as usize] = self.accum.values[p];
                }
            }
            Features::dense(dim, rows)
        }
    }

    /// Interpret the label column as binary ±1 (sign of the value, zero
    /// refused with its source line).
    fn binary_labels(&self) -> Result<Vec<i8>> {
        let mut out = Vec::with_capacity(self.len());
        for (r, &label) in self.accum.labels.iter().enumerate() {
            if label > 0.0 {
                out.push(1);
            } else if label < 0.0 {
                out.push(-1);
            } else {
                bail!(
                    "line {}: label must be nonzero (+1/-1), got {label:?}",
                    self.accum.linenos[r]
                );
            }
        }
        Ok(out)
    }
}

/// Parse one LIBSVM line. Accepts labels `+1/-1/1/-1.0` etc. (sign only).
pub fn parse_line(line: &str) -> Result<SparseExample> {
    let mut accum = CsrAccum::new();
    accum.feed(1, line)?;
    let label_val = *accum
        .labels
        .first()
        .context("comment line holds no example")?;
    let label = if label_val > 0.0 {
        1
    } else if label_val < 0.0 {
        -1
    } else {
        bail!("label must be nonzero (+1/-1), got {label_val:?}");
    };
    let entries = accum
        .indices
        .iter()
        .zip(&accum.values)
        .map(|(&i, &v)| (i as usize, v))
        .collect();
    Ok(SparseExample { label, entries })
}

/// Stream every line of `reader` through the strict parser into a CSR
/// accumulation, reusing one line buffer (the constant-memory path for
/// arbitrarily long files).
fn accum_from<R: BufRead>(mut reader: R) -> Result<CsrAccum> {
    let mut accum = CsrAccum::new();
    let mut line = String::new();
    let mut lineno = 0usize;
    loop {
        line.clear();
        let n = reader
            .read_line(&mut line)
            .with_context(|| format!("line {}: read failed (invalid UTF-8?)", lineno + 1))?;
        if n == 0 {
            break;
        }
        lineno += 1;
        accum.feed(lineno, &line)?;
    }
    Ok(accum)
}

/// Read a LIBSVM file into a dense [`Dataset`]. `force_dim` overrides the
/// inferred dimension (must be >= max index).
pub fn read(path: &Path, force_dim: Option<usize>) -> Result<Dataset> {
    read_with(path, force_dim, Storage::Dense)
}

/// Read a LIBSVM file, keeping CSR storage when the file is sparse
/// enough ([`Storage::Auto`]).
pub fn read_auto(path: &Path, force_dim: Option<usize>) -> Result<Dataset> {
    read_with(path, force_dim, Storage::Auto)
}

/// Read a LIBSVM file (streaming, buffered line at a time) into the
/// requested [`Storage`].
pub fn read_with(path: &Path, force_dim: Option<usize>, storage: Storage) -> Result<Dataset> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    read_with_from(std::io::BufReader::new(file), force_dim, storage)
}

/// Read from any buffered reader into a dense [`Dataset`]
/// (unit-testable without touching disk).
pub fn read_from<R: BufRead>(reader: R, force_dim: Option<usize>) -> Result<Dataset> {
    read_with_from(reader, force_dim, Storage::Dense)
}

/// [`read_with`] from any buffered reader.
pub fn read_with_from<R: BufRead>(
    reader: R,
    force_dim: Option<usize>,
    storage: Storage,
) -> Result<Dataset> {
    let file = accum_from(reader)?.finish(force_dim)?;
    let labels = file.binary_labels()?;
    Ok(Dataset::from_features(file.into_features(storage), labels))
}

/// Whole-file read: the file is pulled into one resident buffer and
/// parsed as borrowed per-line slices — no per-line allocation or
/// copying, the std-only stand-in for an mmap'd view (the toolchain
/// image carries no mmap crate and `unsafe` is audited out of this
/// layer). Produces a dataset identical to the streaming [`read_with`].
pub fn read_mapped(path: &Path, force_dim: Option<usize>, storage: Storage) -> Result<Dataset> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut accum = CsrAccum::new();
    let mut pieces = bytes.split(|&b| b == b'\n').enumerate().peekable();
    while let Some((i, raw)) = pieces.next() {
        let raw = match raw.last() {
            Some(&b'\r') => &raw[..raw.len() - 1],
            _ => raw,
        };
        if pieces.peek().is_none() && raw.is_empty() {
            break; // the remainder after a final newline, not a line
        }
        let line = match std::str::from_utf8(raw) {
            Ok(s) => s,
            Err(_) => bail!("line {}: invalid UTF-8", i + 1),
        };
        accum.feed(i + 1, line)?;
    }
    let file = accum.finish(force_dim)?;
    let labels = file.binary_labels()?;
    Ok(Dataset::from_features(file.into_features(storage), labels))
}

/// Read a LIBSVM file as a regression set: the label column is the
/// real-valued target (any value, including 0).
pub fn read_regression(path: &Path, force_dim: Option<usize>) -> Result<RegressionDataset> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    read_regression_from(std::io::BufReader::new(file), force_dim)
}

/// [`read_regression`] from any buffered reader.
pub fn read_regression_from<R: BufRead>(
    reader: R,
    force_dim: Option<usize>,
) -> Result<RegressionDataset> {
    let file = accum_from(reader)?.finish(force_dim)?;
    let mut ds = RegressionDataset::with_dim(file.dim);
    let mut row = vec![0f32; file.dim];
    for r in 0..file.len() {
        file.densify_row(r, &mut row);
        ds.push(&row, file.accum.labels[r]);
    }
    Ok(ds)
}

/// Read a LIBSVM file as a multiclass set: the label column is an
/// arbitrary integer class id.
pub fn read_multiclass(path: &Path, force_dim: Option<usize>) -> Result<MulticlassDataset> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    read_multiclass_from(std::io::BufReader::new(file), force_dim)
}

/// [`read_multiclass`] from any buffered reader.
pub fn read_multiclass_from<R: BufRead>(
    reader: R,
    force_dim: Option<usize>,
) -> Result<MulticlassDataset> {
    let file = accum_from(reader)?.finish(force_dim)?;
    let mut ds = MulticlassDataset::with_dim(file.dim);
    let mut row = vec![0f32; file.dim];
    for r in 0..file.len() {
        let label = file.accum.labels[r];
        if label.fract() != 0.0 || label.abs() > i32::MAX as f64 {
            bail!(
                "line {}: multiclass label {label} is not an integer class id",
                file.accum.linenos[r]
            );
        }
        file.densify_row(r, &mut row);
        ds.push(&row, label as i32);
    }
    Ok(ds)
}

/// Write one row's stored non-zero entries as ` index:value` tokens
/// (either backend; dense rows skip their zeros, so a dense↔sparse pair
/// of the same logical dataset writes byte-identical files).
fn write_entries<W: Write>(w: &mut W, row: Row<'_>) -> Result<()> {
    let mut io_err: Option<std::io::Error> = None;
    row.for_each_entry(|idx, v| {
        if v != 0.0 {
            if io_err.is_none() {
                if let Err(e) = write!(w, " {}:{}", idx + 1, v) {
                    io_err = Some(e);
                }
            }
        }
    });
    if let Some(e) = io_err {
        return Err(e.into());
    }
    writeln!(w)?;
    Ok(())
}

/// Write a dataset in LIBSVM format (zero entries skipped; both storage
/// backends accepted).
pub fn write(ds: &Dataset, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for i in 0..ds.len() {
        write!(w, "{}", if ds.label(i) > 0 { "+1" } else { "-1" })?;
        write_entries(&mut w, ds.row_ref(i))?;
    }
    Ok(())
}

/// Write a regression dataset in LIBSVM format (the label column is the
/// f64 target; zero feature entries skipped).
pub fn write_regression(ds: &RegressionDataset, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for i in 0..ds.len() {
        write!(w, "{}", ds.target(i))?;
        write_entries(&mut w, Row::Dense(ds.row(i)))?;
    }
    Ok(())
}

/// Write a multiclass dataset in LIBSVM format (integer class labels;
/// zero feature entries skipped).
pub fn write_multiclass(ds: &MulticlassDataset, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for i in 0..ds.len() {
        write!(w, "{}", ds.label(i))?;
        write_entries(&mut w, Row::Dense(ds.row(i)))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_basic_lines() {
        let ex = parse_line("+1 1:0.5 3:2 7:-1.25").unwrap();
        assert_eq!(ex.label, 1);
        assert_eq!(ex.entries, vec![(0, 0.5), (2, 2.0), (6, -1.25)]);
        let ex = parse_line("-1.0 2:1e-3").unwrap();
        assert_eq!(ex.label, -1);
        assert_eq!(ex.entries, vec![(1, 1e-3)]);
    }

    #[test]
    fn label_only_line_is_valid() {
        let ex = parse_line("+1").unwrap();
        assert!(ex.entries.is_empty());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_line("0 1:2").is_err()); // zero label
        assert!(parse_line("+1 0:2").is_err()); // 0-based index
        assert!(parse_line("+1 2:1 2:3").is_err()); // non-increasing
        assert!(parse_line("+1 a:b").is_err());
        assert!(parse_line("").is_err());
    }

    /// The malformed-input table: every deviation from the grammar is
    /// refused with a `line N, col C` position, never skipped.
    #[test]
    fn malformed_lines_are_refused_with_positions() {
        let cases: &[(&str, &str)] = &[
            ("+1 2:1 2:3", "duplicate index 2"),
            ("+1 3:1 2:3", "out-of-order index 2 after 3"),
            ("+1 0:2", "indices are 1-based"),
            ("+1 5000000000:1", "exceeds the supported maximum"),
            ("+1 2:abc", "bad value \"abc\""),
            ("x 1:1", "bad label \"x\""),
            ("+1 junk", "bad feature token"),
            ("+1 :5", "bad index"),
            ("", "empty line"),
            ("   ", "empty line"),
        ];
        for &(bad, want) in cases {
            let text = format!("+1 1:1\n{bad}\n-1 2:2\n");
            for reader in [Storage::Dense, Storage::Sparse] {
                let err = read_with_from(Cursor::new(text.as_str()), None, reader).unwrap_err();
                let msg = format!("{err:#}");
                assert!(msg.contains("line 2"), "{bad:?}: no line position in {msg:?}");
                assert!(msg.contains("col"), "{bad:?}: no column position in {msg:?}");
                assert!(msg.contains(want), "{bad:?}: {msg:?} does not mention {want:?}");
            }
        }
    }

    #[test]
    fn comments_are_allowed_everywhere_but_blank_lines_are_not() {
        let ok = "# leading comment\n+1 1:1 # trailing\n  # indented comment\n-1 2:2\n";
        let ds = read_from(Cursor::new(ok), None).unwrap();
        assert_eq!(ds.len(), 2);
        let err = read_from(Cursor::new("+1 1:1\n\n-1 2:2\n"), None).unwrap_err();
        assert!(format!("{err:#}").contains("empty line"));
    }

    #[test]
    fn read_densifies_and_infers_dim() {
        let text = "+1 1:1 3:3\n-1 2:2\n# comment\n+1 1:9\n";
        let ds = read_from(Cursor::new(text), None).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.row(0), &[1.0, 0.0, 3.0]);
        assert_eq!(ds.row(1), &[0.0, 2.0, 0.0]);
        assert_eq!(ds.labels(), &[1, -1, 1]);
    }

    #[test]
    fn force_dim_pads_and_validates() {
        let ds = read_from(Cursor::new("+1 1:1\n"), Some(5)).unwrap();
        assert_eq!(ds.dim(), 5);
        assert!(read_from(Cursor::new("+1 9:1\n"), Some(3)).is_err());
    }

    #[test]
    fn storage_selection_tracks_density() {
        // 4 stored entries over 2×8 cells = 0.25 density: at the
        // threshold, Auto keeps CSR.
        let sparse_text = "+1 1:1 8:2\n-1 2:1 5:-3\n";
        let dense = read_from(Cursor::new(sparse_text), None).unwrap();
        let sparse = read_with_from(Cursor::new(sparse_text), None, Storage::Sparse).unwrap();
        let auto = read_with_from(Cursor::new(sparse_text), None, Storage::Auto).unwrap();
        assert!(!dense.is_sparse());
        assert!(sparse.is_sparse());
        assert!(auto.is_sparse(), "0.25 density must stay CSR under Auto");
        assert_eq!(sparse, dense.to_sparse(), "CSR read == densify→sparsify");
        assert_eq!(sparse.to_dense(), dense);
        assert_eq!(auto, sparse);
        // A dense file (density 1.0) densifies under Auto.
        let dense_text = "+1 1:1 2:2\n-1 1:3 2:4\n";
        let auto = read_with_from(Cursor::new(dense_text), None, Storage::Auto).unwrap();
        assert!(!auto.is_sparse());
    }

    #[test]
    fn zero_valued_entries_are_dropped_but_count_for_dim() {
        let ds = read_with_from(Cursor::new("+1 2:1 7:0\n"), None, Storage::Sparse).unwrap();
        assert_eq!(ds.dim(), 7, "index 7 sets the dimension even at value 0");
        assert_eq!(ds.nnz(), 1, "the zero-valued entry is not stored");
        let dense = read_from(Cursor::new("+1 2:1 7:0\n"), None).unwrap();
        assert_eq!(ds.to_dense(), dense);
    }

    #[test]
    fn mapped_read_is_identical_to_streamed_read() {
        let dir = std::env::temp_dir().join("pasmo-libsvm-mapped-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.libsvm");
        // CRLF line, comment, negative values, no trailing newline
        std::fs::write(&path, "+1 1:0.5 4:-2\r\n# note\n-1 2:1e-3\n+1 3:7").unwrap();
        for storage in [Storage::Dense, Storage::Sparse, Storage::Auto] {
            let streamed = read_with(&path, None, storage).unwrap();
            let mapped = read_mapped(&path, None, storage).unwrap();
            assert_eq!(streamed, mapped, "{storage:?}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_file_is_a_positioned_error_not_a_partial_dataset() {
        let dir = std::env::temp_dir().join("pasmo-libsvm-trunc-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.libsvm");
        let full = "+1 1:0.5 3:1.25\n-1 2:0.75 4:-1.5\n+1 1:2.5 4:0.125\n";
        // Cut right after the last ':' — the final token has no value.
        let cut = full.rfind(':').unwrap() + 1;
        std::fs::write(&path, &full.as_bytes()[..cut]).unwrap();
        for result in [
            read_with(&path, None, Storage::Sparse),
            read_mapped(&path, None, Storage::Sparse),
        ] {
            let err = result.unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("line 3"), "no position in {msg:?}");
            assert!(msg.contains("bad value"), "{msg:?}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn regression_reader_keeps_real_targets() {
        let text = "0.5 1:1\n-2.25 2:3\n0 1:7\n";
        let ds = read_regression_from(Cursor::new(text), None).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 2);
        assert_eq!(ds.target(0), 0.5);
        assert_eq!(ds.target(1), -2.25);
        assert_eq!(ds.target(2), 0.0, "zero targets are valid for regression");
        assert_eq!(ds.row(1), &[0.0, 3.0]);
    }

    #[test]
    fn multiclass_reader_keeps_integer_classes() {
        let text = "3 1:1\n0 2:1\n-7 1:2 2:2\n";
        let ds = read_multiclass_from(Cursor::new(text), None).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.label(0), 3);
        assert_eq!(ds.label(1), 0);
        assert_eq!(ds.label(2), -7);
        assert_eq!(ds.classes(), vec![-7, 0, 3]);
    }

    #[test]
    fn multiclass_reader_rejects_fractional_labels() {
        let err = read_multiclass_from(Cursor::new("1.5 1:1\n"), None).unwrap_err();
        assert!(format!("{err:#}").contains("not an integer"), "{err:#}");
    }

    #[test]
    fn binary_reader_rejects_zero_label_with_line_number() {
        let err = read_from(Cursor::new("+1 1:1\n0 1:2\n"), None).unwrap_err();
        assert!(format!("{err:#}").contains("line 2"), "{err:#}");
    }

    #[test]
    fn regression_and_multiclass_round_trip_through_files() {
        let dir = std::env::temp_dir().join("pasmo-libsvm-rt-test");
        std::fs::create_dir_all(&dir).unwrap();

        let rpath = dir.join("reg.libsvm");
        let mut rd = crate::data::regression::RegressionDataset::with_dim(2);
        rd.push(&[1.5, 0.0], 0.25);
        rd.push(&[0.0, -2.0], -3.5);
        write_regression(&rd, &rpath).unwrap();
        let rrt = read_regression(&rpath, Some(2)).unwrap();
        assert_eq!(rd, rrt);

        let mpath = dir.join("multi.libsvm");
        let mut md = MulticlassDataset::with_dim(2);
        md.push(&[1.0, 2.0], 4);
        md.push(&[0.5, 0.0], -1);
        write_multiclass(&md, &mpath).unwrap();
        let mrt = read_multiclass(&mpath, Some(2)).unwrap();
        assert_eq!(md, mrt);

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn round_trip_through_file() {
        let dir = std::env::temp_dir().join("pasmo-libsvm-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.libsvm");
        let ds = Dataset::new(3, vec![1.0, 0.0, 2.5, 0.0, 0.0, 0.0], vec![1, -1]);
        write(&ds, &path).unwrap();
        let rt = read(&path, Some(3)).unwrap();
        assert_eq!(ds, rt);
        // the sparse twin writes a byte-identical file
        let spath = dir.join("toy-sparse.libsvm");
        write(&ds.to_sparse(), &spath).unwrap();
        assert_eq!(
            std::fs::read(&path).unwrap(),
            std::fs::read(&spath).unwrap(),
            "dense and sparse writers must produce identical bytes"
        );
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&spath).ok();
    }
}
