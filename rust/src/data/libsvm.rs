//! LIBSVM sparse text format reader/writer.
//!
//! Format: one example per line, `<label> <index>:<value> ...` with
//! 1-based, strictly increasing indices. We densify on read (the solver
//! and the PJRT artifacts are dense); `dim` is the max index seen unless
//! an explicit dimension is forced (to align train/test files).

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};

use super::dataset::Dataset;

/// One parsed sparse example.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseExample {
    /// Class label (±1, sign of the parsed value).
    pub label: i8,
    /// (0-based index, value), strictly increasing by index.
    pub entries: Vec<(usize, f32)>,
}

/// Parse one LIBSVM line. Accepts labels `+1/-1/1/-1.0` etc. (sign only).
pub fn parse_line(line: &str) -> Result<SparseExample> {
    let mut parts = line.split_ascii_whitespace();
    let label_tok = parts.next().context("empty line")?;
    let label_val: f64 = label_tok
        .parse()
        .with_context(|| format!("bad label {label_tok:?}"))?;
    let label = if label_val > 0.0 {
        1
    } else if label_val < 0.0 {
        -1
    } else {
        bail!("label must be nonzero (+1/-1), got {label_tok:?}");
    };
    let mut entries = Vec::new();
    let mut last = 0usize; // 1-based last index
    for tok in parts {
        if tok.starts_with('#') {
            break; // trailing comment
        }
        let (idx, val) = tok
            .split_once(':')
            .with_context(|| format!("bad feature token {tok:?}"))?;
        let idx: usize = idx.parse().with_context(|| format!("bad index {idx:?}"))?;
        if idx == 0 {
            bail!("indices are 1-based, got 0");
        }
        if idx <= last {
            bail!("indices must be strictly increasing ({last} then {idx})");
        }
        last = idx;
        let val: f32 = val.parse().with_context(|| format!("bad value {val:?}"))?;
        entries.push((idx - 1, val));
    }
    Ok(SparseExample { label, entries })
}

/// Read a LIBSVM file into a dense [`Dataset`]. `force_dim` overrides the
/// inferred dimension (must be >= max index).
pub fn read(path: &Path, force_dim: Option<usize>) -> Result<Dataset> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    read_from(std::io::BufReader::new(file), force_dim)
}

/// Read from any buffered reader (unit-testable without touching disk).
pub fn read_from<R: BufRead>(reader: R, force_dim: Option<usize>) -> Result<Dataset> {
    let mut examples = Vec::new();
    let mut max_dim = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let ex = parse_line(trimmed)
            .with_context(|| format!("line {}", lineno + 1))?;
        if let Some((idx, _)) = ex.entries.last() {
            max_dim = max_dim.max(idx + 1);
        }
        examples.push(ex);
    }
    let dim = match force_dim {
        Some(d) => {
            if d < max_dim {
                bail!("force_dim {d} < max feature index {max_dim}");
            }
            d
        }
        None => max_dim.max(1),
    };
    let mut ds = Dataset::with_dim(dim);
    let mut row = vec![0f32; dim];
    for ex in &examples {
        row.iter_mut().for_each(|v| *v = 0.0);
        for &(i, v) in &ex.entries {
            row[i] = v;
        }
        ds.push(&row, ex.label);
    }
    Ok(ds)
}

/// Write a dataset in LIBSVM format (zero entries skipped).
pub fn write(ds: &Dataset, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for i in 0..ds.len() {
        write!(w, "{}", if ds.label(i) > 0 { "+1" } else { "-1" })?;
        for (j, &v) in ds.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(w, " {}:{}", j + 1, v)?;
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_basic_lines() {
        let ex = parse_line("+1 1:0.5 3:2 7:-1.25").unwrap();
        assert_eq!(ex.label, 1);
        assert_eq!(ex.entries, vec![(0, 0.5), (2, 2.0), (6, -1.25)]);
        let ex = parse_line("-1.0 2:1e-3").unwrap();
        assert_eq!(ex.label, -1);
        assert_eq!(ex.entries, vec![(1, 1e-3)]);
    }

    #[test]
    fn label_only_line_is_valid() {
        let ex = parse_line("+1").unwrap();
        assert!(ex.entries.is_empty());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_line("0 1:2").is_err()); // zero label
        assert!(parse_line("+1 0:2").is_err()); // 0-based index
        assert!(parse_line("+1 2:1 2:3").is_err()); // non-increasing
        assert!(parse_line("+1 a:b").is_err());
        assert!(parse_line("").is_err());
    }

    #[test]
    fn read_densifies_and_infers_dim() {
        let text = "+1 1:1 3:3\n-1 2:2\n\n# comment\n+1 1:9\n";
        let ds = read_from(Cursor::new(text), None).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.row(0), &[1.0, 0.0, 3.0]);
        assert_eq!(ds.row(1), &[0.0, 2.0, 0.0]);
        assert_eq!(ds.labels(), &[1, -1, 1]);
    }

    #[test]
    fn force_dim_pads_and_validates() {
        let ds = read_from(Cursor::new("+1 1:1\n"), Some(5)).unwrap();
        assert_eq!(ds.dim(), 5);
        assert!(read_from(Cursor::new("+1 9:1\n"), Some(3)).is_err());
    }

    #[test]
    fn round_trip_through_file() {
        let dir = std::env::temp_dir().join("pasmo-libsvm-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.libsvm");
        let ds = Dataset::new(3, vec![1.0, 0.0, 2.5, 0.0, 0.0, 0.0], vec![1, -1]);
        write(&ds, &path).unwrap();
        let rt = read(&path, Some(3)).unwrap();
        assert_eq!(ds, rt);
        std::fs::remove_file(&path).ok();
    }
}
