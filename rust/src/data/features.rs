//! The feature substrate: one [`Features`] value holds a dataset's rows
//! in either dense row-major or CSR sparse form, and [`Row`] is the
//! zero-copy per-row view the kernel and scorer layers consume.
//!
//! ## Bit-parity contract
//!
//! Every arithmetic helper here ([`Row::dot`], [`Row::sqnorm`],
//! [`Row::sqdist`]) is **bit-identical** across backends, not merely
//! close: the sparse paths visit stored entries in ascending column
//! order and skip only coordinates whose densified value is exactly
//! `+0.0`. On an `f64` accumulator seeded at `+0.0`, adding
//! `x·(±0.0) = ±0.0` (dot) or `(0−0)² = +0.0` (sqdist) is the identity
//! — the accumulator can never itself become `-0.0` once any term is
//! added, because IEEE-754 round-to-nearest gives `(+0.0) + (±0.0) =
//! +0.0` and exact cancellation of nonzeros also yields `+0.0`. Skipping
//! those terms therefore reproduces the dense feature-order sum bit for
//! bit. The dense↔sparse parity wall in `tests/sparse_parity.rs` pins
//! this contract across the whole train/score stack.
//!
//! Sparsification keeps every entry whose bits are not `±0.0` — NaN and
//! infinities are preserved, so converting storage never changes what a
//! kernel sees.

/// True when `v` must be stored by a sparse row: anything but `±0.0`.
/// (Bit test rather than `v != 0.0`, so NaN is kept and no float
/// equality is involved.)
#[inline]
fn is_stored(v: f32) -> bool {
    v.to_bits() << 1 != 0
}

/// Feature storage for a row-indexed `len × dim` matrix: dense
/// row-major, or CSR sparse (`offsets`/`indices`/`values`).
#[derive(Debug, Clone, PartialEq)]
pub enum Features {
    /// Dense row-major storage: `rows.len() == len · dim`.
    Dense {
        /// Feature dimension d (> 0).
        dim: usize,
        /// Row-major `len × dim` feature block.
        rows: Vec<f32>,
    },
    /// CSR sparse storage: row `i` owns
    /// `indices[offsets[i]..offsets[i+1]]` (0-based column ids, strictly
    /// increasing within the row) and the matching `values` slice.
    Sparse {
        /// Feature dimension d (> 0); every stored index is `< dim`.
        dim: usize,
        /// Row start offsets: `len + 1` entries, `offsets[0] == 0`,
        /// non-decreasing, last entry `== indices.len()`.
        offsets: Vec<usize>,
        /// 0-based column indices, strictly increasing within each row.
        indices: Vec<u32>,
        /// Stored values, parallel to `indices`.
        values: Vec<f32>,
    },
}

impl Features {
    /// Dense storage from a row-major block (`rows.len()` must be a
    /// multiple of `dim`).
    pub fn dense(dim: usize, rows: Vec<f32>) -> Features {
        assert!(dim > 0, "feature dimension must be positive");
        assert!(
            rows.len() % dim == 0,
            "feature block of {} floats is not a multiple of dim {dim}",
            rows.len()
        );
        Features::Dense { dim, rows }
    }

    /// An empty dense matrix of the given dimension.
    pub fn dense_with_dim(dim: usize) -> Features {
        Features::dense(dim, Vec::new())
    }

    /// An empty CSR sparse matrix of the given dimension.
    pub fn sparse_with_dim(dim: usize) -> Features {
        assert!(dim > 0, "feature dimension must be positive");
        Features::Sparse { dim, offsets: vec![0], indices: Vec::new(), values: Vec::new() }
    }

    /// CSR storage from raw parts, validating the representation
    /// invariants (offset shape, index bounds and per-row ordering).
    pub fn from_csr(
        dim: usize,
        offsets: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> Features {
        assert!(dim > 0, "feature dimension must be positive");
        assert!(!offsets.is_empty() && offsets[0] == 0, "offsets must start at 0");
        assert!(
            *offsets.last().unwrap_or(&0) == indices.len(),
            "last offset {} != {} stored entries",
            offsets.last().unwrap_or(&0),
            indices.len()
        );
        assert!(indices.len() == values.len(), "indices/values length mismatch");
        for w in offsets.windows(2) {
            assert!(w[0] <= w[1], "offsets must be non-decreasing");
            let row = &indices[w[0]..w[1]];
            for pair in row.windows(2) {
                assert!(
                    pair[0] < pair[1],
                    "indices within a row must be strictly increasing"
                );
            }
            if let Some(&last) = row.last() {
                assert!((last as usize) < dim, "index {last} out of range for dim {dim}");
            }
        }
        Features::Sparse { dim, offsets, indices, values }
    }

    /// An empty matrix with this matrix's backend and dimension.
    pub fn empty_like(&self) -> Features {
        match self {
            Features::Dense { dim, .. } => Features::dense_with_dim(*dim),
            Features::Sparse { dim, .. } => Features::sparse_with_dim(*dim),
        }
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        match self {
            Features::Dense { dim, rows } => rows.len() / dim,
            Features::Sparse { offsets, .. } => offsets.len() - 1,
        }
    }

    /// True when the matrix holds no rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Feature dimension d.
    pub fn dim(&self) -> usize {
        match self {
            Features::Dense { dim, .. } | Features::Sparse { dim, .. } => *dim,
        }
    }

    /// True for the CSR backend.
    pub fn is_sparse(&self) -> bool {
        matches!(self, Features::Sparse { .. })
    }

    /// Stored entries. Dense rows store every coordinate (`len · dim`);
    /// sparse rows store only their explicit entries.
    pub fn nnz(&self) -> usize {
        match self {
            Features::Dense { rows, .. } => rows.len(),
            Features::Sparse { indices, .. } => indices.len(),
        }
    }

    /// Stored entries as a fraction of the full `len · dim` grid
    /// (1.0 for dense storage and for an empty matrix).
    pub fn density(&self) -> f64 {
        let cells = self.len() * self.dim();
        if cells == 0 {
            1.0
        } else {
            self.nnz() as f64 / cells as f64
        }
    }

    /// Heap bytes held by the feature storage (the bytes-resident column
    /// of the density-sweep benches).
    pub fn resident_bytes(&self) -> usize {
        match self {
            Features::Dense { rows, .. } => rows.len() * std::mem::size_of::<f32>(),
            Features::Sparse { offsets, indices, values, .. } => {
                offsets.len() * std::mem::size_of::<usize>()
                    + indices.len() * std::mem::size_of::<u32>()
                    + values.len() * std::mem::size_of::<f32>()
            }
        }
    }

    /// Zero-copy view of row `i`.
    pub fn row(&self, i: usize) -> Row<'_> {
        match self {
            Features::Dense { dim, rows } => Row::Dense(&rows[i * dim..(i + 1) * dim]),
            Features::Sparse { dim, offsets, indices, values } => {
                let (lo, hi) = (offsets[i], offsets[i + 1]);
                Row::Sparse { dim: *dim, indices: &indices[lo..hi], values: &values[lo..hi] }
            }
        }
    }

    /// Append one dense row. The sparse backend keeps only the stored
    /// (non-`±0.0`) coordinates — bit-parity is unaffected (module
    /// docs).
    pub fn push_dense(&mut self, x: &[f32]) {
        assert!(x.len() == self.dim(), "row has {} features, expected {}", x.len(), self.dim());
        match self {
            Features::Dense { rows, .. } => rows.extend_from_slice(x),
            Features::Sparse { offsets, indices, values, .. } => {
                for (k, &v) in x.iter().enumerate() {
                    if is_stored(v) {
                        indices.push(k as u32);
                        values.push(v);
                    }
                }
                offsets.push(indices.len());
            }
        }
    }

    /// Append one sparse row given `(column, value)` entries with
    /// strictly increasing 0-based columns. The dense backend scatters
    /// them into a zero row.
    pub fn push_entries(&mut self, entries: &[(u32, f32)]) {
        let dim = self.dim();
        let mut last: Option<u32> = None;
        for &(idx, _) in entries {
            assert!((idx as usize) < dim, "index {idx} out of range for dim {dim}");
            assert!(
                last.map(|l| l < idx).unwrap_or(true),
                "entry columns must be strictly increasing"
            );
            last = Some(idx);
        }
        match self {
            Features::Dense { dim, rows } => {
                let base = rows.len();
                rows.resize(base + *dim, 0.0);
                for &(idx, v) in entries {
                    rows[base + idx as usize] = v;
                }
            }
            Features::Sparse { offsets, indices, values, .. } => {
                for &(idx, v) in entries {
                    indices.push(idx);
                    values.push(v);
                }
                offsets.push(indices.len());
            }
        }
    }

    /// Append a row view (from either backend) preserving *this*
    /// matrix's backend.
    pub fn push_row(&mut self, r: Row<'_>) {
        assert!(r.dim() == self.dim(), "row dim {} != matrix dim {}", r.dim(), self.dim());
        match r {
            Row::Dense(x) => self.push_dense(x),
            Row::Sparse { indices, values, .. } => match self {
                Features::Dense { dim, rows } => {
                    let base = rows.len();
                    rows.resize(base + *dim, 0.0);
                    for (k, &idx) in indices.iter().enumerate() {
                        rows[base + idx as usize] = values[k];
                    }
                }
                Features::Sparse { offsets, indices: di, values: dv, .. } => {
                    di.extend_from_slice(indices);
                    dv.extend_from_slice(values);
                    offsets.push(di.len());
                }
            },
        }
    }

    /// Gather the rows named by `idx` (with repetition allowed) into a
    /// new matrix with the same backend.
    pub fn gather(&self, idx: &[usize]) -> Features {
        let mut out = self.empty_like();
        match (self, &mut out) {
            (Features::Dense { dim, rows }, Features::Dense { rows: or, .. }) => {
                or.reserve(idx.len() * dim);
                for &i in idx {
                    or.extend_from_slice(&rows[i * dim..(i + 1) * dim]);
                }
            }
            (
                Features::Sparse { offsets, indices, values, .. },
                Features::Sparse { offsets: oo, indices: oi, values: ov, .. },
            ) => {
                for &i in idx {
                    let (lo, hi) = (offsets[i], offsets[i + 1]);
                    oi.extend_from_slice(&indices[lo..hi]);
                    ov.extend_from_slice(&values[lo..hi]);
                    oo.push(oi.len());
                }
            }
            // `empty_like` returns the same variant as `self`.
            _ => unreachable!("gather target backend matches the source"),
        }
        out
    }

    /// A dense copy (identity on the dense backend).
    pub fn to_dense(&self) -> Features {
        match self {
            Features::Dense { .. } => self.clone(),
            Features::Sparse { dim, offsets, indices, values } => {
                let len = offsets.len() - 1;
                let mut rows = vec![0f32; len * dim];
                for i in 0..len {
                    let base = i * dim;
                    for p in offsets[i]..offsets[i + 1] {
                        rows[base + indices[p] as usize] = values[p];
                    }
                }
                Features::Dense { dim: *dim, rows }
            }
        }
    }

    /// A CSR copy keeping only stored (non-`±0.0`) entries (identity on
    /// the sparse backend).
    pub fn to_sparse(&self) -> Features {
        match self {
            Features::Sparse { .. } => self.clone(),
            Features::Dense { dim, rows } => {
                let mut out = Features::sparse_with_dim(*dim);
                for r in rows.chunks_exact(*dim) {
                    out.push_dense(r);
                }
                out
            }
        }
    }
}

/// Zero-copy view of one feature row, from either backend. `Copy`, so
/// it can be captured by the scoped-thread closures of the tiled kernel
/// loops.
#[derive(Debug, Clone, Copy)]
pub enum Row<'a> {
    /// A dense row: one `f32` per coordinate.
    Dense(&'a [f32]),
    /// A sparse row: `values[k]` lives at column `indices[k]`; every
    /// other coordinate is `+0.0`.
    Sparse {
        /// Feature dimension of the owning matrix.
        dim: usize,
        /// Strictly increasing 0-based column indices.
        indices: &'a [u32],
        /// Stored values, parallel to `indices`.
        values: &'a [f32],
    },
}

impl Row<'_> {
    /// The row's feature dimension.
    pub fn dim(&self) -> usize {
        match self {
            Row::Dense(x) => x.len(),
            Row::Sparse { dim, .. } => *dim,
        }
    }

    /// Stored entries (dense rows store every coordinate).
    pub fn nnz(&self) -> usize {
        match self {
            Row::Dense(x) => x.len(),
            Row::Sparse { indices, .. } => indices.len(),
        }
    }

    /// ⟨self, other⟩ on an `f64` accumulator, bit-identical across
    /// backends (module docs: skipped zero terms are exact no-ops).
    pub fn dot(&self, other: Row<'_>) -> f64 {
        match (*self, other) {
            (Row::Dense(a), Row::Dense(b)) => {
                let n = a.len().min(b.len());
                let mut s = 0f64;
                for k in 0..n {
                    s += a[k] as f64 * b[k] as f64;
                }
                s
            }
            (Row::Dense(a), Row::Sparse { indices, values, .. }) => {
                let mut s = 0f64;
                for (p, &idx) in indices.iter().enumerate() {
                    s += a[idx as usize] as f64 * values[p] as f64;
                }
                s
            }
            (Row::Sparse { indices, values, .. }, Row::Dense(b)) => {
                let mut s = 0f64;
                for (p, &idx) in indices.iter().enumerate() {
                    s += values[p] as f64 * b[idx as usize] as f64;
                }
                s
            }
            (
                Row::Sparse { indices: ia, values: va, .. },
                Row::Sparse { indices: ib, values: vb, .. },
            ) => {
                let (mut p, mut q) = (0usize, 0usize);
                let mut s = 0f64;
                while p < ia.len() && q < ib.len() {
                    match ia[p].cmp(&ib[q]) {
                        std::cmp::Ordering::Less => p += 1,
                        std::cmp::Ordering::Greater => q += 1,
                        std::cmp::Ordering::Equal => {
                            s += va[p] as f64 * vb[q] as f64;
                            p += 1;
                            q += 1;
                        }
                    }
                }
                s
            }
        }
    }

    /// ‖self‖² = ⟨self, self⟩, same accumulation as [`Row::dot`].
    pub fn sqnorm(&self) -> f64 {
        match self {
            Row::Dense(x) => x.iter().map(|&v| v as f64 * v as f64).sum(),
            Row::Sparse { values, .. } => values.iter().map(|&v| v as f64 * v as f64).sum(),
        }
    }

    /// ‖self − other‖² with differences taken in `f64` (matching the
    /// dense kernel's direct path). Sparse×sparse merges the index
    /// union; mixed pairs walk every coordinate of the dense side so the
    /// term order — and therefore every bit — matches the dense loop.
    pub fn sqdist(&self, other: Row<'_>) -> f64 {
        match (*self, other) {
            (Row::Dense(a), Row::Dense(b)) => {
                let n = a.len().min(b.len());
                let mut s = 0f64;
                for k in 0..n {
                    let d = a[k] as f64 - b[k] as f64;
                    s += d * d;
                }
                s
            }
            (Row::Dense(a), Row::Sparse { indices, values, .. }) => {
                let mut s = 0f64;
                let mut p = 0usize;
                for (k, &av) in a.iter().enumerate() {
                    let bv = if p < indices.len() && indices[p] as usize == k {
                        let v = values[p];
                        p += 1;
                        v
                    } else {
                        0.0
                    };
                    let d = av as f64 - bv as f64;
                    s += d * d;
                }
                s
            }
            (Row::Sparse { indices, values, .. }, Row::Dense(b)) => {
                let mut s = 0f64;
                let mut p = 0usize;
                for (k, &bv) in b.iter().enumerate() {
                    let av = if p < indices.len() && indices[p] as usize == k {
                        let v = values[p];
                        p += 1;
                        v
                    } else {
                        0.0
                    };
                    let d = av as f64 - bv as f64;
                    s += d * d;
                }
                s
            }
            (
                Row::Sparse { indices: ia, values: va, .. },
                Row::Sparse { indices: ib, values: vb, .. },
            ) => {
                let (mut p, mut q) = (0usize, 0usize);
                let mut s = 0f64;
                while p < ia.len() || q < ib.len() {
                    let d = if q >= ib.len() || (p < ia.len() && ia[p] < ib[q]) {
                        let d = va[p] as f64 - 0.0;
                        p += 1;
                        d
                    } else if p >= ia.len() || ib[q] < ia[p] {
                        let d = 0.0 - vb[q] as f64;
                        q += 1;
                        d
                    } else {
                        let d = va[p] as f64 - vb[q] as f64;
                        p += 1;
                        q += 1;
                        d
                    };
                    s += d * d;
                }
                s
            }
        }
    }

    /// Densify into `out` (length `dim`), zero-filling the gaps.
    pub fn densify_into(&self, out: &mut [f32]) {
        assert!(out.len() == self.dim(), "buffer len {} != dim {}", out.len(), self.dim());
        match self {
            Row::Dense(x) => out.copy_from_slice(x),
            Row::Sparse { indices, values, .. } => {
                out.iter_mut().for_each(|v| *v = 0.0);
                for (p, &idx) in indices.iter().enumerate() {
                    out[idx as usize] = values[p];
                }
            }
        }
    }

    /// The row as an owned dense vector.
    pub fn to_vec(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.dim()];
        self.densify_into(&mut out);
        out
    }

    /// Visit the stored entries in ascending column order (dense rows
    /// visit every coordinate).
    pub fn for_each_entry(&self, mut f: impl FnMut(u32, f32)) {
        match self {
            Row::Dense(x) => {
                for (k, &v) in x.iter().enumerate() {
                    f(k as u32, v);
                }
            }
            Row::Sparse { indices, values, .. } => {
                for (p, &idx) in indices.iter().enumerate() {
                    f(idx, values[p]);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    /// A random dense block with a controllable fraction of exact zeros
    /// (the interesting regime for the skip-zeros parity argument).
    fn random_rows(n: usize, d: usize, density: f64, rng: &mut Pcg) -> Vec<f32> {
        (0..n * d)
            .map(|_| {
                if rng.bernoulli(density) {
                    rng.normal() as f32
                } else {
                    0.0
                }
            })
            .collect()
    }

    #[test]
    fn dense_and_sparse_agree_bitwise_on_dot_sqnorm_sqdist() {
        let mut rng = Pcg::new(7);
        for &density in &[1.0, 0.5, 0.05] {
            let (n, d) = (17, 23);
            let block = random_rows(n, d, density, &mut rng);
            let dense = Features::dense(d, block);
            let sparse = dense.to_sparse();
            assert_eq!(sparse.len(), n);
            for i in 0..n {
                for j in 0..n {
                    let (di, dj) = (dense.row(i), dense.row(j));
                    let (si, sj) = (sparse.row(i), sparse.row(j));
                    // all four backend pairings, every helper
                    for (a, b) in [(di, dj), (di, sj), (si, dj), (si, sj)] {
                        assert_eq!(
                            a.dot(b).to_bits(),
                            di.dot(dj).to_bits(),
                            "dot i={i} j={j} density={density}"
                        );
                        assert_eq!(
                            a.sqdist(b).to_bits(),
                            di.sqdist(dj).to_bits(),
                            "sqdist i={i} j={j} density={density}"
                        );
                    }
                    assert_eq!(si.sqnorm().to_bits(), di.sqnorm().to_bits(), "sqnorm {i}");
                }
            }
        }
    }

    #[test]
    fn round_trips_preserve_logical_content() {
        let mut rng = Pcg::new(8);
        let block = random_rows(9, 11, 0.3, &mut rng);
        let dense = Features::dense(11, block);
        let sparse = dense.to_sparse();
        assert_eq!(sparse.to_dense(), dense);
        assert_eq!(sparse.to_sparse(), sparse);
        for i in 0..dense.len() {
            assert_eq!(sparse.row(i).to_vec(), dense.row(i).to_vec(), "row {i}");
            assert_eq!(sparse.row(i).dim(), 11);
        }
    }

    #[test]
    fn push_paths_agree_across_backends() {
        let mut dense = Features::dense_with_dim(5);
        let mut sparse = Features::sparse_with_dim(5);
        dense.push_dense(&[0.0, 1.5, 0.0, -2.0, 0.0]);
        sparse.push_dense(&[0.0, 1.5, 0.0, -2.0, 0.0]);
        dense.push_entries(&[(0, 3.0), (4, 0.5)]);
        sparse.push_entries(&[(0, 3.0), (4, 0.5)]);
        // cross-backend push_row
        dense.push_row(sparse.row(0));
        sparse.push_row(dense.row(0));
        assert_eq!(dense.len(), 3);
        assert_eq!(sparse.len(), 3);
        for i in 0..3 {
            assert_eq!(dense.row(i).to_vec(), sparse.row(i).to_vec(), "row {i}");
        }
        assert_eq!(sparse.nnz(), 6);
        assert!(sparse.is_sparse() && !dense.is_sparse());
    }

    #[test]
    fn gather_preserves_backend_and_rows() {
        let mut rng = Pcg::new(9);
        let dense = Features::dense(6, random_rows(8, 6, 0.4, &mut rng));
        let sparse = dense.to_sparse();
        let idx = [3usize, 0, 3, 7];
        let gd = dense.gather(&idx);
        let gs = sparse.gather(&idx);
        assert!(!gd.is_sparse() && gs.is_sparse());
        assert_eq!(gd.len(), 4);
        for (k, &i) in idx.iter().enumerate() {
            assert_eq!(gd.row(k).to_vec(), dense.row(i).to_vec());
            assert_eq!(gs.row(k).to_vec(), dense.row(i).to_vec());
        }
    }

    #[test]
    fn sparsification_keeps_nan_and_negative_zero_semantics() {
        let mut sparse = Features::sparse_with_dim(3);
        sparse.push_dense(&[f32::NAN, -0.0, 1.0]);
        // NaN is stored; -0.0 densifies back to +0.0, which every kernel
        // helper treats identically (module docs).
        assert_eq!(sparse.nnz(), 2);
        let v = sparse.row(0).to_vec();
        assert!(v[0].is_nan());
        assert_eq!(v[1].to_bits(), 0.0f32.to_bits());
        assert_eq!(v[2], 1.0);
    }

    #[test]
    fn density_and_resident_bytes_reflect_storage() {
        let mut rng = Pcg::new(10);
        let dense = Features::dense(100, random_rows(50, 100, 0.02, &mut rng));
        let sparse = dense.to_sparse();
        assert!(sparse.density() < 0.1, "density {}", sparse.density());
        assert!(
            sparse.resident_bytes() < dense.resident_bytes(),
            "sparse {} !< dense {}",
            sparse.resident_bytes(),
            dense.resident_bytes()
        );
        assert!((dense.density() - 1.0).abs() < 1e-15);
    }

    #[test]
    fn from_csr_validates_and_matches_pushes() {
        let f = Features::from_csr(4, vec![0, 2, 2, 3], vec![0, 3, 1], vec![1.0, 2.0, 3.0]);
        assert_eq!(f.len(), 3);
        assert_eq!(f.row(0).to_vec(), vec![1.0, 0.0, 0.0, 2.0]);
        assert_eq!(f.row(1).to_vec(), vec![0.0; 4]);
        assert_eq!(f.row(2).to_vec(), vec![0.0, 3.0, 0.0, 0.0]);
    }
}
