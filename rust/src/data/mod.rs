//! Dataset substrate: the dense/CSR-sparse feature matrix, dataset
//! types (binary, regression, multiclass), LIBSVM-format IO, feature
//! scaling, synthetic generators for the paper's 22-dataset suite, and
//! permutation / cross-validation splits.

pub mod dataset;
pub mod features;
pub mod libsvm;
pub mod multiclass;
pub mod regression;
pub mod scale;
pub mod splits;
pub mod suite;
pub mod synth;

pub use dataset::Dataset;
pub use features::{Features, Row};
